//! Microbenchmarks of the softfloat substrate — the hot path of the
//! whole cluster simulator (every simulated FP instruction lands here).

use minifloat_nn::softfloat::{add, cast, ex_fma, fma, mul};
use minifloat_nn::util::bench::Bencher;
use minifloat_nn::util::rng::Rng;
use minifloat_nn::{RoundingMode, FP16, FP32, FP64, FP8};

fn main() {
    let mut b = Bencher::new();
    let rm = RoundingMode::Rne;
    let mut rng = Rng::new(1);
    let vals16: Vec<u64> = (0..1024).map(|_| rng.next_u64() & 0x7bff).collect();
    let vals32: Vec<u64> = (0..1024).map(|_| rng.next_u64() & 0x7f7f_ffff).collect();
    let vals64: Vec<u64> = (0..1024).map(|_| rng.next_u64() & 0x7fef_ffff_ffff_ffff).collect();

    println!("== softfloat op throughput (1024 ops per iteration) ==");
    b.bench_throughput("fp16 add x1024", 1024.0, || {
        let mut acc = 0u64;
        for w in 0..1024 {
            acc ^= add(FP16, vals16[w], vals16[(w + 1) & 1023], rm);
        }
        acc
    });
    b.bench_throughput("fp16 mul x1024", 1024.0, || {
        let mut acc = 0u64;
        for w in 0..1024 {
            acc ^= mul(FP16, vals16[w], vals16[(w + 7) & 1023], rm);
        }
        acc
    });
    b.bench_throughput("fp32 fma chain x1024", 1024.0, || {
        let mut acc = 0u64;
        for w in 0..1024 {
            acc = fma(FP32, vals32[w], vals32[(w + 3) & 1023], acc & 0x7f7f_ffff, rm);
        }
        acc
    });
    b.bench_throughput("fp64 fma chain x1024", 1024.0, || {
        let mut acc = 0u64;
        for w in 0..1024 {
            acc = fma(FP64, vals64[w], vals64[(w + 3) & 1023], acc & 0x7fef_ffff_ffff_ffff, rm);
        }
        acc
    });
    b.bench_throughput("exfma fp16->fp32 chain x1024", 1024.0, || {
        let mut acc = 0u64;
        for w in 0..1024 {
            acc = ex_fma(FP16, FP32, vals16[w], vals16[(w + 5) & 1023], acc & 0x7f7f_ffff, rm);
        }
        acc
    });
    b.bench_throughput("cast fp32->fp8 x1024", 1024.0, || {
        let mut acc = 0u64;
        for w in 0..1024 {
            acc ^= cast(FP32, FP8, vals32[w], rm);
        }
        acc
    });
}
