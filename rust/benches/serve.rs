//! Serving throughput: continuous (iteration-level) batching vs the
//! legacy whole-batch scheduler vs a batch-of-1 baseline.
//!
//! Every arm replays the *same* seeded trace over the same two frozen
//! tenants (HFP8 + FP32), under deliberate overload: ~64 arrivals/tick
//! against a 64-row batch limit and a 12-tick deadline. The legacy
//! run-to-completion scheduler tops out at `max_batch / pipeline
//! latency` ≈ 21 admissions per tenant-tick, so its queues grow without
//! bound and deadlines blow; continuous batching admits a fresh cohort
//! every tick and keeps latency near the pipeline floor. Before any
//! timing, the run gates on correctness:
//!
//! * determinism — two replays (and shard counts 1 vs 4) must produce
//!   bit-identical responses and byte-identical stats;
//! * routing — every expanding-pair tenant GEMM must take the packed
//!   zero-repack route;
//! * **goodput — continuous must deliver ≥ 1.5x the legacy within-
//!   deadline completions per virtual tick, at a p99 latency no worse**
//!   (the CI-blocking gate for the scheduler rebuild);
//! * **throughput — continuous must be ≥ 2x the batch-of-1 baseline on
//!   the wall clock** (best-of-3 minima per arm, so one scheduler
//!   hiccup cannot flake the gate);
//! * backpressure — a bursty overload arm with a token bucket and a
//!   bounded queue must actually shed (and replay deterministically).
//!
//! Appends a trajectory point to `BENCH_serve.json` in the working
//! directory, next to `BENCH_gemm.json` and `BENCH_train.json`.

use minifloat_nn::prelude::*;
use minifloat_nn::serve::{sim, BatchMode};
use std::io::Write;
use std::time::Instant;

fn frozen(session: &Session, policy: PrecisionPolicy, steps: usize) -> InferenceModel {
    let mut tr = session.native_trainer(policy).expect("valid train plan");
    tr.train(steps, 0).expect("train");
    InferenceModel::freeze(session, tr.model(), tr.policy()).expect("freeze")
}

/// Best-of-3 wall seconds for one replay arm (the minimum is the
/// noise-robust estimator: scheduler preemption only ever adds time).
fn best_of_3(mut f: impl FnMut()) -> f64 {
    (0..3)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .fold(f64::INFINITY, f64::min)
}

fn main() {
    let session = Session::builder().seed(42).build();
    let n_requests = 3840;
    println!(
        "== serving: continuous vs whole-batch vs batch-of-1, \
         {n_requests}-request overloaded open loop ==\n"
    );

    let hfp8 = frozen(&session, PrecisionPolicy::hfp8(), 24);
    let fp32 = frozen(&session, PrecisionPolicy::fp32(), 24);
    let plan_with = |mode: BatchMode, max_batch: usize, shards: usize| {
        session
            .server()
            .tenant("hfp8", hfp8.clone())
            .tenant("fp32", fp32.clone())
            .max_batch(max_batch)
            .max_wait_ticks(4)
            .shards(shards)
            .batching(mode)
            .build()
            .expect("valid serve plan")
    };
    let continuous = plan_with(BatchMode::Continuous, 64, 4);
    let legacy = plan_with(BatchMode::WholeBatch, 64, 4);
    let batch_of_1 = plan_with(BatchMode::WholeBatch, 1, 4);
    // ~64 arrivals/tick split over two tenants, each due 12 ticks after
    // arrival (4x the 3-wave pipeline latency): comfortably feasible
    // for continuous admission, structurally infeasible for
    // run-to-completion once the backlog builds.
    let trace =
        sim::Trace::open_loop(42, &[8, 8], n_requests, 1.0 / 64.0, Some(12)).expect("trace");

    // Gate 1: determinism across runs and shard counts, plus routing.
    let run = |plan: &ServePlan| {
        let mut server = plan.server();
        let responses = sim::replay(&mut server, &trace).expect("replay");
        (responses, server.stats().clone())
    };
    let (r1, cont_stats) = run(&continuous);
    let (r2, s2) = run(&continuous);
    let (r3, s3) = run(&plan_with(BatchMode::Continuous, 64, 1));
    assert_eq!(r1.len(), n_requests);
    let bits = |rs: &[minifloat_nn::serve::Response]| -> Vec<Vec<u64>> {
        rs.iter().map(|r| r.logits.iter().map(|v| v.to_bits()).collect()).collect()
    };
    assert_eq!(bits(&r1), bits(&r2), "same trace must replay bit-identically");
    assert_eq!(bits(&r1), bits(&r3), "shard count must not change a single bit");
    assert_eq!(cont_stats.summary_json(), s2.summary_json(), "stats must replay identically");
    assert_eq!(
        cont_stats.summary_json(),
        s3.summary_json(),
        "stats must be shard-count independent"
    );
    assert_eq!(
        cont_stats.tenants[0].packed_runs, cont_stats.tenants[0].gemm_calls,
        "hfp8 tenant: every GEMM must take the packed zero-repack route"
    );
    assert!(cont_stats.tenants[0].gemm_calls > 0 && cont_stats.tenants[1].gemm_calls > 0);
    // And the legacy reference computes the same bits on its own
    // schedule — scheduling policy must never touch a logit.
    let (r_legacy, legacy_stats) = run(&legacy);
    let mut by_id = r_legacy.clone();
    by_id.sort_by_key(|r| r.id);
    let mut r1_by_id = r1.clone();
    r1_by_id.sort_by_key(|r| r.id);
    assert_eq!(
        bits(&r1_by_id),
        bits(&by_id),
        "continuous vs whole-batch must agree on every logit bit"
    );
    println!(
        "determinism: 2 runs x shards {{1,4}} x modes {{cont,whole}} bit-identical; \
         hfp8 routing 100% packed ✓\n"
    );

    // Gate 2: virtual-time goodput and tail latency (deterministic —
    // these come from the replayed stats, not the wall clock).
    let goodput_cont = cont_stats.goodput_per_tick();
    let goodput_legacy = legacy_stats.goodput_per_tick();
    let goodput_ratio = goodput_cont / goodput_legacy.max(1e-12);
    let p99_cont = cont_stats.p99();
    let p99_legacy = legacy_stats.p99();
    println!(
        "goodput:  continuous {goodput_cont:.2} req/tick ({} misses) vs whole-batch \
         {goodput_legacy:.2} req/tick ({} misses) -> {goodput_ratio:.2}x (gate: >= 1.5x)",
        cont_stats.deadline_misses, legacy_stats.deadline_misses
    );
    println!("p99:      continuous {p99_cont} ticks vs whole-batch {p99_legacy} ticks\n");

    // Gate 3: wall-clock throughput, best-of-3 minima per arm.
    let cont_s = best_of_3(|| {
        run(&continuous);
    });
    let legacy_s = best_of_3(|| {
        run(&legacy);
    });
    let one_s = best_of_3(|| {
        run(&batch_of_1);
    });
    let cont_rps = n_requests as f64 / cont_s;
    let legacy_rps = n_requests as f64 / legacy_s;
    let one_rps = n_requests as f64 / one_s;
    let speedup = cont_rps / one_rps;
    println!(
        "wall (best of 3): continuous {cont_rps:.0} req/s, whole-batch {legacy_rps:.0} req/s, \
         batch-of-1 {one_rps:.0} req/s ({speedup:.1}x vs batch-of-1, gate: >= 2x)"
    );

    // Backpressure arm: MMPP bursts against a token bucket and a
    // bounded queue — sheds must actually happen, deterministically.
    let shed_plan = session
        .server()
        .tenant("hfp8", hfp8.clone())
        .tenant("fp32", fp32.clone())
        .max_batch(64)
        .max_wait_ticks(4)
        .shards(4)
        .queue_cap(64)
        .rate_limit("hfp8", 8.0, 32)
        .rate_limit("fp32", 8.0, 32)
        .build()
        .expect("valid shed plan");
    let bursty = sim::Trace::bursty(42, &[8, 8], 768, 1.0 / 64.0, 8.0, 32.0, Some(12))
        .expect("bursty trace");
    let shed_run = |plan: &ServePlan| {
        let mut server = plan.server();
        sim::replay(&mut server, &bursty).expect("replay");
        server.stats().clone()
    };
    let shed_stats = shed_run(&shed_plan);
    assert_eq!(
        shed_stats.summary_json(),
        shed_run(&shed_plan).summary_json(),
        "shed decisions must replay bit-for-bit"
    );
    let shed_rate = shed_stats.shed_rate();
    assert!(
        shed_stats.shed() > 0,
        "the overload arm must exercise admission control (0 sheds recorded)"
    );
    assert!(
        shed_stats.completed > 0 && shed_rate < 1.0,
        "admission control must shed the excess, not the service"
    );
    println!(
        "\nbackpressure: {} shed ({} rate-limited, {} queue-full, {:.1}% of offered), \
         {} served ✓",
        shed_stats.shed(),
        shed_stats.shed_rate_limited,
        shed_stats.shed_queue_full,
        shed_rate * 100.0,
        shed_stats.completed
    );

    // Trajectory point first (a failed gate should still leave data),
    // then the blocking asserts.
    let ts = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let json = format!(
        "{{\"bench\":\"serve_overload_{n_requests}req\",\"unix_time\":{ts},\
         \"continuous_rps\":{cont_rps:.1},\"legacy_rps\":{legacy_rps:.1},\
         \"batch_of_1_rps\":{one_rps:.1},\"speedup_vs_batch_of_1\":{speedup:.2},\
         \"goodput_cont\":{goodput_cont:.4},\"goodput_legacy\":{goodput_legacy:.4},\
         \"goodput_ratio\":{goodput_ratio:.2},\"p99_cont_ticks\":{p99_cont},\
         \"p99_legacy_ticks\":{p99_legacy},\"shed_rate\":{shed_rate:.4},\
         \"deterministic\":true,\"stats\":{}}}\n",
        cont_stats.summary_json()
    );
    match std::fs::OpenOptions::new().create(true).append(true).open("BENCH_serve.json") {
        Ok(mut f) => {
            let _ = f.write_all(json.as_bytes());
            println!("trajectory point appended to BENCH_serve.json");
        }
        Err(e) => eprintln!("could not write BENCH_serve.json: {e}"),
    }

    assert!(
        goodput_ratio >= 1.5 && p99_cont <= p99_legacy,
        "continuous batching must deliver >= 1.5x legacy goodput at a p99 no worse \
         (got {goodput_ratio:.2}x, p99 {p99_cont} vs {p99_legacy}) — the rebuild's \
         reason to exist"
    );
    println!("goodput gate passed: {goodput_ratio:.1}x >= 1.5x, p99 {p99_cont} <= {p99_legacy} ✓");
    assert!(
        speedup >= 2.0,
        "continuous batching must deliver at least 2x the batch-of-1 wall throughput \
         (got {speedup:.2}x)"
    );
    println!("throughput gate passed: {speedup:.1}x >= 2x ✓");
}
