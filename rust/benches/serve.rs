//! Serving throughput: dynamic batching vs a batch-of-1 baseline.
//!
//! Both servers replay the *same* seeded open-loop trace over the same
//! two frozen tenants (HFP8 + FP32). The unbatched baseline runs
//! `max_batch = 1`, so every request occupies a full 8-row padded GEMM
//! alone; the batched server coalesces up to 64 requests per dispatch.
//! Before any timing, the run gates on correctness:
//!
//! * determinism — two replays (and shard counts 1 vs 4) must produce
//!   bit-identical responses and identical stats;
//! * routing — every expanding-pair tenant GEMM must take the packed
//!   zero-repack route (the frozen weights were packed for exactly
//!   that);
//! * **throughput — the batched path must be at least 2x the unbatched
//!   baseline** (the CI-blocking gate: if batching stops paying for
//!   itself, the subsystem lost its reason to exist).
//!
//! Appends a trajectory point to `BENCH_serve.json` in the working
//! directory, next to `BENCH_gemm.json` and `BENCH_train.json`.

use minifloat_nn::prelude::*;
use minifloat_nn::serve::sim;
use minifloat_nn::util::bench::Bencher;
use std::io::Write;

fn frozen(session: &Session, policy: PrecisionPolicy, steps: usize) -> InferenceModel {
    let mut tr = session.native_trainer(policy).expect("valid train plan");
    tr.train(steps, 0).expect("train");
    InferenceModel::freeze(session, tr.model(), tr.policy()).expect("freeze")
}

fn main() {
    let session = Session::builder().seed(42).build();
    let n_requests = 384;
    println!("== serving: dynamic batching vs batch-of-1, {n_requests}-request open-loop trace ==\n");

    let hfp8 = frozen(&session, PrecisionPolicy::hfp8(), 24);
    let fp32 = frozen(&session, PrecisionPolicy::fp32(), 24);
    let plan_with = |max_batch: usize, shards: usize| {
        session
            .server()
            .tenant("hfp8", hfp8.clone())
            .tenant("fp32", fp32.clone())
            .max_batch(max_batch)
            .max_wait_ticks(4)
            .shards(shards)
            .build()
            .expect("valid serve plan")
    };
    let batched = plan_with(64, 4);
    let unbatched = plan_with(1, 4);
    // High arrival rate (8/tick) so the batcher actually has queues to
    // coalesce — the regime batching exists for.
    let trace =
        sim::Trace::open_loop(42, &[8, 8], n_requests, 1.0 / 8.0, None).expect("trace");

    // Gate 1: determinism across runs and shard counts, plus routing.
    let run = |plan: &ServePlan| {
        let mut server = plan.server();
        let responses = sim::replay(&mut server, &trace).expect("replay");
        (responses, server.stats().clone())
    };
    let (r1, s1) = run(&batched);
    let (r2, s2) = run(&batched);
    let (r3, s3) = run(&plan_with(64, 1));
    assert_eq!(r1.len(), n_requests);
    let bits = |rs: &[minifloat_nn::serve::Response]| -> Vec<Vec<u64>> {
        rs.iter().map(|r| r.logits.iter().map(|v| v.to_bits()).collect()).collect()
    };
    assert_eq!(bits(&r1), bits(&r2), "same trace must replay bit-identically");
    assert_eq!(bits(&r1), bits(&r3), "shard count must not change a single bit");
    assert_eq!(s1.summary_json(), s2.summary_json(), "stats must replay identically");
    assert_eq!(s1.summary_json(), s3.summary_json(), "stats must be shard-count independent");
    assert_eq!(
        s1.tenants[0].packed_runs, s1.tenants[0].gemm_calls,
        "hfp8 tenant: every GEMM must take the packed zero-repack route"
    );
    assert!(s1.tenants[0].gemm_calls > 0 && s1.tenants[1].gemm_calls > 0);
    println!(
        "determinism: 2 runs x shards {{1,4}} bit-identical; hfp8 routing 100% packed ✓\n"
    );

    // Gate 2 setup: time both paths on wall clock.
    let mut bench = Bencher::new();
    let batched_s = bench
        .bench_throughput("batched (max_batch 64)", n_requests as f64, || run(&batched).0)
        .median
        .as_secs_f64();
    let unbatched_s = bench
        .bench_throughput("unbatched (max_batch 1)", n_requests as f64, || run(&unbatched).0)
        .median
        .as_secs_f64();
    let batched_rps = n_requests as f64 / batched_s;
    let unbatched_rps = n_requests as f64 / unbatched_s;
    let speedup = batched_rps / unbatched_rps;
    println!(
        "\nthroughput: batched {batched_rps:.0} req/s vs unbatched {unbatched_rps:.0} req/s \
         ({speedup:.1}x, gate: >= 2x)"
    );

    // Trajectory point first (a failed gate should still leave data),
    // then the blocking assert.
    let ts = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let json = format!(
        "{{\"bench\":\"serve_open_loop_{n_requests}req\",\"unix_time\":{ts},\
         \"batched_rps\":{batched_rps:.1},\"unbatched_rps\":{unbatched_rps:.1},\
         \"speedup\":{speedup:.2},\"deterministic\":true,\"stats\":{}}}\n",
        s1.summary_json()
    );
    match std::fs::OpenOptions::new().create(true).append(true).open("BENCH_serve.json") {
        Ok(mut f) => {
            let _ = f.write_all(json.as_bytes());
            println!("trajectory point appended to BENCH_serve.json");
        }
        Err(e) => eprintln!("could not write BENCH_serve.json: {e}"),
    }

    assert!(
        speedup >= 2.0,
        "dynamic batching must deliver at least 2x the batch-of-1 throughput \
         (got {speedup:.2}x) — the serving layer's reason to exist"
    );
    println!("throughput gate passed: {speedup:.1}x >= 2x ✓");
}
