//! The ExSdotp unit: fused datapath vs cascade vs exact oracle
//! throughput, plus the SIMD wrapper — the per-lane cost that bounds
//! the cluster simulator's speed.

use minifloat_nn::exsdotp::fast::{exsdotp_m, simd_exsdotp_m};
use minifloat_nn::exsdotp::{exsdotp_cascade, exsdotp_exact, ExSdotpUnit, SimdExSdotp};
use minifloat_nn::formats::{Fp16, Fp32, Fp8};
use minifloat_nn::util::bench::Bencher;
use minifloat_nn::util::rng::Rng;
use minifloat_nn::{RoundingMode, FP16, FP32, FP8};

fn main() {
    let mut b = Bencher::new();
    let rm = RoundingMode::Rne;
    let mut rng = Rng::new(2);
    let v16: Vec<u64> = (0..1024).map(|_| rng.next_u64() & 0x7bff).collect();
    let v8: Vec<u64> = (0..1024).map(|_| rng.next_u64() & 0x7b).collect();
    let w64: Vec<u64> = (0..1024).map(|_| rng.next_u64()).collect();

    println!("== ExSdotp datapath throughput (1024 ops per iteration) ==");
    let unit = ExSdotpUnit::fp16_to_fp32();
    b.bench_throughput("fused 16->32 x1024", 1024.0, || {
        let mut acc = 0u64;
        for i in 0..1024 {
            acc = unit.exsdotp(v16[i], v16[(i + 1) & 1023], v16[(i + 2) & 1023], v16[(i + 3) & 1023], acc & 0x7f7fffff, rm);
        }
        acc
    });
    let unit8 = ExSdotpUnit::fp8_to_fp16();
    b.bench_throughput("fused 8->16 x1024", 1024.0, || {
        let mut acc = 0u64;
        for i in 0..1024 {
            acc = unit8.exsdotp(v8[i], v8[(i + 1) & 1023], v8[(i + 2) & 1023], v8[(i + 3) & 1023], acc & 0x7bff, rm);
        }
        acc
    });
    b.bench_throughput("cascade 16->32 x1024", 1024.0, || {
        let mut acc = 0u64;
        for i in 0..1024 {
            acc = exsdotp_cascade(FP16, FP32, v16[i], v16[(i + 1) & 1023], v16[(i + 2) & 1023], v16[(i + 3) & 1023], acc & 0x7f7fffff, rm);
        }
        acc
    });
    b.bench_throughput("exact oracle 16->32 x1024", 1024.0, || {
        let mut acc = 0u64;
        for i in 0..1024 {
            acc = exsdotp_exact(FP16, FP32, v16[i], v16[(i + 1) & 1023], v16[(i + 2) & 1023], v16[(i + 3) & 1023], acc & 0x7f7fffff, rm);
        }
        acc
    });
    let simd = SimdExSdotp::new(FP8, FP16);
    b.bench_throughput("SIMD 8->16 (4 units) x1024", 1024.0, || {
        let mut acc = 0u64;
        for i in 0..1024 {
            acc = simd.exsdotp(w64[i], w64[(i + 1) & 1023], acc, rm);
        }
        acc
    });

    println!("\n== monomorphized Tier-A kernels (same datapath, compile-time formats) ==");
    b.bench_throughput("fast fused 16->32 x1024", 1024.0, || {
        let mut acc = 0u64;
        for i in 0..1024 {
            acc = exsdotp_m::<Fp16, Fp32>(v16[i], v16[(i + 1) & 1023], v16[(i + 2) & 1023], v16[(i + 3) & 1023], acc & 0x7f7fffff, rm);
        }
        acc
    });
    b.bench_throughput("fast fused 8->16 x1024", 1024.0, || {
        let mut acc = 0u64;
        for i in 0..1024 {
            acc = exsdotp_m::<Fp8, Fp16>(v8[i], v8[(i + 1) & 1023], v8[(i + 2) & 1023], v8[(i + 3) & 1023], acc & 0x7bff, rm);
        }
        acc
    });
    b.bench_throughput("fast SIMD 8->16 (4 units) x1024", 1024.0, || {
        let mut acc = 0u64;
        for i in 0..1024 {
            acc = simd_exsdotp_m::<Fp8, Fp16>(w64[i], w64[(i + 1) & 1023], acc, rm);
        }
        acc
    });
}
