//! Native-training throughput: steps/s per precision policy.
//!
//! One "step" is the full mixed-precision recipe — batch sampling,
//! forward (3 GEMM plans), loss, backward (6 GEMM plans), loss-scale
//! bookkeeping, optimizer update on the FP32 masters. Before timing,
//! the harness gates on routing: for expanding-pair policies every
//! plan must have taken the packed zero-repack fast path.
//!
//! Appends one trajectory point per policy to `BENCH_train.json` in
//! the working directory so CI can track steps/s over time.

use minifloat_nn::prelude::*;
use minifloat_nn::util::bench::Bencher;
use std::io::Write;

fn main() {
    let session = Session::builder().seed(42).build();
    let mut bench = Bencher::new();
    let mut json = String::new();
    let ts = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);

    println!("== native training step throughput (spiral, 8->32->32->8 MLP, batch 64) ==\n");
    for policy in PrecisionPolicy::presets() {
        let mut tr = session.native_trainer(policy).expect("valid train plan");
        // Warm + routing gate: every GEMM of an expanding-pair policy
        // must hit the packed fast path (a fast wrong route is
        // worthless to measure).
        for _ in 0..3 {
            tr.step().expect("step");
        }
        let expanding = policy.fwd != policy.acc;
        if expanding {
            assert_eq!(
                tr.packed_runs(),
                tr.gemm_calls(),
                "{}: expanding-pair GEMMs must all run the packed fast path",
                policy.name
            );
        }
        let stats = bench.bench(&format!("train step [{}]", policy.name), || {
            tr.step().expect("step")
        });
        let ms = stats.median.as_secs_f64() * 1e3;
        let steps_per_s = 1.0 / stats.median.as_secs_f64();
        json += &format!(
            "{{\"bench\":\"native_train_step\",\"unix_time\":{ts},\"policy\":\"{}\",\
             \"ms_per_step\":{ms:.3},\"steps_per_s\":{steps_per_s:.1},\
             \"packed_fast_path\":{}}}\n",
            policy.name, expanding
        );
    }

    match std::fs::OpenOptions::new().create(true).append(true).open("BENCH_train.json") {
        Ok(mut f) => {
            let _ = f.write_all(json.as_bytes());
            println!("\ntrajectory points appended to BENCH_train.json");
        }
        Err(e) => eprintln!("could not write BENCH_train.json: {e}"),
    }
}
