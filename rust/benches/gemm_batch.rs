//! The batch-engine headline benchmark: per-element descriptor-driven
//! GEMM vs the typed API's functional path, FP8→FP16 at the paper's
//! 128-class sizes.
//!
//! * *per-element baseline*: `kernel_reference` — the descriptor-driven
//!   replay that packs and dispatches every lane individually (what
//!   every accuracy/validation sweep had to run through before Tier B).
//! * *batched*: the redesigned surface — `Session::gemm()` plans on
//!   `ExecMode::Functional` (packed registers, monomorphized kernels,
//!   rows in parallel), so the trajectory measures what users actually
//!   call.
//!
//! All paths produce bit-identical C (verified here before timing).
//! The run appends a trajectory point to `BENCH_gemm.json` in the
//! working directory so CI can track the speedup over time.

use minifloat_nn::isa::instr::OpWidth;
use minifloat_nn::kernels::kernel_reference;
use minifloat_nn::prelude::*;
use minifloat_nn::util::bench::Bencher;
use std::io::Write;

fn main() {
    let kind = GemmKind::ExSdotp(OpWidth::BtoH);
    let (m, n, k) = (128, 128, 128);
    let session = Session::builder().mode(ExecMode::Functional).seed(42).build();
    let serial = Session::builder().mode(ExecMode::Functional).seed(42).threads(1).build();
    let mut rng = session.rng();
    let a: Vec<f64> = (0..m * k).map(|_| rng.gaussian() * 0.25).collect();
    let b: Vec<f64> = (0..k * n).map(|_| rng.gaussian() * 0.25).collect();
    let plan = session.gemm().kind(kind).dims(m, n, k).expect("valid plan");
    let serial_plan = serial.gemm().kind(kind).dims(m, n, k).expect("valid plan");
    let kern = *plan.kernel();
    let flops = kern.flops() as f64;

    // Bit-identity gate before any timing: a fast wrong answer is
    // worthless. Per-element reference replay == typed plan API.
    let want = kernel_reference(&kern, &a, &b);
    let got = plan.run_f64(&a, &b).expect("valid run").c_f64();
    let identical = |x: &[f64], y: &[f64]| {
        x.iter().zip(y).all(|(w, g)| w.to_bits() == g.to_bits() || (w.is_nan() && g.is_nan()))
    };
    assert!(identical(&want, &got), "plan API diverged from the per-element reference");
    println!("bit-identity: Session plan == kernel_reference on {m}x{n}x{k} FP8->FP16 ✓\n");

    println!("== FP8->FP16 {m}x{n}x{k} GEMM: per-element baseline vs typed-API batch engine ==");
    let mut bench = Bencher::new();
    let per_elem = bench
        .bench_throughput("per-element (kernel_reference)", flops, || kernel_reference(&kern, &a, &b))
        .median
        .as_secs_f64();
    let batched = bench
        .bench_throughput("batched (Session::gemm plan, parallel rows)", flops, || {
            plan.run_f64(&a, &b).expect("valid run").c
        })
        .median
        .as_secs_f64();
    let batched_serial = bench
        .bench_throughput("batched (Session with threads(1))", flops, || {
            serial_plan.run_f64(&a, &b).expect("valid run").c
        })
        .median
        .as_secs_f64();

    let speedup = per_elem / batched;
    let speedup_serial = per_elem / batched_serial;
    println!("\nspeedup: {speedup:.1}x parallel, {speedup_serial:.1}x single-thread (target: >= 10x)");

    // Trajectory point for CI.
    let ts = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let json = format!(
        "{{\"bench\":\"gemm_fp8_fp16_{m}x{n}x{k}\",\"unix_time\":{ts},\
         \"per_element_ms\":{:.3},\"batched_ms\":{:.3},\"batched_serial_ms\":{:.3},\
         \"speedup\":{speedup:.2},\"speedup_serial\":{speedup_serial:.2},\
         \"gflops_batched\":{:.3},\"bit_identical\":true,\"api\":\"session_plan\"}}\n",
        per_elem * 1e3,
        batched * 1e3,
        batched_serial * 1e3,
        flops / batched / 1e9,
    );
    match std::fs::OpenOptions::new().create(true).append(true).open("BENCH_gemm.json") {
        Ok(mut f) => {
            let _ = f.write_all(json.as_bytes());
            println!("trajectory point appended to BENCH_gemm.json");
        }
        Err(e) => eprintln!("could not write BENCH_gemm.json: {e}"),
    }
}
