//! The batch-engine headline benchmark: per-element descriptor-driven
//! GEMM vs the monomorphized batch engine, FP8→FP16 at the paper's
//! 128-class sizes.
//!
//! * *per-element baseline*: `kernel_reference` — the descriptor-driven
//!   replay that packs and dispatches every lane individually (what
//!   every accuracy/validation sweep had to run through before Tier B).
//! * *batched*: `batch::gemm` (`ExecMode::Functional`) — packed
//!   registers, monomorphized kernels, rows in parallel.
//!
//! Both produce bit-identical C (verified here before timing). The run
//! appends a trajectory point to `BENCH_gemm.json` in the working
//! directory so CI can track the speedup over time.

use minifloat_nn::batch;
use minifloat_nn::isa::instr::OpWidth;
use minifloat_nn::kernels::{kernel_reference, GemmKernel, GemmKind};
use minifloat_nn::softfloat::RoundingMode;
use minifloat_nn::util::bench::Bencher;
use minifloat_nn::util::rng::Rng;
use std::io::Write;

fn main() {
    let kind = GemmKind::ExSdotp(OpWidth::BtoH);
    let (m, n, k) = (128, 128, 128);
    let mut rng = Rng::new(42);
    let a: Vec<f64> = (0..m * k).map(|_| rng.gaussian() * 0.25).collect();
    let b: Vec<f64> = (0..k * n).map(|_| rng.gaussian() * 0.25).collect();
    let kern = GemmKernel::new(kind, m, n, k);
    let flops = kern.flops() as f64;

    // Bit-identity gate before any timing: a fast wrong answer is
    // worthless.
    let want = kernel_reference(&kern, &a, &b);
    let got = batch::gemm(kind, m, n, k, &a, &b, RoundingMode::Rne);
    let identical = want
        .iter()
        .zip(&got)
        .all(|(w, g)| w.to_bits() == g.to_bits() || (w.is_nan() && g.is_nan()));
    assert!(identical, "batch::gemm diverged from the per-element reference");
    println!("bit-identity: batch::gemm == kernel_reference on {m}x{n}x{k} FP8->FP16 ✓\n");

    println!("== FP8->FP16 {m}x{n}x{k} GEMM: per-element baseline vs batch engine ==");
    let mut bench = Bencher::new();
    let per_elem = bench
        .bench_throughput("per-element (kernel_reference)", flops, || kernel_reference(&kern, &a, &b))
        .median
        .as_secs_f64();
    let batched = bench
        .bench_throughput("batched (batch::gemm, parallel rows)", flops, || {
            batch::gemm(kind, m, n, k, &a, &b, RoundingMode::Rne)
        })
        .median
        .as_secs_f64();
    let batched_serial = {
        std::env::set_var("MINIFLOAT_NN_THREADS", "1");
        let s = bench
            .bench_throughput("batched (single thread)", flops, || {
                batch::gemm(kind, m, n, k, &a, &b, RoundingMode::Rne)
            })
            .median
            .as_secs_f64();
        std::env::remove_var("MINIFLOAT_NN_THREADS");
        s
    };

    let speedup = per_elem / batched;
    let speedup_serial = per_elem / batched_serial;
    println!("\nspeedup: {speedup:.1}x parallel, {speedup_serial:.1}x single-thread (target: >= 10x)");

    // Trajectory point for CI.
    let ts = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let json = format!(
        "{{\"bench\":\"gemm_fp8_fp16_{m}x{n}x{k}\",\"unix_time\":{ts},\
         \"per_element_ms\":{:.3},\"batched_ms\":{:.3},\"batched_serial_ms\":{:.3},\
         \"speedup\":{speedup:.2},\"speedup_serial\":{speedup_serial:.2},\
         \"gflops_batched\":{:.3},\"bit_identical\":true}}\n",
        per_elem * 1e3,
        batched * 1e3,
        batched_serial * 1e3,
        flops / batched / 1e9,
    );
    match std::fs::OpenOptions::new().create(true).append(true).open("BENCH_gemm.json") {
        Ok(mut f) => {
            let _ = f.write_all(json.as_bytes());
            println!("trajectory point appended to BENCH_gemm.json");
        }
        Err(e) => eprintln!("could not write BENCH_gemm.json: {e}"),
    }
}
