//! The batch-engine headline benchmark: per-element descriptor-driven
//! GEMM vs the typed API's functional path, FP8→FP16 at the paper's
//! 128-class sizes.
//!
//! * *per-element baseline*: `kernel_reference` — the descriptor-driven
//!   replay that packs and dispatches every lane individually (what
//!   every accuracy/validation sweep had to run through before Tier B).
//! * *batched*: the redesigned surface — `Session::gemm()` plans on
//!   `ExecMode::Functional` (packed registers, monomorphized kernels,
//!   rows in parallel), so the trajectory measures what users actually
//!   call.
//!
//! All paths produce bit-identical C (verified here before timing).
//! The run appends a trajectory point to `BENCH_gemm.json` in the
//! working directory so CI can track the speedup over time.
//!
//! A second, **CI-blocking** point measures the persistent-executor
//! steady state: a small GEMM run many times through a reusable
//! [`PlanInstance`] (pooled workers, cached operands, recycled output)
//! vs the allocate-per-call path (fresh tensors + plan + scoped
//! threads per call — the pre-executor behaviour). The reusable path
//! must be ≥ 1.5× faster; small GEMMs are exactly where per-call
//! thread churn and allocator traffic used to dominate.
//!
//! A third set of points measures the **lane tiers at scale**:
//! 512×512×512 GEMMs (FP8→FP16 and FP16→FP32) through a bound
//! `PlanInstance` on the SWAR tier (lane-parallel kernels +
//! cache-blocked tiling — the production default) vs the pinned scalar
//! reference tier (`with_lane_tier`). Bit-identity between the tiers is
//! asserted before timing; the FP8→FP16 point carries a **CI-blocking
//! ≥ 2× speedup gate** (best-of-3 wall times, like the reuse gate).

use minifloat_nn::batch::{with_lane_tier, LaneTier};
use minifloat_nn::isa::instr::OpWidth;
use minifloat_nn::kernels::kernel_reference;
use minifloat_nn::prelude::*;
use minifloat_nn::util::bench::Bencher;
use minifloat_nn::util::parallel::{with_dispatch, Dispatch};
use std::io::Write;

fn main() {
    let kind = GemmKind::ExSdotp(OpWidth::BtoH);
    let (m, n, k) = (128, 128, 128);
    let session = Session::builder().mode(ExecMode::Functional).seed(42).build();
    let serial = Session::builder().mode(ExecMode::Functional).seed(42).threads(1).build();
    let mut rng = session.rng();
    let a: Vec<f64> = (0..m * k).map(|_| rng.gaussian() * 0.25).collect();
    let b: Vec<f64> = (0..k * n).map(|_| rng.gaussian() * 0.25).collect();
    let plan = session.gemm().kind(kind).dims(m, n, k).expect("valid plan");
    let serial_plan = serial.gemm().kind(kind).dims(m, n, k).expect("valid plan");
    let kern = *plan.kernel();
    let flops = kern.flops() as f64;

    // Bit-identity gate before any timing: a fast wrong answer is
    // worthless. Per-element reference replay == typed plan API.
    let want = kernel_reference(&kern, &a, &b);
    let got = plan.run_f64(&a, &b).expect("valid run").c_f64();
    let identical = |x: &[f64], y: &[f64]| {
        x.iter().zip(y).all(|(w, g)| w.to_bits() == g.to_bits() || (w.is_nan() && g.is_nan()))
    };
    assert!(identical(&want, &got), "plan API diverged from the per-element reference");
    println!("bit-identity: Session plan == kernel_reference on {m}x{n}x{k} FP8->FP16 ✓\n");

    println!("== FP8->FP16 {m}x{n}x{k} GEMM: per-element baseline vs typed-API batch engine ==");
    let mut bench = Bencher::new();
    let per_elem = bench
        .bench_throughput("per-element (kernel_reference)", flops, || kernel_reference(&kern, &a, &b))
        .median
        .as_secs_f64();
    let batched = bench
        .bench_throughput("batched (Session::gemm plan, parallel rows)", flops, || {
            plan.run_f64(&a, &b).expect("valid run").c
        })
        .median
        .as_secs_f64();
    let batched_serial = bench
        .bench_throughput("batched (Session with threads(1))", flops, || {
            serial_plan.run_f64(&a, &b).expect("valid run").c
        })
        .median
        .as_secs_f64();

    let speedup = per_elem / batched;
    let speedup_serial = per_elem / batched_serial;
    println!("\nspeedup: {speedup:.1}x parallel, {speedup_serial:.1}x single-thread (target: >= 10x)");

    // Trajectory point for CI.
    let ts = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let json = format!(
        "{{\"bench\":\"gemm_fp8_fp16_{m}x{n}x{k}\",\"unix_time\":{ts},\
         \"per_element_ms\":{:.3},\"batched_ms\":{:.3},\"batched_serial_ms\":{:.3},\
         \"speedup\":{speedup:.2},\"speedup_serial\":{speedup_serial:.2},\
         \"gflops_batched\":{:.3},\"bit_identical\":true,\"api\":\"session_plan\"}}\n",
        per_elem * 1e3,
        batched * 1e3,
        batched_serial * 1e3,
        flops / batched / 1e9,
    );
    match std::fs::OpenOptions::new().create(true).append(true).open("BENCH_gemm.json") {
        Ok(mut f) => {
            let _ = f.write_all(json.as_bytes());
            println!("trajectory point appended to BENCH_gemm.json");
        }
        Err(e) => eprintln!("could not write BENCH_gemm.json: {e}"),
    }

    small_gemm_steady_state(&session, ts);
    large_shape_points(&session, ts);
}

/// Large-shape lane-tier points: SWAR (default, blocked) vs the scalar
/// reference tier at 512³. FP8→FP16 is the gated headline (SWAR must
/// win by ≥ 2×); FP16→FP32 is a trajectory point for the wider-lane
/// pair. Returns nothing — panics if the gate fails (CI-blocking).
fn large_shape_points(session: &Session, ts: u64) {
    println!("\n== large-shape lane tiers (512x512x512, SWAR vs scalar reference) ==");
    let s8 = large_tier_point(session, ts, FP8, FP16, "gemm_large_fp8_fp16_512", Some(2.0));
    let s16 = large_tier_point(session, ts, FP16, FP32, "gemm_large_fp16_fp32_512", None);
    println!("tier speedups: FP8->FP16 {s8:.2}x (gate >= 2x), FP16->FP32 {s16:.2}x (advisory)");
    assert!(
        s8 >= 2.0,
        "SWAR tier must beat the scalar tier by >= 2x on FP8->FP16 at 512^3 (got {s8:.2}x) — \
         the lane-parallel kernels' reason to exist"
    );
    println!("SWAR gate passed: {s8:.1}x >= 2x ✓");
}

/// One tier-comparison point: bind a 512³ problem into a `PlanInstance`
/// (packed zero-repack route, blocking precompiled), assert the tiers
/// bit-identical, then best-of-3 the wall time of each tier. Appends a
/// trajectory point and returns the SWAR-over-scalar speedup.
fn large_tier_point(
    session: &Session,
    ts: u64,
    src: FpFormat,
    acc: FpFormat,
    label: &str,
    gate: Option<f64>,
) -> f64 {
    let (m, n, k) = (512usize, 512, 512);
    let mut rng = session.rng();
    let a: Vec<f64> = (0..m * k).map(|_| rng.gaussian() * 0.25).collect();
    let b: Vec<f64> = (0..k * n).map(|_| rng.gaussian() * 0.25).collect();
    let plan = session.gemm().src(src).acc(acc).dims(m, n, k).expect("valid plan");
    let flops = plan.kernel().flops() as f64;
    let ta = session.tensor(&a, m, k, src).expect("tensor A");
    let tb = session.tensor_with_layout(&b, k, n, src, Layout::ColMajor).expect("tensor B");
    let mut inst = plan.instance();
    inst.bind_a(&ta).expect("bind A");
    inst.bind_b(&tb).expect("bind B");
    let mut out = Vec::new();

    // Bit-identity gate before timing: the SWAR tier (blocked) must
    // reproduce the scalar reference tier exactly.
    inst.run_bound(&mut out).expect("run");
    let swar_c = out.clone();
    with_lane_tier(LaneTier::Scalar, || inst.run_bound(&mut out).expect("run"));
    let identical = swar_c
        .iter()
        .zip(&out)
        .all(|(w, g)| w.to_bits() == g.to_bits() || (w.is_nan() && g.is_nan()));
    assert!(identical, "{label}: SWAR tier diverged from the scalar reference tier");
    assert!(inst.packed_runs() == inst.runs(), "large-shape points must ride the packed route");

    // Best-of-3 single-shot wall times per tier (the problem is large
    // enough that one run is a stable sample; best-of-N absorbs shared
    // CI runner jitter, as in the reuse gate).
    let (mut scalar_s, mut swar_s) = (f64::INFINITY, f64::INFINITY);
    for _ in 0..3 {
        let t0 = std::time::Instant::now();
        with_lane_tier(LaneTier::Scalar, || inst.run_bound(&mut out).expect("run"));
        scalar_s = scalar_s.min(t0.elapsed().as_secs_f64());
        let t0 = std::time::Instant::now();
        inst.run_bound(&mut out).expect("run");
        swar_s = swar_s.min(t0.elapsed().as_secs_f64());
    }
    let speedup = scalar_s / swar_s;
    println!(
        "{}->{} {m}x{n}x{k}: scalar {:.1} ms   swar {:.1} ms   speedup {speedup:.2}x   {:.3} GFLOPS",
        src.name(),
        acc.name(),
        scalar_s * 1e3,
        swar_s * 1e3,
        flops / swar_s / 1e9,
    );
    let json = format!(
        "{{\"bench\":\"{label}\",\"unix_time\":{ts},\
         \"scalar_ms\":{:.3},\"swar_ms\":{:.3},\"swar_speedup\":{speedup:.2},\
         \"gflops_swar\":{:.3},\"gate\":{},\"bit_identical\":true,\"api\":\"plan_instance\"}}\n",
        scalar_s * 1e3,
        swar_s * 1e3,
        flops / swar_s / 1e9,
        gate.map_or("null".to_string(), |g| format!("{g:.1}")),
    );
    match std::fs::OpenOptions::new().create(true).append(true).open("BENCH_gemm.json") {
        Ok(mut f) => {
            let _ = f.write_all(json.as_bytes());
            println!("large-shape point appended to BENCH_gemm.json");
        }
        Err(e) => eprintln!("could not write BENCH_gemm.json: {e}"),
    }
    speedup
}

/// Steady-state small-GEMM point + the CI-blocking reuse gate: on a
/// 32×32×32 FP8→FP16 problem over many iterations, the reusable-plan
/// path (compiled `PlanInstance`, bound operands, recycled output
/// buffer, persistent worker pool) must beat the allocate-per-call path
/// (per-call plan build + operand tensors + output tensor, legacy
/// scoped-thread dispatch) by at least 1.5×. Bit-identity is asserted
/// before timing, as everywhere in this harness.
fn small_gemm_steady_state(session: &Session, ts: u64) {
    let (m, n, k) = (32usize, 32, 32);
    let iters = 1000u32;
    let mut rng = session.rng();
    let a: Vec<f64> = (0..m * k).map(|_| rng.gaussian() * 0.25).collect();
    let b: Vec<f64> = (0..k * n).map(|_| rng.gaussian() * 0.25).collect();

    // The allocate-per-call closure: exactly what every nn matmul /
    // serve dispatch used to do per GEMM — build a plan, quantize both
    // operands into fresh tensors, run, decode a fresh C — on per-call
    // scoped threads.
    let per_call = || -> Vec<f64> {
        let plan = session.gemm().src(FP8).acc(FP16).dims(m, n, k).expect("valid plan");
        let ta = session.tensor(&a, m, k, FP8).expect("tensor A");
        let tb = session.tensor_with_layout(&b, k, n, FP8, Layout::ColMajor).expect("tensor B");
        plan.run(&ta, &tb).expect("run").c_f64()
    };

    // The reusable path: compile once, bind the operands once, stream
    // runs through one workspace and one output buffer.
    let plan = session.gemm().src(FP8).acc(FP16).dims(m, n, k).expect("valid plan");
    let ta = session.tensor(&a, m, k, FP8).expect("tensor A");
    let tb = session.tensor_with_layout(&b, k, n, FP8, Layout::ColMajor).expect("tensor B");
    let mut inst = plan.instance();
    inst.bind_a(&ta).expect("bind A");
    inst.bind_b(&tb).expect("bind B");
    let mut out = Vec::new();

    // Bit-identity gate before timing.
    let want = with_dispatch(Dispatch::Scoped, per_call);
    inst.run_bound(&mut out).expect("run");
    let identical =
        want.iter().zip(&out).all(|(w, g)| w.to_bits() == g.to_bits() || (w.is_nan() && g.is_nan()));
    assert!(identical, "reusable-plan path diverged from the allocate-per-call path");
    assert!(
        inst.packed_runs() == inst.runs(),
        "bound packed operands must ride the zero-repack route"
    );

    println!("\n== steady-state small GEMM ({m}x{n}x{k} FP8->FP16, {iters} iterations) ==");
    // Warm both paths, then time the loops directly (the steady state
    // is the loop, not one call). Best of three attempts per arm: the
    // gate is a wall-clock ratio on shared CI runners, so one
    // scheduler-jitter hit must not fail an unrelated build; the 1.5x
    // threshold itself stays blocking.
    for _ in 0..10 {
        with_dispatch(Dispatch::Scoped, per_call);
        inst.run_bound(&mut out).expect("run");
    }
    let (mut alloc_s, mut reuse_s) = (f64::INFINITY, f64::INFINITY);
    for _ in 0..3 {
        let t0 = std::time::Instant::now();
        for _ in 0..iters {
            std::hint::black_box(with_dispatch(Dispatch::Scoped, per_call));
        }
        alloc_s = alloc_s.min(t0.elapsed().as_secs_f64());
        let t0 = std::time::Instant::now();
        for _ in 0..iters {
            inst.run_bound(&mut out).expect("run");
            std::hint::black_box(&out);
        }
        reuse_s = reuse_s.min(t0.elapsed().as_secs_f64());
    }
    let reuse_speedup = alloc_s / reuse_s;
    println!(
        "alloc-per-call {:.3} ms/iter   reusable workspace {:.3} ms/iter   speedup {reuse_speedup:.2}x \
         (gate: >= 1.5x)",
        alloc_s * 1e3 / iters as f64,
        reuse_s * 1e3 / iters as f64,
    );

    // Trajectory point first (a failed gate should still leave data),
    // then the blocking assert.
    let json = format!(
        "{{\"bench\":\"gemm_small_steady_state_{m}x{n}x{k}\",\"unix_time\":{ts},\
         \"iters\":{iters},\"alloc_per_call_ms\":{:.4},\"reuse_ms\":{:.4},\
         \"reuse_speedup\":{reuse_speedup:.2},\"bit_identical\":true,\"api\":\"plan_instance\"}}\n",
        alloc_s * 1e3 / iters as f64,
        reuse_s * 1e3 / iters as f64,
    );
    match std::fs::OpenOptions::new().create(true).append(true).open("BENCH_gemm.json") {
        Ok(mut f) => {
            let _ = f.write_all(json.as_bytes());
            println!("steady-state point appended to BENCH_gemm.json");
        }
        Err(e) => eprintln!("could not write BENCH_gemm.json: {e}"),
    }
    assert!(
        reuse_speedup >= 1.5,
        "reusable-workspace path must be >= 1.5x the allocate-per-call path \
         (got {reuse_speedup:.2}x) — the persistent executor's reason to exist"
    );
    println!("reuse gate passed: {reuse_speedup:.1}x >= 1.5x ✓");
}
