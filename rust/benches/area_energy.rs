//! Fig. 7 and Table III regeneration harness (area + energy models).

use minifloat_nn::report;

fn main() {
    print!("{}", report::fig7a_text());
    println!();
    print!("{}", report::fig7b_text());
    println!();
    print!("{}", report::table3_text(42));
}
