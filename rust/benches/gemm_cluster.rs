//! Table II / Fig. 8 regeneration harness + simulator throughput.
//!
//! Prints the full Table II grid (simulated vs paper cycles) and
//! measures how fast the cycle-level simulation itself runs — both
//! engines driven through the typed `Session`/`GemmPlan` API.

use minifloat_nn::isa::instr::{OpWidth, ScalarFmt};
use minifloat_nn::prelude::*;
use minifloat_nn::report;
use minifloat_nn::util::bench::Bencher;

fn main() {
    println!("== regenerating Table II / Fig. 8 (simulated cluster) ==");
    let rows = report::run_table2(42);
    print!("{}", report::table2_text(&rows));
    println!();
    print!("{}", report::fig8_text(&rows));

    let kinds = [
        (GemmKind::FmaF64, "FP64 64x64"),
        (GemmKind::FmaSimd(ScalarFmt::H), "FP16 64x64"),
        (GemmKind::ExSdotp(OpWidth::BtoH), "FP8->16 64x64"),
    ];

    println!("\n== simulator throughput (simulated cycles / wall second) ==");
    let mut b = Bencher::new();
    let sim = Session::builder().mode(ExecMode::CycleAccurate).seed(9).build();
    let mut rng = sim.rng();
    for (kind, label) in kinds {
        let (m, n, k) = (64, 64, 64);
        let a: Vec<f64> = (0..m * k).map(|_| rng.gaussian() * 0.25).collect();
        let bm: Vec<f64> = (0..k * n).map(|_| rng.gaussian() * 0.25).collect();
        let plan = sim.gemm().kind(kind).dims(m, n, k).expect("valid plan");
        let cycles = plan.run_f64(&a, &bm).expect("valid run").cycles.unwrap_or(0) as f64;
        b.bench_throughput(&format!("sim {label}"), cycles, || {
            plan.run_f64(&a, &bm).expect("valid run").cycles
        });
    }

    println!("\n== ExecMode::Functional (batch engine) on the same problems ==");
    let fun = Session::builder().mode(ExecMode::Functional).seed(9).build();
    let mut rng = fun.rng();
    for (kind, label) in kinds {
        let (m, n, k) = (64, 64, 64);
        let a: Vec<f64> = (0..m * k).map(|_| rng.gaussian() * 0.25).collect();
        let bm: Vec<f64> = (0..k * n).map(|_| rng.gaussian() * 0.25).collect();
        let plan = fun.gemm().kind(kind).dims(m, n, k).expect("valid plan");
        let flops = plan.kernel().flops() as f64;
        b.bench_throughput(&format!("fun {label}"), flops, || {
            plan.run_f64(&a, &bm).expect("valid run").c
        });
    }
}
