//! Table II / Fig. 8 regeneration harness + simulator throughput.
//!
//! Prints the full Table II grid (simulated vs paper cycles) and
//! measures how fast the cycle-level simulation itself runs.

use minifloat_nn::isa::instr::{OpWidth, ScalarFmt};
use minifloat_nn::kernels::{ExecMode, GemmKernel, GemmKind};
use minifloat_nn::report;
use minifloat_nn::util::bench::Bencher;
use minifloat_nn::util::rng::Rng;

fn main() {
    println!("== regenerating Table II / Fig. 8 (simulated cluster) ==");
    let rows = report::run_table2(42);
    print!("{}", report::table2_text(&rows));
    println!();
    print!("{}", report::fig8_text(&rows));

    println!("\n== simulator throughput (simulated cycles / wall second) ==");
    let mut b = Bencher::new();
    let mut rng = Rng::new(9);
    for (kind, label) in [
        (GemmKind::FmaF64, "sim FP64 64x64"),
        (GemmKind::FmaSimd(ScalarFmt::H), "sim FP16 64x64"),
        (GemmKind::ExSdotp(OpWidth::BtoH), "sim FP8->16 64x64"),
    ] {
        let (m, n, k) = (64, 64, 64);
        let a: Vec<f64> = (0..m * k).map(|_| rng.gaussian() * 0.25).collect();
        let bm: Vec<f64> = (0..k * n).map(|_| rng.gaussian() * 0.25).collect();
        let kern = GemmKernel::new(kind, m, n, k);
        let cycles = kern.run(&a, &bm).cycles as f64;
        b.bench_throughput(label, cycles, || kern.run(&a, &bm).cycles);
    }

    println!("\n== ExecMode::Functional (batch engine) on the same problems ==");
    let mut rng = Rng::new(9);
    for (kind, label) in [
        (GemmKind::FmaF64, "fun FP64 64x64"),
        (GemmKind::FmaSimd(ScalarFmt::H), "fun FP16 64x64"),
        (GemmKind::ExSdotp(OpWidth::BtoH), "fun FP8->16 64x64"),
    ] {
        let (m, n, k) = (64, 64, 64);
        let a: Vec<f64> = (0..m * k).map(|_| rng.gaussian() * 0.25).collect();
        let bm: Vec<f64> = (0..k * n).map(|_| rng.gaussian() * 0.25).collect();
        let kern = GemmKernel::new(kind, m, n, k);
        let flops = kern.flops() as f64;
        b.bench_throughput(label, flops, || kern.run_mode(&a, &bm, ExecMode::Functional).c.len());
    }
}
