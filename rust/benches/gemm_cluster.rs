//! Table II / Fig. 8 regeneration harness + simulator throughput.
//!
//! Prints the full Table II grid (simulated vs paper cycles) and
//! measures how fast the cycle-level simulation itself runs — both
//! engines driven through the typed `Session`/`GemmPlan` API — then
//! runs the SoC roofline sweep and appends a trajectory point to
//! `BENCH_cluster.json`.

use std::io::Write;

use minifloat_nn::isa::instr::{OpWidth, ScalarFmt};
use minifloat_nn::prelude::*;
use minifloat_nn::report;
use minifloat_nn::util::bench::Bencher;

fn main() {
    println!("== regenerating Table II / Fig. 8 (simulated cluster) ==");
    let rows = report::run_table2(42);
    print!("{}", report::table2_text(&rows));
    println!();
    print!("{}", report::fig8_text(&rows));

    let kinds = [
        (GemmKind::FmaF64, "FP64 64x64"),
        (GemmKind::FmaSimd(ScalarFmt::H), "FP16 64x64"),
        (GemmKind::ExSdotp(OpWidth::BtoH), "FP8->16 64x64"),
    ];

    println!("\n== simulator throughput (simulated cycles / wall second) ==");
    let mut b = Bencher::new();
    let sim = Session::builder().mode(ExecMode::CycleAccurate).seed(9).build();
    let mut rng = sim.rng();
    for (kind, label) in kinds {
        let (m, n, k) = (64, 64, 64);
        let a: Vec<f64> = (0..m * k).map(|_| rng.gaussian() * 0.25).collect();
        let bm: Vec<f64> = (0..k * n).map(|_| rng.gaussian() * 0.25).collect();
        let plan = sim.gemm().kind(kind).dims(m, n, k).expect("valid plan");
        let cycles = plan.run_f64(&a, &bm).expect("valid run").cycles.unwrap_or(0) as f64;
        b.bench_throughput(&format!("sim {label}"), cycles, || {
            plan.run_f64(&a, &bm).expect("valid run").cycles
        });
    }

    println!("\n== ExecMode::Functional (batch engine) on the same problems ==");
    let fun = Session::builder().mode(ExecMode::Functional).seed(9).build();
    let mut rng = fun.rng();
    for (kind, label) in kinds {
        let (m, n, k) = (64, 64, 64);
        let a: Vec<f64> = (0..m * k).map(|_| rng.gaussian() * 0.25).collect();
        let bm: Vec<f64> = (0..k * n).map(|_| rng.gaussian() * 0.25).collect();
        let plan = fun.gemm().kind(kind).dims(m, n, k).expect("valid plan");
        let flops = plan.kernel().flops() as f64;
        b.bench_throughput(&format!("fun {label}"), flops, || {
            plan.run_f64(&a, &bm).expect("valid run").c
        });
    }

    println!("\n== SoC roofline (FLOP/cycle + GFLOPS/W vs cluster count) ==");
    let rows = minifloat_nn::soc::run_roofline(
        &[1, 2, 4, 8],
        &[GemmKind::ExSdotp(OpWidth::BtoH), GemmKind::ExSdotp(OpWidth::HtoS)],
        128,
        256,
        128,
        ExecMode::CycleAccurate,
        42,
    )
    .expect("the anchor roofline sweep is a valid configuration");
    print!("{}", report::roofline_text(&rows));

    let ts = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let json = format!(
        "{{\"bench\":\"soc_roofline_128x256x128\",\"unix_time\":{ts},\
         \"deterministic\":true,\"body\":{}}}\n",
        report::roofline_json(&rows)
    );
    match std::fs::OpenOptions::new().create(true).append(true).open("BENCH_cluster.json") {
        Ok(mut f) => {
            let _ = f.write_all(json.as_bytes());
            println!("trajectory point appended to BENCH_cluster.json");
        }
        Err(e) => eprintln!("could not write BENCH_cluster.json: {e}"),
    }
}
