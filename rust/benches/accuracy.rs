//! Table IV regeneration harness + accumulation throughput: the
//! descriptor-driven path vs the monomorphized fast path (bit-identical
//! results — the speedup is what makes wide sweeps tractable).

use minifloat_nn::accuracy::{accumulate, accumulate_fast};
use minifloat_nn::report;
use minifloat_nn::util::bench::Bencher;
use minifloat_nn::{FP16, FP32, FP8};

fn main() {
    println!("== regenerating Table IV ==");
    print!("{}", report::table4_text(42));

    println!("\n== accumulation harness throughput ==");
    let mut b = Bencher::new();
    b.bench_throughput("accumulate 2000 fp16->fp32", 2000.0, || accumulate(FP16, FP32, 2000, 1).err_exsdotp);
    b.bench_throughput("accumulate 2000 fp8->fp16", 2000.0, || accumulate(FP8, FP16, 2000, 1).err_exsdotp);
    b.bench_throughput("fast accumulate 2000 fp16->fp32", 2000.0, || {
        accumulate_fast(FP16, FP32, 2000, 1).err_exsdotp
    });
    b.bench_throughput("fast accumulate 2000 fp8->fp16", 2000.0, || {
        accumulate_fast(FP8, FP16, 2000, 1).err_exsdotp
    });
}
