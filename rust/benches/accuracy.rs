//! Table IV regeneration harness + accumulation throughput: the
//! descriptor-driven path vs the monomorphized fast path, both driven
//! through typed `AccumulatePlan`s (bit-identical results — the speedup
//! is what makes wide sweeps tractable).

use minifloat_nn::prelude::*;
use minifloat_nn::report;
use minifloat_nn::util::bench::Bencher;

fn main() {
    println!("== regenerating Table IV ==");
    print!("{}", report::table4_text(42));

    println!("\n== accumulation harness throughput ==");
    // CycleAccurate sessions run the descriptor-driven unit path,
    // Functional sessions the monomorphized fast path.
    let slow = Session::builder().mode(ExecMode::CycleAccurate).seed(1).build();
    let fast = Session::builder().mode(ExecMode::Functional).seed(1).build();
    let mut b = Bencher::new();
    for (label, session) in [("descriptor", &slow), ("fast", &fast)] {
        for (src, dst, name) in [(FP16, FP32, "fp16->fp32"), (FP8, FP16, "fp8->fp16")] {
            let plan = session.accumulate().src(src).acc(dst).n(2000).expect("valid plan");
            b.bench_throughput(&format!("{label} accumulate 2000 {name}"), 2000.0, || {
                plan.run().err_exsdotp
            });
        }
    }
}
