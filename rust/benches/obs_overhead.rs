//! Observability overhead benchmark (CI-visible, gate advisory).
//!
//! The obs layer's contract has two halves: instrumentation must be
//! **bit-identical** (hard, asserted here and in
//! `tests/obs_differential.rs`) and **cheap** (soft: ≤ 5% wall-clock
//! overhead on the 128³ FP8→FP16 headline GEMM with metrics *and*
//! tracing fully enabled). The cheapness half is advisory — wall-clock
//! ratios on shared CI runners jitter, and a slow-but-correct trace
//! must not block an unrelated build — but the measured ratio lands in
//! `BENCH_obs.json` on every run so a regression shows up as a
//! trajectory, not a flake.

use minifloat_nn::obs;
use minifloat_nn::prelude::*;
use std::io::Write;

fn main() {
    let (m, n, k) = (128usize, 128, 128);
    let iters = 200u32;
    let session = Session::builder().mode(ExecMode::Functional).seed(42).build();
    let mut rng = session.rng();
    let a: Vec<f64> = (0..m * k).map(|_| rng.gaussian() * 0.25).collect();
    let b: Vec<f64> = (0..k * n).map(|_| rng.gaussian() * 0.25).collect();
    let plan = session.gemm().src(FP8).acc(FP16).dims(m, n, k).expect("valid plan");

    // Bit-identity gate before any timing: obs fully on vs fully off
    // must agree in every result word. Hard — a fast observer that
    // perturbs the observed run is worthless.
    obs::disable_all();
    obs::reset_all();
    let c_off = plan.run_f64(&a, &b).expect("run").c_f64();
    obs::enable_all();
    obs::reset_all();
    let c_on = plan.run_f64(&a, &b).expect("run").c_f64();
    obs::disable_all();
    obs::reset_all();
    let identical = c_off
        .iter()
        .zip(&c_on)
        .all(|(x, y)| x.to_bits() == y.to_bits() || (x.is_nan() && y.is_nan()));
    assert!(identical, "observability perturbed the GEMM result — hard invariant broken");
    println!("bit-identity: obs on == obs off on {m}x{n}x{k} FP8->FP16 ✓\n");

    println!("== obs overhead ({m}x{n}x{k} FP8->FP16 functional, {iters} iterations/arm) ==");
    // Warm both arms, then best-of-3 loop times (shared-runner jitter
    // absorption, same shape as the gemm_batch gates). The traced arm
    // resets the ring between attempts so it measures steady recording,
    // never the drop-at-capacity path.
    let mut inst = plan.instance();
    let mut out = Vec::new();
    for _ in 0..10 {
        inst.run_f64_into(&a, &b, &mut out).expect("run");
    }
    let (mut off_s, mut on_s) = (f64::INFINITY, f64::INFINITY);
    for _ in 0..3 {
        obs::disable_all();
        let t0 = std::time::Instant::now();
        for _ in 0..iters {
            inst.run_f64_into(&a, &b, &mut out).expect("run");
            std::hint::black_box(&out);
        }
        off_s = off_s.min(t0.elapsed().as_secs_f64());

        obs::enable_all();
        obs::reset_all();
        let t0 = std::time::Instant::now();
        for _ in 0..iters {
            inst.run_f64_into(&a, &b, &mut out).expect("run");
            std::hint::black_box(&out);
        }
        on_s = on_s.min(t0.elapsed().as_secs_f64());
        obs::disable_all();
    }
    obs::reset_all();

    let overhead = on_s / off_s - 1.0;
    println!(
        "obs off {:.3} ms/iter   obs on (metrics+trace) {:.3} ms/iter   overhead {:+.2}%",
        off_s * 1e3 / iters as f64,
        on_s * 1e3 / iters as f64,
        overhead * 100.0,
    );

    let ts = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let json = format!(
        "{{\"bench\":\"obs_overhead_{m}x{n}x{k}\",\"unix_time\":{ts},\"iters\":{iters},\
         \"off_ms\":{:.4},\"on_ms\":{:.4},\"overhead_ratio\":{:.4},\
         \"advisory_gate\":0.05,\"bit_identical\":true}}\n",
        off_s * 1e3 / iters as f64,
        on_s * 1e3 / iters as f64,
        on_s / off_s,
    );
    match std::fs::OpenOptions::new().create(true).append(true).open("BENCH_obs.json") {
        Ok(mut f) => {
            let _ = f.write_all(json.as_bytes());
            println!("trajectory point appended to BENCH_obs.json");
        }
        Err(e) => eprintln!("could not write BENCH_obs.json: {e}"),
    }

    if overhead > 0.05 {
        println!(
            "ADVISORY: obs overhead {:.1}% exceeds the 5% budget — check the hot-path \
             macros before it calcifies (not blocking: wall ratios jitter on shared runners)",
            overhead * 100.0
        );
    } else {
        println!("overhead within the 5% advisory budget ✓");
    }
}
