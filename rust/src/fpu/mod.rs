//! The extended-FPU model (Fig. 5): FPnew's operation-group
//! organization with the new SDOTP group.
//!
//! FPnew is "natively organized in modules, each one responsible for
//! one operation group: ADDMUL, DIVSQRT, COMP, CONV" (§III-D); this
//! reproduction disables DIVSQRT (as the Snitch configuration does) and
//! adds SDOTP. The [`Fpu`] type is the functional model: it dispatches
//! an operation to its group, computes the exact result through
//! [`crate::softfloat`] / [`crate::exsdotp`], and reports the group's
//! pipeline latency and FLOP count — the same contract the PE's
//! sequencer relies on, packaged standalone so the unit can be
//! evaluated FPU-first like the paper's Table III top rows.

use crate::exsdotp::simd::SimdExSdotp;
use crate::formats::FpFormat;
use crate::isa::csr::FpCsr;
use crate::isa::instr::{OpWidth, ScalarFmt};
use crate::softfloat;

/// FPnew operation groups (§III-D), with the paper's SDOTP addition.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum OpGroup {
    /// FMA / add / mul (multi-format, SIMD for narrow formats).
    AddMul,
    /// The new expanding-sum-of-dot-product group.
    Sdotp,
    /// Format conversions.
    Conv,
    /// Comparisons, classify, sign injection.
    Comp,
}

impl OpGroup {
    /// Pipeline registers configured for this group (§III-E / §IV-A).
    pub const fn pipeline_stages(self) -> u64 {
        match self {
            OpGroup::AddMul => 3,
            OpGroup::Sdotp => 3,
            OpGroup::Conv => 2,
            OpGroup::Comp => 1,
        }
    }
}

/// One FPU operation (operands packed in 64-bit registers).
#[derive(Clone, Copy, Debug)]
pub enum FpuOp {
    /// Vectorial/scalar FMA: `rd = rs1*rs2 + rs3` lanewise in `fmt`.
    Fmadd { fmt: ScalarFmt, rs1: u64, rs2: u64, rs3: u64 },
    /// Lanewise addition.
    Fadd { fmt: ScalarFmt, rs1: u64, rs2: u64 },
    /// Lanewise multiplication.
    Fmul { fmt: ScalarFmt, rs1: u64, rs2: u64 },
    /// SIMD expanding sum of dot products (accumulator in `rd`).
    ExSdotp { w: OpWidth, rs1: u64, rs2: u64, rd: u64 },
    /// SIMD expanding vector inner sum.
    ExVsum { w: OpWidth, rs1: u64, rd: u64 },
    /// SIMD non-expanding vector inner sum.
    Vsum { w: OpWidth, rs1: u64, rd: u64 },
    /// Scalar conversion between formats.
    Fcvt { to: ScalarFmt, from: ScalarFmt, rs1: u64 },
    /// Lanewise sign injection.
    Fsgnj { fmt: ScalarFmt, rs1: u64, rs2: u64 },
}

impl FpuOp {
    /// Which group executes this op.
    pub fn group(&self) -> OpGroup {
        match self {
            FpuOp::Fmadd { .. } | FpuOp::Fadd { .. } | FpuOp::Fmul { .. } => OpGroup::AddMul,
            FpuOp::ExSdotp { .. } | FpuOp::ExVsum { .. } | FpuOp::Vsum { .. } => OpGroup::Sdotp,
            FpuOp::Fcvt { .. } => OpGroup::Conv,
            FpuOp::Fsgnj { .. } => OpGroup::Comp,
        }
    }
}

/// Result of executing one op.
#[derive(Clone, Copy, Debug)]
pub struct FpuResult {
    /// Packed 64-bit result.
    pub value: u64,
    /// Pipeline latency in cycles (fully pipelined: issue 1/cycle).
    pub latency: u64,
    /// FLOP performed (paper counting).
    pub flops: u64,
}

/// The functional FPU: formats resolved through the FP CSR.
#[derive(Clone, Copy, Debug, Default)]
pub struct Fpu;

impl Fpu {
    /// Execute one operation under the given CSR state.
    pub fn execute(&self, op: FpuOp, csr: &FpCsr) -> FpuResult {
        let rm = csr.frm;
        let group = op.group();
        let (value, flops) = match op {
            FpuOp::Fmadd { fmt, rs1, rs2, rs3 } => {
                let f = csr.scalar_format(fmt);
                (lanewise3(f, rs1, rs2, rs3, |a, b, c| softfloat::fma(f, a, b, c, rm)), 2 * f.lanes_in_64() as u64)
            }
            FpuOp::Fadd { fmt, rs1, rs2 } => {
                let f = csr.scalar_format(fmt);
                (lanewise2(f, rs1, rs2, |a, b| softfloat::add(f, a, b, rm)), f.lanes_in_64() as u64)
            }
            FpuOp::Fmul { fmt, rs1, rs2 } => {
                let f = csr.scalar_format(fmt);
                (lanewise2(f, rs1, rs2, |a, b| softfloat::mul(f, a, b, rm)), f.lanes_in_64() as u64)
            }
            FpuOp::ExSdotp { w, rs1, rs2, rd } => {
                let simd = self.simd(w, csr);
                (simd.exsdotp(rs1, rs2, rd, rm), simd.flops(crate::exsdotp::SimdOp::ExSdotp))
            }
            FpuOp::ExVsum { w, rs1, rd } => {
                let simd = self.simd(w, csr);
                (simd.exvsum(rs1, rd, rm), simd.flops(crate::exsdotp::SimdOp::ExVsum))
            }
            FpuOp::Vsum { w, rs1, rd } => {
                let simd = self.simd(w, csr);
                (simd.vsum(rs1, rd, rm), simd.flops(crate::exsdotp::SimdOp::Vsum))
            }
            FpuOp::Fcvt { to, from, rs1 } => {
                let tf = csr.scalar_format(to);
                let ff = csr.scalar_format(from);
                (softfloat::cast(ff, tf, rs1 & ff.width_mask(), rm), 0)
            }
            FpuOp::Fsgnj { fmt, rs1, rs2 } => {
                let f = csr.scalar_format(fmt);
                (lanewise2(f, rs1, rs2, |a, b| softfloat::ops::sgnj(f, a, b)), 0)
            }
        };
        FpuResult { value, latency: group.pipeline_stages(), flops }
    }

    fn simd(&self, w: OpWidth, csr: &FpCsr) -> SimdExSdotp {
        SimdExSdotp::new(csr.src_format(w), csr.dst_format(w))
    }

    /// Peak FLOP/cycle for a compute op class (Table III's performance
    /// columns: expanding / non-expanding per format).
    pub fn peak_flop_per_cycle(&self, op: &FpuOp, csr: &FpCsr) -> u64 {
        self.execute(*op, csr).flops
    }
}

fn lanewise2(f: FpFormat, a: u64, b: u64, op: impl Fn(u64, u64) -> u64) -> u64 {
    use crate::exsdotp::simd::{lane, set_lane};
    let w = f.width();
    if w == 64 {
        return op(a, b);
    }
    let mut out = 0u64;
    for i in 0..f.lanes_in_64() {
        out = set_lane(out, i, w, op(lane(a, i, w), lane(b, i, w)));
    }
    out
}

fn lanewise3(f: FpFormat, a: u64, b: u64, c: u64, op: impl Fn(u64, u64, u64) -> u64) -> u64 {
    use crate::exsdotp::simd::{lane, set_lane};
    let w = f.width();
    if w == 64 {
        return op(a, b, c);
    }
    let mut out = 0u64;
    for i in 0..f.lanes_in_64() {
        out = set_lane(out, i, w, op(lane(a, i, w), lane(b, i, w), lane(c, i, w)));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{FP16, FP32, FP64};
    use crate::softfloat::{from_f64, to_f64, RoundingMode};

    #[test]
    fn pipeline_depths_match_paper() {
        // §IV-A: "3 levels of pipeline registers for the SDOTP operation
        // group, 3 for the ADDMUL, 2 for the CAST, and 1 for the COMP".
        assert_eq!(OpGroup::Sdotp.pipeline_stages(), 3);
        assert_eq!(OpGroup::AddMul.pipeline_stages(), 3);
        assert_eq!(OpGroup::Conv.pipeline_stages(), 2);
        assert_eq!(OpGroup::Comp.pipeline_stages(), 1);
    }

    #[test]
    fn group_dispatch() {
        let ex = FpuOp::ExSdotp { w: OpWidth::BtoH, rs1: 0, rs2: 0, rd: 0 };
        assert_eq!(ex.group(), OpGroup::Sdotp);
        assert_eq!(FpuOp::Fmadd { fmt: ScalarFmt::D, rs1: 0, rs2: 0, rs3: 0 }.group(), OpGroup::AddMul);
        assert_eq!(FpuOp::Fcvt { to: ScalarFmt::S, from: ScalarFmt::H, rs1: 0 }.group(), OpGroup::Conv);
        assert_eq!(FpuOp::Fsgnj { fmt: ScalarFmt::H, rs1: 0, rs2: 0 }.group(), OpGroup::Comp);
    }

    #[test]
    fn peak_flop_matches_table3_columns() {
        // Table III: FP8 16/16, FP16 8/8 (expanding/non-expanding).
        let fpu = Fpu;
        let csr = FpCsr::default();
        assert_eq!(fpu.peak_flop_per_cycle(&FpuOp::ExSdotp { w: OpWidth::BtoH, rs1: 0, rs2: 0, rd: 0 }, &csr), 16);
        assert_eq!(fpu.peak_flop_per_cycle(&FpuOp::Fmadd { fmt: ScalarFmt::B, rs1: 0, rs2: 0, rs3: 0 }, &csr), 16);
        assert_eq!(fpu.peak_flop_per_cycle(&FpuOp::ExSdotp { w: OpWidth::HtoS, rs1: 0, rs2: 0, rd: 0 }, &csr), 8);
        assert_eq!(fpu.peak_flop_per_cycle(&FpuOp::Fmadd { fmt: ScalarFmt::H, rs1: 0, rs2: 0, rs3: 0 }, &csr), 8);
        // FP64 FMA: 2 FLOP/cycle.
        assert_eq!(fpu.peak_flop_per_cycle(&FpuOp::Fmadd { fmt: ScalarFmt::D, rs1: 0, rs2: 0, rs3: 0 }, &csr), 2);
    }

    #[test]
    fn numerics_route_through_softfloat() {
        let fpu = Fpu;
        let csr = FpCsr::default();
        let a = from_f64(2.0, FP64, RoundingMode::Rne);
        let b = from_f64(3.0, FP64, RoundingMode::Rne);
        let c = from_f64(1.0, FP64, RoundingMode::Rne);
        let r = fpu.execute(FpuOp::Fmadd { fmt: ScalarFmt::D, rs1: a, rs2: b, rs3: c }, &csr);
        assert_eq!(f64::from_bits(r.value), 7.0);
        assert_eq!(r.latency, 3);

        // SIMD exsdotp: 4 FP16 pairs -> 2 FP32 accumulators.
        let h = |v: f64| from_f64(v, FP16, RoundingMode::Rne);
        let rs1 = h(1.0) | (h(2.0) << 16) | (h(3.0) << 32) | (h(4.0) << 48);
        let rs2 = h(1.0) | (h(1.0) << 16) | (h(1.0) << 32) | (h(1.0) << 48);
        let r = fpu.execute(FpuOp::ExSdotp { w: OpWidth::HtoS, rs1, rs2, rd: 0 }, &csr);
        assert_eq!(to_f64(r.value & 0xffff_ffff, FP32), 3.0); // 1+2
        assert_eq!(to_f64(r.value >> 32, FP32), 7.0); // 3+4
        assert_eq!(r.flops, 8);
    }

    #[test]
    fn alt_csr_bit_retargets_the_same_op() {
        let fpu = Fpu;
        let std = FpCsr::default();
        let alt = FpCsr { src_is_alt: true, ..FpCsr::default() };
        // The same bit pattern means different values under FP8 vs
        // FP8alt, so the same op must produce different results.
        let rs1 = 0x3838_3838_3838_3838u64; // FP8alt 1.0 x8
        let rs2 = rs1;
        let r_std = fpu.execute(FpuOp::ExSdotp { w: OpWidth::BtoH, rs1, rs2, rd: 0 }, &std);
        let r_alt = fpu.execute(FpuOp::ExSdotp { w: OpWidth::BtoH, rs1, rs2, rd: 0 }, &alt);
        assert_ne!(r_std.value, r_alt.value);
        // Under FP8alt, 0x38 = 1.0 -> each accumulator = 1+1 = 2.0 (FP16).
        assert_eq!(to_f64(r_alt.value & 0xffff, FP16), 2.0);
    }
}
