//! Differential validation of the batch engine: the fast tiers must be
//! **bit-identical** to the descriptor-driven softfloat/ExSdotp path —
//! across format pairs, rounding modes and special values — and the
//! batch GEMM engine (`gemm_dispatch` and the monomorphized kernels
//! behind it) must reproduce the generated kernels' C matrices exactly
//! (same accumulation order, same epilogue tree).

use super::*;
use crate::exsdotp::simd::{lane, set_lane};
use crate::isa::instr::{OpWidth, ScalarFmt};
use crate::kernels::{kernel_reference, GemmKernel};
use crate::softfloat::from_f64;
use crate::util::prop::{for_all, FpGen};
use crate::util::rng::Rng;

const RMS: [RoundingMode; 5] = [
    RoundingMode::Rne,
    RoundingMode::Rtz,
    RoundingMode::Rdn,
    RoundingMode::Rup,
    RoundingMode::Rmm,
];

/// The six Table I expanding pairs.
fn expanding_pairs() -> [(FpFormat, FpFormat); 6] {
    use crate::formats::{FP16, FP16ALT, FP32, FP8, FP8ALT};
    [(FP16, FP32), (FP16ALT, FP32), (FP8, FP16), (FP8, FP16ALT), (FP8ALT, FP16), (FP8ALT, FP16ALT)]
}

fn random_mats(m: usize, n: usize, k: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let a: Vec<f64> = (0..m * k).map(|_| rng.gaussian() * 0.5).collect();
    let b: Vec<f64> = (0..k * n).map(|_| rng.gaussian() * 0.5).collect();
    (a, b)
}

fn all_kinds() -> [GemmKind; 5] {
    [
        GemmKind::FmaF64,
        GemmKind::FmaSimd(ScalarFmt::S),
        GemmKind::FmaSimd(ScalarFmt::H),
        GemmKind::ExSdotp(OpWidth::HtoS),
        GemmKind::ExSdotp(OpWidth::BtoH),
    ]
}

// ---------------------------------------------------------------- slices

#[test]
fn accumulate_matches_descriptor_fold_all_pairs() {
    // Packed-register accumulation: monomorphized dispatch vs a plain
    // descriptor-driven fold, random words (NaN/Inf lanes included by
    // construction — random bits hit specials often in narrow formats).
    for (src, dst) in expanding_pairs() {
        let simd = SimdExSdotp::new(src, dst);
        for_all("batch accumulate", 400, |rng| {
            let len = (rng.below(24) + 1) as usize;
            let rs1: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
            let rs2: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
            let acc0 = rng.next_u64();
            for rm in RMS {
                let want = rs1.iter().zip(&rs2).fold(acc0, |acc, (&x, &y)| simd.exsdotp(x, y, acc, rm));
                assert_eq!(
                    exsdotp_accumulate(src, dst, &rs1, &rs2, acc0, rm),
                    want,
                    "{}→{} rm={rm:?}",
                    src.name(),
                    dst.name()
                );
            }
        });
    }
}

#[test]
fn accumulate_fallback_for_custom_formats() {
    // A non-Table-I pair takes the descriptor fallback and still folds
    // correctly.
    let e5m1 = FpFormat::new(5, 1);
    let dst = crate::formats::FP16;
    let simd = SimdExSdotp::new(e5m1, dst);
    let mut rng = Rng::new(9);
    let rs1: Vec<u64> = (0..16).map(|_| rng.next_u64()).collect();
    let rs2: Vec<u64> = (0..16).map(|_| rng.next_u64()).collect();
    let want = rs1.iter().zip(&rs2).fold(7u64, |acc, (&x, &y)| simd.exsdotp(x, y, acc, RoundingMode::Rne));
    assert_eq!(exsdotp_accumulate(e5m1, dst, &rs1, &rs2, 7, RoundingMode::Rne), want);
}

#[test]
fn cast_slice_matches_scalar_casts_with_specials() {
    use crate::formats::PAPER_FORMATS;
    // Boundary-biased values for every (from, to) paper pair, all modes.
    for from in PAPER_FORMATS {
        let gen = FpGen::new(from);
        let mut rng = Rng::new(0xCA57);
        let vals: Vec<u64> = (0..512).map(|_| gen.any(&mut rng)).collect();
        for to in PAPER_FORMATS {
            for rm in RMS {
                let got = cast_slice(from, to, &vals, rm);
                for (i, &v) in vals.iter().enumerate() {
                    assert_eq!(got[i], cast(from, to, v, rm), "{}→{} {v:#x} rm={rm:?}", from.name(), to.name());
                }
            }
        }
    }
    // Custom-format fallback.
    let e3m4 = FpFormat::new(3, 4);
    let vals: Vec<u64> = (0..256).collect();
    let got = cast_slice(e3m4, crate::formats::FP32, &vals, RoundingMode::Rne);
    for (i, &v) in vals.iter().enumerate() {
        assert_eq!(got[i], cast(e3m4, crate::formats::FP32, v, RoundingMode::Rne));
    }
}

// ------------------------------------------------------------------ GEMM

#[test]
fn batch_gemm_bit_identical_to_kernel_reference_all_kinds() {
    // The reference replays the generated kernels' accumulation order
    // per element; gemm_dispatch must match it bit for bit.
    let (m, n, k) = (16, 24, 32);
    let (a, b) = random_mats(m, n, k, 2024);
    for kind in all_kinds() {
        let kern = GemmKernel::new(kind, m, n, k);
        let got = gemm_dispatch(kind, m, n, k, &a, &b, RoundingMode::Rne);
        let want = kernel_reference(&kern, &a, &b);
        for (idx, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!(
                g.to_bits() == w.to_bits() || (g.is_nan() && w.is_nan()),
                "{} C[{}/{}]: got {g}, want {w}",
                kind.label(),
                idx / n,
                idx % n
            );
        }
    }
}

#[test]
fn functional_mode_bit_identical_to_cycle_accurate() {
    // The acceptance gate: ExecMode::Functional C == the simulated
    // cluster's C, element for element (f64-decoded bits).
    let (m, n, k) = (16, 16, 32);
    let (a, b) = random_mats(m, n, k, 7);
    for kind in all_kinds() {
        let kern = GemmKernel::new(kind, m, n, k);
        let sim = kern.run_mode(&a, &b, crate::kernels::ExecMode::CycleAccurate);
        let fun = kern.run_mode(&a, &b, crate::kernels::ExecMode::Functional);
        assert_eq!(sim.flops, fun.flops);
        for (idx, (s, f)) in sim.c.iter().zip(&fun.c).enumerate() {
            assert!(
                s.to_bits() == f.to_bits() || (s.is_nan() && f.is_nan()),
                "{} C[{idx}]: simulated {s} vs functional {f}",
                kind.label()
            );
        }
    }
}

#[test]
fn gemm_handles_special_inputs_like_the_reference() {
    // Inf/NaN-producing inputs (FP8 saturates early) must flow through
    // both paths identically, not just well-conditioned Gaussians.
    let (m, n, k) = (8, 8, 16);
    let mut rng = Rng::new(55);
    let spice = |r: &mut Rng| match r.below(8) {
        0 => f64::INFINITY,
        1 => f64::NEG_INFINITY,
        2 => 60000.0,  // overflows FP8 products
        3 => -60000.0,
        4 => 1e-9,     // subnormal territory for 8-bit formats
        _ => r.gaussian(),
    };
    let a: Vec<f64> = (0..m * k).map(|_| spice(&mut rng)).collect();
    let b: Vec<f64> = (0..k * n).map(|_| spice(&mut rng)).collect();
    for kind in [GemmKind::ExSdotp(OpWidth::BtoH), GemmKind::ExSdotp(OpWidth::HtoS)] {
        let kern = GemmKernel::new(kind, m, n, k);
        let got = gemm_dispatch(kind, m, n, k, &a, &b, RoundingMode::Rne);
        let want = kernel_reference(&kern, &a, &b);
        for (g, w) in got.iter().zip(&want) {
            assert!(g.to_bits() == w.to_bits() || (g.is_nan() && w.is_nan()), "{}: {g} vs {w}", kind.label());
        }
    }
}

#[test]
fn gemm_m_rounding_modes_propagate() {
    // Direct monomorphized entry point, non-default rounding mode: the
    // result must track a hand-rolled packed fold in the same mode.
    use crate::formats::spec::{Fp16, Fp8};
    let (m, n, k) = (4, 4, 16);
    let (a, b) = random_mats(m, n, k, 31);
    for rm in RMS {
        let got = gemm_m::<Fp8, Fp16>(m, n, k, &a, &b, rm);
        // Reference: per (i, j), pack lanes and fold with the
        // descriptor-driven SIMD unit in the same rounding mode.
        let simd = SimdExSdotp::new(crate::formats::FP8, crate::formats::FP16);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0u64;
                for kc in 0..k / 8 {
                    let mut aw = 0u64;
                    let mut bw = 0u64;
                    for l in 0..8 {
                        let kk = kc * 8 + l;
                        aw = set_lane(aw, l as u32, 8, from_f64(a[i * k + kk], crate::formats::FP8, rm));
                        bw = set_lane(bw, l as u32, 8, from_f64(b[kk * n + j], crate::formats::FP8, rm));
                    }
                    acc = simd.exsdotp(aw, bw, acc, rm);
                }
                let t = simd.vsum(acc, 0, rm);
                let t2 = simd.vsum(t, 0, rm);
                let want = crate::softfloat::to_f64(lane(t2, 0, 16), crate::formats::FP16);
                let got_ij = got[i * n + j];
                assert!(
                    got_ij.to_bits() == want.to_bits() || (got_ij.is_nan() && want.is_nan()),
                    "rm={rm:?} C[{i},{j}]"
                );
            }
        }
    }
}

#[test]
fn packing_layouts_match_expectations() {
    use crate::formats::spec::Fp16;
    // 2×8 row pack: row r, word w holds elements [w*4, w*4+4) of row r.
    let data: Vec<f64> = (0..16).map(|x| x as f64).collect();
    let rows = pack_rows_m::<Fp16>(&data, 2, 8, RoundingMode::Rne);
    assert_eq!(rows.len(), 4);
    assert_eq!(lane(rows[0], 2, 16), from_f64(2.0, crate::formats::FP16, RoundingMode::Rne));
    assert_eq!(lane(rows[3], 1, 16), from_f64(13.0, crate::formats::FP16, RoundingMode::Rne));
    // 8×2 column pack: column j, word w holds rows [w*4, w*4+4) of col j.
    let cols = pack_cols_m::<Fp16>(&data, 8, 2, RoundingMode::Rne);
    assert_eq!(cols.len(), 4);
    // column 1, word 0, lane 2 = element (row 2, col 1) = 5.0
    assert_eq!(lane(cols[2], 2, 16), from_f64(5.0, crate::formats::FP16, RoundingMode::Rne));
}

// ------------------------------------------------ backward-pass shapes

#[test]
fn transposed_gemms_are_bit_identical_to_pretransposed_plain_gemms() {
    // gemm_tn_m / gemm_nt_m only swap which packer builds each stream,
    // so against a host-side pre-transpose of the same operand they
    // must reproduce gemm_m bit for bit — for every expanding pair.
    let (m, n, k) = (8, 12, 16);
    let transpose = |x: &[f64], rows: usize, cols: usize| -> Vec<f64> {
        let mut out = vec![0f64; x.len()];
        for r in 0..rows {
            for c in 0..cols {
                out[c * rows + r] = x[r * cols + c];
            }
        }
        out
    };
    let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    let mut rng = Rng::new(123);
    let a_raw: Vec<f64> = (0..k * m).map(|_| rng.gaussian() * 0.3).collect();
    let b: Vec<f64> = (0..k * n).map(|_| rng.gaussian() * 0.3).collect();
    let a: Vec<f64> = (0..m * k).map(|_| rng.gaussian() * 0.3).collect();
    let b_raw: Vec<f64> = (0..n * k).map(|_| rng.gaussian() * 0.3).collect();
    for (src, dst) in expanding_pairs() {
        let rm = RoundingMode::Rne;
        let tn = gemm_expanding(src, dst, true, false, m, n, k, &a_raw, &b, rm).expect("pair");
        let want = gemm_expanding(src, dst, false, false, m, n, k, &transpose(&a_raw, k, m), &b, rm)
            .expect("pair");
        assert_eq!(bits(&tn), bits(&want), "{}→{} A^T·B", src.name(), dst.name());
        let nt = gemm_expanding(src, dst, false, true, m, n, k, &a, &b_raw, rm).expect("pair");
        let want = gemm_expanding(src, dst, false, false, m, n, k, &a, &transpose(&b_raw, n, k), rm)
            .expect("pair");
        assert_eq!(bits(&nt), bits(&want), "{}→{} A·B^T", src.name(), dst.name());
    }
    // Double transpose and non-expanding pairs stay unsupported here.
    assert!(gemm_expanding(crate::formats::FP8, crate::formats::FP16, true, true, m, n, k, &a, &b, RoundingMode::Rne).is_none());
    assert!(gemm_expanding(crate::formats::FP32, crate::formats::FP32, true, false, m, n, k, &a_raw, &b, RoundingMode::Rne).is_none());
}

// ------------------------------------------- executor & workspace reuse

#[test]
fn dispatch_backends_bit_identical_all_expanding_pairs() {
    // The pooled executor, the legacy scoped-thread backend and the
    // serial path must produce bit-identical GEMMs for every Table I
    // pair (the chunk→index mapping is the determinism contract).
    use crate::util::parallel::{with_dispatch, with_worker_count, Dispatch};
    let (m, n, k) = (16, 24, 32);
    let (a, b) = random_mats(m, n, k, 4242);
    let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    for (src, dst) in expanding_pairs() {
        let run = |mode: Dispatch| {
            with_dispatch(mode, || {
                gemm_expanding(src, dst, false, false, m, n, k, &a, &b, RoundingMode::Rne).expect("pair")
            })
        };
        let pooled = run(Dispatch::Pool);
        let scoped = run(Dispatch::Scoped);
        let serial = run(Dispatch::Serial);
        assert_eq!(bits(&pooled), bits(&scoped), "{}→{} pool vs scoped", src.name(), dst.name());
        assert_eq!(bits(&pooled), bits(&serial), "{}→{} pool vs serial", src.name(), dst.name());
        // And at odd worker budgets over the pool.
        for workers in [3usize, 7] {
            let odd = with_worker_count(workers, || run(Dispatch::Pool));
            assert_eq!(bits(&odd), bits(&pooled), "{}→{} pool @{workers} workers", src.name(), dst.name());
        }
    }
}

#[test]
fn dispatch_backends_bit_identical_all_kinds() {
    // Same contract for the FMA kernel families (fp64 / SIMD FMA).
    use crate::util::parallel::{with_dispatch, Dispatch};
    let (m, n, k) = (8, 8, 16);
    let (a, b) = random_mats(m, n, k, 99);
    let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    for kind in all_kinds() {
        let run = |mode: Dispatch| with_dispatch(mode, || gemm_dispatch(kind, m, n, k, &a, &b, RoundingMode::Rne));
        let pooled = run(Dispatch::Pool);
        assert_eq!(bits(&pooled), bits(&run(Dispatch::Scoped)), "{} pool vs scoped", kind.label());
        assert_eq!(bits(&pooled), bits(&run(Dispatch::Serial)), "{} pool vs serial", kind.label());
    }
}

#[test]
fn workspace_reuse_is_bit_invisible() {
    // One workspace threaded through different shapes, formats and
    // transposes in sequence: every result must equal a fresh-buffer
    // run (a workspace is capacity, not state).
    let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    let mut ws = Workspace::new();
    let mut out = Vec::new();
    let cases = [(8usize, 8usize, 16usize, 1u64), (16, 24, 32, 2), (8, 12, 16, 3), (16, 16, 16, 4)];
    for (i, &(m, n, k, seed)) in cases.iter().enumerate() {
        let (a, b) = random_mats(m, n, k, seed);
        for (src, dst) in expanding_pairs() {
            assert!(gemm_expanding_into(src, dst, false, false, m, n, k, &a, &b, RoundingMode::Rne, &mut ws, &mut out));
            let fresh = gemm_expanding(src, dst, false, false, m, n, k, &a, &b, RoundingMode::Rne).expect("pair");
            assert_eq!(bits(&out), bits(&fresh), "case {i} {}→{} reused workspace diverged", src.name(), dst.name());
        }
        for kind in all_kinds() {
            gemm_dispatch_into(kind, m, n, k, &a, &b, RoundingMode::Rne, &mut ws, &mut out);
            let fresh = gemm_dispatch(kind, m, n, k, &a, &b, RoundingMode::Rne);
            assert_eq!(bits(&out), bits(&fresh), "case {i} {} reused workspace diverged", kind.label());
        }
    }
    assert!(ws.capacity_bytes() > 0, "workspace should retain capacity after use");
}

#[test]
fn into_variants_match_allocating_twins() {
    use crate::formats::{FP16, FP8};
    let (m, n, k) = (8, 8, 16);
    let (a, b) = random_mats(m, n, k, 77);
    let rm = RoundingMode::Rne;
    // Packing into a reused (dirty) buffer.
    let mut buf = vec![0xDEAD_BEEFu64; 3]; // wrong size + garbage on purpose
    pack_rows_into_m::<Fp8>(&a, m, k, rm, &mut buf);
    assert_eq!(buf, pack_rows_m::<Fp8>(&a, m, k, rm));
    pack_cols_into_m::<Fp8>(&b, k, n, rm, &mut buf);
    assert_eq!(buf, pack_cols_m::<Fp8>(&b, k, n, rm));
    // Packed GEMM into a reused buffer.
    let ap = pack_rows_m::<Fp8>(&a, m, k, rm);
    let bp = pack_cols_m::<Fp8>(&b, k, n, rm);
    let mut c = vec![f64::NAN; 1]; // garbage on purpose
    gemm_packed_into_m::<Fp8, Fp16>(m, n, k, &ap, &bp, rm, &mut c);
    let fresh = gemm_packed_m::<Fp8, Fp16>(m, n, k, &ap, &bp, rm);
    assert_eq!(
        c.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        fresh.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
    );
    assert!(gemm_packed_into(FP8, FP16, m, n, k, &ap, &bp, rm, &mut c), "runtime dispatch must hit");
    // Cast into a reused buffer (monomorphized pair + custom fallback).
    let words: Vec<u64> = (0..300).collect();
    let mut cast_buf = vec![7u64; 9000];
    cast_slice_into(FP8, FP16, &words, rm, &mut cast_buf);
    assert_eq!(cast_buf, cast_slice(FP8, FP16, &words, rm));
    let e3m4 = FpFormat::new(3, 4);
    cast_slice_into(e3m4, FP16, &words, rm, &mut cast_buf);
    assert_eq!(cast_buf, cast_slice(e3m4, FP16, &words, rm));
}

// --------------------------------------------- lane tiers and blocking

#[test]
fn lane_tiers_bit_identical_all_pairs() {
    // The SWAR default vs the pinned scalar reference, full GEMMs, all
    // six expanding pairs, all rounding modes — with inputs spiced to
    // produce Inf/NaN/subnormal lanes so both the all-finite fast path
    // and the screened fallback run.
    let (m, n, k) = (12, 20, 32);
    let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    let mut rng = Rng::new(0x5AA5);
    let spice = |r: &mut Rng| match r.below(10) {
        0 => f64::INFINITY,
        1 => -60000.0, // overflows narrow formats
        2 => 1e-9,     // subnormal territory
        3 => -0.0,
        _ => r.gaussian() * 0.5,
    };
    let a: Vec<f64> = (0..m * k).map(|_| spice(&mut rng)).collect();
    let b: Vec<f64> = (0..k * n).map(|_| spice(&mut rng)).collect();
    let (ga, gb) = random_mats(m, n, k, 0xF1E1D); // all-finite Gaussians
    for (src, dst) in expanding_pairs() {
        for rm in [RoundingMode::Rne, RoundingMode::Rdn, RoundingMode::Rup, RoundingMode::Rtz, RoundingMode::Rmm] {
            for (aa, bb) in [(&a, &b), (&ga, &gb)] {
                let swar = with_lane_tier(LaneTier::Swar, || {
                    gemm_expanding(src, dst, false, false, m, n, k, aa, bb, rm).expect("pair")
                });
                let scalar = with_lane_tier(LaneTier::Scalar, || {
                    gemm_expanding(src, dst, false, false, m, n, k, aa, bb, rm).expect("pair")
                });
                assert_eq!(bits(&swar), bits(&scalar), "{}→{} rm={rm:?} tiers diverged", src.name(), dst.name());
            }
        }
    }
}

#[test]
fn blocked_plans_bit_identical_to_simple_loop() {
    // Forced custom tilings — including tile sizes that do not divide
    // the problem in any dimension — must reproduce the simple loop bit
    // for bit on both tiers: blocking only re-associates the loop nest,
    // never the per-element fold order.
    use crate::formats::spec::{Fp16, Fp8};
    let (m, n, k) = (10, 20, 48); // wpr = 6 for FP8
    let (a, b) = random_mats(m, n, k, 0xB10C);
    let rm = RoundingMode::Rne;
    let mut ws = Workspace::new();
    pack_rows_into_m::<Fp8>(&a, m, k, rm, &mut ws.pa);
    pack_cols_into_m::<Fp8>(&b, k, n, rm, &mut ws.pb);
    let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();

    let mut simple = Vec::new();
    gemm_packed_planned_into_m::<Fp8, Fp16>(&BlockPlan::simple(), m, n, k, &ws.pa, &ws.pb, rm, &mut simple);
    let plans = [
        BlockPlan::custom(4, 8, 4),  // none of m/n/wpr divide evenly
        BlockPlan::custom(1, 1, 1),  // degenerate 1×1 tiles, word-at-a-time K
        BlockPlan::custom(16, 64, 512), // tiles larger than the problem
        BlockPlan::custom(3, 7, 5),  // coprime everything
    ];
    for tier in [LaneTier::Swar, LaneTier::Scalar] {
        for plan in &plans {
            let mut blocked = Vec::new();
            with_lane_tier(tier, || {
                gemm_packed_planned_into_m::<Fp8, Fp16>(plan, m, n, k, &ws.pa, &ws.pb, rm, &mut blocked);
            });
            assert_eq!(bits(&blocked), bits(&simple), "{tier:?} {plan:?} diverged from simple loop");
        }
    }
}

#[test]
fn blocked_plans_handle_special_lanes() {
    // Packed panels carrying Inf/NaN lanes defeat the pack-once screen;
    // the blocked SWAR path must fall back per register and still match.
    use crate::formats::spec::{Fp16, Fp8};
    let (m, n, k) = (8, 8, 32);
    let (a, mut b) = random_mats(m, n, k, 0x5bec);
    b[3] = f64::INFINITY;
    b[17] = f64::NAN;
    let rm = RoundingMode::Rne;
    let mut ws = Workspace::new();
    pack_rows_into_m::<Fp8>(&a, m, k, rm, &mut ws.pa);
    pack_cols_into_m::<Fp8>(&b, k, n, rm, &mut ws.pb);
    assert!(!crate::softfloat::swar::slice_all_finite::<Fp8>(&ws.pb));
    let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    let mut want = Vec::new();
    with_lane_tier(LaneTier::Scalar, || {
        gemm_packed_planned_into_m::<Fp8, Fp16>(&BlockPlan::simple(), m, n, k, &ws.pa, &ws.pb, rm, &mut want);
    });
    let mut got = Vec::new();
    gemm_packed_planned_into_m::<Fp8, Fp16>(&BlockPlan::custom(4, 4, 2), m, n, k, &ws.pa, &ws.pb, rm, &mut got);
    assert_eq!(bits(&got), bits(&want));
}

#[test]
fn block_plan_threshold_decisions() {
    // Small/benchmark shapes stay simple; large shapes tile.
    assert!(!BlockPlan::for_problem(32, 32, 4).blocked, "32³ steady-state stays simple");
    assert!(!BlockPlan::for_problem(128, 128, 16).blocked, "128³ FP8 headline stays simple");
    assert!(BlockPlan::for_problem(512, 512, 64).blocked, "512³ FP8 tiles");
    assert!(BlockPlan::for_problem(512, 512, 128).blocked, "512³ FP16 tiles");
    assert!(!BlockPlan::for_problem(16, 4096, 64).blocked, "too few rows to tile");
    assert!(!BlockPlan::for_problem(4096, 16, 640).blocked, "too few cols to tile");
    // The tier override is scoped and restored.
    assert_eq!(lane_tier(), LaneTier::Swar);
    with_lane_tier(LaneTier::Scalar, || assert_eq!(lane_tier(), LaneTier::Scalar));
    assert_eq!(lane_tier(), LaneTier::Swar);
}

#[test]
fn regrid_in_place_matches_quantize_decode() {
    use crate::formats::{FP16, FP8, FP8ALT};
    let mut rng = Rng::new(0x9E61D);
    let vals: Vec<f64> = (0..600)
        .map(|i| match i % 7 {
            0 => f64::INFINITY,
            1 => -0.0,
            2 => 1e-9,
            3 => 70000.0,
            _ => rng.gaussian() * 4.0,
        })
        .collect();
    for fmt in [FP8, FP8ALT, FP16, FpFormat::new(3, 4)] {
        for rm in RMS {
            let mut got = vals.clone();
            regrid_in_place(fmt, &mut got, rm);
            for (i, &v) in vals.iter().enumerate() {
                let want = to_f64(from_f64(v, fmt, rm), fmt);
                assert!(
                    got[i].to_bits() == want.to_bits() || (got[i].is_nan() && want.is_nan()),
                    "{} rm={rm:?} v={v}: {} vs {want}",
                    fmt.name(),
                    got[i]
                );
            }
        }
    }
}
