//! Tier B of the batch numerics engine: slice-level operations over
//! packed 64-bit registers.
//!
//! The cycle-accurate cluster pushes every simulated FP instruction
//! through a runtime-`FpFormat`-dispatched `unpack → compute →
//! round_pack` chain — perfect for studying the machine, hopeless for
//! *using* the numerics at scale (a 128×128×128 FP8 GEMM is two million
//! ExSdotp lane evaluations, each re-deriving format parameters). This
//! module is the scale path:
//!
//! * operands live packed in `u64` words, exactly as the 64-bit FP
//!   register file holds them (§III-D), and move through the
//!   monomorphized Tier-A kernels ([`crate::softfloat::fast`],
//!   [`crate::exsdotp::fast`]) with no per-lane re-dispatch;
//! * slice operations ([`exsdotp_accumulate`], [`cast_slice`],
//!   [`gemm_m`]) iterate whole registers and parallelize across output
//!   rows with [`crate::util::parallel`] (the persistent worker pool);
//! * every operation replays the **identical accumulation order** of
//!   the generated GEMM kernels (packed-lane partial sums, `vsum`
//!   epilogue tree), so results are bit-identical to the simulated
//!   cluster's C matrix — the differential tests in this module and the
//!   `ExecMode` equivalence tests in [`crate::kernels`] pin that down.
//!
//! ## `_into` variants and the [`Workspace`]
//!
//! Every hot entry point has an `_into` twin writing into
//! caller-provided buffers ([`gemm_packed_into_m`], [`cast_slice_into`],
//! [`pack_rows_into_m`], …); the allocating functions are thin wrappers
//! that delegate to them with fresh buffers. A [`Workspace`] bundles the
//! packed-operand and staging scratch a GEMM needs, so steady-state
//! callers ([`crate::api::PlanInstance`], and through it the nn trainer
//! and serve shards) pay **zero allocation per call**. A workspace is
//! recycled capacity only — it carries no numeric state, so reuse
//! cannot change a single output bit (pinned by differential tests).
//!
//! ## Lane tiers and cache blocking
//!
//! The expanding GEMM core runs one of two **lane tiers** — selected
//! here and nowhere else (layering rule: layers above `batch` never
//! pick a tier, layers below never see one):
//!
//! * [`LaneTier::Swar`] (default) — the lane-parallel kernels of
//!   [`crate::exsdotp::swar`]: packed operand panels are screened for
//!   special lanes **once per GEMM** ([`slice_all_finite`]), then the
//!   inner loop runs the all-finite SWAR datapath with only the
//!   running accumulator re-screened per step;
//! * [`LaneTier::Scalar`] — the untouched PR-5 per-lane path
//!   ([`simd_exsdotp_m`] row loop), kept verbatim as the differential
//!   and timing reference ([`with_lane_tier`] pins it for tests and
//!   the bench speedup gates).
//!
//! Both tiers are bit-identical by construction (shared `round_pack`,
//! specials routed to the scalar kernels) and pinned by differential
//! tests here and in [`crate::exsdotp::swar`]. Large GEMMs additionally
//! run **cache-blocked**: a [`BlockPlan`] tiles the output into
//! `MC×NC` blocks streamed over `KC_WORDS`-word K-panels, with the
//! packed-operand panels in [`Workspace`] (`pa`/`pb`) as the tile
//! storage and a per-worker stack accumulator tile. The k-outer loop
//! order folds each output's words in the identical ascending-k
//! sequence, so blocking cannot change a single bit either —
//! [`BlockPlan::for_problem`] only decides *when* it pays.
//!
//! This is the engine behind `ExecMode::Functional`
//! ([`crate::kernels::gemm::ExecMode`]) and the accuracy-sweep fast
//! path ([`crate::accuracy`]).

#[cfg(test)]
mod tests;

use crate::exsdotp::fast::{simd_exsdotp_m, vsum_m, vsum_tree_m};
use crate::exsdotp::simd::SimdExSdotp;
use crate::exsdotp::swar::{swar_exsdotp_m, swar_exsdotp_operands_finite_m, vsum_tree_swar_m};
use crate::formats::spec::{ExpandTo, FormatSpec, Fp16, Fp16alt, Fp32, Fp64, Fp8, Fp8alt};
use crate::formats::FpFormat;
use crate::kernels::gemm::GemmKind;
use crate::softfloat::fast::{cast_m, fma_m, from_f64_m, to_f64_m};
use crate::softfloat::swar::slice_all_finite;
use crate::softfloat::{cast, from_f64, to_f64, RoundingMode};
use crate::util::parallel::par_chunks_mut;
use std::cell::Cell;

/// Elements per parallel work chunk for flat slice operations.
const CAST_CHUNK: usize = 8192;

/// Dispatch a runtime [`FpFormat`] to its compile-time [`FormatSpec`]
/// type, binding it as `$S` within `$body`. Falls through (no-op) for
/// non-paper formats so the caller's fallback code runs; `$body` must
/// diverge (e.g. `return`) when it fully handles the case.
macro_rules! with_spec {
    ($fmt:expr, $S:ident, $body:block) => {
        match ($fmt.exp_bits, $fmt.man_bits) {
            (5, 2) => {
                type $S = Fp8;
                $body
            }
            (4, 3) => {
                type $S = Fp8alt;
                $body
            }
            (5, 10) => {
                type $S = Fp16;
                $body
            }
            (8, 7) => {
                type $S = Fp16alt;
                $body
            }
            (8, 23) => {
                type $S = Fp32;
                $body
            }
            (11, 52) => {
                type $S = Fp64;
                $body
            }
            _ => {}
        }
    };
}

// ------------------------------------------------------------ lane tier

/// Which per-register kernel implementation the expanding GEMM core
/// runs. Tier selection happens in this module only; both tiers are
/// bit-identical (differentially pinned), so the choice is purely a
/// throughput knob.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LaneTier {
    /// Lane-parallel SWAR kernels ([`crate::exsdotp::swar`]) — the
    /// default.
    Swar,
    /// The per-lane scalar kernels ([`crate::exsdotp::fast`]) — the
    /// differential / timing reference.
    Scalar,
}

thread_local! {
    /// Per-thread lane-tier override (see [`with_lane_tier`]).
    static LANE_TIER_OVERRIDE: Cell<Option<LaneTier>> = const { Cell::new(None) };
}

/// The lane tier active on this thread (default [`LaneTier::Swar`]).
/// The GEMM entry points resolve this **on the calling thread** before
/// fanning out to the worker pool, so an override scopes the whole
/// parallel operation.
pub fn lane_tier() -> LaneTier {
    LANE_TIER_OVERRIDE.with(|c| c.get()).unwrap_or(LaneTier::Swar)
}

/// Run `f` with the lane tier pinned on this thread; restored on exit
/// (even across panics). Exists for differential tests and the
/// scalar-baseline legs of the speedup benchmarks — production code
/// leaves the default SWAR tier in place.
pub fn with_lane_tier<R>(t: LaneTier, f: impl FnOnce() -> R) -> R {
    struct Restore(Option<LaneTier>);
    impl Drop for Restore {
        fn drop(&mut self) {
            LANE_TIER_OVERRIDE.with(|c| c.set(self.0));
        }
    }
    let _guard = Restore(LANE_TIER_OVERRIDE.with(|c| c.replace(Some(t))));
    f()
}

// ------------------------------------------------------------- blocking

/// Output rows per cache block (and per parallel work chunk on the
/// blocked path).
pub const BLOCK_MC: usize = 16;
/// Output columns per cache block.
pub const BLOCK_NC: usize = 64;
/// Packed K-dimension words per panel chunk (`KC_WORDS · 8` bytes of
/// one operand row stream ≈ half an L1d).
pub const BLOCK_KC_WORDS: usize = 512;
/// Capacity of the per-worker stack accumulator tile (8 KiB).
const ACC_TILE_WORDS: usize = BLOCK_MC * BLOCK_NC;

/// A compiled blocking decision for one GEMM shape: either the simple
/// row-streaming loop (small problems — every shape the generated
/// cluster kernels actually run) or `MC×NC×KC` cache-blocked tiling.
/// Blocking is loop *re-association without re-ordering*: each output
/// element still folds its packed words in ascending-k order, so a plan
/// never changes results — [`crate::api::PlanInstance`] compiles one at
/// assembly time and reuses it every run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockPlan {
    /// Rows per block.
    pub mc: usize,
    /// Columns per block.
    pub nc: usize,
    /// Packed words of K per panel chunk.
    pub kc_words: usize,
    /// Whether the blocked path runs at all.
    pub blocked: bool,
}

impl BlockPlan {
    /// The simple row-streaming loop (no tiling).
    pub const fn simple() -> BlockPlan {
        BlockPlan { mc: BLOCK_MC, nc: BLOCK_NC, kc_words: BLOCK_KC_WORDS, blocked: false }
    }

    /// Decide blocking for an `m×n` output over `wpr` packed words per
    /// row stream. Tiling pays once the B-panel working set outgrows
    /// cache and blocks are full-sized; below that the simple loop wins
    /// (and keeps the benchmarked small-shape paths byte-for-byte on
    /// the PR-5 code).
    pub fn for_problem(m: usize, n: usize, wpr: usize) -> BlockPlan {
        let blocked = m >= 2 * BLOCK_MC && n >= 2 * BLOCK_NC && n * wpr >= 1 << 13;
        BlockPlan { blocked, ..BlockPlan::simple() }
    }

    /// A forced custom tiling (tests exercise edge geometries with it).
    /// Tile dimensions must be nonzero and fit the stack accumulator.
    pub fn custom(mc: usize, nc: usize, kc_words: usize) -> BlockPlan {
        assert!(mc > 0 && nc > 0 && kc_words > 0, "degenerate block plan");
        assert!(mc * nc <= ACC_TILE_WORDS, "tile exceeds the stack accumulator");
        BlockPlan { mc, nc, kc_words, blocked: true }
    }
}

// ------------------------------------------------------------ workspace

/// Reusable scratch for the batch engine's `_into` entry points:
/// packed operands and f64 staging. Plain recycled capacity — a
/// workspace carries **no numeric state**, so reusing one across calls
/// (of any shape or format) cannot change a single result bit; every
/// buffer is cleared and resized by the operation that fills it.
#[derive(Debug, Default)]
pub struct Workspace {
    /// Packed operand-A words (also FMA64's transposed-B bit image).
    pub(crate) pa: Vec<u64>,
    /// Packed operand-B words.
    pub(crate) pb: Vec<u64>,
    /// f64 staging for operand A (tensor decode on the fallback route).
    pub(crate) fa: Vec<f64>,
    /// f64 staging for operand B.
    pub(crate) fb: Vec<f64>,
    /// f64 staging for a transposed logical A (FMA-family fallback).
    pub(crate) ft_a: Vec<f64>,
    /// f64 staging for a transposed logical B (FMA-family fallback).
    pub(crate) ft_b: Vec<f64>,
}

impl Workspace {
    /// An empty workspace (buffers grow on first use, then stick).
    pub fn new() -> Workspace {
        Workspace::default()
    }

    /// Bytes of capacity currently held across all scratch buffers
    /// (introspection for tests and allocation accounting).
    pub fn capacity_bytes(&self) -> usize {
        8 * (self.pa.capacity()
            + self.pb.capacity()
            + self.fa.capacity()
            + self.fb.capacity()
            + self.ft_a.capacity()
            + self.ft_b.capacity())
    }
}

// ---------------------------------------------------------------- casts

/// Cast every element of `bits` (encodings in `from`, one per `u64`)
/// into `to`, correctly rounded. Monomorphizes over the six paper
/// formats (36 specialized pairs) and falls back to the descriptor path
/// for custom formats; parallel over chunks either way.
pub fn cast_slice(from: FpFormat, to: FpFormat, bits: &[u64], rm: RoundingMode) -> Vec<u64> {
    let mut out = Vec::new();
    cast_slice_into(from, to, bits, rm, &mut out);
    out
}

/// [`cast_slice`] into a caller-provided buffer (cleared and resized;
/// capacity is reused).
pub fn cast_slice_into(from: FpFormat, to: FpFormat, bits: &[u64], rm: RoundingMode, out: &mut Vec<u64>) {
    out.clear();
    out.resize(bits.len(), 0);
    with_spec!(from, S, {
        with_spec!(to, D, {
            cast_into_m::<S, D>(bits, out, rm);
            return;
        })
    });
    // Fallback: custom formats go through the runtime descriptors.
    par_chunks_mut(out, CAST_CHUNK, |ci, chunk| {
        let base = ci * CAST_CHUNK;
        for (off, o) in chunk.iter_mut().enumerate() {
            *o = cast(from, to, bits[base + off], rm.sr_element((base + off) as u64));
        }
    });
}

/// Monomorphized slice cast `S → D` into a preallocated output.
/// Element `i` rounds under `rm.sr_element(i)` — identity for the IEEE
/// modes, a per-element stochastic key otherwise, derived from the
/// *global* element index so the result is independent of worker count.
pub fn cast_into_m<S: FormatSpec, D: FormatSpec>(bits: &[u64], out: &mut [u64], rm: RoundingMode) {
    assert_eq!(bits.len(), out.len());
    par_chunks_mut(out, CAST_CHUNK, |ci, chunk| {
        let base = ci * CAST_CHUNK;
        for (off, o) in chunk.iter_mut().enumerate() {
            *o = cast_m::<S, D>(bits[base + off], rm.sr_element((base + off) as u64));
        }
    });
}

/// Round every value onto `fmt`'s grid in place (quantize + decode,
/// single rounding) — the plan layer's epilogue re-encode without
/// materializing a tensor. Bit-identical to packing the slice into an
/// [`crate::api::MfTensor`] and decoding it back, for every format
/// (monomorphized for the six paper formats, descriptor fallback
/// otherwise).
pub fn regrid_in_place(fmt: FpFormat, vals: &mut [f64], rm: RoundingMode) {
    with_spec!(fmt, S, {
        par_chunks_mut(vals, CAST_CHUNK, |ci, chunk| {
            let base = ci * CAST_CHUNK;
            for (off, v) in chunk.iter_mut().enumerate() {
                *v = to_f64_m::<S>(from_f64_m::<S>(*v, rm.sr_element((base + off) as u64)));
            }
        });
        return;
    });
    par_chunks_mut(vals, CAST_CHUNK, |ci, chunk| {
        let base = ci * CAST_CHUNK;
        for (off, v) in chunk.iter_mut().enumerate() {
            *v = to_f64(from_f64(*v, fmt, rm.sr_element((base + off) as u64)), fmt);
        }
    });
}

// --------------------------------------------------------- accumulation

/// Fold packed source registers through the SIMD ExSdotp datapath:
/// `acc = exsdotp(rs1[i], rs2[i], acc)` over the whole slice, exactly
/// the register-level loop a GEMM inner kernel executes. `acc0` and the
/// result are packed `dst` lanes.
///
/// Dispatches to the monomorphized kernel for Table I's six expanding
/// pairs; custom formats use the descriptor-driven SIMD wrapper.
pub fn exsdotp_accumulate(
    src: FpFormat,
    dst: FpFormat,
    rs1: &[u64],
    rs2: &[u64],
    acc0: u64,
    rm: RoundingMode,
) -> u64 {
    assert_eq!(rs1.len(), rs2.len(), "operand streams must pair up");
    crate::with_expanding_pair!(
        src,
        dst,
        S,
        D,
        { exsdotp_accumulate_m::<S, D>(rs1, rs2, acc0, rm) },
        {
            let simd = SimdExSdotp::new(src, dst);
            rs1.iter()
                .zip(rs2)
                .enumerate()
                .fold(acc0, |acc, (i, (&x, &y))| simd.exsdotp(x, y, acc, rm.sr_step(i as u64)))
        }
    )
}

/// Monomorphized [`exsdotp_accumulate`]. Step `i` of the fold rounds
/// under `rm.sr_step(i)` (identity for the IEEE modes), so a stochastic
/// fold decorrelates across the K dimension.
#[inline]
pub fn exsdotp_accumulate_m<S: ExpandTo<D>, D: FormatSpec>(
    rs1: &[u64],
    rs2: &[u64],
    acc0: u64,
    rm: RoundingMode,
) -> u64 {
    debug_assert_eq!(rs1.len(), rs2.len());
    rs1.iter()
        .zip(rs2)
        .enumerate()
        .fold(acc0, |acc, (i, (&x, &y))| simd_exsdotp_m::<S, D>(x, y, acc, rm.sr_step(i as u64)))
}

// -------------------------------------------------------------- packing

/// Quantize a row-major f64 matrix into packed `u64` words, `F::LANES`
/// elements per word along rows (the layout SSR stream `ft0` delivers
/// to the kernels). `cols` must divide by the lane count.
pub fn pack_rows_m<F: FormatSpec>(data: &[f64], rows: usize, cols: usize, rm: RoundingMode) -> Vec<u64> {
    let mut out = Vec::new();
    pack_rows_into_m::<F>(data, rows, cols, rm, &mut out);
    out
}

/// [`pack_rows_m`] into a caller-provided buffer (cleared and resized;
/// capacity is reused).
pub fn pack_rows_into_m<F: FormatSpec>(
    data: &[f64],
    rows: usize,
    cols: usize,
    rm: RoundingMode,
    out: &mut Vec<u64>,
) {
    let l = F::LANES as usize;
    assert_eq!(data.len(), rows * cols);
    assert_eq!(cols % l, 0, "cols must divide by the SIMD width");
    let wpr = cols / l;
    out.clear();
    out.resize(rows * wpr, 0);
    par_chunks_mut(out, wpr.max(1), |r, row| {
        for (w, word) in row.iter_mut().enumerate() {
            let mut packed = 0u64;
            for lane_i in 0..l {
                // Per-element stochastic key from the *source* element
                // index (identity for the IEEE modes), so quantization
                // noise decorrelates across the matrix and the packing
                // stays independent of worker count.
                let idx = r * cols + w * l + lane_i;
                let v = from_f64_m::<F>(data[idx], rm.sr_element(idx as u64));
                packed |= v << (lane_i as u32 * F::WIDTH);
            }
            *word = packed;
        }
    });
}

/// Quantize a row-major f64 matrix into packed words running down each
/// *column* (`F::LANES` consecutive row elements of one column per
/// word) — the layout stream `ft1` delivers for column-major B. `rows`
/// must divide by the lane count. Output is column-major: column `j`
/// occupies words `[j*rows/LANES, (j+1)*rows/LANES)`.
pub fn pack_cols_m<F: FormatSpec>(data: &[f64], rows: usize, cols: usize, rm: RoundingMode) -> Vec<u64> {
    let mut out = Vec::new();
    pack_cols_into_m::<F>(data, rows, cols, rm, &mut out);
    out
}

/// [`pack_cols_m`] into a caller-provided buffer (cleared and resized;
/// capacity is reused).
pub fn pack_cols_into_m<F: FormatSpec>(
    data: &[f64],
    rows: usize,
    cols: usize,
    rm: RoundingMode,
    out: &mut Vec<u64>,
) {
    let l = F::LANES as usize;
    assert_eq!(data.len(), rows * cols);
    assert_eq!(rows % l, 0, "rows must divide by the SIMD width");
    let wpc = rows / l;
    out.clear();
    out.resize(cols * wpc, 0);
    par_chunks_mut(out, wpc.max(1), |j, col| {
        for (w, word) in col.iter_mut().enumerate() {
            let mut packed = 0u64;
            for lane_i in 0..l {
                // Key from the source (row-major) element index, as in
                // [`pack_rows_into_m`].
                let idx = (w * l + lane_i) * cols + j;
                let v = from_f64_m::<F>(data[idx], rm.sr_element(idx as u64));
                packed |= v << (lane_i as u32 * F::WIDTH);
            }
            *word = packed;
        }
    });
}

/// Runtime-dispatched [`pack_rows_into_m`]: monomorphized (parallel)
/// packing into `out` for the six paper formats; returns `false`
/// (leaving `out` untouched) for custom formats so the caller can fall
/// back to a descriptor-driven loop. Crate-internal — typed tensors
/// ([`crate::api::MfTensor`]) are the public route, so the validated
/// front door stays the only one.
pub(crate) fn pack_rows_into(
    fmt: FpFormat,
    data: &[f64],
    rows: usize,
    cols: usize,
    rm: RoundingMode,
    out: &mut Vec<u64>,
) -> bool {
    let _sp = crate::obs::trace::span_with("pack.rows", "batch", || {
        format!("\"rows\":{rows},\"cols\":{cols},\"fmt\":\"{}\"", fmt.name())
    });
    with_spec!(fmt, S, {
        pack_rows_into_m::<S>(data, rows, cols, rm, out);
        return true;
    });
    false
}

/// Runtime-dispatched [`pack_cols_into_m`] (see [`pack_rows_into`]).
pub(crate) fn pack_cols_into(
    fmt: FpFormat,
    data: &[f64],
    rows: usize,
    cols: usize,
    rm: RoundingMode,
    out: &mut Vec<u64>,
) -> bool {
    let _sp = crate::obs::trace::span_with("pack.cols", "batch", || {
        format!("\"rows\":{rows},\"cols\":{cols},\"fmt\":\"{}\"", fmt.name())
    });
    with_spec!(fmt, S, {
        pack_cols_into_m::<S>(data, rows, cols, rm, out);
        return true;
    });
    false
}

// ----------------------------------------------------------------- GEMM

/// Functional GEMM `C = A·B` on the batch engine — the engine behind
/// `ExecMode::Functional`: same numerics, same accumulation order, same
/// `vsum` epilogue as the generated cluster kernels (bit-identical C),
/// but iterating packed registers directly and parallelizing across
/// output rows. `a` is `m×k`, `b` is `k×n`, both row-major f64
/// (quantized to the kernel's source format on packing). Crate-internal
/// so all public traffic flows through the typed plan API
/// ([`crate::api::GemmPlan`]).
pub(crate) fn gemm_dispatch(
    kind: GemmKind,
    m: usize,
    n: usize,
    k: usize,
    a: &[f64],
    b: &[f64],
    rm: RoundingMode,
) -> Vec<f64> {
    let mut ws = Workspace::new();
    let mut out = Vec::new();
    gemm_dispatch_into(kind, m, n, k, a, b, rm, &mut ws, &mut out);
    out
}

/// [`gemm_dispatch`] into a caller-provided workspace + output.
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_dispatch_into(
    kind: GemmKind,
    m: usize,
    n: usize,
    k: usize,
    a: &[f64],
    b: &[f64],
    rm: RoundingMode,
    ws: &mut Workspace,
    out: &mut Vec<f64>,
) {
    use crate::isa::instr::{OpWidth, ScalarFmt};
    assert_eq!(a.len(), m * k, "A must be m×k");
    assert_eq!(b.len(), k * n, "B must be k×n");
    match kind {
        GemmKind::FmaF64 => gemm_fma64_into(m, n, k, a, b, rm, ws, out),
        GemmKind::FmaSimd(ScalarFmt::S) => gemm_fma_simd_into::<Fp32, Fp16, Fp32>(m, n, k, a, b, rm, ws, out),
        GemmKind::FmaSimd(ScalarFmt::H) => gemm_fma_simd_into::<Fp16, Fp8, Fp16>(m, n, k, a, b, rm, ws, out),
        GemmKind::FmaSimd(f) => panic!("unsupported SIMD FMA format {f:?}"),
        GemmKind::ExSdotp(OpWidth::HtoS) => gemm_into_m::<Fp16, Fp32>(m, n, k, a, b, rm, ws, out),
        GemmKind::ExSdotp(OpWidth::BtoH) => gemm_into_m::<Fp8, Fp16>(m, n, k, a, b, rm, ws, out),
    }
}

/// Monomorphized expanding-GEMM core (`ExSdotp` kernels): packed SIMD
/// ExSdotp inner loop + `vsum` tree epilogue, rows in parallel.
pub fn gemm_m<S: ExpandTo<D>, D: FormatSpec>(
    m: usize,
    n: usize,
    k: usize,
    a: &[f64],
    b: &[f64],
    rm: RoundingMode,
) -> Vec<f64> {
    let mut ws = Workspace::new();
    let mut out = Vec::new();
    gemm_into_m::<S, D>(m, n, k, a, b, rm, &mut ws, &mut out);
    out
}

/// [`gemm_m`] packing into `ws` and writing C into `out` (all capacity
/// reused).
#[allow(clippy::too_many_arguments)]
pub fn gemm_into_m<S: ExpandTo<D>, D: FormatSpec>(
    m: usize,
    n: usize,
    k: usize,
    a: &[f64],
    b: &[f64],
    rm: RoundingMode,
    ws: &mut Workspace,
    out: &mut Vec<f64>,
) {
    {
        let _sp = crate::obs::trace::span_with("pack.a", "batch", || {
            format!("\"rows\":{m},\"cols\":{k}")
        });
        pack_rows_into_m::<S>(a, m, k, rm, &mut ws.pa);
    }
    {
        let _sp = crate::obs::trace::span_with("pack.b", "batch", || {
            format!("\"rows\":{k},\"cols\":{n}")
        });
        pack_cols_into_m::<S>(b, k, n, rm, &mut ws.pb);
    }
    gemm_packed_into_m::<S, D>(m, n, k, &ws.pa, &ws.pb, rm, out);
}

/// [`gemm_m`] on **pre-packed** operands: `ap` holds A's rows packed
/// `S::LANES` per word ([`pack_rows_m`] layout), `bp` holds B's columns
/// packed the same way ([`pack_cols_m`] layout). This is the zero-repack
/// entry [`crate::api::GemmPlan::run`] uses when handed [`crate::api::MfTensor`]s
/// whose storage already matches the kernel's streams.
pub fn gemm_packed_m<S: ExpandTo<D>, D: FormatSpec>(
    m: usize,
    n: usize,
    k: usize,
    ap: &[u64],
    bp: &[u64],
    rm: RoundingMode,
) -> Vec<f64> {
    let mut out = Vec::new();
    gemm_packed_into_m::<S, D>(m, n, k, ap, bp, rm, &mut out);
    out
}

/// [`gemm_packed_m`] into a caller-provided output (cleared and
/// resized; capacity is reused). Compiles a [`BlockPlan`] for the shape
/// and runs the active [`LaneTier`]; see [`gemm_packed_planned_into_m`].
pub fn gemm_packed_into_m<S: ExpandTo<D>, D: FormatSpec>(
    m: usize,
    n: usize,
    k: usize,
    ap: &[u64],
    bp: &[u64],
    rm: RoundingMode,
    out: &mut Vec<f64>,
) {
    let plan = BlockPlan::for_problem(m, n, k / S::LANES as usize);
    gemm_packed_planned_into_m::<S, D>(&plan, m, n, k, ap, bp, rm, out);
}

/// The expanding-GEMM core on pre-packed operands, with the blocking
/// decision supplied by the caller (steady-state callers —
/// [`crate::api::PlanInstance`] — compile the plan once at assembly
/// time). Resolves the [`LaneTier`] **on the calling thread** (worker
/// threads do not inherit thread-local overrides), screens the packed
/// panels once for the SWAR tier, and dispatches to the simple or
/// blocked loop. Every `(tier, plan)` combination is bit-identical.
#[allow(clippy::too_many_arguments)]
pub fn gemm_packed_planned_into_m<S: ExpandTo<D>, D: FormatSpec>(
    plan: &BlockPlan,
    m: usize,
    n: usize,
    k: usize,
    ap: &[u64],
    bp: &[u64],
    rm: RoundingMode,
    out: &mut Vec<f64>,
) {
    let l = S::LANES as usize;
    assert_eq!(k % l, 0, "K must divide by the SIMD width");
    let wpr = k / l;
    assert_eq!(ap.len(), m * wpr, "packed A must be m*k/lanes words");
    assert_eq!(bp.len(), n * wpr, "packed B must be n*k/lanes words");
    out.clear();
    out.resize(m * n, 0f64);
    let tier = lane_tier();
    // The scalar reference tier always runs the simple loop (below),
    // so the route counters reflect the loop actually executed.
    let runs_blocked = plan.blocked && tier == LaneTier::Swar;
    crate::obs_count!(match tier {
        LaneTier::Swar => "batch.tier.swar",
        LaneTier::Scalar => "batch.tier.scalar",
    });
    crate::obs_count!(if runs_blocked { "batch.gemm.blocked" } else { "batch.gemm.simple" });
    let _sp = crate::obs::trace::span_with("gemm.tier", "batch", || {
        format!(
            "\"m\":{m},\"n\":{n},\"k\":{k},\"tier\":\"{}\",\"blocked\":{runs_blocked}",
            match tier {
                LaneTier::Swar => "swar",
                LaneTier::Scalar => "scalar",
            }
        )
    });
    // Stochastic-key plumbing: the kernel closure receives the global
    // output-element index and packed-word index, the epilogue closure
    // the element index; both derive per-site keys (`sr_element` /
    // `sr_step` / `sr_tree`) that are the identity for the IEEE modes.
    // Keys depend only on *global* indices, never on worker identity,
    // so SR results stay bit-identical across thread counts, blocking
    // decisions, and lane tiers.
    match tier {
        LaneTier::Scalar => {
            // The reference tier stays on the untouched simple loop —
            // it is the timing baseline the speedup gates compare
            // against, and the numeric reference the differential
            // tests pin the SWAR tier to.
            gemm_loops::<D, _, _>(
                plan,
                n,
                wpr,
                ap,
                bp,
                out,
                |x, y, acc, e, kw| simd_exsdotp_m::<S, D>(x, y, acc, rm.sr_element(e).sr_step(kw)),
                |acc, e| vsum_tree_m::<S, D>(acc, rm.sr_element(e).sr_tree(0)),
                false,
            );
        }
        LaneTier::Swar => {
            // Pack-once panel screen: one pass over the packed words
            // decides whether the whole GEMM can run the all-finite
            // SWAR kernel (screening only the running accumulator per
            // step) or must keep the full per-register screen.
            let clean = slice_all_finite::<S>(ap) && slice_all_finite::<S>(bp);
            if clean {
                gemm_loops::<D, _, _>(
                    plan,
                    n,
                    wpr,
                    ap,
                    bp,
                    out,
                    |x, y, acc, e, kw| {
                        swar_exsdotp_operands_finite_m::<S, D>(x, y, acc, rm.sr_element(e).sr_step(kw))
                    },
                    |acc, e| vsum_tree_swar_m::<S, D>(acc, rm.sr_element(e).sr_tree(0)),
                    plan.blocked,
                );
            } else {
                gemm_loops::<D, _, _>(
                    plan,
                    n,
                    wpr,
                    ap,
                    bp,
                    out,
                    |x, y, acc, e, kw| swar_exsdotp_m::<S, D>(x, y, acc, rm.sr_element(e).sr_step(kw)),
                    |acc, e| vsum_tree_swar_m::<S, D>(acc, rm.sr_element(e).sr_tree(0)),
                    plan.blocked,
                );
            }
        }
    }
}

/// Shared loop structure for both tiers: `kernel` folds one packed
/// register pair into the accumulator, `vsum` is the epilogue reduction
/// tree. Both closures additionally receive the **global** output
/// element index (`i·n + j`), and `kernel` the global packed-word index
/// along K — the stochastic-rounding key sites (ignored under IEEE
/// modes). With `blocked`, the output is tiled `plan.mc × plan.nc` with
/// K streamed in `plan.kc_words` panels — the accumulator tile persists
/// across K-panels on the worker's stack, so each output element still
/// folds its words in ascending-k order *with the same global indices*
/// (bit-identical to the simple loop by construction, IEEE or SR).
#[allow(clippy::too_many_arguments)]
fn gemm_loops<D: FormatSpec, K, V>(
    plan: &BlockPlan,
    n: usize,
    wpr: usize,
    ap: &[u64],
    bp: &[u64],
    out: &mut [f64],
    kernel: K,
    vsum: V,
    blocked: bool,
) where
    K: Fn(u64, u64, u64, u64, u64) -> u64 + Sync,
    V: Fn(u64, u64) -> u64 + Sync,
{
    if !blocked {
        par_chunks_mut(out, n.max(1), |i, row| {
            let aw = &ap[i * wpr..(i + 1) * wpr];
            for (j, o) in row.iter_mut().enumerate() {
                let bw = &bp[j * wpr..(j + 1) * wpr];
                let elem = (i * n + j) as u64;
                let mut acc = 0u64; // all destination lanes +0.0
                for (kw, (&x, &y)) in aw.iter().zip(bw).enumerate() {
                    acc = kernel(x, y, acc, elem, kw as u64);
                }
                *o = to_f64_m::<D>(vsum(acc, elem));
            }
        });
        return;
    }
    let (mc, nc, kc) = (plan.mc, plan.nc, plan.kc_words);
    debug_assert!(mc * nc <= ACC_TILE_WORDS);
    par_chunks_mut(out, (mc * n).max(1), |bi, rows| {
        let i0 = bi * mc;
        let block_rows = rows.len() / n; // last block may be short
        let mut tile = [0u64; ACC_TILE_WORDS];
        for jb in (0..n).step_by(nc) {
            let ncb = nc.min(n - jb);
            let _tile_sp = crate::obs::trace::span_with("gemm.tile", "batch", || {
                format!("\"i0\":{i0},\"jb\":{jb},\"rows\":{block_rows},\"cols\":{ncb}")
            });
            tile[..block_rows * nc].fill(0); // all destination lanes +0.0
            for kb in (0..wpr).step_by(kc) {
                let kcb = kc.min(wpr - kb);
                for ii in 0..block_rows {
                    let aw = &ap[(i0 + ii) * wpr + kb..][..kcb];
                    for jj in 0..ncb {
                        let bw = &bp[(jb + jj) * wpr + kb..][..kcb];
                        let elem = ((i0 + ii) * n + jb + jj) as u64;
                        let mut acc = tile[ii * nc + jj];
                        for (off, (&x, &y)) in aw.iter().zip(bw).enumerate() {
                            acc = kernel(x, y, acc, elem, (kb + off) as u64);
                        }
                        tile[ii * nc + jj] = acc;
                    }
                }
            }
            for ii in 0..block_rows {
                for jj in 0..ncb {
                    let elem = ((i0 + ii) * n + jb + jj) as u64;
                    rows[ii * n + jb + jj] = to_f64_m::<D>(vsum(tile[ii * nc + jj], elem));
                }
            }
        }
    });
}

// ------------------------------------------------- chunked accumulation
//
// Long-K accumulation in a narrow wide-format swamps: once the running
// sum grows, each new product loses its low bits to rounding, and with
// biased modes the error compounds monotonically (Wang et al. 2018,
// §"chunk-based accumulation"). Chunking re-associates the fold: K is
// split into fixed-size sub-ranges, each accumulated from a fresh zero
// in the wide format exactly like a miniature naive GEMM (same packed
// ExSdotp fold, same `vsum` epilogue tree), and the per-chunk partials
// are then combined left-to-right with the scalar three-term `vsum`.
// Each addend into the long chain is now a chunk sum instead of a
// single product, cutting the number of large-magnitude-absorbs-small
// rounding steps per element from K to K/chunk + chunk.
//
// `chunk = K` degenerates to the naive path bit-for-bit (one chunk,
// combined with nothing) — pinned by differential tests, which makes
// the naive ascending-k fold the differential reference for the
// chunked path's plumbing.

/// Chunked-accumulation expanding GEMM on pre-packed operands:
/// `chunk_words` packed words of K per sub-accumulation (`chunk_words ·
/// S::LANES` source elements). Resolves the [`LaneTier`] on the calling
/// thread like [`gemm_packed_planned_into_m`]; both tiers fold the
/// per-chunk partials with the *scalar* [`vsum_m`], so tier
/// bit-identity holds by construction. Runs the simple row-parallel
/// loop (chunking is itself a K-blocking; cache tiling is not layered
/// on top).
#[allow(clippy::too_many_arguments)]
pub fn gemm_packed_chunked_into_m<S: ExpandTo<D>, D: FormatSpec>(
    chunk_words: usize,
    m: usize,
    n: usize,
    k: usize,
    ap: &[u64],
    bp: &[u64],
    rm: RoundingMode,
    out: &mut Vec<f64>,
) {
    let l = S::LANES as usize;
    assert_eq!(k % l, 0, "K must divide by the SIMD width");
    assert!(chunk_words > 0, "chunk must cover at least one packed word");
    let wpr = k / l;
    assert_eq!(ap.len(), m * wpr, "packed A must be m*k/lanes words");
    assert_eq!(bp.len(), n * wpr, "packed B must be n*k/lanes words");
    out.clear();
    out.resize(m * n, 0f64);
    let tier = lane_tier();
    crate::obs_count!("batch.gemm.chunked");
    let _sp = crate::obs::trace::span_with("gemm.chunked", "batch", || {
        format!("\"m\":{m},\"n\":{n},\"k\":{k},\"chunk_words\":{chunk_words}")
    });
    let clean = tier == LaneTier::Swar && slice_all_finite::<S>(ap) && slice_all_finite::<S>(bp);
    par_chunks_mut(out, n.max(1), |i, row| {
        let aw = &ap[i * wpr..(i + 1) * wpr];
        for (j, o) in row.iter_mut().enumerate() {
            let bw = &bp[j * wpr..(j + 1) * wpr];
            let elem = (i * n + j) as u64;
            let erm = rm.sr_element(elem);
            let mut result = 0u64;
            let mut chunk = 0u64;
            let mut kb = 0usize;
            while kb < wpr {
                let kcb = chunk_words.min(wpr - kb);
                let mut acc = 0u64; // all destination lanes +0.0
                for off in 0..kcb {
                    let (x, y) = (aw[kb + off], bw[kb + off]);
                    // Same global (element, word) keys as the naive
                    // loop, so chunk = K reproduces it bit-for-bit.
                    let krm = erm.sr_step((kb + off) as u64);
                    acc = match tier {
                        LaneTier::Scalar => simd_exsdotp_m::<S, D>(x, y, acc, krm),
                        LaneTier::Swar if clean => swar_exsdotp_operands_finite_m::<S, D>(x, y, acc, krm),
                        LaneTier::Swar => swar_exsdotp_m::<S, D>(x, y, acc, krm),
                    };
                }
                let trm = erm.sr_tree(chunk);
                let s = match tier {
                    LaneTier::Scalar => vsum_tree_m::<S, D>(acc, trm),
                    LaneTier::Swar => vsum_tree_swar_m::<S, D>(acc, trm),
                };
                // First chunk passes through untouched (a `0 + s` vsum
                // would lose −0.0); later chunks fold left-to-right on
                // the scalar combine shared by both tiers.
                result = if chunk == 0 { s } else { vsum_m::<S, D>(result, s, 0, erm.sr_fold(chunk - 1)) };
                chunk += 1;
                kb += kcb;
            }
            *o = to_f64_m::<D>(result);
        }
    });
}

/// Runtime-dispatched [`gemm_packed_chunked_into_m`]: `true` when
/// `(src, dst)` is one of Table I's six expanding pairs, `false`
/// otherwise (caller falls back). Crate-internal — the validated
/// [`crate::api::GemmPlan`] (`chunk_k`) is the public route.
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_packed_chunked_into(
    src: FpFormat,
    dst: FpFormat,
    chunk_words: usize,
    m: usize,
    n: usize,
    k: usize,
    ap: &[u64],
    bp: &[u64],
    rm: RoundingMode,
    out: &mut Vec<f64>,
) -> bool {
    crate::with_expanding_pair!(
        src,
        dst,
        S,
        D,
        {
            gemm_packed_chunked_into_m::<S, D>(chunk_words, m, n, k, ap, bp, rm, out);
            true
        },
        { false }
    )
}

/// Chunked twin of [`gemm_expanding_into`]: packs f64 operands for the
/// requested shape (`A·B`, `Aᵀ·B`, `A·Bᵀ`) with the same packers as the
/// naive route, then runs the chunked core. `true` when the pair/shape
/// combination ran.
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_expanding_chunked_into(
    src: FpFormat,
    dst: FpFormat,
    trans_a: bool,
    trans_b: bool,
    chunk_words: usize,
    m: usize,
    n: usize,
    k: usize,
    a: &[f64],
    b: &[f64],
    rm: RoundingMode,
    ws: &mut Workspace,
    out: &mut Vec<f64>,
) -> bool {
    crate::with_expanding_pair!(src, dst, S, D, {
        match (trans_a, trans_b) {
            (false, false) => {
                pack_rows_into_m::<S>(a, m, k, rm, &mut ws.pa);
                pack_cols_into_m::<S>(b, k, n, rm, &mut ws.pb);
            }
            (true, false) => {
                pack_cols_into_m::<S>(a, k, m, rm, &mut ws.pa);
                pack_cols_into_m::<S>(b, k, n, rm, &mut ws.pb);
            }
            (false, true) => {
                pack_rows_into_m::<S>(a, m, k, rm, &mut ws.pa);
                pack_rows_into_m::<S>(b, n, k, rm, &mut ws.pb);
            }
            (true, true) => return false,
        }
        gemm_packed_chunked_into_m::<S, D>(chunk_words, m, n, k, &ws.pa, &ws.pb, rm, out);
        true
    }, {
        false
    })
}

/// Runtime-dispatched [`gemm_packed_into_m`] for the expanding
/// (`ExSdotp`) kernel families: `true` (C written into `out`) when
/// `(src, dst)` is one of Table I's six monomorphized pairs, `false`
/// otherwise (caller falls back to the f64 path). Operands are
/// pre-packed words in the [`pack_rows_m`] / [`pack_cols_m`] layouts.
/// Crate-internal: the validated [`crate::api::GemmPlan`] is the public
/// route (its builder guarantees the shape/divisibility invariants
/// these asserts assume). Production traffic moved to the precompiled
/// [`gemm_packed_planned_into`]; this unplanned twin remains as the
/// differential tests' reference entry.
#[cfg_attr(not(test), allow(dead_code))]
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_packed_into(
    src: FpFormat,
    dst: FpFormat,
    m: usize,
    n: usize,
    k: usize,
    ap: &[u64],
    bp: &[u64],
    rm: RoundingMode,
    out: &mut Vec<f64>,
) -> bool {
    crate::with_expanding_pair!(
        src,
        dst,
        S,
        D,
        {
            gemm_packed_into_m::<S, D>(m, n, k, ap, bp, rm, out);
            true
        },
        { false }
    )
}

/// [`gemm_packed_into`] with the blocking decision precompiled by the
/// caller — the zero-per-call-planning route [`crate::api::PlanInstance`]
/// runs: the instance compiles a [`BlockPlan`] once at assembly time
/// and replays it every call.
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_packed_planned_into(
    src: FpFormat,
    dst: FpFormat,
    plan: &BlockPlan,
    m: usize,
    n: usize,
    k: usize,
    ap: &[u64],
    bp: &[u64],
    rm: RoundingMode,
    out: &mut Vec<f64>,
) -> bool {
    crate::with_expanding_pair!(
        src,
        dst,
        S,
        D,
        {
            gemm_packed_planned_into_m::<S, D>(plan, m, n, k, ap, bp, rm, out);
            true
        },
        { false }
    )
}

// ------------------------------------------------ backward-pass shapes
//
// Training needs two more GEMM shapes (Wang et al. 2018, "Training DNNs
// with 8-bit Floating Point Numbers"): the weight gradient `Aᵀ·G` and
// the input gradient `G·Bᵀ`. A transpose only changes *which packer*
// produces an operand's register stream — rows of `Aᵀ` are columns of
// `A` — so both shapes run the identical [`gemm_packed_m`] inner kernel
// (same ExSdotp accumulation order, same `vsum` epilogue, bit-identical
// to what the cluster would compute on pre-transposed data) with no
// extra data motion.

/// `C = Aᵀ·B` on the batch engine. `a` is `k×m` row-major f64 (the
/// *untransposed* operand, e.g. forward activations `X`), `b` is `k×n`
/// row-major f64; returns row-major `m×n` C. `k` must divide by the
/// SIMD width — both streams pack *down* the shared inner dimension.
pub fn gemm_tn_m<S: ExpandTo<D>, D: FormatSpec>(
    m: usize,
    n: usize,
    k: usize,
    a: &[f64],
    b: &[f64],
    rm: RoundingMode,
) -> Vec<f64> {
    let mut ws = Workspace::new();
    let mut out = Vec::new();
    gemm_tn_into_m::<S, D>(m, n, k, a, b, rm, &mut ws, &mut out);
    out
}

/// [`gemm_tn_m`] through a caller-provided workspace + output.
#[allow(clippy::too_many_arguments)]
pub fn gemm_tn_into_m<S: ExpandTo<D>, D: FormatSpec>(
    m: usize,
    n: usize,
    k: usize,
    a: &[f64],
    b: &[f64],
    rm: RoundingMode,
    ws: &mut Workspace,
    out: &mut Vec<f64>,
) {
    pack_cols_into_m::<S>(a, k, m, rm, &mut ws.pa); // columns of A = rows of Aᵀ
    pack_cols_into_m::<S>(b, k, n, rm, &mut ws.pb);
    gemm_packed_into_m::<S, D>(m, n, k, &ws.pa, &ws.pb, rm, out);
}

/// `C = A·Bᵀ` on the batch engine. `a` is `m×k` row-major f64, `b` is
/// `n×k` row-major f64 (the *untransposed* operand, e.g. a weight
/// matrix streamed against output gradients); returns row-major `m×n` C.
pub fn gemm_nt_m<S: ExpandTo<D>, D: FormatSpec>(
    m: usize,
    n: usize,
    k: usize,
    a: &[f64],
    b: &[f64],
    rm: RoundingMode,
) -> Vec<f64> {
    let mut ws = Workspace::new();
    let mut out = Vec::new();
    gemm_nt_into_m::<S, D>(m, n, k, a, b, rm, &mut ws, &mut out);
    out
}

/// [`gemm_nt_m`] through a caller-provided workspace + output.
#[allow(clippy::too_many_arguments)]
pub fn gemm_nt_into_m<S: ExpandTo<D>, D: FormatSpec>(
    m: usize,
    n: usize,
    k: usize,
    a: &[f64],
    b: &[f64],
    rm: RoundingMode,
    ws: &mut Workspace,
    out: &mut Vec<f64>,
) {
    pack_rows_into_m::<S>(a, m, k, rm, &mut ws.pa);
    pack_rows_into_m::<S>(b, n, k, rm, &mut ws.pb); // rows of B = columns of Bᵀ
    gemm_packed_into_m::<S, D>(m, n, k, &ws.pa, &ws.pb, rm, out);
}

/// Runtime-dispatched expanding GEMM over all three shapes (`A·B`,
/// `Aᵀ·B`, `A·Bᵀ`): `Some(C)` for Table I's six monomorphized pairs,
/// `None` otherwise (including the unsupported `Aᵀ·Bᵀ`). Operand
/// shapes follow [`gemm_m`] / [`gemm_tn_m`] / [`gemm_nt_m`].
/// Crate-internal: [`crate::api::GemmPlan`]'s `transpose_a`/`transpose_b`
/// builders are the public route; production code runs the `_into`
/// twin below, and this allocating form remains as the differential
/// tests' reference entry.
#[cfg_attr(not(test), allow(dead_code))]
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_expanding(
    src: FpFormat,
    dst: FpFormat,
    trans_a: bool,
    trans_b: bool,
    m: usize,
    n: usize,
    k: usize,
    a: &[f64],
    b: &[f64],
    rm: RoundingMode,
) -> Option<Vec<f64>> {
    let mut ws = Workspace::new();
    let mut out = Vec::new();
    gemm_expanding_into(src, dst, trans_a, trans_b, m, n, k, a, b, rm, &mut ws, &mut out).then_some(out)
}

/// [`gemm_expanding`] through a caller-provided workspace + output:
/// `true` when the pair/shape combination ran (C is in `out`).
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_expanding_into(
    src: FpFormat,
    dst: FpFormat,
    trans_a: bool,
    trans_b: bool,
    m: usize,
    n: usize,
    k: usize,
    a: &[f64],
    b: &[f64],
    rm: RoundingMode,
    ws: &mut Workspace,
    out: &mut Vec<f64>,
) -> bool {
    crate::with_expanding_pair!(src, dst, S, D, {
        match (trans_a, trans_b) {
            (false, false) => {
                gemm_into_m::<S, D>(m, n, k, a, b, rm, ws, out);
                true
            }
            (true, false) => {
                gemm_tn_into_m::<S, D>(m, n, k, a, b, rm, ws, out);
                true
            }
            (false, true) => {
                gemm_nt_into_m::<S, D>(m, n, k, a, b, rm, ws, out);
                true
            }
            (true, true) => false,
        }
    }, {
        false
    })
}

/// Packed-SIMD FMA GEMM (`FmaSimd` kernels): lanewise FMA partial sums
/// in `F`, reduced with the `(RS → RD)` `vsum` tree the corresponding
/// generated kernel uses in its epilogue. Operands pack into `ws`, C
/// lands in `out`.
#[allow(clippy::too_many_arguments)]
fn gemm_fma_simd_into<F: FormatSpec, RS: ExpandTo<RD>, RD: FormatSpec>(
    m: usize,
    n: usize,
    k: usize,
    a: &[f64],
    b: &[f64],
    rm: RoundingMode,
    ws: &mut Workspace,
    out: &mut Vec<f64>,
) {
    let l = F::LANES as usize;
    assert_eq!(k % l, 0, "K must divide by the SIMD width");
    let wpr = k / l;
    pack_rows_into_m::<F>(a, m, k, rm, &mut ws.pa);
    pack_cols_into_m::<F>(b, k, n, rm, &mut ws.pb);
    let (ap, bp) = (&ws.pa, &ws.pb);
    out.clear();
    out.resize(m * n, 0f64);
    par_chunks_mut(out, n.max(1), |i, row| {
        let aw = &ap[i * wpr..(i + 1) * wpr];
        for (j, o) in row.iter_mut().enumerate() {
            let bw = &bp[j * wpr..(j + 1) * wpr];
            let erm = rm.sr_element((i * n + j) as u64);
            let mut acc = 0u64;
            for (kw, (&x, &y)) in aw.iter().zip(bw).enumerate() {
                acc = simd_fma_m::<F>(x, y, acc, erm.sr_step(kw as u64));
            }
            *o = to_f64_m::<RD>(vsum_tree_m::<RS, RD>(acc, erm.sr_tree(0)));
        }
    });
}

/// Scalar FP64 FMA GEMM (the classic Snitch kernel's numerics). The
/// transposed-B bit image and C both live in the workspace/output —
/// the last per-call allocations on this path are gone.
#[allow(clippy::too_many_arguments)]
fn gemm_fma64_into(
    m: usize,
    n: usize,
    k: usize,
    a: &[f64],
    b: &[f64],
    rm: RoundingMode,
    ws: &mut Workspace,
    out: &mut Vec<f64>,
) {
    // Pack B transposed (as raw f64 bits) so the inner loop walks
    // contiguous memory; `ws.pa` holds the bit image.
    let bt = &mut ws.pa;
    bt.clear();
    bt.resize(n * k, 0);
    par_chunks_mut(bt, k.max(1), |j, col| {
        for (kk, w) in col.iter_mut().enumerate() {
            *w = b[kk * n + j].to_bits();
        }
    });
    let bt = &ws.pa;
    out.clear();
    out.resize(m * n, 0f64);
    par_chunks_mut(out, n.max(1), |i, row| {
        for (j, o) in row.iter_mut().enumerate() {
            let erm = rm.sr_element((i * n + j) as u64);
            let mut acc = 0u64; // +0.0
            for kk in 0..k {
                acc = fma_m::<Fp64>(a[i * k + kk].to_bits(), bt[j * k + kk], acc, erm.sr_step(kk as u64));
            }
            *o = f64::from_bits(acc);
        }
    });
}

/// Lanewise FMA over packed words (monomorphized twin of the PE's
/// vectorial FMA; constant trip count after monomorphization).
#[inline]
pub fn simd_fma_m<F: FormatSpec>(rs1: u64, rs2: u64, rd: u64, rm: RoundingMode) -> u64 {
    // `u64::MAX >> (64 - WIDTH)` is shift-safe for every width up to 64
    // (a single 64-bit lane degenerates to one scalar FMA).
    let mask = u64::MAX >> (64 - F::WIDTH);
    let mut out = 0u64;
    for i in 0..F::LANES {
        let sh = i * F::WIDTH;
        let v = fma_m::<F>((rs1 >> sh) & mask, (rs2 >> sh) & mask, (rd >> sh) & mask, rm.sr_lane(i));
        out |= (v & mask) << sh;
    }
    out
}
