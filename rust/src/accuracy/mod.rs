//! The Table IV accuracy experiment: accumulate `n` Gaussian dot
//! products with (i) the fused ExSdotp unit, (ii) the ExFMA cascade,
//! and (iii) FP64 ExFMAs as the golden model; report relative errors.
//!
//! §IV-D: "We generate the inputs randomly, with a Gaussian
//! distribution, in the source precision. ... The golden FP64 result is
//! converted to FP32/FP16 for the error calculation."

use crate::exsdotp::cascade::exsdotp_cascade;
use crate::exsdotp::fast::exsdotp_m;
use crate::exsdotp::unit::ExSdotpUnit;
use crate::formats::spec::{ExpandTo, FormatSpec};
use crate::formats::FpFormat;
use crate::softfloat::fast::{ex_fma_m, from_f64_m, to_f64_m};
use crate::softfloat::{from_f64, to_f64, RoundingMode};
use crate::util::rng::Rng;

/// One Table IV cell pair: relative error of the fused unit and of the
/// cascade against the FP64 golden accumulation.
#[derive(Clone, Copy, Debug)]
pub struct AccuracyPoint {
    /// Dot products accumulated.
    pub n: usize,
    /// |fused − golden| / |golden|, after converting golden to dst.
    pub err_exsdotp: f64,
    /// |cascade − golden| / |golden|.
    pub err_exfma: f64,
}

/// Run the accumulation experiment for one (src→dst) pair and input
/// count (Table IV rows use n ∈ {500, 1000, 2000}).
pub fn accumulate(src: FpFormat, dst: FpFormat, n: usize, seed: u64) -> AccuracyPoint {
    accumulate_with(src, dst, n, seed, RoundingMode::Rne)
}

/// [`accumulate`] under an explicit rounding mode — RNE reproduces the
/// Table IV setup bit for bit; a seeded [`RoundingMode::StochasticRound`]
/// runs the same draw sequence with per-element quantization keys and
/// per-step accumulation keys (`sr_element` / `sr_step`, identity under
/// RNE). The FP64 golden and its final conversion always round RNE —
/// the reference must not inherit the noise under test.
pub fn accumulate_with(src: FpFormat, dst: FpFormat, n: usize, seed: u64, rm: RoundingMode) -> AccuracyPoint {
    let unit = ExSdotpUnit::new(src, dst);
    let mut rng = Rng::new(seed);

    let mut acc_fused = dst.zero(false);
    let mut acc_casc = dst.zero(false);
    let mut acc_f64 = 0f64; // FP64 ExFMA accumulation == native f64 FMA chain

    // n dot products = n/2 ExSdotp operations (each handles two).
    for step in 0..(n / 2) as u64 {
        let a = from_f64(rng.gaussian(), src, rm.sr_element(4 * step));
        let b = from_f64(rng.gaussian(), src, rm.sr_element(4 * step + 1));
        let c = from_f64(rng.gaussian(), src, rm.sr_element(4 * step + 2));
        let d = from_f64(rng.gaussian(), src, rm.sr_element(4 * step + 3));
        acc_fused = unit.exsdotp(a, b, c, d, acc_fused, rm.sr_step(step));
        acc_casc = exsdotp_cascade(src, dst, a, b, c, d, acc_casc, rm.sr_step(step));
        let (af, bf, cf, df) = (to_f64(a, src), to_f64(b, src), to_f64(c, src), to_f64(d, src));
        acc_f64 = af.mul_add(bf, acc_f64);
        acc_f64 = cf.mul_add(df, acc_f64);
    }

    // "The golden FP64 result is converted to FP32/FP16 for the error
    // calculation."
    let golden = to_f64(from_f64(acc_f64, dst, RoundingMode::Rne), dst);
    let rel = |x: u64| {
        if golden == 0.0 {
            (to_f64(x, dst) - golden).abs()
        } else {
            ((to_f64(x, dst) - golden) / golden).abs()
        }
    };
    AccuracyPoint { n, err_exsdotp: rel(acc_fused), err_exfma: rel(acc_casc) }
}

/// [`accumulate`] on the monomorphized Tier-A kernels: bit-identical
/// results (same datapaths, compile-time formats — asserted by the
/// differential tests), several times faster, which is what makes wide
/// Table IV-style sweeps (`table4_averaged` with hundreds of draws, or
/// the `n ≫ 2000` regimes of the FP8-training literature) tractable.
/// Falls back to the descriptor path for non-Table I pairs.
pub fn accumulate_fast(src: FpFormat, dst: FpFormat, n: usize, seed: u64) -> AccuracyPoint {
    accumulate_fast_with(src, dst, n, seed, RoundingMode::Rne)
}

/// [`accumulate_fast`] under an explicit rounding mode (the fast twin
/// of [`accumulate_with`], deriving the identical `sr_element` /
/// `sr_step` key schedule so the two paths stay bit-identical for any
/// mode). Falls back to the descriptor path for non-Table I pairs.
pub fn accumulate_fast_with(src: FpFormat, dst: FpFormat, n: usize, seed: u64, rm: RoundingMode) -> AccuracyPoint {
    crate::with_expanding_pair!(src, dst, S, D, { accumulate_m::<S, D>(n, seed, rm) }, {
        accumulate_with(src, dst, n, seed, rm)
    })
}

/// Monomorphized accumulation experiment — the same draw sequence and
/// datapaths as [`accumulate_with`], dispatched at compile time.
fn accumulate_m<S: ExpandTo<D>, D: FormatSpec>(n: usize, seed: u64, rm: RoundingMode) -> AccuracyPoint {
    let mut rng = Rng::new(seed);

    let mut acc_fused = D::FMT.zero(false);
    let mut acc_casc = D::FMT.zero(false);
    let mut acc_f64 = 0f64;

    for step in 0..(n / 2) as u64 {
        let a = from_f64_m::<S>(rng.gaussian(), rm.sr_element(4 * step));
        let b = from_f64_m::<S>(rng.gaussian(), rm.sr_element(4 * step + 1));
        let c = from_f64_m::<S>(rng.gaussian(), rm.sr_element(4 * step + 2));
        let d = from_f64_m::<S>(rng.gaussian(), rm.sr_element(4 * step + 3));
        let srm = rm.sr_step(step);
        acc_fused = exsdotp_m::<S, D>(a, b, c, d, acc_fused, srm);
        // The two-ExFMA cascade, monomorphized: c·d + e first, then a·b.
        let inner = ex_fma_m::<S, D>(c, d, acc_casc, srm);
        acc_casc = ex_fma_m::<S, D>(a, b, inner, srm);
        let (af, bf, cf, df) =
            (to_f64_m::<S>(a), to_f64_m::<S>(b), to_f64_m::<S>(c), to_f64_m::<S>(d));
        acc_f64 = af.mul_add(bf, acc_f64);
        acc_f64 = cf.mul_add(df, acc_f64);
    }

    let golden = to_f64_m::<D>(from_f64_m::<D>(acc_f64, RoundingMode::Rne));
    let rel = |x: u64| {
        if golden == 0.0 {
            (to_f64_m::<D>(x) - golden).abs()
        } else {
            ((to_f64_m::<D>(x) - golden) / golden).abs()
        }
    };
    AccuracyPoint { n, err_exsdotp: rel(acc_fused), err_exfma: rel(acc_casc) }
}

/// Seed for draw `i` of an averaged sweep — the single source of truth
/// for sweep seed derivation, shared by [`table4_averaged`] and the
/// typed accumulation plans ([`crate::api::AccumulatePlan::sweep`]).
/// Both the descriptor path ([`accumulate`]) and the fast path
/// ([`accumulate_fast`]) consume these seeds identically, so
/// fused-vs-cascade errors agree bit for bit across paths for any draw
/// (pinned by `sweep_seeds_identical_across_paths`).
pub fn sweep_seed(draw: u64) -> u64 {
    1000 + draw
}

/// The Table IV format pairs (source → expanding destination) — the
/// single grid definition shared by [`table4`], [`table4_averaged`]
/// and the report/plan renderers.
pub const TABLE4_PAIRS: [(FpFormat, FpFormat); 2] =
    [(crate::formats::FP16, crate::formats::FP32), (crate::formats::FP8, crate::formats::FP16)];

/// The Table IV accumulation lengths.
pub const TABLE4_NS: [usize; 3] = [500, 1000, 2000];

/// The full Table IV grid: FP16→FP32 and FP8→FP16, n ∈ {500,1000,2000}.
pub fn table4(seed: u64) -> Vec<(FpFormat, FpFormat, AccuracyPoint)> {
    let mut out = Vec::new();
    for (src, dst) in TABLE4_PAIRS {
        for n in TABLE4_NS {
            out.push((src, dst, accumulate(src, dst, n, seed)));
        }
    }
    out
}

/// Averaged over many seeds (the paper reports a single draw; averaging
/// shows the trend is not seed luck). Runs on [`accumulate_fast`] —
/// bit-identical to the descriptor path, so the averages are exactly
/// those the slow path would produce.
pub fn table4_averaged(seeds: u64) -> Vec<(FpFormat, FpFormat, usize, f64, f64)> {
    let mut out = Vec::new();
    for (src, dst) in TABLE4_PAIRS {
        for n in TABLE4_NS {
            let mut s_fused = 0.0;
            let mut s_casc = 0.0;
            for draw in 0..seeds {
                let p = accumulate_fast(src, dst, n, sweep_seed(draw));
                s_fused += p.err_exsdotp;
                s_casc += p.err_exfma;
            }
            out.push((src, dst, n, s_fused / seeds as f64, s_casc / seeds as f64));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{FP16, FP32, FP8};

    #[test]
    fn error_magnitudes_match_table4_bands() {
        // FP16→FP32 errors are ~1e-7-ish; FP8→FP16 ~1e-3..1e-2 — the
        // format-resolution bands Table IV reports.
        let p16 = accumulate(FP16, FP32, 1000, 42);
        assert!(p16.err_exsdotp < 5e-6, "fp16→32 fused err {}", p16.err_exsdotp);
        assert!(p16.err_exfma < 5e-5, "fp16→32 cascade err {}", p16.err_exfma);
        let p8 = accumulate(FP8, FP16, 1000, 42);
        assert!(p8.err_exsdotp < 5e-2, "fp8→16 fused err {}", p8.err_exsdotp);
        assert!(p8.err_exfma < 2e-1, "fp8→16 cascade err {}", p8.err_exfma);
        // And FP8 errors dwarf FP16 errors.
        assert!(p8.err_exsdotp > p16.err_exsdotp);
    }

    #[test]
    fn fused_wins_in_median() {
        // Table IV's qualitative claim: "the ExSdotp unit consistently
        // shows better accuracy than the ExFMA". Per-draw outcomes are
        // noisy (a near-cancelling golden sum inflates relative errors
        // arbitrarily), so we compare the *median* across draws, which
        // is robust to those outliers.
        for (src, dst) in [(FP16, FP32), (FP8, FP16)] {
            for n in [500usize, 1000, 2000] {
                let mut fused: Vec<f64> = Vec::new();
                let mut casc: Vec<f64> = Vec::new();
                for seed in 0..101 {
                    let p = accumulate(src, dst, n, 7000 + seed);
                    fused.push(p.err_exsdotp);
                    casc.push(p.err_exfma);
                }
                fused.sort_by(|a, b| a.partial_cmp(b).unwrap());
                casc.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let (mf, mc) = (fused[50], casc[50]);
                if src == FP8 && n == 2000 {
                    // Reproduction finding (EXPERIMENTS.md §Table IV): in
                    // this regime FP8 products are *exactly* representable
                    // in FP16, so the cascade's stepwise additions are
                    // often exact and the two datapaths are statistically
                    // comparable; the paper's 3× single-draw gap is draw
                    // variance. We assert comparability, not dominance.
                    assert!(
                        mf <= 2.0 * mc,
                        "{}→{} n={n}: median fused {mf} ≫ cascade {mc}",
                        src.name(),
                        dst.name()
                    );
                } else {
                    assert!(
                        mf <= mc,
                        "{}→{} n={n}: median fused {mf} > cascade {mc}",
                        src.name(),
                        dst.name()
                    );
                }
            }
        }
    }

    #[test]
    fn table4_has_all_cells() {
        let t = table4(42);
        assert_eq!(t.len(), 6);
        assert_eq!(t[0].2.n, 500);
        assert_eq!(t[5].2.n, 2000);
    }

    #[test]
    fn fast_path_bit_identical_to_descriptor_path() {
        // accumulate_fast must reproduce accumulate exactly — same draw
        // sequence, same datapaths, compile-time formats. Relative
        // errors are f64-exact equal, not approximately equal.
        use crate::formats::{FP16ALT, FP8ALT};
        for (src, dst) in [(FP16, FP32), (FP16ALT, FP32), (FP8, FP16), (FP8ALT, FP16), (FP8, FP16ALT), (FP8ALT, FP16ALT)] {
            for n in [100usize, 501, 1000] {
                for seed in [1u64, 42, 977] {
                    let slow = accumulate(src, dst, n, seed);
                    let fast = accumulate_fast(src, dst, n, seed);
                    assert_eq!(slow.err_exsdotp.to_bits(), fast.err_exsdotp.to_bits(), "{}→{} n={n} seed={seed}", src.name(), dst.name());
                    assert_eq!(slow.err_exfma.to_bits(), fast.err_exfma.to_bits(), "{}→{} n={n} seed={seed}", src.name(), dst.name());
                }
            }
        }
        // Custom formats fall back to the descriptor path.
        let e5m1 = FpFormat::new(5, 1);
        let a = accumulate(e5m1, FP16, 200, 3);
        let b = accumulate_fast(e5m1, FP16, 200, 3);
        assert_eq!(a.err_exsdotp.to_bits(), b.err_exsdotp.to_bits());
    }

    #[test]
    fn sweep_seeds_identical_across_paths() {
        // The averaged sweep and the fast path must derive draw seeds
        // from the same helper: for every sweep seed, the descriptor
        // path and the monomorphized path report f64-identical fused
        // AND cascade errors (this is what makes `table4_averaged`'s
        // means exactly those the slow path would produce).
        for (src, dst) in [(FP16, FP32), (FP8, FP16)] {
            for draw in 0..6u64 {
                let seed = sweep_seed(draw);
                let slow = accumulate(src, dst, 500, seed);
                let fast = accumulate_fast(src, dst, 500, seed);
                assert_eq!(
                    slow.err_exsdotp.to_bits(),
                    fast.err_exsdotp.to_bits(),
                    "fused err diverged: {}→{} draw {draw}",
                    src.name(),
                    dst.name()
                );
                assert_eq!(
                    slow.err_exfma.to_bits(),
                    fast.err_exfma.to_bits(),
                    "cascade err diverged: {}→{} draw {draw}",
                    src.name(),
                    dst.name()
                );
            }
        }
        // And the schedule itself is the documented one.
        assert_eq!(sweep_seed(0), 1000);
        assert_eq!(sweep_seed(31), 1031);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = accumulate(FP8, FP16, 500, 9);
        let b = accumulate(FP8, FP16, 500, 9);
        assert_eq!(a.err_exsdotp, b.err_exsdotp);
        assert_eq!(a.err_exfma, b.err_exfma);
    }
}
