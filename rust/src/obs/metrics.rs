//! The typed metrics registry: counters, max-gauges and log2-bucket
//! histograms behind per-thread shards.
//!
//! Recording locks only the calling thread's own shard (uncontended in
//! steady state); [`snapshot`] locks every shard and folds them into
//! one deterministic view. A thread that exits folds its shard into a
//! process-wide *retired* accumulator first, so short-lived scoped
//! worker threads (the serve shard pool spawns them per tick) never
//! lose data and never grow the live-shard list without bound.
//!
//! Merging is commutative and associative by construction — counters
//! and histogram buckets add, gauges take the max — which is what makes
//! [`snapshot_json`] byte-stable under any thread count.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Is the metrics recorder on? One relaxed load — this is the entire
/// hot-path cost when observability is disabled.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Switch the recorder on or off (off by default).
pub fn enable(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Number of histogram buckets: one for zero plus one per power of two
/// of the u64 range.
pub const HIST_BUCKETS: usize = 65;

/// A log2-bucket histogram. Bucket 0 holds exact zeros; bucket `i`
/// (1..=64) holds values in `[2^(i-1), 2^i - 1]`.
#[derive(Clone)]
pub struct Hist {
    /// Total samples recorded.
    pub count: u64,
    /// Sum of all samples (saturating).
    pub sum: u64,
    /// Per-bucket sample counts.
    pub buckets: [u64; HIST_BUCKETS],
}

impl Default for Hist {
    fn default() -> Self {
        Hist { count: 0, sum: 0, buckets: [0; HIST_BUCKETS] }
    }
}

impl Hist {
    fn record(&mut self, v: u64) {
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
        self.buckets[bucket_index(v)] += 1;
    }

    fn merge(&mut self, other: &Hist) {
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
        for (b, o) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += o;
        }
    }

    /// Upper edge of the bucket where the cumulative count first
    /// reaches `q` of the total — a conservative (rounded-up) quantile.
    /// Returns 0 for an empty histogram.
    pub fn quantile_upper(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= target {
                return bucket_upper_edge(i);
            }
        }
        bucket_upper_edge(HIST_BUCKETS - 1)
    }
}

/// Bucket index for a sample: 0 for 0, else `64 - leading_zeros(v)`.
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        64 - v.leading_zeros() as usize
    }
}

/// Largest value a bucket can hold (`u64::MAX` for the top bucket).
pub fn bucket_upper_edge(i: usize) -> u64 {
    match i {
        0 => 0,
        64.. => u64::MAX,
        _ => (1u64 << i) - 1,
    }
}

#[derive(Default)]
struct ShardData {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, u64>,
    hists: BTreeMap<String, Hist>,
}

impl ShardData {
    fn merge(&mut self, other: &ShardData) {
        for (k, v) in &other.counters {
            *entry_or_zero(&mut self.counters, k) += v;
        }
        for (k, v) in &other.gauges {
            let g = entry_or_zero(&mut self.gauges, k);
            *g = (*g).max(*v);
        }
        for (k, h) in &other.hists {
            if let Some(mine) = self.hists.get_mut(k.as_str()) {
                mine.merge(h);
            } else {
                self.hists.insert(k.clone(), h.clone());
            }
        }
    }
}

fn entry_or_zero<'a>(map: &'a mut BTreeMap<String, u64>, key: &str) -> &'a mut u64 {
    if !map.contains_key(key) {
        map.insert(key.to_string(), 0);
    }
    map.get_mut(key).expect("just inserted")
}

struct Registry {
    live: Mutex<Vec<Arc<Mutex<ShardData>>>>,
    retired: Mutex<ShardData>,
}

fn registry() -> &'static Registry {
    static REG: OnceLock<Registry> = OnceLock::new();
    REG.get_or_init(|| Registry {
        live: Mutex::new(Vec::new()),
        retired: Mutex::new(ShardData::default()),
    })
}

// A poisoned shard (a panic while holding the lock) must not take the
// whole registry down — the data is monotone counters, always safe to
// read.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Thread-local handle: registers the shard on first use, folds it
/// into the retired accumulator (and drops out of the live list) when
/// the thread exits.
struct ThreadShard(Arc<Mutex<ShardData>>);

impl Drop for ThreadShard {
    fn drop(&mut self) {
        let reg = registry();
        lock(&reg.live).retain(|s| !Arc::ptr_eq(s, &self.0));
        let data = std::mem::take(&mut *lock(&self.0));
        lock(&reg.retired).merge(&data);
    }
}

thread_local! {
    static SHARD: ThreadShard = {
        let shard = Arc::new(Mutex::new(ShardData::default()));
        lock(&registry().live).push(shard.clone());
        ThreadShard(shard)
    };
}

fn with_shard(f: impl FnOnce(&mut ShardData)) {
    SHARD.with(|s| f(&mut lock(&s.0)));
}

/// Add `n` to counter `name` (created at 0 on first touch). Prefer the
/// [`obs_count!`](crate::obs_count) macro, which skips the call when
/// disabled.
pub fn counter_add(name: &str, n: u64) {
    with_shard(|d| *entry_or_zero(&mut d.counters, name) += n);
}

/// Raise gauge `name` to at least `v`.
pub fn gauge_max(name: &str, v: u64) {
    with_shard(|d| {
        let g = entry_or_zero(&mut d.gauges, name);
        *g = (*g).max(v);
    });
}

/// Record one histogram sample.
pub fn hist_record(name: &str, v: u64) {
    with_shard(|d| {
        if !d.hists.contains_key(name) {
            d.hists.insert(name.to_string(), Hist::default());
        }
        d.hists.get_mut(name).expect("just inserted").record(v);
    });
}

/// An aggregated, immutable view of every shard at one instant.
pub struct Snapshot {
    /// Monotone event counts.
    pub counters: BTreeMap<String, u64>,
    /// Max-aggregated gauges.
    pub gauges: BTreeMap<String, u64>,
    /// Log2-bucket histograms.
    pub hists: BTreeMap<String, Hist>,
}

impl Snapshot {
    /// Counter value, 0 if never touched.
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Gauge value, 0 if never touched.
    pub fn gauge(&self, name: &str) -> u64 {
        self.gauges.get(name).copied().unwrap_or(0)
    }

    /// Histogram by name.
    pub fn hist(&self, name: &str) -> Option<&Hist> {
        self.hists.get(name)
    }

    /// Byte-stable JSON rendering: `BTreeMap` iteration fixes key
    /// order, histogram buckets are emitted sparsely as
    /// `[index, count]` pairs in ascending index order.
    pub fn json(&self) -> String {
        let mut s = String::from("{\"counters\":{");
        let mut first = true;
        for (k, v) in &self.counters {
            if !first {
                s.push(',');
            }
            first = false;
            s += &format!("\"{k}\":{v}");
        }
        s += "},\"gauges\":{";
        first = true;
        for (k, v) in &self.gauges {
            if !first {
                s.push(',');
            }
            first = false;
            s += &format!("\"{k}\":{v}");
        }
        s += "},\"hists\":{";
        first = true;
        for (k, h) in &self.hists {
            if !first {
                s.push(',');
            }
            first = false;
            let buckets: Vec<String> = h
                .buckets
                .iter()
                .enumerate()
                .filter(|(_, &n)| n > 0)
                .map(|(i, n)| format!("[{i},{n}]"))
                .collect();
            s += &format!(
                "\"{k}\":{{\"count\":{},\"sum\":{},\"buckets\":[{}]}}",
                h.count,
                h.sum,
                buckets.join(",")
            );
        }
        s += "}}";
        s
    }
}

/// Aggregate every shard (live + retired) into one [`Snapshot`].
pub fn snapshot() -> Snapshot {
    let reg = registry();
    let mut acc = ShardData::default();
    acc.merge(&lock(&reg.retired));
    // Clone the shard list out so no shard lock is held while another
    // thread's Drop handler wants the live-list lock.
    let shards: Vec<Arc<Mutex<ShardData>>> = lock(&reg.live).clone();
    for shard in shards {
        let data = lock(&shard);
        acc.merge(&data);
    }
    Snapshot { counters: acc.counters, gauges: acc.gauges, hists: acc.hists }
}

/// [`Snapshot::json`] of the current state.
pub fn snapshot_json() -> String {
    snapshot().json()
}

/// Zero every shard, live and retired. (Keys are dropped, not kept at
/// zero, so a snapshot after reset is `{}`-clean.)
pub fn reset() {
    let reg = registry();
    *lock(&reg.retired) = ShardData::default();
    let shards: Vec<Arc<Mutex<ShardData>>> = lock(&reg.live).clone();
    for shard in shards {
        *lock(&shard) = ShardData::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::test_guard;

    // Unit tests here use test-unique metric names so that unrelated
    // instrumented code running in parallel test threads (which only
    // records while these tests hold the recorder enabled) cannot
    // collide with the asserted values. Whole-snapshot byte-stability
    // lives in the dedicated `obs_differential` integration binary.

    #[test]
    fn bucket_edges_are_exact_powers_of_two() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        assert_eq!(bucket_index(255), 8);
        assert_eq!(bucket_index(256), 9);
        assert_eq!(bucket_index(u64::MAX), 64);
        assert_eq!(bucket_upper_edge(0), 0);
        assert_eq!(bucket_upper_edge(1), 1);
        assert_eq!(bucket_upper_edge(8), 255);
        assert_eq!(bucket_upper_edge(64), u64::MAX);
        // Every non-zero value lands in the bucket whose upper edge
        // bounds it and whose predecessor's edge does not.
        for v in [1u64, 2, 3, 7, 8, 1023, 1024, 1 << 40] {
            let i = bucket_index(v);
            assert!(v <= bucket_upper_edge(i), "{v} above bucket {i}");
            assert!(v > bucket_upper_edge(i - 1), "{v} below bucket {i}");
        }
    }

    #[test]
    fn counters_and_gauges_record_only_when_enabled() {
        let _g = test_guard();
        reset();
        enable(false);
        crate::obs_count!("test.metrics.off", 5);
        assert_eq!(snapshot().counter("test.metrics.off"), 0);
        enable(true);
        crate::obs_count!("test.metrics.on", 5);
        crate::obs_count!("test.metrics.on");
        crate::obs_gauge_max!("test.metrics.gauge", 7);
        crate::obs_gauge_max!("test.metrics.gauge", 3);
        enable(false);
        let snap = snapshot();
        assert_eq!(snap.counter("test.metrics.on"), 6);
        assert_eq!(snap.gauge("test.metrics.gauge"), 7);
        reset();
        assert_eq!(snapshot().counter("test.metrics.on"), 0);
    }

    #[test]
    fn shards_from_exited_threads_fold_into_the_snapshot() {
        let _g = test_guard();
        reset();
        enable(true);
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| counter_add("test.metrics.sharded", 10));
            }
        });
        counter_add("test.metrics.sharded", 2);
        enable(false);
        assert_eq!(snapshot().counter("test.metrics.sharded"), 42);
        reset();
    }

    #[test]
    fn histogram_quantiles_return_bucket_upper_edges() {
        let mut h = Hist::default();
        for v in [0u64, 1, 1, 2, 3, 4, 200, 300, 70_000] {
            h.record(v);
        }
        assert_eq!(h.count, 9);
        assert_eq!(h.buckets[0], 1);
        assert_eq!(h.buckets[1], 2);
        assert_eq!(h.buckets[2], 2);
        assert_eq!(h.buckets[3], 1);
        assert_eq!(h.buckets[8], 1);
        assert_eq!(h.buckets[9], 1);
        assert_eq!(h.buckets[17], 1);
        // p50 of 9 samples = 5th -> bucket 2 (values 2..=3).
        assert_eq!(h.quantile_upper(0.50), 3);
        assert_eq!(h.quantile_upper(1.0), bucket_upper_edge(17));
        assert_eq!(Hist::default().quantile_upper(0.5), 0);
    }
}
