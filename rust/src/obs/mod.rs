//! Unified deterministic observability: metrics, tracing, profiling.
//!
//! The paper's headline results are *per-layer breakdowns* — compute
//! vs. DMA overlap, FPU utilization, packed-route hit rates — and the
//! scattered one-off counters grown by PRs 1–7 (`nn::GemmCtx`,
//! `api::PlanInstance`, `serve::ServeStats`, `soc::L2Stats`) could not
//! answer "where do the cycles go?" for a whole run. This module is the
//! common substrate those counters now feed:
//!
//! * [`metrics`] — a typed registry (counters, max-gauges, log2-bucket
//!   histograms) with per-thread shards aggregated at snapshot time and
//!   a byte-stable [`metrics::snapshot_json`].
//! * [`trace`] — structured spans over **virtual time where it exists**
//!   (SoC cycles, serve ticks) and monotonic wall time elsewhere, in a
//!   bounded ring recorder with a Chrome-trace-event JSON exporter
//!   (Perfetto-loadable, see [`trace::write_chrome_trace`]).
//! * [`prof`] — the roll-up: per-phase cycle shares, packed/SWAR-route
//!   hit rates, serve percentiles, derived from a metrics snapshot
//!   (rendered by `report::obs_text` / `report::obs_json`).
//!
//! ## The two invariants
//!
//! **Observation never perturbs the system.** Every hot-path macro
//! compiles to one relaxed atomic load when observability is off, and
//! no module reads obs state to make a control-flow decision — obs is
//! a *leaf* of the module graph (it depends only on `std`). The
//! differential suite (`tests/obs_differential.rs`) pins bit-identity
//! of every result word *and* cycle count with instrumentation on vs.
//! off across the batch, nn, serve and soc pillars.
//!
//! **Snapshots are deterministic.** Counter/histogram merges are
//! additive and gauges merge by max, so the aggregated snapshot — and
//! its JSON rendering — is byte-identical however the work was sharded
//! across threads (pinned under worker counts {1,4,7}).
//!
//! Everything here is disabled by default; `repro ... --metrics` and
//! `repro ... --trace FILE` switch it on per run.

pub mod metrics;
pub mod prof;
pub mod trace;

/// Enable metrics and tracing together (the `--trace` + `--metrics`
/// CLI combination).
pub fn enable_all() {
    metrics::enable(true);
    trace::enable(true);
}

/// Disable both recorders (the default state).
pub fn disable_all() {
    metrics::enable(false);
    trace::enable(false);
}

/// Clear all recorded metrics and trace events (recorder enablement is
/// left as-is).
pub fn reset_all() {
    metrics::reset();
    trace::reset();
}

/// Serialize tests that enable the global recorders. The registry and
/// the trace ring are process-global, so concurrent tests that enable
/// them would observe each other's increments; every test touching obs
/// state holds this guard first. Poison-tolerant: a panicking test must
/// not cascade into every later obs test.
pub fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static GUARD: std::sync::Mutex<()> = std::sync::Mutex::new(());
    GUARD.lock().unwrap_or_else(|e| e.into_inner())
}

/// Bump a counter: `obs_count!("api.plan.runs")` or
/// `obs_count!("soc.l2.read_bytes", n)`. Compiles to a relaxed atomic
/// load + branch when metrics are disabled; the name and value
/// expressions are only evaluated when enabled.
#[macro_export]
macro_rules! obs_count {
    ($name:expr) => {
        $crate::obs_count!($name, 1u64)
    };
    ($name:expr, $n:expr) => {
        if $crate::obs::metrics::enabled() {
            $crate::obs::metrics::counter_add($name, $n as u64);
        }
    };
}

/// Record a max-gauge: keeps the maximum value seen (max is the one
/// aggregation that stays deterministic under arbitrary sharding).
#[macro_export]
macro_rules! obs_gauge_max {
    ($name:expr, $v:expr) => {
        if $crate::obs::metrics::enabled() {
            $crate::obs::metrics::gauge_max($name, $v as u64);
        }
    };
}

/// Record a histogram sample into fixed log2 buckets:
/// `obs_hist!("serve.batch_size", batch.len())`.
#[macro_export]
macro_rules! obs_hist {
    ($name:expr, $v:expr) => {
        if $crate::obs::metrics::enabled() {
            $crate::obs::metrics::hist_record($name, $v as u64);
        }
    };
}
