//! Structured span tracing with a Chrome-trace-event exporter.
//!
//! ## The clock rule
//!
//! Spans run on **virtual time wherever the system has one** and on
//! monotonic wall time only where it does not:
//!
//! * [`Clock::Cycles`] — the SoC/cluster integer-cycle timelines
//!   (`soc::sched`): DMA chunk fetches, compute windows, write-backs.
//! * [`Clock::Ticks`] — the serving layer's virtual ticks: batch
//!   dispatches on the tick they happen.
//! * [`Clock::Wall`] — everything that has no simulated clock: plan
//!   compilation, operand packing, tier dispatch, training phases.
//!
//! Each clock exports as its own Chrome *process* (pid 1 = wall,
//! pid 2 = cycles, pid 3 = ticks) so Perfetto renders the three time
//! bases side by side instead of interleaving nanoseconds with cycle
//! numbers. Within the cycles process, tid is the cluster index;
//! within the wall process, tids are small per-thread integers handed
//! out on first use.
//!
//! Events land in a bounded ring ([`CAPACITY`]); overflow increments a
//! drop counter instead of reallocating (observation must never cause
//! unbounded memory growth). Everything is a no-op — one relaxed
//! atomic load — while tracing is disabled.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Instant;

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Is the trace recorder on? One relaxed load on the hot path.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Switch the recorder on or off (off by default).
pub fn enable(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Ring capacity in events; past this, new events are counted as
/// dropped rather than stored.
pub const CAPACITY: usize = 1 << 18;

/// Which time base an event's `ts`/`dur` are measured in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Clock {
    /// Monotonic wall time, nanoseconds since the process trace epoch.
    Wall,
    /// Simulated hardware cycles (SoC / cluster timelines).
    Cycles,
    /// Serving-layer virtual ticks.
    Ticks,
}

impl Clock {
    fn pid(self) -> u32 {
        match self {
            Clock::Wall => 1,
            Clock::Cycles => 2,
            Clock::Ticks => 3,
        }
    }

    fn process_name(self) -> &'static str {
        match self {
            Clock::Wall => "wall",
            Clock::Cycles => "cycles",
            Clock::Ticks => "virtual-ticks",
        }
    }
}

/// One recorded complete ("ph":"X") event.
#[derive(Clone)]
pub struct Event {
    /// Span name (the taxonomy table in DESIGN.md lists them all).
    pub name: &'static str,
    /// Category, e.g. `"api"`, `"batch"`, `"nn"`, `"serve"`, `"soc"`.
    pub cat: &'static str,
    /// Time base of `ts`/`dur`.
    pub clock: Clock,
    /// Thread/cluster/queue lane within the clock's process.
    pub tid: u64,
    /// Start time (ns for [`Clock::Wall`], native units otherwise).
    pub ts: u64,
    /// Duration in the same unit as `ts`.
    pub dur: u64,
    /// Pre-rendered JSON object *body* (no braces), e.g.
    /// `"m":128,"tier":"swar"` — built by the caller only when tracing
    /// is enabled.
    pub args: Option<String>,
}

struct Recorder {
    events: Mutex<Vec<Event>>,
    dropped: AtomicU64,
}

fn recorder() -> &'static Recorder {
    static REC: OnceLock<Recorder> = OnceLock::new();
    REC.get_or_init(|| Recorder { events: Mutex::new(Vec::new()), dropped: AtomicU64::new(0) })
}

fn lock_events() -> MutexGuard<'static, Vec<Event>> {
    recorder().events.lock().unwrap_or_else(|e| e.into_inner())
}

/// Record one event (caller has already checked [`enabled`]).
pub fn record(ev: Event) {
    let mut events = lock_events();
    if events.len() >= CAPACITY {
        recorder().dropped.fetch_add(1, Ordering::Relaxed);
    } else {
        events.push(ev);
    }
}

/// Events dropped since the last [`reset`] because the ring was full.
pub fn dropped() -> u64 {
    recorder().dropped.load(Ordering::Relaxed)
}

/// Number of events currently held.
pub fn len() -> usize {
    lock_events().len()
}

/// Clear the ring and the drop counter.
pub fn reset() {
    lock_events().clear();
    recorder().dropped.store(0, Ordering::Relaxed);
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Nanoseconds since the process trace epoch (first use).
pub fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

fn wall_tid() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    thread_local! {
        static TID: u64 = NEXT.fetch_add(1, Ordering::Relaxed);
    }
    TID.with(|t| *t)
}

/// A wall-clock span guard: created at phase entry, records one
/// complete event when dropped. `None` inside means tracing was off at
/// creation — the guard is then a true no-op.
pub struct Span(Option<SpanInner>);

struct SpanInner {
    name: &'static str,
    cat: &'static str,
    start_ns: u64,
    args: Option<String>,
}

/// Open a wall-clock span (no-op while tracing is disabled).
pub fn span(name: &'static str, cat: &'static str) -> Span {
    if !enabled() {
        return Span(None);
    }
    Span(Some(SpanInner { name, cat, start_ns: now_ns(), args: None }))
}

/// Open a wall-clock span carrying pre-rendered args. The `args`
/// closure runs only when tracing is enabled, so hot paths pay no
/// formatting cost while off.
pub fn span_with(name: &'static str, cat: &'static str, args: impl FnOnce() -> String) -> Span {
    if !enabled() {
        return Span(None);
    }
    Span(Some(SpanInner { name, cat, start_ns: now_ns(), args: Some(args()) }))
}

impl Span {
    /// Is this guard actually recording?
    pub fn is_active(&self) -> bool {
        self.0.is_some()
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(inner) = self.0.take() {
            let end = now_ns();
            record(Event {
                name: inner.name,
                cat: inner.cat,
                clock: Clock::Wall,
                tid: wall_tid(),
                ts: inner.start_ns,
                dur: end.saturating_sub(inner.start_ns),
                args: inner.args,
            });
        }
    }
}

/// Record a virtual-time span (cycles or ticks) directly: virtual
/// timelines are resolved after the fact by the schedulers, so there
/// is no guard to hold open. No-op while tracing is disabled; the
/// `args` closure runs only when it is not.
pub fn virt_span(
    clock: Clock,
    tid: u64,
    name: &'static str,
    cat: &'static str,
    ts: u64,
    dur: u64,
    args: impl FnOnce() -> String,
) {
    if !enabled() {
        return;
    }
    record(Event { name, cat, clock, tid, ts, dur, args: Some(args()) });
}

// ------------------------------------------------------------- export

fn push_ts(out: &mut String, clock: Clock, v: u64) {
    match clock {
        // Wall ns -> fractional microseconds (Chrome's native unit).
        Clock::Wall => *out += &format!("{}.{:03}", v / 1000, v % 1000),
        // One cycle / one tick renders as one microsecond: virtual
        // timelines keep their integer coordinates verbatim.
        Clock::Cycles | Clock::Ticks => *out += &v.to_string(),
    }
}

/// Render the ring as a Chrome trace-event JSON document
/// (`chrome://tracing` / Perfetto "Open trace file").
pub fn chrome_json() -> String {
    let events = lock_events();
    let mut s = String::from("{\"traceEvents\":[");
    let mut first = true;
    // Name the three clock processes so the viewer labels the tracks.
    for clock in [Clock::Wall, Clock::Cycles, Clock::Ticks] {
        if !first {
            s.push(',');
        }
        first = false;
        s += &format!(
            "{{\"ph\":\"M\",\"pid\":{},\"tid\":0,\"name\":\"process_name\",\
             \"args\":{{\"name\":\"{}\"}}}}",
            clock.pid(),
            clock.process_name()
        );
    }
    for ev in events.iter() {
        s.push(',');
        s += &format!(
            "{{\"ph\":\"X\",\"pid\":{},\"tid\":{},\"name\":\"{}\",\"cat\":\"{}\",\"ts\":",
            ev.clock.pid(),
            ev.tid,
            ev.name,
            ev.cat
        );
        push_ts(&mut s, ev.clock, ev.ts);
        s += ",\"dur\":";
        push_ts(&mut s, ev.clock, ev.dur);
        if let Some(args) = &ev.args {
            s += &format!(",\"args\":{{{args}}}");
        }
        s += "}";
    }
    let dropped = recorder().dropped.load(Ordering::Relaxed);
    s += &format!("],\"displayTimeUnit\":\"ms\",\"otherData\":{{\"dropped\":{dropped}}}}}");
    s
}

/// Write [`chrome_json`] to `path`.
pub fn write_chrome_trace(path: &str) -> std::io::Result<()> {
    std::fs::write(path, chrome_json())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::test_guard;

    #[test]
    fn disabled_recorder_stores_nothing() {
        let _g = test_guard();
        reset();
        enable(false);
        {
            let s = span("test.trace.off", "test");
            assert!(!s.is_active());
        }
        virt_span(Clock::Cycles, 0, "test.trace.off", "test", 0, 10, String::new);
        assert_eq!(len(), 0);
    }

    #[test]
    fn spans_and_virtual_events_export_as_chrome_json() {
        let _g = test_guard();
        reset();
        enable(true);
        {
            let _s = span_with("test.trace.span", "test", || "\"k\":1".to_string());
        }
        virt_span(Clock::Cycles, 3, "test.trace.dma", "soc", 100, 40, || {
            "\"bytes\":512".to_string()
        });
        virt_span(Clock::Ticks, 0, "test.trace.tick", "serve", 7, 1, String::new);
        enable(false);
        let json = chrome_json();
        reset();
        assert!(json.starts_with("{\"traceEvents\":["), "{json}");
        assert!(json.contains("\"name\":\"test.trace.span\""), "{json}");
        // The cycles-clock event keeps its integer coordinates and
        // lands in pid 2, tid 3.
        assert!(
            json.contains(
                "{\"ph\":\"X\",\"pid\":2,\"tid\":3,\"name\":\"test.trace.dma\",\"cat\":\"soc\",\
                 \"ts\":100,\"dur\":40,\"args\":{\"bytes\":512}}"
            ),
            "{json}"
        );
        assert!(json.contains("\"args\":{\"name\":\"cycles\"}"), "{json}");
        assert!(json.ends_with("\"otherData\":{\"dropped\":0}}"), "{json}");
        // Balanced braces: the document must parse as JSON.
        let open = json.matches('{').count();
        let close = json.matches('}').count();
        assert_eq!(open, close, "unbalanced braces in {json}");
    }

    #[test]
    fn ring_overflow_counts_drops_instead_of_growing() {
        let _g = test_guard();
        reset();
        // Exercise the bound without allocating 256k events: fill via
        // the public record path up to capacity is too slow here, so
        // emulate by checking the drop counter path with a full ring.
        {
            let mut events = super::lock_events();
            events.clear();
            let ev = Event {
                name: "test.trace.fill",
                cat: "test",
                clock: Clock::Wall,
                tid: 1,
                ts: 0,
                dur: 0,
                args: None,
            };
            events.resize(CAPACITY, ev);
        }
        record(Event {
            name: "test.trace.over",
            cat: "test",
            clock: Clock::Wall,
            tid: 1,
            ts: 0,
            dur: 0,
            args: None,
        });
        assert_eq!(len(), CAPACITY);
        assert_eq!(dropped(), 1);
        reset();
        assert_eq!(len(), 0);
        assert_eq!(dropped(), 0);
    }
}
