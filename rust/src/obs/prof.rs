//! The profiling roll-up: one derived view answering "where did the
//! run spend its time and which fast paths did it hit?", computed from
//! a metrics [`Snapshot`](super::metrics::Snapshot).
//!
//! Rendering lives in `report::obs_text` / `report::obs_json`; this
//! module only derives numbers, so the report layer stays the single
//! place that owns formatting.

use super::metrics::Snapshot;

/// Per-tenant serving roll-up.
pub struct TenantProfile {
    /// Tenant name (the CLI `--tenants` entry).
    pub name: String,
    /// GemmPlan runs issued by this tenant's shard contexts.
    pub gemm_calls: u64,
    /// How many of those took the zero-repack packed route.
    pub packed_runs: u64,
}

/// Everything the roll-up report prints, derived from one snapshot.
pub struct Profile {
    /// `api.plan.runs`: plan-instance executions.
    pub plan_runs: u64,
    /// `api.plan.packed_runs`: executions on the zero-repack route.
    pub plan_packed: u64,
    /// `batch.tier.swar` / `batch.tier.scalar`: lane-tier dispatches.
    pub tier_swar: u64,
    /// Scalar-tier dispatches (reference path).
    pub tier_scalar: u64,
    /// `batch.gemm.blocked` / `batch.gemm.simple`: BlockPlan routing.
    pub gemm_blocked: u64,
    /// Unblocked (single-tile) GEMM loops.
    pub gemm_simple: u64,
    /// `nn.plan.builds` / `nn.plan.reuses`: GemmCtx plan cache.
    pub plan_builds: u64,
    /// Plan-cache hits.
    pub plan_reuses: u64,
    /// `nn.scale.skips`: loss-scaler overflow skips (each also backs
    /// the scale off).
    pub scale_skips: u64,
    /// `nn.scale.growths`: loss-scale doublings.
    pub scale_growths: u64,
    /// `soc.cycles.total/compute/dma_stall` summed over clusters.
    pub soc_total: u64,
    /// Busy compute cycles.
    pub soc_compute: u64,
    /// Cycles compute sat stalled on DMA.
    pub soc_stall: u64,
    /// `serve.submitted` / `serve.completed` / `serve.batches` /
    /// `serve.deadline_misses` / `serve.ticks`.
    pub serve_submitted: u64,
    /// Completed responses.
    pub serve_completed: u64,
    /// Batch dispatches.
    pub serve_batches: u64,
    /// Responses past their deadline.
    pub serve_deadline_misses: u64,
    /// Virtual ticks simulated.
    pub serve_ticks: u64,
    /// Approximate latency percentiles (p50, p95, p99) in ticks from
    /// the `serve.latency_ticks` log2 histogram — each is the upper
    /// edge of the bucket the quantile falls in.
    pub serve_latency: Option<(u64, u64, u64)>,
    /// Per-tenant routing, in name order.
    pub tenants: Vec<TenantProfile>,
}

fn share(part: u64, whole: u64) -> f64 {
    if whole == 0 {
        0.0
    } else {
        part as f64 / whole as f64
    }
}

impl Profile {
    /// Packed-route hit rate over all plan runs (0..=1).
    pub fn packed_rate(&self) -> f64 {
        share(self.plan_packed, self.plan_runs)
    }

    /// SWAR share of lane-tier dispatches (0..=1).
    pub fn swar_rate(&self) -> f64 {
        share(self.tier_swar, self.tier_swar + self.tier_scalar)
    }

    /// SoC (compute, dma_stall, idle) cycle shares; zeros when no SoC
    /// run was recorded.
    pub fn soc_shares(&self) -> (f64, f64, f64) {
        let idle = self.soc_total.saturating_sub(self.soc_compute + self.soc_stall);
        (
            share(self.soc_compute, self.soc_total),
            share(self.soc_stall, self.soc_total),
            share(idle, self.soc_total),
        )
    }
}

/// Derive the roll-up from a snapshot. Tenant rows are discovered from
/// the `serve.tenant.<name>.gemm_calls` counter namespace.
pub fn profile(s: &Snapshot) -> Profile {
    let mut tenants = Vec::new();
    for (key, &calls) in &s.counters {
        if let Some(rest) = key.strip_prefix("serve.tenant.") {
            if let Some(name) = rest.strip_suffix(".gemm_calls") {
                tenants.push(TenantProfile {
                    name: name.to_string(),
                    gemm_calls: calls,
                    packed_runs: s.counter(&format!("serve.tenant.{name}.packed_runs")),
                });
            }
        }
    }
    let latency = s.hist("serve.latency_ticks").map(|h| {
        (h.quantile_upper(0.50), h.quantile_upper(0.95), h.quantile_upper(0.99))
    });
    Profile {
        plan_runs: s.counter("api.plan.runs"),
        plan_packed: s.counter("api.plan.packed_runs"),
        tier_swar: s.counter("batch.tier.swar"),
        tier_scalar: s.counter("batch.tier.scalar"),
        gemm_blocked: s.counter("batch.gemm.blocked"),
        gemm_simple: s.counter("batch.gemm.simple"),
        plan_builds: s.counter("nn.plan.builds"),
        plan_reuses: s.counter("nn.plan.reuses"),
        scale_skips: s.counter("nn.scale.skips"),
        scale_growths: s.counter("nn.scale.growths"),
        soc_total: s.counter("soc.cycles.total"),
        soc_compute: s.counter("soc.cycles.compute"),
        soc_stall: s.counter("soc.cycles.dma_stall"),
        serve_submitted: s.counter("serve.submitted"),
        serve_completed: s.counter("serve.completed"),
        serve_batches: s.counter("serve.batches"),
        serve_deadline_misses: s.counter("serve.deadline_misses"),
        // Virtual time is monotone, so the tick clock dual-writes as a
        // max-gauge (an assignment, not an increment, in ServeStats).
        serve_ticks: s.gauge("serve.ticks"),
        serve_latency: latency,
        tenants,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::metrics::Hist;
    use std::collections::BTreeMap;

    #[test]
    fn derives_rates_shares_and_tenant_rows_from_a_snapshot() {
        let mut counters = BTreeMap::new();
        for (k, v) in [
            ("api.plan.runs", 10u64),
            ("api.plan.packed_runs", 8),
            ("batch.tier.swar", 6),
            ("batch.tier.scalar", 2),
            ("soc.cycles.total", 1000),
            ("soc.cycles.compute", 700),
            ("soc.cycles.dma_stall", 100),
            ("serve.tenant.fp32.gemm_calls", 4),
            ("serve.tenant.fp32.packed_runs", 4),
            ("serve.tenant.hfp8.gemm_calls", 5),
            ("serve.tenant.hfp8.packed_runs", 3),
        ] {
            counters.insert(k.to_string(), v);
        }
        let mut lat = Hist::default();
        for v in [1u64, 2, 2, 3, 9] {
            lat.count += 1;
            lat.sum += v;
            lat.buckets[crate::obs::metrics::bucket_index(v)] += 1;
        }
        let mut hists = BTreeMap::new();
        hists.insert("serve.latency_ticks".to_string(), lat);
        let snap = Snapshot { counters, gauges: BTreeMap::new(), hists };
        let p = profile(&snap);
        assert!((p.packed_rate() - 0.8).abs() < 1e-12);
        assert!((p.swar_rate() - 0.75).abs() < 1e-12);
        let (compute, stall, idle) = p.soc_shares();
        assert!((compute - 0.7).abs() < 1e-12);
        assert!((stall - 0.1).abs() < 1e-12);
        assert!((idle - 0.2).abs() < 1e-12);
        assert_eq!(p.tenants.len(), 2);
        assert_eq!(p.tenants[0].name, "fp32");
        assert_eq!(p.tenants[1].packed_runs, 3);
        // 5 samples: p50 = 3rd sample (2) -> bucket 2 upper edge 3.
        assert_eq!(p.serve_latency, Some((3, 15, 15)));
    }

    #[test]
    fn empty_snapshot_degrades_to_zeros() {
        let snap = Snapshot {
            counters: BTreeMap::new(),
            gauges: BTreeMap::new(),
            hists: BTreeMap::new(),
        };
        let p = profile(&snap);
        assert_eq!(p.packed_rate(), 0.0);
        assert_eq!(p.soc_shares(), (0.0, 0.0, 0.0));
        assert!(p.serve_latency.is_none());
        assert!(p.tenants.is_empty());
    }
}
