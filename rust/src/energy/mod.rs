//! Per-operation energy model (Table III, §IV-C).
//!
//! **Substitution note (DESIGN.md §2):** the paper extracts power with
//! PrimePower from switching activity of the placed-and-routed 12 nm
//! netlist. We model energy as a per-operation table (pJ at 0.8 V,
//! typical corner) applied to the simulator's op counters, plus a
//! per-cycle static/clock-tree term — the standard architecture-level
//! energy-model shape. Calibration anchors from the paper:
//!
//! * FPU peak efficiency, SIMD FP8→FP16 ExSdotp: **1631 GFLOPS/W**
//!   (Table III top row) → 16 FLOP / E(sdotp-op) ⇒ ≈ 9.8 pJ/op.
//! * Cluster computing 128×256 FP8→FP16 GEMM: **224 mW @ 1.26 GHz**
//!   ⇒ ≈ 178 pJ/cycle ⇒ 575 GFLOPS/W (§IV-C).
//! * The native FP64 Snitch cluster reference: ~80 GFLOPS/W (Table III
//!   bottom row, 22 nm — our 12 nm model lands in the same band, which
//!   the paper itself leans on for its 7.2× claim).

use crate::core::CoreStats;
use crate::isa::instr::{OpWidth, ScalarFmt};

/// Operating point (paper: typical corner).
pub const VDD: f64 = 0.8;
/// Clock frequency in GHz (typical corner, §IV-A).
pub const FREQ_GHZ: f64 = 1.26;

/// Energy per operation in pJ (0.8 V, GF12, model values).
#[derive(Clone, Copy, Debug)]
pub struct EnergyTable {
    /// SIMD SDOTP-group op, 8→16 (4 units busy).
    pub sdotp_btoh: f64,
    /// SIMD SDOTP-group op, 16→32 (2 units busy).
    pub sdotp_htos: f64,
    /// Scalar FP64 FMA.
    pub fma_d: f64,
    /// SIMD 2×FP32 FMA.
    pub fma_s: f64,
    /// SIMD 4×FP16 FMA.
    pub fma_h: f64,
    /// Cast-group op.
    pub cast: f64,
    /// Comparison/sign-injection op.
    pub comp: f64,
    /// FP load/store.
    pub fmem: f64,
    /// One TCDM access (SSR element or load/store data side).
    pub tcdm: f64,
    /// One integer-core instruction.
    pub int_instr: f64,
    /// Static + clock-tree energy per cycle for the whole cluster.
    pub static_per_cycle: f64,
}

impl Default for EnergyTable {
    fn default() -> Self {
        EnergyTable {
            sdotp_btoh: 9.8,
            sdotp_htos: 10.5,
            fma_d: 12.0,
            fma_s: 9.0,
            fma_h: 8.5,
            cast: 3.0,
            comp: 1.5,
            fmem: 4.0,
            tcdm: 4.5,
            int_instr: 1.8,
            static_per_cycle: 45.0,
        }
    }
}

/// Which compute op dominates a kernel (selects the FPU energy row).
#[derive(Clone, Copy, Debug)]
pub enum ComputeClass {
    /// SIMD expanding dot product of the given width.
    Sdotp(OpWidth),
    /// FMA of the given format.
    Fma(ScalarFmt),
}

/// Energy/power/efficiency report for one kernel run.
#[derive(Clone, Copy, Debug)]
pub struct EnergyReport {
    /// Total energy in µJ.
    pub total_uj: f64,
    /// Average power in mW at [`FREQ_GHZ`].
    pub avg_mw: f64,
    /// Achieved GFLOPS at [`FREQ_GHZ`].
    pub gflops: f64,
    /// Energy efficiency in GFLOPS/W.
    pub gflops_per_w: f64,
}

/// Dynamic (switching) energy in pJ for one run's op counters — the
/// per-cycle static term is the caller's, so multi-cluster aggregations
/// can bill static time per cluster without double counting.
fn dynamic_pj(stats: &CoreStats, class: ComputeClass, table: &EnergyTable) -> f64 {
    let fpu_op = match class {
        ComputeClass::Sdotp(OpWidth::BtoH) => table.sdotp_btoh,
        ComputeClass::Sdotp(OpWidth::HtoS) => table.sdotp_htos,
        ComputeClass::Fma(ScalarFmt::D) => table.fma_d,
        ComputeClass::Fma(ScalarFmt::S) => table.fma_s,
        ComputeClass::Fma(_) => table.fma_h,
    };
    // SDOTP counters include the epilogue vsum ops; ADDMUL counters the
    // FMAs — both billed at the kernel's compute-op energy; COMP/CAST at
    // their own rows.
    let mut pj = 0.0;
    pj += (stats.ops_sdotp + stats.ops_addmul) as f64 * fpu_op;
    pj += stats.ops_cast as f64 * table.cast;
    pj += stats.ops_comp as f64 * table.comp;
    pj += stats.ops_fmem as f64 * table.fmem;
    pj += stats.ssr_elems as f64 * table.tcdm;
    pj += stats.ops_fmem as f64 * table.tcdm; // data side of fl/fs
    pj += stats.int_retired as f64 * table.int_instr;
    pj
}

fn report(pj: f64, flops: f64, cycles: u64) -> EnergyReport {
    let seconds = cycles as f64 / (FREQ_GHZ * 1e9);
    let total_j = pj * 1e-12;
    EnergyReport {
        total_uj: total_j * 1e6,
        avg_mw: total_j / seconds * 1e3,
        gflops: flops / seconds / 1e9,
        gflops_per_w: flops / total_j / 1e9,
    }
}

/// Estimate energy for a simulated run from its op counters.
pub fn estimate(stats: &CoreStats, cycles: u64, class: ComputeClass, table: &EnergyTable) -> EnergyReport {
    let pj = dynamic_pj(stats, class, table) + cycles as f64 * table.static_per_cycle;
    report(pj, stats.flops as f64, cycles)
}

// --------------------------------------------------------- SoC aggregation

/// SoC-level energy terms layered on the per-cluster table: the shared
/// L2 and the cluster-to-L2 interconnect. Model values in the same
/// 0.8 V GF12 regime as [`EnergyTable`]: SRAM macro access energy per
/// byte, interconnect wire/mux toggling per byte, and an L2 + fabric
/// leakage/clock term per cycle.
#[derive(Clone, Copy, Debug)]
pub struct SocEnergyTable {
    /// L2 SRAM access energy per byte (pJ/B).
    pub l2_per_byte: f64,
    /// Interconnect traversal energy per byte (pJ/B).
    pub interconnect_per_byte: f64,
    /// L2 + interconnect static/clock energy per cycle (pJ).
    pub l2_static_per_cycle: f64,
}

impl Default for SocEnergyTable {
    fn default() -> Self {
        SocEnergyTable { l2_per_byte: 1.1, interconnect_per_byte: 0.4, l2_static_per_cycle: 60.0 }
    }
}

/// Compute-region aggregate over clusters — the paper's *cluster*
/// efficiency metric, scaled out: each entry is one cluster's
/// (aggregated op counters, busy compute cycles). Static energy is
/// billed per cluster for its own busy window; the wall clock for
/// power/GFLOPS is the slowest cluster's busy window (they compute in
/// parallel). With a single cluster this reduces exactly to
/// [`estimate`] — the identity the roofline's N = 1 column and the
/// `repro roofline --check-anchor` CI gate rely on.
pub fn estimate_cluster_region(
    clusters: &[(CoreStats, u64)],
    class: ComputeClass,
    table: &EnergyTable,
) -> EnergyReport {
    let mut pj = 0.0;
    let mut flops = 0u64;
    let mut busy_max = 0u64;
    for (stats, busy) in clusters {
        pj += dynamic_pj(stats, class, table) + *busy as f64 * table.static_per_cycle;
        flops += stats.flops;
        busy_max = busy_max.max(*busy);
    }
    report(pj, flops as f64, busy_max)
}

/// Whole-SoC estimate: cluster dynamic energy, per-cluster static for
/// the full wall clock (idle clusters still burn leakage — the scale-out
/// tax the roofline exists to show), plus L2/interconnect dynamic per
/// byte moved and L2 static per cycle.
pub fn estimate_soc(
    clusters: &[(CoreStats, u64)],
    total_cycles: u64,
    l2_bytes: u64,
    class: ComputeClass,
    table: &EnergyTable,
    soc: &SocEnergyTable,
) -> EnergyReport {
    let mut pj = 0.0;
    let mut flops = 0u64;
    for (stats, _busy) in clusters {
        pj += dynamic_pj(stats, class, table);
        flops += stats.flops;
    }
    pj += clusters.len() as f64 * total_cycles as f64 * table.static_per_cycle;
    pj += l2_bytes as f64 * (soc.l2_per_byte + soc.interconnect_per_byte);
    pj += total_cycles as f64 * soc.l2_static_per_cycle;
    report(pj, flops as f64, total_cycles)
}

/// FPU-only peak efficiency for Table III's top rows: the op energy at
/// full utilization, no cluster overheads.
pub fn fpu_peak_gflops_per_w(class: ComputeClass, table: &EnergyTable) -> f64 {
    let (flop, pj) = match class {
        ComputeClass::Sdotp(OpWidth::BtoH) => (16.0, table.sdotp_btoh),
        ComputeClass::Sdotp(OpWidth::HtoS) => (8.0, table.sdotp_htos),
        ComputeClass::Fma(ScalarFmt::D) => (2.0, table.fma_d),
        ComputeClass::Fma(ScalarFmt::S) => (4.0, table.fma_s),
        ComputeClass::Fma(_) => (8.0, table.fma_h),
    };
    flop / (pj * 1e-12) / 1e9 / 1e9 * 1.0e9 // FLOP/op / (J/op) → FLOPS/W → GFLOPS/W
}

/// Peak throughput of one FPU in GFLOPS (Table III "Peak Throughput").
pub fn fpu_peak_gflops(class: ComputeClass) -> f64 {
    let flop_per_cycle = match class {
        ComputeClass::Sdotp(OpWidth::BtoH) => 16.0,
        ComputeClass::Sdotp(OpWidth::HtoS) => 8.0,
        ComputeClass::Fma(ScalarFmt::D) => 2.0,
        ComputeClass::Fma(ScalarFmt::S) => 4.0,
        ComputeClass::Fma(_) => 8.0,
    };
    flop_per_cycle * FREQ_GHZ
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fpu_peak_matches_table3() {
        let t = EnergyTable::default();
        // 16 FLOP/cycle × 1.26 GHz = 20.2 GFLOPS (exFP8 row).
        assert!((fpu_peak_gflops(ComputeClass::Sdotp(OpWidth::BtoH)) - 20.16).abs() < 0.01);
        // 1631 GFLOPS/W peak efficiency for exFP8.
        let eff = fpu_peak_gflops_per_w(ComputeClass::Sdotp(OpWidth::BtoH), &t);
        assert!((eff - 1632.0).abs() < 15.0, "peak eff {eff:.0}");
    }

    #[test]
    fn cluster_fp8_gemm_hits_575_gflops_per_w() {
        // Full-stack anchor: simulate the paper's headline workload
        // (128×256 FP8→FP16 GEMM) and check power/efficiency.
        use crate::kernels::{GemmKernel, GemmKind};
        let mut rng = crate::util::rng::Rng::new(3);
        let (m, n, k) = (128, 256, 128);
        let a: Vec<f64> = (0..m * k).map(|_| rng.gaussian() * 0.25).collect();
        let b: Vec<f64> = (0..k * n).map(|_| rng.gaussian() * 0.25).collect();
        let kern = GemmKernel::new(GemmKind::ExSdotp(OpWidth::BtoH), m, n, k);
        let run = kern.run(&a, &b);
        let rep = estimate(&run.stats, run.cycles, ComputeClass::Sdotp(OpWidth::BtoH), &EnergyTable::default());
        // §IV-C: 128 GFLOPS, 224 mW, 575 GFLOPS/W.
        assert!((rep.gflops - 128.0).abs() < 15.0, "GFLOPS {:.1}", rep.gflops);
        assert!((rep.avg_mw - 224.0).abs() < 35.0, "power {:.0} mW", rep.avg_mw);
        assert!((rep.gflops_per_w - 575.0).abs() < 60.0, "efficiency {:.0}", rep.gflops_per_w);
    }

    #[test]
    fn fpu_peak_is_the_exact_16_flop_over_9p8_pj_derivation() {
        // Calibration pin: the 1631 GFLOPS/W Table III figure is not a
        // tuned constant but the arithmetic 16 FLOP / 9.8 pJ. If either
        // the op energy or the derivation drifts, this fails exactly.
        let t = EnergyTable::default();
        assert_eq!(t.sdotp_btoh, 9.8, "exFP8 SDOTP op energy is the paper's 9.8 pJ");
        let eff = fpu_peak_gflops_per_w(ComputeClass::Sdotp(OpWidth::BtoH), &t);
        assert_eq!(eff, 16.0 / 9.8 * 1000.0, "derivation must be exactly FLOP/op ÷ pJ/op");
        assert!((eff - 1632.65).abs() < 0.01, "≈1631 GFLOPS/W anchor, got {eff:.2}");
    }

    #[test]
    fn anchor_gemm_cluster_power_derives_178_pj_per_cycle() {
        // The 575 GFLOPS/W anchor implies 224 mW at 1.26 GHz, i.e.
        // ≈177.8 pJ per cluster-cycle. Pin the simulated derivation:
        // avg_mw / FREQ_GHZ is pJ/cycle by construction.
        use crate::kernels::{GemmKernel, GemmKind};
        let mut rng = crate::util::rng::Rng::new(3);
        let (m, n, k) = (128, 256, 128);
        let a: Vec<f64> = (0..m * k).map(|_| rng.gaussian() * 0.25).collect();
        let b: Vec<f64> = (0..k * n).map(|_| rng.gaussian() * 0.25).collect();
        let run = GemmKernel::new(GemmKind::ExSdotp(OpWidth::BtoH), m, n, k).run(&a, &b);
        let rep = estimate(&run.stats, run.cycles, ComputeClass::Sdotp(OpWidth::BtoH), &EnergyTable::default());
        let pj_per_cycle = rep.avg_mw / FREQ_GHZ;
        assert!(
            (160.0..195.0).contains(&pj_per_cycle),
            "cluster power {pj_per_cycle:.1} pJ/cycle vs paper ≈177.8"
        );
    }

    #[test]
    fn cluster_region_of_one_is_identical_to_estimate() {
        // The N = 1 roofline column leans on this reduction being exact.
        use crate::kernels::{GemmKernel, GemmKind};
        let mut rng = crate::util::rng::Rng::new(6);
        let (m, n, k) = (64, 64, 64);
        let a: Vec<f64> = (0..m * k).map(|_| rng.gaussian() * 0.25).collect();
        let b: Vec<f64> = (0..k * n).map(|_| rng.gaussian() * 0.25).collect();
        let run = GemmKernel::new(GemmKind::ExSdotp(OpWidth::BtoH), m, n, k).run(&a, &b);
        let t = EnergyTable::default();
        let one = estimate(&run.stats, run.cycles, ComputeClass::Sdotp(OpWidth::BtoH), &t);
        let reg = estimate_cluster_region(
            &[(run.stats, run.cycles)],
            ComputeClass::Sdotp(OpWidth::BtoH),
            &t,
        );
        assert_eq!(one.gflops_per_w.to_bits(), reg.gflops_per_w.to_bits());
        assert_eq!(one.avg_mw.to_bits(), reg.avg_mw.to_bits());
        assert_eq!(one.total_uj.to_bits(), reg.total_uj.to_bits());
    }

    #[test]
    fn soc_estimate_charges_l2_and_idle_static_on_top() {
        // SoC efficiency must be strictly below the compute-region
        // figure: same flops, extra L2/interconnect/static energy.
        use crate::kernels::{GemmKernel, GemmKind};
        let mut rng = crate::util::rng::Rng::new(7);
        let (m, n, k) = (64, 64, 64);
        let a: Vec<f64> = (0..m * k).map(|_| rng.gaussian() * 0.25).collect();
        let b: Vec<f64> = (0..k * n).map(|_| rng.gaussian() * 0.25).collect();
        let run = GemmKernel::new(GemmKind::ExSdotp(OpWidth::BtoH), m, n, k).run(&a, &b);
        let t = EnergyTable::default();
        let soc_t = SocEnergyTable::default();
        let per = [(run.stats, run.cycles)];
        let reg = estimate_cluster_region(&per, ComputeClass::Sdotp(OpWidth::BtoH), &t);
        let soc = estimate_soc(
            &per,
            run.cycles + 200, // wall clock includes DMA fill/drain
            (m * k + k * n + m * n * 2) as u64,
            ComputeClass::Sdotp(OpWidth::BtoH),
            &t,
            &soc_t,
        );
        assert!(soc.gflops_per_w < reg.gflops_per_w);
        assert!(soc.gflops_per_w > 0.25 * reg.gflops_per_w, "L2 terms should tax, not dominate");
    }

    #[test]
    fn fp64_reference_efficiency_near_snitch_80() {
        use crate::kernels::{GemmKernel, GemmKind};
        let mut rng = crate::util::rng::Rng::new(4);
        let (m, n, k) = (64, 64, 64);
        let a: Vec<f64> = (0..m * k).map(|_| rng.gaussian()).collect();
        let b: Vec<f64> = (0..k * n).map(|_| rng.gaussian()).collect();
        let kern = GemmKernel::new(GemmKind::FmaF64, m, n, k);
        let run = kern.run(&a, &b);
        let rep = estimate(&run.stats, run.cycles, ComputeClass::Fma(ScalarFmt::D), &EnergyTable::default());
        assert!((60.0..100.0).contains(&rep.gflops_per_w), "FP64 eff {:.0}", rep.gflops_per_w);
    }

    #[test]
    fn efficiency_ratio_fp8_vs_fp64_near_7x() {
        use crate::kernels::{GemmKernel, GemmKind};
        let mut rng = crate::util::rng::Rng::new(5);
        let mut mk = |kind, m: usize, n: usize, k: usize, class| {
            let a: Vec<f64> = (0..m * k).map(|_| rng.gaussian() * 0.25).collect();
            let b: Vec<f64> = (0..k * n).map(|_| rng.gaussian() * 0.25).collect();
            let run = GemmKernel::new(kind, m, n, k).run(&a, &b);
            estimate(&run.stats, run.cycles, class, &EnergyTable::default()).gflops_per_w
        };
        let fp8 = mk(GemmKind::ExSdotp(OpWidth::BtoH), 128, 256, 128, ComputeClass::Sdotp(OpWidth::BtoH));
        let fp64 = mk(GemmKind::FmaF64, 64, 64, 64, ComputeClass::Fma(ScalarFmt::D));
        let ratio = fp8 / fp64;
        assert!((5.5..9.0).contains(&ratio), "ratio {ratio:.1} (paper: 7.2)");
    }
}
