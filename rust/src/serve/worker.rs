//! The wave scheduler, shard pool and the [`Server`] driving them.
//!
//! ## Execution model: layer waves
//!
//! The unit of scheduling is a **cohort**: up to `max_batch` requests
//! admitted together at a layer-0 boundary, carried as one lane-padded
//! activation matrix. Every tick, *each* in-flight cohort advances
//! exactly one layer (one **wave**); a cohort that has cleared its
//! last layer completes one service quantum later. Under
//! [`BatchMode::Continuous`] a fresh cohort is admitted every tick a
//! queue is non-empty — new requests join at the next layer-0 boundary
//! and pipeline *alongside* the cohorts already in flight, so nobody
//! waits for the previous batch to drain. Under
//! [`BatchMode::WholeBatch`] (the legacy reference) a tenant admits
//! only when its pipeline is empty, reproducing the old
//! run-to-completion timing on the same wave engine.
//!
//! ## Shards
//!
//! A [`Shard`] is one parallel execution lane: it owns **one
//! persistent [`GemmCtx`] per tenant** — compiled
//! [`crate::api::PlanInstance`]s (pre-warmed for the boundary padded
//! batch shapes at assembly, cached thereafter) plus reusable
//! workspaces and scratch — so a steady-state wave re-plans nothing
//! and allocates nothing beyond the cohort's own activation buffer.
//! Wave jobs spread round-robin over the pool in formation order (so
//! even one tenant's pipelined cohorts saturate every shard). The
//! fan-out rides per-tick scoped threads (control plane), while every
//! GEMM inside a shard dispatches to the persistent
//! [`crate::util::parallel`] executor pool.
//!
//! ## Determinism
//!
//! Scheduling decisions — admission, wave composition, shard routing —
//! are made by the [`Server`] *before* the fan-out, and each output
//! row of a GEMM depends only on its own input row, so shards are a
//! pure wall-clock parallelism vehicle: per-request responses are
//! identical at any shard count, and — because per-row independence
//! also holds across *batch composition* — identical between
//! continuous, whole-batch, and batch-of-1 scheduling (pinned by
//! `tests/serve_differential.rs`). The per-tick response stream is
//! sorted by request id to keep the observable ordering schedule
//! independent too.
//!
//! ## Admission control
//!
//! In front of the scheduler, [`Server::try_submit`] applies
//! backpressure deterministically: a bounded per-tenant queue
//! (`queue_cap`) and a per-tenant token bucket
//! ([`crate::serve::admission::TokenBucket`]) shed with a typed
//! [`Admission::Shed`] instead of queueing unboundedly.

use crate::api::Session;
use crate::nn::engine::GemmCtx;
use crate::util::error::{Error, Result};
use crate::util::parallel::par_chunks_mut;
use crate::{bail, ensure};

use super::admission::{Admission, RateLimit, ShedReason, TokenBucket};
use super::batcher::{
    pad_rows, pipeline_latency_ticks, BatchMode, BatchPolicy, ROW_PAD, SERVICE_TICKS,
};
use super::model::InferenceModel;
use super::queue::{Request, Response, TenantQueue};
use super::stats::ServeStats;

/// One named tenant: a frozen model served under its own precision
/// policy, isolated from every other tenant's traffic by its queue.
#[derive(Clone, Debug)]
pub struct Tenant {
    /// Human-readable tenant name (unique per server).
    pub name: String,
    /// The tenant's frozen model.
    pub model: InferenceModel,
}

/// One in-flight batch: requests admitted together at a layer-0
/// boundary plus their current activation matrix. Advances one layer
/// per wave; owned by the server between waves, loaned to a shard
/// during one.
#[derive(Debug)]
struct Cohort {
    /// Tenant index.
    tenant: usize,
    /// Next layer to execute (== layers.len() when done).
    layer: usize,
    /// Logical rows (requests), before lane padding.
    size: usize,
    /// The member requests, in id order (row i belongs to reqs[i]).
    reqs: Vec<Request>,
    /// Current activations, `pad_rows(size) × current-layer-in_dim`
    /// row-major. Padding rows start zero and ride along — per-row
    /// independence keeps them bit-invisible to the real rows.
    acts: Vec<f64>,
    /// Global formation sequence number: the deterministic shard
    /// routing and re-insertion key.
    seq: u64,
}

/// One parallel execution lane of the pool: persistent per-tenant GEMM
/// contexts plus reusable per-wave scratch.
#[derive(Debug)]
pub struct Shard {
    inbox: Vec<Cohort>,
    done: Vec<Cohort>,
    /// Per-tenant (gemm_calls, packed_runs) accumulated this tick.
    counters: Vec<(u64, u64)>,
    /// One persistent context per tenant: compiled plan instances and
    /// workspaces reused across waves.
    ctxs: Vec<GemmCtx>,
    /// Reused wave-output scratch (swapped into the cohort after each
    /// wave, so the cohort always owns its current activations).
    scratch: Vec<f64>,
    /// Recycled quantized-input word storage.
    xt_pool: Vec<u64>,
    error: Option<Error>,
}

impl Shard {
    fn new(session: Session, tenants: &[Tenant], policy: &BatchPolicy) -> Self {
        let mut ctxs: Vec<GemmCtx> =
            tenants.iter().map(|t| GemmCtx::new(&session, t.model.policy().acc)).collect();
        // Pre-warm the per-layer plan instances at the boundary padded
        // batch shapes (the same shapes the ServePlan probe proved
        // buildable — warm errors are therefore unreachable, and a
        // hypothetical one would just fall back to lazy compilation on
        // first dispatch). Intermediate padded sizes compile lazily and
        // stay cached.
        for (t, ctx) in tenants.iter().zip(&mut ctxs) {
            for rows in [ROW_PAD, pad_rows(policy.max_batch)] {
                for l in t.model.layers() {
                    let _ = ctx.warm(t.model.policy().fwd, rows, l.out_dim, l.in_dim);
                }
            }
        }
        Shard {
            inbox: Vec::new(),
            done: Vec::new(),
            counters: vec![(0, 0); tenants.len()],
            ctxs,
            scratch: Vec::new(),
            xt_pool: Vec::new(),
            error: None,
        }
    }

    /// `(plan_builds, plan_reuses)` summed over this shard's tenant
    /// contexts.
    fn plan_counters(&self) -> (u64, u64) {
        self.ctxs.iter().fold((0, 0), |(b, r), c| (b + c.plan_builds, r + c.plan_reuses))
    }

    /// Execute every wave job in the inbox (called from the parallel
    /// fan-out; errors are parked and surfaced after the join).
    fn run_waves(&mut self, tenants: &[Tenant]) {
        let inbox = std::mem::take(&mut self.inbox);
        for mut cohort in inbox {
            match self.advance(&tenants[cohort.tenant], &mut cohort) {
                Ok(()) => self.done.push(cohort),
                Err(e) => {
                    self.error = Some(e);
                    return;
                }
            }
        }
    }

    /// Run one wave: advance a cohort through its next layer on the
    /// tenant's persistent context, swapping the shard scratch in as
    /// the cohort's new activation buffer.
    fn advance(&mut self, tenant: &Tenant, cohort: &mut Cohort) -> Result<()> {
        let rows = pad_rows(cohort.size);
        tenant.model.forward_layer_into(
            &mut self.ctxs[cohort.tenant],
            cohort.layer,
            &cohort.acts,
            rows,
            &mut self.scratch,
            &mut self.xt_pool,
        )?;
        std::mem::swap(&mut cohort.acts, &mut self.scratch);
        let (calls, packed) = self.ctxs[cohort.tenant].take_counters();
        self.counters[cohort.tenant].0 += calls;
        self.counters[cohort.tenant].1 += packed;
        cohort.layer += 1;
        Ok(())
    }
}

/// The multi-tenant batched inference server.
///
/// Construct through the typed front door —
/// [`crate::api::Session::server`] →
/// [`crate::api::ServePlanBuilder::build`] →
/// [`crate::api::ServePlan::server`] — which validates tenants, knobs
/// and per-layer GEMM feasibility before this type exists.
pub struct Server {
    tenants: Vec<Tenant>,
    queues: Vec<TenantQueue>,
    shards: Vec<Shard>,
    policy: BatchPolicy,
    stats: ServeStats,
    /// Per-tenant in-flight cohorts, ordered by formation sequence.
    inflight: Vec<Vec<Cohort>>,
    /// Per-tenant token buckets (None = unlimited).
    buckets: Vec<Option<TokenBucket>>,
    /// Bounded-queue cap (None = unbounded).
    queue_cap: Option<usize>,
    now: u64,
    next_id: u64,
    next_cohort: u64,
}

impl Server {
    /// Wire a validated configuration (done by
    /// [`crate::api::ServePlan::server`]).
    pub(crate) fn assemble(
        session: Session,
        tenants: Vec<Tenant>,
        policy: BatchPolicy,
        n_shards: usize,
        queue_cap: Option<usize>,
        limits: Vec<Option<RateLimit>>,
    ) -> Self {
        let n_tenants = tenants.len();
        let shards = (0..n_shards).map(|_| Shard::new(session, &tenants, &policy)).collect();
        Server {
            queues: (0..n_tenants).map(|_| TenantQueue::new()).collect(),
            shards,
            stats: ServeStats::new(n_tenants),
            inflight: (0..n_tenants).map(|_| Vec::new()).collect(),
            buckets: limits.into_iter().map(|l| l.map(TokenBucket::new)).collect(),
            queue_cap,
            tenants,
            policy,
            now: 0,
            next_id: 0,
            next_cohort: 0,
        }
    }

    /// The tenant table (index = the id [`Server::submit`] takes).
    pub fn tenants(&self) -> &[Tenant] {
        &self.tenants
    }

    /// Look a tenant up by name.
    pub fn tenant_index(&self, name: &str) -> Option<usize> {
        self.tenants.iter().position(|t| t.name == name)
    }

    /// Current virtual tick.
    pub fn now(&self) -> u64 {
        self.now
    }

    /// Requests parked across all tenant queues (not yet admitted to a
    /// cohort).
    pub fn pending(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    /// Requests riding in-flight cohorts (admitted, not yet completed).
    pub fn in_flight(&self) -> usize {
        self.inflight.iter().map(|v| v.iter().map(|c| c.size).sum::<usize>()).sum()
    }

    /// Accumulated statistics.
    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }

    /// Shards in the pool.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// `(plan_builds, plan_reuses)` summed over every shard's
    /// per-tenant contexts — how many GEMM executions compiled a plan
    /// instance vs reused one. After the warm-up shapes are covered,
    /// builds stay flat while reuses track traffic (asserted by tests;
    /// intentionally *not* part of [`ServeStats::summary_json`], since
    /// builds scale with the shard count while the stats JSON is
    /// pinned shard-count independent).
    pub fn plan_counters(&self) -> (u64, u64) {
        self.shards.iter().fold((0, 0), |(b, r), s| {
            let (sb, sr) = s.plan_counters();
            (b + sb, r + sr)
        })
    }

    /// The tenant's end-to-end pipeline latency in ticks (one wave per
    /// layer plus the service quantum).
    fn depth_ticks(&self, tenant: usize) -> u64 {
        pipeline_latency_ticks(self.tenants[tenant].model.layers().len())
    }

    /// Submit a request through admission control: the bounded queue
    /// and the tenant's token bucket may **shed** it (a typed
    /// [`Admission::Shed`], not an error — nothing is enqueued and the
    /// shed is counted). A malformed submission (unknown tenant, wrong
    /// feature width) is still a typed error. The queue-cap check runs
    /// first so a full queue does not burn bucket tokens.
    pub fn try_submit(
        &mut self,
        tenant: usize,
        features: Vec<f64>,
        deadline_in: Option<u64>,
    ) -> Result<Admission> {
        let Some(t) = self.tenants.get(tenant) else {
            bail!("unknown tenant index {tenant} (server has {})", self.tenants.len());
        };
        ensure!(
            features.len() == t.model.in_dim(),
            "tenant '{}' consumes {} features, got {}",
            t.name,
            t.model.in_dim(),
            features.len()
        );
        if let Some(cap) = self.queue_cap {
            if self.queues[tenant].len() >= cap {
                self.stats.record_shed(ShedReason::QueueFull);
                return Ok(Admission::Shed(ShedReason::QueueFull));
            }
        }
        if let Some(bucket) = &mut self.buckets[tenant] {
            if !bucket.try_take(self.now) {
                self.stats.record_shed(ShedReason::RateLimited);
                return Ok(Admission::Shed(ShedReason::RateLimited));
            }
        }
        let id = self.next_id;
        self.next_id += 1;
        self.queues[tenant].push(Request {
            id,
            tenant,
            features,
            arrival_tick: self.now,
            // Saturate: a deadline near u64::MAX means "effectively
            // never due", not an overflow panic.
            deadline_tick: deadline_in.map(|d| self.now.saturating_add(d)),
        });
        self.stats.submitted += 1;
        crate::obs_count!("serve.submitted");
        Ok(Admission::Admitted(id))
    }

    /// Enqueue a request for `tenant`, due `deadline_in` ticks from now
    /// if set. Returns the assigned request id (monotone in submission
    /// order — the id responses are keyed and sorted by). A shed
    /// submission is an error here; callers that want to react to
    /// backpressure use [`Server::try_submit`].
    pub fn submit(
        &mut self,
        tenant: usize,
        features: Vec<f64>,
        deadline_in: Option<u64>,
    ) -> Result<u64> {
        match self.try_submit(tenant, features, deadline_in)? {
            Admission::Admitted(id) => Ok(id),
            Admission::Shed(reason) => bail!(
                "request for tenant {tenant} shed ({reason}); use try_submit to handle \
                 backpressure"
            ),
        }
    }

    /// Admit queued requests into fresh layer-0 cohorts, per the mode:
    /// Continuous admits up to `max_batch` SLO-weighted rows per tenant
    /// every tick; WholeBatch admits (FIFO) only when the tenant's
    /// pipeline is empty and a size/wait/deadline trigger fires.
    fn admit(&mut self) {
        let now = self.now;
        for t in 0..self.tenants.len() {
            let batch = match self.policy.mode {
                BatchMode::Continuous => {
                    if self.queues[t].is_empty() {
                        continue;
                    }
                    self.queues[t].take_prioritized(self.policy.max_batch)
                }
                BatchMode::WholeBatch => {
                    if !self.inflight[t].is_empty() {
                        continue;
                    }
                    let lead = self.depth_ticks(t);
                    if !self.policy.should_dispatch(&self.queues[t], now, lead) {
                        continue;
                    }
                    self.queues[t].take(self.policy.max_batch)
                }
            };
            let size = batch.len();
            self.stats.record_batch(size);
            // Virtual-ticks clock: one span per admitted cohort at the
            // tick it leaves the queue (tid = tenant index).
            crate::obs::trace::virt_span(
                crate::obs::trace::Clock::Ticks,
                t as u64,
                "serve.dispatch",
                "serve",
                now,
                1,
                || format!("\"tenant\":{t},\"batch\":{size},\"tick\":{now}"),
            );
            let in_dim = self.tenants[t].model.in_dim();
            let rows = pad_rows(size);
            let mut acts = vec![0f64; rows * in_dim];
            for (i, r) in batch.iter().enumerate() {
                acts[i * in_dim..(i + 1) * in_dim].copy_from_slice(&r.features);
            }
            let seq = self.next_cohort;
            self.next_cohort += 1;
            self.inflight[t].push(Cohort { tenant: t, layer: 0, size, reqs: batch, acts, seq });
        }
    }

    /// Turn a completed cohort into per-request responses.
    fn finish(tenants: &[Tenant], cohort: Cohort, now: u64, out: &mut Vec<Response>) {
        let Cohort { tenant, size, reqs, acts, .. } = cohort;
        let model = &tenants[tenant].model;
        let w = model.out_dim();
        let classes = model.classes();
        // Results are ready one service quantum after the final wave;
        // the quantum is uniform, so completion ticks are shard- and
        // schedule-independent given the admission tick.
        let done = now.saturating_add(SERVICE_TICKS);
        for (i, r) in reqs.into_iter().enumerate() {
            let row = acts[i * w..(i + 1) * w].to_vec();
            let pred = row[..classes]
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
                .map(|(j, _)| j)
                .unwrap_or(0);
            out.push(Response {
                id: r.id,
                tenant,
                logits: row,
                pred,
                arrival_tick: r.arrival_tick,
                completion_tick: done,
                batch_size: size,
                deadline_missed: r.deadline_tick.map(|d| done > d).unwrap_or(false),
            });
        }
    }

    /// Advance virtual time by one tick: sample queue depths, admit
    /// fresh cohorts at the layer-0 boundary, run one wave for *every*
    /// in-flight cohort over the shard pool, and return the responses
    /// of cohorts that cleared their last layer, sorted by request id.
    pub fn tick(&mut self) -> Result<Vec<Response>> {
        self.stats.record_depth(self.pending());
        self.admit();
        // Wave formation is global and precedes the fan-out, so the
        // schedule is independent of the shard count. Jobs spread
        // round-robin in formation order — keyed by a job counter, not
        // the tenant index, so even a single tenant's pipelined cohorts
        // use the whole pool.
        let n_shards = self.shards.len();
        let mut any = false;
        let mut job_no = 0usize;
        for t in 0..self.tenants.len() {
            for cohort in self.inflight[t].drain(..) {
                let (now, layer, size) = (self.now, cohort.layer, cohort.size);
                self.stats.record_wave(size);
                crate::obs::trace::virt_span(
                    crate::obs::trace::Clock::Ticks,
                    t as u64,
                    "serve.wave",
                    "serve",
                    now,
                    1,
                    || format!("\"tenant\":{t},\"layer\":{layer},\"rows\":{size},\"tick\":{now}"),
                );
                self.shards[job_no % n_shards].inbox.push(cohort);
                job_no += 1;
                any = true;
            }
        }
        let mut responses = Vec::new();
        if any {
            let tenants: &[Tenant] = &self.tenants;
            // The shard fan-out runs on per-tick scoped threads, NOT on
            // the executor pool: pool workers run nested dispatch
            // inline, so parking shards on the pool would serialize
            // every GEMM inside a shard and idle the remaining cores
            // whenever shards < cores. Scoped threads here are control
            // plane (at most `shards` spawns per dispatching tick);
            // each shard re-pins the *ambient* dispatch mode before its
            // GEMMs, so production stays on the persistent pool and a
            // caller-pinned mode (the differential tests, a sanitizer
            // run under Serial) governs the in-shard numerics even
            // across the spawn boundary. The Scoped override applies
            // only when the fan-out will actually spawn — an inline
            // fan-out (one shard, or a 1-wide budget) must not be
            // kicked back onto per-call thread churn.
            use crate::util::parallel::{dispatch_mode, with_dispatch, worker_count, Dispatch};
            let ambient = dispatch_mode();
            let fanout = |shards: &mut [Shard]| {
                par_chunks_mut(shards, 1, |_, s| {
                    with_dispatch(ambient, || s[0].run_waves(tenants))
                });
            };
            // An ambient Serial pin means "single-threaded, period"
            // (bisecting, sanitizers): honor it instead of spawning.
            if self.shards.len() > 1 && worker_count() > 1 && ambient != Dispatch::Serial {
                with_dispatch(Dispatch::Scoped, || fanout(&mut self.shards));
            } else {
                fanout(&mut self.shards);
            }
            let mut advanced: Vec<Cohort> = Vec::new();
            for shard in &mut self.shards {
                if let Some(e) = shard.error.take() {
                    return Err(e);
                }
                advanced.append(&mut shard.done);
                for (t, (calls, packed)) in shard.counters.iter_mut().enumerate() {
                    self.stats.tenants[t].gemm_calls += *calls;
                    self.stats.tenants[t].packed_runs += *packed;
                    if crate::obs::metrics::enabled() && (*calls != 0 || *packed != 0) {
                        let name = &self.tenants[t].name;
                        crate::obs::metrics::counter_add(
                            &format!("serve.tenant.{name}.gemm_calls"),
                            *calls,
                        );
                        crate::obs::metrics::counter_add(
                            &format!("serve.tenant.{name}.packed_runs"),
                            *packed,
                        );
                    }
                    *calls = 0;
                    *packed = 0;
                }
            }
            // Re-insert in formation order: the deterministic schedule
            // key, independent of which shard ran which wave.
            advanced.sort_by_key(|c| c.seq);
            let now = self.now;
            for cohort in advanced {
                if cohort.layer == self.tenants[cohort.tenant].model.layers().len() {
                    Self::finish(&self.tenants, cohort, now, &mut responses);
                } else {
                    self.inflight[cohort.tenant].push(cohort);
                }
            }
            responses.sort_by_key(|r| r.id);
            for r in &responses {
                self.stats.record_response(r);
            }
        }
        self.now += 1;
        self.stats.ticks = self.now;
        crate::obs_gauge_max!("serve.ticks", self.now);
        Ok(responses)
    }

    /// The earliest tick at which the scheduler has work: `Some(now)`
    /// when a cohort is in flight (a wave runs every tick) or a queue
    /// can admit right now, the nearest future wait/deadline trigger
    /// otherwise (WholeBatch), `None` when fully idle.
    fn next_dispatch_tick(&self) -> Option<u64> {
        if self.inflight.iter().any(|v| !v.is_empty()) {
            return Some(self.now);
        }
        let mut next: Option<u64> = None;
        for (t, q) in self.queues.iter().enumerate() {
            if q.is_empty() {
                continue;
            }
            // Continuous admission is greedy: a non-empty queue admits
            // at the very next tick.
            if self.policy.mode == BatchMode::Continuous {
                return Some(self.now);
            }
            let lead = self.depth_ticks(t);
            if self.policy.should_dispatch(q, self.now, lead) {
                return Some(self.now);
            }
            // should_dispatch was false, so both triggers are strictly
            // in the future (and the size trigger needs a new arrival,
            // which only the caller can produce).
            let mut tick = q
                .oldest_arrival()
                .map(|a| a.saturating_add(self.policy.max_wait_ticks))
                .unwrap_or(u64::MAX);
            if let Some(d) = q.earliest_deadline() {
                tick = tick.min(d.saturating_sub(lead));
            }
            next = Some(next.map_or(tick, |n: u64| n.min(tick)));
        }
        next
    }

    /// Fast-forward to `cap` or the next tick with schedulable work,
    /// whichever is earlier — observably identical to ticking through
    /// the skipped quiet ticks one by one (each would sample the same
    /// queue depth and dispatch nothing) but O(1). Never skips while a
    /// cohort is in flight (a wave runs every tick). Keeps sparse-trace
    /// replay and large `max_wait_ticks` drains O(events) instead of
    /// O(tick span). Returns the new current tick.
    pub fn advance_to(&mut self, cap: u64) -> u64 {
        let target = match self.next_dispatch_tick() {
            Some(t) => t.min(cap),
            None => cap,
        };
        if target > self.now {
            self.stats.record_quiet(target - self.now, self.pending());
            self.now = target;
            self.stats.ticks = self.now;
            crate::obs_gauge_max!("serve.ticks", self.now);
        }
        self.now
    }

    /// Tick until every queue is empty and every cohort has completed,
    /// collecting the responses. Progress is guaranteed: each tick with
    /// work either admits a cohort or advances every in-flight cohort
    /// one layer, and quiet stretches fast-forward in O(1).
    pub fn drain(&mut self) -> Result<Vec<Response>> {
        let mut out = Vec::new();
        let max_lat = self
            .tenants
            .iter()
            .map(|t| pipeline_latency_ticks(t.model.layers().len()))
            .max()
            .unwrap_or(SERVICE_TICKS);
        // Worst case is WholeBatch batch-of-1: each remaining request
        // occupies the pipeline for a full latency, serially, after at
        // most `max_wait_ticks` of queueing — anything beyond that
        // bound is a scheduler bug, not a slow drain.
        let work = (self.pending() + self.in_flight()) as u64;
        let bound = self
            .now
            .saturating_add(self.policy.max_wait_ticks)
            .saturating_add(work.max(1).saturating_mul(max_lat + 1))
            .saturating_add(max_lat + 1);
        while self.pending() > 0 || self.in_flight() > 0 {
            self.advance_to(bound);
            out.append(&mut self.tick()?);
            ensure!(
                (self.pending() == 0 && self.in_flight() == 0) || self.now <= bound,
                "server failed to drain within the wait bound (a scheduler bug)"
            );
        }
        Ok(out)
    }
}
