//! The shard pool and the [`Server`] driving it.
//!
//! A [`Shard`] is one parallel execution lane: it owns **one
//! persistent [`GemmCtx`] per tenant** — compiled
//! [`crate::api::PlanInstance`]s (pre-warmed for the boundary padded
//! batch shapes at assembly, cached thereafter) plus reusable
//! workspaces — and per-dispatch buffers (padded input, logits,
//! ping-pong scratch, quantized-input words), so a steady-state
//! dispatch re-plans nothing and allocates nothing. Plan execution and
//! routing counters never share mutable state across shards. Batches
//! spread round-robin over the pool in formation order (so even one
//! tenant saturates every shard). The shard fan-out itself rides
//! per-tick scoped threads (control plane — at most `shards` spawns
//! per dispatching tick), while every GEMM inside a shard dispatches
//! to the persistent [`crate::util::parallel`] executor pool, so the
//! numeric hot path uses the whole machine even when `shards` is
//! smaller than the core count.
//!
//! **Determinism.** Scheduling decisions (batch formation, dispatch
//! ticks) are made by the [`Server`] *before* the fan-out, and each
//! output row of a GEMM depends only on its own input row, so shards
//! are a pure wall-clock parallelism vehicle: per-request responses —
//! logits bits, ticks, batch sizes — are identical at any shard count.
//! The per-tick response stream is sorted by request id to keep the
//! observable ordering shard-count independent too. Reused contexts
//! and buffers carry capacity, never values, so reuse is bit-invisible
//! (pinned by the dispatch-mode and shard-count differential tests).

use crate::api::Session;
use crate::nn::engine::GemmCtx;
use crate::util::error::{Error, Result};
use crate::util::parallel::par_chunks_mut;
use crate::{bail, ensure};

use super::batcher::{pad_rows, BatchPolicy, ROW_PAD, SERVICE_TICKS};
use super::model::InferenceModel;
use super::queue::{Request, Response, TenantQueue};
use super::stats::ServeStats;

/// One named tenant: a frozen model served under its own precision
/// policy, isolated from every other tenant's traffic by its queue.
#[derive(Clone, Debug)]
pub struct Tenant {
    /// Human-readable tenant name (unique per server).
    pub name: String,
    /// The tenant's frozen model.
    pub model: InferenceModel,
}

/// One parallel execution lane of the pool: persistent per-tenant GEMM
/// contexts plus reusable per-dispatch buffers.
#[derive(Debug)]
pub struct Shard {
    inbox: Vec<(usize, Vec<Request>)>,
    outbox: Vec<Response>,
    /// Per-tenant (gemm_calls, packed_runs) accumulated this tick.
    counters: Vec<(u64, u64)>,
    /// One persistent context per tenant: compiled plan instances and
    /// workspaces reused across dispatches.
    ctxs: Vec<GemmCtx>,
    /// Reused padded-input buffer.
    x: Vec<f64>,
    /// Reused logits buffer.
    logits: Vec<f64>,
    /// Reused inter-layer ping-pong scratch.
    scratch: Vec<f64>,
    /// Recycled quantized-input word storage.
    xt_pool: Vec<u64>,
    error: Option<Error>,
}

impl Shard {
    fn new(session: Session, tenants: &[Tenant], policy: &BatchPolicy) -> Self {
        let mut ctxs: Vec<GemmCtx> =
            tenants.iter().map(|t| GemmCtx::new(&session, t.model.policy().acc)).collect();
        // Pre-warm the per-layer plan instances at the boundary padded
        // batch shapes (the same shapes the ServePlan probe proved
        // buildable — warm errors are therefore unreachable, and a
        // hypothetical one would just fall back to lazy compilation on
        // first dispatch). Intermediate padded sizes compile lazily and
        // stay cached.
        for (t, ctx) in tenants.iter().zip(&mut ctxs) {
            for rows in [ROW_PAD, pad_rows(policy.max_batch)] {
                for l in t.model.layers() {
                    let _ = ctx.warm(t.model.policy().fwd, rows, l.out_dim, l.in_dim);
                }
            }
        }
        Shard {
            inbox: Vec::new(),
            outbox: Vec::new(),
            counters: vec![(0, 0); tenants.len()],
            ctxs,
            x: Vec::new(),
            logits: Vec::new(),
            scratch: Vec::new(),
            xt_pool: Vec::new(),
            error: None,
        }
    }

    /// `(plan_builds, plan_reuses)` summed over this shard's tenant
    /// contexts.
    fn plan_counters(&self) -> (u64, u64) {
        self.ctxs.iter().fold((0, 0), |(b, r), c| (b + c.plan_builds, r + c.plan_reuses))
    }

    /// Execute every batch in the inbox (called from the parallel
    /// fan-out; errors are parked and surfaced after the join).
    fn run_inbox(&mut self, tenants: &[Tenant], now: u64) {
        let inbox = std::mem::take(&mut self.inbox);
        for (t, batch) in inbox {
            match self.execute(&tenants[t], t, batch, now) {
                Ok(mut responses) => self.outbox.append(&mut responses),
                Err(e) => {
                    self.error = Some(e);
                    return;
                }
            }
        }
    }

    /// Run one tenant batch: pad rows to the kernel granularity, one
    /// forward pass on the tenant's persistent context and the shard's
    /// reused buffers, slice the logical rows back out.
    fn execute(
        &mut self,
        tenant: &Tenant,
        t: usize,
        batch: Vec<Request>,
        now: u64,
    ) -> Result<Vec<Response>> {
        let model = &tenant.model;
        let size = batch.len();
        let rows = pad_rows(size);
        let in_dim = model.in_dim();
        self.x.clear();
        self.x.resize(rows * in_dim, 0f64);
        for (i, r) in batch.iter().enumerate() {
            ensure!(
                r.features.len() == in_dim,
                "request {} for tenant '{}' has {} features, the model consumes {in_dim}",
                r.id,
                tenant.name,
                r.features.len()
            );
            self.x[i * in_dim..(i + 1) * in_dim].copy_from_slice(&r.features);
        }
        let ctx = &mut self.ctxs[t];
        model.forward_into(ctx, &self.x, rows, &mut self.logits, &mut self.scratch, &mut self.xt_pool)?;
        let (calls, packed) = ctx.take_counters();
        self.counters[t].0 += calls;
        self.counters[t].1 += packed;
        let w = model.out_dim();
        let classes = model.classes();
        // Results are ready one service quantum after dispatch; the
        // quantum is uniform, so completion ticks are shard-independent.
        let done = now.saturating_add(SERVICE_TICKS);
        Ok(batch
            .into_iter()
            .enumerate()
            .map(|(i, r)| {
                let row = self.logits[i * w..(i + 1) * w].to_vec();
                let pred = row[..classes]
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
                    .map(|(j, _)| j)
                    .unwrap_or(0);
                Response {
                    id: r.id,
                    tenant: t,
                    logits: row,
                    pred,
                    arrival_tick: r.arrival_tick,
                    completion_tick: done,
                    batch_size: size,
                    deadline_missed: r.deadline_tick.map(|d| done > d).unwrap_or(false),
                }
            })
            .collect())
    }
}

/// The multi-tenant batched inference server.
///
/// Construct through the typed front door —
/// [`crate::api::Session::server`] →
/// [`crate::api::ServePlanBuilder::build`] →
/// [`crate::api::ServePlan::server`] — which validates tenants, knobs
/// and per-layer GEMM feasibility before this type exists.
pub struct Server {
    tenants: Vec<Tenant>,
    queues: Vec<TenantQueue>,
    shards: Vec<Shard>,
    policy: BatchPolicy,
    stats: ServeStats,
    now: u64,
    next_id: u64,
}

impl Server {
    /// Wire a validated configuration (done by
    /// [`crate::api::ServePlan::server`]).
    pub(crate) fn assemble(
        session: Session,
        tenants: Vec<Tenant>,
        policy: BatchPolicy,
        n_shards: usize,
    ) -> Self {
        let n_tenants = tenants.len();
        let shards = (0..n_shards).map(|_| Shard::new(session, &tenants, &policy)).collect();
        Server {
            queues: (0..n_tenants).map(|_| TenantQueue::new()).collect(),
            shards,
            stats: ServeStats::new(n_tenants),
            tenants,
            policy,
            now: 0,
            next_id: 0,
        }
    }

    /// The tenant table (index = the id [`Server::submit`] takes).
    pub fn tenants(&self) -> &[Tenant] {
        &self.tenants
    }

    /// Look a tenant up by name.
    pub fn tenant_index(&self, name: &str) -> Option<usize> {
        self.tenants.iter().position(|t| t.name == name)
    }

    /// Current virtual tick.
    pub fn now(&self) -> u64 {
        self.now
    }

    /// Requests parked across all tenant queues.
    pub fn pending(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    /// Accumulated statistics.
    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }

    /// Shards in the pool.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// `(plan_builds, plan_reuses)` summed over every shard's
    /// per-tenant contexts — how many GEMM executions compiled a plan
    /// instance vs reused one. After the warm-up shapes are covered,
    /// builds stay flat while reuses track traffic (asserted by tests;
    /// intentionally *not* part of [`ServeStats::summary_json`], since
    /// builds scale with the shard count while the stats JSON is
    /// pinned shard-count independent).
    pub fn plan_counters(&self) -> (u64, u64) {
        self.shards.iter().fold((0, 0), |(b, r), s| {
            let (sb, sr) = s.plan_counters();
            (b + sb, r + sr)
        })
    }

    /// Enqueue a request for `tenant`, due `deadline_in` ticks from now
    /// if set. Returns the assigned request id (monotone in submission
    /// order — the id responses are keyed and sorted by).
    pub fn submit(
        &mut self,
        tenant: usize,
        features: Vec<f64>,
        deadline_in: Option<u64>,
    ) -> Result<u64> {
        let Some(t) = self.tenants.get(tenant) else {
            bail!("unknown tenant index {tenant} (server has {})", self.tenants.len());
        };
        ensure!(
            features.len() == t.model.in_dim(),
            "tenant '{}' consumes {} features, got {}",
            t.name,
            t.model.in_dim(),
            features.len()
        );
        let id = self.next_id;
        self.next_id += 1;
        self.queues[tenant].push(Request {
            id,
            tenant,
            features,
            arrival_tick: self.now,
            // Saturate: a deadline near u64::MAX means "effectively
            // never due", not an overflow panic.
            deadline_tick: deadline_in.map(|d| self.now.saturating_add(d)),
        });
        self.stats.submitted += 1;
        crate::obs_count!("serve.submitted");
        Ok(id)
    }

    /// Advance virtual time by one tick: sample queue depths, let the
    /// batcher coalesce ready requests, fan the batches out over the
    /// shard pool, and return this tick's responses sorted by request
    /// id.
    pub fn tick(&mut self) -> Result<Vec<Response>> {
        self.stats.record_depth(self.pending());
        // Batch formation is global and precedes the fan-out, so the
        // dispatch schedule is independent of the shard count. Batches
        // spread round-robin in formation order — keyed by a batch
        // counter, not the tenant index, so a single-tenant server
        // still uses the whole pool.
        let n_shards = self.shards.len();
        let mut any = false;
        let mut batch_no = 0usize;
        for (t, q) in self.queues.iter_mut().enumerate() {
            for batch in self.policy.drain(q, self.now) {
                self.stats.record_batch(batch.len());
                // Virtual-ticks clock: one span per dispatched batch at
                // the tick it leaves the queue (tid = tenant index).
                let (now, size) = (self.now, batch.len());
                crate::obs::trace::virt_span(
                    crate::obs::trace::Clock::Ticks,
                    t as u64,
                    "serve.dispatch",
                    "serve",
                    now,
                    1,
                    || format!("\"tenant\":{t},\"batch\":{size},\"tick\":{now}"),
                );
                self.shards[batch_no % n_shards].inbox.push((t, batch));
                batch_no += 1;
                any = true;
            }
        }
        let mut responses = Vec::new();
        if any {
            let tenants: &[Tenant] = &self.tenants;
            let now = self.now;
            // The shard fan-out runs on per-tick scoped threads, NOT on
            // the executor pool: pool workers run nested dispatch
            // inline, so parking shards on the pool would serialize
            // every GEMM inside a shard and idle the remaining cores
            // whenever shards < cores. Scoped threads here are control
            // plane (at most `shards` spawns per dispatching tick);
            // each shard re-pins the *ambient* dispatch mode before its
            // GEMMs, so production stays on the persistent pool and a
            // caller-pinned mode (the differential tests, a sanitizer
            // run under Serial) governs the in-shard numerics even
            // across the spawn boundary. The Scoped override applies
            // only when the fan-out will actually spawn — an inline
            // fan-out (one shard, or a 1-wide budget) must not be
            // kicked back onto per-call thread churn.
            use crate::util::parallel::{dispatch_mode, with_dispatch, worker_count, Dispatch};
            let ambient = dispatch_mode();
            let fanout = |shards: &mut [Shard]| {
                par_chunks_mut(shards, 1, |_, s| {
                    with_dispatch(ambient, || s[0].run_inbox(tenants, now))
                });
            };
            // An ambient Serial pin means "single-threaded, period"
            // (bisecting, sanitizers): honor it instead of spawning.
            if self.shards.len() > 1 && worker_count() > 1 && ambient != Dispatch::Serial {
                with_dispatch(Dispatch::Scoped, || fanout(&mut self.shards));
            } else {
                fanout(&mut self.shards);
            }
            for shard in &mut self.shards {
                if let Some(e) = shard.error.take() {
                    return Err(e);
                }
                responses.append(&mut shard.outbox);
                for (t, (calls, packed)) in shard.counters.iter_mut().enumerate() {
                    self.stats.tenants[t].gemm_calls += *calls;
                    self.stats.tenants[t].packed_runs += *packed;
                    if crate::obs::metrics::enabled() && (*calls != 0 || *packed != 0) {
                        let name = &self.tenants[t].name;
                        crate::obs::metrics::counter_add(
                            &format!("serve.tenant.{name}.gemm_calls"),
                            *calls,
                        );
                        crate::obs::metrics::counter_add(
                            &format!("serve.tenant.{name}.packed_runs"),
                            *packed,
                        );
                    }
                    *calls = 0;
                    *packed = 0;
                }
            }
            responses.sort_by_key(|r| r.id);
            for r in &responses {
                self.stats.record_response(r);
            }
        }
        self.now += 1;
        self.stats.ticks = self.now;
        crate::obs_gauge_max!("serve.ticks", self.now);
        Ok(responses)
    }

    /// The earliest tick at which the batcher could dispatch anything:
    /// `Some(now)` when a queue is ready right now, the nearest future
    /// wait/deadline trigger otherwise, `None` when nothing is pending.
    fn next_dispatch_tick(&self) -> Option<u64> {
        let mut next: Option<u64> = None;
        for q in &self.queues {
            if q.is_empty() {
                continue;
            }
            if self.policy.should_dispatch(q, self.now) {
                return Some(self.now);
            }
            // should_dispatch was false, so both triggers are strictly
            // in the future (and the size trigger needs a new arrival,
            // which only the caller can produce).
            let mut t = q
                .oldest_arrival()
                .map(|a| a.saturating_add(self.policy.max_wait_ticks))
                .unwrap_or(u64::MAX);
            if let Some(d) = q.earliest_deadline() {
                t = t.min(d.saturating_sub(super::batcher::SERVICE_TICKS));
            }
            next = Some(next.map_or(t, |n: u64| n.min(t)));
        }
        next
    }

    /// Fast-forward to `cap` or the next possible dispatch tick,
    /// whichever is earlier — observably identical to ticking through
    /// the skipped quiet ticks one by one (each would sample the same
    /// queue depth and dispatch nothing) but O(1). Keeps sparse-trace
    /// replay and large `max_wait_ticks` drains O(events) instead of
    /// O(tick span). Returns the new current tick.
    pub fn advance_to(&mut self, cap: u64) -> u64 {
        let target = match self.next_dispatch_tick() {
            Some(t) => t.min(cap),
            None => cap,
        };
        if target > self.now {
            self.stats.record_quiet(target - self.now, self.pending());
            self.now = target;
            self.stats.ticks = self.now;
            crate::obs_gauge_max!("serve.ticks", self.now);
        }
        self.now
    }

    /// Tick until every queue is empty, collecting the responses.
    /// Progress is guaranteed: a non-empty queue dispatches at the
    /// latest `max_wait_ticks` after its oldest arrival, and quiet
    /// stretches fast-forward in O(1).
    pub fn drain(&mut self) -> Result<Vec<Response>> {
        let mut out = Vec::new();
        // Every pending request arrived at or before `now`, so the wait
        // trigger guarantees the last one dispatches within
        // `max_wait_ticks` ticks — anything longer is a batcher bug.
        let bound = self.now.saturating_add(self.policy.max_wait_ticks).saturating_add(1);
        while self.pending() > 0 {
            self.advance_to(bound);
            out.append(&mut self.tick()?);
            ensure!(
                self.pending() == 0 || self.now <= bound,
                "server failed to drain within the wait bound (a batcher bug)"
            );
        }
        Ok(out)
    }
}
