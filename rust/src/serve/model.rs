//! [`InferenceModel`] — a frozen, serving-ready snapshot of a trained
//! [`crate::nn::Mlp`].
//!
//! Training re-quantizes the FP32 master weights into the policy's
//! forward format on **every** step (they change between steps). A
//! frozen model's weights never change, so freezing packs each layer's
//! weight matrix **once**, column-major — the layout the GEMM kernels
//! stream operand B in — and every request batch then takes
//! [`crate::api::GemmPlan::run`]'s zero-repack route: the stored words
//! feed the batch engine directly, no decode, no re-pack. Because the
//! packed words are bit-identical to what [`crate::nn::Linear::forward`]
//! would have built from the same masters, a frozen forward pass is
//! bit-identical to the training-path forward (pinned by tests).
//!
//! ## Checkpoint format (version 1)
//!
//! A little-endian binary file: magic `MFNN`, format version `u32`,
//! then the policy name, activation tag, class count and per-layer
//! `(in, out, weights f32…, bias f32…)` records. The FP32 *masters*
//! are stored (not the packed words): they are exact, rounding-mode
//! independent, and re-packing on load is deterministic, so a loaded
//! model's packed weights are bit-identical to the saved one's under
//! the same session rounding mode.

use crate::api::{Layout, MfTensor, Session};
use crate::nn::engine::GemmCtx;
use crate::nn::layer::{Activation, Mlp};
use crate::nn::policy::PrecisionPolicy;
use crate::util::error::{Context, Result};
use crate::{bail, ensure};

/// Checkpoint magic bytes.
const MAGIC: &[u8; 4] = b"MFNN";
/// Checkpoint format version this build reads and writes.
const VERSION: u32 = 1;

/// One frozen fully-connected layer: FP32 masters (for checkpointing)
/// plus the weights pre-packed in the forward format, column-major.
#[derive(Clone, Debug)]
pub struct FrozenLayer {
    /// Input width.
    pub in_dim: usize,
    /// Output width.
    pub out_dim: usize,
    /// FP32 master weights, `in_dim×out_dim` row-major.
    w_master: Vec<f32>,
    /// Bias, FP32.
    bias: Vec<f32>,
    /// Weights quantized to the policy's forward format and packed
    /// column-major — operand B's kernel stream layout.
    w_packed: MfTensor,
}

/// A frozen inference model: the serving hot path.
#[derive(Clone, Debug)]
pub struct InferenceModel {
    policy: PrecisionPolicy,
    act: Activation,
    classes: usize,
    layers: Vec<FrozenLayer>,
}

fn act_tag(act: Activation) -> u8 {
    match act {
        Activation::Relu => 0,
        Activation::Gelu => 1,
    }
}

fn act_from_tag(tag: u8) -> Result<Activation> {
    match tag {
        0 => Ok(Activation::Relu),
        1 => Ok(Activation::Gelu),
        other => bail!("checkpoint names unknown activation tag {other}"),
    }
}

impl InferenceModel {
    /// Freeze a trained MLP under its training policy: quantize each
    /// layer's masters to `policy.fwd` and pack them column-major using
    /// the session's rounding mode.
    pub fn freeze(session: &Session, model: &Mlp, policy: &PrecisionPolicy) -> Result<Self> {
        policy.validate()?;
        let mut layers = Vec::with_capacity(model.layers.len());
        for l in &model.layers {
            layers.push(FrozenLayer::freeze(session, policy, l.in_dim, l.out_dim, &l.w, &l.b)?);
        }
        let frozen = InferenceModel {
            policy: *policy,
            act: model.act,
            classes: model.loss.classes,
            layers,
        };
        frozen.validate()?;
        Ok(frozen)
    }

    /// The precision policy the model serves under.
    pub fn policy(&self) -> &PrecisionPolicy {
        &self.policy
    }

    /// Activation between linear layers.
    pub fn act(&self) -> Activation {
        self.act
    }

    /// Logical class count (`<= out_dim`; the tail is lane padding).
    pub fn classes(&self) -> usize {
        self.classes
    }

    /// Input feature width.
    pub fn in_dim(&self) -> usize {
        self.layers[0].in_dim
    }

    /// Logit width (lane-padded).
    pub fn out_dim(&self) -> usize {
        self.layers[self.layers.len() - 1].out_dim
    }

    /// The frozen layers.
    pub fn layers(&self) -> &[FrozenLayer] {
        &self.layers
    }

    /// Structural invariants (checked on freeze and on load).
    pub fn validate(&self) -> Result<()> {
        ensure!(!self.layers.is_empty(), "an inference model needs at least one layer");
        for (i, l) in self.layers.iter().enumerate() {
            ensure!(
                l.in_dim > 0 && l.out_dim > 0,
                "layer {i} has an empty dimension ({}x{})",
                l.in_dim,
                l.out_dim
            );
            if i + 1 < self.layers.len() {
                ensure!(
                    l.out_dim == self.layers[i + 1].in_dim,
                    "layer {i} produces {} features but layer {} consumes {}",
                    l.out_dim,
                    i + 1,
                    self.layers[i + 1].in_dim
                );
            }
        }
        ensure!(
            self.classes >= 2 && self.classes <= self.out_dim(),
            "class count ({}) must be in 2..={} (the logit width)",
            self.classes,
            self.out_dim()
        );
        Ok(())
    }

    /// Forward a padded batch (`rows` a multiple of the serving row
    /// granularity, `rows×in_dim` row-major features) to logits.
    ///
    /// Each layer runs [`crate::nn::layer::linear_forward_into`] — the
    /// *same* implementation the training forward uses, fed the
    /// pre-packed column-major weights (zero-repack for expanding-pair
    /// policies) — so the served pass is bit-identical to the
    /// training-path forward by construction, not by parallel
    /// maintenance. Each output row depends only on its own input row,
    /// which is what makes per-request results independent of batch
    /// composition.
    pub fn forward(&self, ctx: &mut GemmCtx, x: &[f64], rows: usize) -> Result<Vec<f64>> {
        let mut out = Vec::new();
        let mut scratch = Vec::new();
        let mut xt_pool = Vec::new();
        self.forward_into(ctx, x, rows, &mut out, &mut scratch, &mut xt_pool)?;
        Ok(out)
    }

    /// [`InferenceModel::forward`] on recycled storage — the serving
    /// hot path. Logits land in `out`; `scratch` ping-pongs the
    /// inter-layer activations; `xt_pool` recycles the quantized-input
    /// word storage. All three are shard-owned buffers reused across
    /// dispatches (capacity only; bit-identical to the allocating
    /// form).
    pub fn forward_into(
        &self,
        ctx: &mut GemmCtx,
        x: &[f64],
        rows: usize,
        out: &mut Vec<f64>,
        scratch: &mut Vec<f64>,
        xt_pool: &mut Vec<u64>,
    ) -> Result<()> {
        let n = self.layers.len();
        // `scratch` carries the activations entering the next layer.
        scratch.clear();
        scratch.extend_from_slice(x);
        for i in 0..n {
            self.forward_layer_into(ctx, i, scratch, rows, out, xt_pool)?;
            std::mem::swap(scratch, out);
        }
        // The loop parks the final activations in `scratch`.
        std::mem::swap(scratch, out);
        Ok(())
    }

    /// Advance a padded batch through **one** layer: the wave quantum
    /// of the continuous batcher. `x` is `rows × layers[i].in_dim`
    /// row-major activations; `out` receives `rows × layers[i].out_dim`
    /// (with the inter-layer activation applied on every layer but the
    /// last, exactly as the whole-model forward does). Chaining the
    /// waves layer by layer is bit-identical to [`forward_into`] by
    /// construction — same [`crate::nn::layer::linear_forward_into`]
    /// call, same activation site — which is what lets the continuous
    /// scheduler interleave cohorts at different layers without
    /// touching the numerics.
    ///
    /// [`forward_into`]: InferenceModel::forward_into
    pub fn forward_layer_into(
        &self,
        ctx: &mut GemmCtx,
        layer: usize,
        x: &[f64],
        rows: usize,
        out: &mut Vec<f64>,
        xt_pool: &mut Vec<u64>,
    ) -> Result<()> {
        ensure!(
            layer < self.layers.len(),
            "layer index {layer} out of range (model has {} layers)",
            self.layers.len()
        );
        let l = &self.layers[layer];
        ensure!(
            x.len() == rows * l.in_dim,
            "layer {layer} input must be {rows}x{} = {} values, got {}",
            l.in_dim,
            rows * l.in_dim,
            x.len()
        );
        ensure!(
            ctx.acc == self.policy.acc,
            "GemmCtx accumulates in {} but the model's policy wants {}",
            ctx.acc.name(),
            self.policy.acc.name()
        );
        let xt = crate::nn::layer::linear_forward_into(
            ctx,
            &self.policy,
            &l.w_packed,
            &l.bias,
            x,
            rows,
            l.in_dim,
            l.out_dim,
            std::mem::take(xt_pool),
            out,
        )?;
        *xt_pool = xt.into_words();
        if layer + 1 < self.layers.len() {
            self.act.apply_in_place(out);
        }
        Ok(())
    }

    // ------------------------------------------------------ checkpoints

    /// Serialize to the version-1 binary checkpoint format.
    ///
    /// Only the named policy presets round-trip (the file stores the
    /// policy by name); a hand-built anonymous policy is a typed error.
    pub fn to_bytes(&self) -> Result<Vec<u8>> {
        ensure!(
            PrecisionPolicy::parse(self.policy.name).map(|p| p == self.policy).unwrap_or(false),
            "only the named policy presets can be checkpointed (policy '{}' does not \
             round-trip through its name)",
            self.policy.name
        );
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        let name = self.policy.name.as_bytes();
        out.extend_from_slice(&(name.len() as u32).to_le_bytes());
        out.extend_from_slice(name);
        out.push(act_tag(self.act));
        out.extend_from_slice(&(self.classes as u32).to_le_bytes());
        out.extend_from_slice(&(self.layers.len() as u32).to_le_bytes());
        for l in &self.layers {
            out.extend_from_slice(&(l.in_dim as u32).to_le_bytes());
            out.extend_from_slice(&(l.out_dim as u32).to_le_bytes());
            for w in &l.w_master {
                out.extend_from_slice(&w.to_le_bytes());
            }
            for b in &l.bias {
                out.extend_from_slice(&b.to_le_bytes());
            }
        }
        Ok(out)
    }

    /// Deserialize a version-1 checkpoint, re-quantizing and re-packing
    /// the stored masters under `session`'s rounding mode.
    pub fn from_bytes(session: &Session, bytes: &[u8]) -> Result<Self> {
        let mut r = Reader { bytes, pos: 0 };
        let magic = r.take(4)?;
        ensure!(magic == MAGIC, "not a minifloat-nn checkpoint (bad magic bytes)");
        let version = r.u32()?;
        ensure!(
            version == VERSION,
            "unsupported checkpoint version {version} (this build reads version {VERSION})"
        );
        let name_len = r.u32()? as usize;
        ensure!(name_len <= 64, "checkpoint policy name is implausibly long ({name_len} bytes)");
        let name = String::from_utf8(r.take(name_len)?.to_vec())
            .map_err(|_| crate::util::error::Error::msg("checkpoint policy name is not UTF-8"))?;
        let policy = PrecisionPolicy::parse(&name)
            .with_context(|| format!("checkpoint names unknown policy '{name}'"))?;
        let act = act_from_tag(r.u8()?)?;
        let classes = r.u32()? as usize;
        let n_layers = r.u32()? as usize;
        ensure!(
            (1..=64).contains(&n_layers),
            "checkpoint layer count {n_layers} is outside the sane range 1..=64"
        );
        let mut layers = Vec::with_capacity(n_layers);
        for i in 0..n_layers {
            let in_dim = r.u32()? as usize;
            let out_dim = r.u32()? as usize;
            ensure!(
                in_dim * out_dim <= 1 << 24,
                "checkpoint layer {i} is implausibly large ({in_dim}x{out_dim})"
            );
            let w: Vec<f32> = r.f32s(in_dim * out_dim)?;
            let b: Vec<f32> = r.f32s(out_dim)?;
            layers.push(FrozenLayer::freeze(session, &policy, in_dim, out_dim, &w, &b)?);
        }
        ensure!(r.pos == bytes.len(), "checkpoint has {} trailing bytes", bytes.len() - r.pos);
        let model = InferenceModel { policy, act, classes, layers };
        model.validate().context("checkpoint failed structural validation")?;
        Ok(model)
    }

    /// Write a checkpoint file.
    pub fn save(&self, path: &str) -> Result<()> {
        let bytes = self.to_bytes()?;
        std::fs::write(path, bytes).with_context(|| format!("writing checkpoint '{path}'"))
    }

    /// Read a checkpoint file.
    pub fn load(session: &Session, path: &str) -> Result<Self> {
        let bytes =
            std::fs::read(path).with_context(|| format!("opening checkpoint '{path}'"))?;
        Self::from_bytes(session, &bytes)
            .with_context(|| format!("reading checkpoint '{path}'"))
    }
}

impl FrozenLayer {
    fn freeze(
        session: &Session,
        policy: &PrecisionPolicy,
        in_dim: usize,
        out_dim: usize,
        w: &[f32],
        b: &[f32],
    ) -> Result<Self> {
        ensure!(
            w.len() == in_dim * out_dim,
            "layer weights must be {in_dim}x{out_dim} = {} values, got {}",
            in_dim * out_dim,
            w.len()
        );
        ensure!(b.len() == out_dim, "layer bias must be {out_dim} values, got {}", b.len());
        let w64: Vec<f64> = w.iter().map(|&v| v as f64).collect();
        let w_packed =
            session.tensor_with_layout(&w64, in_dim, out_dim, policy.fwd, Layout::ColMajor)?;
        Ok(FrozenLayer { in_dim, out_dim, w_master: w.to_vec(), bias: b.to_vec(), w_packed })
    }

    /// The pre-packed weight tensor (forward format, column-major).
    pub fn packed_weights(&self) -> &MfTensor {
        &self.w_packed
    }

    /// The FP32 master weights.
    pub fn master_weights(&self) -> &[f32] {
        &self.w_master
    }

    /// The FP32 bias.
    pub fn bias(&self) -> &[f32] {
        &self.bias
    }
}

/// Bounds-checked little-endian cursor (a malformed checkpoint must be
/// a typed error, never a slice panic).
struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(
            self.pos + n <= self.bytes.len(),
            "checkpoint is truncated (wanted {n} bytes at offset {}, file has {})",
            self.pos,
            self.bytes.len()
        );
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let b = self.take(4 * n)?;
        Ok(b.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }
}
