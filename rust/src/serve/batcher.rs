//! Dynamic batching: coalesce queued requests into lane-padded GEMM
//! batches under `max_batch` / `max_wait_ticks` knobs.
//!
//! The whole point of the serving layer is that the engine is fast at
//! *large, lane-aligned* GEMMs and wasteful at tiny ones: a single
//! request still has to occupy [`ROW_PAD`] padded rows (the kernel's
//! M-divisibility), so batch-of-1 throws away 7/8 of the compute. The
//! batcher trades a bounded amount of queueing latency for full rows.
//!
//! Two scheduling modes ([`BatchMode`]):
//!
//! * **Continuous** (the default) — iteration-level batching. Every
//!   tick is a layer-0 boundary: up to `max_batch` queued rows join a
//!   fresh cohort immediately and advance one layer per tick alongside
//!   the cohorts already in flight, so a request never waits for the
//!   previous batch to drain. Wave composition is SLO-weighted: when
//!   the queue overflows one wave, near-deadline rows go first.
//! * **WholeBatch** (the legacy reference, kept behind this flag the
//!   way `batch::with_lane_tier` pins the scalar tier) — a tenant's
//!   queue dispatches when it has a full `max_batch`, when its oldest
//!   request has waited `max_wait_ticks`, or when a pending deadline
//!   is about to become infeasible; the dispatched batch then runs to
//!   completion (one model's worth of layers) before the tenant can
//!   dispatch again.

use super::queue::{Request, TenantQueue};
use crate::bail;
use crate::util::error::Result;

/// Row granularity every GEMM batch is padded to: the kernels require
/// `M % 8 == 0` (8 compute cores), which also covers the widest SIMD
/// lane count (8×FP8 per 64-bit word).
pub const ROW_PAD: usize = 8;

/// The virtual service quantum: one **layer wave**. Each tick, every
/// in-flight cohort advances exactly one layer; a cohort's results are
/// ready `SERVICE_TICKS` after its final wave. Uniform (independent of
/// batch shape and shard), so completion ticks stay shard-count
/// independent. A whole model therefore costs
/// [`pipeline_latency_ticks`] ticks end to end, which is what makes
/// the deadline metric meaningful: the legacy dispatch trigger fires
/// early enough that any deadline of at least one pipeline latency is
/// met by construction, while a shorter one is infeasible and counted
/// as missed.
pub const SERVICE_TICKS: u64 = 1;

/// Round a logical batch size up to the row-padding granularity.
pub fn pad_rows(n: usize) -> usize {
    (n + ROW_PAD - 1) / ROW_PAD * ROW_PAD
}

/// End-to-end service latency of an `layers`-deep model in ticks: one
/// wave per layer (waves run back to back, one per tick), results
/// ready [`SERVICE_TICKS`] after the last wave. A cohort admitted at
/// tick `T` completes at `T + pipeline_latency_ticks(layers)`.
pub fn pipeline_latency_ticks(layers: usize) -> u64 {
    layers.saturating_sub(1) as u64 + SERVICE_TICKS
}

/// How the server schedules queued requests onto layer waves.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BatchMode {
    /// Continuous (iteration-level) batching: requests join a fresh
    /// cohort at the next layer-0 boundary — i.e. the very next tick —
    /// and pipeline through the layers alongside the cohorts already
    /// in flight.
    #[default]
    Continuous,
    /// The legacy whole-batch policy: one cohort per tenant at a time,
    /// dispatched by the size/wait/deadline triggers and run to
    /// completion. Kept as the differential/timing reference.
    WholeBatch,
}

impl BatchMode {
    /// Parse the CLI spelling (`--batching continuous|whole`).
    pub fn parse(s: &str) -> Result<BatchMode> {
        match s {
            "continuous" | "cont" => Ok(BatchMode::Continuous),
            "whole" | "legacy" | "wholebatch" => Ok(BatchMode::WholeBatch),
            other => bail!(
                "unknown batching mode '{other}' (--batching takes 'continuous' or 'whole')"
            ),
        }
    }

    /// The CLI spelling.
    pub fn name(&self) -> &'static str {
        match self {
            BatchMode::Continuous => "continuous",
            BatchMode::WholeBatch => "whole",
        }
    }
}

/// The batching knobs, shared by every tenant queue.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BatchPolicy {
    /// Largest logical batch one dispatch coalesces (>= 1).
    pub max_batch: usize,
    /// Longest a request may wait before its queue dispatches anyway
    /// (WholeBatch mode; Continuous admits every tick regardless).
    /// 0 = dispatch on the first tick the request is visible.
    pub max_wait_ticks: u64,
    /// Wave scheduling mode.
    pub mode: BatchMode,
}

impl BatchPolicy {
    /// Should this queue dispatch at tick `now`? `lead_ticks` is the
    /// tenant's end-to-end pipeline latency
    /// ([`pipeline_latency_ticks`]): the deadline trigger fires while
    /// dispatching can still meet the deadline.
    pub fn should_dispatch(&self, q: &TenantQueue, now: u64, lead_ticks: u64) -> bool {
        if q.is_empty() {
            return false;
        }
        if q.len() >= self.max_batch {
            return true;
        }
        let waited =
            q.oldest_arrival().map(|a| a.saturating_add(self.max_wait_ticks) <= now).unwrap_or(false);
        // Deadline-aware: dispatch while the deadline can still be met
        // (results land `lead_ticks` after dispatch).
        let due = q
            .earliest_deadline()
            .map(|d| d <= now.saturating_add(lead_ticks))
            .unwrap_or(false);
        waited || due
    }

    /// Drain every batch the policy says is ready at tick `now`, in
    /// FIFO order, each at most `max_batch` requests. The dispatch
    /// condition is re-evaluated after each batch, so one call may
    /// yield several; a FIFO remainder of *newer* arrivals whose own
    /// wait/deadline has not fired (and that no longer fills
    /// `max_batch`) stays queued until its trigger comes up. (The
    /// server itself admits at most one cohort per tenant per tick —
    /// this loop form exists for the batcher unit tests.)
    pub fn drain(&self, q: &mut TenantQueue, now: u64, lead_ticks: u64) -> Vec<Vec<Request>> {
        let mut out = Vec::new();
        while self.should_dispatch(q, now, lead_ticks) {
            out.push(q.take(self.max_batch));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, arrival: u64, deadline: Option<u64>) -> Request {
        Request { id, tenant: 0, features: vec![0.0; 8], arrival_tick: arrival, deadline_tick: deadline }
    }

    fn pol(max_batch: usize, max_wait_ticks: u64) -> BatchPolicy {
        BatchPolicy { max_batch, max_wait_ticks, mode: BatchMode::WholeBatch }
    }

    #[test]
    fn pads_to_the_kernel_row_granularity() {
        assert_eq!(pad_rows(1), 8);
        assert_eq!(pad_rows(8), 8);
        assert_eq!(pad_rows(9), 16);
        assert_eq!(pad_rows(64), 64);
    }

    #[test]
    fn pipeline_latency_is_one_tick_per_layer() {
        assert_eq!(pipeline_latency_ticks(1), SERVICE_TICKS);
        assert_eq!(pipeline_latency_ticks(3), 2 + SERVICE_TICKS);
    }

    #[test]
    fn batch_mode_parses_the_cli_spellings() {
        assert_eq!(BatchMode::parse("continuous").unwrap(), BatchMode::Continuous);
        assert_eq!(BatchMode::parse("whole").unwrap(), BatchMode::WholeBatch);
        assert_eq!(BatchMode::parse("legacy").unwrap(), BatchMode::WholeBatch);
        assert!(BatchMode::parse("bogus").is_err());
        assert_eq!(BatchMode::default(), BatchMode::Continuous);
    }

    #[test]
    fn dispatches_on_full_batch() {
        let pol = pol(4, 100);
        let mut q = TenantQueue::new();
        for i in 0..3 {
            q.push(req(i, 0, None));
        }
        assert!(!pol.should_dispatch(&q, 0, 1), "3 < max_batch and nothing waited");
        q.push(req(3, 0, None));
        assert!(pol.should_dispatch(&q, 0, 1));
        let batches = pol.drain(&mut q, 0, 1);
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].len(), 4);
        assert!(q.is_empty());
    }

    #[test]
    fn dispatches_on_wait_and_flushes_the_remainder() {
        let pol = pol(4, 2);
        let mut q = TenantQueue::new();
        for i in 0..6 {
            q.push(req(i, 0, None));
        }
        // 6 pending: one full batch triggers on size, the remainder of 2
        // flushes with it once the wait clock fires.
        assert!(pol.should_dispatch(&q, 0, 1), "over max_batch");
        let batches = pol.drain(&mut q, 2, 1);
        assert_eq!(batches.iter().map(Vec::len).collect::<Vec<_>>(), vec![4, 2]);
        assert!(q.is_empty());

        // A lone request dispatches only once it has waited long enough.
        q.push(req(9, 10, None));
        assert!(!pol.should_dispatch(&q, 11, 1));
        assert!(pol.should_dispatch(&q, 12, 1));
        let batches = pol.drain(&mut q, 12, 1);
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0][0].id, 9);
    }

    #[test]
    fn dispatches_one_pipeline_latency_before_the_deadline() {
        let pol = pol(64, 1000);
        let mut q = TenantQueue::new();
        q.push(req(0, 0, Some(5)));
        // Results land `lead` ticks after dispatch. With a 3-layer
        // pipeline (lead 3) the trigger fires at tick 2: dispatch then,
        // complete at 5 — met exactly.
        assert!(!pol.should_dispatch(&q, 1, 3), "deadline still comfortably ahead");
        assert!(pol.should_dispatch(&q, 2, 3), "last tick that can meet the deadline");
        assert!(pol.should_dispatch(&q, 5, 3), "overdue still dispatches (counted as a miss)");
        // A single-layer model (lead = SERVICE_TICKS) keeps the old
        // one-quantum trigger.
        assert!(!pol.should_dispatch(&q, 3, 1));
        assert!(pol.should_dispatch(&q, 4, 1));
    }

    #[test]
    fn fifo_order_is_preserved() {
        let pol = pol(2, 0);
        let mut q = TenantQueue::new();
        for i in 0..5 {
            q.push(req(i, 0, None));
        }
        let ids: Vec<u64> =
            pol.drain(&mut q, 0, 1).into_iter().flatten().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    }
}
