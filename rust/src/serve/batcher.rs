//! Dynamic batching: coalesce queued requests into lane-padded GEMM
//! batches under `max_batch` / `max_wait_ticks` knobs.
//!
//! The whole point of the serving layer is that the engine is fast at
//! *large, lane-aligned* GEMMs and wasteful at tiny ones: a single
//! request still has to occupy [`ROW_PAD`] padded rows (the kernel's
//! M-divisibility), so batch-of-1 throws away 7/8 of the compute. The
//! batcher trades a bounded amount of queueing latency for full rows:
//! a tenant's queue dispatches when it has a full `max_batch`, when its
//! oldest request has waited `max_wait_ticks`, or when a pending
//! deadline is already due — whichever comes first.

use super::queue::{Request, TenantQueue};

/// Row granularity every GEMM batch is padded to: the kernels require
/// `M % 8 == 0` (8 compute cores), which also covers the widest SIMD
/// lane count (8×FP8 per 64-bit word).
pub const ROW_PAD: usize = 8;

/// The virtual service quantum: a dispatched batch's results are ready
/// this many ticks after dispatch. Uniform (independent of batch shape
/// and shard), so completion ticks stay shard-count independent. It
/// also makes the deadline metric meaningful: the deadline trigger
/// dispatches early enough that every deadline of at least one quantum
/// is met by construction, while a sub-quantum deadline is infeasible
/// and counted as missed.
pub const SERVICE_TICKS: u64 = 1;

/// Round a logical batch size up to the row-padding granularity.
pub fn pad_rows(n: usize) -> usize {
    (n + ROW_PAD - 1) / ROW_PAD * ROW_PAD
}

/// The batching knobs, shared by every tenant queue.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BatchPolicy {
    /// Largest logical batch one dispatch coalesces (>= 1).
    pub max_batch: usize,
    /// Longest a request may wait before its queue dispatches anyway.
    /// 0 = dispatch on the first tick the request is visible.
    pub max_wait_ticks: u64,
}

impl BatchPolicy {
    /// Should this queue dispatch at tick `now`?
    pub fn should_dispatch(&self, q: &TenantQueue, now: u64) -> bool {
        if q.is_empty() {
            return false;
        }
        if q.len() >= self.max_batch {
            return true;
        }
        let waited =
            q.oldest_arrival().map(|a| a.saturating_add(self.max_wait_ticks) <= now).unwrap_or(false);
        // Deadline-aware: dispatch while the deadline can still be met
        // (results land SERVICE_TICKS after dispatch).
        let due = q
            .earliest_deadline()
            .map(|d| d <= now.saturating_add(SERVICE_TICKS))
            .unwrap_or(false);
        waited || due
    }

    /// Drain every batch the policy says is ready at tick `now`, in
    /// FIFO order, each at most `max_batch` requests. The dispatch
    /// condition is re-evaluated after each batch, so one call may
    /// yield several; a FIFO remainder of *newer* arrivals whose own
    /// wait/deadline has not fired (and that no longer fills
    /// `max_batch`) stays queued until its trigger comes up.
    pub fn drain(&self, q: &mut TenantQueue, now: u64) -> Vec<Vec<Request>> {
        let mut out = Vec::new();
        while self.should_dispatch(q, now) {
            out.push(q.take(self.max_batch));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, arrival: u64, deadline: Option<u64>) -> Request {
        Request { id, tenant: 0, features: vec![0.0; 8], arrival_tick: arrival, deadline_tick: deadline }
    }

    #[test]
    fn pads_to_the_kernel_row_granularity() {
        assert_eq!(pad_rows(1), 8);
        assert_eq!(pad_rows(8), 8);
        assert_eq!(pad_rows(9), 16);
        assert_eq!(pad_rows(64), 64);
    }

    #[test]
    fn dispatches_on_full_batch() {
        let pol = BatchPolicy { max_batch: 4, max_wait_ticks: 100 };
        let mut q = TenantQueue::new();
        for i in 0..3 {
            q.push(req(i, 0, None));
        }
        assert!(!pol.should_dispatch(&q, 0), "3 < max_batch and nothing waited");
        q.push(req(3, 0, None));
        assert!(pol.should_dispatch(&q, 0));
        let batches = pol.drain(&mut q, 0);
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].len(), 4);
        assert!(q.is_empty());
    }

    #[test]
    fn dispatches_on_wait_and_flushes_the_remainder() {
        let pol = BatchPolicy { max_batch: 4, max_wait_ticks: 2 };
        let mut q = TenantQueue::new();
        for i in 0..6 {
            q.push(req(i, 0, None));
        }
        // 6 pending: one full batch triggers on size, the remainder of 2
        // flushes with it once the wait clock fires.
        assert!(pol.should_dispatch(&q, 0), "over max_batch");
        let batches = pol.drain(&mut q, 2);
        assert_eq!(batches.iter().map(Vec::len).collect::<Vec<_>>(), vec![4, 2]);
        assert!(q.is_empty());

        // A lone request dispatches only once it has waited long enough.
        q.push(req(9, 10, None));
        assert!(!pol.should_dispatch(&q, 11));
        assert!(pol.should_dispatch(&q, 12));
        let batches = pol.drain(&mut q, 12);
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0][0].id, 9);
    }

    #[test]
    fn dispatches_one_service_quantum_before_the_deadline() {
        let pol = BatchPolicy { max_batch: 64, max_wait_ticks: 1000 };
        let mut q = TenantQueue::new();
        q.push(req(0, 0, Some(5)));
        // Results land SERVICE_TICKS after dispatch, so the trigger
        // fires at tick 4: dispatch then, complete at 5 — met exactly.
        assert!(!pol.should_dispatch(&q, 3), "deadline still comfortably ahead");
        assert!(pol.should_dispatch(&q, 4), "last tick that can meet the deadline");
        assert!(pol.should_dispatch(&q, 5), "overdue still dispatches (counted as a miss)");
    }

    #[test]
    fn fifo_order_is_preserved() {
        let pol = BatchPolicy { max_batch: 2, max_wait_ticks: 0 };
        let mut q = TenantQueue::new();
        for i in 0..5 {
            q.push(req(i, 0, None));
        }
        let ids: Vec<u64> =
            pol.drain(&mut q, 0).into_iter().flatten().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    }
}
