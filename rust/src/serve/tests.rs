//! Serving-subsystem tests: freeze/checkpoint fidelity, batching
//! behavior, and the acceptance gates — bit-identical replay across
//! runs and shard counts, with every expanding-pair tenant GEMM
//! asserted through the packed zero-repack route.

use super::model::InferenceModel;
use super::sim::{self, Trace};
use crate::api::Session;
use crate::nn::engine::GemmCtx;
use crate::nn::policy::PrecisionPolicy;
use crate::nn::Tape;
use crate::util::rng::Rng;

fn session() -> Session {
    Session::builder().seed(77).build()
}

/// Train a small model briefly and freeze it.
fn frozen(session: &Session, policy: PrecisionPolicy, steps: usize) -> InferenceModel {
    let mut tr = session.native_trainer(policy).expect("trainer");
    tr.train(steps, 0).expect("train");
    InferenceModel::freeze(session, tr.model(), tr.policy()).expect("freeze")
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn padded_batch(rng: &mut Rng, rows: usize, in_dim: usize) -> Vec<f64> {
    let mut x = Vec::with_capacity(rows * in_dim);
    for _ in 0..rows {
        x.extend(sim::sample_features(rng, in_dim));
    }
    x
}

// ------------------------------------------------------------- freezing

#[test]
fn frozen_forward_is_bit_identical_to_training_forward() {
    // The zero-repack serving path (pre-packed column-major weights)
    // must reproduce the training-path forward bit for bit.
    let session = session();
    for policy in [PrecisionPolicy::hfp8(), PrecisionPolicy::fp8(), PrecisionPolicy::fp32()] {
        let mut tr = session.native_trainer(policy).expect("trainer");
        tr.train(4, 0).expect("train");
        let model = InferenceModel::freeze(&session, tr.model(), tr.policy()).expect("freeze");
        let mut rng = Rng::new(9);
        let rows = 16;
        let x = padded_batch(&mut rng, rows, model.in_dim());
        let mut ctx = GemmCtx::new(&session, policy.acc);
        let served = model.forward(&mut ctx, &x, rows).expect("serve forward");
        let mut ctx2 = GemmCtx::new(&session, policy.acc);
        let trained =
            tr.model().forward_inference(&mut ctx2, &policy, &x, rows).expect("train forward");
        assert_eq!(bits(&served), bits(&trained), "{}", policy.name);
        // Expanding-pair policies must take the packed route on every
        // GEMM — the weights were packed for exactly that.
        if policy.fwd != policy.acc {
            assert_eq!(ctx.packed, ctx.calls, "{}: zero-repack route", policy.name);
        }
        assert_eq!(ctx.calls, model.layers().len() as u64);
    }
}

#[test]
fn freezing_also_works_via_taped_training_forward() {
    // Belt and suspenders for the extraction satellite: the frozen path
    // equals the *taped* training forward too (tape only records).
    let session = session();
    let policy = PrecisionPolicy::hfp8();
    let mut tr = session.native_trainer(policy).expect("trainer");
    tr.train(2, 0).expect("train");
    let model = InferenceModel::freeze(&session, tr.model(), tr.policy()).expect("freeze");
    let mut rng = Rng::new(3);
    let x = padded_batch(&mut rng, 8, model.in_dim());
    let mut ctx = GemmCtx::new(&session, policy.acc);
    let served = model.forward(&mut ctx, &x, 8).expect("serve");
    let mut tape = Tape::new();
    let mut ctx2 = GemmCtx::new(&session, policy.acc);
    let taped =
        tr.model().forward(&mut ctx2, &policy, &x, 8, Some(&mut tape)).expect("taped forward");
    assert_eq!(bits(&served), bits(&taped));
}

// ---------------------------------------------------------- checkpoints

#[test]
fn checkpoint_roundtrips_bit_exactly() {
    let session = session();
    let model = frozen(&session, PrecisionPolicy::hfp8(), 4);
    let bytes = model.to_bytes().expect("serialize");
    let loaded = InferenceModel::from_bytes(&session, &bytes).expect("deserialize");
    assert_eq!(loaded.policy(), model.policy());
    assert_eq!(loaded.act(), model.act());
    assert_eq!(loaded.classes(), model.classes());
    assert_eq!(loaded.layers().len(), model.layers().len());
    for (a, b) in loaded.layers().iter().zip(model.layers()) {
        assert_eq!(a.master_weights(), b.master_weights());
        assert_eq!(a.bias(), b.bias());
        // Packed words re-derive identically under the same rounding.
        assert_eq!(a.packed_weights(), b.packed_weights());
    }
    // And the loaded model serves identical logits.
    let mut rng = Rng::new(21);
    let x = padded_batch(&mut rng, 8, model.in_dim());
    let mut c1 = GemmCtx::new(&session, model.policy().acc);
    let mut c2 = GemmCtx::new(&session, model.policy().acc);
    let a = model.forward(&mut c1, &x, 8).expect("forward");
    let b = loaded.forward(&mut c2, &x, 8).expect("forward");
    assert_eq!(bits(&a), bits(&b));
}

#[test]
fn checkpoint_file_roundtrip_and_load_errors_are_typed() {
    let session = session();
    let model = frozen(&session, PrecisionPolicy::fp8(), 2);
    let dir = std::env::temp_dir();
    let path = dir.join(format!("mfnn_ckpt_test_{}.bin", std::process::id()));
    let path = path.to_str().expect("utf-8 temp path").to_string();
    model.save(&path).expect("save");
    let loaded = InferenceModel::load(&session, &path).expect("load");
    assert_eq!(loaded.policy().name, "fp8");
    // Unknown path: typed error naming the file, not a panic.
    let err = InferenceModel::load(&session, "/nonexistent/nowhere.bin").unwrap_err();
    assert!(err.to_string().contains("checkpoint"), "{err}");
    // Garbage: bad magic.
    let err = InferenceModel::from_bytes(&session, b"JUNKJUNKJUNK").unwrap_err();
    assert!(err.to_string().contains("bad magic"), "{err}");
    // Truncation anywhere: typed, bounds-checked.
    let bytes = model.to_bytes().expect("serialize");
    for cut in [3, 7, 11, bytes.len() / 2, bytes.len() - 1] {
        let err = InferenceModel::from_bytes(&session, &bytes[..cut]).unwrap_err();
        assert!(err.to_string().contains("truncated"), "cut at {cut}: {err}");
    }
    // Version from the future: named in the error.
    let mut future = bytes.clone();
    future[4..8].copy_from_slice(&99u32.to_le_bytes());
    let err = InferenceModel::from_bytes(&session, &future).unwrap_err();
    assert!(err.to_string().contains("version 99"), "{err}");
    std::fs::remove_file(&path).ok();
}

// ------------------------------------------------- end-to-end serving

fn two_tenant_plan(session: &Session, shards: usize) -> crate::api::ServePlan {
    let hfp8 = frozen(session, PrecisionPolicy::hfp8(), 4);
    let fp8 = frozen(session, PrecisionPolicy::fp8(), 4);
    session
        .server()
        .tenant("hfp8", hfp8)
        .tenant("fp8", fp8)
        .max_batch(16)
        .max_wait_ticks(3)
        .shards(shards)
        .build()
        .expect("valid serve plan")
}

#[test]
fn replay_is_bit_identical_across_runs_and_shard_counts() {
    // The subsystem's acceptance gate: same seed + trace → bit-identical
    // per-request outputs, across runs and across shard counts {1, 4},
    // with every expanding-pair tenant GEMM on the packed route.
    let session = session();
    let trace = Trace::open_loop(1234, &[8, 8], 300, 0.4, Some(64)).expect("trace");
    let mut runs = Vec::new();
    for shards in [1usize, 1, 4] {
        let plan = two_tenant_plan(&session, shards);
        let mut server = plan.server();
        assert_eq!(server.shard_count(), shards);
        let responses = sim::replay(&mut server, &trace).expect("replay");
        assert_eq!(responses.len(), 300);
        runs.push((responses, server.stats().clone()));
    }
    let (r0, s0) = &runs[0];
    for (ri, si) in &runs[1..] {
        assert_eq!(r0.len(), ri.len());
        for (a, b) in r0.iter().zip(ri) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tenant, b.tenant);
            assert_eq!(bits(&a.logits), bits(&b.logits), "request {}", a.id);
            assert_eq!(a.pred, b.pred);
            assert_eq!(a.completion_tick, b.completion_tick);
            assert_eq!(a.batch_size, b.batch_size);
        }
        assert_eq!(s0.summary_json(), si.summary_json(), "stats must replay identically");
    }
    // Routing gate: both tenants are expanding pairs (FP8/FP8alt→FP16);
    // every one of their GEMMs must have fed the engine packed.
    for (t, counters) in s0.tenants.iter().enumerate() {
        assert!(counters.gemm_calls > 0, "tenant {t} served no GEMMs");
        assert_eq!(
            counters.packed_runs, counters.gemm_calls,
            "tenant {t}: every serving GEMM must take the zero-repack route"
        );
    }
}

#[test]
fn per_request_outputs_are_independent_of_batch_composition() {
    // Serve the same feature row once in a crowded batch and once
    // nearly alone: the logits must not change — the structural
    // property the determinism gates rest on.
    let session = session();
    let model = frozen(&session, PrecisionPolicy::hfp8(), 4);
    let plan =
        session.server().tenant("only", model).max_batch(32).max_wait_ticks(0).build().expect("plan");
    let mut rng = Rng::new(5);
    let probe = sim::sample_features(&mut rng, 8);
    let crowd: Vec<Vec<f64>> = (0..23).map(|_| sim::sample_features(&mut rng, 8)).collect();

    let mut a = plan.server();
    let probe_id = a.submit(0, probe.clone(), None).expect("submit");
    for f in &crowd {
        a.submit(0, f.clone(), None).expect("submit");
    }
    let crowded = a.drain().expect("drain");
    let crowded_probe = crowded.iter().find(|r| r.id == probe_id).expect("probe served");
    assert_eq!(crowded_probe.batch_size, 24);

    let mut b = plan.server();
    let lone_id = b.submit(0, probe, None).expect("submit");
    let lone = b.drain().expect("drain");
    let lone_probe = lone.iter().find(|r| r.id == lone_id).expect("probe served");
    assert_eq!(lone_probe.batch_size, 1);

    assert_eq!(bits(&crowded_probe.logits), bits(&lone_probe.logits));
    assert_eq!(crowded_probe.pred, lone_probe.pred);
}

#[test]
fn whole_batch_coalesces_pads_and_runs_to_completion() {
    // The legacy reference: one cohort at a time, occupying the
    // pipeline for a full latency (3 waves for the 3-layer MLP).
    let session = session();
    let model = frozen(&session, PrecisionPolicy::hfp8(), 2);
    let plan = session
        .server()
        .tenant("t", model)
        .max_batch(8)
        .max_wait_ticks(2)
        .batching(super::batcher::BatchMode::WholeBatch)
        .build()
        .expect("plan");
    let mut server = plan.server();
    let mut rng = Rng::new(1);
    // 19 requests at tick 0: the first batch of 8 dispatches
    // immediately (size trigger); the rest wait for the pipeline.
    for _ in 0..19 {
        server.submit(0, sim::sample_features(&mut rng, 8), None).expect("submit");
    }
    assert!(server.tick().expect("tick 0").is_empty(), "wave 1 of 3 in flight");
    assert!(server.tick().expect("tick 1").is_empty(), "wave 2 of 3 in flight");
    let first = server.tick().expect("tick 2");
    assert_eq!(first.len(), 8);
    // Dispatched at tick 0, three waves, ready one quantum after the last.
    assert!(first.iter().all(|r| r.batch_size == 8 && r.completion_tick == 3));
    assert_eq!(server.pending(), 11);
    let rest = server.drain().expect("drain");
    assert_eq!(rest.len(), 11);
    // Second full batch dispatches at tick 3 (pipeline empty again),
    // the remainder of 3 at tick 6 (wait trigger: 6 - 0 >= 2).
    assert!(rest[..8].iter().all(|r| r.batch_size == 8 && r.completion_tick == 6));
    assert!(rest[8..].iter().all(|r| r.batch_size == 3 && r.completion_tick == 9));
    let stats = server.stats();
    assert_eq!(stats.batch_hist.get(&8), Some(&2));
    assert_eq!(stats.batch_hist.get(&3), Some(&1));
    assert_eq!(stats.completed, 19);
    assert_eq!(stats.queue_depth_max, 19);
    assert_eq!(stats.waves, 9, "three cohorts x three layers");
    assert_eq!(stats.p50(), 6);
    assert_eq!(stats.latency_percentile(1.0), 9);
}

#[test]
fn continuous_pipelines_cohorts_instead_of_draining() {
    // The tentpole's timing win in miniature: a late request joins at
    // the next layer-0 boundary and pipelines alongside the running
    // cohort (completing at arrival + pipeline latency), instead of
    // waiting for the whole previous batch to drain.
    use super::batcher::BatchMode;
    let session = session();
    let model = frozen(&session, PrecisionPolicy::hfp8(), 2);
    let mut rng = Rng::new(6);
    let f0 = sim::sample_features(&mut rng, 8);
    let f1 = sim::sample_features(&mut rng, 8);
    let run = |mode: BatchMode| {
        let plan = session
            .server()
            .tenant("t", model.clone())
            .max_batch(8)
            .max_wait_ticks(2)
            .batching(mode)
            .build()
            .expect("plan");
        let mut server = plan.server();
        server.submit(0, f0.clone(), None).expect("submit r0");
        // One tick elapses before the second request arrives.
        assert!(server.tick().expect("tick 0").is_empty());
        server.submit(0, f1.clone(), None).expect("submit r1");
        let mut out = server.drain().expect("drain");
        out.sort_by_key(|r| r.id);
        assert_eq!(out.len(), 2);
        (out[0].completion_tick, out[1].completion_tick, bits(&out[0].logits), bits(&out[1].logits))
    };
    let (c0, c1, cl0, cl1) = run(BatchMode::Continuous);
    let (w0, w1, wl0, wl1) = run(BatchMode::WholeBatch);
    // r0 admitted at tick 0 either way: 3 waves, done at tick 3.
    assert_eq!(c0, 3);
    assert_eq!(w0, 3);
    // r1 (arrival tick 1): continuous admits it at tick 1 -> done at 4;
    // whole-batch waits for the pipeline to drain (tick 3) plus the
    // wait trigger (1 + max_wait = 3) -> done at 6.
    assert_eq!(c1, 4, "continuous joins the next layer-0 boundary");
    assert_eq!(w1, 6, "legacy runs the first batch to completion");
    // Per-row independence: identical logits under either schedule.
    assert_eq!(cl0, wl0);
    assert_eq!(cl1, wl1);
}

#[test]
fn feasible_deadlines_are_met_and_infeasible_ones_are_counted_missed() {
    let session = session();
    let model = frozen(&session, PrecisionPolicy::hfp8(), 2);
    let plan = session
        .server()
        .tenant("t", model)
        .max_batch(64)
        .max_wait_ticks(100)
        .batching(super::batcher::BatchMode::WholeBatch)
        .build()
        .expect("plan");
    let mut server = plan.server();
    let mut rng = Rng::new(2);
    // Due at tick 5: the deadline trigger dispatches one pipeline
    // latency (3 ticks for the 3-layer MLP) early — tick 2 — so the
    // result lands exactly on time, long before the 100-tick wait clock.
    server.submit(0, sim::sample_features(&mut rng, 8), Some(5)).expect("submit");
    assert!(server.tick().expect("tick 0").is_empty());
    assert!(server.tick().expect("tick 1").is_empty());
    assert!(server.tick().expect("tick 2").is_empty(), "dispatched, wave 1 of 3");
    assert!(server.tick().expect("tick 3").is_empty(), "wave 2 of 3");
    let due = server.tick().expect("tick 4");
    assert_eq!(due.len(), 1);
    assert_eq!(due[0].completion_tick, 5);
    assert!(!due[0].deadline_missed, "a feasible deadline is met by construction");
    assert_eq!(server.stats().deadline_misses, 0);
    // A sub-latency deadline (due the instant it arrives) is infeasible:
    // it dispatches immediately but needs a full pipeline latency — the
    // miss counter must actually count it.
    server.submit(0, sim::sample_features(&mut rng, 8), Some(0)).expect("submit");
    let late = server.drain().expect("drain");
    assert_eq!(late.len(), 1);
    assert!(late[0].deadline_missed, "sub-latency deadline must be recorded as missed");
    assert_eq!(server.stats().deadline_misses, 1);
}

#[test]
fn replay_fast_forwards_sparse_traces() {
    // Arrivals 10k ticks apart: replay must skip the quiet gaps (O(events),
    // not O(tick span)) while the virtual clock still covers the full
    // span and dispatch timing stays exactly per-policy.
    let session = session();
    let model = frozen(&session, PrecisionPolicy::hfp8(), 2);
    let plan = session
        .server()
        .tenant("t", model)
        .max_batch(4)
        .max_wait_ticks(1)
        .batching(super::batcher::BatchMode::WholeBatch)
        .build()
        .expect("plan");
    let mut server = plan.server();
    let mut rng = Rng::new(8);
    let events = [0u64, 10_000, 20_000]
        .into_iter()
        .map(|tick| sim::TraceEvent {
            tick,
            tenant: 0,
            features: sim::sample_features(&mut rng, 8),
            deadline_in: None,
        })
        .collect();
    let trace = Trace { events };
    let responses = sim::replay(&mut server, &trace).expect("replay");
    assert_eq!(responses.len(), 3);
    let ticks: Vec<u64> = responses.iter().map(|r| r.completion_tick).collect();
    // Dispatch after exactly max_wait_ticks, then one wave per layer
    // (3) with the result ready one quantum after the last.
    assert_eq!(ticks, vec![4, 10_004, 20_004]);
    assert!(server.now() >= 20_003);
    assert_eq!(server.stats().queue_depth_max, 1);
}

#[test]
fn closed_loop_serves_every_client_deterministically() {
    let session = session();
    let plan = two_tenant_plan(&session, 2);
    let run = |plan: &crate::api::ServePlan| {
        let mut server = plan.server();
        sim::closed_loop(&mut server, 8, 64, 1, 99, None).expect("closed loop")
    };
    let a = run(&plan);
    let b = run(&plan);
    assert_eq!(a.len(), 64);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.id, y.id);
        assert_eq!(bits(&x.logits), bits(&y.logits));
        assert_eq!(x.completion_tick, y.completion_tick);
    }
    // Both tenants saw traffic (clients round-robin over tenants).
    let tenants: std::collections::BTreeSet<usize> = a.iter().map(|r| r.tenant).collect();
    assert_eq!(tenants.len(), 2);
}

#[test]
fn mixed_precision_tenants_serve_side_by_side() {
    // An expanding-pair tenant and an FMA-family (fp32) tenant share
    // one server; routing counters keep them apart.
    let session = session();
    let hfp8 = frozen(&session, PrecisionPolicy::hfp8(), 2);
    let fp32 = frozen(&session, PrecisionPolicy::fp32(), 2);
    let plan = session
        .server()
        .tenant("hfp8", hfp8)
        .tenant("fp32", fp32)
        .max_batch(8)
        .max_wait_ticks(1)
        .build()
        .expect("plan");
    let mut server = plan.server();
    let mut rng = Rng::new(4);
    for t in [0usize, 1, 0, 1, 0, 1] {
        server.submit(t, sim::sample_features(&mut rng, 8), None).expect("submit");
    }
    let responses = server.drain().expect("drain");
    assert_eq!(responses.len(), 6);
    let stats = server.stats();
    assert!(stats.tenants[0].gemm_calls > 0 && stats.tenants[1].gemm_calls > 0);
    assert_eq!(stats.tenants[0].packed_runs, stats.tenants[0].gemm_calls, "hfp8 packs");
    assert_eq!(stats.tenants[1].packed_runs, 0, "fp32 runs the FMA family (no packed route)");
}

// --------------------------------------------------- plan validation

#[test]
fn serve_plan_rejects_bad_configurations() {
    let session = session();
    let model = frozen(&session, PrecisionPolicy::hfp8(), 1);

    let err = session.server().build().unwrap_err();
    assert!(err.to_string().contains("at least one tenant"), "{err}");

    let err = session.server().tenant("a", model.clone()).max_batch(0).build().unwrap_err();
    assert!(err.to_string().contains("max_batch"), "{err}");
    assert!(err.to_string().contains("--max-batch"), "{err}");

    let err = session.server().tenant("a", model.clone()).shards(0).build().unwrap_err();
    assert!(err.to_string().contains("shard count"), "{err}");

    // Unbounded wait knobs would overflow tick arithmetic downstream.
    let err =
        session.server().tenant("a", model.clone()).max_wait_ticks(u64::MAX).build().unwrap_err();
    assert!(err.to_string().contains("max_wait_ticks"), "{err}");

    let err = session
        .server()
        .tenant("a", model.clone())
        .tenant("a", model.clone())
        .build()
        .unwrap_err();
    assert!(err.to_string().contains("duplicate tenant name"), "{err}");

    // Admission-control knobs validate at build too.
    let err = session.server().tenant("a", model.clone()).queue_cap(0).build().unwrap_err();
    assert!(err.to_string().contains("queue_cap"), "{err}");

    let err = session
        .server()
        .tenant("a", model.clone())
        .rate_limit("nobody", 2.0, 8)
        .build()
        .unwrap_err();
    assert!(err.to_string().contains("unknown tenant 'nobody'"), "{err}");

    let err = session
        .server()
        .tenant("a", model.clone())
        .rate_limit("a", 2.0, 8)
        .rate_limit("a", 4.0, 8)
        .build()
        .unwrap_err();
    assert!(err.to_string().contains("duplicate rate limit"), "{err}");

    let err = session
        .server()
        .tenant("a", model.clone())
        .rate_limit("a", -1.0, 8)
        .build()
        .unwrap_err();
    assert!(err.to_string().contains("rate limit for tenant 'a'"), "{err}");

    let cycle = Session::builder().mode(crate::kernels::gemm::ExecMode::CycleAccurate).build();
    let err = cycle.server().tenant("a", model).build().unwrap_err();
    assert!(err.to_string().contains("functional"), "{err}");
}

#[test]
fn server_rejects_malformed_submissions() {
    let session = session();
    let model = frozen(&session, PrecisionPolicy::hfp8(), 1);
    let plan = session.server().tenant("t", model).build().expect("plan");
    let mut server = plan.server();
    let err = server.submit(5, vec![0.0; 8], None).unwrap_err();
    assert!(err.to_string().contains("unknown tenant"), "{err}");
    let err = server.submit(0, vec![0.0; 3], None).unwrap_err();
    assert!(err.to_string().contains("features"), "{err}");
    // try_submit makes the same structural checks typed errors (a shed
    // is an Ok(Admission::Shed), a malformed submission never is).
    let err = server.try_submit(5, vec![0.0; 8], None).unwrap_err();
    assert!(err.to_string().contains("unknown tenant"), "{err}");
}

// ------------------------------------------------- admission control

#[test]
fn token_bucket_sheds_over_budget_and_refills_with_virtual_time() {
    use super::admission::{Admission, ShedReason};
    let session = session();
    let model = frozen(&session, PrecisionPolicy::hfp8(), 2);
    // 1 request/tick sustained, 2 of burst headroom.
    let plan = session
        .server()
        .tenant("t", model)
        .max_batch(8)
        .rate_limit("t", 1.0, 2)
        .build()
        .expect("plan");
    let mut server = plan.server();
    let mut rng = Rng::new(3);
    let mut feat = || sim::sample_features(&mut rng, 8);
    // Tick 0: the full bucket admits the 2-token burst, then sheds.
    assert!(matches!(server.try_submit(0, feat(), None).expect("a"), Admission::Admitted(_)));
    assert!(matches!(server.try_submit(0, feat(), None).expect("b"), Admission::Admitted(_)));
    let shed = server.try_submit(0, feat(), None).expect("c");
    assert_eq!(shed, Admission::Shed(ShedReason::RateLimited));
    assert!(shed.is_shed() && shed.id().is_none());
    // The plain submit wrapper turns the shed into a typed error.
    let err = server.submit(0, feat(), None).unwrap_err();
    assert!(err.to_string().contains("rate-limited"), "{err}");
    // One virtual tick refills one token.
    server.tick().expect("tick");
    assert!(matches!(server.try_submit(0, feat(), None).expect("d"), Admission::Admitted(_)));
    assert_eq!(server.stats().shed(), 2);
    assert_eq!(server.stats().shed_rate_limited, 2);
    // Every admitted request still completes; the sheds never entered a
    // queue, so the books balance.
    let out = server.drain().expect("drain");
    assert_eq!(out.len(), 3);
    assert_eq!(server.stats().submitted, 3);
    assert_eq!(server.stats().completed, 3);
}

#[test]
fn bounded_queues_shed_overflow_without_burning_tokens() {
    use super::admission::{Admission, ShedReason};
    let session = session();
    let model = frozen(&session, PrecisionPolicy::hfp8(), 2);
    let plan = session
        .server()
        .tenant("t", model)
        .max_batch(8)
        .queue_cap(2)
        .rate_limit("t", 1.0, 3)
        .build()
        .expect("plan");
    assert_eq!(plan.queue_cap(), Some(2));
    let mut server = plan.server();
    let mut rng = Rng::new(4);
    let mut feat = || sim::sample_features(&mut rng, 8);
    assert!(matches!(server.try_submit(0, feat(), None).expect("a"), Admission::Admitted(_)));
    assert!(matches!(server.try_submit(0, feat(), None).expect("b"), Admission::Admitted(_)));
    // Queue full: shed as QueueFull, and — checked before the bucket —
    // the third token survives for after the queue drains below cap.
    let shed = server.try_submit(0, feat(), None).expect("c");
    assert_eq!(shed, Admission::Shed(ShedReason::QueueFull));
    assert_eq!(server.stats().shed_queue_full, 1);
    assert_eq!(server.stats().shed_rate_limited, 0);
    // The admit pass empties the queue into a cohort; the saved token
    // admits the retry.
    server.tick().expect("tick");
    assert!(matches!(server.try_submit(0, feat(), None).expect("d"), Admission::Admitted(_)));
    let out = server.drain().expect("drain");
    assert_eq!(out.len(), 3);
    assert_eq!(server.stats().completed, 3);
    assert_eq!(server.stats().shed(), 1);
}

#[test]
fn continuous_waves_are_slo_weighted_when_oversubscribed() {
    // max_batch 2 with 4 queued requests: the wave takes the two
    // nearest deadlines first (ties and the deadline-free tail by id),
    // so near-SLO rows complete a full pipeline latency earlier.
    let session = session();
    let model = frozen(&session, PrecisionPolicy::hfp8(), 2);
    let plan = session.server().tenant("t", model).max_batch(2).build().expect("plan");
    let mut server = plan.server();
    let mut rng = Rng::new(9);
    let ids = [
        server.submit(0, sim::sample_features(&mut rng, 8), None).expect("r0"),
        server.submit(0, sim::sample_features(&mut rng, 8), Some(10)).expect("r1"),
        server.submit(0, sim::sample_features(&mut rng, 8), Some(2)).expect("r2"),
        server.submit(0, sim::sample_features(&mut rng, 8), None).expect("r3"),
    ];
    let mut out = server.drain().expect("drain");
    out.sort_by_key(|r| r.id);
    assert_eq!(out.len(), 4);
    let tick_of = |id: u64| out.iter().find(|r| r.id == id).expect("served").completion_tick;
    // First wave (tick 0): r2 (due 2) and r1 (due 10) -> done at 3.
    assert_eq!(tick_of(ids[2]), 3);
    assert_eq!(tick_of(ids[1]), 3);
    // Second wave (tick 1): the deadline-free pair -> done at 4.
    assert_eq!(tick_of(ids[0]), 4);
    assert_eq!(tick_of(ids[3]), 4);
}

// --------------------------------------- executor / plan-instance reuse

#[test]
fn serve_dispatch_backends_bit_identical_at_shards_1_and_4() {
    // The differential suite's serving leg: the same trace replayed on
    // the pooled executor, the legacy scoped-thread backend and the
    // serial path — at shard counts {1, 4} — must produce bit-identical
    // responses and byte-identical stats JSON.
    use crate::util::parallel::{with_dispatch, Dispatch};
    let session = session();
    let model = frozen(&session, PrecisionPolicy::hfp8(), 4);
    let trace = Trace::open_loop(17, &[model.in_dim()], 40, 0.4, Some(32)).expect("trace");
    let run = |mode: Dispatch, shards: usize| {
        with_dispatch(mode, || {
            let plan = session
                .server()
                .tenant("t", model.clone())
                .max_batch(8)
                .max_wait_ticks(2)
                .shards(shards)
                .build()
                .expect("plan");
            let mut server = plan.server();
            let responses = sim::replay(&mut server, &trace).expect("replay");
            let payload: Vec<(u64, u64, Vec<u64>)> = responses
                .iter()
                .map(|r| (r.id, r.completion_tick, bits(&r.logits)))
                .collect();
            (payload, server.stats().summary_json())
        })
    };
    let want = run(Dispatch::Pool, 1);
    for shards in [1usize, 4] {
        for mode in [Dispatch::Pool, Dispatch::Scoped, Dispatch::Serial] {
            let got = run(mode, shards);
            assert_eq!(got, want, "{mode:?} @ {shards} shards diverged");
        }
    }
}

#[test]
fn serve_shards_reuse_compiled_plan_instances() {
    // Shards pre-warm per-layer instances at the boundary padded batch
    // shapes, so a steady stream of full batches compiles nothing new:
    // builds stay flat while reuses track traffic.
    let session = session();
    let model = frozen(&session, PrecisionPolicy::hfp8(), 4);
    let in_dim = model.in_dim();
    let layers = model.layers().len() as u64;
    let plan = session
        .server()
        .tenant("t", model)
        .max_batch(8)
        .max_wait_ticks(2)
        .shards(2)
        .build()
        .expect("plan");
    let mut server = plan.server();
    let (builds0, reuses0) = server.plan_counters();
    // Warm-up covered ROW_PAD == pad_rows(max_batch) == 8 here: one
    // instance per layer per shard, zero executions yet.
    assert_eq!(builds0, 2 * layers, "pre-warmed instances per shard per layer");
    assert_eq!(reuses0, 0);
    let mut rng = Rng::new(5);
    let mut drive = |server: &mut super::worker::Server| {
        for _ in 0..8 {
            let f = sim::sample_features(&mut rng, in_dim);
            server.submit(0, f, None).expect("submit");
        }
        server.drain().expect("drain");
    };
    drive(&mut server);
    let (builds1, reuses1) = server.plan_counters();
    assert_eq!(builds1, builds0, "full-batch dispatch must not compile new instances");
    assert!(reuses1 >= layers, "dispatch must execute through cached instances");
    drive(&mut server);
    let (builds2, reuses2) = server.plan_counters();
    assert_eq!(builds2, builds1, "steady state compiles nothing");
    assert!(reuses2 > reuses1);
    // Routing counters still flow into the stats as before.
    assert!(server.stats().gemm_calls() >= 2 * layers);
    assert_eq!(server.stats().packed_runs(), server.stats().gemm_calls(), "hfp8 stays packed");
}
