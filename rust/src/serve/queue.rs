//! Request/response types and the per-tenant pending queue.
//!
//! A [`Request`] is one inference call: a feature row for one tenant's
//! model, stamped with its arrival tick and an optional deadline. The
//! server parks requests in per-tenant [`TenantQueue`]s until the
//! dynamic batcher ([`crate::serve::batcher`]) coalesces them into
//! lane-padded GEMM batches. Time is **virtual** throughout — ticks,
//! not wall clock — so a whole traffic trace replays bit-for-bit.

use std::collections::VecDeque;

/// One queued inference request.
#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    /// Server-assigned id, unique and monotone in submission order.
    pub id: u64,
    /// Index of the tenant whose model serves this request.
    pub tenant: usize,
    /// Feature row, `in_dim` wide (the tenant model's input width).
    pub features: Vec<f64>,
    /// Virtual tick the request entered the queue.
    pub arrival_tick: u64,
    /// Absolute tick the response is due, if the client set a deadline.
    pub deadline_tick: Option<u64>,
}

/// One completed inference.
#[derive(Clone, Debug, PartialEq)]
pub struct Response {
    /// The request's id.
    pub id: u64,
    /// The tenant that served it.
    pub tenant: usize,
    /// Logit row (`out_dim` wide, on the tenant policy's accumulation
    /// grid) — per-request bits are independent of batch composition
    /// and shard count, which is what makes replay deterministic.
    pub logits: Vec<f64>,
    /// Argmax over the tenant's logical classes.
    pub pred: usize,
    /// Tick the request arrived.
    pub arrival_tick: u64,
    /// Tick the results are ready: the dispatch tick plus the uniform
    /// service quantum ([`crate::serve::batcher::SERVICE_TICKS`]).
    pub completion_tick: u64,
    /// Logical batch size (requests coalesced, before lane padding).
    pub batch_size: usize,
    /// True when a deadline was set and the completion tick passed it.
    pub deadline_missed: bool,
}

impl Response {
    /// End-to-end latency in virtual ticks: queueing + batching wait
    /// plus the service quantum.
    pub fn latency_ticks(&self) -> u64 {
        self.completion_tick - self.arrival_tick
    }
}

/// FIFO of pending requests for one tenant.
#[derive(Debug, Default)]
pub struct TenantQueue {
    pending: VecDeque<Request>,
}

impl TenantQueue {
    /// An empty queue.
    pub fn new() -> Self {
        TenantQueue::default()
    }

    /// Park a request.
    pub fn push(&mut self, r: Request) {
        self.pending.push_back(r);
    }

    /// Pending requests.
    pub fn len(&self) -> usize {
        self.pending.len()
    }

    /// True when nothing is pending.
    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Arrival tick of the oldest pending request.
    pub fn oldest_arrival(&self) -> Option<u64> {
        self.pending.front().map(|r| r.arrival_tick)
    }

    /// Earliest deadline among pending requests, if any carries one.
    pub fn earliest_deadline(&self) -> Option<u64> {
        self.pending.iter().filter_map(|r| r.deadline_tick).min()
    }

    /// Dequeue up to `n` requests in FIFO order.
    pub fn take(&mut self, n: usize) -> Vec<Request> {
        let n = n.min(self.pending.len());
        self.pending.drain(..n).collect()
    }
}
