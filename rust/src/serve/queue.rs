//! Request/response types and the per-tenant pending queue.
//!
//! A [`Request`] is one inference call: a feature row for one tenant's
//! model, stamped with its arrival tick and an optional deadline. The
//! server parks requests in per-tenant [`TenantQueue`]s until the
//! dynamic batcher ([`crate::serve::batcher`]) coalesces them into
//! lane-padded GEMM batches. Time is **virtual** throughout — ticks,
//! not wall clock — so a whole traffic trace replays bit-for-bit.

use std::collections::VecDeque;

/// One queued inference request.
#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    /// Server-assigned id, unique and monotone in submission order.
    pub id: u64,
    /// Index of the tenant whose model serves this request.
    pub tenant: usize,
    /// Feature row, `in_dim` wide (the tenant model's input width).
    pub features: Vec<f64>,
    /// Virtual tick the request entered the queue.
    pub arrival_tick: u64,
    /// Absolute tick the response is due, if the client set a deadline.
    pub deadline_tick: Option<u64>,
}

/// One completed inference.
#[derive(Clone, Debug, PartialEq)]
pub struct Response {
    /// The request's id.
    pub id: u64,
    /// The tenant that served it.
    pub tenant: usize,
    /// Logit row (`out_dim` wide, on the tenant policy's accumulation
    /// grid) — per-request bits are independent of batch composition
    /// and shard count, which is what makes replay deterministic.
    pub logits: Vec<f64>,
    /// Argmax over the tenant's logical classes.
    pub pred: usize,
    /// Tick the request arrived.
    pub arrival_tick: u64,
    /// Tick the results are ready: the cohort's final layer wave plus
    /// the uniform service quantum
    /// ([`crate::serve::batcher::SERVICE_TICKS`]) — i.e. the admission
    /// tick plus [`crate::serve::batcher::pipeline_latency_ticks`].
    pub completion_tick: u64,
    /// Logical batch size (requests coalesced, before lane padding).
    pub batch_size: usize,
    /// True when a deadline was set and the completion tick passed it.
    pub deadline_missed: bool,
}

impl Response {
    /// End-to-end latency in virtual ticks: queueing + batching wait
    /// plus the service quantum.
    pub fn latency_ticks(&self) -> u64 {
        self.completion_tick - self.arrival_tick
    }
}

/// FIFO of pending requests for one tenant.
#[derive(Debug, Default)]
pub struct TenantQueue {
    pending: VecDeque<Request>,
}

impl TenantQueue {
    /// An empty queue.
    pub fn new() -> Self {
        TenantQueue::default()
    }

    /// Park a request.
    pub fn push(&mut self, r: Request) {
        self.pending.push_back(r);
    }

    /// Pending requests.
    pub fn len(&self) -> usize {
        self.pending.len()
    }

    /// True when nothing is pending.
    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Arrival tick of the oldest pending request.
    pub fn oldest_arrival(&self) -> Option<u64> {
        self.pending.front().map(|r| r.arrival_tick)
    }

    /// Earliest deadline among pending requests, if any carries one.
    pub fn earliest_deadline(&self) -> Option<u64> {
        self.pending.iter().filter_map(|r| r.deadline_tick).min()
    }

    /// Dequeue up to `n` requests in FIFO order.
    pub fn take(&mut self, n: usize) -> Vec<Request> {
        let n = n.min(self.pending.len());
        self.pending.drain(..n).collect()
    }

    /// Dequeue up to `n` requests, SLO-weighted: when more than `n`
    /// are pending, the `n` most urgent — nearest deadline first,
    /// deadline-free rows last, request id breaking ties — are
    /// selected; the selected rows are returned in FIFO (id) order so
    /// the batch row layout stays deterministic, and the rest keep
    /// their queue order. When everything fits in one wave this is
    /// exactly [`TenantQueue::take`].
    pub fn take_prioritized(&mut self, n: usize) -> Vec<Request> {
        if self.pending.len() <= n {
            return self.take(n);
        }
        let mut order: Vec<usize> = (0..self.pending.len()).collect();
        order.sort_by_key(|&i| {
            (self.pending[i].deadline_tick.unwrap_or(u64::MAX), self.pending[i].id)
        });
        let mut pick = vec![false; self.pending.len()];
        for &i in order.iter().take(n) {
            pick[i] = true;
        }
        let mut taken = Vec::with_capacity(n);
        let mut rest = VecDeque::with_capacity(self.pending.len() - n);
        for (i, r) in self.pending.drain(..).enumerate() {
            if pick[i] {
                taken.push(r);
            } else {
                rest.push_back(r);
            }
        }
        self.pending = rest;
        taken
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, deadline: Option<u64>) -> Request {
        Request { id, tenant: 0, features: vec![], arrival_tick: 0, deadline_tick: deadline }
    }

    #[test]
    fn prioritized_take_prefers_near_deadlines() {
        let mut q = TenantQueue::new();
        q.push(req(0, None)); // deadline-free: least urgent
        q.push(req(1, Some(10)));
        q.push(req(2, Some(5))); // most urgent
        q.push(req(3, Some(10))); // ties with id 1, loses on id
        let wave = q.take_prioritized(2);
        // Urgency picks {2, 1}; the wave itself is in id (FIFO) order.
        assert_eq!(wave.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2]);
        // The remainder keeps queue order.
        assert_eq!(q.take(10).iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 3]);
    }

    #[test]
    fn prioritized_take_degenerates_to_fifo_when_everything_fits() {
        let mut q = TenantQueue::new();
        q.push(req(0, None));
        q.push(req(1, Some(3)));
        let wave = q.take_prioritized(8);
        assert_eq!(wave.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        assert!(q.is_empty());
    }
}
