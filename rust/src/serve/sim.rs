//! Seeded traffic generation and the virtual-time replay driver.
//!
//! There is no wall clock anywhere in the serving pipeline: arrivals,
//! dispatches and completions all live on the tick axis, and traffic is
//! generated from a [`crate::util::rng::Rng`] seed. A million-request
//! trace is therefore a pure function of `(seed, knobs)` — replaying it
//! twice, or on a different shard count, yields bit-identical
//! responses (the determinism tests pin exactly that).
//!
//! Three load models:
//!
//! * **open loop** ([`Trace::open_loop`]) — arrivals are an exponential
//!   (Poisson-process) stream that does not react to the server:
//!   the back-pressure-free regime where queues and batches build.
//! * **bursty** ([`Trace::bursty`]) — an on/off Markov-modulated
//!   Poisson process: exponential dwell times alternate an ON phase
//!   (Poisson arrivals at the given rate) with a silent OFF phase.
//!   The offered load arrives in bursts far above the mean rate —
//!   exactly the regime admission control and shed paths exist for.
//! * **closed loop** ([`closed_loop`]) — a fixed population of clients,
//!   each submitting its next request a think-time after its previous
//!   response: arrival rate self-throttles to the server's throughput.

use crate::util::error::Result;
use crate::util::rng::Rng;
use crate::{bail, ensure};

use super::queue::Response;
use super::worker::Server;

/// One scheduled arrival in a pre-generated trace.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    /// Arrival tick.
    pub tick: u64,
    /// Target tenant index.
    pub tenant: usize,
    /// Feature row for that tenant's model.
    pub features: Vec<f64>,
    /// Deadline budget in ticks from arrival, if any.
    pub deadline_in: Option<u64>,
}

/// A replayable traffic trace (events in non-decreasing tick order).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Trace {
    /// The scheduled arrivals.
    pub events: Vec<TraceEvent>,
}

/// Sample one synthetic feature row: a 2-D point run through the
/// datasets' own embedding ([`crate::nn::data::embed_padded`] —
/// `[x, y, r², 1]` in f32, zero lane padding), so a model trained on
/// spiral/rings traffic sees bit-faithfully in-distribution requests.
pub fn sample_features(rng: &mut Rng, in_dim: usize) -> Vec<f64> {
    let (px, py) = (rng.gaussian() * 0.5, rng.gaussian() * 0.5);
    crate::nn::data::embed_padded(px, py, in_dim)
}

impl Trace {
    /// Generate an open-loop trace: `n` requests, exponential
    /// inter-arrival gaps with the given mean (in ticks), tenants drawn
    /// uniformly. `in_dims[t]` is tenant `t`'s feature width.
    /// Deterministic in `(seed, n, mean_gap_ticks, in_dims, deadline_in)`.
    pub fn open_loop(
        seed: u64,
        in_dims: &[usize],
        n: usize,
        mean_gap_ticks: f64,
        deadline_in: Option<u64>,
    ) -> Result<Trace> {
        ensure!(!in_dims.is_empty(), "a trace needs at least one tenant");
        ensure!(
            mean_gap_ticks >= 0.0 && mean_gap_ticks.is_finite(),
            "mean inter-arrival gap must be finite and non-negative, got {mean_gap_ticks}"
        );
        for (t, &d) in in_dims.iter().enumerate() {
            ensure!(d >= 4, "tenant {t} feature width ({d}) must be at least 4 (the embedding)");
        }
        let mut rng = Rng::new(seed);
        let mut tick = 0u64;
        let mut events = Vec::with_capacity(n);
        for _ in 0..n {
            // Exponential gap, floored onto the tick grid. The f64→u64
            // cast saturates and the add saturates, so an extreme mean
            // gap cannot wrap the clock into a non-monotonic trace.
            let u = rng.uniform();
            tick = tick.saturating_add((-(1.0 - u).ln() * mean_gap_ticks) as u64);
            let tenant = rng.below(in_dims.len() as u64) as usize;
            events.push(TraceEvent {
                tick,
                tenant,
                features: sample_features(&mut rng, in_dims[tenant]),
                deadline_in,
            });
        }
        Ok(Trace { events })
    }

    /// Generate a bursty trace: an on/off Markov-modulated Poisson
    /// process. During an ON phase (mean dwell `mean_on_ticks`),
    /// arrivals are exponential with mean gap `mean_gap_ticks`; an OFF
    /// phase (mean dwell `mean_off_ticks`) is silent — the arrival
    /// clock pauses and resumes when the next ON phase starts. Tenants
    /// are drawn uniformly, `in_dims[t]` is tenant `t`'s feature
    /// width. Deterministic in every argument, ticks non-decreasing.
    pub fn bursty(
        seed: u64,
        in_dims: &[usize],
        n: usize,
        mean_gap_ticks: f64,
        mean_on_ticks: f64,
        mean_off_ticks: f64,
        deadline_in: Option<u64>,
    ) -> Result<Trace> {
        ensure!(!in_dims.is_empty(), "a trace needs at least one tenant");
        for (name, v, positive) in [
            ("mean inter-arrival gap", mean_gap_ticks, false),
            ("mean ON dwell", mean_on_ticks, true),
            ("mean OFF dwell", mean_off_ticks, false),
        ] {
            ensure!(
                v.is_finite() && v <= 1e12 && (if positive { v > 0.0 } else { v >= 0.0 }),
                "{name} must be finite, {} and at most 1e12 ticks, got {v}",
                if positive { "positive" } else { "non-negative" }
            );
        }
        for (t, &d) in in_dims.iter().enumerate() {
            ensure!(d >= 4, "tenant {t} feature width ({d}) must be at least 4 (the embedding)");
        }
        let mut rng = Rng::new(seed);
        fn exp(rng: &mut Rng, mean: f64) -> f64 {
            -(1.0 - rng.uniform()).ln() * mean
        }
        // Continuous virtual time `t`; `on_left` is the remainder of
        // the current ON dwell. An arrival gap that outlives the ON
        // phase carries its remainder across the OFF dwell (the
        // arrival clock pauses while OFF — the standard MMPP).
        let mut t = 0f64;
        let mut on_left = exp(&mut rng, mean_on_ticks);
        let mut events = Vec::with_capacity(n);
        for _ in 0..n {
            let mut gap = exp(&mut rng, mean_gap_ticks);
            // `>=` so an exhausted ON budget (on_left == 0) always
            // rolls into the next dwell pair: forward progress even at
            // the boundary, since every pass consumes RNG draws and
            // adds the OFF dwell.
            while gap >= on_left {
                gap -= on_left;
                t += on_left + exp(&mut rng, mean_off_ticks);
                on_left = exp(&mut rng, mean_on_ticks);
            }
            t += gap;
            on_left -= gap;
            // The f64→u64 cast saturates, so extreme dwell means cannot
            // wrap the clock into a non-monotonic trace.
            let tick = t as u64;
            let tenant = rng.below(in_dims.len() as u64) as usize;
            events.push(TraceEvent {
                tick,
                tenant,
                features: sample_features(&mut rng, in_dims[tenant]),
                deadline_in,
            });
        }
        Ok(Trace { events })
    }

    /// Scheduled arrivals.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// True when the trace is empty.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

/// Replay a trace against a server from its current tick: submit each
/// event when its tick comes up, tick through quiet gaps, and drain the
/// tail. Returns all responses in completion order (sorted by id within
/// each tick). Submissions go through admission control
/// ([`Server::try_submit`]): a shed event is counted in the server's
/// stats and simply produces no response — exactly what an open-loop
/// client would observe — so a rate-limited or queue-capped replay
/// stays deterministic instead of erroring.
pub fn replay(server: &mut Server, trace: &Trace) -> Result<Vec<Response>> {
    let mut responses = Vec::new();
    let base = server.now();
    let mut idx = 0;
    while idx < trace.events.len() {
        // Fast-forward quiet stretches: jump to the next arrival or the
        // next tick the batcher could dispatch, whichever comes first
        // (keeps sparse traces O(events), not O(tick span)).
        server.advance_to(base.saturating_add(trace.events[idx].tick));
        let now = server.now();
        while idx < trace.events.len() && base.saturating_add(trace.events[idx].tick) <= now {
            let e = &trace.events[idx];
            server.try_submit(e.tenant, e.features.clone(), e.deadline_in)?;
            idx += 1;
        }
        responses.append(&mut server.tick()?);
    }
    responses.append(&mut server.drain()?);
    Ok(responses)
}

/// Drive a closed loop: `clients` concurrent clients, each re-submitting
/// `think_ticks` after its previous response, until `total` responses
/// have been produced. Tenants are assigned round-robin over clients.
pub fn closed_loop(
    server: &mut Server,
    clients: usize,
    total: usize,
    think_ticks: u64,
    seed: u64,
    deadline_in: Option<u64>,
) -> Result<Vec<Response>> {
    ensure!(clients > 0, "a closed loop needs at least one client");
    ensure!(total >= clients, "total responses ({total}) must cover every client ({clients})");
    let n_tenants = server.tenants().len();
    let mut rng = Rng::new(seed);
    let mut responses = Vec::with_capacity(total);
    // id → client; a client re-submits one think-time after completion.
    let mut owner = std::collections::BTreeMap::new();
    let mut wakeups: Vec<(u64, usize)> = Vec::new(); // (tick, client)
    let mut submitted = 0usize;
    let submit = |server: &mut Server, rng: &mut Rng, client: usize, submitted: &mut usize| {
        let tenant = client % n_tenants;
        let in_dim = server.tenants()[tenant].model.in_dim();
        let id = server.submit(tenant, sample_features(rng, in_dim), deadline_in)?;
        *submitted += 1;
        Ok::<u64, crate::util::error::Error>(id)
    };
    for client in 0..clients.min(total) {
        let id = submit(server, &mut rng, client, &mut submitted)?;
        owner.insert(id, client);
    }
    let mut rounds = 0u64;
    while responses.len() < total {
        // Jump quiet stretches: to the next client wakeup or the next
        // tick the batcher could dispatch, whichever comes first
        // (advance_to stops at the dispatch trigger when requests are
        // pending, so large max_wait stays O(events) here too).
        match wakeups.iter().map(|&(t, _)| t).min() {
            Some(t) => {
                server.advance_to(t);
            }
            None if server.pending() > 0 => {
                server.advance_to(u64::MAX);
            }
            None => {}
        }
        let now = server.now();
        let mut due: Vec<usize> =
            wakeups.iter().filter(|&&(t, _)| t <= now).map(|&(_, c)| c).collect();
        wakeups.retain(|&(t, _)| t > now);
        due.sort_unstable();
        for client in due {
            if submitted < total {
                let id = submit(server, &mut rng, client, &mut submitted)?;
                owner.insert(id, client);
            }
        }
        for r in server.tick()? {
            if let Some(client) = owner.remove(&r.id) {
                // Resubmit exactly think_ticks after the response.
                wakeups.push((r.completion_tick.saturating_add(think_ticks), client));
            }
            responses.push(r);
        }
        // Safety valve: every iteration either submits, dispatches, or
        // jumps to the next wakeup/trigger, so a handful of rounds per
        // request suffices; an iteration bound (ticks can legitimately
        // jump far under large max_wait) catches scheduler regressions
        // instead of hanging the test.
        rounds += 1;
        if rounds > 10 * total as u64 + 1_000 {
            bail!("closed loop failed to converge (scheduler bug)");
        }
    }
    Ok(responses)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traces_are_deterministic_and_ordered() {
        let a = Trace::open_loop(9, &[8, 8], 200, 0.5, Some(16)).unwrap();
        let b = Trace::open_loop(9, &[8, 8], 200, 0.5, Some(16)).unwrap();
        assert_eq!(a, b, "same seed must generate the identical trace");
        assert_eq!(a.len(), 200);
        assert!(a.events.windows(2).all(|w| w[0].tick <= w[1].tick), "ticks must be sorted");
        assert!(a.events.iter().all(|e| e.features.len() == 8 && e.tenant < 2));
        let c = Trace::open_loop(10, &[8, 8], 200, 0.5, Some(16)).unwrap();
        assert_ne!(a, c, "different seeds must differ");
    }

    #[test]
    fn trace_rejects_degenerate_knobs() {
        assert!(Trace::open_loop(1, &[], 10, 1.0, None).is_err());
        assert!(Trace::open_loop(1, &[2], 10, 1.0, None).is_err());
        assert!(Trace::open_loop(1, &[8], 10, f64::NAN, None).is_err());
        assert!(Trace::open_loop(1, &[8], 10, -1.0, None).is_err());
    }

    #[test]
    fn bursty_traces_are_deterministic_and_ordered() {
        let a = Trace::bursty(5, &[8, 8], 300, 0.25, 8.0, 64.0, Some(16)).unwrap();
        let b = Trace::bursty(5, &[8, 8], 300, 0.25, 8.0, 64.0, Some(16)).unwrap();
        assert_eq!(a, b, "same seed must generate the identical trace");
        assert_eq!(a.len(), 300);
        assert!(a.events.windows(2).all(|w| w[0].tick <= w[1].tick), "ticks must be sorted");
        assert!(a.events.iter().all(|e| e.features.len() == 8 && e.tenant < 2));
        let c = Trace::bursty(6, &[8, 8], 300, 0.25, 8.0, 64.0, Some(16)).unwrap();
        assert_ne!(a, c, "different seeds must differ");
    }

    #[test]
    fn bursty_traces_actually_burst() {
        // ON bursts at 4 req/tick, mean OFF silence 8x the ON dwell:
        // inter-arrival gaps must be bimodal — mostly tiny (intra-burst)
        // with a heavy tail of long OFF silences. Deterministic given
        // the seed, so concrete thresholds are safe to assert.
        let t = Trace::bursty(11, &[8], 400, 0.25, 8.0, 64.0, None).unwrap();
        let gaps: Vec<u64> =
            t.events.windows(2).map(|w| w[1].tick - w[0].tick).collect();
        let long = gaps.iter().filter(|&&g| g >= 16).count();
        let tiny = gaps.iter().filter(|&&g| g <= 1).count();
        assert!(long >= 5, "expected OFF-phase silences >= 16 ticks, saw {long}");
        assert!(tiny >= gaps.len() / 2, "expected mostly intra-burst arrivals, saw {tiny}");
        // The same knobs with no OFF phase degenerate toward plain
        // Poisson: long silences should all but vanish.
        let p = Trace::bursty(11, &[8], 400, 0.25, 8.0, 0.0, None).unwrap();
        let plong =
            p.events.windows(2).filter(|w| w[1].tick - w[0].tick >= 16).count();
        assert!(plong < long / 2, "no-OFF trace still bursting ({plong} vs {long})");
    }

    #[test]
    fn bursty_rejects_degenerate_knobs() {
        assert!(Trace::bursty(1, &[], 10, 1.0, 8.0, 8.0, None).is_err());
        assert!(Trace::bursty(1, &[2], 10, 1.0, 8.0, 8.0, None).is_err());
        assert!(Trace::bursty(1, &[8], 10, f64::NAN, 8.0, 8.0, None).is_err());
        assert!(Trace::bursty(1, &[8], 10, 1.0, 0.0, 8.0, None).is_err(), "ON dwell must be > 0");
        assert!(Trace::bursty(1, &[8], 10, 1.0, 8.0, -1.0, None).is_err());
        assert!(Trace::bursty(1, &[8], 10, 1.0, 1e13, 8.0, None).is_err());
        assert!(Trace::bursty(1, &[8], 10, 1.0, 8.0, 0.0, None).is_ok(), "OFF dwell 0 is Poisson");
    }

    #[test]
    fn features_go_through_the_dataset_embedding() {
        let mut rng = Rng::new(3);
        let f = sample_features(&mut rng, 8);
        assert_eq!(f.len(), 8);
        // Bit-faithful to the training pipeline: the stored lanes are
        // the f32 embedding (including its f32 r² arithmetic), not a
        // parallel f64 reimplementation.
        let e = crate::nn::data::SpiralDataset::embed(f[0] as f32, f[1] as f32);
        assert_eq!(f[0], e[0] as f64);
        assert_eq!(f[2], e[2] as f64);
        assert_eq!(f[3], 1.0);
        assert!(f[4..].iter().all(|&v| v == 0.0));
    }
}
