//! Multi-tenant batched inference serving over the minifloat engine —
//! the fourth pillar next to [`crate::batch`], [`crate::api`] and
//! [`crate::nn`].
//!
//! The cluster exists to make large, lane-aligned low-precision GEMMs
//! cheap; inference traffic arrives as many small, latency-bound
//! requests. This subsystem is the standard bridge between the two:
//! **continuous (iteration-level) batching**. Requests pass admission
//! control (per-tenant token buckets, bounded queues — overflow is a
//! typed shed, not an unbounded backlog), park briefly in per-tenant
//! queues, and join a lane-padded **cohort** at the next layer-0
//! boundary; every tick, each in-flight cohort advances one layer
//! (one **wave**) over the shard pool, so new requests pipeline
//! alongside running batches instead of waiting for them to drain.
//! The frozen models' weights were packed *once* into the GEMM
//! kernels' preferred stream layout — so every request rides the
//! zero-repack fast path the engine is built around. The legacy
//! whole-batch run-to-completion policy stays available behind
//! [`BatchMode::WholeBatch`] as the differential/timing reference.
//!
//! Everything is **offline and deterministic**: time is virtual
//! (ticks), traffic is seeded ([`sim`]), and per-request outputs are
//! bit-identical across runs *and across shard counts*, because each
//! GEMM output row depends only on its own input row. That turns load
//! tests into regression tests: a million-request trace replays
//! bit-for-bit.
//!
//! ## Module map
//!
//! | module | role |
//! |---|---|
//! | [`model`]   | [`InferenceModel`]: frozen packed weights + versioned checkpoints, per-layer wave forward |
//! | [`queue`]   | [`Request`]/[`Response`] + per-tenant deadline-aware queues (SLO-weighted take) |
//! | [`batcher`] | scheduling modes ([`BatchMode`]) + knobs (`max_batch`, `max_wait_ticks`, row padding) |
//! | [`admission`] | token buckets, [`Admission`]/[`ShedReason`] backpressure types |
//! | [`worker`]  | cohort/wave scheduler + [`worker::Shard`] pool (persistent per-tenant plan instances) + the [`Server`] tick loop |
//! | [`stats`]   | [`ServeStats`]: throughput, goodput, wave occupancy, shed counts, p50/p95/p99 ticks |
//! | [`sim`]     | seeded open/closed-loop + bursty load generation + [`sim::replay`] |
//!
//! ## Layering
//!
//! `serve` sits **above** the numerics stack, beside `nn`: it calls
//! only the [`crate::api`] public surface (`Session` / `MfTensor` /
//! `GemmPlan` via [`crate::nn::GemmCtx`]) and `nn`'s public layer
//! types — never `batch` internals, `kernels`, `cluster` or `core`.
//! The sanctioned front door is [`crate::api::serve`]:
//! [`crate::api::Session::server`] →
//! [`crate::api::ServePlanBuilder`] validates tenants, knobs and
//! per-layer GEMM feasibility (probe plans) before a [`Server`] exists.
//!
//! ```
//! use minifloat_nn::prelude::*;
//! use minifloat_nn::serve::{sim, InferenceModel};
//!
//! # fn main() -> minifloat_nn::util::error::Result<()> {
//! let session = Session::builder().seed(7).build();
//! // Train briefly, freeze, serve.
//! let mut tr = session.native_trainer(PrecisionPolicy::hfp8())?;
//! tr.train(20, 0)?;
//! let model = InferenceModel::freeze(&session, tr.model(), tr.policy())?;
//! let mut server = session
//!     .server()
//!     .tenant("hfp8", model)
//!     .max_batch(16)
//!     .max_wait_ticks(4)
//!     .build()?
//!     .server();
//! let trace = sim::Trace::open_loop(7, &[8], 64, 0.5, None)?;
//! let responses = sim::replay(&mut server, &trace)?;
//! assert_eq!(responses.len(), 64);
//! # Ok(())
//! # }
//! ```

pub mod admission;
pub mod batcher;
pub mod model;
pub mod queue;
pub mod sim;
pub mod stats;
pub mod worker;

#[cfg(test)]
mod tests;

pub use admission::{Admission, RateLimit, ShedReason, TokenBucket};
pub use batcher::{
    pad_rows, pipeline_latency_ticks, BatchMode, BatchPolicy, ROW_PAD, SERVICE_TICKS,
};
pub use model::{FrozenLayer, InferenceModel};
pub use queue::{Request, Response, TenantQueue};
pub use sim::{Trace, TraceEvent};
pub use stats::{ServeStats, TenantCounters};
// `worker::Shard` stays behind its module path: the Server manages the
// pool; the flat namespace exports only what callers construct or read.
pub use worker::{Server, Tenant};
