//! Serving telemetry: throughput, batch-size histogram, queue depth,
//! latency percentiles — all in virtual ticks, all deterministic.
//!
//! Every number here is derived from the simulated clock and the
//! request stream, never from the wall clock, so two replays of the
//! same trace produce byte-identical summaries (the determinism tests
//! compare [`ServeStats::summary_json`] strings directly). Wall-clock
//! throughput is measured one layer up, in `benches/serve.rs`.

use std::collections::BTreeMap;

use super::admission::ShedReason;
use super::queue::Response;

/// Per-tenant GEMM routing counters (mirrors
/// [`crate::nn::GemmCtx`]'s calls/packed pair, aggregated over shards).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TenantCounters {
    /// GEMM plans executed for this tenant.
    pub gemm_calls: u64,
    /// How many fed the batch engine packed (zero decode/re-pack).
    pub packed_runs: u64,
}

/// Aggregate statistics for one server run.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ServeStats {
    /// Requests accepted by [`crate::serve::Server::submit`].
    pub submitted: u64,
    /// Responses produced.
    pub completed: u64,
    /// Virtual ticks elapsed.
    pub ticks: u64,
    /// Batches dispatched.
    pub batches: u64,
    /// Logical batch size → dispatch count.
    pub batch_hist: BTreeMap<usize, u64>,
    /// Per-response latency in ticks, in completion order.
    pub latencies: Vec<u64>,
    /// Deepest total queue backlog observed at a tick boundary.
    pub queue_depth_max: usize,
    /// Responses whose deadline had already passed at completion.
    pub deadline_misses: u64,
    /// Layer waves executed (one per in-flight cohort per tick).
    pub waves: u64,
    /// Logical rows advanced, summed over waves (occupancy numerator).
    pub wave_rows: u64,
    /// Submissions shed by an empty token bucket.
    pub shed_rate_limited: u64,
    /// Submissions shed by a full bounded queue.
    pub shed_queue_full: u64,
    /// Per-tenant GEMM routing counters.
    pub tenants: Vec<TenantCounters>,
    queue_depth_sum: u64,
    depth_samples: u64,
}

impl ServeStats {
    /// Fresh stats for `n_tenants` tenants.
    pub fn new(n_tenants: usize) -> Self {
        ServeStats { tenants: vec![TenantCounters::default(); n_tenants], ..Default::default() }
    }

    /// Record the total queue backlog at a tick boundary.
    pub(crate) fn record_depth(&mut self, depth: usize) {
        self.queue_depth_max = self.queue_depth_max.max(depth);
        self.queue_depth_sum += depth as u64;
        self.depth_samples += 1;
        crate::obs_gauge_max!("serve.queue_depth_max", depth);
    }

    /// Record `n` quiet (no-dispatch) ticks at backlog `depth` in one
    /// step — exactly what `n` calls to [`ServeStats::record_depth`]
    /// would record.
    pub(crate) fn record_quiet(&mut self, n: u64, depth: usize) {
        if n == 0 {
            return;
        }
        self.queue_depth_max = self.queue_depth_max.max(depth);
        self.queue_depth_sum += n.saturating_mul(depth as u64);
        self.depth_samples += n;
    }

    /// Record one dispatched batch's logical size. The obs dual-write
    /// happens here, at the same single choke point `summary_json`
    /// reads, so the two views agree by construction (cross-checked in
    /// `tests/obs_differential.rs`).
    pub(crate) fn record_batch(&mut self, size: usize) {
        self.batches += 1;
        *self.batch_hist.entry(size).or_insert(0) += 1;
        crate::obs_count!("serve.batches");
        crate::obs_hist!("serve.batch_size", size);
    }

    /// Record one layer wave advancing `rows` logical rows. Dual-written
    /// to obs at the same choke point, like [`ServeStats::record_batch`].
    pub(crate) fn record_wave(&mut self, rows: usize) {
        self.waves += 1;
        self.wave_rows += rows as u64;
        crate::obs_count!("serve.waves");
        crate::obs_hist!("serve.wave_rows", rows);
    }

    /// Record one shed submission.
    pub(crate) fn record_shed(&mut self, reason: ShedReason) {
        match reason {
            ShedReason::RateLimited => {
                self.shed_rate_limited += 1;
                crate::obs_count!("serve.shed.rate_limited");
            }
            ShedReason::QueueFull => {
                self.shed_queue_full += 1;
                crate::obs_count!("serve.shed.queue_full");
            }
        }
    }

    /// Record one completed response.
    pub(crate) fn record_response(&mut self, r: &Response) {
        self.completed += 1;
        self.latencies.push(r.latency_ticks());
        self.deadline_misses += r.deadline_missed as u64;
        crate::obs_count!("serve.completed");
        crate::obs_hist!("serve.latency_ticks", r.latency_ticks());
        if r.deadline_missed {
            crate::obs_count!("serve.deadline_misses");
        }
    }

    fn rank(sorted: &[u64], q: f64) -> u64 {
        if sorted.is_empty() {
            return 0;
        }
        let idx = (q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64).round() as usize;
        sorted[idx]
    }

    /// Latency percentile (nearest-rank on the sorted latencies), in
    /// ticks; 0 when nothing completed yet. One-off convenience —
    /// reports wanting several ranks should call
    /// [`ServeStats::latency_percentiles`], which sorts once.
    pub fn latency_percentile(&self, q: f64) -> u64 {
        let mut sorted = self.latencies.clone();
        sorted.sort_unstable();
        Self::rank(&sorted, q)
    }

    /// `(p50, p95, p99)` from a single sort — million-request traces
    /// should not pay six clones and sorts per report.
    pub fn latency_percentiles(&self) -> (u64, u64, u64) {
        let mut sorted = self.latencies.clone();
        sorted.sort_unstable();
        (Self::rank(&sorted, 0.50), Self::rank(&sorted, 0.95), Self::rank(&sorted, 0.99))
    }

    /// Median latency in ticks.
    pub fn p50(&self) -> u64 {
        self.latency_percentile(0.50)
    }

    /// 95th-percentile latency in ticks.
    pub fn p95(&self) -> u64 {
        self.latency_percentile(0.95)
    }

    /// 99th-percentile latency in ticks.
    pub fn p99(&self) -> u64 {
        self.latency_percentile(0.99)
    }

    /// Mean logical batch size over all dispatches.
    pub fn mean_batch(&self) -> f64 {
        self.completed as f64 / self.batches.max(1) as f64
    }

    /// Mean total queue backlog per tick.
    pub fn mean_queue_depth(&self) -> f64 {
        self.queue_depth_sum as f64 / self.depth_samples.max(1) as f64
    }

    /// Completed requests per virtual tick.
    pub fn throughput_per_tick(&self) -> f64 {
        self.completed as f64 / self.ticks.max(1) as f64
    }

    /// Submissions shed, all reasons.
    pub fn shed(&self) -> u64 {
        self.shed_rate_limited + self.shed_queue_full
    }

    /// Shed fraction of everything offered (shed + admitted).
    pub fn shed_rate(&self) -> f64 {
        let offered = self.shed() + self.submitted;
        self.shed() as f64 / offered.max(1) as f64
    }

    /// Responses that completed *within* their deadline (deadline-free
    /// responses count as good).
    pub fn goodput(&self) -> u64 {
        self.completed - self.deadline_misses
    }

    /// Within-deadline completions per virtual tick — the metric the
    /// serve bench gates continuous vs whole-batch scheduling on.
    pub fn goodput_per_tick(&self) -> f64 {
        self.goodput() as f64 / self.ticks.max(1) as f64
    }

    /// Mean logical rows per wave (lane-occupancy proxy: divide by the
    /// padded wave width for a utilization fraction).
    pub fn mean_wave_rows(&self) -> f64 {
        self.wave_rows as f64 / self.waves.max(1) as f64
    }

    /// Total GEMM plans executed across tenants.
    pub fn gemm_calls(&self) -> u64 {
        self.tenants.iter().map(|t| t.gemm_calls).sum()
    }

    /// Total packed zero-repack runs across tenants.
    pub fn packed_runs(&self) -> u64 {
        self.tenants.iter().map(|t| t.packed_runs).sum()
    }

    /// One deterministic JSON object (no wall clock, no floats beyond
    /// fixed-precision formatting): the payload `benches/serve.rs`
    /// embeds in `BENCH_serve.json` and the determinism tests compare
    /// byte-for-byte.
    pub fn summary_json(&self) -> String {
        let hist: Vec<String> =
            self.batch_hist.iter().map(|(size, n)| format!("\"{size}\":{n}")).collect();
        let (p50, p95, p99) = self.latency_percentiles();
        format!(
            "{{\"submitted\":{},\"completed\":{},\"ticks\":{},\"batches\":{},\
             \"mean_batch\":{:.3},\"throughput_per_tick\":{:.4},\
             \"p50_ticks\":{p50},\"p95_ticks\":{p95},\"p99_ticks\":{p99},\
             \"queue_depth_max\":{},\"deadline_misses\":{},\
             \"waves\":{},\"mean_wave_rows\":{:.2},\"goodput_per_tick\":{:.4},\
             \"shed_rate_limited\":{},\"shed_queue_full\":{},\
             \"gemm_calls\":{},\"packed_runs\":{},\"batch_hist\":{{{}}}}}",
            self.submitted,
            self.completed,
            self.ticks,
            self.batches,
            self.mean_batch(),
            self.throughput_per_tick(),
            self.queue_depth_max,
            self.deadline_misses,
            self.waves,
            self.mean_wave_rows(),
            self.goodput_per_tick(),
            self.shed_rate_limited,
            self.shed_queue_full,
            self.gemm_calls(),
            self.packed_runs(),
            hist.join(",")
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn resp(arrival: u64, done: u64, missed: bool) -> Response {
        Response {
            id: 0,
            tenant: 0,
            logits: vec![],
            pred: 0,
            arrival_tick: arrival,
            completion_tick: done,
            batch_size: 1,
            deadline_missed: missed,
        }
    }

    #[test]
    fn percentiles_use_nearest_rank() {
        let mut s = ServeStats::new(1);
        for lat in [4u64, 1, 3, 0, 2] {
            s.record_response(&resp(0, lat, false));
        }
        assert_eq!(s.p50(), 2);
        assert_eq!(s.latency_percentile(0.0), 0);
        assert_eq!(s.latency_percentile(1.0), 4);
        assert_eq!(s.p99(), 4);
    }

    #[test]
    fn empty_stats_do_not_divide_by_zero() {
        let s = ServeStats::new(0);
        assert_eq!(s.p95(), 0);
        assert_eq!(s.throughput_per_tick(), 0.0);
        assert_eq!(s.mean_batch(), 0.0);
        assert_eq!(s.mean_queue_depth(), 0.0);
        assert!(s.summary_json().starts_with('{'));
    }

    #[test]
    fn histogram_and_misses_accumulate() {
        let mut s = ServeStats::new(2);
        s.record_batch(4);
        s.record_batch(4);
        s.record_batch(1);
        s.record_response(&resp(0, 3, true));
        s.record_depth(7);
        s.record_depth(3);
        assert_eq!(s.batch_hist[&4], 2);
        assert_eq!(s.batch_hist[&1], 1);
        assert_eq!(s.deadline_misses, 1);
        assert_eq!(s.queue_depth_max, 7);
        assert_eq!(s.mean_queue_depth(), 5.0);
        // JSON is stable: BTreeMap orders the histogram keys.
        assert!(s.summary_json().contains("\"batch_hist\":{\"1\":1,\"4\":2}"));
    }

    #[test]
    fn waves_sheds_and_goodput_accumulate() {
        let mut s = ServeStats::new(1);
        s.record_wave(8);
        s.record_wave(4);
        s.record_shed(ShedReason::RateLimited);
        s.record_shed(ShedReason::RateLimited);
        s.record_shed(ShedReason::QueueFull);
        s.submitted = 7;
        s.ticks = 4;
        s.record_response(&resp(0, 3, false));
        s.record_response(&resp(0, 9, true));
        assert_eq!(s.waves, 2);
        assert_eq!(s.mean_wave_rows(), 6.0);
        assert_eq!(s.shed(), 3);
        assert_eq!(s.shed_rate_limited, 2);
        assert_eq!(s.shed_queue_full, 1);
        assert_eq!(s.shed_rate(), 0.3);
        assert_eq!(s.goodput(), 1, "the missed-deadline response is not goodput");
        assert_eq!(s.goodput_per_tick(), 0.25);
        let json = s.summary_json();
        assert!(json.contains("\"waves\":2"), "{json}");
        assert!(json.contains("\"shed_rate_limited\":2,\"shed_queue_full\":1"), "{json}");
        assert!(json.contains("\"goodput_per_tick\":0.2500"), "{json}");
    }
}
