//! Admission control: per-tenant token-bucket rate limits and typed
//! shed decisions.
//!
//! Overload protection has to be **deterministic** here — the whole
//! serving subsystem replays bit-for-bit — so the bucket runs on the
//! virtual tick clock in pure integer arithmetic: tokens are counted
//! in micro-tokens ([`TOKEN_SCALE`] per request) and refill is lazy,
//! computed from the elapsed ticks at the moment of admission. A
//! fractional per-tick rate like 0.25 requests/tick therefore
//! accumulates *exactly* (one token every 4 ticks), with no float
//! drift across a million-tick trace.
//!
//! A submission that the bucket (or a bounded queue) rejects is not an
//! error: it is a typed [`Admission::Shed`] with a [`ShedReason`], the
//! backpressure signal a load generator or upstream router reacts to.

use crate::ensure;
use crate::util::error::Result;

/// Micro-tokens per request: the integer sub-tick resolution of the
/// bucket. 10^6 keeps any CLI-plausible fractional rate exact enough
/// that rounding error is below one token per ~10^6 ticks.
pub const TOKEN_SCALE: u64 = 1_000_000;

/// A validated per-tenant rate-limit configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RateLimit {
    /// Micro-tokens added per elapsed tick.
    pub refill_micro: u64,
    /// Bucket capacity in micro-tokens (the burst allowance).
    pub burst_micro: u64,
}

impl RateLimit {
    /// Build from the user-facing knobs: `rate` requests per tick
    /// (fractional allowed) and `burst` whole requests of headroom.
    pub fn per_tick(rate: f64, burst: u64) -> Result<RateLimit> {
        ensure!(
            rate.is_finite() && rate > 0.0 && rate <= 1e6,
            "rate limit must be a positive finite rate up to 1e6 requests/tick, got {rate} \
             (--rate-limit)"
        );
        ensure!(
            (1..=1_000_000_000).contains(&burst),
            "rate-limit burst ({burst}) must be in 1..=1e9 requests (--burst)"
        );
        let refill_micro = (rate * TOKEN_SCALE as f64).round() as u64;
        ensure!(refill_micro > 0, "rate limit {rate} rounds to zero micro-tokens per tick");
        Ok(RateLimit { refill_micro, burst_micro: burst.saturating_mul(TOKEN_SCALE) })
    }
}

/// A deterministic token bucket on the virtual tick clock.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TokenBucket {
    micro: u64,
    refill_micro: u64,
    burst_micro: u64,
    last_tick: u64,
}

impl TokenBucket {
    /// A bucket that starts full (the burst allowance is immediately
    /// spendable, the standard token-bucket convention).
    pub fn new(cfg: RateLimit) -> Self {
        TokenBucket {
            micro: cfg.burst_micro,
            refill_micro: cfg.refill_micro,
            burst_micro: cfg.burst_micro,
            last_tick: 0,
        }
    }

    /// Credit the ticks elapsed since the last observation. Saturating
    /// multiply + clamp to capacity: a quiet aeon fills the bucket, it
    /// never wraps it.
    pub fn refill(&mut self, now: u64) {
        if now > self.last_tick {
            let dt = now - self.last_tick;
            self.micro =
                self.micro.saturating_add(dt.saturating_mul(self.refill_micro)).min(self.burst_micro);
            self.last_tick = now;
        }
    }

    /// Try to spend one request's worth of tokens at tick `now`.
    pub fn try_take(&mut self, now: u64) -> bool {
        self.refill(now);
        if self.micro >= TOKEN_SCALE {
            self.micro -= TOKEN_SCALE;
            true
        } else {
            false
        }
    }

    /// Current balance in micro-tokens (test/report introspection).
    pub fn micro(&self) -> u64 {
        self.micro
    }
}

/// Why a submission was shed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedReason {
    /// The tenant's token bucket was empty.
    RateLimited,
    /// The tenant's bounded queue was full.
    QueueFull,
}

impl std::fmt::Display for ShedReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            ShedReason::RateLimited => "rate-limited",
            ShedReason::QueueFull => "queue-full",
        })
    }
}

/// The typed outcome of [`crate::serve::Server::try_submit`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// Accepted; carries the assigned request id.
    Admitted(u64),
    /// Rejected by admission control; nothing was enqueued.
    Shed(ShedReason),
}

impl Admission {
    /// The request id, when admitted.
    pub fn id(&self) -> Option<u64> {
        match self {
            Admission::Admitted(id) => Some(*id),
            Admission::Shed(_) => None,
        }
    }

    /// True when the submission was shed.
    pub fn is_shed(&self) -> bool {
        matches!(self, Admission::Shed(_))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fractional_refill_accumulates_exactly() {
        // 0.25 requests/tick: one token every 4 ticks, exactly, with
        // integer micro-token arithmetic — no float drift.
        let mut b = TokenBucket::new(RateLimit::per_tick(0.25, 1).unwrap());
        assert!(b.try_take(0), "the bucket starts full (burst 1)");
        assert!(!b.try_take(0), "second take at the same tick must fail");
        assert!(!b.try_take(3), "3 ticks x 0.25 = 0.75 tokens, still short");
        assert!(b.try_take(4), "4 ticks x 0.25 = exactly 1 token");
        assert!(!b.try_take(7));
        assert!(b.try_take(8));
        assert_eq!(b.micro(), 0, "exact arithmetic leaves no residue on the 4-tick grid");
    }

    #[test]
    fn burst_caps_the_balance() {
        let mut b = TokenBucket::new(RateLimit::per_tick(1.0, 3).unwrap());
        // A long quiet stretch refills to the burst cap, not beyond.
        b.refill(1_000_000);
        assert_eq!(b.micro(), 3 * TOKEN_SCALE);
        assert!(b.try_take(1_000_000));
        assert!(b.try_take(1_000_000));
        assert!(b.try_take(1_000_000));
        assert!(!b.try_take(1_000_000), "burst of 3 spent within one tick");
        assert!(b.try_take(1_000_001), "the per-tick refill resumes");
    }

    #[test]
    fn one_big_jump_equals_many_small_refills() {
        let cfg = RateLimit::per_tick(0.3, 100).unwrap();
        let mut jump = TokenBucket::new(cfg);
        let mut steps = TokenBucket::new(cfg);
        jump.try_take(0);
        steps.try_take(0);
        jump.refill(97);
        for t in 1..=97 {
            steps.refill(t);
        }
        assert_eq!(jump, steps, "lazy refill must be path-independent");
    }

    #[test]
    fn refill_saturates_instead_of_wrapping() {
        let mut b = TokenBucket::new(RateLimit { refill_micro: u64::MAX, burst_micro: u64::MAX });
        b.refill(u64::MAX);
        assert_eq!(b.micro(), u64::MAX, "saturating math, no wrap");
        assert!(b.try_take(u64::MAX));
    }

    #[test]
    fn rate_limit_rejects_degenerate_knobs() {
        assert!(RateLimit::per_tick(0.0, 1).is_err());
        assert!(RateLimit::per_tick(-1.0, 1).is_err());
        assert!(RateLimit::per_tick(f64::NAN, 1).is_err());
        assert!(RateLimit::per_tick(f64::INFINITY, 1).is_err());
        assert!(RateLimit::per_tick(1.0, 0).is_err());
        assert!(RateLimit::per_tick(1e-9, 1).is_err(), "rounds to zero micro-tokens");
        assert!(RateLimit::per_tick(0.5, 16).is_ok());
    }

    #[test]
    fn admission_accessors() {
        assert_eq!(Admission::Admitted(7).id(), Some(7));
        assert!(!Admission::Admitted(7).is_shed());
        assert_eq!(Admission::Shed(ShedReason::QueueFull).id(), None);
        assert!(Admission::Shed(ShedReason::RateLimited).is_shed());
        assert_eq!(ShedReason::RateLimited.to_string(), "rate-limited");
        assert_eq!(ShedReason::QueueFull.to_string(), "queue-full");
    }
}
