//! The roofline sweep: achieved FLOP/cycle and GFLOPS/W versus cluster
//! count and expanding format pair (the SoC's Table III/IV story).
//!
//! One row per (cluster count × kernel family) on a fixed problem; the
//! single-cluster expanding-FP8 row on the paper's 128×256 anchor
//! reproduces §IV-C's 575 GFLOPS/W from the unmodified [`crate::energy`]
//! model (the `repro roofline --check-anchor` CI gate pins it within 1%).

use crate::energy::{self, ComputeClass, EnergyTable, SocEnergyTable};
use crate::isa::instr::{OpWidth, ScalarFmt};
use crate::kernels::{ExecMode, GemmKind};
use crate::util::error::Result;
use crate::util::rng::Rng;

use super::{Soc, SocCfg};

/// One roofline row: one (cluster count, kernel family) cell.
#[derive(Clone, Debug)]
pub struct RooflineRow {
    /// Clusters configured.
    pub n_clusters: usize,
    /// Kernel family.
    pub kind: GemmKind,
    /// Problem shape.
    pub m: usize,
    /// Problem shape.
    pub n: usize,
    /// Problem shape.
    pub k: usize,
    /// SoC wall-clock cycles.
    pub total_cycles: u64,
    /// Critical cluster's busy compute cycles.
    pub compute_cycles: u64,
    /// Critical cluster's DMA-wait cycles.
    pub dma_stall_cycles: u64,
    /// Total FLOP.
    pub flops: u64,
    /// Achieved FLOP/cycle across the SoC.
    pub flop_per_cycle: f64,
    /// Peak FLOP/cycle (per-cluster kernel peak × cluster count).
    pub peak_flop_per_cycle: f64,
    /// Achieved / peak.
    pub utilization: f64,
    /// Achieved GFLOPS at [`energy::FREQ_GHZ`].
    pub gflops: f64,
    /// Compute-region cluster efficiency in GFLOPS/W (the paper's
    /// cluster metric; 575 on the FP8 anchor at N = 1). `None` in
    /// [`ExecMode::Functional`], which collects no op counters.
    pub cluster_gflops_per_w: Option<f64>,
    /// SoC efficiency including L2, interconnect and idle-cluster
    /// static terms. `None` in [`ExecMode::Functional`].
    pub soc_gflops_per_w: Option<f64>,
    /// Bytes read from + written to L2.
    pub l2_bytes: u64,
    /// FLOP per L2 byte (the roofline's x-axis).
    pub arith_intensity: f64,
}

/// Per-cluster kernel peak in FLOP/cycle (Fig. 8's rooflines: 8 FPUs ×
/// the per-FPU width of the compute op).
pub fn cluster_peak_flop_per_cycle(kind: GemmKind) -> f64 {
    let per_fpu = match kind {
        GemmKind::FmaF64 => 2.0,
        GemmKind::FmaSimd(ScalarFmt::S) => 4.0,
        GemmKind::FmaSimd(_) => 8.0,
        GemmKind::ExSdotp(OpWidth::HtoS) => 8.0,
        GemmKind::ExSdotp(OpWidth::BtoH) => 16.0,
    };
    8.0 * per_fpu
}

/// The energy row a kernel family bills its compute ops at.
pub fn compute_class(kind: GemmKind) -> ComputeClass {
    match kind {
        GemmKind::FmaF64 => ComputeClass::Fma(ScalarFmt::D),
        GemmKind::FmaSimd(f) => ComputeClass::Fma(f),
        GemmKind::ExSdotp(w) => ComputeClass::Sdotp(w),
    }
}

/// Run the sweep: `clusters × kinds` on one `M×N×K` problem with
/// seeded Gaussian operands (the same operand bits for every cluster
/// count, so scale-out is also a bit-identity differential).
pub fn run_roofline(
    clusters: &[usize],
    kinds: &[GemmKind],
    m: usize,
    n: usize,
    k: usize,
    mode: ExecMode,
    seed: u64,
) -> Result<Vec<RooflineRow>> {
    crate::ensure!(!clusters.is_empty(), "--clusters must list at least one cluster count");
    crate::ensure!(!kinds.is_empty(), "at least one kernel family is required");
    let table = EnergyTable::default();
    let soc_table = SocEnergyTable::default();
    let mut rows = Vec::with_capacity(clusters.len() * kinds.len());
    for &kind in kinds {
        let mut rng = Rng::new(seed ^ kind_salt(kind));
        let a: Vec<f64> = (0..m * k).map(|_| rng.gaussian() * 0.25).collect();
        let b: Vec<f64> = (0..k * n).map(|_| rng.gaussian() * 0.25).collect();
        let mut c_ref: Option<Vec<u64>> = None;
        for &nc in clusters {
            let soc = Soc::new(SocCfg { n_clusters: nc, mode, ..SocCfg::default() })?;
            let run = soc.run_gemm(kind, m, n, k, &a, &b)?;
            // Scale-out bit-identity: every cluster count must produce
            // the same C words.
            let bits: Vec<u64> = run.c.iter().map(|v| v.to_bits()).collect();
            match &c_ref {
                None => c_ref = Some(bits),
                Some(r) => crate::ensure!(
                    *r == bits,
                    "{} at {} clusters diverged bitwise from the first cluster count",
                    kind.label(),
                    nc
                ),
            }

            let class = compute_class(kind);
            let (cluster_eff, soc_eff) = if mode == ExecMode::CycleAccurate {
                let per_cluster: Vec<(crate::core::CoreStats, u64)> = run
                    .clusters
                    .iter()
                    .map(|c| (c.stats, c.timeline.compute_busy))
                    .collect();
                let reg = energy::estimate_cluster_region(&per_cluster, class, &table);
                let soc_rep = energy::estimate_soc(
                    &per_cluster,
                    run.total_cycles,
                    run.l2.total_bytes(),
                    class,
                    &table,
                    &soc_table,
                );
                (Some(reg.gflops_per_w), Some(soc_rep.gflops_per_w))
            } else {
                (None, None)
            };

            let fpc = run.flop_per_cycle();
            let peak = cluster_peak_flop_per_cycle(kind) * nc as f64;
            rows.push(RooflineRow {
                n_clusters: nc,
                kind,
                m,
                n,
                k,
                total_cycles: run.total_cycles,
                compute_cycles: run.compute_cycles,
                dma_stall_cycles: run.dma_stall_cycles,
                flops: run.flops,
                flop_per_cycle: fpc,
                peak_flop_per_cycle: peak,
                utilization: fpc / peak,
                gflops: fpc * energy::FREQ_GHZ,
                cluster_gflops_per_w: cluster_eff,
                soc_gflops_per_w: soc_eff,
                l2_bytes: run.l2.total_bytes(),
                arith_intensity: run.flops as f64 / run.l2.total_bytes().max(1) as f64,
            });
        }
    }
    Ok(rows)
}

/// The `--check-anchor` gate's outcome.
#[derive(Clone, Copy, Debug)]
pub struct AnchorCheck {
    /// The SoC roofline's N = 1 FP8 compute-region GFLOPS/W.
    pub soc_gflops_per_w: f64,
    /// The direct kernel-plus-energy-model estimate on the same operands.
    pub direct_gflops_per_w: f64,
    /// |soc − direct| / direct.
    pub rel_err: f64,
}

/// Run the paper's §IV-C anchor (128×256 K=128 FP8→FP16) through the
/// SoC stack at one cluster and through the bare kernel + energy model,
/// and compare — the CI gate requires agreement within 1% (and both
/// sides in the 575 GFLOPS/W band).
pub fn check_anchor(seed: u64) -> Result<AnchorCheck> {
    let (m, n, k) = (128, 256, 128);
    let kind = GemmKind::ExSdotp(OpWidth::BtoH);
    let rows = run_roofline(&[1], &[kind], m, n, k, ExecMode::CycleAccurate, seed)?;
    let soc_eff = rows[0]
        .cluster_gflops_per_w
        .expect("cycle-accurate roofline rows always carry energy");

    let mut rng = Rng::new(seed ^ kind_salt(kind));
    let a: Vec<f64> = (0..m * k).map(|_| rng.gaussian() * 0.25).collect();
    let b: Vec<f64> = (0..k * n).map(|_| rng.gaussian() * 0.25).collect();
    let bare = crate::kernels::GemmKernel::try_new(kind, m, n, k)?.run(&a, &b);
    let direct = energy::estimate(
        &bare.stats,
        bare.cycles,
        ComputeClass::Sdotp(OpWidth::BtoH),
        &EnergyTable::default(),
    );
    let rel_err = (soc_eff - direct.gflops_per_w).abs() / direct.gflops_per_w;
    Ok(AnchorCheck { soc_gflops_per_w: soc_eff, direct_gflops_per_w: direct.gflops_per_w, rel_err })
}

/// Per-kind operand salt so different format pairs draw different
/// (but per-pair stable) operands.
fn kind_salt(kind: GemmKind) -> u64 {
    match kind {
        GemmKind::FmaF64 => 0x64,
        GemmKind::FmaSimd(ScalarFmt::S) => 0x32,
        GemmKind::FmaSimd(_) => 0x16,
        GemmKind::ExSdotp(OpWidth::HtoS) => 0x1632,
        GemmKind::ExSdotp(OpWidth::BtoH) => 0x0816,
    }
}
