//! SoC differential pins: the scale-out model must never change a bit
//! of the single-cluster truth, and the N = 1 column must match the
//! bare `cluster::` simulation in both result words and compute cycles.

use crate::isa::instr::OpWidth;
use crate::kernels::{ExecMode, GemmKernel, GemmKind};
use crate::soc::{run_roofline, Soc, SocCfg};
use crate::util::rng::Rng;

const FP8: GemmKind = GemmKind::ExSdotp(OpWidth::BtoH);
const FP16: GemmKind = GemmKind::ExSdotp(OpWidth::HtoS);

fn operands(seed: u64, m: usize, n: usize, k: usize) -> (Vec<f64>, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let a = (0..m * k).map(|_| rng.gaussian() * 0.25).collect();
    let b = (0..k * n).map(|_| rng.gaussian() * 0.25).collect();
    (a, b)
}

fn bits(c: &[f64]) -> Vec<u64> {
    c.iter().map(|v| v.to_bits()).collect()
}

/// The tentpole differential: at N = 1 on a TCDM-fitting problem the
/// SoC is exactly the bare cluster sim — same result words, same
/// compute cycle count — with DMA fill/drain visible only in the wall
/// clock.
fn pin_single_cluster(kind: GemmKind, seed: u64) {
    let (m, n, k) = (64, 64, 64);
    let (a, b) = operands(seed, m, n, k);

    let kern = GemmKernel::new(kind, m, n, k);
    let bare = kern.run(&a, &b);

    let soc = Soc::new(SocCfg::default()).unwrap();
    let run = soc.run_gemm(kind, m, n, k, &a, &b).unwrap();

    assert_eq!(bits(&run.c), bits(&bare.c), "{}: SoC C words diverged", kind.label());
    assert_eq!(
        run.compute_cycles,
        bare.cycles,
        "{}: SoC compute region must be the bare cluster's cycle count",
        kind.label()
    );
    assert!(
        run.total_cycles > run.compute_cycles,
        "wall clock must include the L2 fill the cluster sim never sees"
    );
    assert_eq!(run.active_clusters, 1);
    assert_eq!(run.flops, bare.flops);
}

#[test]
fn single_cluster_is_bit_identical_fp8_to_fp16() {
    pin_single_cluster(FP8, 11);
}

#[test]
fn single_cluster_is_bit_identical_fp16_to_fp32() {
    pin_single_cluster(FP16, 12);
}

#[test]
fn scale_out_preserves_result_bits() {
    // M-only partitioning: every cluster count folds each output
    // element in the same ascending-k order, so C is bitwise stable.
    let (m, n, k) = (128, 64, 64);
    let (a, b) = operands(13, m, n, k);
    let mut reference: Option<Vec<u64>> = None;
    for nc in [1usize, 2, 4, 8] {
        let soc = Soc::new(SocCfg { n_clusters: nc, ..SocCfg::default() }).unwrap();
        let run = soc.run_gemm(FP8, m, n, k, &a, &b).unwrap();
        let got = bits(&run.c);
        match &reference {
            None => reference = Some(got),
            Some(r) => assert_eq!(*r, got, "{nc} clusters diverged bitwise"),
        }
    }
}

#[test]
fn multi_tile_run_matches_stitched_kernel_runs() {
    // A problem too big for one TCDM residency (FP8 256×256 K=256:
    // C alone is 128 kB) must split into tiles; the stitched reference
    // below re-runs the unmodified kernel per 8-row band — a different
    // tiling — and the bits must still agree, because each output row's
    // fold never crosses a tile boundary.
    let (m, n, k) = (256, 256, 256);
    let (a, b) = operands(14, m, n, k);

    let soc = Soc::new(SocCfg { n_clusters: 2, mode: ExecMode::Functional, ..SocCfg::default() })
        .unwrap();
    let run = soc.run_gemm(FP8, m, n, k, &a, &b).unwrap();
    assert!(run.clusters.iter().map(|c| c.tiles).sum::<usize>() > 2, "expected a multi-tile plan");

    let mut stitched = Vec::with_capacity(m * n);
    let band = GemmKernel::try_new(FP8, 8, n, k).unwrap();
    for r0 in (0..m).step_by(8) {
        let res = band.run_mode(&a[r0 * k..(r0 + 8) * k], &b, ExecMode::Functional);
        stitched.extend_from_slice(&res.c);
    }
    assert_eq!(bits(&run.c), bits(&stitched));
}

#[test]
fn functional_mode_reports_no_energy_columns() {
    let rows = run_roofline(&[1], &[FP8], 16, 16, 16, ExecMode::Functional, 9).unwrap();
    assert_eq!(rows.len(), 1);
    assert!(rows[0].cluster_gflops_per_w.is_none(), "no op counters → no energy estimate");
    assert!(rows[0].soc_gflops_per_w.is_none());
}

#[test]
fn roofline_single_cluster_reproduces_575_anchor() {
    // The paper's §IV-C anchor through the whole SoC stack: the N = 1
    // FP8 row on 128×256 K=128 must agree with a direct kernel-plus-
    // energy-model estimate within 1%, and sit in the 575 GFLOPS/W band.
    let (m, n, k) = (128, 256, 128);
    let rows = run_roofline(&[1], &[FP8], m, n, k, ExecMode::CycleAccurate, 0x575).unwrap();
    let eff = rows[0].cluster_gflops_per_w.expect("cycle mode must report energy");
    assert!((eff - 575.0).abs() < 60.0, "anchor efficiency {eff:.0}");

    let mut rng = Rng::new(0x575 ^ 0x0816); // run_roofline's FP8 operand salt
    let a: Vec<f64> = (0..m * k).map(|_| rng.gaussian() * 0.25).collect();
    let b: Vec<f64> = (0..k * n).map(|_| rng.gaussian() * 0.25).collect();
    let bare = GemmKernel::new(FP8, m, n, k).run(&a, &b);
    let direct = crate::energy::estimate(
        &bare.stats,
        bare.cycles,
        crate::energy::ComputeClass::Sdotp(OpWidth::BtoH),
        &crate::energy::EnergyTable::default(),
    );
    let rel = (eff - direct.gflops_per_w).abs() / direct.gflops_per_w;
    assert!(rel < 0.01, "SoC N=1 column off direct estimate by {:.2}%", rel * 100.0);
}

#[test]
fn more_clusters_cut_wall_clock_on_a_wide_problem() {
    let (m, n, k) = (512, 256, 128);
    let (a, b) = operands(15, m, n, k);
    let cycles_at = |nc| {
        let soc = Soc::new(SocCfg { n_clusters: nc, ..SocCfg::default() }).unwrap();
        soc.run_gemm(FP8, m, n, k, &a, &b).unwrap().total_cycles
    };
    let one = cycles_at(1);
    let eight = cycles_at(8);
    assert!(
        eight * 2 < one,
        "8 clusters should be well over 2× faster ({one} → {eight} cycles)"
    );
}

#[test]
fn l2_traffic_accounts_every_operand_byte() {
    // Single tile, N = 1: reads are A + B images (B per tile), writes
    // exactly C.
    let (m, n, k) = (64, 64, 64);
    let (a, b) = operands(16, m, n, k);
    let soc = Soc::new(SocCfg::default()).unwrap();
    let run = soc.run_gemm(FP8, m, n, k, &a, &b).unwrap();
    assert_eq!(run.l2.read_bytes, (m * k + k * n) as u64, "FP8 source bytes");
    assert_eq!(run.l2.write_bytes, (m * n * 2) as u64, "FP16 destination bytes");
}

#[test]
fn cluster_count_is_validated() {
    assert!(Soc::new(SocCfg { n_clusters: 0, ..SocCfg::default() }).is_err());
    assert!(Soc::new(SocCfg { n_clusters: 9, ..SocCfg::default() }).is_err());
}
