//! The multi-cluster SoC model: N clusters off a shared L2, scale-out
//! for the cycle model (the paper positions the 8-core cluster as "the
//! foundation for future scalable architectures", §V).
//!
//! ## Hierarchy
//!
//! ```text
//!                ┌────────────────────── SoC ──────────────────────┐
//!                │            shared L2 (A, B, C images)           │
//!                │      bandwidth/latency model: soc::l2            │
//!                │    ┌────────┬──── interconnect ────┬────────┐   │
//!                │  DMA 0    DMA 1       ...        DMA N-1       │
//!                │    │        │                      │           │
//!                │ cluster 0 cluster 1    ...     cluster N-1     │
//!                │ (8 PEs +  (the unmodified `cluster::` sim,      │
//!                │  TCDM)     one private 128 kB TCDM each)        │
//!                └─────────────────────────────────────────────────┘
//! ```
//!
//! The coordinator ([`coord`]) partitions one large GEMM over M across
//! clusters and cuts each cluster's band into TCDM-resident tiles; the
//! schedule ([`sched`]) overlaps each tile's ascending-k input fills
//! with compute via ping-pong double-buffering on the per-cluster DMA
//! engine; the L2 model ([`l2`]) prices every transfer under contention.
//!
//! ## What is simulated vs modeled
//!
//! * **Data plane: real.** A, B and C live as packed byte images in an
//!   L2 array; every tile fill and write-back is performed by the
//!   actual [`crate::cluster::dma::DmaEngine`] using its 2-D strided
//!   transfers, and the staged bytes are asserted identical to what
//!   the tile kernel packs — the DMA path and the kernel path must
//!   agree byte-for-byte.
//! * **Tile compute: the existing engines.** Each tile runs the
//!   unmodified [`crate::kernels::GemmKernel`] in the configured
//!   [`ExecMode`] — cycle-accurate cluster simulation by default.
//! * **Overlap timing: analytic.** Transfer/compute overlap is resolved
//!   by the integer-cycle schedule in [`sched`] (the cluster sim's DMA
//!   does not contend for TCDM banks, so co-simulating it would add
//!   cost, not fidelity).
//!
//! ## Bit-identity
//!
//! Splitting M only (never the k fold) keeps every output element's
//! accumulation order exactly the single-cluster kernel's; see
//! [`coord`] for the argument and `soc::tests` for the differential
//! pins (result words *and* compute cycles at N = 1).

pub mod coord;
pub mod l2;
pub mod roofline;
pub mod sched;
#[cfg(test)]
mod tests;

use crate::cluster::dma::DmaEngine;
use crate::cluster::{GLOBAL_BASE, TCDM_BASE};
use crate::core::CoreStats;
use crate::kernels::layout::{pack_matrix, pack_matrix_ld, unpack_matrix, MatrixOrder};
use crate::kernels::{ExecMode, GemmKernel, GemmKind};
use crate::util::error::Result;
use l2::{L2Cfg, L2Model, L2Stats};
use sched::{ChunkCost, TileCost, Timeline};

pub use roofline::{run_roofline, RooflineRow};

/// SoC configuration.
#[derive(Clone, Copy, Debug)]
pub struct SocCfg {
    /// Cluster count (1..=8, the paper's scale-out range).
    pub n_clusters: usize,
    /// Shared-L2 bandwidth/latency parameters.
    pub l2: L2Cfg,
    /// Per-cluster TCDM bytes available for a tile's logical footprint
    /// (the paper's 128 kB).
    pub tcdm_budget: u64,
    /// Tile compute engine (cycle-accurate sim by default; Functional
    /// runs the batch engine with modeled cycles and no op counters).
    pub mode: ExecMode,
}

impl Default for SocCfg {
    fn default() -> Self {
        SocCfg {
            n_clusters: 1,
            l2: L2Cfg::default(),
            tcdm_budget: 128 * 1024,
            mode: ExecMode::CycleAccurate,
        }
    }
}

/// One cluster's share of a run.
#[derive(Clone, Debug)]
pub struct ClusterRun {
    /// Resolved DMA/compute timeline.
    pub timeline: Timeline,
    /// Aggregated op counters across this cluster's tiles (empty in
    /// [`ExecMode::Functional`], which collects no per-op stats).
    pub stats: CoreStats,
    /// L2 traffic this cluster generated.
    pub l2: L2Stats,
    /// Tiles computed.
    pub tiles: usize,
}

/// Result of one SoC GEMM run.
pub struct SocRunResult {
    /// C matrix decoded to f64 (row-major M×N) — bit-identical to the
    /// single-cluster kernel at every cluster count.
    pub c: Vec<f64>,
    /// SoC wall-clock cycles (all clusters' compute and DMA retired).
    pub total_cycles: u64,
    /// Busy compute cycles on the critical cluster (max over clusters;
    /// at N = 1 exactly the bare `cluster::` simulation's cycle count).
    pub compute_cycles: u64,
    /// Cycles the critical cluster's compute waited on DMA.
    pub dma_stall_cycles: u64,
    /// Total FLOP (2·M·N·K).
    pub flops: u64,
    /// SoC-wide L2 traffic.
    pub l2: L2Stats,
    /// Per-cluster breakdown (length = configured cluster count).
    pub clusters: Vec<ClusterRun>,
    /// Clusters that had work.
    pub active_clusters: usize,
}

impl SocRunResult {
    /// Achieved FLOP/cycle across the SoC (the roofline's y-axis).
    pub fn flop_per_cycle(&self) -> f64 {
        self.flops as f64 / self.total_cycles.max(1) as f64
    }

    /// Aggregated op counters over all clusters (for SoC energy).
    pub fn stats_total(&self) -> CoreStats {
        let mut agg = CoreStats::default();
        for cl in &self.clusters {
            add_stats(&mut agg, &cl.stats);
        }
        agg
    }
}

/// The SoC model.
pub struct Soc {
    cfg: SocCfg,
}

impl Soc {
    /// Build an SoC, validating the cluster count as a typed error.
    pub fn new(cfg: SocCfg) -> Result<Self> {
        crate::ensure!(
            (1..=8).contains(&cfg.n_clusters),
            "SoC cluster count must be 1..=8 (the paper's scale-out range), got {}",
            cfg.n_clusters
        );
        Ok(Soc { cfg })
    }

    /// The bound configuration.
    pub fn cfg(&self) -> &SocCfg {
        &self.cfg
    }

    /// Run one `M×N×K` GEMM partitioned across the clusters. `a` is
    /// M×K and `b` is K×N, both row-major f64 (quantized to the source
    /// format when packed into L2, exactly like the kernel harness).
    pub fn run_gemm(
        &self,
        kind: GemmKind,
        m: usize,
        n: usize,
        k: usize,
        a: &[f64],
        b: &[f64],
    ) -> Result<SocRunResult> {
        let plan = coord::partition(kind, m, n, k, self.cfg.n_clusters, self.cfg.tcdm_budget)?;
        crate::ensure!(a.len() == m * k, "A must be M*K = {} f64s, got {}", m * k, a.len());
        crate::ensure!(b.len() == k * n, "B must be K*N = {} f64s, got {}", k * n, b.len());
        let src = kind.try_src_fmt()?;
        let dst = kind.try_dst_fmt()?;
        let sw = src.width() as usize / 8;
        let dw = dst.width() as usize / 8;

        // ---- L2 images -------------------------------------------------
        // B's stream layout (order + anti-bank-aliasing leading
        // dimension) depends only on (kind, K, N): every tile shares it,
        // so B is packed into L2 once and re-read per tile.
        let b_ld = GemmKernel::try_new(kind, plan.tiles[0].rows, n, k)?.b_ld();
        let a_img = pack_matrix(a, m, k, src, MatrixOrder::RowMajor);
        let b_img = pack_matrix_ld(b, k, n, src, kind.b_order(), b_ld);
        let a_off = 0u64;
        let b_off = align64(a_off + a_img.len() as u64);
        let c_off = align64(b_off + b_img.len() as u64);
        let mut l2_img = vec![0u8; (c_off as usize) + m * n * dw];
        l2_img[..a_img.len()].copy_from_slice(&a_img);
        l2_img[b_off as usize..b_off as usize + b_img.len()].copy_from_slice(&b_img);

        let l2_model = L2Model::new(self.cfg.l2, plan.active_clusters);
        let mut clusters = Vec::with_capacity(self.cfg.n_clusters);
        let mut l2_total = L2Stats::default();
        let mut flops = 0u64;

        for (cl_id, tile_ids) in plan.per_cluster.iter().enumerate() {
            let mut dma = DmaEngine::default();
            let mut stats = CoreStats::default();
            let mut l2_stats = L2Stats::default();
            let mut tile_costs = Vec::with_capacity(tile_ids.len());
            for &ti in tile_ids {
                let tile = &plan.tiles[ti];
                let tk = GemmKernel::try_new(kind, tile.rows, n, k)?;
                let b_rel = tk.b_base() - TCDM_BASE;
                let c_rel = tk.c_base() - TCDM_BASE;
                let mut staging = vec![0u8; tk.footprint_padded() as usize];

                // -- input fills: one 2-D strided A + one B transfer per
                //    ascending-k chunk, through the real DMA engine.
                let mut fills = Vec::with_capacity(tile.chunks.len());
                for ch in &tile.chunks {
                    dma.src = GLOBAL_BASE + a_off + ((tile.row0 * k + ch.k0) * sw) as u64;
                    dma.dst = TCDM_BASE + (ch.k0 * sw) as u64;
                    let a_id = dma.enqueue_2d(
                        tile.rows as u64,
                        (ch.klen * sw) as u64,
                        (k * sw) as u64,
                        (k * sw) as u64,
                    );
                    let stride = (b_ld * sw) as u64;
                    let (lines, line_bytes, boff) = match kind.b_order() {
                        MatrixOrder::ColMajor => (n as u64, (ch.klen * sw) as u64, (ch.k0 * sw) as u64),
                        MatrixOrder::RowMajor => (ch.klen as u64, (n * sw) as u64, (ch.k0 * b_ld * sw) as u64),
                    };
                    dma.src = GLOBAL_BASE + b_off + boff;
                    dma.dst = TCDM_BASE + b_rel + boff;
                    let b_id = dma.enqueue_2d(lines, line_bytes, stride, stride);
                    let dma_cycles = dma.drain(&mut staging, &mut l2_img);
                    // The transfer-complete events arrive in FIFO order;
                    // the schedule's "chunk ready" edge is b_id retiring.
                    let done = dma.take_completed();
                    debug_assert_eq!(done, vec![a_id, b_id], "DMA completion order broke FIFO");
                    let bytes = ((tile.rows + n) * ch.klen * sw) as u64;
                    l2_stats.read_bytes += bytes;
                    l2_stats.transfers += 2;
                    fills.push(ChunkCost { bytes, dma_cycles, compute_cycles: 0 });
                }

                // The DMA-staged TCDM image must be byte-identical to
                // what the kernel harness packs — the data plane and the
                // compute plane must agree before we trust either.
                assert_eq!(
                    &staging[..tile.rows * k * sw],
                    &pack_matrix(&a[tile.row0 * k..(tile.row0 + tile.rows) * k], tile.rows, k, src, MatrixOrder::RowMajor)[..],
                    "DMA-staged A tile differs from kernel packing (rows {}..{})",
                    tile.row0,
                    tile.row0 + tile.rows
                );
                assert_eq!(
                    &staging[b_rel as usize..b_rel as usize + b_img.len()],
                    &b_img[..],
                    "DMA-staged B differs from kernel packing"
                );

                // -- tile compute: the unmodified single-cluster kernel,
                //    full-K fold (this is the bit-identity invariant).
                let res = tk.run_mode(
                    &a[tile.row0 * k..(tile.row0 + tile.rows) * k],
                    b,
                    self.cfg.mode,
                );
                flops += res.flops;
                add_stats(&mut stats, &res.stats);

                // Apportion the tile's cycles to its chunks by k share
                // (integer; remainder to the last chunk so they sum
                // exactly to the kernel's cycle count).
                let mut given = 0u64;
                for (i, ch) in tile.chunks.iter().enumerate() {
                    let share = if i + 1 == tile.chunks.len() {
                        res.cycles - given
                    } else {
                        res.cycles * ch.klen as u64 / k as u64
                    };
                    given += share;
                    fills[i].compute_cycles = share;
                }

                // -- C write-back through the same engine.
                let c_len = tile.rows * n * dw;
                let c_pack = pack_matrix(&res.c, tile.rows, n, dst, MatrixOrder::RowMajor);
                staging[c_rel as usize..c_rel as usize + c_len].copy_from_slice(&c_pack);
                dma.src = TCDM_BASE + c_rel;
                dma.dst = GLOBAL_BASE + c_off + (tile.row0 * n * dw) as u64;
                dma.enqueue((c_len) as u64);
                let wb_cycles = dma.drain(&mut staging, &mut l2_img);
                dma.take_completed();
                l2_stats.write_bytes += c_len as u64;
                l2_stats.transfers += 1;

                tile_costs.push(TileCost {
                    chunks: fills,
                    writeback: ChunkCost { bytes: c_len as u64, dma_cycles: wb_cycles, compute_cycles: 0 },
                });
            }
            // Both branches run the same resolver (`sched::schedule_impl`),
            // so tracing can never move a cycle — the differential tests
            // pin the timeline either way.
            let timeline = if crate::obs::trace::enabled() {
                let (tl, events) = sched::schedule_with_events(&tile_costs, &l2_model);
                for ev in &events {
                    let (name, cat) = match ev.kind {
                        sched::SchedEventKind::Fill => ("dma.chunk", "soc"),
                        sched::SchedEventKind::Compute => ("compute.chunk", "soc"),
                        sched::SchedEventKind::Writeback => ("writeback", "soc"),
                    };
                    crate::obs::trace::virt_span(
                        crate::obs::trace::Clock::Cycles,
                        cl_id as u64,
                        name,
                        cat,
                        ev.start,
                        ev.end - ev.start,
                        || format!("\"tile\":{},\"chunk\":{},\"bytes\":{}", ev.tile, ev.chunk, ev.bytes),
                    );
                }
                tl
            } else {
                sched::schedule(&tile_costs, &l2_model)
            };
            l2_total.merge(&l2_stats);
            clusters.push(ClusterRun { timeline, stats, l2: l2_stats, tiles: tile_ids.len() });
        }

        // SoC barrier: the run ends when the slowest cluster retires.
        let total_cycles = clusters.iter().map(|c| c.timeline.end).max().unwrap_or(0);
        let critical = clusters
            .iter()
            .max_by_key(|c| c.timeline.end)
            .map(|c| c.timeline)
            .unwrap_or_default();
        let compute_cycles = clusters.iter().map(|c| c.timeline.compute_busy).max().unwrap_or(0);

        // Metrics dual-write next to the same aggregates the result
        // struct reports (critical-cluster view, matching `soc_shares`).
        crate::obs_count!("soc.cycles.total", total_cycles);
        crate::obs_count!("soc.cycles.compute", compute_cycles);
        crate::obs_count!("soc.cycles.dma_stall", critical.dma_stall);
        crate::obs_count!("soc.l2.read_bytes", l2_total.read_bytes);
        crate::obs_count!("soc.l2.write_bytes", l2_total.write_bytes);
        crate::obs_count!("soc.l2.transfers", l2_total.transfers);

        let c_bytes = &l2_img[c_off as usize..c_off as usize + m * n * dw];
        let c = unpack_matrix(c_bytes, m, n, dst, MatrixOrder::RowMajor);
        Ok(SocRunResult {
            c,
            total_cycles,
            compute_cycles,
            dma_stall_cycles: critical.dma_stall,
            flops,
            l2: l2_total,
            clusters,
            active_clusters: plan.active_clusters,
        })
    }
}

/// Field-wise accumulation of op counters (cycles saturate to max —
/// tiles run back-to-back on one cluster, so summing wall-cycles here
/// would double-count what the timeline already owns).
fn add_stats(agg: &mut CoreStats, s: &CoreStats) {
    agg.cycles = agg.cycles.max(s.cycles);
    agg.int_retired += s.int_retired;
    agg.fp_issued += s.fp_issued;
    agg.flops += s.flops;
    agg.fp_idle += s.fp_idle;
    agg.stall_raw += s.stall_raw;
    agg.stall_bank += s.stall_bank;
    agg.stall_fifo_full += s.stall_fifo_full;
    agg.ssr_elems += s.ssr_elems;
    agg.ops_addmul += s.ops_addmul;
    agg.ops_sdotp += s.ops_sdotp;
    agg.ops_cast += s.ops_cast;
    agg.ops_comp += s.ops_comp;
    agg.ops_fmem += s.ops_fmem;
}

fn align64(a: u64) -> u64 {
    (a + 63) & !63
}
