//! The per-cluster ping-pong schedule: overlapping DMA with compute.
//!
//! Each cluster owns two input staging buffers. Chunk `c` lands in
//! buffer `c mod 2`; its transfer may start once the DMA engine is free
//! **and** the previous occupant of that buffer has been consumed
//! (compute of chunk `c − 2` finished). Compute of chunk `c` may start
//! once chunk `c − 1`'s compute finished **and** chunk `c`'s transfer
//! retired — that transfer-complete edge is the
//! [`crate::cluster::dma::DmaEngine::take_completed`] event in the data
//! plane. C write-backs queue on the same DMA engine after their tile's
//! compute, overlapping the next tile's fills.
//!
//! All arithmetic is integer cycles: the schedule is exactly
//! reproducible, and the chunk compute durations of a tile sum to the
//! tile kernel's simulated cycle count — so a one-chunk, one-tile,
//! one-cluster schedule degenerates to `transfer + kernel + writeback`
//! with the compute region bit-identical to the bare cluster sim.

use super::l2::L2Model;

/// Cost of one scheduled DMA+compute granule.
#[derive(Clone, Copy, Debug, Default)]
pub struct ChunkCost {
    /// Bytes moved through L2.
    pub bytes: u64,
    /// Cycles the cluster-local DMA engine needs (measured by draining
    /// the real engine; `ceil(bytes / 64)` for saturating transfers).
    pub dma_cycles: u64,
    /// Compute cycles unlocked by this chunk (0 for write-backs).
    pub compute_cycles: u64,
}

/// One tile's schedule inputs: input chunks then a C write-back.
#[derive(Clone, Debug)]
pub struct TileCost {
    /// Ascending-k input fills (ping-pong pairs).
    pub chunks: Vec<ChunkCost>,
    /// The C write-back transfer (compute_cycles = 0).
    pub writeback: ChunkCost,
}

/// One cluster's resolved timeline.
#[derive(Clone, Copy, Debug, Default)]
pub struct Timeline {
    /// Cycle everything (compute and DMA) retired.
    pub end: u64,
    /// Cycles the cores were computing (sum of chunk compute shares =
    /// sum of tile kernel cycles).
    pub compute_busy: u64,
    /// Cycles the DMA engine was occupied (incl. L2 latency/contention).
    pub dma_busy: u64,
    /// Cycles compute sat waiting on a transfer (includes the initial
    /// fill of the first chunk — the cold-start cost the overlap can
    /// never hide).
    pub dma_stall: u64,
}

/// What one resolved [`SchedEvent`] window was doing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedEventKind {
    /// An input-chunk DMA fill occupying the engine.
    Fill,
    /// A chunk's compute window on the cores.
    Compute,
    /// The tile's C write-back transfer.
    Writeback,
}

/// One resolved window of the ping-pong timeline, in absolute cluster
/// cycles — the raw material the observability layer exports as
/// cycles-clock trace spans. Produced by [`schedule_with_events`];
/// [`schedule`] resolves the identical timeline without materializing
/// them.
#[derive(Clone, Copy, Debug)]
pub struct SchedEvent {
    /// Index into the scheduled tile sequence.
    pub tile: usize,
    /// Chunk index within the tile (0 for write-backs).
    pub chunk: usize,
    /// Fill / compute / write-back.
    pub kind: SchedEventKind,
    /// First cycle of the window.
    pub start: u64,
    /// One past the last cycle.
    pub end: u64,
    /// Bytes moved (0 for compute windows).
    pub bytes: u64,
}

/// The one ping-pong resolver: both public entry points run this exact
/// loop, so emitting events can never change a cycle of the timeline
/// (the obs differential tests pin `schedule` == `schedule_with_events`
/// on the cycle counts).
fn schedule_impl(tiles: &[TileCost], l2: &L2Model, on_event: &mut dyn FnMut(SchedEvent)) -> Timeline {
    let mut dma_free = 0u64;
    let mut compute_free = 0u64;
    let mut buffer_free = [0u64; 2];
    let mut parity = 0usize;
    let mut tl = Timeline::default();
    for (ti, tile) in tiles.iter().enumerate() {
        for (ci, ch) in tile.chunks.iter().enumerate() {
            let dur = l2.transfer_cycles(ch.bytes, ch.dma_cycles);
            let t_start = dma_free.max(buffer_free[parity]);
            let t_end = t_start + dur;
            dma_free = t_end;
            tl.dma_busy += dur;
            on_event(SchedEvent {
                tile: ti,
                chunk: ci,
                kind: SchedEventKind::Fill,
                start: t_start,
                end: t_end,
                bytes: ch.bytes,
            });
            let c_start = compute_free.max(t_end);
            tl.dma_stall += c_start - compute_free;
            let c_end = c_start + ch.compute_cycles;
            on_event(SchedEvent {
                tile: ti,
                chunk: ci,
                kind: SchedEventKind::Compute,
                start: c_start,
                end: c_end,
                bytes: 0,
            });
            buffer_free[parity] = c_end;
            compute_free = c_end;
            tl.compute_busy += ch.compute_cycles;
            parity ^= 1;
        }
        // Write-back: queued behind the tile's compute; the next tile's
        // fills queue behind it on the same engine.
        let dur = l2.transfer_cycles(tile.writeback.bytes, tile.writeback.dma_cycles);
        let w_start = dma_free.max(compute_free);
        dma_free = w_start + dur;
        tl.dma_busy += dur;
        on_event(SchedEvent {
            tile: ti,
            chunk: 0,
            kind: SchedEventKind::Writeback,
            start: w_start,
            end: w_start + dur,
            bytes: tile.writeback.bytes,
        });
    }
    tl.end = compute_free.max(dma_free);
    tl
}

/// Resolve one cluster's tile sequence against the (contended) L2.
pub fn schedule(tiles: &[TileCost], l2: &L2Model) -> Timeline {
    schedule_impl(tiles, l2, &mut |_| {})
}

/// [`schedule`] plus the per-window event list (same resolver, same
/// cycles) — what `Soc::run_gemm` exports as cycles-clock trace spans
/// when tracing is enabled.
pub fn schedule_with_events(tiles: &[TileCost], l2: &L2Model) -> (Timeline, Vec<SchedEvent>) {
    let mut events = Vec::new();
    let tl = schedule_impl(tiles, l2, &mut |ev| events.push(ev));
    (tl, events)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::l2::{L2Cfg, L2Model};

    fn l2() -> L2Model {
        // latency 10, port wide enough that the mover time dominates.
        L2Model::new(L2Cfg { bytes_per_cycle: 1 << 30, latency: 10 }, 1)
    }

    fn chunk(dma: u64, compute: u64) -> ChunkCost {
        ChunkCost { bytes: dma * 64, dma_cycles: dma, compute_cycles: compute }
    }

    #[test]
    fn single_chunk_is_fill_then_compute_then_writeback() {
        let tiles = [TileCost { chunks: vec![chunk(20, 100)], writeback: chunk(5, 0) }];
        let tl = schedule(&tiles, &l2());
        // fill 10+20, compute 100, writeback 10+5 — nothing overlaps.
        assert_eq!(tl.end, 30 + 100 + 15);
        assert_eq!(tl.compute_busy, 100);
        assert_eq!(tl.dma_stall, 30, "cold-start fill is all stall");
        assert_eq!(tl.dma_busy, 30 + 15);
    }

    #[test]
    fn second_chunk_transfer_hides_behind_first_compute() {
        let tiles = [TileCost {
            chunks: vec![chunk(20, 100), chunk(20, 100)],
            writeback: chunk(5, 0),
        }];
        let tl = schedule(&tiles, &l2());
        // Chunk 1 fills during chunk 0's 100-cycle compute: no stall
        // beyond the cold start; total = 30 + 200 + 15.
        assert_eq!(tl.dma_stall, 30);
        assert_eq!(tl.compute_busy, 200);
        assert_eq!(tl.end, 30 + 200 + 15);
    }

    #[test]
    fn slow_transfers_stall_compute() {
        let tiles = [TileCost {
            chunks: vec![chunk(200, 50), chunk(200, 50)],
            writeback: chunk(1, 0),
        }];
        let tl = schedule(&tiles, &l2());
        // DMA-bound: chunk 1's compute waits for its 210-cycle fill
        // which itself queued behind chunk 0's.
        assert_eq!(tl.dma_stall, 210 + (420 - 260));
        assert_eq!(tl.end, 420 + 50 + 11);
    }

    #[test]
    fn ping_pong_buffer_reuse_gates_the_third_chunk() {
        // Four chunks, tiny computes: chunk 2 reuses buffer 0 and must
        // wait for chunk 0's compute to finish — but with compute far
        // shorter than transfers, the DMA engine (serial) is the real
        // serializer; buffer reuse must never let transfer 2 start
        // before compute 0 ends.
        let tiles = [TileCost {
            chunks: vec![chunk(10, 1000), chunk(10, 1000), chunk(10, 1000), chunk(10, 1000)],
            writeback: chunk(1, 0),
        }];
        let tl = schedule(&tiles, &l2());
        // fill0 20; c0: 20..1020; fill1 by 40; c1: 1020..2020;
        // fill2 starts at max(dma_free=40, buffer0 free=1020) = 1020;
        // c2: 2020..3020; fill3 at max(1040, 2020); c3: 3020..4020.
        assert_eq!(tl.compute_busy, 4000);
        assert_eq!(tl.dma_stall, 20);
        assert_eq!(tl.end, 4020 + 11);
    }

    #[test]
    fn writeback_overlaps_next_tile_fill_queue() {
        let mk = |c| TileCost { chunks: vec![chunk(10, c)], writeback: chunk(10, 0) };
        let tiles = [mk(500), mk(500)];
        let tl = schedule(&tiles, &l2());
        // Tile 1's fill queues behind tile 0's writeback start but
        // still lands inside tile 0's compute? No: writeback waits for
        // compute end (520), then tile-1 fill 540..560, compute to 1060,
        // writeback ends 1060+20.
        assert_eq!(tl.end, 1060 + 20);
        assert_eq!(tl.compute_busy, 1000);
    }

    #[test]
    fn empty_schedule_is_zero() {
        let tl = schedule(&[], &l2());
        assert_eq!((tl.end, tl.compute_busy, tl.dma_busy, tl.dma_stall), (0, 0, 0, 0));
    }
}
