//! The shared-L2 bandwidth/latency model and its traffic accounting.
//!
//! Every cluster's DMA engine moves tiles between its private TCDM and
//! one L2 scratchpad shared by all clusters. Two resources bound a
//! transfer:
//!
//! * the cluster's own mover ([`crate::cluster::dma::DMA_BYTES_PER_CYCLE`]
//!   = 64 B/cycle) — its cost is measured by actually draining the
//!   [`crate::cluster::dma::DmaEngine`] that performs the copy;
//! * the L2 port ([`L2Cfg::bytes_per_cycle`]), shared by every active
//!   cluster. Contention is modeled as a **mean bandwidth share**: with
//!   `A` active clusters a transfer of `B` bytes occupies the port for
//!   `ceil(B·A / bytes_per_cycle)` cycles. This is a deliberate
//!   simplification (no per-beat interleaving) that keeps the schedule
//!   deterministic and errs pessimistic for bursty traffic — see
//!   DESIGN.md's `soc/` section.
//!
//! Each transfer additionally pays [`L2Cfg::latency`] cycles once
//! (request traversal of the interconnect + L2 access setup).

/// L2 + interconnect configuration.
#[derive(Clone, Copy, Debug)]
pub struct L2Cfg {
    /// Peak L2 port bandwidth in bytes per cycle, shared by all
    /// clusters. The default (256) feeds four clusters at the full
    /// 64 B/cycle DMA rate; eight clusters see half that each — which
    /// is exactly the knee the roofline report is there to show.
    pub bytes_per_cycle: u64,
    /// Per-transfer latency in cycles (interconnect traversal + L2
    /// access setup), paid once per queued transfer.
    pub latency: u64,
}

impl Default for L2Cfg {
    fn default() -> Self {
        L2Cfg { bytes_per_cycle: 256, latency: 40 }
    }
}

/// The L2 model bound to a run's contention level.
#[derive(Clone, Copy, Debug)]
pub struct L2Model {
    cfg: L2Cfg,
    /// Clusters actively issuing DMA in this run (≥ 1).
    contention: u64,
}

impl L2Model {
    /// Bind the configuration to a run with `active` clusters issuing
    /// transfers (clamped to ≥ 1).
    pub fn new(cfg: L2Cfg, active: usize) -> Self {
        L2Model { cfg, contention: (active as u64).max(1) }
    }

    /// Cycles one transfer occupies: the per-transfer latency plus the
    /// slower of the cluster-local mover (`dma_cycles`, measured) and
    /// the contended L2 port.
    pub fn transfer_cycles(&self, bytes: u64, dma_cycles: u64) -> u64 {
        let port = (bytes * self.contention).div_ceil(self.cfg.bytes_per_cycle);
        self.cfg.latency + dma_cycles.max(port)
    }

    /// Effective per-cluster bandwidth in bytes/cycle under the bound
    /// contention (reporting helper).
    pub fn effective_bytes_per_cycle(&self) -> f64 {
        let share = self.cfg.bytes_per_cycle as f64 / self.contention as f64;
        share.min(crate::cluster::dma::DMA_BYTES_PER_CYCLE as f64)
    }
}

/// L2 traffic accounting for one run (per cluster or SoC totals).
#[derive(Clone, Copy, Debug, Default)]
pub struct L2Stats {
    /// Bytes read from L2 (A/B tile fills).
    pub read_bytes: u64,
    /// Bytes written to L2 (C tile write-backs).
    pub write_bytes: u64,
    /// Number of DMA transfers issued.
    pub transfers: u64,
}

impl L2Stats {
    /// Total bytes through the L2 port.
    pub fn total_bytes(&self) -> u64 {
        self.read_bytes + self.write_bytes
    }

    /// Merge another accounting record into this one.
    pub fn merge(&mut self, other: &L2Stats) {
        self.read_bytes += other.read_bytes;
        self.write_bytes += other.write_bytes;
        self.transfers += other.transfers;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uncontended_transfer_is_latency_plus_mover_time() {
        let l2 = L2Model::new(L2Cfg::default(), 1);
        // 256 B at 64 B/cycle mover = 4 cycles; port does it in 1 —
        // the mover is the bottleneck when the port is idle.
        assert_eq!(l2.transfer_cycles(256, 4), 40 + 4);
    }

    #[test]
    fn contention_divides_the_port() {
        let cfg = L2Cfg::default();
        // 8 clusters share 256 B/cycle → 32 B/cycle each: a 6400-byte
        // tile fill takes 200 port cycles, dominating the 100-cycle
        // mover time.
        let l2 = L2Model::new(cfg, 8);
        assert_eq!(l2.transfer_cycles(6400, 100), 40 + 200);
        assert!((l2.effective_bytes_per_cycle() - 32.0).abs() < 1e-12);
        // At 4 clusters the port share equals the mover rate.
        let l2 = L2Model::new(cfg, 4);
        assert!((l2.effective_bytes_per_cycle() - 64.0).abs() < 1e-12);
    }

    #[test]
    fn stats_merge_accumulates() {
        let mut a = L2Stats { read_bytes: 10, write_bytes: 2, transfers: 1 };
        a.merge(&L2Stats { read_bytes: 5, write_bytes: 3, transfers: 2 });
        assert_eq!(a.total_bytes(), 20);
        assert_eq!(a.transfers, 3);
    }
}
