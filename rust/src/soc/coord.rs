//! The SoC coordinator: partitioning one large GEMM across clusters.
//!
//! ## Partitioning strategy (and why it preserves bit-identity)
//!
//! The coordinator splits **M only**. Each cluster owns a contiguous
//! band of output rows; each band is cut into tiles whose logical
//! footprint fits the 128 kB TCDM, and each tile runs the *unmodified*
//! single-cluster kernel ([`crate::kernels::GemmKernel`]) over the
//! **full K extent**. Because every output element is produced by
//! exactly one kernel invocation folding k = 0..K in the kernel's own
//! ascending order, the result bits are identical to a monolithic
//! single-cluster run no matter how many clusters participate — there
//! is no cross-cluster partial-sum join to get wrong.
//!
//! K *is* chunked, but only for **data movement**: a tile's A/B inputs
//! stream from L2 in ascending-k chunks so the second chunk's DMA
//! overlaps the first chunk's compute (ping-pong double-buffering).
//! The chunk boundary is a barrier in the *schedule* (compute of chunk
//! c may not start before its transfer retires), never a boundary in
//! the *fold* — accumulators live in registers across it.

use crate::kernels::GemmKind;
use crate::util::error::Result;

/// One ascending-k input chunk of a tile (data movement granule).
#[derive(Clone, Copy, Debug)]
pub struct KChunk {
    /// First k index covered.
    pub k0: usize,
    /// Number of k indices covered (a multiple of the kernel's SIMD
    /// width, so chunk boundaries fall between packed words).
    pub klen: usize,
}

/// One tile: a contiguous band of output rows owned by one cluster.
#[derive(Clone, Debug)]
pub struct Tile {
    /// Owning cluster index.
    pub cluster: usize,
    /// First output row.
    pub row0: usize,
    /// Rows in this tile (a positive multiple of 8).
    pub rows: usize,
    /// Ascending-k input chunks (1 or 2; ping-pong pairs).
    pub chunks: Vec<KChunk>,
}

/// The full partition of one GEMM across the SoC.
#[derive(Clone, Debug)]
pub struct SocPlan {
    /// All tiles, in (cluster, row) order.
    pub tiles: Vec<Tile>,
    /// Tile indices per cluster (empty for idle clusters).
    pub per_cluster: Vec<Vec<usize>>,
    /// The row cap a TCDM-resident tile may have for this problem.
    pub tile_m_max: usize,
    /// Clusters that received at least one tile.
    pub active_clusters: usize,
}

/// Partition `M×N×K` across `n_clusters`, with per-tile footprints
/// bounded by `tcdm_budget` bytes (the paper's 128 kB criterion counts
/// logical data, matching [`crate::kernels::GemmKernel::footprint`]).
pub fn partition(
    kind: GemmKind,
    m: usize,
    n: usize,
    k: usize,
    n_clusters: usize,
    tcdm_budget: u64,
) -> Result<SocPlan> {
    crate::ensure!(
        (1..=8).contains(&n_clusters),
        "SoC cluster count must be 1..=8 (the paper's scale-out range), got {n_clusters}"
    );
    // Validate kind + divisibility (M % 8, N % unroll, K % lanes) with
    // the kernel's own typed errors — the tile kernels inherit them.
    let probe = crate::kernels::GemmKernel::try_new(kind, m, n, k)?;
    let sw = kind.try_src_fmt()?.width() as usize / 8;
    let dw = kind.try_dst_fmt()?.width() as usize / 8;

    // Largest TCDM-resident tile: B (K×N) is fully resident per tile,
    // each 8-row block adds A rows + C rows.
    let b_bytes = (k * n * sw) as u64;
    let per_block = (8 * (k * sw + n * dw)) as u64;
    crate::ensure!(
        b_bytes + per_block <= tcdm_budget,
        "GEMM {}x{} (K={}) cannot be tiled over M: B plus one 8-row strip needs {} bytes, \
         the TCDM budget is {} (split N or K before the SoC layer)",
        m,
        n,
        k,
        b_bytes + per_block,
        tcdm_budget
    );
    let blocks_fit = ((tcdm_budget - b_bytes) / per_block) as usize;
    let tile_m_max = m.min(blocks_fit * 8);

    // Contiguous block-balanced row assignment: m/8 blocks of 8 rows,
    // the first (blocks % n_clusters) clusters get one extra block.
    let total_blocks = m / 8;
    let base = total_blocks / n_clusters;
    let extra = total_blocks % n_clusters;

    // Data-movement chunking: split the k sweep in two word-aligned
    // halves when possible, so the ping-pong buffers have work.
    let lanes = probe.kind.lanes();
    let k_words = k / lanes;
    let chunks = if k_words >= 2 {
        let k_half = (k_words / 2) * lanes;
        vec![KChunk { k0: 0, klen: k_half }, KChunk { k0: k_half, klen: k - k_half }]
    } else {
        vec![KChunk { k0: 0, klen: k }]
    };

    let mut tiles = Vec::new();
    let mut per_cluster = vec![Vec::new(); n_clusters];
    let mut row = 0usize;
    for (cl, assigned) in per_cluster.iter_mut().enumerate() {
        let mut rows_left = (base + usize::from(cl < extra)) * 8;
        while rows_left > 0 {
            let rows = rows_left.min(tile_m_max);
            assigned.push(tiles.len());
            tiles.push(Tile { cluster: cl, row0: row, rows, chunks: chunks.clone() });
            row += rows;
            rows_left -= rows;
        }
    }
    debug_assert_eq!(row, m, "tiles must cover all output rows exactly once");
    let active_clusters = per_cluster.iter().filter(|t| !t.is_empty()).count();
    Ok(SocPlan { tiles, per_cluster, tile_m_max, active_clusters })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::instr::OpWidth;

    const FP8: GemmKind = GemmKind::ExSdotp(OpWidth::BtoH);

    #[test]
    fn single_cluster_fitting_problem_is_one_whole_tile() {
        // The paper's 575 GFLOPS/W anchor problem fits the TCDM whole:
        // at N=1 the plan must be exactly the monolithic kernel run.
        let p = partition(FP8, 128, 256, 128, 1, 128 * 1024).unwrap();
        assert_eq!(p.tiles.len(), 1);
        assert_eq!((p.tiles[0].row0, p.tiles[0].rows), (0, 128));
        assert_eq!(p.active_clusters, 1);
        assert_eq!(p.tiles[0].chunks.len(), 2, "k=128 splits into a ping-pong pair");
        assert_eq!(p.tiles[0].chunks[0].klen + p.tiles[0].chunks[1].klen, 128);
        assert_eq!(p.tiles[0].chunks[0].klen % 8, 0, "chunk edge on a packed-word boundary");
    }

    #[test]
    fn rows_are_covered_once_in_8_row_blocks() {
        for n_clusters in [1, 2, 3, 5, 8] {
            let p = partition(FP8, 192, 64, 64, n_clusters, 128 * 1024).unwrap();
            let mut covered = 0;
            let mut next_row = 0;
            for t in &p.tiles {
                assert_eq!(t.row0, next_row, "tiles are contiguous in row order");
                assert!(t.rows > 0 && t.rows % 8 == 0);
                next_row += t.rows;
                covered += t.rows;
            }
            assert_eq!(covered, 192);
            // Balance: cluster row totals differ by at most one block.
            let totals: Vec<usize> = p
                .per_cluster
                .iter()
                .map(|ts| ts.iter().map(|&i| p.tiles[i].rows).sum())
                .collect();
            let (min, max) = (totals.iter().min().unwrap(), totals.iter().max().unwrap());
            assert!(max - min <= 8, "unbalanced rows {totals:?}");
        }
    }

    #[test]
    fn oversized_problems_split_into_tcdm_sized_tiles() {
        // FP8 256×256 K=256: logical footprint 256 kB — must split.
        let p = partition(FP8, 256, 256, 256, 2, 128 * 1024).unwrap();
        assert!(p.tiles.len() > 2, "expected multiple tiles per cluster");
        let sw = 1;
        let dw = 2;
        for t in &p.tiles {
            let fp = (t.rows * 256 + 256 * 256) * sw + t.rows * 256 * dw;
            assert!(fp as u64 <= 128 * 1024, "tile rows={} footprint {fp} over budget", t.rows);
        }
    }

    #[test]
    fn infeasible_column_footprint_is_a_typed_error() {
        // B alone (K×N in FP8 = 512×512 = 256 kB) exceeds the TCDM: no
        // M-tiling can help, and the coordinator must say so.
        let err = partition(FP8, 64, 512, 512, 4, 128 * 1024).unwrap_err();
        assert!(err.to_string().contains("cannot be tiled over M"), "{err}");
    }

    #[test]
    fn invalid_shapes_reuse_kernel_typed_errors() {
        assert!(partition(FP8, 12, 64, 64, 2, 128 * 1024).is_err(), "M % 8");
        assert!(partition(FP8, 64, 66, 64, 2, 128 * 1024).is_err(), "N % unroll");
        assert!(partition(FP8, 64, 64, 12, 2, 128 * 1024).is_err(), "K % lanes");
        assert!(partition(FP8, 64, 64, 64, 0, 128 * 1024).is_err(), "cluster count");
        assert!(partition(FP8, 64, 64, 64, 9, 128 * 1024).is_err(), "cluster count");
    }
}
