//! # minifloat-nn
//!
//! Reproduction of **"MiniFloat-NN and ExSdotp: An ISA Extension and a
//! Modular Open Hardware Unit for Low-Precision Training on RISC-V cores"**
//! (Bertaccini, Paulin, Fischer, Mach, Benini — 2022).
//!
//! ## Entry point: the typed [`api`]
//!
//! The crate's front door is the [`api`] module (re-exported through
//! [`prelude`]): build a [`api::Session`] holding execution policy,
//! quantize matrices into typed [`api::MfTensor`]s, and run validated
//! [`api::GemmPlan`]s / [`api::AccumulatePlan`]s that return structured
//! [`api::RunReport`]s. All argument errors — unsupported format pairs,
//! shape mismatches, infeasible problems — surface as typed
//! [`util::error::Error`]s at plan-build time.
//!
//! ```
//! use minifloat_nn::prelude::*;
//!
//! # fn main() -> minifloat_nn::util::error::Result<()> {
//! let session = Session::builder().mode(ExecMode::Functional).build();
//! let mut rng = session.rng();
//! let a: Vec<f64> = (0..16 * 16).map(|_| rng.gaussian() * 0.25).collect();
//! let b: Vec<f64> = (0..16 * 16).map(|_| rng.gaussian() * 0.25).collect();
//! // FP8 sources, FP16 expanding accumulation (the paper's headline kernel).
//! let report = session.gemm().src(FP8).acc(FP16).dims(16, 16, 16)?.run_f64(&a, &b)?;
//! println!("{:.1} FLOP/cycle", report.flop_per_cycle().unwrap_or(0.0));
//! # Ok(())
//! # }
//! ```
//!
//! ## The stack underneath
//!
//! The crate models the paper's full hardware/software stack:
//!
//! * [`formats`] — parametric floating-point format descriptors (FP64,
//!   FP32, FP16, FP16alt, FP8, FP8alt and user-defined minifloats),
//!   plus the compile-time [`formats::spec`] layer (`FormatSpec`) that
//!   the monomorphized fast tiers instantiate at.
//! * [`softfloat`] — bit-accurate IEEE-754 emulation for any format:
//!   add/mul/FMA/expanding-FMA, casts, comparisons, all five RISC-V
//!   rounding modes; [`softfloat::fast`] is the monomorphized twin.
//! * [`batch`] — the slice-level batch numerics engine: packed-register
//!   GEMM, accumulation and cast sweeps on the monomorphized kernels,
//!   parallel across rows — bit-identical to the simulated cluster
//!   (`ExecMode::Functional` runs on it).
//! * [`exsdotp`] — the paper's core contribution: the fused expanding
//!   sum-of-dot-product datapath (§III-B), the ExVsum/Vsum reuse of the
//!   same datapath (§III-C), the discrete two-ExFMA-cascade baseline, and
//!   the 64-bit SIMD wrapper (§III-D).
//! * [`fpu`] — the extended-FPnew model: operation groups, pipeline
//!   depths, per-op bookkeeping used by the timing and energy models.
//! * [`isa`] — the MiniFloat-NN RISC-V ISA extension: instruction forms,
//!   32-bit encodings, assembler/disassembler, FP CSR with the
//!   `src_is_alt` / `dst_is_alt` bits (§III-E).
//! * [`core`] — the Snitch PE model: pseudo dual-issue sequencer, FP
//!   scoreboard, SSR stream semantic registers, FREP hardware loop.
//! * [`cluster`] — the 8-compute-core + DMA-core cluster sharing a
//!   32-bank scratchpad (TCDM) with bank-conflict arbitration (Fig. 6).
//! * [`kernels`] — GEMM program generators (FMA-based and ExSdotp-based)
//!   mirroring the paper's SSR+FREP kernel structure (§IV-B).
//! * [`area`] — parametric gate-count area/timing model (Fig. 7).
//! * [`energy`] — per-op energy model (Table III, §IV-C).
//! * [`accuracy`] — the Gaussian dot-product accumulation accuracy
//!   harness (Table IV).
//! * [`runtime`] — PJRT loader executing the AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`) from Rust.
//! * [`coordinator`] — the artifact-backed (PJRT) training driver:
//!   batching, step loop, metrics.
//! * [`nn`] — the **native** mixed-precision training subsystem:
//!   layers with hand-written backward passes, a reverse-mode tape
//!   over typed minifloat activations, FP32-master optimizers and
//!   dynamic loss scaling — every matmul a validated [`api::GemmPlan`]
//!   on the ExSdotp batch engine ([`api::Session::train`]).
//! * [`serve`] — multi-tenant batched inference serving: frozen
//!   [`serve::InferenceModel`] snapshots with pre-packed weights
//!   (every request GEMM on the zero-repack route), deadline-aware
//!   queues, a dynamic batcher, a shard pool, and a seeded
//!   virtual-time load generator — deterministic down to the bit
//!   ([`api::Session::server`]).
//! * [`soc`] — the multi-cluster SoC model: N clusters off a shared
//!   L2 with bandwidth/latency contention, per-cluster DMA ping-pong
//!   double-buffering, an M-partitioning coordinator that keeps results
//!   bit-identical to a single cluster at every cluster count, and the
//!   roofline sweep ([`soc::run_roofline`], `repro roofline`).
//! * [`numerics`] — accuracy-at-scale numerics: seeded stochastic
//!   rounding ([`softfloat::RoundingMode::StochasticRound`], threaded
//!   through every engine tier bit-deterministically), chunked big-K
//!   accumulation ([`api::GemmPlanBuilder::chunk_k`]), Flexpoint-style
//!   scaled tensors ([`numerics::ScaledTensor`]) with predictive
//!   exponent management, and the accuracy matrix behind
//!   `repro accuracy` ([`numerics::run_sweep`]).
//! * [`obs`] — the deterministic observability layer: a sharded
//!   metrics registry with byte-stable snapshots, virtual-time /
//!   wall-time span tracing with a Chrome-trace exporter, and the
//!   profiling roll-up — off by default, bit-transparent when on
//!   (`repro ... --metrics --trace FILE`).
//!
//! See `DESIGN.md` for the paper→module map and `EXPERIMENTS.md` for the
//! reproduced tables and figures.
//!
//! The batch engine's lane math runs a SWAR (SIMD-within-a-register)
//! tier by default ([`batch::LaneTier`]); the `simd-nightly` cargo
//! feature additionally widens the packed-panel screens with
//! `std::simd` (nightly toolchains only — the stable SWAR default needs
//! no feature).

#![cfg_attr(feature = "simd-nightly", feature(portable_simd))]

pub mod accuracy;
pub mod api;
pub mod area;
pub mod batch;
pub mod cluster;
pub mod coordinator;
pub mod core;
pub mod energy;
pub mod exsdotp;
pub mod formats;
pub mod fpu;
pub mod isa;
pub mod kernels;
pub mod nn;
pub mod numerics;
pub mod obs;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod soc;
pub mod softfloat;
pub mod util;
pub mod wide;

pub use formats::{FpFormat, FP16, FP16ALT, FP32, FP64, FP8, FP8ALT};
pub use kernels::gemm::ExecMode;
pub use softfloat::{RoundingMode, SoftFloat};

/// One-line import for the typed API:
/// `use minifloat_nn::prelude::*;` brings in the session/tensor/plan
/// types (including the native-training and serving plans), the six
/// paper formats, and the execution/rounding enums.
pub mod prelude {
    pub use crate::accuracy::AccuracyPoint;
    pub use crate::api::{
        AccumulatePlan, AccumulatePlanBuilder, GemmPlan, GemmPlanBuilder, Layout, MfTensor,
        MfTensorView, PlanInstance, RunInfo, RunReport, ServePlan, ServePlanBuilder, Session,
        SessionBuilder, TrainPlan, TrainPlanBuilder,
    };
    pub use crate::formats::{FpFormat, FP16, FP16ALT, FP32, FP64, FP8, FP8ALT};
    pub use crate::kernels::gemm::{ExecMode, GemmKind};
    pub use crate::nn::{
        Activation, DataSpec, NativeTrainer, OptimSpec, PrecisionPolicy, StepRecord,
    };
    pub use crate::numerics::{ExponentManager, ScaledTensor};
    pub use crate::serve::{InferenceModel, ServeStats, Server};
    pub use crate::soc::{Soc, SocCfg};
    pub use crate::softfloat::RoundingMode;
    pub use crate::util::error::{Error, Result};
}
