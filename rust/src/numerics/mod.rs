//! Accuracy-at-scale numerics: stochastic rounding, chunked
//! accumulation, and Flexpoint-style scaled-tensor formats.
//!
//! Three pillars, layered strictly on `formats`/`softfloat`/`batch`/
//! `api` (the sweep additionally drives the `nn` trainer from above):
//!
//! 1. **Stochastic rounding** lives in the softfloat core
//!    ([`crate::softfloat::RoundingMode::StochasticRound`]): every
//!    rounding decision is a seeded coin flip whose probability is the
//!    distance to the two neighboring grid points. The key is derived
//!    counter-style from the element/lane/step indices
//!    (`sr_element`/`sr_lane`/`sr_step`/…, see `softfloat::round`), so
//!    results are deterministic per seed and bit-identical across
//!    thread counts, lane tiers, and executor backends. Sessions opt in
//!    with [`crate::api::SessionBuilder::stochastic_rounding`].
//! 2. **Chunked accumulation** lives in the batch engine
//!    ([`crate::batch::gemm_packed_chunked_into`], selected via
//!    [`crate::api::GemmPlanBuilder::chunk_k`]): big-K dot products
//!    fold in fixed-size sub-trees instead of one long sequential
//!    chain, shrinking the worst-case rounding-error growth from
//!    O(K) toward O(K/c + log c).
//! 3. **Scaled tensors** ([`ScaledTensor`], this module): a packed
//!    minifloat payload plus one shared power-of-two scale per tensor,
//!    with predictive exponent management ([`ExponentManager`]) driven
//!    by overflow/headroom statistics — the Flexpoint recipe (Köster et
//!    al. 2017) adapted to minifloat payloads. The nn trainer applies
//!    the same recipe to forward activations under
//!    [`crate::nn::PrecisionPolicy::fp8flex`].
//!
//! [`sweep`] ties the three together: the accuracy matrix
//! ({format × rounding × chunking × scaling} on spiral training plus a
//! big-K dot probe against an f64 reference) behind `repro accuracy`.

pub mod scaled;
pub mod sweep;

pub use scaled::{exp2, shared_exponent, ExponentManager, ScaledTensor, TensorStats};
pub use sweep::{run_sweep, AccuracySweep, DotPoint, TrainPoint};
