//! The accuracy-at-scale matrix: {format × rounding × chunking ×
//! scaling} on spiral training, plus a big-K dot-product probe against
//! an f64 reference — the numbers behind `repro accuracy` and
//! `BENCH_accuracy.json`.
//!
//! Everything here is deterministic from the sweep seed: the trainer
//! rows reuse the nn subsystem's seeded spiral task, the dot probe
//! draws its operands from a seeded RNG, and the embedded
//! stochastic-rounding determinism check re-runs the SR+chunked probe
//! under thread budgets {1, 4, 7} and demands bit-equal outputs — the
//! repo-wide bit-identity invariant, gated in CI.

use crate::api::Session;
use crate::ensure;
use crate::formats::{FP16, FP8};
use crate::nn::policy::PrecisionPolicy;
use crate::util::error::Result;
use crate::util::rng::Rng;

/// Dot-probe shape: `PROBE_M×PROBE_N` outputs over a `PROBE_K`-deep
/// inner dimension — deep enough that accumulation-order error growth
/// dominates quantization noise.
pub const PROBE_M: usize = 8;
/// See [`PROBE_M`].
pub const PROBE_N: usize = 8;
/// See [`PROBE_M`].
pub const PROBE_K: usize = 8192;
/// Chunk size (elements of K) the chunked probe folds at.
pub const PROBE_CHUNK: usize = 256;

/// One spiral-training row of the matrix.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TrainPoint {
    /// Policy name (`fp32`, `fp8sr`, …).
    pub policy: &'static str,
    /// `"sr"` when the policy rounds stochastically, else `"rne"`.
    pub rounding: &'static str,
    /// Whether forward activations ran through the shared-scale path.
    pub scaled: bool,
    /// Classification accuracy over the full dataset after training.
    pub accuracy: f64,
    /// Final training loss.
    pub final_loss: f64,
    /// Steps skipped by loss-scaling overflow backoff.
    pub skipped: u64,
}

/// One big-K dot-probe cell: FP8 operands, FP16 ExSdotp accumulation,
/// error against the f64 reference GEMM.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DotPoint {
    /// `"rne"` or `"sr"`.
    pub rounding: &'static str,
    /// `Some(chunk)` for the chunked-accumulation run, `None` naive.
    pub chunk: Option<usize>,
    /// Max absolute error over the `PROBE_M×PROBE_N` outputs.
    pub max_abs_err: f64,
    /// Mean absolute error.
    pub mean_abs_err: f64,
}

/// The full sweep result (render with
/// [`crate::report::accuracy_text`] / [`crate::report::accuracy_json`]).
#[derive(Clone, Debug, PartialEq)]
pub struct AccuracySweep {
    /// Training steps per policy row.
    pub steps: usize,
    /// Seed everything derives from.
    pub seed: u64,
    /// Spiral-training rows (one per policy).
    pub train: Vec<TrainPoint>,
    /// Dot-probe cells ({rne, sr} × {naive, chunked}).
    pub dot: Vec<DotPoint>,
    /// Whether the SR+chunked probe was bit-identical across thread
    /// budgets {1, 4, 7}.
    pub sr_deterministic: bool,
}

impl AccuracySweep {
    /// The accuracy of a named policy row, if present.
    pub fn train_accuracy(&self, policy: &str) -> Option<f64> {
        self.train.iter().find(|t| t.policy == policy).map(|t| t.accuracy)
    }

    /// The CI gates: SR must be bit-deterministic across thread
    /// budgets, and the FP8+SR spiral row must land within 3 accuracy
    /// points of the fp32 baseline.
    pub fn check_gates(&self) -> Result<()> {
        ensure!(
            self.sr_deterministic,
            "stochastic rounding was not bit-identical across thread budgets {{1, 4, 7}}"
        );
        let fp32 = self.train_accuracy("fp32").unwrap_or(0.0);
        let fp8sr = self.train_accuracy("fp8sr").unwrap_or(0.0);
        ensure!(
            fp8sr + 0.03 >= fp32,
            "fp8sr spiral accuracy {fp8sr:.3} fell more than 3 points below the fp32 \
             baseline {fp32:.3}"
        );
        Ok(())
    }
}

/// Reference `C = A·B` in f64 (row-major, no quantization) — the
/// golden the dot probe measures against.
fn gemm_f64(a: &[f64], b: &[f64], m: usize, n: usize, k: usize) -> Vec<f64> {
    let mut c = vec![0.0; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0;
            for kk in 0..k {
                acc += a[i * k + kk] * b[kk * n + j];
            }
            c[i * n + j] = acc;
        }
    }
    c
}

/// Run one probe cell and return the output values.
fn probe_run(seed: u64, sr: bool, chunk: Option<usize>, threads: Option<usize>, a: &[f64], b: &[f64]) -> Result<Vec<f64>> {
    let mut builder = Session::builder().seed(seed);
    if sr {
        builder = builder.stochastic_rounding();
    }
    if let Some(t) = threads {
        builder = builder.threads(t);
    }
    let session = builder.build();
    let mut plan = session.gemm().src(FP8).acc(FP16);
    if let Some(c) = chunk {
        plan = plan.chunk_k(c);
    }
    let report = plan.dims(PROBE_M, PROBE_N, PROBE_K)?.run_f64(a, b)?;
    Ok(report.c_f64())
}

/// Run the full matrix. `steps` spiral-training steps per policy
/// (`repro accuracy` uses 300); everything derives from `seed`.
pub fn run_sweep(steps: usize, seed: u64) -> Result<AccuracySweep> {
    let _sp = crate::obs::trace::span("numerics.sweep", "numerics");
    // ---- training rows: the five plain presets + the two numerics
    // presets, all on the same task, same seed.
    let mut train = Vec::new();
    let policies = PrecisionPolicy::presets()
        .into_iter()
        .chain(PrecisionPolicy::numerics_presets());
    for policy in policies {
        let session = Session::builder().seed(seed).build();
        let mut tr = session.train().policy(policy).build()?.trainer()?;
        let final_loss = tr.train(steps, 0)?;
        let accuracy = tr.accuracy()?;
        train.push(TrainPoint {
            policy: policy.name,
            rounding: if policy.stochastic { "sr" } else { "rne" },
            scaled: policy.scaled,
            accuracy,
            final_loss,
            skipped: tr.skipped_steps(),
        });
    }

    // ---- big-K dot probe: FP8 -> FP16 accumulation, {rne, sr} ×
    // {naive, chunked}, error vs the f64 reference.
    let mut rng = Rng::new(seed ^ 0xACC5);
    let a: Vec<f64> = (0..PROBE_M * PROBE_K).map(|_| rng.gaussian() * 0.25).collect();
    let b: Vec<f64> = (0..PROBE_K * PROBE_N).map(|_| rng.gaussian() * 0.25).collect();
    let golden = gemm_f64(&a, &b, PROBE_M, PROBE_N, PROBE_K);
    let mut dot = Vec::new();
    for sr in [false, true] {
        for chunk in [None, Some(PROBE_CHUNK)] {
            let out = probe_run(seed, sr, chunk, None, &a, &b)?;
            let mut max_abs = 0.0f64;
            let mut sum_abs = 0.0f64;
            for (&g, &o) in golden.iter().zip(&out) {
                let e = (g - o).abs();
                max_abs = max_abs.max(e);
                sum_abs += e;
            }
            dot.push(DotPoint {
                rounding: if sr { "sr" } else { "rne" },
                chunk,
                max_abs_err: max_abs,
                mean_abs_err: sum_abs / golden.len() as f64,
            });
        }
    }

    // ---- SR determinism: the SR+chunked cell re-run under explicit
    // thread budgets must be bit-identical.
    let reference: Vec<u64> = probe_run(seed, true, Some(PROBE_CHUNK), Some(1), &a, &b)?
        .iter()
        .map(|v| v.to_bits())
        .collect();
    let mut sr_deterministic = true;
    for t in [4usize, 7] {
        let bits: Vec<u64> = probe_run(seed, true, Some(PROBE_CHUNK), Some(t), &a, &b)?
            .iter()
            .map(|v| v.to_bits())
            .collect();
        sr_deterministic &= bits == reference;
    }

    Ok(AccuracySweep { steps, seed, train, dot, sr_deterministic })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_probe_is_seeded_and_chunking_helps_at_big_k() {
        // One training step keeps this test cheap; the probe is the
        // point here.
        let sweep = run_sweep(1, 42).expect("sweep");
        assert_eq!(sweep.train.len(), 7, "five plain presets + fp8sr + fp8flex");
        assert_eq!(sweep.dot.len(), 4, "{{rne, sr}} x {{naive, chunked}}");
        assert!(sweep.sr_deterministic, "SR must be bit-identical across thread budgets");
        let cell = |r: &str, c: Option<usize>| {
            sweep
                .dot
                .iter()
                .find(|d| d.rounding == r && d.chunk == c)
                .copied()
                .expect("cell present")
        };
        let naive = cell("rne", None);
        let chunked = cell("rne", Some(PROBE_CHUNK));
        assert!(
            chunked.max_abs_err <= naive.max_abs_err,
            "chunked accumulation must not be worse than the naive chain at K={PROBE_K}: \
             chunked {} vs naive {}",
            chunked.max_abs_err,
            naive.max_abs_err
        );
        // SR decorrelates the accumulation bias: its mean error stays
        // in the same regime as RNE's (sanity band, not a tight claim).
        let sr = cell("sr", None);
        assert!(sr.mean_abs_err <= 10.0 * naive.mean_abs_err.max(1e-12));
    }
}
