//! Flexpoint-style scaled tensors: a packed minifloat payload plus one
//! shared power-of-two scale, with predictive exponent management.
//!
//! A narrow format spends most of its encoding space on a fixed window
//! of binades; real tensors drift out of that window as training
//! proceeds. [`ScaledTensor`] re-centers each tensor before
//! quantization: the logical value is `payload · 2^scale_exp`, where
//! `scale_exp` places the tensor's largest magnitude a configurable
//! headroom below the format's overflow threshold. Because the scale is
//! a power of two, applying and removing it is *exact* in f64, and —
//! as long as no value crosses the subnormal or overflow boundary —
//! commutes with round-to-nearest quantization bit-for-bit.
//!
//! [`ExponentManager`] chooses the next tensor's scale predictively
//! from the current tensor's statistics (max exponent trend +
//! saturation pressure), the Flexpoint "Autoflex" recipe: adjusting
//! from *stats* rather than re-scanning avoids a second pass over the
//! data on the hot path. Every committed adjustment counts on the
//! `numerics.scale.adjusts` observability counter.

use crate::api::{MfTensor, Session};
use crate::ensure;
use crate::formats::FpFormat;
use crate::util::error::Result;

/// Exact power-of-two `2^e` as f64, built by bit assembly (no libm, so
/// the value is identical on every platform). `e` is clamped to the
/// f64 normal range — scales outside ±1022 binades are far beyond any
/// representable payload anyway.
pub fn exp2(e: i32) -> f64 {
    let e = e.clamp(-1022, 1023);
    f64::from_bits(((e + 1023) as u64) << 52)
}

/// The unbiased binary exponent of `v`'s magnitude (⌊log2 |v|⌋), by bit
/// inspection. Subnormals report the subnormal-range floor (-1022);
/// returns `None` for zero and non-finite values, which never
/// participate in scale decisions.
fn f64_exp(v: f64) -> Option<i32> {
    let bits = v.to_bits() & !(1u64 << 63);
    if bits == 0 {
        return None;
    }
    let raw = (bits >> 52) as i32;
    match raw {
        0x7ff => None,
        0 => Some(-1022),
        _ => Some(raw - 1023),
    }
}

/// The shared scale exponent Flexpoint assigns `data` for `fmt`:
/// dividing by `2^result` places the largest finite magnitude
/// `headroom` binades below the format's overflow threshold. An
/// all-zero (or all-non-finite) tensor scales by `2^0`.
pub fn shared_exponent(data: &[f64], fmt: FpFormat, headroom: i32) -> i32 {
    let mut max_exp = i32::MIN;
    for &v in data {
        if let Some(e) = f64_exp(v) {
            max_exp = max_exp.max(e);
        }
    }
    if max_exp == i32::MIN {
        return 0;
    }
    max_exp - (fmt.emax() - headroom)
}

/// Payload statistics that drive predictive exponent management.
/// Exponents are *logical* (payload exponent + the tensor's scale), so
/// a manager can track a tensor series across scale changes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TensorStats {
    /// Largest logical magnitude exponent (⌊log2 |v|⌋) in the tensor;
    /// `i32::MIN` for an all-zero tensor.
    pub max_exp: i32,
    /// Saturation pressure: payload elements at the format's
    /// max-finite magnitude, plus any that overflowed to non-finite
    /// (RNE rounds overflow to ±inf on the quantization path).
    pub saturated: u64,
    /// Non-zero payload elements.
    pub nonzero: u64,
    /// Total elements.
    pub total: u64,
}

/// A packed minifloat tensor with one shared power-of-two scale:
/// logical value = `payload() · 2^scale_exp()`.
#[derive(Clone, Debug, PartialEq)]
pub struct ScaledTensor {
    payload: MfTensor,
    scale_exp: i32,
}

impl ScaledTensor {
    /// Quantize `data` (row-major `rows×cols`) into `fmt` under the
    /// tensor's own shared exponent (one binade of headroom), using the
    /// session's rounding mode and thread budget for the payload pack.
    pub fn quantize(
        session: &Session,
        data: &[f64],
        rows: usize,
        cols: usize,
        fmt: FpFormat,
    ) -> Result<Self> {
        let scale_exp = shared_exponent(data, fmt, 1);
        Self::quantize_with_exp(session, data, rows, cols, fmt, scale_exp)
    }

    /// [`ScaledTensor::quantize`] with an externally chosen scale —
    /// what an [`ExponentManager`]-driven pipeline uses (the predicted
    /// scale is committed *before* the data exists).
    pub fn quantize_with_exp(
        session: &Session,
        data: &[f64],
        rows: usize,
        cols: usize,
        fmt: FpFormat,
        scale_exp: i32,
    ) -> Result<Self> {
        let inv = exp2(-scale_exp);
        let scaled: Vec<f64> = data.iter().map(|&v| v * inv).collect();
        let payload = session.tensor(&scaled, rows, cols, fmt)?;
        Ok(ScaledTensor { payload, scale_exp })
    }

    /// The packed payload (values in `fmt`'s window).
    pub fn payload(&self) -> &MfTensor {
        &self.payload
    }

    /// The shared scale exponent.
    pub fn scale_exp(&self) -> i32 {
        self.scale_exp
    }

    /// Payload element format.
    pub fn fmt(&self) -> FpFormat {
        self.payload.fmt()
    }

    /// Decode to logical row-major f64 values. The scale removal is a
    /// power-of-two multiply — exact, so this loses nothing beyond the
    /// original quantization.
    pub fn to_f64(&self) -> Vec<f64> {
        let s = exp2(self.scale_exp);
        self.payload.to_f64().iter().map(|&v| v * s).collect()
    }

    /// Statistics of the logical tensor (drives [`ExponentManager`]).
    pub fn stats(&self) -> TensorStats {
        let fmt = self.payload.fmt();
        let max_mag = crate::softfloat::to_f64(fmt.max_finite(false), fmt);
        let vals = self.payload.to_f64();
        let mut st = TensorStats {
            max_exp: i32::MIN,
            saturated: 0,
            nonzero: 0,
            total: vals.len() as u64,
        };
        for &v in &vals {
            if v == 0.0 {
                continue;
            }
            st.nonzero += 1;
            if !v.is_finite() {
                st.saturated += 1;
                continue;
            }
            if let Some(e) = f64_exp(v) {
                st.max_exp = st.max_exp.max(e + self.scale_exp);
            }
            if v.abs() == max_mag {
                st.saturated += 1;
            }
        }
        st
    }

    /// `C = A·B` on the payloads through a validated
    /// [`crate::api::GemmPlan`] (src = payload format, `acc`
    /// accumulation), rescaled by `2^(sa+sb)` — exact, because the
    /// scales commute with the multiply: each product `a·b` carries
    /// the factor `2^(sa+sb)` out of the sum unchanged. Returns logical
    /// row-major f64 values.
    pub fn gemm(session: &Session, a: &ScaledTensor, b: &ScaledTensor, acc: FpFormat) -> Result<Vec<f64>> {
        ensure!(
            a.fmt() == b.fmt(),
            "scaled GEMM operands must share a payload format, got {} and {}",
            a.fmt().name(),
            b.fmt().name()
        );
        let (m, k) = (a.payload.rows(), a.payload.cols());
        let n = b.payload.cols();
        let plan = session.gemm().src(a.fmt()).acc(acc).dims(m, n, k)?;
        let report = plan.run(&a.payload, &b.payload)?;
        let s = exp2(a.scale_exp + b.scale_exp);
        Ok(report.c_f64().iter().map(|&v| v * s).collect())
    }
}

/// Predictive per-tensor exponent management (Flexpoint "Autoflex"):
/// commit the *next* tensor's scale from the *current* tensor's
/// statistics, so the hot path never re-scans data to pick a scale.
///
/// The prediction is `observed max exponent + rising trend`, bumped one
/// binade when any element saturated; the committed scale places that
/// prediction `headroom` binades below the format's overflow
/// threshold. Every committed change counts on the
/// `numerics.scale.adjusts` observability counter.
#[derive(Clone, Debug)]
pub struct ExponentManager {
    fmt: FpFormat,
    headroom: i32,
    scale_exp: i32,
    last_max: Option<i32>,
    /// Committed scale changes so far.
    pub adjusts: u64,
}

impl ExponentManager {
    /// A manager for `fmt` with one binade of headroom and scale `2^0`.
    pub fn new(fmt: FpFormat) -> Self {
        Self::with_headroom(fmt, 1)
    }

    /// A manager keeping `headroom` binades between the predicted max
    /// and the overflow threshold.
    pub fn with_headroom(fmt: FpFormat, headroom: i32) -> Self {
        ExponentManager { fmt, headroom, scale_exp: 0, last_max: None, adjusts: 0 }
    }

    /// The scale committed for the next tensor.
    pub fn scale_exp(&self) -> i32 {
        self.scale_exp
    }

    /// Feed one tensor's statistics; returns the scale committed for
    /// the *next* tensor. An all-zero tensor (no finite nonzero
    /// elements) leaves the scale untouched — there is nothing to
    /// predict from.
    pub fn observe(&mut self, stats: &TensorStats) -> i32 {
        if stats.max_exp == i32::MIN {
            return self.scale_exp;
        }
        let trend = self.last_max.map(|p| (stats.max_exp - p).max(0)).unwrap_or(0);
        self.last_max = Some(stats.max_exp);
        let sat_bump = i32::from(stats.saturated > 0);
        let predicted = stats.max_exp + trend + sat_bump;
        let want = predicted - (self.fmt.emax() - self.headroom);
        if want != self.scale_exp {
            self.scale_exp = want;
            self.adjusts += 1;
            crate::obs_count!("numerics.scale.adjusts");
        }
        self.scale_exp
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{FP16, FP8};
    use crate::util::rng::Rng;

    #[test]
    fn exp2_is_exact_bit_assembly() {
        assert_eq!(exp2(0), 1.0);
        assert_eq!(exp2(3), 8.0);
        assert_eq!(exp2(-3), 0.125);
        assert_eq!(exp2(10) * exp2(-10), 1.0);
    }

    #[test]
    fn shared_exponent_places_max_below_overflow() {
        // FP8 (e5m2): emax 15. Max magnitude 3.0 has exponent 1; one
        // binade of headroom targets exponent 14, so the scale is
        // 1 - 14 = -13.
        let s = shared_exponent(&[0.5, -3.0, 0.0], FP8, 1);
        assert_eq!(s, 1 - (FP8.emax() - 1));
        // Zeros (and empty tensors) scale by 2^0.
        assert_eq!(shared_exponent(&[0.0, -0.0], FP8, 1), 0);
        assert_eq!(shared_exponent(&[], FP8, 1), 0);
        // Non-finite values are ignored, not propagated into the scale.
        assert_eq!(shared_exponent(&[f64::INFINITY, 2.0], FP8, 1), 1 - (FP8.emax() - 1));
    }

    #[test]
    fn scaling_commutes_with_quantization_in_the_normal_range() {
        // Values whose payload stays normal before *and* after scaling:
        // power-of-two scaling shifts only the exponent, so RNE rounds
        // the same mantissa either way and the round trip is exact.
        let session = Session::new();
        let data: Vec<f64> = {
            let mut rng = Rng::new(7);
            (0..64).map(|_| 1.0 + rng.gaussian().abs() % 1.0).collect()
        };
        let direct = session.tensor(&data, 8, 8, FP8).expect("direct").to_f64();
        let scaled = ScaledTensor::quantize(&session, &data, 8, 8, FP8).expect("scaled");
        assert!(scaled.scale_exp() != 0, "test data should need a re-center");
        assert_eq!(scaled.to_f64(), direct, "power-of-two scaling must commute with RNE here");
    }

    #[test]
    fn scaling_rescues_subnormal_underflow() {
        // Magnitudes around 2^-17: below FP8's subnormal floor (2^-16),
        // direct quantization flushes or coarsens badly; the shared
        // scale re-centers them into the normal window.
        let session = Session::new();
        let mut rng = Rng::new(11);
        let data: Vec<f64> = (0..64).map(|_| rng.gaussian() * exp2(-17)).collect();
        let rel_err = |got: &[f64]| {
            data.iter()
                .zip(got)
                .filter(|(&d, _)| d != 0.0)
                .map(|(&d, &g)| ((g - d) / d).abs())
                .fold(0.0, f64::max)
        };
        let direct = session.tensor(&data, 8, 8, FP8).expect("direct").to_f64();
        let scaled = ScaledTensor::quantize(&session, &data, 8, 8, FP8).expect("scaled").to_f64();
        assert!(
            rel_err(&scaled) < rel_err(&direct),
            "shared scale should beat direct quantization on subnormal-range data: \
             scaled {} vs direct {}",
            rel_err(&scaled),
            rel_err(&direct)
        );
        // And the scaled payload is within the format's relative error
        // bound for normals (2^-(man_bits+1) = 1/8 for e5m2).
        assert!(rel_err(&scaled) <= 0.125 + 1e-12, "rel err {}", rel_err(&scaled));
    }

    #[test]
    fn stats_report_logical_exponents_and_saturation() {
        let session = Session::new();
        // One binade of headroom ⇒ quantized max sits at exponent
        // emax-1 of the payload; logically back at its true exponent.
        let data = [2.0, 0.25, 0.0, -4.0];
        let t = ScaledTensor::quantize(&session, &data, 1, 4, FP8).expect("quantize");
        let st = t.stats();
        assert_eq!(st.total, 4);
        assert_eq!(st.nonzero, 3);
        assert_eq!(st.max_exp, 2, "logical max exponent of -4.0");
        assert_eq!(st.saturated, 0);
        // Force saturation: scale so the payload overflows to
        // max-finite (RNE overflow on the quantization path clamps).
        let hot = ScaledTensor::quantize_with_exp(&session, &data, 1, 4, FP8, -20).expect("hot");
        assert!(hot.stats().saturated > 0, "payload should pin at max finite");
    }

    #[test]
    fn exponent_manager_tracks_trend_and_saturation() {
        let mut mgr = ExponentManager::new(FP8);
        let stats = |max_exp: i32, saturated: u64| TensorStats {
            max_exp,
            saturated,
            nonzero: 10,
            total: 16,
        };
        // First observation: no trend; scale targets emax-1 = 14.
        assert_eq!(mgr.observe(&stats(4, 0)), 4 - 14);
        assert_eq!(mgr.adjusts, 1);
        // Steady input: no change, no new adjustment.
        assert_eq!(mgr.observe(&stats(4, 0)), 4 - 14);
        assert_eq!(mgr.adjusts, 1);
        // Rising max: predicted = observed + trend.
        assert_eq!(mgr.observe(&stats(6, 0)), 6 + 2 - 14);
        assert_eq!(mgr.adjusts, 2);
        // Saturation pressure bumps one extra binade.
        let before = mgr.scale_exp();
        mgr.observe(&stats(6, 3));
        assert_eq!(mgr.scale_exp(), before - 2 + 1, "trend collapses to 0, sat adds 1");
        // All-zero tensors never move the scale.
        let frozen = mgr.scale_exp();
        assert_eq!(mgr.observe(&stats(i32::MIN, 0)), frozen);
    }

    #[test]
    fn scaled_gemm_matches_unscaled_plan_modulo_scale() {
        let session = Session::new();
        let mut rng = Rng::new(3);
        let a: Vec<f64> = (0..8 * 8).map(|_| rng.gaussian() * 0.5).collect();
        let b: Vec<f64> = (0..8 * 8).map(|_| rng.gaussian() * 0.5).collect();
        // Scale 0 payloads: bit-identical to the plain plan route.
        let a0 = ScaledTensor::quantize_with_exp(&session, &a, 8, 8, FP8, 0).expect("a0");
        let b0 = ScaledTensor::quantize_with_exp(&session, &b, 8, 8, FP8, 0).expect("b0");
        let c0 = ScaledTensor::gemm(&session, &a0, &b0, FP16).expect("c0");
        let plan = session.gemm().src(FP8).acc(FP16).dims(8, 8, 8).expect("plan");
        let plain = plan
            .run(a0.payload(), b0.payload())
            .expect("plain run")
            .c_f64();
        assert_eq!(c0, plain);
        // Auto-scaled: same result modulo the exact power-of-two factor
        // (payload mantissas match by the commutation argument), so the
        // outputs agree to FP16 accumulation accuracy.
        let a1 = ScaledTensor::quantize(&session, &a, 8, 8, FP8).expect("a1");
        let b1 = ScaledTensor::quantize(&session, &b, 8, 8, FP8).expect("b1");
        let c1 = ScaledTensor::gemm(&session, &a1, &b1, FP16).expect("c1");
        for (x, y) in c0.iter().zip(&c1) {
            let tol = 1e-2 * x.abs().max(1.0);
            assert!((x - y).abs() <= tol, "scaled {y} vs plain {x}");
        }
    }
}
