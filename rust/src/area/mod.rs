//! Parametric gate-count area and critical-path model (Fig. 7).
//!
//! **Substitution note (DESIGN.md §2):** the paper synthesizes RTL with
//! Fusion Compiler in GF 12 nm. We cannot run a 12 nm flow, so area and
//! timing come from a *complexity model*: standard gate-count estimates
//! for the datapath building blocks (array multiplier ~ p², barrel
//! shifter ~ w·log₂ w, prefix adder ~ w, LZC ~ w, pipeline registers ~
//! bits), with one global GE scale calibrated against the paper's
//! published absolute numbers (SIMD SDOTP module = 44.5 kGE, FPU =
//! 165 kGE, cluster = 4.3 MGE). What the model must get *right* —
//! because Fig. 7a's claim depends on it — is the **relative** cost of
//! a fused ExSdotp versus the two discrete ExFMAs it replaces, and that
//! ratio is technology-independent structural complexity.

use crate::formats::FpFormat;

/// Gate-equivalents of an s-bit-operand array multiplier.
fn mult_ge(p: u32) -> f64 {
    // Partial-product array + reduction tree: ~1.1 GE per bit-cell.
    1.1 * (p * p) as f64
}

/// Barrel shifter over `w` bits with `log2(range)` stages.
fn shifter_ge(w: u32, range: u32) -> f64 {
    let stages = 32 - range.leading_zeros();
    2.2 * w as f64 * stages as f64
}

/// Prefix adder.
fn adder_ge(w: u32) -> f64 {
    3.4 * w as f64
}

/// Leading-zero counter + normalization shifter.
fn norm_ge(w: u32) -> f64 {
    2.0 * w as f64 + shifter_ge(w, w)
}

/// Rounding + special-case handling.
fn round_ge(p: u32) -> f64 {
    9.0 * p as f64
}

/// Exponent datapath (differences, min/max, adjust).
fn exp_path_ge(eb: u32, terms: u32) -> f64 {
    55.0 * (eb * terms) as f64
}

/// Pipeline/IO registers.
fn regs_ge(bits: u32, stages: u32) -> f64 {
    4.5 * (bits * stages) as f64
}

/// Area (GE) of one fused ExSdotp unit for a (src, dst) pair (§III-B
/// datapath, unpipelined core logic + the 3 pipeline stage registers of
/// the paper's configuration).
pub fn exsdotp_unit_ge(src: FpFormat, dst: FpFormat) -> f64 {
    let ps = src.precision();
    let pd = dst.precision();
    let w1 = 2 * pd + 3; // first-sum field
    let w2 = 2 * pd + ps + 5; // widened second-sum field
    let mut ge = 0.0;
    ge += 2.0 * mult_ge(ps); // two mantissa multipliers
    ge += exp_path_ge(dst.exp_bits, 3); // sort + shift amounts for 3 addends
    ge += 3.0 * adder_ge(pd + dst.exp_bits); // 3-way magnitude sort comparators
    ge += 2.0 * shifter_ge(w1, w1); // int + min alignment shifters
    ge += adder_ge(w1) + adder_ge(w2); // the two staged additions
    ge += 2.0 * w2 as f64; // cancellation-recovery mux (§III-B)
    ge += norm_ge(w2); // single normalization
    ge += round_ge(pd); // single rounding
    ge += regs_ge(4 * src.width() + 2 * dst.width(), 3); // operand/pipe regs
    ge
}

/// Area (GE) of one expanding FMA unit (multiplier + single wide
/// add/normalize/round — the FPnew-style baseline block).
pub fn exfma_unit_ge(src: FpFormat, dst: FpFormat) -> f64 {
    let ps = src.precision();
    let pd = dst.precision();
    let w = 3 * pd + 2; // classic FMA alignment field
    let mut ge = 0.0;
    ge += mult_ge(ps);
    ge += exp_path_ge(dst.exp_bits, 2);
    ge += shifter_ge(w, w); // addend aligner
    ge += adder_ge(w);
    ge += norm_ge(w);
    ge += round_ge(pd);
    ge += regs_ge(2 * src.width() + 2 * dst.width(), 3);
    ge
}

/// Critical-path estimate in gate delays (FO4-ish units).
fn mult_delay(p: u32) -> f64 {
    8.0 + 3.2 * (p as f64).log2()
}

fn shift_delay(w: u32) -> f64 {
    1.4 * (w as f64).log2()
}

fn add_delay(w: u32) -> f64 {
    3.0 + 1.6 * (w as f64).log2()
}

/// Critical path of the fused unit: mult → sort/align → add → widen →
/// add → normalize → round, overlapping exponent logic.
pub fn exsdotp_delay(src: FpFormat, dst: FpFormat) -> f64 {
    let ps = src.precision();
    let pd = dst.precision();
    let w1 = 2 * pd + 3;
    let w2 = 2 * pd + ps + 5;
    // mult → 3-way sort → align → add → widened add → normalize → round.
    mult_delay(ps)
        + 8.0 // exponent sort + operand swap muxes
        + shift_delay(w1)
        + add_delay(w1)
        + add_delay(w2)
        + shift_delay(w2)
        + 4.0
}

/// Critical path of the *cascade*: two full ExFMA latencies in series
/// (the second unit cannot start before the first rounds — §IV-A's
/// "each FMA instance is required to work at 667 MHz").
pub fn exfma_cascade_delay(src: FpFormat, dst: FpFormat) -> f64 {
    let ps = src.precision();
    let pd = dst.precision();
    let w = 3 * pd + 2;
    let one = mult_delay(ps) + shift_delay(w) + add_delay(w) + shift_delay(w) + 3.0 + 4.0;
    2.0 * one
}

// ------------------------------------------------------------ module level

/// Global scale: complexity units → GE, calibrated so the SIMD SDOTP
/// module matches the paper's 44.5 kGE (§IV-A).
fn simd_sdotp_raw() -> f64 {
    use crate::formats::{FP16, FP32, FP8};
    // Two 16→32 + two 8→16 units + operand packing/unpacking muxes.
    let units = 2.0 * exsdotp_unit_ge(FP16, FP32) + 2.0 * exsdotp_unit_ge(FP8, FP16);
    units * 1.12 // wrapper/mux overhead
}

/// Calibration factor (dimensionless).
fn ge_scale() -> f64 {
    44_500.0 / simd_sdotp_raw()
}

/// Area of the SIMD SDOTP operation-group module (kGE).
pub fn sdotp_module_kge() -> f64 {
    simd_sdotp_raw() * ge_scale() / 1000.0
}

/// Areas of the extended FPU's operation groups in kGE (Fig. 7b).
/// ADDMUL hosts the multi-format FMA (FP64-capable — dominated by the
/// 53-bit multiplier); CONV the cast network; COMP the comparison /
/// sign-injection logic.
pub fn fpu_breakdown_kge() -> Vec<(&'static str, f64)> {
    use crate::formats::{FP16, FP64};
    use crate::formats::{FP32, FP8};
    let s = ge_scale();
    // FPnew's ADDMUL in the "parallel" topology instantiates one FMA
    // slice per format and lane (FP64 + 2×FP32 + 4×FP16 + 8×FP8), with
    // some inter-slice sharing (0.85 factor).
    let addmul = (exfma_unit_ge(FP64, FP64)
        + 2.0 * exfma_unit_ge(FP32, FP32)
        + 4.0 * exfma_unit_ge(FP16, FP16)
        + 8.0 * exfma_unit_ge(FP8, FP8))
        * 0.85
        * s
        / 1000.0;
    let sdotp = sdotp_module_kge();
    // Conversion network: shifters + rounders for all format pairs
    // (FPnew-class CONV block).
    let conv = 22.0;
    // Comparison / classify / sign-injection SIMD.
    let comp = 6.5;
    // Operand distributor, arbiter, output mux, CSR plumbing.
    let interface = 9.0;
    vec![("ADDMUL", addmul), ("SDOTP", sdotp), ("CONV", conv), ("COMP", comp), ("interface", interface)]
}

/// Total extended-FPU area (kGE) — paper: 165 kGE.
pub fn fpu_total_kge() -> f64 {
    fpu_breakdown_kge().iter().map(|(_, a)| a).sum()
}

/// Cluster area in MGE (paper: 4.3 MGE): 8 PEs (Snitch int core +
/// extended FPU + SSRs) + TCDM + interconnect + DMA + instruction cache.
pub fn cluster_breakdown_mge() -> Vec<(&'static str, f64)> {
    let fpu = fpu_total_kge() / 1000.0;
    let snitch_int = 0.022; // tiny RV32 core ~22 kGE
    let ssrs = 0.012; // 3 streamers + FIFOs
    let pes = 8.0 * (fpu + snitch_int + ssrs);
    let tcdm = 128.0 * 1024.0 * 8.0 * 1.9 / 1e6; // SRAM macro GE-equivalent
    let icache = 0.14;
    let interconnect = 0.45;
    let dma = 0.12;
    vec![
        ("8 × PE (core+FPU+SSR)", pes),
        ("TCDM 128 kB", tcdm),
        ("icache", icache),
        ("interconnect", interconnect),
        ("DMA", dma),
    ]
}

/// Total cluster area in MGE.
pub fn cluster_total_mge() -> f64 {
    cluster_breakdown_mge().iter().map(|(_, a)| a).sum()
}

/// SoC area in MGE: N clusters + shared L2 SRAM + the cluster-to-L2
/// interconnect. L2 SRAM macros are denser than TCDM banks (~1.2
/// GE-equivalent/bit vs 1.9 — single wide port, no 32-way banking);
/// the interconnect term grows with the crossbar's port count.
pub fn soc_breakdown_mge(n_clusters: usize, l2_kib: usize) -> Vec<(&'static str, f64)> {
    let clusters = n_clusters as f64 * cluster_total_mge();
    let l2 = l2_kib as f64 * 1024.0 * 8.0 * 1.2 / 1e6;
    let interconnect = 0.08 + 0.06 * n_clusters as f64;
    vec![
        ("clusters", clusters),
        ("L2 SRAM", l2),
        ("L2 interconnect", interconnect),
    ]
}

/// Total SoC area in MGE.
pub fn soc_total_mge(n_clusters: usize, l2_kib: usize) -> f64 {
    soc_breakdown_mge(n_clusters, l2_kib).iter().map(|(_, a)| a).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{FP16, FP32, FP8};

    #[test]
    fn fused_unit_saves_about_30_percent_area() {
        // Fig. 7a: the fused ExSdotp occupies ~30% less area than two
        // cascaded ExFMAs, for both instantiations.
        for (src, dst) in [(FP16, FP32), (FP8, FP16)] {
            let fused = exsdotp_unit_ge(src, dst);
            let cascade = 2.0 * exfma_unit_ge(src, dst);
            let ratio = fused / cascade;
            assert!(
                (0.58..0.78).contains(&ratio),
                "{}→{}: fused/cascade area ratio {ratio:.2} outside 0.58–0.78",
                src.name(),
                dst.name()
            );
        }
    }

    #[test]
    fn fused_unit_saves_about_30_percent_delay() {
        for (src, dst) in [(FP16, FP32), (FP8, FP16)] {
            let ratio = exsdotp_delay(src, dst) / exfma_cascade_delay(src, dst);
            assert!(
                (0.58..0.78).contains(&ratio),
                "{}→{}: delay ratio {ratio:.2} outside 0.58–0.78",
                src.name(),
                dst.name()
            );
        }
    }

    #[test]
    fn simd_module_calibrated_to_paper() {
        let kge = sdotp_module_kge();
        assert!((kge - 44.5).abs() < 0.1, "SDOTP module {kge:.1} kGE != 44.5");
    }

    #[test]
    fn fpu_total_and_share_match_fig7b() {
        let total = fpu_total_kge();
        assert!((160.0..170.0).contains(&total), "FPU {total:.1} kGE");
        let share = sdotp_module_kge() / total;
        assert!((0.25..0.29).contains(&share), "SDOTP share {:.0}%", share * 100.0);
    }

    #[test]
    fn cluster_total_matches_4_3_mge() {
        let total = cluster_total_mge();
        assert!((4.0..4.6).contains(&total), "cluster {total:.2} MGE");
    }

    #[test]
    fn soc_area_scales_with_clusters_and_is_cluster_dominated() {
        let one = soc_total_mge(1, 1024);
        let eight = soc_total_mge(8, 1024);
        assert!(eight > one, "more clusters must cost more");
        // Clusters dominate: 8 clusters alone are ≥ 70% of the SoC.
        let clusters = 8.0 * cluster_total_mge();
        assert!(clusters / eight > 0.7, "cluster share {:.2}", clusters / eight);
        // And the uncore is not free either.
        assert!(eight > clusters);
    }

    #[test]
    fn bigger_formats_cost_more() {
        assert!(exsdotp_unit_ge(FP16, FP32) > exsdotp_unit_ge(FP8, FP16));
        assert!(exsdotp_delay(FP16, FP32) > exsdotp_delay(FP8, FP16));
    }
}
