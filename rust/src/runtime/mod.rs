//! PJRT runtime boundary: load AOT-compiled HLO artifacts and execute
//! them from Rust — Python never runs on this path.
//!
//! The interchange format is **HLO text** (`artifacts/*.hlo.txt`),
//! produced once by `python/compile/aot.py`.
//!
//! ## Offline build
//!
//! The PJRT client itself lives behind the `xla` crate, which is not
//! available in the offline build environment (no crates.io registry).
//! This module therefore compiles the *boundary* — [`Tensor`],
//! [`Runtime`], [`Executable`] keep their full API — but
//! [`Runtime::cpu`] reports an explanatory error instead of creating a
//! client. Everything upstream of the boundary (the coordinator's batch
//! loop, dataset, metrics) still builds and tests; the e2e training
//! tests skip when no backend/artifacts are present, exactly as they
//! skip when `make artifacts` has not run.
//!
//! The original xla-backed implementation (client creation, HLO
//! compile, literal conversion, execute) is preserved verbatim in git
//! history — seed commit `0260bbf`, this file — and drops back in once
//! the build environment can resolve the `xla` crate. A cargo feature
//! can't gate it today: optional registry dependencies still enter
//! lockfile resolution, which fails offline.

use crate::util::error::{Context, Result};
use std::path::{Path, PathBuf};

/// A tensor: row-major f32 data + shape.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    /// Row-major data.
    pub data: Vec<f32>,
    /// Dimensions (empty = scalar).
    pub shape: Vec<usize>,
}

impl Tensor {
    /// Build from data + shape (checked).
    pub fn new(data: Vec<f32>, shape: &[usize]) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>().max(1), "shape/data mismatch");
        Tensor { data, shape: shape.to_vec() }
    }

    /// A zero tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { data: vec![0.0; shape.iter().product::<usize>().max(1)], shape: shape.to_vec() }
    }

    /// Total elements.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// The PJRT client wrapper (CPU).
pub struct Runtime {
    _priv: (),
}

impl Runtime {
    /// Create a CPU PJRT client.
    ///
    /// In the offline build this always fails: the `xla` crate that
    /// provides the PJRT bindings cannot be vendored without a registry.
    pub fn cpu() -> Result<Self> {
        crate::bail!(
            "PJRT backend unavailable: the offline build has no `xla` crate. \
             The coordinator and its batch/dataset layers still run; only \
             artifact execution requires the PJRT-enabled build."
        )
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    /// Load and compile an HLO-text artifact.
    pub fn load(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let path = path.as_ref();
        // Reading the artifact validates the path even without a client.
        std::fs::read_to_string(path).with_context(|| format!("reading HLO text {}", path.display()))?;
        crate::bail!("PJRT backend unavailable: cannot compile {}", path.display())
    }

    /// Load `name.hlo.txt` from an artifacts directory.
    pub fn load_artifact(&self, dir: impl AsRef<Path>, name: &str) -> Result<Executable> {
        let mut p = PathBuf::from(dir.as_ref());
        p.push(format!("{name}.hlo.txt"));
        self.load(p)
    }
}

/// A compiled artifact ready to execute.
pub struct Executable {
    /// Artifact name (diagnostics).
    pub name: String,
    _priv: (),
}

impl Executable {
    /// Execute with f32 tensor inputs; returns the flattened tuple of
    /// f32 tensor outputs (aot.py lowers with `return_tuple=True`).
    pub fn run(&self, _inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        crate::bail!("PJRT backend unavailable: cannot execute {}", self.name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_checks() {
        let t = Tensor::new(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        assert_eq!(t.len(), 4);
        let z = Tensor::zeros(&[3, 5]);
        assert_eq!(z.data.len(), 15);
        assert!(!z.is_empty());
        assert_eq!(Tensor::zeros(&[]).len(), 1); // scalar
    }

    #[test]
    fn offline_runtime_reports_unavailable() {
        let err = Runtime::cpu().err().expect("offline build must not create a client");
        assert!(err.to_string().contains("PJRT backend unavailable"), "{err}");
    }
}
