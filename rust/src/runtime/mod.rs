//! PJRT runtime: load AOT-compiled HLO artifacts and execute them from
//! Rust — Python never runs on this path.
//!
//! The interchange format is **HLO text** (`artifacts/*.hlo.txt`),
//! produced once by `python/compile/aot.py`. Text, not serialized
//! protos: jax ≥ 0.5 emits 64-bit instruction ids that the crate's
//! xla_extension 0.5.1 rejects, while the text parser reassigns ids
//! (see /opt/xla-example/README.md).

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// A tensor: row-major f32 data + shape.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    /// Row-major data.
    pub data: Vec<f32>,
    /// Dimensions (empty = scalar).
    pub shape: Vec<usize>,
}

impl Tensor {
    /// Build from data + shape (checked).
    pub fn new(data: Vec<f32>, shape: &[usize]) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>().max(1), "shape/data mismatch");
        Tensor { data, shape: shape.to_vec() }
    }

    /// A zero tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { data: vec![0.0; shape.iter().product::<usize>().max(1)], shape: shape.to_vec() }
    }

    /// Total elements.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(&self.data).reshape(&dims)?)
    }

    fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        Ok(Tensor { data: lit.to_vec::<f32>()?, shape: dims })
    }
}

/// The PJRT client wrapper (CPU).
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        Ok(Runtime { client: xla::PjRtClient::cpu()? })
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile an HLO-text artifact.
    pub fn load(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path.to_str().context("non-utf8 path")?)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe, name: path.file_stem().unwrap_or_default().to_string_lossy().into_owned() })
    }

    /// Load `name.hlo.txt` from an artifacts directory.
    pub fn load_artifact(&self, dir: impl AsRef<Path>, name: &str) -> Result<Executable> {
        let mut p = PathBuf::from(dir.as_ref());
        p.push(format!("{name}.hlo.txt"));
        self.load(p)
    }
}

/// A compiled artifact ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Artifact name (diagnostics).
    pub name: String,
}

impl Executable {
    /// Execute with f32 tensor inputs; returns the flattened tuple of
    /// f32 tensor outputs (aot.py lowers with `return_tuple=True`).
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let lits: Vec<xla::Literal> = inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        parts.iter().map(Tensor::from_literal).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        p.join("gemm_fp8_fp16.hlo.txt").exists().then_some(p)
    }

    #[test]
    fn tensor_shape_checks() {
        let t = Tensor::new(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        assert_eq!(t.len(), 4);
        let z = Tensor::zeros(&[3, 5]);
        assert_eq!(z.data.len(), 15);
    }

    #[test]
    fn gemm_artifact_executes_and_matches_quantized_semantics() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        };
        let rt = Runtime::cpu().unwrap();
        let exe = rt.load_artifact(&dir, "gemm_fp8_fp16").unwrap();

        // Identity × small values: quantization (FP8) must show through.
        let n = 32;
        let mut a = Tensor::zeros(&[n, n]);
        for i in 0..n {
            a.data[i * n + i] = 1.0;
        }
        let mut b = Tensor::zeros(&[n, n]);
        for (i, v) in b.data.iter_mut().enumerate() {
            *v = 0.1 + (i % 7) as f32 * 0.31; // values NOT on the FP8 grid
        }
        let out = exe.run(&[a, b.clone()]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shape, vec![n, n]);
        // Each output element = FP8-quantized b element (identity A).
        use crate::formats::FP8;
        use crate::softfloat::{from_f64, to_f64, RoundingMode};
        for (o, x) in out[0].data.iter().zip(&b.data) {
            let q = to_f64(from_f64(*x as f64, FP8, RoundingMode::Rne), FP8) as f32;
            assert_eq!(*o, q, "runtime GEMM output must carry FP8-quantized operand {x}");
        }
    }
}
