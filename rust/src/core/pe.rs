//! The MiniFloat-NN processing element: a Snitch-style pseudo
//! dual-issue RV32 core coupled to the extended FPU (§III-E).
//!
//! ## Timing model
//!
//! Two loosely-coupled engines advance each cycle:
//!
//! * the **integer core** retires ≤ 1 instruction/cycle; FP
//!   instructions are not executed here but pushed into a FIFO toward
//!   the FP subsystem (the Snitch "accelerator interface"), so integer
//!   address arithmetic and loop control overlap FP compute — the
//!   pseudo dual-issue that lets Snitch exceed 90% FPU utilization;
//! * the **FP sequencer** issues ≤ 1 FP instruction/cycle from the FIFO
//!   (or from the FREP loop buffer) into the fully-pipelined FPU,
//!   subject to the register scoreboard and to TCDM bank grants for SSR
//!   operands.
//!
//! Latencies follow the paper's pipeline configuration (§III-E / §IV-A):
//! SDOTP 3, ADDMUL 3, CAST 2, COMP 1 — all fully pipelined, so they cost
//! issue slots only through data dependencies (which GEMM kernels avoid
//! by construction).
//!
//! Numerics are exact: every FP instruction executes on
//! [`crate::softfloat`] / [`crate::exsdotp`] with the formats resolved
//! through the FP CSR (`src_is_alt` / `dst_is_alt`).

use super::ssr::Ssr;
use crate::exsdotp::simd::{lane, set_lane, SimdExSdotp, SimdOp};
use crate::formats::FpFormat;
use crate::isa::csr::{addr as csr_addr, FpCsr};
use crate::isa::instr::{FReg, Instr, OpWidth, Reg};
use crate::softfloat;
use std::collections::VecDeque;

/// Pipeline depths per operation group (§IV-A).
pub mod latency {
    /// Expanding sum-of-dot-product group.
    pub const SDOTP: u64 = 3;
    /// FMA / add / mul group.
    pub const ADDMUL: u64 = 3;
    /// Conversion group.
    pub const CAST: u64 = 2;
    /// Comparison / sign-injection group.
    pub const COMP: u64 = 1;
    /// FP load-to-use latency from TCDM.
    pub const FLOAD: u64 = 3;
}

/// Memory access interface the cluster provides to each core.
pub trait Bus {
    /// Claim a bank slot for a (64-bit word) access this cycle. Returns
    /// false on a bank conflict — the caller must retry next cycle.
    fn request(&mut self, requester: u32, addr: u64, write: bool) -> bool;
    /// Read a 64-bit word (little-endian) at `addr` (byte address).
    fn read64(&mut self, addr: u64) -> u64;
    /// Write the low `bytes` bytes of `value` at `addr`.
    fn write_n(&mut self, addr: u64, value: u64, bytes: u32);
    /// DMA frontend (only the DMA core issues these).
    fn dma_src(&mut self, addr: u64);
    /// Set DMA destination address.
    fn dma_dst(&mut self, addr: u64);
    /// Enqueue a copy of `len` bytes; returns a transfer id.
    fn dma_copy(&mut self, len: u64) -> u32;
    /// Outstanding DMA transfers.
    fn dma_busy(&self) -> u32;
}

/// Issue-stall and throughput counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct CoreStats {
    /// Total cycles ticked.
    pub cycles: u64,
    /// Integer instructions retired.
    pub int_retired: u64,
    /// FP instructions issued to the FPU.
    pub fp_issued: u64,
    /// FLOP performed (paper counting: FMA = 2·lanes, ExSdotp = 4·units).
    pub flops: u64,
    /// Cycles the FP sequencer had nothing to issue.
    pub fp_idle: u64,
    /// Issue stalls: operand not ready (scoreboard).
    pub stall_raw: u64,
    /// Issue stalls: TCDM bank conflict on an SSR/load port.
    pub stall_bank: u64,
    /// Int-core stalls: FP FIFO full.
    pub stall_fifo_full: u64,
    /// SSR elements streamed (reads + writes).
    pub ssr_elems: u64,
    /// ADDMUL-group ops issued (fmadd/fadd/fmul, any format).
    pub ops_addmul: u64,
    /// SDOTP-group ops issued (exsdotp/exvsum/vsum).
    pub ops_sdotp: u64,
    /// CAST-group ops issued (fcvt).
    pub ops_cast: u64,
    /// COMP-group ops issued (fsgnj & friends).
    pub ops_comp: u64,
    /// FP memory ops issued (fl*/fs*).
    pub ops_fmem: u64,
}

/// An FP instruction as offloaded through the accelerator interface:
/// memory operands are resolved by the integer core at offload time
/// (the hardware sends the computed address along with the request), so
/// later integer-register updates cannot race the queued access.
#[derive(Clone, Copy, Debug)]
struct FpOp {
    instr: Instr,
    /// Captured effective address for FLoad/FStore.
    addr: u64,
}

/// FREP sequencer state.
#[derive(Clone, Debug)]
enum SeqState {
    Normal,
    /// Capturing the next `remaining` FP instructions into the buffer
    /// while issuing them (first round).
    Capturing { remaining: u8, rounds_left: u32, buf: Vec<FpOp>, inner: bool },
    /// Replaying the captured buffer.
    Replaying { pos: usize, rounds_left: u32, buf: Vec<FpOp>, inner: bool },
}

/// One Snitch-style PE.
pub struct Core {
    /// Hart id (cluster index).
    pub id: u32,
    /// Integer register file (x0 hardwired).
    pub regs: [u32; 32],
    /// 64-bit FP register file.
    pub fregs: [u64; 32],
    /// Program counter (instruction index).
    pub pc: usize,
    /// FP CSR (rounding mode + alt bits).
    pub csr: FpCsr,
    /// The three stream semantic registers.
    pub ssrs: [Ssr; 3],
    /// SSR master enable (CSR 0x7c0).
    pub ssr_enabled: bool,
    /// Waiting at the cluster barrier.
    pub at_barrier: bool,
    /// Counters.
    pub stats: CoreStats,
    program: Vec<Instr>,
    halted: bool,
    int_stall: u64,
    fp_queue: VecDeque<FpOp>,
    seq: SeqState,
    scoreboard: [u64; 32], // ready-at cycle per FP register
    now: u64,
    /// Per-streamer prefetch FIFOs (read streams). The hardware SSR is a
    /// data mover with a small FIFO; it decouples TCDM fetch timing from
    /// FP issue, absorbing transient bank conflicts.
    ssr_fifo: [VecDeque<u64>; 3],
    /// Pending write-stream entries (addr, value) awaiting a bank slot.
    ssr_wq: VecDeque<(u64, u64)>,
}

/// Depth of each SSR prefetch/write FIFO. The hardware uses
/// credit-based buffering deep enough to ride out transient TCDM bank
/// conflicts; 8 entries reproduce the measured Snitch utilization.
const SSR_FIFO_DEPTH: usize = 8;

/// Depth of the int→FP instruction FIFO (Snitch uses a small FIFO; deep
/// enough to let the int core run ahead across loop boundaries).
const FP_QUEUE_DEPTH: usize = 16;

impl Core {
    /// Create a PE with a loaded program.
    pub fn new(id: u32, program: Vec<Instr>) -> Self {
        Core {
            id,
            regs: [0; 32],
            fregs: [0; 32],
            pc: 0,
            csr: FpCsr::default(),
            ssrs: Default::default(),
            ssr_enabled: false,
            at_barrier: false,
            stats: CoreStats::default(),
            program,
            halted: false,
            // Small per-hart startup skew (the cluster wakes cores
            // sequentially); also de-phases the SSR streams of cores
            // walking identical patterns, as on the real interconnect.
            int_stall: id as u64,
            fp_queue: VecDeque::with_capacity(FP_QUEUE_DEPTH),
            seq: SeqState::Normal,
            scoreboard: [0; 32],
            now: 0,
            ssr_fifo: Default::default(),
            ssr_wq: VecDeque::with_capacity(SSR_FIFO_DEPTH),
        }
    }

    /// Has the program completed (halt retired and FP work drained)?
    pub fn done(&self) -> bool {
        self.halted && self.fp_queue.is_empty() && matches!(self.seq, SeqState::Normal) && self.ssr_wq.is_empty()
    }

    /// Release from the barrier (cluster calls when all cores arrive).
    pub fn release_barrier(&mut self) {
        self.at_barrier = false;
    }

    /// Is the core blocked at a barrier with the FP side drained?
    pub fn barrier_ready(&self) -> bool {
        self.at_barrier && self.fp_queue.is_empty() && matches!(self.seq, SeqState::Normal)
    }

    /// Advance one cycle.
    pub fn tick(&mut self, bus: &mut dyn Bus) {
        self.now += 1;
        self.stats.cycles = self.now;
        self.ssr_move(bus);
        self.tick_fp(bus);
        self.tick_int(bus);
    }

    /// SSR data movers: each streamer independently transfers one
    /// element per cycle between its FIFO and the TCDM (subject to bank
    /// arbitration).
    fn ssr_move(&mut self, bus: &mut dyn Bus) {
        if !self.ssr_enabled {
            return;
        }
        // Drain one write-stream entry.
        if let Some(&(addr, val)) = self.ssr_wq.front() {
            if bus.request(self.id, addr, true) {
                bus.write_n(addr, val, 8);
                self.ssr_wq.pop_front();
            }
        }
        // Prefetch one element per read streamer. An element with
        // repetition r is fetched from the TCDM once and enqueued r
        // times — the repeat feature exists precisely to cut TCDM
        // traffic (one port access serves r operand reads).
        for i in 0..3 {
            if self.ssrs[i].write || !self.ssrs[i].active || self.ssr_fifo[i].len() >= SSR_FIFO_DEPTH {
                continue;
            }
            let addr = self.ssrs[i].peek_addr().expect("active stream has an address");
            if bus.request(self.id, addr, false) {
                let v = bus.read64(addr);
                let reps = self.ssrs[i].take_element();
                for _ in 0..reps {
                    self.ssr_fifo[i].push_back(v);
                }
            }
        }
    }

    // ------------------------------------------------------------- FP side

    fn tick_fp(&mut self, bus: &mut dyn Bus) {
        // Determine the next FP instruction (from FREP replay or FIFO).
        let next: Option<FpOp> = match &self.seq {
            SeqState::Replaying { pos, buf, .. } => Some(buf[*pos]),
            _ => self.fp_queue.front().copied(),
        };
        let Some(op) = next else {
            self.stats.fp_idle += 1;
            return;
        };
        let instr = op.instr;

        // FREP markers are consumed by the sequencer, not the FPU.
        if let Instr::FrepO { n_inst, rep } = instr {
            let rounds = self.regs[rep.0 as usize];
            self.fp_queue.pop_front();
            self.seq = SeqState::Capturing {
                remaining: n_inst,
                rounds_left: rounds,
                buf: Vec::with_capacity(n_inst as usize),
                inner: false,
            };
            // Sequencer bookkeeping is free; attempt an issue this cycle.
            self.tick_fp(bus);
            return;
        }
        if let Instr::FrepI { n_inst, rep } = instr {
            let rounds = self.regs[rep.0 as usize];
            self.fp_queue.pop_front();
            self.seq = SeqState::Capturing {
                remaining: n_inst,
                rounds_left: rounds,
                buf: Vec::with_capacity(n_inst as usize),
                inner: true,
            };
            self.tick_fp(bus);
            return;
        }

        // Scoreboard: all non-SSR source registers must be ready. The
        // same pass counts SSR FIFO demand for claim_memory (one
        // fp_reads evaluation per issue attempt).
        let mut ssr_need = [0usize; 3];
        for r in instr.fp_reads().iter() {
            if self.is_ssr_reg(r) {
                if !self.ssrs[r.0 as usize].write {
                    ssr_need[r.0 as usize] += 1;
                }
                continue;
            }
            if self.scoreboard[r.0 as usize] > self.now {
                self.stats.stall_raw += 1;
                return;
            }
        }
        // Destination WAW: the previous value must have landed.
        if let Some(fd) = instr.fp_write() {
            if !self.is_ssr_reg(fd) && self.scoreboard[fd.0 as usize] > self.now {
                self.stats.stall_raw += 1;
                return;
            }
        }

        // SSR operand ports + explicit memory ops need bank grants.
        if !self.claim_memory(&op, &ssr_need, bus) {
            self.stats.stall_bank += 1;
            return;
        }

        // Issue: pop SSR data, execute numerics, schedule writeback.
        self.execute_fp(&op, bus);
        self.stats.fp_issued += 1;

        // Advance the sequencer / FIFO.
        match std::mem::replace(&mut self.seq, SeqState::Normal) {
            SeqState::Normal => {
                self.fp_queue.pop_front();
                self.seq = SeqState::Normal;
            }
            SeqState::Capturing { remaining, rounds_left, mut buf, inner } => {
                self.fp_queue.pop_front();
                buf.push(op);
                let remaining = remaining - 1;
                if remaining > 0 {
                    self.seq = SeqState::Capturing { remaining, rounds_left, buf, inner };
                } else if rounds_left > 0 {
                    self.seq = SeqState::Replaying { pos: 0, rounds_left, buf, inner };
                } else {
                    self.seq = SeqState::Normal;
                }
            }
            SeqState::Replaying { pos, rounds_left, buf, inner } => {
                // Inner repetition: repeat the same instruction
                // `rounds_left` times before advancing; outer: sweep the
                // buffer then decrement.
                let (npos, nrounds) = if inner {
                    if rounds_left > 0 {
                        (pos, rounds_left - 1)
                    } else if pos + 1 < buf.len() {
                        (pos + 1, rounds_left)
                    } else {
                        self.seq = SeqState::Normal;
                        return;
                    }
                } else if pos + 1 < buf.len() {
                    (pos + 1, rounds_left)
                } else if rounds_left > 1 {
                    (0, rounds_left - 1)
                } else {
                    self.seq = SeqState::Normal;
                    return;
                };
                self.seq = SeqState::Replaying { pos: npos, rounds_left: nrounds, buf, inner };
            }
        }
    }

    fn is_ssr_reg(&self, r: FReg) -> bool {
        self.ssr_enabled && r.0 < 3
    }

    /// Check stream-operand availability and claim bank slots for
    /// explicit FP loads/stores. SSR reads come from the prefetch FIFOs
    /// (filled by [`Self::ssr_move`]); SSR writes need queue space.
    fn claim_memory(&mut self, op: &FpOp, need: &[usize; 3], bus: &mut dyn Bus) -> bool {
        let instr = &op.instr;
        // `need` = FIFO elements required per streamer (one per operand
        // occurrence), pre-counted by the caller's scoreboard pass.
        for i in 0..3 {
            if self.ssr_fifo[i].len() < need[i] {
                return false; // data not prefetched yet
            }
        }
        if let Some(fd) = instr.fp_write() {
            if self.is_ssr_reg(fd) && self.ssrs[fd.0 as usize].write {
                if self.ssr_wq.len() >= SSR_FIFO_DEPTH || !self.ssrs[fd.0 as usize].active {
                    return false;
                }
            }
        }
        match instr {
            Instr::FLoad { .. } => {
                if !bus.request(self.id, op.addr, false) {
                    return false;
                }
            }
            Instr::FStore { .. } => {
                if !bus.request(self.id, op.addr, true) {
                    return false;
                }
            }
            _ => {}
        }
        true
    }

    /// Read an FP operand, popping the SSR prefetch FIFO if mapped.
    fn read_fp(&mut self, r: FReg, _bus: &mut dyn Bus) -> u64 {
        if self.is_ssr_reg(r) && !self.ssrs[r.0 as usize].write {
            let v = self.ssr_fifo[r.0 as usize].pop_front().expect("claim_memory checked occupancy");
            self.stats.ssr_elems += 1;
            self.fregs[r.0 as usize] = v;
            return v;
        }
        self.fregs[r.0 as usize]
    }

    /// Write an FP result, pushing to the SSR write queue if mapped.
    fn write_fp(&mut self, r: FReg, v: u64, lat: u64, _bus: &mut dyn Bus) {
        if self.is_ssr_reg(r) && self.ssrs[r.0 as usize].write {
            let ssr = &mut self.ssrs[r.0 as usize];
            if let Some(a) = ssr.peek_addr() {
                ssr.advance();
                self.ssr_wq.push_back((a, v));
                self.stats.ssr_elems += 1;
                return;
            }
        }
        self.fregs[r.0 as usize] = v;
        self.scoreboard[r.0 as usize] = self.now + lat;
    }

    /// Execute FP numerics (exact softfloat) and account FLOP.
    fn execute_fp(&mut self, op: &FpOp, bus: &mut dyn Bus) {
        let instr = &op.instr;
        let rm = self.csr.frm;
        match *instr {
            Instr::Fmadd { fmt, fd, fs1, fs2, fs3 } => {
                let f = self.csr.scalar_format(fmt);
                let (a, b, c) = (self.read_fp(fs1, bus), self.read_fp(fs2, bus), self.read_fp(fs3, bus));
                let out = lanewise3(f, a, b, c, |x, y, z| softfloat::fma(f, x, y, z, rm));
                self.stats.flops += 2 * f.lanes_in_64() as u64;
                self.stats.ops_addmul += 1;
                self.write_fp(fd, out, latency::ADDMUL, bus);
            }
            Instr::Fadd { fmt, fd, fs1, fs2 } => {
                let f = self.csr.scalar_format(fmt);
                let (a, b) = (self.read_fp(fs1, bus), self.read_fp(fs2, bus));
                let out = lanewise2(f, a, b, |x, y| softfloat::add(f, x, y, rm));
                self.stats.flops += f.lanes_in_64() as u64;
                self.stats.ops_addmul += 1;
                self.write_fp(fd, out, latency::ADDMUL, bus);
            }
            Instr::Fmul { fmt, fd, fs1, fs2 } => {
                let f = self.csr.scalar_format(fmt);
                let (a, b) = (self.read_fp(fs1, bus), self.read_fp(fs2, bus));
                let out = lanewise2(f, a, b, |x, y| softfloat::mul(f, x, y, rm));
                self.stats.flops += f.lanes_in_64() as u64;
                self.stats.ops_addmul += 1;
                self.write_fp(fd, out, latency::ADDMUL, bus);
            }
            Instr::Fsgnj { fmt, fd, fs1, fs2 } => {
                let f = self.csr.scalar_format(fmt);
                let (a, b) = (self.read_fp(fs1, bus), self.read_fp(fs2, bus));
                let out = lanewise2(f, a, b, |x, y| softfloat::ops::sgnj(f, x, y));
                self.stats.ops_comp += 1;
                self.write_fp(fd, out, latency::COMP, bus);
            }
            Instr::Fcvt { to, from, fd, fs1 } => {
                let tf = self.csr.scalar_format(to);
                let ff = self.csr.scalar_format(from);
                let a = self.read_fp(fs1, bus);
                let out = softfloat::cast(ff, tf, a & ff.width_mask(), rm);
                self.stats.ops_cast += 1;
                self.write_fp(fd, out, latency::CAST, bus);
            }
            Instr::ExSdotp { w, fd, fs1, fs2 } => {
                let simd = self.simd_unit(w);
                let (a, b) = (self.read_fp(fs1, bus), self.read_fp(fs2, bus));
                let acc = self.read_fp(fd, bus);
                let out = simd.exsdotp(a, b, acc, rm);
                self.stats.flops += simd.flops(SimdOp::ExSdotp);
                self.stats.ops_sdotp += 1;
                self.write_fp(fd, out, latency::SDOTP, bus);
            }
            Instr::ExVsum { w, fd, fs1 } => {
                let simd = self.simd_unit(w);
                let a = self.read_fp(fs1, bus);
                let acc = self.read_fp(fd, bus);
                let out = simd.exvsum(a, acc, rm);
                self.stats.flops += simd.flops(SimdOp::ExVsum);
                self.stats.ops_sdotp += 1;
                self.write_fp(fd, out, latency::SDOTP, bus);
            }
            Instr::Vsum { w, fd, fs1 } => {
                let simd = self.simd_unit(w);
                let a = self.read_fp(fs1, bus);
                let acc = self.read_fp(fd, bus);
                let out = simd.vsum(a, acc, rm);
                self.stats.flops += simd.flops(SimdOp::Vsum);
                self.stats.ops_sdotp += 1;
                self.write_fp(fd, out, latency::SDOTP, bus);
            }
            Instr::FLoad { fmt, fd, .. } => {
                self.stats.ops_fmem += 1;
                let a = op.addr;
                let word = bus.read64(a & !7);
                let off = (a & 7) as u32 * 8;
                let v = match fmt.width() {
                    64 => word,
                    w => (word >> off) & ((1u64 << w) - 1),
                };
                self.write_fp(fd, v, latency::FLOAD, bus);
            }
            Instr::FStore { fmt, fs, .. } => {
                self.stats.ops_fmem += 1;
                let v = self.read_fp(fs, bus);
                bus.write_n(op.addr, v, fmt.width() / 8);
            }
            _ => unreachable!("non-FP instruction in FP path: {instr:?}"),
        }
    }

    fn simd_unit(&self, w: OpWidth) -> SimdExSdotp {
        SimdExSdotp::new(self.csr.src_format(w), self.csr.dst_format(w))
    }

    // ------------------------------------------------------------ int side

    fn tick_int(&mut self, bus: &mut dyn Bus) {
        if self.halted || self.at_barrier {
            return;
        }
        if self.int_stall > 0 {
            self.int_stall -= 1;
            return;
        }
        let Some(&instr) = self.program.get(self.pc) else {
            self.halted = true;
            return;
        };

        // FP instructions (and FREP markers) go to the FP FIFO, with
        // memory addresses resolved here (offload-time capture).
        if instr.is_fp() || matches!(instr, Instr::FrepO { .. } | Instr::FrepI { .. }) {
            if self.fp_queue.len() >= FP_QUEUE_DEPTH {
                self.stats.stall_fifo_full += 1;
                return;
            }
            let addr = match instr {
                Instr::FLoad { rs1, imm, .. } | Instr::FStore { rs1, imm, .. } => {
                    self.regs[rs1.0 as usize].wrapping_add(imm as u32) as u64
                }
                _ => 0,
            };
            self.fp_queue.push_back(FpOp { instr, addr });
            self.pc += 1;
            self.stats.int_retired += 1;
            return;
        }

        let mut next_pc = self.pc + 1;
        match instr {
            Instr::Lui { rd, imm } => self.set_reg(rd, (imm as u32) << 12),
            Instr::Addi { rd, rs1, imm } => {
                let v = self.regs[rs1.0 as usize].wrapping_add(imm as u32);
                self.set_reg(rd, v);
            }
            Instr::Add { rd, rs1, rs2 } => {
                self.set_reg(rd, self.regs[rs1.0 as usize].wrapping_add(self.regs[rs2.0 as usize]))
            }
            Instr::Sub { rd, rs1, rs2 } => {
                self.set_reg(rd, self.regs[rs1.0 as usize].wrapping_sub(self.regs[rs2.0 as usize]))
            }
            Instr::Mul { rd, rs1, rs2 } => {
                self.set_reg(rd, self.regs[rs1.0 as usize].wrapping_mul(self.regs[rs2.0 as usize]))
            }
            Instr::Slli { rd, rs1, shamt } => self.set_reg(rd, self.regs[rs1.0 as usize] << shamt),
            Instr::Srli { rd, rs1, shamt } => self.set_reg(rd, self.regs[rs1.0 as usize] >> shamt),
            Instr::Beq { rs1, rs2, offset } => {
                if self.regs[rs1.0 as usize] == self.regs[rs2.0 as usize] {
                    next_pc = (self.pc as i64 + offset as i64) as usize;
                    self.int_stall = 1;
                }
            }
            Instr::Bne { rs1, rs2, offset } => {
                if self.regs[rs1.0 as usize] != self.regs[rs2.0 as usize] {
                    next_pc = (self.pc as i64 + offset as i64) as usize;
                    self.int_stall = 1;
                }
            }
            Instr::Blt { rs1, rs2, offset } => {
                if (self.regs[rs1.0 as usize] as i32) < (self.regs[rs2.0 as usize] as i32) {
                    next_pc = (self.pc as i64 + offset as i64) as usize;
                    self.int_stall = 1;
                }
            }
            Instr::Bge { rs1, rs2, offset } => {
                if (self.regs[rs1.0 as usize] as i32) >= (self.regs[rs2.0 as usize] as i32) {
                    next_pc = (self.pc as i64 + offset as i64) as usize;
                    self.int_stall = 1;
                }
            }
            Instr::Jal { rd, offset } => {
                self.set_reg(rd, (self.pc as u32 + 1) * 4);
                next_pc = (self.pc as i64 + offset as i64) as usize;
                self.int_stall = 1;
            }
            Instr::Lw { rd, rs1, imm } => {
                let a = self.regs[rs1.0 as usize].wrapping_add(imm as u32) as u64;
                if !bus.request(self.id, a, false) {
                    return; // retry next cycle
                }
                let word = bus.read64(a & !7);
                let v = (word >> ((a & 4) * 8)) as u32;
                self.set_reg(rd, v);
            }
            Instr::Sw { rs1, rs2, imm } => {
                let a = self.regs[rs1.0 as usize].wrapping_add(imm as u32) as u64;
                if !bus.request(self.id, a, true) {
                    return;
                }
                bus.write_n(a, self.regs[rs2.0 as usize] as u64, 4);
            }
            Instr::Csrrwi { rd, csr, imm } => {
                // Writes to FP-visible CSRs (SSR enable, rounding mode,
                // alt bits) synchronize with the FP subsystem: the write
                // must not overtake queued FP instructions.
                if self.fp_csr_hazard(csr) {
                    return;
                }
                let old = self.csr_read(csr);
                self.csr_write(csr, imm as u32);
                self.set_reg(rd, old);
            }
            Instr::Csrrw { rd, csr, rs1 } => {
                if self.fp_csr_hazard(csr) {
                    return;
                }
                let old = self.csr_read(csr);
                self.csr_write(csr, self.regs[rs1.0 as usize]);
                self.set_reg(rd, old);
            }
            Instr::Csrrs { rd, csr, rs1 } => {
                if rs1.0 != 0 && self.fp_csr_hazard(csr) {
                    return;
                }
                let old = self.csr_read(csr);
                if rs1.0 != 0 {
                    self.csr_write(csr, old | self.regs[rs1.0 as usize]);
                }
                self.set_reg(rd, old);
            }
            Instr::ScfgWi { rs1, cfg } => {
                let streamer = (cfg / 32) as usize;
                let reg = cfg % 32;
                if streamer < 3 {
                    self.ssrs[streamer].cfg_write(reg, self.regs[rs1.0 as usize] as u64);
                }
            }
            Instr::FmvXW { rd, fs1 } => {
                // Synchronizing move: wait for the FP side to drain.
                if !self.fp_queue.is_empty()
                    || !matches!(self.seq, SeqState::Normal)
                    || self.scoreboard[fs1.0 as usize] > self.now
                {
                    return;
                }
                self.set_reg(rd, self.fregs[fs1.0 as usize] as u32);
            }
            Instr::FmvWX { fd, rs1 } => {
                self.fregs[fd.0 as usize] = self.regs[rs1.0 as usize] as u64;
                self.scoreboard[fd.0 as usize] = self.now + 1;
            }
            Instr::Barrier => {
                // Require the FP side drained before reporting arrival.
                self.at_barrier = true;
            }
            Instr::Halt => {
                self.halted = true;
            }
            Instr::DmSrc { rs1 } => bus.dma_src(self.regs[rs1.0 as usize] as u64),
            Instr::DmDst { rs1 } => bus.dma_dst(self.regs[rs1.0 as usize] as u64),
            Instr::DmCpy { rd, rs1 } => {
                let id = bus.dma_copy(self.regs[rs1.0 as usize] as u64);
                self.set_reg(rd, id);
            }
            Instr::DmStat { rd } => self.set_reg(rd, bus.dma_busy()),
            _ => unreachable!("unhandled int instruction {instr:?}"),
        }
        self.pc = next_pc;
        self.stats.int_retired += 1;
    }

    fn set_reg(&mut self, r: Reg, v: u32) {
        if r.0 != 0 {
            self.regs[r.0 as usize] = v;
        }
    }

    /// Must a write to this CSR wait for the FP pipeline to drain?
    fn fp_csr_hazard(&self, a: u16) -> bool {
        matches!(a, csr_addr::FCSR | csr_addr::SSR)
            && !(self.fp_queue.is_empty() && matches!(self.seq, SeqState::Normal) && self.ssr_wq.is_empty())
    }

    fn csr_read(&self, a: u16) -> u32 {
        match a {
            csr_addr::FCSR => self.csr.to_bits(),
            csr_addr::SSR => self.ssr_enabled as u32,
            csr_addr::MHARTID => self.id,
            _ => 0,
        }
    }

    fn csr_write(&mut self, a: u16, v: u32) {
        match a {
            csr_addr::FCSR => self.csr = FpCsr::from_bits(v),
            csr_addr::SSR => self.ssr_enabled = v & 1 != 0,
            _ => {}
        }
    }
}

/// Apply a scalar op lanewise over packed data (1 lane for 64-bit).
fn lanewise2(f: FpFormat, a: u64, b: u64, op: impl Fn(u64, u64) -> u64) -> u64 {
    let w = f.width();
    if w == 64 {
        return op(a, b);
    }
    let mut out = 0u64;
    for i in 0..f.lanes_in_64() {
        out = set_lane(out, i, w, op(lane(a, i, w), lane(b, i, w)));
    }
    out
}

/// Three-operand lanewise application.
fn lanewise3(f: FpFormat, a: u64, b: u64, c: u64, op: impl Fn(u64, u64, u64) -> u64) -> u64 {
    let w = f.width();
    if w == 64 {
        return op(a, b, c);
    }
    let mut out = 0u64;
    for i in 0..f.lanes_in_64() {
        out = set_lane(out, i, w, op(lane(a, i, w), lane(b, i, w), lane(c, i, w)));
    }
    out
}
