//! The Snitch processing element (PE) model: pseudo dual-issue integer
//! core + FP subsystem with SSR streamers and the FREP loop buffer,
//! extended with the MiniFloat-NN SDOTP operation group (§III-E).

pub mod pe;
pub mod ssr;

pub use pe::{latency, Bus, Core, CoreStats};
pub use ssr::{cfg_regs, Ssr};
