//! Stream Semantic Registers (SSR) — the Snitch extension that maps a
//! regular load/store access pattern onto fixed FP registers
//! (`ft0..ft2`), "effectively eliminating most of the implicit load and
//! store instructions" (§III-E).
//!
//! Each of the three streamers walks a 4-dimensional affine address
//! pattern:
//!
//! ```text
//! addr = base + i0·stride0 + i1·stride1 + i2·stride2 + i3·stride3
//! ```
//!
//! with `i_d ∈ [0, bound_d)`, dimension 0 innermost, plus a *repeat*
//! count: each element is served `repeat` times before the pattern
//! advances — the feature GEMM kernels use to multiply one streamed
//! `A` element against several packed `B` columns without re-loading.
//!
//! Configuration happens through `scfgwi` writes to the per-streamer
//! register file ([`cfg_regs`]); writing a read/write pointer register
//! arms the streamer, exactly like Snitch's `rptr/wptr` convention.

/// SSR config register indices (the `scfgwi` immediate is
/// `streamer * 32 + reg`).
pub mod cfg_regs {
    /// `bounds[d]` = reg `BOUND0 + d` (iterations per dimension).
    pub const BOUND0: u16 = 0;
    /// `strides[d]` = reg `STRIDE0 + d` (byte strides).
    pub const STRIDE0: u16 = 8;
    /// Element repetition count (1 = no repetition).
    pub const REPEAT: u16 = 24;
    /// Write `base` and arm as a *read* stream of dimensionality d+1.
    pub const RPTR0: u16 = 16;
    /// Write `base` and arm as a *write* stream of dimensionality d+1.
    pub const WPTR0: u16 = 20;
}

/// One stream semantic register (data mover).
#[derive(Clone, Debug, Default)]
pub struct Ssr {
    /// Iteration bounds per dimension (dimension 0 innermost).
    pub bounds: [u32; 4],
    /// Byte strides per dimension.
    pub strides: [i64; 4],
    /// Base byte address.
    pub base: u64,
    /// Dimensions in use (1..=4).
    pub dims: u8,
    /// Serve each element this many times (≥1).
    pub repeat: u32,
    /// Write stream (true) or read stream (false).
    pub write: bool,
    /// Armed and not exhausted.
    pub active: bool,
    idx: [u32; 4],
    rep_left: u32,
    served: u64,
}

impl Ssr {
    /// Handle an `scfgwi` write to register `reg` with `value`.
    pub fn cfg_write(&mut self, reg: u16, value: u64) {
        use cfg_regs::*;
        match reg {
            r if (BOUND0..BOUND0 + 4).contains(&r) => self.bounds[(r - BOUND0) as usize] = value as u32,
            r if (STRIDE0..STRIDE0 + 4).contains(&r) => self.strides[(r - STRIDE0) as usize] = value as i64,
            REPEAT => self.repeat = (value as u32).max(1),
            r if (RPTR0..RPTR0 + 4).contains(&r) => {
                self.base = value;
                self.dims = (r - RPTR0) as u8 + 1;
                self.write = false;
                self.arm();
            }
            r if (WPTR0..WPTR0 + 4).contains(&r) => {
                self.base = value;
                self.dims = (r - WPTR0) as u8 + 1;
                self.write = true;
                self.arm();
            }
            _ => {} // unmapped registers ignored (like hardware WARL)
        }
    }

    fn arm(&mut self) {
        self.idx = [0; 4];
        self.rep_left = self.repeat.max(1);
        self.served = 0;
        self.active = self.total_accesses() > 0;
    }

    /// Total number of element accesses this pattern will serve.
    pub fn total_accesses(&self) -> u64 {
        let mut n = 1u64;
        for d in 0..self.dims as usize {
            n *= self.bounds[d].max(1) as u64;
        }
        n * self.repeat.max(1) as u64
    }

    /// Address of the *next* element access (None if exhausted).
    pub fn peek_addr(&self) -> Option<u64> {
        if !self.active {
            return None;
        }
        let mut a = self.base as i64;
        for d in 0..self.dims as usize {
            a += self.idx[d] as i64 * self.strides[d];
        }
        Some(a as u64)
    }

    /// Consume one access and advance the pattern.
    pub fn advance(&mut self) {
        if !self.active {
            return;
        }
        self.served += 1;
        if self.rep_left > 1 {
            self.rep_left -= 1;
            return;
        }
        self.rep_left = self.repeat.max(1);
        // Odometer increment.
        for d in 0..self.dims as usize {
            self.idx[d] += 1;
            if self.idx[d] < self.bounds[d].max(1) {
                return;
            }
            self.idx[d] = 0;
        }
        self.active = false; // pattern exhausted
    }

    /// Accesses served so far (for stats/tests).
    pub fn served(&self) -> u64 {
        self.served
    }

    /// Consume the *entire current element* (all remaining repetitions)
    /// in one step, returning how many servings that is. Used by the
    /// prefetcher: the hardware fetches a repeated element from the
    /// TCDM once and replays it from the stream FIFO.
    pub fn take_element(&mut self) -> u32 {
        if !self.active {
            return 0;
        }
        let n = self.rep_left;
        for _ in 0..n {
            self.advance();
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn armed(bounds: &[u32], strides: &[i64], base: u64, repeat: u32) -> Ssr {
        let mut s = Ssr::default();
        for (d, &b) in bounds.iter().enumerate() {
            s.cfg_write(cfg_regs::BOUND0 + d as u16, b as u64);
        }
        for (d, &st) in strides.iter().enumerate() {
            s.cfg_write(cfg_regs::STRIDE0 + d as u16, st as u64);
        }
        s.cfg_write(cfg_regs::REPEAT, repeat as u64);
        s.cfg_write(cfg_regs::RPTR0 + (bounds.len() as u16 - 1), base);
        s
    }

    #[test]
    fn one_dim_walk() {
        let mut s = armed(&[4], &[8], 0x100, 1);
        let mut addrs = vec![];
        while let Some(a) = s.peek_addr() {
            addrs.push(a);
            s.advance();
        }
        assert_eq!(addrs, vec![0x100, 0x108, 0x110, 0x118]);
        assert!(!s.active);
    }

    #[test]
    fn repeat_serves_elements_multiple_times() {
        let mut s = armed(&[2], &[8], 0, 3);
        let mut addrs = vec![];
        while let Some(a) = s.peek_addr() {
            addrs.push(a);
            s.advance();
        }
        assert_eq!(addrs, vec![0, 0, 0, 8, 8, 8]);
        assert_eq!(s.served(), 6);
    }

    #[test]
    fn multi_dim_odometer() {
        // dim0: 2 elems stride 8; dim1: 3 iterations stride 100.
        let mut s = armed(&[2, 3], &[8, 100], 0, 1);
        let mut addrs = vec![];
        while let Some(a) = s.peek_addr() {
            addrs.push(a);
            s.advance();
        }
        assert_eq!(addrs, vec![0, 8, 100, 108, 200, 208]);
    }

    #[test]
    fn zero_stride_dimension_repeats_pattern() {
        // The GEMM trick: stride-0 outer dim re-streams the same row.
        let mut s = armed(&[2, 2], &[8, 0], 0x40, 1);
        let mut addrs = vec![];
        while let Some(a) = s.peek_addr() {
            addrs.push(a);
            s.advance();
        }
        assert_eq!(addrs, vec![0x40, 0x48, 0x40, 0x48]);
    }

    #[test]
    fn negative_strides() {
        let mut s = armed(&[3], &[-16], 0x100, 1);
        let mut addrs = vec![];
        while let Some(a) = s.peek_addr() {
            addrs.push(a);
            s.advance();
        }
        assert_eq!(addrs, vec![0x100, 0xf0, 0xe0]);
    }

    #[test]
    fn write_pointer_arms_write_stream() {
        let mut s = Ssr::default();
        s.cfg_write(cfg_regs::BOUND0, 4);
        s.cfg_write(cfg_regs::STRIDE0, 8);
        s.cfg_write(cfg_regs::WPTR0, 0x200);
        assert!(s.active && s.write);
        assert_eq!(s.total_accesses(), 4);
    }

    #[test]
    fn four_dim_total() {
        let s = armed(&[2, 3, 4, 5], &[1, 10, 100, 1000], 0, 2);
        assert_eq!(s.total_accesses(), 2 * 3 * 4 * 5 * 2);
    }
}
