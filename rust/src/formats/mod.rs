//! Parametric floating-point format descriptors.
//!
//! The paper's hardware (FPnew + the ExSdotp unit) is parameterized over
//! `(exponent bits, mantissa bits)` pairs so that new formats can be
//! "rapidly defined" (§III-A). This module is the software equivalent: a
//! [`FpFormat`] fully describes an IEEE-754-style binary format and every
//! arithmetic routine in [`crate::softfloat`] and [`crate::exsdotp`] is
//! generic over it.
//!
//! The six formats the paper enables (§III-A, Fig. 1):
//!
//! | name      | exp | man | width | remarks |
//! |-----------|-----|-----|-------|---------|
//! | [`FP64`]    | 11  | 52  | 64    | IEEE binary64 |
//! | [`FP32`]    | 8   | 23  | 32    | IEEE binary32 |
//! | [`FP16`]    | 5   | 10  | 16    | IEEE binary16 |
//! | [`FP16ALT`] | 8   | 7   | 16    | bfloat16 layout, IEEE semantics |
//! | [`FP8`]     | 5   | 2   | 8     | "FP8" (e5m2) |
//! | [`FP8ALT`]  | 4   | 3   | 8     | "FP8alt" (e4m3, fully IEEE: has inf) |
//!
//! All formats — including the 8-bit ones — follow full IEEE-754
//! semantics here (subnormals, infinities, NaNs), exactly as the paper's
//! FPnew-based implementation does ("FP16alt matches ... bfloat16 but
//! follows the IEEE-754 directives for rounding and subnormal number
//! handling", §III-A).

pub mod spec;

pub use spec::{ExpandTo, FormatSpec, Fp16, Fp16alt, Fp32, Fp64, Fp8, Fp8alt};

/// A binary interchange floating-point format: 1 sign bit, `exp_bits`
/// exponent bits (biased), `man_bits` mantissa bits with a hidden leading
/// one for normal values.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct FpFormat {
    /// Number of exponent bits (2..=15 supported).
    pub exp_bits: u32,
    /// Number of explicit mantissa (fraction) bits.
    pub man_bits: u32,
}

/// IEEE binary64.
pub const FP64: FpFormat = FpFormat::new(11, 52);
/// IEEE binary32.
pub const FP32: FpFormat = FpFormat::new(8, 23);
/// IEEE binary16.
pub const FP16: FpFormat = FpFormat::new(5, 10);
/// bfloat16 bit layout with IEEE-754 rounding/subnormal semantics.
pub const FP16ALT: FpFormat = FpFormat::new(8, 7);
/// FP8 (e5m2): FP16 dynamic range, 2-bit mantissa.
pub const FP8: FpFormat = FpFormat::new(5, 2);
/// FP8alt (e4m3): 4-bit exponent, 3-bit mantissa.
pub const FP8ALT: FpFormat = FpFormat::new(4, 3);

impl FpFormat {
    /// Create a format descriptor. `const` so new formats are one-liners,
    /// mirroring FPnew's parameterization scheme.
    pub const fn new(exp_bits: u32, man_bits: u32) -> Self {
        Self { exp_bits, man_bits }
    }

    /// Total storage width in bits (1 + exp + man).
    pub const fn width(&self) -> u32 {
        1 + self.exp_bits + self.man_bits
    }

    /// Exponent bias: `2^(exp_bits-1) - 1`.
    pub const fn bias(&self) -> i32 {
        (1 << (self.exp_bits - 1)) - 1
    }

    /// Precision `p` = mantissa bits + hidden bit. The paper calls this
    /// `p_src` / `p_dst` (§III-B).
    pub const fn precision(&self) -> u32 {
        self.man_bits + 1
    }

    /// Maximum unbiased exponent of a normal value.
    pub const fn emax(&self) -> i32 {
        self.bias()
    }

    /// Minimum unbiased exponent of a normal value.
    pub const fn emin(&self) -> i32 {
        1 - self.bias()
    }

    /// All-ones exponent field (infinity/NaN encoding).
    pub const fn exp_special(&self) -> u64 {
        (1u64 << self.exp_bits) - 1
    }

    /// Bit mask for the mantissa field.
    pub const fn man_mask(&self) -> u64 {
        (1u64 << self.man_bits) - 1
    }

    /// Bit mask of the sign bit.
    pub const fn sign_mask(&self) -> u64 {
        1u64 << (self.exp_bits + self.man_bits)
    }

    /// Mask covering all `width()` bits of an encoding.
    pub const fn width_mask(&self) -> u64 {
        if self.width() >= 64 {
            u64::MAX
        } else {
            (1u64 << self.width()) - 1
        }
    }

    /// The canonical quiet NaN (sign 0, exponent all ones, mantissa MSB
    /// set) — matches RISC-V / FPnew canonical NaN.
    pub const fn quiet_nan(&self) -> u64 {
        (self.exp_special() << self.man_bits) | (1u64 << (self.man_bits - 1))
    }

    /// Positive or negative infinity.
    pub const fn infinity(&self, sign: bool) -> u64 {
        let mag = self.exp_special() << self.man_bits;
        if sign {
            mag | self.sign_mask()
        } else {
            mag
        }
    }

    /// Largest finite magnitude with the given sign.
    pub const fn max_finite(&self, sign: bool) -> u64 {
        let mag = ((self.exp_special() - 1) << self.man_bits) | self.man_mask();
        if sign {
            mag | self.sign_mask()
        } else {
            mag
        }
    }

    /// Signed zero.
    pub const fn zero(&self, sign: bool) -> u64 {
        if sign {
            self.sign_mask()
        } else {
            0
        }
    }

    /// Smallest positive subnormal.
    pub const fn min_subnormal(&self) -> u64 {
        1
    }

    /// Smallest positive normal.
    pub const fn min_normal(&self) -> u64 {
        1u64 << self.man_bits
    }

    /// Split an encoding into (sign, biased exponent field, mantissa field).
    #[inline]
    pub fn split(&self, bits: u64) -> (bool, u64, u64) {
        let sign = bits & self.sign_mask() != 0;
        let exp = (bits >> self.man_bits) & self.exp_special();
        let man = bits & self.man_mask();
        (sign, exp, man)
    }

    /// Assemble an encoding from (sign, biased exponent field, mantissa
    /// field). Fields must already be in range.
    #[inline]
    pub fn assemble(&self, sign: bool, exp: u64, man: u64) -> u64 {
        debug_assert!(exp <= self.exp_special());
        debug_assert!(man <= self.man_mask());
        (if sign { self.sign_mask() } else { 0 }) | (exp << self.man_bits) | man
    }

    /// True if the encoding is a NaN in this format.
    pub fn is_nan(&self, bits: u64) -> bool {
        let (_, e, m) = self.split(bits);
        e == self.exp_special() && m != 0
    }

    /// True if the encoding is ±infinity.
    pub fn is_inf(&self, bits: u64) -> bool {
        let (_, e, m) = self.split(bits);
        e == self.exp_special() && m == 0
    }

    /// True if the encoding is ±0.
    pub fn is_zero(&self, bits: u64) -> bool {
        let (_, e, m) = self.split(bits);
        e == 0 && m == 0
    }

    /// True if the encoding is subnormal (nonzero with zero exponent field).
    pub fn is_subnormal(&self, bits: u64) -> bool {
        let (_, e, m) = self.split(bits);
        e == 0 && m != 0
    }

    /// Sign bit of the encoding.
    pub fn sign(&self, bits: u64) -> bool {
        bits & self.sign_mask() != 0
    }

    /// Number of lanes of this format that fit a 64-bit FP register
    /// (§III-D: 2×FP32, 4×FP16/FP16alt, 8×FP8/FP8alt).
    pub const fn lanes_in_64(&self) -> u32 {
        64 / self.width()
    }

    /// Short human name for the six paper formats, or `e{E}m{M}`.
    pub fn name(&self) -> String {
        match (self.exp_bits, self.man_bits) {
            (11, 52) => "FP64".into(),
            (8, 23) => "FP32".into(),
            (5, 10) => "FP16".into(),
            (8, 7) => "FP16alt".into(),
            (5, 2) => "FP8".into(),
            (4, 3) => "FP8alt".into(),
            (e, m) => format!("e{e}m{m}"),
        }
    }

    /// The "alternate" companion of a format sharing the same width
    /// (§III-E: FP16↔FP16alt, FP8↔FP8alt selected via CSR bits).
    pub fn alt(&self) -> Option<FpFormat> {
        match (self.exp_bits, self.man_bits) {
            (5, 10) => Some(FP16ALT),
            (8, 7) => Some(FP16),
            (5, 2) => Some(FP8ALT),
            (4, 3) => Some(FP8),
            _ => None,
        }
    }

    /// The expanding destination format for this source format in the
    /// paper's ExSdotp units: 8-bit → FP16, 16-bit → FP32 (Table I).
    pub fn expand_dst(&self) -> Option<FpFormat> {
        match self.width() {
            8 => Some(FP16),
            16 => Some(FP32),
            _ => None,
        }
    }
}

impl std::fmt::Display for FpFormat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// All six formats the paper enables, in Fig. 1 order.
pub const PAPER_FORMATS: [FpFormat; 6] = [FP64, FP32, FP16, FP16ALT, FP8, FP8ALT];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn widths_and_biases_match_fig1() {
        assert_eq!(FP64.width(), 64);
        assert_eq!(FP32.width(), 32);
        assert_eq!(FP16.width(), 16);
        assert_eq!(FP16ALT.width(), 16);
        assert_eq!(FP8.width(), 8);
        assert_eq!(FP8ALT.width(), 8);

        assert_eq!(FP64.bias(), 1023);
        assert_eq!(FP32.bias(), 127);
        assert_eq!(FP16.bias(), 15);
        assert_eq!(FP16ALT.bias(), 127);
        assert_eq!(FP8.bias(), 15);
        assert_eq!(FP8ALT.bias(), 7);
    }

    #[test]
    fn precision_matches_paper_p() {
        // §III-B: for FP16→FP32 ExSdotp, 2*p_src = 22 and p_dst = 24.
        assert_eq!(2 * FP16.precision(), 22);
        assert_eq!(FP32.precision(), 24);
    }

    #[test]
    fn special_encodings() {
        // FP32 specials must match IEEE binary32.
        assert_eq!(FP32.infinity(false), 0x7f80_0000);
        assert_eq!(FP32.infinity(true), 0xff80_0000);
        assert_eq!(FP32.quiet_nan(), 0x7fc0_0000);
        assert_eq!(FP32.max_finite(false), 0x7f7f_ffff);
        assert_eq!(FP32.zero(true), 0x8000_0000);
        // FP16 specials.
        assert_eq!(FP16.infinity(false), 0x7c00);
        assert_eq!(FP16.quiet_nan(), 0x7e00);
        assert_eq!(FP16.max_finite(false), 0x7bff);
    }

    #[test]
    fn classification() {
        assert!(FP16.is_nan(0x7e00));
        assert!(FP16.is_inf(0x7c00));
        assert!(FP16.is_inf(0xfc00));
        assert!(FP16.is_zero(0x0000));
        assert!(FP16.is_zero(0x8000));
        assert!(FP16.is_subnormal(0x0001));
        assert!(!FP16.is_subnormal(0x0400));
        assert!(FP8.is_nan(FP8.quiet_nan()));
        assert!(FP8ALT.is_inf(FP8ALT.infinity(true)));
    }

    #[test]
    fn split_assemble_roundtrip() {
        for fmt in PAPER_FORMATS {
            for bits in [
                0u64,
                1,
                fmt.min_normal(),
                fmt.max_finite(false),
                fmt.infinity(true),
                fmt.quiet_nan(),
                fmt.width_mask(),
            ] {
                let b = bits & fmt.width_mask();
                let (s, e, m) = fmt.split(b);
                assert_eq!(fmt.assemble(s, e, m), b);
            }
        }
    }

    #[test]
    fn simd_lane_counts_match_section_iiid() {
        assert_eq!(FP32.lanes_in_64(), 2);
        assert_eq!(FP16.lanes_in_64(), 4);
        assert_eq!(FP16ALT.lanes_in_64(), 4);
        assert_eq!(FP8.lanes_in_64(), 8);
        assert_eq!(FP8ALT.lanes_in_64(), 8);
    }

    #[test]
    fn alt_pairing() {
        assert_eq!(FP16.alt(), Some(FP16ALT));
        assert_eq!(FP16ALT.alt(), Some(FP16));
        assert_eq!(FP8.alt(), Some(FP8ALT));
        assert_eq!(FP8ALT.alt(), Some(FP8));
        assert_eq!(FP32.alt(), None);
    }

    #[test]
    fn expanding_destinations_match_table1() {
        assert_eq!(FP16.expand_dst(), Some(FP32));
        assert_eq!(FP16ALT.expand_dst(), Some(FP32));
        assert_eq!(FP8.expand_dst(), Some(FP16));
        assert_eq!(FP8ALT.expand_dst(), Some(FP16));
        assert_eq!(FP64.expand_dst(), None);
    }
}
