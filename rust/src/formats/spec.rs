//! Compile-time format descriptors — Tier A of the batch numerics
//! engine.
//!
//! [`super::FpFormat`] is a *runtime* descriptor: every arithmetic
//! routine that takes one re-derives widths, masks and biases per call,
//! which is what the hardware's parameterized generate-time elaboration
//! emphatically does **not** do. [`FormatSpec`] is the generate-time
//! equivalent: a zero-sized type per format whose parameters are
//! associated `const`s, so a generic function instantiated at a
//! `FormatSpec` monomorphizes into format-specialized code — the masks
//! and shift amounts constant-fold exactly like an elaborated FPnew
//! instance bakes them into gates.
//!
//! The runtime API stays the source of truth: every fast kernel
//! ([`crate::softfloat::fast`], [`crate::exsdotp::fast`]) calls the
//! *same* implementation functions with [`FormatSpec::FMT`], so the two
//! tiers are bit-identical by construction (and differential tests in
//! [`crate::batch`] pin that down).
//!
//! [`ExpandTo`] encodes Table I's legal expanding pairs in the type
//! system: `exsdotp_m::<S, D>` only compiles for the six combinations
//! the hardware instantiates.

use super::FpFormat;

/// Broadcast `pattern` (the low `width` bits) into every `width`-bit
/// lane of a 64-bit register. `width` must divide 64 — true for every
/// paper format (8/16/32/64), and the SWAR tier is only instantiated at
/// those.
pub const fn splat(pattern: u64, width: u32) -> u64 {
    let mut out = 0u64;
    let mut sh = 0u32;
    while sh < 64 {
        out |= pattern << sh;
        sh += width;
    }
    out
}

/// A floating-point format known at compile time. All parameters are
/// associated constants derived from `EXP_BITS`/`MAN_BITS`, mirroring
/// [`FpFormat`]'s methods one for one.
pub trait FormatSpec: Copy + Send + Sync + 'static {
    /// Number of exponent bits.
    const EXP_BITS: u32;
    /// Number of explicit mantissa bits.
    const MAN_BITS: u32;

    /// The equivalent runtime descriptor (bridge to the descriptor API).
    const FMT: FpFormat = FpFormat::new(Self::EXP_BITS, Self::MAN_BITS);
    /// Total storage width in bits.
    const WIDTH: u32 = 1 + Self::EXP_BITS + Self::MAN_BITS;
    /// SIMD lanes in a 64-bit register.
    const LANES: u32 = 64 / Self::WIDTH;
    /// Precision `p` = mantissa bits + hidden bit.
    const PRECISION: u32 = Self::MAN_BITS + 1;
    /// Exponent bias.
    const BIAS: i32 = (1 << (Self::EXP_BITS - 1)) - 1;

    // ---- SWAR lane masks / broadcast planes -------------------------
    //
    // The SWAR tier ([`crate::softfloat::swar`], [`crate::exsdotp::swar`])
    // treats a packed `u64` as `LANES` parallel bit fields. These
    // constants are the broadcast masks that address one field of every
    // lane at once; they constant-fold per instantiation exactly like
    // the width/bias parameters above.

    /// Mask of one lane's storage bits (low `WIDTH` bits).
    const LANE_MASK: u64 = if Self::WIDTH == 64 { u64::MAX } else { (1u64 << Self::WIDTH) - 1 };
    /// Mask of one lane's exponent field, at the field's own base.
    const EXP_FIELD_MASK: u64 = (1u64 << Self::EXP_BITS) - 1;
    /// Mask of one lane's mantissa field, at the field's own base.
    const MAN_FIELD_MASK: u64 = (1u64 << Self::MAN_BITS) - 1;
    /// Bit 0 of every lane.
    const LANE_LSB_PLANE: u64 = splat(1, Self::WIDTH);
    /// The sign bit of every lane, in place.
    const SIGN_PLANE: u64 = splat(1u64 << (Self::WIDTH - 1), Self::WIDTH);
    /// Every lane's exponent-field mask, shifted down to the lane base
    /// (apply after `reg >> MAN_BITS`).
    const EXP_FIELD_PLANE: u64 = splat(Self::EXP_FIELD_MASK, Self::WIDTH);
    /// Every lane's mantissa-field mask, in place (the mantissa already
    /// sits at the lane base).
    const MAN_FIELD_PLANE: u64 = splat(Self::MAN_FIELD_MASK, Self::WIDTH);
}

/// FP8 (e5m2).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Fp8;
/// FP8alt (e4m3, fully IEEE: has inf).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Fp8alt;
/// IEEE binary16.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Fp16;
/// bfloat16 layout with IEEE semantics.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Fp16alt;
/// IEEE binary32.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Fp32;
/// IEEE binary64.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Fp64;

impl FormatSpec for Fp8 {
    const EXP_BITS: u32 = 5;
    const MAN_BITS: u32 = 2;
}

impl FormatSpec for Fp8alt {
    const EXP_BITS: u32 = 4;
    const MAN_BITS: u32 = 3;
}

impl FormatSpec for Fp16 {
    const EXP_BITS: u32 = 5;
    const MAN_BITS: u32 = 10;
}

impl FormatSpec for Fp16alt {
    const EXP_BITS: u32 = 8;
    const MAN_BITS: u32 = 7;
}

impl FormatSpec for Fp32 {
    const EXP_BITS: u32 = 8;
    const MAN_BITS: u32 = 23;
}

impl FormatSpec for Fp64 {
    const EXP_BITS: u32 = 11;
    const MAN_BITS: u32 = 52;
}

/// Marker for the expanding source→destination pairs the ExSdotp unit
/// supports (Table I): monomorphized expanding kernels bound on
/// `S: ExpandTo<D>` can only be instantiated at hardware-legal pairs.
pub trait ExpandTo<D: FormatSpec>: FormatSpec {}

impl ExpandTo<Fp32> for Fp16 {}
impl ExpandTo<Fp32> for Fp16alt {}
impl ExpandTo<Fp16> for Fp8 {}
impl ExpandTo<Fp16alt> for Fp8 {}
impl ExpandTo<Fp16> for Fp8alt {}
impl ExpandTo<Fp16alt> for Fp8alt {}

/// Dispatch a runtime `(src, dst)` [`FpFormat`] pair to the matching
/// compile-time [`ExpandTo`] pair, binding the types as `$S`/`$D`
/// within `$body`; evaluates `$fallback` for pairs outside Table I.
/// The single source of truth for the six legal expanding pairs on the
/// runtime→compile-time boundary — used by `batch::exsdotp_accumulate`
/// and `accuracy::accumulate_fast`.
#[macro_export]
macro_rules! with_expanding_pair {
    ($src:expr, $dst:expr, $S:ident, $D:ident, $body:block, $fallback:block) => {
        match ($src.exp_bits, $src.man_bits, $dst.exp_bits, $dst.man_bits) {
            (5, 10, 8, 23) => {
                type $S = $crate::formats::spec::Fp16;
                type $D = $crate::formats::spec::Fp32;
                $body
            }
            (8, 7, 8, 23) => {
                type $S = $crate::formats::spec::Fp16alt;
                type $D = $crate::formats::spec::Fp32;
                $body
            }
            (5, 2, 5, 10) => {
                type $S = $crate::formats::spec::Fp8;
                type $D = $crate::formats::spec::Fp16;
                $body
            }
            (5, 2, 8, 7) => {
                type $S = $crate::formats::spec::Fp8;
                type $D = $crate::formats::spec::Fp16alt;
                $body
            }
            (4, 3, 5, 10) => {
                type $S = $crate::formats::spec::Fp8alt;
                type $D = $crate::formats::spec::Fp16;
                $body
            }
            (4, 3, 8, 7) => {
                type $S = $crate::formats::spec::Fp8alt;
                type $D = $crate::formats::spec::Fp16alt;
                $body
            }
            _ => $fallback,
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{FP16, FP16ALT, FP32, FP64, FP8, FP8ALT};

    #[test]
    fn specs_bridge_to_the_runtime_descriptors() {
        assert_eq!(Fp8::FMT, FP8);
        assert_eq!(Fp8alt::FMT, FP8ALT);
        assert_eq!(Fp16::FMT, FP16);
        assert_eq!(Fp16alt::FMT, FP16ALT);
        assert_eq!(Fp32::FMT, FP32);
        assert_eq!(Fp64::FMT, FP64);
    }

    #[test]
    fn swar_planes_address_every_lane() {
        // Spot checks against hand-written masks…
        assert_eq!(Fp8::LANE_LSB_PLANE, 0x0101_0101_0101_0101);
        assert_eq!(Fp8::SIGN_PLANE, 0x8080_8080_8080_8080);
        assert_eq!(Fp16::LANE_LSB_PLANE, 0x0001_0001_0001_0001);
        assert_eq!(Fp16::SIGN_PLANE, 0x8000_8000_8000_8000);
        assert_eq!(Fp16::MAN_FIELD_PLANE, 0x03ff_03ff_03ff_03ff);
        assert_eq!(Fp64::SIGN_PLANE, 0x8000_0000_0000_0000);
        assert_eq!(Fp64::LANE_MASK, u64::MAX);

        // …and the general invariants: each plane is the per-lane field
        // replicated at every lane base, for every paper format.
        fn check<F: FormatSpec>() {
            assert_eq!(F::LANES * F::WIDTH, 64, "paper formats tile a register exactly");
            for i in 0..F::LANES {
                let sh = i * F::WIDTH;
                assert_eq!((F::LANE_LSB_PLANE >> sh) & F::LANE_MASK, 1);
                assert_eq!((F::SIGN_PLANE >> sh) & F::LANE_MASK, 1 << (F::WIDTH - 1));
                assert_eq!((F::EXP_FIELD_PLANE >> sh) & F::LANE_MASK, F::EXP_FIELD_MASK);
                assert_eq!((F::MAN_FIELD_PLANE >> sh) & F::LANE_MASK, F::MAN_FIELD_MASK);
            }
            assert_eq!(F::EXP_FIELD_MASK, F::FMT.exp_special());
            assert_eq!(F::MAN_FIELD_MASK, F::FMT.man_mask());
        }
        check::<Fp8>();
        check::<Fp8alt>();
        check::<Fp16>();
        check::<Fp16alt>();
        check::<Fp32>();
        check::<Fp64>();
    }

    #[test]
    fn derived_consts_match_descriptor_methods() {
        fn check<F: FormatSpec>() {
            assert_eq!(F::WIDTH, F::FMT.width());
            assert_eq!(F::LANES, F::FMT.lanes_in_64());
            assert_eq!(F::PRECISION, F::FMT.precision());
            assert_eq!(F::BIAS, F::FMT.bias());
        }
        check::<Fp8>();
        check::<Fp8alt>();
        check::<Fp16>();
        check::<Fp16alt>();
        check::<Fp32>();
        check::<Fp64>();
    }
}
