//! Optimizers over FP32 master weights.
//!
//! The mixed-precision recipe (Wang et al. 2018 §3) keeps a
//! full-precision master copy of every parameter: minifloat rounding
//! happens *on the way down* — when [`crate::nn::layer::Linear`] casts
//! the masters to the compute format each step — never in the update
//! itself, so tiny gradient contributions accumulate instead of being
//! swallowed by the 8-bit grid. Update arithmetic runs in f64 and
//! stores back to the f32 masters.

use crate::ensure;
use crate::util::error::Result;

/// One parameter tensor paired with its gradient (already unscaled).
pub struct ParamMut<'a> {
    /// FP32 master values, updated in place.
    pub value: &'a mut [f32],
    /// Gradient of the last backward pass.
    pub grad: &'a [f32],
}

/// Optimizer selection + hyperparameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum OptimSpec {
    /// SGD with classical momentum: `m ← μ·m + g`, `w ← w − lr·m`.
    Sgd {
        /// Learning rate.
        lr: f64,
        /// Momentum coefficient μ.
        momentum: f64,
    },
    /// Adam (Kingma & Ba) with bias correction.
    Adam {
        /// Learning rate.
        lr: f64,
        /// First-moment decay β₁.
        beta1: f64,
        /// Second-moment decay β₂.
        beta2: f64,
        /// Denominator fuzz ε.
        eps: f64,
    },
}

impl OptimSpec {
    /// SGD with the conventional μ = 0.9.
    pub fn sgd(lr: f64) -> Self {
        OptimSpec::Sgd { lr, momentum: 0.9 }
    }

    /// Adam with the conventional β₁ = 0.9, β₂ = 0.999, ε = 1e-8.
    pub fn adam(lr: f64) -> Self {
        OptimSpec::Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8 }
    }

    /// Learning rate.
    pub fn lr(&self) -> f64 {
        match *self {
            OptimSpec::Sgd { lr, .. } | OptimSpec::Adam { lr, .. } => lr,
        }
    }
}

/// Optimizer state: per-parameter moment buffers, FP32 like the masters.
pub struct Optim {
    spec: OptimSpec,
    t: u64,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl Optim {
    /// Fresh optimizer (state allocates lazily on the first step).
    pub fn new(spec: OptimSpec) -> Self {
        Optim { spec, t: 0, m: Vec::new(), v: Vec::new() }
    }

    /// The spec this optimizer runs.
    pub fn spec(&self) -> OptimSpec {
        self.spec
    }

    /// Steps applied so far (skipped steps do not count).
    pub fn steps(&self) -> u64 {
        self.t
    }

    /// Apply one update to every parameter. The parameter list must be
    /// stable across calls (same tensors, same order) — state buffers
    /// are positional.
    pub fn step(&mut self, params: &mut [ParamMut<'_>]) -> Result<()> {
        if self.m.is_empty() {
            self.m = params.iter().map(|p| vec![0.0; p.value.len()]).collect();
            if matches!(self.spec, OptimSpec::Adam { .. }) {
                self.v = params.iter().map(|p| vec![0.0; p.value.len()]).collect();
            }
        }
        ensure!(
            self.m.len() == params.len(),
            "optimizer state tracks {} parameters but {} were passed (the list must be stable)",
            self.m.len(),
            params.len()
        );
        self.t += 1;
        match self.spec {
            OptimSpec::Sgd { lr, momentum } => {
                for (p, mbuf) in params.iter_mut().zip(self.m.iter_mut()) {
                    ensure!(p.value.len() == p.grad.len(), "parameter/gradient length mismatch");
                    for ((w, &g), mv) in p.value.iter_mut().zip(p.grad).zip(mbuf.iter_mut()) {
                        let m = momentum * *mv as f64 + g as f64;
                        *mv = m as f32;
                        *w = (*w as f64 - lr * m) as f32;
                    }
                }
            }
            OptimSpec::Adam { lr, beta1, beta2, eps } => {
                let bc1 = 1.0 - beta1.powi(self.t as i32);
                let bc2 = 1.0 - beta2.powi(self.t as i32);
                for ((p, mbuf), vbuf) in params.iter_mut().zip(self.m.iter_mut()).zip(self.v.iter_mut()) {
                    ensure!(p.value.len() == p.grad.len(), "parameter/gradient length mismatch");
                    for (i, (w, &g)) in p.value.iter_mut().zip(p.grad).enumerate() {
                        let g = g as f64;
                        let m = beta1 * mbuf[i] as f64 + (1.0 - beta1) * g;
                        let v = beta2 * vbuf[i] as f64 + (1.0 - beta2) * g * g;
                        mbuf[i] = m as f32;
                        vbuf[i] = v as f32;
                        let update = lr * (m / bc1) / ((v / bc2).sqrt() + eps);
                        *w = (*w as f64 - update) as f32;
                    }
                }
            }
        }
        Ok(())
    }
}
