//! Synthetic classification datasets for the training subsystem.
//!
//! [`SpiralDataset`] (moved here from `coordinator::data`, which
//! re-exports it for the PJRT path) keeps its original 4-wide embedding
//! and `runtime::Tensor` batch API. [`Dataset`] is the native trainer's
//! generalized form: features are padded to [`IN_DIM`] — a multiple of
//! the widest SIMD lane count (8×FP8 per 64-bit word), so every batch
//! packs cleanly into the GEMM streams — and batches come back as plain
//! host slices plus raw labels.

use crate::runtime::Tensor;
use crate::util::error::Result;
use crate::util::rng::Rng;
use crate::{bail, ensure};

/// Padded feature width: 4 real features + 4 zeros, sized so the input
/// dimension divides by every policy's lane count (8 for FP8/FP8alt).
pub const IN_DIM: usize = 8;
/// Padded logit width (same lane-alignment argument; unused tail
/// classes never appear as labels).
pub const OUT_DIM: usize = 8;

/// Spiral points with labels, pre-embedded into the model's input space.
pub struct SpiralDataset {
    /// Embedded features, row-major (n × FEATURES).
    pub x: Vec<[f32; 4]>,
    /// Class labels (0..3).
    pub y: Vec<u8>,
}

impl SpiralDataset {
    /// Generate `n_per_class` points per arm (3 arms).
    pub fn generate(n_per_class: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut x = Vec::with_capacity(3 * n_per_class);
        let mut y = Vec::with_capacity(3 * n_per_class);
        for class in 0..3u8 {
            for i in 0..n_per_class {
                let t = 0.1 + 0.9 * (i as f64 / (n_per_class - 1).max(1) as f64);
                let theta = t * 4.5 + class as f64 * 2.1 + rng.gaussian() * 0.1;
                let r = t;
                let (px, py) = (r * theta.cos(), r * theta.sin());
                x.push(Self::embed(px as f32, py as f32));
                y.push(class);
            }
        }
        // Shuffle (deterministic).
        for i in (1..x.len()).rev() {
            let j = rng.below(i as u64 + 1) as usize;
            x.swap(i, j);
            y.swap(i, j);
        }
        SpiralDataset { x, y }
    }

    /// The (x, y, r², 1) embedding (matches `model.embed`).
    pub fn embed(px: f32, py: f32) -> [f32; 4] {
        [px, py, px * px + py * py, 1.0]
    }

    /// Number of samples.
    pub fn len(&self) -> usize {
        self.x.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.x.is_empty()
    }

    /// Random batch as (features, one-hot labels) tensors.
    pub fn batch(&self, size: usize, rng: &mut Rng) -> (Tensor, Tensor) {
        let mut xs = Vec::with_capacity(size * 4);
        let mut ys = vec![0f32; size * 4];
        for b in 0..size {
            let i = rng.below(self.x.len() as u64) as usize;
            xs.extend_from_slice(&self.x[i]);
            ys[b * 4 + self.y[i] as usize] = 1.0;
        }
        (Tensor::new(xs, &[size, 4]), Tensor::new(ys, &[size, 4]))
    }

    /// Sequential batch starting at `start` (for evaluation sweeps);
    /// returns raw labels.
    pub fn ordered_batch(&self, start: usize, size: usize) -> (Tensor, Vec<u8>) {
        let mut xs = Vec::with_capacity(size * 4);
        let mut labels = Vec::with_capacity(size);
        for b in 0..size {
            let i = (start + b) % self.x.len();
            xs.extend_from_slice(&self.x[i]);
            labels.push(self.y[i]);
        }
        (Tensor::new(xs, &[size, 4]), labels)
    }
}

// ----------------------------------------------------- native datasets

/// Which synthetic task a [`crate::api::TrainPlan`] trains on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DataSpec {
    /// The three-arm spiral (the PJRT workload's task).
    Spiral {
        /// Points per arm.
        n_per_class: usize,
    },
    /// Two concentric rings — a second scenario with a different
    /// decision-boundary shape (radial instead of angular).
    Rings {
        /// Points per ring.
        n_per_class: usize,
    },
}

impl DataSpec {
    /// Parse a CLI-style dataset name at the default size.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "spiral" => Ok(DataSpec::Spiral { n_per_class: 300 }),
            "rings" => Ok(DataSpec::Rings { n_per_class: 300 }),
            other => bail!("--dataset must be spiral|rings, got '{other}'"),
        }
    }

    /// Samples the spec will generate (known without materializing).
    pub fn len(&self) -> usize {
        match *self {
            DataSpec::Spiral { n_per_class } => 3 * n_per_class,
            DataSpec::Rings { n_per_class } => 2 * n_per_class,
        }
    }

    /// True when the spec would generate nothing.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Logical class count of the generated dataset.
    pub fn classes(&self) -> usize {
        match *self {
            DataSpec::Spiral { .. } => 3,
            DataSpec::Rings { .. } => 2,
        }
    }

    /// Materialize the dataset.
    pub fn generate(&self, seed: u64) -> Dataset {
        match *self {
            DataSpec::Spiral { n_per_class } => Dataset::spiral(n_per_class, seed),
            DataSpec::Rings { n_per_class } => Dataset::rings(n_per_class, seed),
        }
    }
}

/// A lane-padded classification dataset for the native trainer.
pub struct Dataset {
    /// Features, row-major `len()×IN_DIM` (4 real features + zero pad).
    pub x: Vec<f64>,
    /// Labels, `< classes`.
    pub y: Vec<u8>,
    /// Logical class count.
    pub classes: usize,
}

/// Embed a 2-D point exactly as the datasets do — through
/// [`SpiralDataset::embed`]'s f32 arithmetic (`[x, y, r², 1]`), widened
/// back to f64 and zero-padded to `width` lanes; widths below the 4
/// embedding lanes are clamped to 4 (the embedding is never truncated).
/// The serving load generator ([`crate::serve::sim`]) uses this so
/// generated request features are bit-faithful to the training feature
/// pipeline.
pub fn embed_padded(px: f64, py: f64, width: usize) -> Vec<f64> {
    let e = SpiralDataset::embed(px as f32, py as f32);
    let mut out: Vec<f64> = e.iter().map(|&v| v as f64).collect();
    out.resize(width.max(4), 0.0);
    out
}

fn pad_features(px: f64, py: f64, out: &mut Vec<f64>) {
    out.extend(embed_padded(px, py, IN_DIM));
}

impl Dataset {
    /// The spiral task, padded for the native trainer — same generator
    /// (and therefore the same points, bit-for-bit) as
    /// [`SpiralDataset::generate`].
    pub fn spiral(n_per_class: usize, seed: u64) -> Dataset {
        let s = SpiralDataset::generate(n_per_class, seed);
        let mut x = Vec::with_capacity(s.len() * IN_DIM);
        for row in &s.x {
            x.extend(row.iter().map(|&v| v as f64));
            x.extend(std::iter::repeat(0.0).take(IN_DIM - 4));
        }
        Dataset { x, y: s.y, classes: 3 }
    }

    /// Two concentric rings (classes 0 and 1) with radial noise,
    /// embedded and padded like the spiral. The r² embedding feature
    /// makes this nearly linearly separable — a fast-converging
    /// contrast scenario to the spiral.
    pub fn rings(n_per_class: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let mut x = Vec::with_capacity(2 * n_per_class * IN_DIM);
        let mut y = Vec::with_capacity(2 * n_per_class);
        for class in 0..2u8 {
            let r0 = 0.35 + 0.5 * class as f64;
            for _ in 0..n_per_class {
                let theta = rng.range(0.0, 2.0 * std::f64::consts::PI);
                let r = r0 + rng.gaussian() * 0.05;
                pad_features(r * theta.cos(), r * theta.sin(), &mut x);
                y.push(class);
            }
        }
        // Shuffle (deterministic), mirroring the spiral generator.
        let n = y.len();
        for i in (1..n).rev() {
            let j = rng.below(i as u64 + 1) as usize;
            for e in 0..IN_DIM {
                x.swap(i * IN_DIM + e, j * IN_DIM + e);
            }
            y.swap(i, j);
        }
        Dataset { x, y, classes: 2 }
    }

    /// Number of samples.
    pub fn len(&self) -> usize {
        self.y.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Random batch: `size×IN_DIM` features + raw labels.
    pub fn batch(&self, size: usize, rng: &mut Rng) -> (Vec<f64>, Vec<u8>) {
        let mut xs = Vec::new();
        let mut labels = Vec::new();
        self.batch_into(size, rng, &mut xs, &mut labels);
        (xs, labels)
    }

    /// [`Dataset::batch`] into caller-provided buffers (cleared;
    /// capacity reused across steps — the trainer's per-step arena).
    /// Same RNG consumption, so sequences are bit-identical to the
    /// allocating form.
    pub fn batch_into(&self, size: usize, rng: &mut Rng, xs: &mut Vec<f64>, labels: &mut Vec<u8>) {
        xs.clear();
        labels.clear();
        for _ in 0..size {
            let i = rng.below(self.len() as u64) as usize;
            xs.extend_from_slice(&self.x[i * IN_DIM..(i + 1) * IN_DIM]);
            labels.push(self.y[i]);
        }
    }

    /// Sequential batch starting at `start` (evaluation sweeps).
    pub fn ordered_batch(&self, start: usize, size: usize) -> (Vec<f64>, Vec<u8>) {
        let mut xs = Vec::new();
        let mut labels = Vec::new();
        self.ordered_batch_into(start, size, &mut xs, &mut labels);
        (xs, labels)
    }

    /// [`Dataset::ordered_batch`] into caller-provided buffers.
    pub fn ordered_batch_into(&self, start: usize, size: usize, xs: &mut Vec<f64>, labels: &mut Vec<u8>) {
        xs.clear();
        labels.clear();
        for b in 0..size {
            let i = (start + b) % self.len();
            xs.extend_from_slice(&self.x[i * IN_DIM..(i + 1) * IN_DIM]);
            labels.push(self.y[i]);
        }
    }

    /// Sanity-check invariants (trainer-build time).
    pub fn validate(&self) -> Result<()> {
        ensure!(!self.is_empty(), "dataset is empty");
        ensure!(self.x.len() == self.len() * IN_DIM, "feature matrix is not len x IN_DIM");
        ensure!(self.classes >= 2 && self.classes <= OUT_DIM, "classes must be in 2..={OUT_DIM}");
        ensure!(
            self.y.iter().all(|&l| (l as usize) < self.classes),
            "a label exceeds the class count"
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_balanced_classes() {
        let d = SpiralDataset::generate(50, 1);
        assert_eq!(d.len(), 150);
        for c in 0..3u8 {
            assert_eq!(d.y.iter().filter(|&&y| y == c).count(), 50);
        }
    }

    #[test]
    fn batches_have_one_hot_labels() {
        let d = SpiralDataset::generate(50, 2);
        let mut rng = Rng::new(3);
        let (x, y) = d.batch(16, &mut rng);
        assert_eq!(x.shape, vec![16, 4]);
        assert_eq!(y.shape, vec![16, 4]);
        for b in 0..16 {
            let row = &y.data[b * 4..(b + 1) * 4];
            assert_eq!(row.iter().sum::<f32>(), 1.0);
        }
    }

    #[test]
    fn deterministic_generation() {
        let a = SpiralDataset::generate(20, 9);
        let b = SpiralDataset::generate(20, 9);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn deterministic_batches_same_seed() {
        // Same generation seed + same batch RNG seed ⇒ identical batch
        // *sequences*, for both dataset APIs (the regression the native
        // trainer's reproducibility rests on).
        let (a, b) = (SpiralDataset::generate(40, 7), SpiralDataset::generate(40, 7));
        let (mut ra, mut rb) = (Rng::new(11), Rng::new(11));
        for _ in 0..5 {
            let (xa, ya) = a.batch(16, &mut ra);
            let (xb, yb) = b.batch(16, &mut rb);
            assert_eq!(xa.data, xb.data);
            assert_eq!(ya.data, yb.data);
        }
        let (da, db) = (Dataset::spiral(40, 7), Dataset::spiral(40, 7));
        let (mut ra, mut rb) = (Rng::new(11), Rng::new(11));
        for _ in 0..5 {
            let (xa, la) = da.batch(16, &mut ra);
            let (xb, lb) = db.batch(16, &mut rb);
            assert_eq!(xa, xb);
            assert_eq!(la, lb);
        }
    }

    #[test]
    fn padded_dataset_mirrors_spiral_points() {
        let s = SpiralDataset::generate(30, 4);
        let d = Dataset::spiral(30, 4);
        d.validate().unwrap();
        assert_eq!(d.len(), s.len());
        assert_eq!(d.y, s.y);
        for i in 0..d.len() {
            let row = &d.x[i * IN_DIM..(i + 1) * IN_DIM];
            for e in 0..4 {
                assert_eq!(row[e], s.x[i][e] as f64);
            }
            assert!(row[4..].iter().all(|&v| v == 0.0), "pad lanes must be zero");
        }
    }

    #[test]
    fn rings_are_balanced_and_valid() {
        let d = Dataset::rings(64, 5);
        d.validate().unwrap();
        assert_eq!(d.len(), 128);
        assert_eq!(d.classes, 2);
        for c in 0..2u8 {
            assert_eq!(d.y.iter().filter(|&&y| y == c).count(), 64);
        }
        // Mean squared radius separates the classes by construction
        // (0.35² vs 0.85², noise σ = 0.05).
        let (mut inner, mut outer, mut ni, mut no) = (0f64, 0f64, 0usize, 0usize);
        for i in 0..d.len() {
            let r2 = d.x[i * IN_DIM + 2];
            match d.y[i] {
                0 => {
                    inner += r2;
                    ni += 1;
                }
                _ => {
                    outer += r2;
                    no += 1;
                }
            }
        }
        assert!(inner / ni as f64 + 0.2 < outer / no as f64, "ring radii are not separated");
    }
}
