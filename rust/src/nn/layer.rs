//! Layers with hand-written forward/backward passes.
//!
//! Every multiply in [`Linear`] — the forward product `X·W`, the weight
//! gradient `Xᵀ·G`, the input gradient `G·Wᵀ` — is a validated
//! [`crate::api::GemmPlan`] compiled to a reusable
//! [`crate::api::PlanInstance`] and executed through [`GemmCtx`],
//! operands quantized to the policy's minifloat formats and accumulated
//! in the wider ExSdotp destination format. Elementwise work (bias add,
//! activation functions, softmax) runs in host precision but is
//! re-gridded to the accumulation format where the hardware's epilogue
//! would round, so inter-layer activations always sit on the `acc`
//! grid.
//!
//! Buffer discipline: with a tape present, the hot-path buffers —
//! quantized activations and weights, the masters' f64 staging, layer
//! outputs, gradient host buffers — take recycled storage from the
//! [`Tape`] arena and hand it back once consumed, so the dominant
//! per-step allocations disappear in the steady state (the remaining
//! ones are inside `MfTensor::cast`/`with_layout` on the backward
//! re-cast path). Recycling is capacity-only and cannot change a
//! result bit.
//!
//! Gradients flowing through `backward` are **loss-scaled** (see
//! [`crate::nn::policy::LossScaler`]); layers store them scaled and the
//! trainer unscales once before the optimizer step.

use crate::api::{Layout, MfTensor, Session};
use crate::ensure;
use crate::formats::FpFormat;
use crate::nn::engine::GemmCtx;
use crate::nn::policy::PrecisionPolicy;
use crate::nn::tape::Tape;
use crate::util::error::Result;
use crate::util::rng::Rng;

// -------------------------------------------------------------- linear

/// One linear forward step against an already-prepared weight tensor
/// (`policy.fwd`; column-major storage hits the packed zero-repack
/// route): quantize `x` row-major, run the plan, add the bias in host
/// precision, re-grid the result onto `policy.acc`. Returns the output
/// and the quantized input (what a tape saves for backward).
///
/// This is the **single** implementation of the linear epilogue: the
/// training [`Linear::forward`] (which quantizes its FP32 masters every
/// step) and the frozen serving path
/// ([`crate::serve::InferenceModel`], which packed its weights once)
/// both call it (via [`linear_forward_into`]), so the two can never
/// silently diverge.
pub fn linear_forward_with(
    ctx: &mut GemmCtx,
    policy: &PrecisionPolicy,
    wt: &MfTensor,
    bias: &[f32],
    x: &[f64],
    batch: usize,
    in_dim: usize,
    out_dim: usize,
) -> Result<(Vec<f64>, MfTensor)> {
    let mut y = Vec::new();
    let xt = linear_forward_into(ctx, policy, wt, bias, x, batch, in_dim, out_dim, Vec::new(), &mut y)?;
    Ok((y, xt))
}

/// [`linear_forward_with`] on recycled storage: the output lands in `y`
/// (cleared and resized; capacity reused) and the quantized input packs
/// into `xt_buf`'s allocation (grab it from the tape arena; recover it
/// with [`MfTensor::into_words`] once consumed). Bit-identical to the
/// allocating wrapper.
#[allow(clippy::too_many_arguments)]
pub fn linear_forward_into(
    ctx: &mut GemmCtx,
    policy: &PrecisionPolicy,
    wt: &MfTensor,
    bias: &[f32],
    x: &[f64],
    batch: usize,
    in_dim: usize,
    out_dim: usize,
    xt_buf: Vec<u64>,
    y: &mut Vec<f64>,
) -> Result<MfTensor> {
    ensure!(
        x.len() == batch * in_dim,
        "linear forward: input must be {batch}x{in_dim} = {} values, got {}",
        batch * in_dim,
        x.len()
    );
    ensure!(bias.len() == out_dim, "linear forward: bias must be {out_dim} values, got {}", bias.len());
    let session = ctx.session();
    // A row-major, B column-major: the layouts the kernel streams,
    // so the plan's zero-repack route runs.
    let xt = session.tensor_reusing(x, batch, in_dim, policy.fwd, Layout::RowMajor, xt_buf)?;
    if policy.scaled {
        // Flexpoint-style activation scaling ([`crate::numerics`]): one
        // shared power-of-two scale re-centers the batch near the top
        // of the forward format's range before quantizing, so small
        // post-activation values stay out of the subnormal band and
        // large ones clear of saturation. The GEMM streams the scaled
        // payload; the output is rescaled exactly (power of two) before
        // the bias add. The tape keeps the *unscaled* quantized input
        // (`xt` above), so the backward GEMMs never see the scale.
        let sexp = crate::numerics::shared_exponent(x, policy.fwd, 1);
        crate::obs_count!("numerics.scale.tensors");
        let inv = crate::numerics::exp2(-sexp);
        let scaled: Vec<f64> = x.iter().map(|&v| v * inv).collect();
        let st = session.tensor(&scaled, batch, in_dim, policy.fwd)?;
        ctx.matmul_into(policy.fwd, &st, wt, batch, out_dim, in_dim, false, false, y)?;
        let back = crate::numerics::exp2(sexp);
        for v in y.iter_mut() {
            *v *= back;
        }
    } else {
        ctx.matmul_into(policy.fwd, &xt, wt, batch, out_dim, in_dim, false, false, y)?;
    }
    for bi in 0..batch {
        for j in 0..out_dim {
            y[bi * out_dim + j] += bias[j] as f64;
        }
    }
    // Epilogue rounding: the bias add happens in the accumulation
    // precision on hardware, so re-grid the result there (in place —
    // bit-identical to the old tensor round-trip).
    session.regrid_in_place(policy.acc, y);
    Ok(xt)
}

/// A fully-connected layer: `Y = X·W + b` with FP32 master parameters
/// and minifloat compute.
#[derive(Clone, Debug)]
pub struct Linear {
    /// Input width (must divide by the policy's widest lane count).
    pub in_dim: usize,
    /// Output width (same divisibility requirement).
    pub out_dim: usize,
    /// Master weights, `in_dim×out_dim` row-major, FP32.
    pub w: Vec<f32>,
    /// Master bias, FP32.
    pub b: Vec<f32>,
    /// Weight gradient of the last backward pass (loss-scaled).
    pub gw: Vec<f32>,
    /// Bias gradient of the last backward pass (loss-scaled).
    pub gb: Vec<f32>,
}

impl Linear {
    /// He-style initialization (matches `coordinator::Params::init`).
    pub fn init(in_dim: usize, out_dim: usize, rng: &mut Rng) -> Self {
        let scale = (2.0 / in_dim as f64).sqrt();
        Linear {
            in_dim,
            out_dim,
            w: (0..in_dim * out_dim).map(|_| (rng.gaussian() * scale) as f32).collect(),
            b: vec![0.0; out_dim],
            gw: vec![0.0; in_dim * out_dim],
            gb: vec![0.0; out_dim],
        }
    }

    fn w_f64(&self) -> Vec<f64> {
        let mut out = Vec::new();
        self.w_f64_into(&mut out);
        out
    }

    /// Stage the FP32 masters as f64 into a recycled buffer.
    fn w_f64_into(&self, out: &mut Vec<f64>) {
        out.clear();
        out.extend(self.w.iter().map(|&v| v as f64));
    }

    /// Forward: quantize `x` (`batch×in_dim` row-major) and the master
    /// weights to the policy's forward format, run the plan, add the
    /// bias, round the result onto the accumulation grid. Saves the
    /// quantized input tensor when a tape is supplied — and with a tape
    /// present, *every* per-call buffer (the masters' f64 staging, the
    /// packed weight words, the quantized input, the output) cycles
    /// through the tape arena instead of the allocator.
    pub fn forward(
        &self,
        ctx: &mut GemmCtx,
        policy: &PrecisionPolicy,
        x: &[f64],
        batch: usize,
        tape: Option<&mut Tape>,
    ) -> Result<Vec<f64>> {
        let session = ctx.session();
        match tape {
            Some(t) => {
                let mut w64 = t.grab_host();
                self.w_f64_into(&mut w64);
                let wt = session.tensor_reusing(
                    &w64,
                    self.in_dim,
                    self.out_dim,
                    policy.fwd,
                    Layout::ColMajor,
                    t.grab_words(),
                )?;
                t.recycle_host(w64);
                let buf = t.grab_words();
                let mut y = t.grab_host();
                let xt =
                    linear_forward_into(ctx, policy, &wt, &self.b, x, batch, self.in_dim, self.out_dim, buf, &mut y)?;
                t.recycle_mf(wt);
                t.push_mf(xt);
                Ok(y)
            }
            None => {
                let w64 = self.w_f64();
                let wt =
                    session.tensor_with_layout(&w64, self.in_dim, self.out_dim, policy.fwd, Layout::ColMajor)?;
                let (y, _xt) =
                    linear_forward_with(ctx, policy, &wt, &self.b, x, batch, self.in_dim, self.out_dim)?;
                Ok(y)
            }
        }
    }

    /// Backward: consumes the output gradient `g` (`batch×out_dim`,
    /// loss-scaled) and the saved input activation, produces the input
    /// gradient, and stores the (still scaled) parameter gradients in
    /// [`Linear::gw`] / [`Linear::gb`].
    ///
    /// Both GEMMs follow Wang et al.'s recipe — operands cast to the
    /// (range-oriented) backward format, accumulated wide:
    /// `dW = Xᵀ·G` streams the saved activation re-cast from the
    /// forward format (the FP8-training memory story: nothing wider was
    /// kept), `dX = G·Wᵀ` streams the master weights cast down. Every
    /// intermediate tensor's storage cycles through the tape arena.
    pub fn backward(
        &mut self,
        ctx: &mut GemmCtx,
        policy: &PrecisionPolicy,
        g: &[f64],
        batch: usize,
        tape: &mut Tape,
    ) -> Result<Vec<f64>> {
        ensure!(
            g.len() == batch * self.out_dim,
            "Linear backward: gradient must be {batch}x{} = {} values, got {}",
            self.out_dim,
            batch * self.out_dim,
            g.len()
        );
        let session = ctx.session();
        let rm = session.rounding();
        let x_saved = tape.pop_mf()?;
        ensure!(
            x_saved.shape() == (batch, self.in_dim),
            "Linear backward: saved activation is {}x{}, expected {batch}x{}",
            x_saved.rows(),
            x_saved.cols(),
            self.in_dim
        );
        // dW = Xᵀ·G  (in×out, inner batch): both streams pack *down*
        // the batch dimension, i.e. column-major storage.
        let x_bwd = if x_saved.fmt() == policy.bwd {
            x_saved
        } else {
            let cast = x_saved.cast(policy.bwd, rm)?;
            tape.recycle_mf(x_saved);
            cast
        };
        let x_col = x_bwd.with_layout(Layout::ColMajor)?;
        let g_col = session.tensor_reusing(g, batch, self.out_dim, policy.bwd, Layout::ColMajor, tape.grab_words())?;
        let mut dw = tape.grab_host();
        ctx.matmul_into(policy.bwd, &x_col, &g_col, self.in_dim, self.out_dim, batch, true, false, &mut dw)?;
        tape.recycle_mf(x_col);
        tape.recycle_mf(x_bwd);
        tape.recycle_mf(g_col);
        // dX = G·Wᵀ  (batch×in, inner out): both streams pack along
        // rows — G's rows and W's rows (columns of Wᵀ).
        let g_row = session.tensor_reusing(g, batch, self.out_dim, policy.bwd, Layout::RowMajor, tape.grab_words())?;
        let mut w64 = tape.grab_host();
        self.w_f64_into(&mut w64);
        let w_row = session.tensor_reusing(&w64, self.in_dim, self.out_dim, policy.bwd, Layout::RowMajor, tape.grab_words())?;
        tape.recycle_host(w64);
        let mut dx = tape.grab_host();
        ctx.matmul_into(policy.bwd, &g_row, &w_row, batch, self.in_dim, self.out_dim, false, true, &mut dx)?;
        tape.recycle_mf(g_row);
        tape.recycle_mf(w_row);
        for (o, v) in self.gw.iter_mut().zip(&dw) {
            *o = *v as f32;
        }
        tape.recycle_host(dw);
        // Bias gradient: a pure reduction over the batch (elementwise,
        // not a matmul) in host precision.
        for j in 0..self.out_dim {
            let mut s = 0f64;
            for bi in 0..batch {
                s += g[bi * self.out_dim + j];
            }
            self.gb[j] = s as f32;
        }
        Ok(dx)
    }
}

// --------------------------------------------------------- activations

/// Elementwise nonlinearity between linear layers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Activation {
    /// `max(0, x)`.
    Relu,
    /// GELU (tanh approximation).
    Gelu,
}

const SQRT_2_OVER_PI: f64 = 0.797_884_560_802_865_4;
const GELU_C: f64 = 0.044_715;

fn gelu(x: f64) -> f64 {
    0.5 * x * (1.0 + (SQRT_2_OVER_PI * (x + GELU_C * x * x * x)).tanh())
}

fn gelu_prime(x: f64) -> f64 {
    let u = SQRT_2_OVER_PI * (x + GELU_C * x * x * x);
    let t = u.tanh();
    let du = SQRT_2_OVER_PI * (1.0 + 3.0 * GELU_C * x * x);
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * du
}

impl Activation {
    /// Parse a CLI-style name.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "relu" => Ok(Activation::Relu),
            "gelu" => Ok(Activation::Gelu),
            other => crate::bail!("--act must be relu|gelu, got '{other}'"),
        }
    }

    /// Apply the activation elementwise in place — the inference hot
    /// path (same math as [`Activation::forward`], no tape, no copy).
    pub fn apply_in_place(&self, x: &mut [f64]) {
        match self {
            Activation::Relu => {
                for v in x.iter_mut() {
                    *v = v.max(0.0);
                }
            }
            Activation::Gelu => {
                for v in x.iter_mut() {
                    *v = gelu(*v);
                }
            }
        }
    }

    /// Forward over a `rows×cols` host matrix. The pre-activation is
    /// saved on the tape quantized to `acc` — exact, because linear
    /// epilogues already rounded it onto that grid. With a tape, the
    /// output buffer is drawn from the arena too.
    pub fn forward(
        &self,
        session: &Session,
        acc: FpFormat,
        x: &[f64],
        rows: usize,
        cols: usize,
        mut tape: Option<&mut Tape>,
    ) -> Result<Vec<f64>> {
        ensure!(x.len() == rows * cols, "activation input must be {rows}x{cols}");
        let mut y = match tape.as_deref_mut() {
            Some(t) => t.grab_host(),
            None => Vec::new(),
        };
        y.clear();
        match self {
            Activation::Relu => y.extend(x.iter().map(|&v| v.max(0.0))),
            Activation::Gelu => y.extend(x.iter().map(|&v| gelu(v))),
        }
        if let Some(t) = tape {
            let buf = t.grab_words();
            t.push_mf(session.tensor_reusing(x, rows, cols, acc, Layout::RowMajor, buf)?);
        }
        Ok(y)
    }

    /// Backward: `g ⊙ f'(x)` from the saved pre-activation. Both the
    /// decoded pre-activation and the output gradient draw recycled
    /// storage from the tape arena.
    pub fn backward(&self, g: &[f64], tape: &mut Tape) -> Result<Vec<f64>> {
        let xt = tape.pop_mf()?;
        let mut x = tape.grab_host();
        xt.view().to_f64_into(&mut x);
        tape.recycle_mf(xt);
        ensure!(
            x.len() == g.len(),
            "activation backward: gradient has {} values but the saved input has {}",
            g.len(),
            x.len()
        );
        let mut out = tape.grab_host();
        out.clear();
        match self {
            Activation::Relu => {
                out.extend(x.iter().zip(g).map(|(&xv, &gv)| if xv > 0.0 { gv } else { 0.0 }))
            }
            Activation::Gelu => out.extend(x.iter().zip(g).map(|(&xv, &gv)| gv * gelu_prime(xv))),
        }
        tape.recycle_host(x);
        Ok(out)
    }
}

// ------------------------------------------------- softmax cross-entropy

/// Fused softmax + cross-entropy over padded logits.
///
/// Logit rows are `width` wide (lane-padded); labels index the first
/// `classes` entries. The padded tail participates in the softmax —
/// training pushes it down like any wrong class — but never appears as
/// a label, and evaluation argmaxes over the logical classes only.
#[derive(Clone, Copy, Debug)]
pub struct SoftmaxXent {
    /// Padded logit width.
    pub width: usize,
    /// Logical class count (`labels < classes <= width`).
    pub classes: usize,
}

impl SoftmaxXent {
    /// Mean cross-entropy loss; saves the probabilities (host slot —
    /// they never feed a GEMM) when a tape is supplied, drawing the
    /// buffer from the tape arena.
    pub fn forward(&self, logits: &[f64], labels: &[u8], mut tape: Option<&mut Tape>) -> Result<f64> {
        let batch = labels.len();
        ensure!(
            logits.len() == batch * self.width,
            "loss forward: logits must be {batch}x{} values, got {}",
            self.width,
            logits.len()
        );
        let mut probs = match tape.as_deref_mut() {
            Some(t) => t.grab_host(),
            None => Vec::new(),
        };
        probs.clear();
        probs.resize(logits.len(), 0f64);
        let mut loss = 0f64;
        for (bi, &label) in labels.iter().enumerate() {
            ensure!(
                (label as usize) < self.classes,
                "label {label} out of range (classes = {})",
                self.classes
            );
            let row = &logits[bi * self.width..(bi + 1) * self.width];
            let max = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let mut sum = 0f64;
            for (j, &v) in row.iter().enumerate() {
                let e = (v - max).exp();
                probs[bi * self.width + j] = e;
                sum += e;
            }
            for p in &mut probs[bi * self.width..(bi + 1) * self.width] {
                *p /= sum;
            }
            // log-sum-exp form: finite even when p[label] underflows.
            loss += max + sum.ln() - row[label as usize];
        }
        if let Some(t) = tape {
            t.push_host(probs);
        }
        Ok(loss / batch as f64)
    }

    /// Gradient w.r.t. the logits, pre-multiplied by `scale` (the loss
    /// scale) and averaged over the batch: `(p - onehot)·scale/batch`.
    /// Reuses the saved probabilities' storage for the gradient.
    pub fn backward(&self, labels: &[u8], scale: f64, tape: &mut Tape) -> Result<Vec<f64>> {
        let probs = tape.pop_host()?;
        let batch = labels.len();
        ensure!(
            probs.len() == batch * self.width,
            "loss backward: saved probabilities are {} values, expected {batch}x{}",
            probs.len(),
            self.width
        );
        let mut g = probs;
        for (bi, &label) in labels.iter().enumerate() {
            g[bi * self.width + label as usize] -= 1.0;
        }
        let f = scale / batch as f64;
        for v in &mut g {
            *v *= f;
        }
        Ok(g)
    }
}

// ------------------------------------------------------------------ MLP

/// The training MLP: `Linear → act → Linear → act → Linear → softmax`.
#[derive(Clone, Debug)]
pub struct Mlp {
    /// The linear layers (input → hidden → hidden → output).
    pub layers: Vec<Linear>,
    /// Activation between linear layers.
    pub act: Activation,
    /// The loss head.
    pub loss: SoftmaxXent,
}

impl Mlp {
    /// Build the three-layer MLP.
    pub fn new(
        in_dim: usize,
        hidden: usize,
        out_dim: usize,
        classes: usize,
        act: Activation,
        rng: &mut Rng,
    ) -> Self {
        Mlp {
            layers: vec![
                Linear::init(in_dim, hidden, rng),
                Linear::init(hidden, hidden, rng),
                Linear::init(hidden, out_dim, rng),
            ],
            act,
            loss: SoftmaxXent { width: out_dim, classes },
        }
    }

    /// Forward to logits. Pass a tape to save for backward, or `None`
    /// for evaluation. With a tape, the inter-layer activation buffers
    /// cycle through the arena as each layer supersedes them.
    pub fn forward(
        &self,
        ctx: &mut GemmCtx,
        policy: &PrecisionPolicy,
        x: &[f64],
        batch: usize,
        mut tape: Option<&mut Tape>,
    ) -> Result<Vec<f64>> {
        /// Swap `next` in as the live activation, recycling the
        /// superseded buffer into the arena when one is available.
        fn advance(tape: &mut Option<&mut Tape>, h: &mut Vec<f64>, next: Vec<f64>) {
            let old = std::mem::replace(h, next);
            if let Some(t) = tape.as_deref_mut() {
                t.recycle_host(old);
            }
        }
        let n = self.layers.len();
        let mut h = match tape.as_deref_mut() {
            Some(t) => t.grab_host(),
            None => Vec::new(),
        };
        h.clear();
        h.extend_from_slice(x);
        for (i, l) in self.layers.iter().enumerate() {
            let y = l.forward(ctx, policy, &h, batch, tape.as_deref_mut())?;
            advance(&mut tape, &mut h, y);
            if i + 1 < n {
                let y = self.act.forward(&ctx.session(), policy.acc, &h, batch, l.out_dim, tape.as_deref_mut())?;
                advance(&mut tape, &mut h, y);
            }
        }
        Ok(h)
    }

    /// Inference-only forward: no tape, no activation recording, no
    /// loss-scale plumbing — the hot path [`crate::serve`] freezes and
    /// serves. Delegates to [`Mlp::forward`] with no tape (the tape
    /// only *saves* operands; it never changes the compute), so the
    /// two entry points cannot diverge; the `nn` tests pin the
    /// bit-identity anyway.
    pub fn forward_inference(
        &self,
        ctx: &mut GemmCtx,
        policy: &PrecisionPolicy,
        x: &[f64],
        batch: usize,
    ) -> Result<Vec<f64>> {
        self.forward(ctx, policy, x, batch, None)
    }

    /// Backward from the logit gradient; fills every layer's `gw`/`gb`
    /// (loss-scaled) and drains the tape, recycling every intermediate
    /// gradient buffer through the arena.
    pub fn backward(
        &mut self,
        ctx: &mut GemmCtx,
        policy: &PrecisionPolicy,
        g_logits: &[f64],
        batch: usize,
        tape: &mut Tape,
    ) -> Result<()> {
        let mut g = tape.grab_host();
        g.clear();
        g.extend_from_slice(g_logits);
        for i in (0..self.layers.len()).rev() {
            let dx = self.layers[i].backward(ctx, policy, &g, batch, tape)?;
            tape.recycle_host(std::mem::replace(&mut g, dx));
            if i > 0 {
                let ga = self.act.backward(&g, tape)?;
                tape.recycle_host(std::mem::replace(&mut g, ga));
            }
        }
        tape.recycle_host(g);
        ensure!(tape.is_empty(), "backward pass left {} unconsumed tape slots", tape.len());
        Ok(())
    }

    /// True when every stored gradient is finite (the loss-scaling
    /// overflow check).
    pub fn grads_finite(&self) -> bool {
        self.layers
            .iter()
            .all(|l| l.gw.iter().all(|v| v.is_finite()) && l.gb.iter().all(|v| v.is_finite()))
    }

    /// Multiply every stored gradient by `s` (the 1/scale unscale).
    pub fn scale_grads(&mut self, s: f32) {
        for l in &mut self.layers {
            for v in &mut l.gw {
                *v *= s;
            }
            for v in &mut l.gb {
                *v *= s;
            }
        }
    }

    /// Master parameters paired with their gradients, in a stable order
    /// (`w1, b1, w2, b2, w3, b3`) — what the optimizer steps.
    pub fn params_mut(&mut self) -> Vec<crate::nn::optim::ParamMut<'_>> {
        let mut out = Vec::new();
        for l in self.layers.iter_mut() {
            let Linear { w, b, gw, gb, .. } = l;
            out.push(crate::nn::optim::ParamMut { value: w.as_mut_slice(), grad: gw.as_slice() });
            out.push(crate::nn::optim::ParamMut { value: b.as_mut_slice(), grad: gb.as_slice() });
        }
        out
    }
}
