//! The subsystem's single matmul door: every forward and backward GEMM
//! is built as a validated [`crate::api::GemmPlan`] and executed here —
//! there is no other multiply path in `nn`, which is what makes "no f64
//! shortcut on the compute path" an invariant rather than a convention.
//! The context counts plan executions and packed-fast-path hits so
//! tests (and the trainer's summary) can *assert* the routing instead
//! of trusting it.

use crate::api::{MfTensor, Session};
use crate::formats::FpFormat;
use crate::util::error::Result;

/// GEMM router + instrumentation for one trainer (or one test).
pub struct GemmCtx<'s> {
    session: &'s Session,
    /// Accumulation / output format for every plan built here.
    pub acc: FpFormat,
    /// Plans executed.
    pub calls: u64,
    /// Plans whose operands fed the batch engine packed (zero
    /// decode/re-pack — `RunReport::packed_input`).
    pub packed: u64,
}

impl<'s> GemmCtx<'s> {
    /// A context accumulating into `acc`.
    pub fn new(session: &'s Session, acc: FpFormat) -> Self {
        GemmCtx { session, acc, calls: 0, packed: 0 }
    }

    /// The session plans are built from.
    pub fn session(&self) -> &'s Session {
        self.session
    }

    /// `C = op(A)·op(B)` through a validated [`crate::api::GemmPlan`]: `op` is a
    /// transpose when the corresponding flag is set, and `(m, n, k)` are
    /// the *logical* product dimensions (output `m×n`, inner `k`).
    /// Operands must already be [`MfTensor`]s in `src` — the caller
    /// chooses layouts; matching the kernel streams keeps the run on
    /// the packed fast path. Returns C decoded to row-major f64.
    pub fn matmul(
        &mut self,
        src: FpFormat,
        a: &MfTensor,
        b: &MfTensor,
        m: usize,
        n: usize,
        k: usize,
        ta: bool,
        tb: bool,
    ) -> Result<Vec<f64>> {
        let mut builder = self.session.gemm().src(src).acc(self.acc);
        if ta {
            builder = builder.transpose_a();
        }
        if tb {
            builder = builder.transpose_b();
        }
        let plan = builder.dims(m, n, k)?;
        let run = plan.run(a, b)?;
        self.calls += 1;
        if run.packed_input {
            self.packed += 1;
        }
        Ok(run.c_f64())
    }
}
