//! The subsystem's single matmul door: every forward and backward GEMM
//! is built as a validated [`crate::api::GemmPlan`], **compiled once
//! into a reusable [`crate::api::PlanInstance`]**, and executed here —
//! there is no other multiply path in `nn`, which is what makes "no f64
//! shortcut on the compute path" an invariant rather than a convention.
//!
//! The context owns a small instance cache keyed by GEMM shape: a
//! training step re-runs the same nine shapes every iteration, a serve
//! shard the same per-layer shapes every dispatch, so the steady state
//! is pure cache hits — no plan re-validation, no workspace
//! allocation. The context counts plan executions, packed-fast-path
//! hits, and instance builds vs reuses so tests (and the trainer's
//! summary) can *assert* the routing and the reuse instead of trusting
//! them.

use crate::api::{MfTensor, PlanInstance, Session};
use crate::formats::FpFormat;
use crate::util::error::Result;

/// Cache key: one GEMM shape as the ctx sees it (the accumulation
/// format is fixed per context).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct PlanKey {
    src: FpFormat,
    m: usize,
    n: usize,
    k: usize,
    ta: bool,
    tb: bool,
}

/// GEMM router + instrumentation for one trainer or one serve shard.
/// Owns a copy of the session policy (`Session` is `Copy`), so a
/// context persists across training steps and serve dispatches instead
/// of being rebuilt per call.
#[derive(Debug)]
pub struct GemmCtx {
    session: Session,
    /// Accumulation / output format for every plan built here.
    pub acc: FpFormat,
    /// Plans executed.
    pub calls: u64,
    /// Plans whose operands fed the batch engine packed (zero
    /// decode/re-pack — `RunInfo::packed_input`).
    pub packed: u64,
    /// Instances compiled (cache misses). A steady-state trainer stays
    /// flat here after the first step.
    pub plan_builds: u64,
    /// Executions that reused a compiled instance (cache hits).
    pub plan_reuses: u64,
    /// Compiled instances, keyed by shape. Small (a trainer holds ~9,
    /// a shard a handful per tenant) — scanned linearly.
    plans: Vec<(PlanKey, PlanInstance)>,
}

impl GemmCtx {
    /// A context accumulating into `acc` (copies the session policy).
    pub fn new(session: &Session, acc: FpFormat) -> Self {
        GemmCtx {
            session: *session,
            acc,
            calls: 0,
            packed: 0,
            plan_builds: 0,
            plan_reuses: 0,
            plans: Vec::new(),
        }
    }

    /// The session plans are built from (an owned copy — cheap,
    /// `Session` is `Copy`).
    pub fn session(&self) -> Session {
        self.session
    }

    /// Find or compile the instance for a shape; the flag reports a
    /// cache hit (callers count it as a reuse only once the run
    /// actually executes).
    fn instance_for(
        &mut self,
        src: FpFormat,
        m: usize,
        n: usize,
        k: usize,
        ta: bool,
        tb: bool,
    ) -> Result<(usize, bool)> {
        let key = PlanKey { src, m, n, k, ta, tb };
        if let Some(i) = self.plans.iter().position(|(pk, _)| *pk == key) {
            return Ok((i, true));
        }
        let mut builder = self.session.gemm().src(src).acc(self.acc);
        if ta {
            builder = builder.transpose_a();
        }
        if tb {
            builder = builder.transpose_b();
        }
        let inst = builder.dims(m, n, k)?.instance();
        self.plans.push((key, inst));
        self.plan_builds += 1;
        crate::obs_count!("nn.plan.builds");
        Ok((self.plans.len() - 1, false))
    }

    /// Pre-compile the (untransposed) instance for a shape without
    /// running it — serve shards warm their per-layer plans at
    /// assembly so the first dispatch is already steady-state.
    pub fn warm(&mut self, src: FpFormat, m: usize, n: usize, k: usize) -> Result<()> {
        self.instance_for(src, m, n, k, false, false).map(|_| ())
    }

    /// Compiled instances currently cached.
    pub fn cached_plans(&self) -> usize {
        self.plans.len()
    }

    /// Drain the per-dispatch routing counters (serve shards aggregate
    /// them per tenant per tick); the build/reuse counters persist.
    pub fn take_counters(&mut self) -> (u64, u64) {
        let c = (self.calls, self.packed);
        self.calls = 0;
        self.packed = 0;
        c
    }

    /// `C = op(A)·op(B)` through the cached [`PlanInstance`] for the
    /// shape: `op` is a transpose when the corresponding flag is set,
    /// and `(m, n, k)` are the *logical* product dimensions (output
    /// `m×n`, inner `k`). Operands must already be [`MfTensor`]s in
    /// `src` — the caller chooses layouts; matching the kernel streams
    /// keeps the run on the packed fast path. Returns C decoded to
    /// row-major f64.
    #[allow(clippy::too_many_arguments)]
    pub fn matmul(
        &mut self,
        src: FpFormat,
        a: &MfTensor,
        b: &MfTensor,
        m: usize,
        n: usize,
        k: usize,
        ta: bool,
        tb: bool,
    ) -> Result<Vec<f64>> {
        let mut out = Vec::new();
        self.matmul_into(src, a, b, m, n, k, ta, tb, &mut out)?;
        Ok(out)
    }

    /// [`GemmCtx::matmul`] writing C into a caller-provided buffer
    /// (cleared and resized; capacity reused) — the zero-alloc hot
    /// path.
    #[allow(clippy::too_many_arguments)]
    pub fn matmul_into(
        &mut self,
        src: FpFormat,
        a: &MfTensor,
        b: &MfTensor,
        m: usize,
        n: usize,
        k: usize,
        ta: bool,
        tb: bool,
        out: &mut Vec<f64>,
    ) -> Result<()> {
        let (i, cached) = self.instance_for(src, m, n, k, ta, tb)?;
        let info = self.plans[i].1.run_into(a, b, out)?;
        // Reuses count only after a successful execution; builds count
        // at compile time (a warmed or error-stranded instance is still
        // a compile). So `plan_reuses <= calls` always, and on the
        // error-free hot loop `plan_reuses == calls - plan_builds`.
        self.calls += 1;
        crate::obs_count!("nn.gemm.calls");
        if cached {
            self.plan_reuses += 1;
            crate::obs_count!("nn.plan.reuses");
        }
        if info.packed_input {
            self.packed += 1;
            crate::obs_count!("nn.gemm.packed");
        }
        Ok(())
    }
}
