//! Precision policies and dynamic loss scaling.
//!
//! A [`PrecisionPolicy`] names, per tensor role, which minifloat format
//! each GEMM operand is cast to and which wider format the ExSdotp
//! datapath accumulates in — the software half of the paper's
//! mixed-precision story. The presets mirror the literature:
//!
//! | preset | fwd operands | bwd operands | accumulate | loss scaling |
//! |---|---|---|---|---|
//! | [`PrecisionPolicy::fp32`]    | FP32    | FP32    | FP32 | static 1 |
//! | [`PrecisionPolicy::fp16`]    | FP16    | FP16    | FP32 | dynamic |
//! | [`PrecisionPolicy::fp16alt`] | FP16alt | FP16alt | FP32 | static 1 |
//! | [`PrecisionPolicy::fp8`]     | FP8     | FP8     | FP16 | dynamic |
//! | [`PrecisionPolicy::hfp8`]    | FP8alt  | FP8     | FP16 | dynamic |
//! | [`PrecisionPolicy::fp8sr`]   | FP8     | FP8     | FP16 | dynamic + stochastic rounding |
//! | [`PrecisionPolicy::fp8flex`] | FP8     | FP8     | FP16 | dynamic + SR + tensor scaling |
//!
//! HFP8 (Sun et al. / Wang et al.) is the headline recipe: e4m3 for the
//! forward pass (precision-bound), e5m2 for gradients (range-bound),
//! FP16 ExSdotp accumulation, FP32 master weights in the optimizer.
//!
//! [`LossScaler`] implements dynamic loss scaling with overflow
//! backoff (Noune et al. §loss scaling, NVIDIA AMP-style): gradients
//! are computed pre-multiplied by `scale`; a non-finite gradient skips
//! the optimizer step and halves the scale, while `growth_interval`
//! consecutive good steps double it.

use crate::formats::{FpFormat, FP16, FP16ALT, FP32, FP8, FP8ALT};
use crate::util::error::Result;
use crate::{bail, ensure};

/// Per-tensor-role formats for mixed-precision training. Construct via
/// the presets or literal struct syntax; [`PrecisionPolicy::validate`]
/// checks the pairs against the ExSdotp/FMA kernel families.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PrecisionPolicy {
    /// Short human name (`fp32`, `hfp8`, …).
    pub name: &'static str,
    /// Operand format for forward GEMMs (activations and weights).
    pub fwd: FpFormat,
    /// Operand format for backward GEMMs (gradients, and the weights /
    /// saved activations re-cast for them).
    pub bwd: FpFormat,
    /// Accumulation / output format of every GEMM (the ExSdotp
    /// destination; equal to the operand format for the FMA families).
    pub acc: FpFormat,
    /// Initial loss scale (1.0 = unscaled).
    pub init_loss_scale: f64,
    /// Whether the loss scale adapts (overflow backoff / growth).
    pub dynamic_loss_scale: bool,
    /// Round stochastically instead of RNE: the trainer rekeys its
    /// session to `RoundingMode::StochasticRound(seed)`, so every
    /// quantization and GEMM rounding decision is an unbiased seeded
    /// coin flip (still deterministic per seed, still bit-identical
    /// across thread counts).
    pub stochastic: bool,
    /// Flexpoint-style per-tensor scaling: operands are managed through
    /// [`crate::numerics::ScaledTensor`] with predictive exponent
    /// management, trading the shared scale's headroom against the
    /// narrow format's dynamic range.
    pub scaled: bool,
}

impl PrecisionPolicy {
    /// Full-FP32 baseline (packed-SIMD FMA kernels, no scaling).
    pub fn fp32() -> Self {
        PrecisionPolicy {
            name: "fp32",
            fwd: FP32,
            bwd: FP32,
            acc: FP32,
            init_loss_scale: 1.0,
            dynamic_loss_scale: false,
            stochastic: false,
            scaled: false,
        }
    }

    /// FP16 operands with FP32 ExSdotp accumulation; dynamic loss
    /// scaling covers FP16's limited gradient range.
    pub fn fp16() -> Self {
        PrecisionPolicy {
            name: "fp16",
            fwd: FP16,
            bwd: FP16,
            acc: FP32,
            init_loss_scale: 1024.0,
            dynamic_loss_scale: true,
            stochastic: false,
            scaled: false,
        }
    }

    /// FP16alt (bfloat16 layout) operands with FP32 accumulation — the
    /// FP32-range format, so no scaling is needed.
    pub fn fp16alt() -> Self {
        PrecisionPolicy {
            name: "fp16alt",
            fwd: FP16ALT,
            bwd: FP16ALT,
            acc: FP32,
            init_loss_scale: 1.0,
            dynamic_loss_scale: false,
            stochastic: false,
            scaled: false,
        }
    }

    /// FP8 (e5m2) everywhere with FP16 accumulation.
    pub fn fp8() -> Self {
        PrecisionPolicy {
            name: "fp8",
            fwd: FP8,
            bwd: FP8,
            acc: FP16,
            init_loss_scale: 256.0,
            dynamic_loss_scale: true,
            stochastic: false,
            scaled: false,
        }
    }

    /// The hybrid-FP8 recipe: FP8alt (e4m3) forward, FP8 (e5m2)
    /// backward, FP16 ExSdotp accumulation (Sun et al., the precision
    /// the `train_step_hfp8` artifact compiles).
    pub fn hfp8() -> Self {
        PrecisionPolicy {
            name: "hfp8",
            fwd: FP8ALT,
            bwd: FP8,
            acc: FP16,
            init_loss_scale: 256.0,
            dynamic_loss_scale: true,
            stochastic: false,
            scaled: false,
        }
    }

    /// FP8 with seeded stochastic rounding: same formats as
    /// [`PrecisionPolicy::fp8`], but every rounding decision in the
    /// quantizers and the ExSdotp datapath is an unbiased coin flip
    /// keyed on the session seed. SR decorrelates the systematic
    /// round-to-nearest bias that stalls low-precision training
    /// (Gupta et al. 2015); runs stay deterministic per seed.
    pub fn fp8sr() -> Self {
        PrecisionPolicy { name: "fp8sr", stochastic: true, ..Self::fp8() }
    }

    /// FP8 with stochastic rounding *and* Flexpoint-style per-tensor
    /// scaling ([`crate::numerics::ScaledTensor`]): a shared power-of-two
    /// scale re-centers each tensor in FP8's dynamic range, managed
    /// predictively from overflow/headroom statistics (Köster et al.
    /// 2017). The widest-range recipe the crate offers at 8 bits.
    pub fn fp8flex() -> Self {
        PrecisionPolicy { name: "fp8flex", stochastic: true, scaled: true, ..Self::fp8() }
    }

    /// Parse a CLI-style policy name.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "fp32" => Ok(Self::fp32()),
            "fp16" => Ok(Self::fp16()),
            "fp16alt" => Ok(Self::fp16alt()),
            "fp8" => Ok(Self::fp8()),
            "hfp8" => Ok(Self::hfp8()),
            "fp8sr" => Ok(Self::fp8sr()),
            "fp8flex" => Ok(Self::fp8flex()),
            other => bail!("--precision must be fp32|fp16|fp16alt|fp8|hfp8|fp8sr|fp8flex, got '{other}'"),
        }
    }

    /// All presets (bench / report sweeps), widest first.
    pub fn presets() -> [PrecisionPolicy; 5] {
        [Self::fp32(), Self::fp16alt(), Self::fp16(), Self::fp8(), Self::hfp8()]
    }

    /// The numerics presets layered on top of [`PrecisionPolicy::presets`]
    /// — the accuracy-at-scale recipes ([`crate::numerics::sweep`]
    /// compares these against the plain ones).
    pub fn numerics_presets() -> [PrecisionPolicy; 2] {
        [Self::fp8sr(), Self::fp8flex()]
    }

    /// The widest SIMD lane count any operand format uses — model
    /// dimensions must divide by this so every GEMM shape (forward and
    /// both backward transposes) packs cleanly.
    pub fn max_lanes(&self) -> usize {
        (self.fwd.lanes_in_64().max(self.bwd.lanes_in_64()).max(self.acc.lanes_in_64())) as usize
    }

    /// Check that both `(operand, acc)` pairs name a runnable plan
    /// (an expanding ExSdotp pair or a same-format FMA family) — the
    /// same resolution [`crate::api::GemmPlanBuilder::dims`] performs,
    /// surfaced at trainer-build time.
    pub fn validate(&self) -> Result<()> {
        for (role, fmt) in [("forward", self.fwd), ("backward", self.bwd)] {
            let expanding = crate::api::plan::expanding_family(fmt, self.acc).is_some();
            let fma_family = fmt == self.acc && (fmt == FP32 || fmt == FP16 || fmt == crate::formats::FP64);
            ensure!(
                expanding || fma_family,
                "policy '{}': {role} pair {}->{} is neither a Table I expanding pair nor a \
                 same-format FMA family",
                self.name,
                fmt.name(),
                self.acc.name()
            );
        }
        ensure!(
            self.init_loss_scale.is_finite() && self.init_loss_scale >= 1.0,
            "policy '{}': initial loss scale must be finite and >= 1, got {}",
            self.name,
            self.init_loss_scale
        );
        Ok(())
    }
}

/// Dynamic loss scaling with overflow backoff.
///
/// The trainer multiplies the loss gradient by [`LossScaler::scale`]
/// before the backward pass (lifting small gradients above the narrow
/// format's underflow threshold) and divides it back out before the
/// optimizer step. [`LossScaler::update`] consumes the step's
/// gradient-finiteness verdict and returns whether the step should
/// apply: an overflowed step is *skipped* (the standard AMP recipe) and
/// the scale halves; `growth_interval` consecutive good steps double it
/// again, probing for the largest safe scale.
#[derive(Clone, Debug)]
pub struct LossScaler {
    scale: f64,
    dynamic: bool,
    /// Consecutive finite steps before the scale doubles.
    pub growth_interval: u32,
    good_steps: u32,
    /// Total overflowed (skipped) steps observed.
    pub overflows: u64,
}

/// Scale ceiling: far above anything useful, far below f64 overflow.
const MAX_SCALE: f64 = (1u64 << 24) as f64;

impl LossScaler {
    /// Scaler for a policy (fixed at 1.0 when the policy is static).
    pub fn for_policy(p: &PrecisionPolicy) -> Self {
        LossScaler {
            scale: p.init_loss_scale,
            dynamic: p.dynamic_loss_scale,
            growth_interval: 200,
            good_steps: 0,
            overflows: 0,
        }
    }

    /// Current loss scale.
    pub fn scale(&self) -> f64 {
        self.scale
    }

    /// Force a scale (testing / resuming); keeps the dynamic flag.
    pub fn set_scale(&mut self, scale: f64) {
        self.scale = scale.clamp(1.0, MAX_SCALE);
        self.good_steps = 0;
    }

    /// Record one step's outcome. Returns `true` when the optimizer
    /// step should apply (gradients were finite), `false` when it must
    /// be skipped. Non-finite gradients always skip — even under a
    /// static policy, applying an inf/NaN update would destroy the
    /// master weights.
    pub fn update(&mut self, grads_finite: bool) -> bool {
        if !grads_finite {
            self.overflows += 1;
            // One overflow event = one skipped step and (dynamic mode)
            // one scale backoff; a single counter covers both.
            crate::obs_count!("nn.scale.skips");
            if self.dynamic {
                self.scale = (self.scale * 0.5).max(1.0);
            }
            self.good_steps = 0;
            return false;
        }
        if self.dynamic {
            self.good_steps += 1;
            if self.good_steps >= self.growth_interval {
                self.scale = (self.scale * 2.0).min(MAX_SCALE);
                self.good_steps = 0;
                crate::obs_count!("nn.scale.growths");
            }
        }
        true
    }
}
