//! A minimal reverse-mode tape over [`MfTensor`]-backed activations.
//!
//! Layers push what their backward pass needs during the forward pass
//! and pop it back — in reverse order, because the tape is a stack —
//! during the backward pass. The GEMM-feeding activations are saved as
//! quantized [`MfTensor`]s (the *exact* low-precision operands the
//! forward GEMMs streamed, which is also the memory-saving recipe of
//! FP8 training: nothing wider than the compute format is retained);
//! host-precision slots exist for values that never touch a GEMM
//! (softmax probabilities, activation masks).
//!
//! Pops are type- and shape-checked: popping the wrong slot kind is a
//! typed [`crate::util::error::Error`] naming both kinds, which turns
//! a mis-ordered backward implementation into a diagnosable failure
//! instead of silent garbage.

use crate::api::MfTensor;
use crate::util::error::Result;
use crate::bail;

/// One saved value.
#[derive(Clone, Debug)]
enum Slot {
    /// A quantized activation — the words a forward GEMM streamed.
    Mf(MfTensor),
    /// Host-precision data that never feeds a GEMM.
    Host(Vec<f64>),
}

impl Slot {
    fn kind(&self) -> &'static str {
        match self {
            Slot::Mf(_) => "MfTensor",
            Slot::Host(_) => "host",
        }
    }
}

/// The tape: a stack of saved-for-backward values.
#[derive(Clone, Debug, Default)]
pub struct Tape {
    slots: Vec<Slot>,
}

impl Tape {
    /// An empty tape.
    pub fn new() -> Self {
        Tape::default()
    }

    /// Save a quantized activation.
    pub fn push_mf(&mut self, t: MfTensor) {
        self.slots.push(Slot::Mf(t));
    }

    /// Save host-precision data.
    pub fn push_host(&mut self, v: Vec<f64>) {
        self.slots.push(Slot::Host(v));
    }

    /// Pop the most recent slot as a quantized activation.
    pub fn pop_mf(&mut self) -> Result<MfTensor> {
        match self.slots.pop() {
            Some(Slot::Mf(t)) => Ok(t),
            Some(other) => bail!(
                "tape order violation: expected an MfTensor slot, found a {} slot \
                 (backward passes must pop in exact reverse push order)",
                other.kind()
            ),
            None => bail!("tape underflow: backward pass popped more slots than forward pushed"),
        }
    }

    /// Pop the most recent slot as host data.
    pub fn pop_host(&mut self) -> Result<Vec<f64>> {
        match self.slots.pop() {
            Some(Slot::Host(v)) => Ok(v),
            Some(other) => bail!(
                "tape order violation: expected a host slot, found a {} slot \
                 (backward passes must pop in exact reverse push order)",
                other.kind()
            ),
            None => bail!("tape underflow: backward pass popped more slots than forward pushed"),
        }
    }

    /// Slots currently saved.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// True when no slots are saved (a completed backward pass must
    /// leave the tape empty — the trainer asserts this).
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Drop all saved slots (evaluation-mode reuse).
    pub fn clear(&mut self) {
        self.slots.clear();
    }
}
