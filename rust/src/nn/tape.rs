//! A minimal reverse-mode tape over [`MfTensor`]-backed activations —
//! and the training loop's **buffer arena**.
//!
//! Layers push what their backward pass needs during the forward pass
//! and pop it back — in reverse order, because the tape is a stack —
//! during the backward pass. The GEMM-feeding activations are saved as
//! quantized [`MfTensor`]s (the *exact* low-precision operands the
//! forward GEMMs streamed, which is also the memory-saving recipe of
//! FP8 training: nothing wider than the compute format is retained);
//! host-precision slots exist for values that never touch a GEMM
//! (softmax probabilities, activation masks).
//!
//! Pops are type- and shape-checked: popping the wrong slot kind is a
//! typed [`crate::util::error::Error`] naming both kinds, which turns
//! a mis-ordered backward implementation into a diagnosable failure
//! instead of silent garbage.
//!
//! ## The arena
//!
//! A training step allocates the same activation and gradient buffers
//! every iteration. A persistent tape (the trainer keeps one across
//! steps) doubles as the recycling arena: consumed slots hand their
//! storage back ([`Tape::recycle_mf`] / [`Tape::recycle_host`]), the
//! next step grabs it ([`Tape::grab_words`] / [`Tape::grab_host`]), and
//! [`Tape::clear`] sweeps leftover slots into the pools. Pools hold
//! capacity only — never values — so recycling cannot change a result
//! bit (the dispatch-mode differential tests pin the whole step).

use crate::api::MfTensor;
use crate::bail;
use crate::util::error::Result;

/// Buffers each spare pool retains; beyond this, storage is dropped
/// (bounds arena memory at a handful of step-sized buffers).
const POOL_CAP: usize = 16;

/// One saved value.
#[derive(Clone, Debug)]
enum Slot {
    /// A quantized activation — the words a forward GEMM streamed.
    Mf(MfTensor),
    /// Host-precision data that never feeds a GEMM.
    Host(Vec<f64>),
}

impl Slot {
    fn kind(&self) -> &'static str {
        match self {
            Slot::Mf(_) => "MfTensor",
            Slot::Host(_) => "host",
        }
    }
}

/// The tape: a stack of saved-for-backward values plus the recycled
/// buffer pools.
#[derive(Clone, Debug, Default)]
pub struct Tape {
    slots: Vec<Slot>,
    spare_words: Vec<Vec<u64>>,
    spare_host: Vec<Vec<f64>>,
}

impl Tape {
    /// An empty tape.
    pub fn new() -> Self {
        Tape::default()
    }

    /// Save a quantized activation.
    pub fn push_mf(&mut self, t: MfTensor) {
        self.slots.push(Slot::Mf(t));
    }

    /// Save host-precision data.
    pub fn push_host(&mut self, v: Vec<f64>) {
        self.slots.push(Slot::Host(v));
    }

    /// Pop the most recent slot as a quantized activation.
    pub fn pop_mf(&mut self) -> Result<MfTensor> {
        match self.slots.pop() {
            Some(Slot::Mf(t)) => Ok(t),
            Some(other) => bail!(
                "tape order violation: expected an MfTensor slot, found a {} slot \
                 (backward passes must pop in exact reverse push order)",
                other.kind()
            ),
            None => bail!("tape underflow: backward pass popped more slots than forward pushed"),
        }
    }

    /// Pop the most recent slot as host data.
    pub fn pop_host(&mut self) -> Result<Vec<f64>> {
        match self.slots.pop() {
            Some(Slot::Host(v)) => Ok(v),
            Some(other) => bail!(
                "tape order violation: expected a host slot, found a {} slot \
                 (backward passes must pop in exact reverse push order)",
                other.kind()
            ),
            None => bail!("tape underflow: backward pass popped more slots than forward pushed"),
        }
    }

    // ----------------------------------------------------------- arena

    /// Grab a recycled packed-word buffer (or a fresh empty one) for
    /// quantizing an activation — pair with
    /// [`crate::api::Session::tensor_reusing`] and return the storage
    /// via [`Tape::recycle_mf`] once the tensor is consumed.
    pub fn grab_words(&mut self) -> Vec<u64> {
        self.spare_words.pop().unwrap_or_default()
    }

    /// Grab a recycled host-precision buffer (or a fresh empty one).
    pub fn grab_host(&mut self) -> Vec<f64> {
        self.spare_host.pop().unwrap_or_default()
    }

    /// Return a consumed activation's storage to the arena.
    pub fn recycle_mf(&mut self, t: MfTensor) {
        if self.spare_words.len() < POOL_CAP {
            self.spare_words.push(t.into_words());
        }
    }

    /// Return a consumed host buffer to the arena.
    pub fn recycle_host(&mut self, v: Vec<f64>) {
        if self.spare_host.len() < POOL_CAP {
            self.spare_host.push(v);
        }
    }

    /// Buffers currently parked in the arena pools (word, host).
    pub fn pooled(&self) -> (usize, usize) {
        (self.spare_words.len(), self.spare_host.len())
    }

    // ----------------------------------------------------------- stack

    /// Slots currently saved.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// True when no slots are saved (a completed backward pass must
    /// leave the tape empty — the trainer asserts this).
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Drop all saved slots, sweeping their storage into the arena
    /// pools (evaluation-mode and cross-step reuse).
    pub fn clear(&mut self) {
        while let Some(slot) = self.slots.pop() {
            match slot {
                Slot::Mf(t) => self.recycle_mf(t),
                Slot::Host(v) => self.recycle_host(v),
            }
        }
    }
}
