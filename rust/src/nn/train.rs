//! [`NativeTrainer`] — the mixed-precision training loop.
//!
//! One step is the full Wang et al. 2018 recipe end to end:
//!
//! 1. sample a batch, forward through the MLP (minifloat GEMMs,
//!    ExSdotp accumulation, FP32-master weights cast down);
//! 2. softmax-cross-entropy loss; seed the backward pass with the
//!    logit gradient **pre-multiplied by the loss scale**;
//! 3. backward through the tape (two GEMMs per linear layer —
//!    `Xᵀ·G` and `G·Wᵀ` — in the backward format);
//! 4. finiteness check → [`crate::nn::policy::LossScaler::update`]:
//!    overflowed steps are skipped and the scale backs off;
//! 5. unscale the gradients and step the optimizer on the FP32 masters.
//!
//! The trainer owns its execution state **across steps**: one
//! [`GemmCtx`] whose compiled [`crate::api::PlanInstance`]s (nine GEMM
//! shapes) persist — the first step compiles them, every later step is
//! pure reuse — plus a persistent [`Tape`] whose arena recycles
//! activation/gradient buffers and a step arena for the sampled batch.
//! Every matmul is a validated [`crate::api::GemmPlan`]; the trainer
//! exposes plan executions ([`NativeTrainer::gemm_calls`]), packed
//! fast-path hits ([`NativeTrainer::packed_runs`]) and instance
//! builds/reuses ([`NativeTrainer::plan_builds`] /
//! [`NativeTrainer::plan_reuses`]) so that routing **and reuse** are
//! asserted by tests, not assumed. Construct through the typed front
//! door: [`crate::api::Session::train`] /
//! [`crate::api::Session::native_trainer`].

use crate::api::Session;
use crate::nn::data::{Dataset, IN_DIM, OUT_DIM};
use crate::nn::engine::GemmCtx;
use crate::nn::layer::{Activation, Mlp};
use crate::nn::optim::{Optim, OptimSpec};
use crate::nn::policy::{LossScaler, PrecisionPolicy};
use crate::nn::tape::Tape;
use crate::util::error::Result;
use crate::util::rng::Rng;

/// One training step's record.
#[derive(Clone, Copy, Debug)]
pub struct StepRecord {
    /// Step index.
    pub step: usize,
    /// Training loss of the batch (before the update).
    pub loss: f64,
    /// Loss scale the step ran with.
    pub scale: f64,
    /// True when the step overflowed and the update was skipped.
    pub skipped: bool,
}

/// Reusable per-step buffers for the sampled batch (the tape arena and
/// the GemmCtx workspaces cover everything downstream).
#[derive(Debug, Default)]
struct StepArena {
    x: Vec<f64>,
    labels: Vec<u8>,
}

/// The native mixed-precision training driver.
pub struct NativeTrainer {
    session: Session,
    policy: PrecisionPolicy,
    model: Mlp,
    optim: Optim,
    scaler: LossScaler,
    data: Dataset,
    rng: Rng,
    batch: usize,
    /// Per-step records (loss curve, scale trajectory, skips).
    pub history: Vec<StepRecord>,
    ctx: GemmCtx,
    tape: Tape,
    arena: StepArena,
}

impl NativeTrainer {
    /// Assemble a trainer. Validation happened in
    /// [`crate::api::TrainPlanBuilder::build`]; this only wires state.
    pub(crate) fn assemble(
        session: Session,
        policy: PrecisionPolicy,
        data: Dataset,
        hidden: usize,
        batch: usize,
        act: Activation,
        optim: OptimSpec,
    ) -> Self {
        // A stochastic policy rekeys the session before any plan or
        // tensor is built, so quantization and every GEMM rounding
        // decision draw from the same seeded stream. Weight init and
        // batch sampling below key off `seed()` and are unaffected.
        let session = if policy.stochastic {
            session
                .with_rounding(crate::softfloat::RoundingMode::StochasticRound(session.seed()))
        } else {
            session
        };
        let mut init_rng = session.rng();
        let model = Mlp::new(IN_DIM, hidden, OUT_DIM, data.classes, act, &mut init_rng);
        let scaler = LossScaler::for_policy(&policy);
        let ctx = GemmCtx::new(&session, policy.acc);
        NativeTrainer {
            session,
            policy,
            model,
            optim: Optim::new(optim),
            scaler,
            data,
            rng: Rng::new(session.seed() ^ 0x5339),
            batch,
            history: Vec::new(),
            ctx,
            tape: Tape::new(),
            arena: StepArena::default(),
        }
    }

    /// The active precision policy.
    pub fn policy(&self) -> &PrecisionPolicy {
        &self.policy
    }

    /// The session the trainer runs under (what
    /// [`crate::serve::InferenceModel::freeze`] needs next to
    /// [`NativeTrainer::model`] to snapshot a trained model for
    /// serving).
    pub fn session(&self) -> &Session {
        &self.session
    }

    /// The model (read access for inspection/tests).
    pub fn model(&self) -> &Mlp {
        &self.model
    }

    /// Current loss scale.
    pub fn loss_scale(&self) -> f64 {
        self.scaler.scale()
    }

    /// Force the loss scale (testing the backoff path; resuming runs).
    pub fn set_loss_scale(&mut self, scale: f64) {
        self.scaler.set_scale(scale);
    }

    /// GEMM plans executed so far (forward + backward + evaluation).
    pub fn gemm_calls(&self) -> u64 {
        self.ctx.calls
    }

    /// How many of those fed the batch engine packed (zero
    /// decode/re-pack). Expanding-pair policies hit this on every plan.
    pub fn packed_runs(&self) -> u64 {
        self.ctx.packed
    }

    /// Plan instances compiled (one per distinct GEMM shape — nine for
    /// the three-layer MLP; flat after the first step).
    pub fn plan_builds(&self) -> u64 {
        self.ctx.plan_builds
    }

    /// GEMM executions that reused a compiled instance (everything
    /// after the first step).
    pub fn plan_reuses(&self) -> u64 {
        self.ctx.plan_reuses
    }

    /// Steps skipped by loss-scaling overflow backoff.
    pub fn skipped_steps(&self) -> u64 {
        self.history.iter().filter(|r| r.skipped).count() as u64
    }

    /// Run one SGD/Adam step on a random batch; returns the record.
    pub fn step(&mut self) -> Result<StepRecord> {
        let step_no = self.history.len();
        let _step_sp = crate::obs::trace::span_with("train.step", "nn", || {
            format!("\"step\":{step_no}")
        });
        crate::obs_count!("train.steps");
        self.data.batch_into(self.batch, &mut self.rng, &mut self.arena.x, &mut self.arena.labels);
        let scale = self.scaler.scale();
        self.tape.clear();
        let (logits, loss) = {
            let _sp = crate::obs::trace::span("train.forward", "nn");
            let logits = self.model.forward(
                &mut self.ctx,
                &self.policy,
                &self.arena.x,
                self.batch,
                Some(&mut self.tape),
            )?;
            let loss = self.model.loss.forward(&logits, &self.arena.labels, Some(&mut self.tape))?;
            (logits, loss)
        };
        {
            let _sp = crate::obs::trace::span("train.backward", "nn");
            let g0 = self.model.loss.backward(&self.arena.labels, scale, &mut self.tape)?;
            self.model.backward(&mut self.ctx, &self.policy, &g0, self.batch, &mut self.tape)?;
            self.tape.recycle_host(g0);
            self.tape.recycle_host(logits);
        }
        // A non-finite *loss* (forward overflow) skips exactly like a
        // gradient overflow.
        let finite = loss.is_finite() && self.model.grads_finite();
        let apply = self.scaler.update(finite);
        if apply {
            let _sp = crate::obs::trace::span("train.optim", "nn");
            self.model.scale_grads((1.0 / scale) as f32);
            let mut params = self.model.params_mut();
            self.optim.step(&mut params)?;
        }
        let record = StepRecord { step: step_no, loss, scale, skipped: !apply };
        self.history.push(record);
        Ok(record)
    }

    /// Train for `steps` batches, logging every `log_every` (0 = quiet);
    /// returns the final loss.
    pub fn train(&mut self, steps: usize, log_every: usize) -> Result<f64> {
        let mut last = f64::NAN;
        for i in 0..steps {
            let r = self.step()?;
            last = r.loss;
            if log_every > 0 && (i % log_every == 0 || i + 1 == steps) {
                let skip = if r.skipped { "  [overflow: step skipped]" } else { "" };
                println!("step {i:>4}  loss {:.4}  scale {:>6}{skip}", r.loss, r.scale);
            }
        }
        Ok(last)
    }

    /// Classification accuracy over the whole dataset (forward passes
    /// in the policy's forward precision, argmax over the logical
    /// classes). Walks full batches; the tail remainder (< batch) is
    /// skipped, exactly like the PJRT evaluator. Runs on the same
    /// persistent context (and therefore the same compiled instances)
    /// as training — the forward shapes are identical. The tape-free
    /// forward still allocates its per-layer buffers: recording to the
    /// tape arena would add a pre-activation quantization per layer per
    /// batch, which costs more than the allocations it saves on this
    /// cold path (a deliberate tradeoff; the hot training step is the
    /// arena-recycled one).
    pub fn accuracy(&mut self) -> Result<f64> {
        let mut correct = 0usize;
        let mut total = 0usize;
        let mut idx = 0;
        while idx + self.batch <= self.data.len() {
            self.data.ordered_batch_into(idx, self.batch, &mut self.arena.x, &mut self.arena.labels);
            let logits =
                self.model.forward_inference(&mut self.ctx, &self.policy, &self.arena.x, self.batch)?;
            for (b, &label) in self.arena.labels.iter().enumerate() {
                let row = &logits[b * OUT_DIM..b * OUT_DIM + self.data.classes];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
                    .map(|(i, _)| i)
                    .unwrap_or(0);
                correct += (pred == label as usize) as usize;
                total += 1;
            }
            self.tape.recycle_host(logits);
            idx += self.batch;
        }
        Ok(correct as f64 / total.max(1) as f64)
    }

    /// Mean loss over the most recent `n` non-skipped steps.
    pub fn recent_loss(&self, n: usize) -> f64 {
        let applied: Vec<f64> =
            self.history.iter().rev().filter(|r| !r.skipped).take(n).map(|r| r.loss).collect();
        if applied.is_empty() {
            return f64::NAN;
        }
        applied.iter().sum::<f64>() / applied.len() as f64
    }
}
