//! Subsystem tests: every layer's backward pass against f64 central
//! finite differences (tolerances scaled to the policy's format
//! epsilon), the loss-scaling overflow/backoff path, GEMM-plan routing
//! assertions, and bit-level determinism.

use super::data::{Dataset, IN_DIM, OUT_DIM};
use super::engine::GemmCtx;
use super::layer::{Activation, Linear, Mlp, SoftmaxXent};
use super::optim::{Optim, OptimSpec, ParamMut};
use super::policy::{LossScaler, PrecisionPolicy};
use super::tape::Tape;
use crate::api::Session;
use crate::util::rng::Rng;

fn session() -> Session {
    Session::builder().seed(77).build()
}

/// `|fd - an| <= atol + rtol*max(|fd|, |an|)` with a diagnostic.
fn assert_close(fd: f64, an: f64, atol: f64, rtol: f64, what: &str) {
    let tol = atol + rtol * fd.abs().max(an.abs());
    assert!(
        (fd - an).abs() <= tol,
        "{what}: finite-difference {fd:.6e} vs analytic {an:.6e} (tol {tol:.2e})"
    );
}

/// Per-policy FD step + tolerances, scaled to the *operand* epsilon
/// (2^-p): the staircase of the quantized forward bounds how small `h`
/// may be, and operand rounding bounds how closely the analytic
/// backward can match the true secant.
fn fd_params(p: &PrecisionPolicy) -> (f64, f64, f64) {
    let eps = 2f64.powi(-(p.fwd.precision().min(p.bwd.precision()) as i32));
    match p.fwd.width() {
        32 => (1e-3, 1e-4, 1e-2),           // (h, atol, rtol) — FP32: tight
        _ => (2e-2, 5e-3, 300.0 * eps),     // FP16: eps = 2^-11 → rtol ≈ 0.15
    }
}

// ---------------------------------------------------------- Linear FD

/// Scalar probe loss `L = Σ y ⊙ R` over a layer output.
fn probe_loss(y: &[f64], r: &[f64]) -> f64 {
    y.iter().zip(r).map(|(a, b)| a * b).sum()
}

#[test]
fn linear_backward_matches_finite_differences() {
    let session = session();
    let (batch, in_dim, out_dim) = (8, 8, 8);
    for policy in [PrecisionPolicy::fp32(), PrecisionPolicy::fp16()] {
        let (h, atol, rtol) = fd_params(&policy);
        let mut rng = Rng::new(31);
        let mut layer = Linear::init(in_dim, out_dim, &mut rng);
        let x: Vec<f64> = (0..batch * in_dim).map(|_| rng.gaussian() * 0.5).collect();
        let r: Vec<f64> = (0..batch * out_dim).map(|_| rng.gaussian()).collect();
        let fwd = |layer: &Linear, x: &[f64]| -> f64 {
            let mut ctx = GemmCtx::new(&session, policy.acc);
            let y = layer.forward(&mut ctx, &policy, x, batch, None).expect("forward");
            probe_loss(&y, &r)
        };
        // Analytic pass: dL/dy = R.
        let mut ctx = GemmCtx::new(&session, policy.acc);
        let mut tape = Tape::new();
        layer.forward(&mut ctx, &policy, &x, batch, Some(&mut tape)).expect("forward");
        let dx = layer.backward(&mut ctx, &policy, &r, batch, &mut tape).expect("backward");
        let mut rng_pick = Rng::new(5);
        // Weight gradient.
        for _ in 0..6 {
            let i = rng_pick.below((in_dim * out_dim) as u64) as usize;
            let orig = layer.w[i];
            layer.w[i] = (orig as f64 + h) as f32;
            let lp = fwd(&layer, &x);
            layer.w[i] = (orig as f64 - h) as f32;
            let lm = fwd(&layer, &x);
            layer.w[i] = orig;
            assert_close((lp - lm) / (2.0 * h), layer.gw[i] as f64, atol, rtol,
                &format!("{} dW[{i}]", policy.name));
        }
        // Bias gradient.
        for _ in 0..3 {
            let j = rng_pick.below(out_dim as u64) as usize;
            let orig = layer.b[j];
            layer.b[j] = (orig as f64 + h) as f32;
            let lp = fwd(&layer, &x);
            layer.b[j] = (orig as f64 - h) as f32;
            let lm = fwd(&layer, &x);
            layer.b[j] = orig;
            assert_close((lp - lm) / (2.0 * h), layer.gb[j] as f64, atol, rtol,
                &format!("{} db[{j}]", policy.name));
        }
        // Input gradient.
        let mut x2 = x.clone();
        for _ in 0..6 {
            let i = rng_pick.below((batch * in_dim) as u64) as usize;
            let orig = x2[i];
            x2[i] = orig + h;
            let lp = fwd(&layer, &x2);
            x2[i] = orig - h;
            let lm = fwd(&layer, &x2);
            x2[i] = orig;
            assert_close((lp - lm) / (2.0 * h), dx[i], atol, rtol,
                &format!("{} dX[{i}]", policy.name));
        }
    }
}

// ------------------------------------------------------ activation FD

#[test]
fn activation_backward_matches_finite_differences() {
    // Host math is exact f64, so the FD tolerance is pure curvature;
    // GELU is smooth, ReLU is checked away from its kink.
    let session = session();
    let acc = crate::formats::FP32;
    let mut rng = Rng::new(9);
    let x: Vec<f64> = (0..32).map(|_| rng.gaussian()).collect();
    let r: Vec<f64> = (0..32).map(|_| rng.gaussian()).collect();
    let h = 1e-5;
    for act in [Activation::Relu, Activation::Gelu] {
        let mut tape = Tape::new();
        act.forward(&session, acc, &x, 4, 8, Some(&mut tape)).expect("forward");
        let dx = act.backward(&r, &mut tape).expect("backward");
        for i in 0..x.len() {
            if act == Activation::Relu && x[i].abs() < 10.0 * h {
                continue; // FD is undefined across the kink
            }
            let mut xp = x.clone();
            xp[i] = x[i] + h;
            let lp = probe_loss(&act.forward(&session, acc, &xp, 4, 8, None).unwrap(), &r);
            xp[i] = x[i] - h;
            let lm = probe_loss(&act.forward(&session, acc, &xp, 4, 8, None).unwrap(), &r);
            assert_close((lp - lm) / (2.0 * h), dx[i], 1e-6, 1e-5, &format!("{act:?} dX[{i}]"));
        }
    }
}

#[test]
fn softmax_xent_backward_matches_finite_differences() {
    let loss = SoftmaxXent { width: OUT_DIM, classes: 3 };
    let mut rng = Rng::new(13);
    let batch = 6;
    let logits: Vec<f64> = (0..batch * OUT_DIM).map(|_| rng.gaussian()).collect();
    let labels: Vec<u8> = (0..batch).map(|_| rng.below(3) as u8).collect();
    let mut tape = Tape::new();
    loss.forward(&logits, &labels, Some(&mut tape)).expect("forward");
    let g = loss.backward(&labels, 1.0, &mut tape).expect("backward");
    let h = 1e-6;
    for i in 0..logits.len() {
        let mut lp = logits.clone();
        lp[i] += h;
        let up = loss.forward(&lp, &labels, None).unwrap();
        lp[i] = logits[i] - h;
        let dn = loss.forward(&lp, &labels, None).unwrap();
        assert_close((up - dn) / (2.0 * h), g[i], 1e-8, 1e-5, &format!("dlogits[{i}]"));
    }
}

// -------------------------------------------------------- MLP-level FD

#[test]
fn mlp_weight_gradients_match_finite_differences() {
    // End-to-end: three linears + GELU (smooth — no ReLU kinks under
    // the FD probe) + softmax-xent, gradients of sampled master-weight
    // coordinates vs central differences of the whole quantized forward.
    let session = session();
    let (batch, hidden) = (8, 8);
    for policy in [PrecisionPolicy::fp32(), PrecisionPolicy::fp16()] {
        // Deeper chain ⇒ staircase noise from every quantization point
        // compounds; widen the FD step and the floors accordingly.
        let (h, atol, rtol) = match policy.fwd.width() {
            32 => (1e-3, 5e-4, 2e-2),
            _ => (3e-2, 2e-2, 0.2),
        };
        let mut rng = Rng::new(21);
        let mut model = Mlp::new(IN_DIM, hidden, OUT_DIM, 3, Activation::Gelu, &mut rng);
        let data = Dataset::spiral(20, 3);
        let (x, labels) = data.ordered_batch(0, batch);
        let loss_of = |model: &Mlp| -> f64 {
            let mut ctx = GemmCtx::new(&session, policy.acc);
            let logits = model.forward(&mut ctx, &policy, &x, batch, None).expect("forward");
            model.loss.forward(&logits, &labels, None).expect("loss")
        };
        // Analytic gradients (scale 1.0).
        {
            let mut ctx = GemmCtx::new(&session, policy.acc);
            let mut tape = Tape::new();
            let logits = model.forward(&mut ctx, &policy, &x, batch, Some(&mut tape)).expect("fwd");
            model.loss.forward(&logits, &labels, Some(&mut tape)).expect("loss");
            let g0 = model.loss.backward(&labels, 1.0, &mut tape).expect("loss bwd");
            model.backward(&mut ctx, &policy, &g0, batch, &mut tape).expect("bwd");
        }
        let mut rng_pick = Rng::new(8);
        for li in 0..model.layers.len() {
            for _ in 0..4 {
                let n = model.layers[li].w.len();
                let i = rng_pick.below(n as u64) as usize;
                let orig = model.layers[li].w[i];
                model.layers[li].w[i] = (orig as f64 + h) as f32;
                let lp = loss_of(&model);
                model.layers[li].w[i] = (orig as f64 - h) as f32;
                let lm = loss_of(&model);
                model.layers[li].w[i] = orig;
                assert_close(
                    (lp - lm) / (2.0 * h),
                    model.layers[li].gw[i] as f64,
                    atol,
                    rtol,
                    &format!("{} layer{li} dW[{i}]", policy.name),
                );
            }
        }
    }
}

// ------------------------------------------------------- loss scaling

#[test]
fn loss_scaler_grows_and_backs_off() {
    let mut s = LossScaler::for_policy(&PrecisionPolicy::hfp8());
    s.growth_interval = 3;
    let s0 = s.scale();
    assert!(s.update(true) && s.update(true));
    assert_eq!(s.scale(), s0);
    assert!(s.update(true));
    assert_eq!(s.scale(), s0 * 2.0, "doubles after growth_interval good steps");
    assert!(!s.update(false), "overflow must skip the step");
    assert_eq!(s.scale(), s0, "halves on overflow");
    assert_eq!(s.overflows, 1);
    // Static policies never move the scale but still skip bad steps.
    let mut f = LossScaler::for_policy(&PrecisionPolicy::fp32());
    assert!(!f.update(false));
    assert_eq!(f.scale(), 1.0);
}

#[test]
fn forced_fp8_overflow_skips_step_and_backs_off() {
    // Drive the scale high enough that the scaled logit gradient
    // overflows FP8 (e5m2 max 57344) on quantization: the step must be
    // skipped (masters untouched), the scale halved, and training must
    // continue cleanly afterwards.
    let session = Session::builder().seed(3).build();
    let mut tr = session.native_trainer(PrecisionPolicy::hfp8()).expect("trainer");
    let huge = (1u64 << 24) as f64;
    tr.set_loss_scale(huge);
    let w_before = tr.model().layers[0].w.clone();
    let rec = tr.step().expect("step");
    assert!(rec.skipped, "overflowed step must be skipped");
    assert!(rec.loss.is_finite(), "forward pass is unaffected by the gradient scale");
    assert_eq!(rec.scale, huge);
    assert_eq!(tr.loss_scale(), huge / 2.0, "scale must back off");
    assert_eq!(tr.skipped_steps(), 1);
    assert_eq!(tr.model().layers[0].w, w_before, "skipped step must not touch the masters");
    // Subsequent (sane-scale) steps apply again.
    tr.set_loss_scale(256.0);
    let rec = tr.step().expect("step");
    assert!(!rec.skipped);
    assert_ne!(tr.model().layers[0].w, w_before, "recovered step must update the masters");
}

// ----------------------------------------------------- routing / misc

#[test]
fn every_training_matmul_is_a_packed_gemm_plan() {
    // The acceptance invariant: 9 GemmPlan executions per step (3
    // forward + 6 backward), and for an expanding-pair policy every
    // single one feeds the batch engine packed — no decode/re-pack, no
    // f64 shortcut.
    let session = Session::builder().seed(11).build();
    for policy in [PrecisionPolicy::hfp8(), PrecisionPolicy::fp8(), PrecisionPolicy::fp16()] {
        let mut tr = session.native_trainer(policy).expect("trainer");
        for _ in 0..3 {
            tr.step().expect("step");
        }
        assert_eq!(tr.gemm_calls(), 3 * 9, "{}: 9 plans per step", policy.name);
        assert_eq!(
            tr.packed_runs(),
            tr.gemm_calls(),
            "{}: every plan must take the packed fast path",
            policy.name
        );
    }
}

#[test]
fn forward_inference_is_bit_identical_to_training_forward() {
    // The no-tape inference entry point (the serving hot path) must
    // produce bit-for-bit the logits the training-path forward computes
    // — with a tape (a training step's forward) and without one.
    let session = session();
    for policy in [PrecisionPolicy::hfp8(), PrecisionPolicy::fp32()] {
        let mut tr = session.native_trainer(policy).expect("trainer");
        tr.train(3, 0).expect("train");
        let mut rng = Rng::new(5);
        let batch = 16;
        let x: Vec<f64> = (0..batch * IN_DIM)
            .map(|i| if i % IN_DIM < 4 { rng.gaussian() * 0.5 } else { 0.0 })
            .collect();
        let model = tr.model().clone();
        let mut ctx = GemmCtx::new(&session, policy.acc);
        let inference = model.forward_inference(&mut ctx, &policy, &x, batch).expect("inference");
        let mut ctx2 = GemmCtx::new(&session, policy.acc);
        let no_tape = model.forward(&mut ctx2, &policy, &x, batch, None).expect("forward");
        let mut ctx3 = GemmCtx::new(&session, policy.acc);
        let mut tape = Tape::new();
        let taped =
            model.forward(&mut ctx3, &policy, &x, batch, Some(&mut tape)).expect("forward");
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&inference), bits(&no_tape), "{}: tape=None path", policy.name);
        assert_eq!(bits(&inference), bits(&taped), "{}: taped training path", policy.name);
        assert_eq!(ctx.calls, ctx3.calls, "same number of GEMM plans either way");
        assert!(!tape.is_empty(), "the taped pass must have recorded activations");
    }
}

#[test]
fn training_is_bit_deterministic() {
    let mk = || {
        let session = Session::builder().seed(42).build();
        let mut tr = session.native_trainer(PrecisionPolicy::hfp8()).expect("trainer");
        tr.train(10, 0).expect("train");
        tr
    };
    let (a, b) = (mk(), mk());
    for (ra, rb) in a.history.iter().zip(&b.history) {
        assert_eq!(ra.loss.to_bits(), rb.loss.to_bits());
        assert_eq!(ra.skipped, rb.skipped);
    }
    for (la, lb) in a.model().layers.iter().zip(&b.model().layers) {
        assert_eq!(la.w, lb.w);
        assert_eq!(la.b, lb.b);
    }
}

#[test]
fn tape_enforces_pop_order_and_kind() {
    let session = session();
    let mut tape = Tape::new();
    tape.push_host(vec![1.0, 2.0]);
    let err = tape.pop_mf().unwrap_err();
    assert!(err.to_string().contains("tape order violation"), "{err}");
    assert!(tape.is_empty());
    let err = tape.pop_host().unwrap_err();
    assert!(err.to_string().contains("tape underflow"), "{err}");
    let t = session.tensor(&[1.0; 64], 8, 8, crate::formats::FP8).expect("tensor");
    tape.push_mf(t);
    let err = tape.pop_host().unwrap_err();
    assert!(err.to_string().contains("expected a host slot"), "{err}");
}

#[test]
fn relu_backward_is_an_exact_mask() {
    let session = session();
    let acc = crate::formats::FP16;
    let x = [-2.0, -0.5, 0.0, 0.25, 1.5, -1.0, 3.0, 0.125];
    let g = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
    let mut tape = Tape::new();
    let y = Activation::Relu.forward(&session, acc, &x, 2, 4, Some(&mut tape)).unwrap();
    assert_eq!(y, vec![0.0, 0.0, 0.0, 0.25, 1.5, 0.0, 3.0, 0.125]);
    let dx = Activation::Relu.backward(&g, &mut tape).unwrap();
    assert_eq!(dx, vec![0.0, 0.0, 0.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
}

#[test]
fn optimizers_descend_a_quadratic() {
    // Sanity on the update rules: minimize ½‖w‖² (gradient = w).
    for spec in [OptimSpec::sgd(0.1), OptimSpec::adam(0.1)] {
        let mut w = vec![1.0f32, -2.0, 0.5, 3.0];
        let mut opt = Optim::new(spec);
        for _ in 0..200 {
            let grad: Vec<f32> = w.clone();
            let mut params = [ParamMut { value: w.as_mut_slice(), grad: grad.as_slice() }];
            opt.step(&mut params).expect("step");
        }
        let norm: f32 = w.iter().map(|v| v * v).sum();
        assert!(norm < 1e-2, "{spec:?} failed to descend: {w:?}");
    }
}

#[test]
fn hfp8_loss_decreases_quickly() {
    // Wiring smoke (the full convergence gate lives in the integration
    // suite): 120 HFP8 steps must cut the loss substantially.
    let session = Session::builder().seed(42).build();
    let mut tr = session.native_trainer(PrecisionPolicy::hfp8()).expect("trainer");
    let first = tr.step().expect("step").loss;
    tr.train(119, 0).expect("train");
    let last = tr.recent_loss(10);
    assert!(first.is_finite() && last.is_finite());
    assert!(last < first * 0.75, "loss did not drop: {first} -> {last}");
}

#[test]
fn policy_validation_rejects_bad_pairs() {
    let bad = PrecisionPolicy {
        name: "bad",
        fwd: crate::formats::FP8,
        bwd: crate::formats::FP8,
        acc: crate::formats::FP32, // FP8→FP32 is not a Table I pair
        init_loss_scale: 1.0,
        dynamic_loss_scale: false,
        stochastic: false,
        scaled: false,
    };
    let err = bad.validate().unwrap_err();
    assert!(err.to_string().contains("neither a Table I expanding pair"), "{err}");
    for p in PrecisionPolicy::presets() {
        p.validate().unwrap_or_else(|e| panic!("preset {} invalid: {e}", p.name));
    }
}

// --------------------------------------- executor / plan-instance reuse

#[test]
fn trainer_reuses_compiled_plan_instances_across_steps() {
    // The three-layer MLP runs nine distinct GEMM shapes per step
    // (3 forward + 6 backward). The persistent GemmCtx must compile
    // each exactly once; every later execution — including accuracy
    // evaluation, whose forward shapes coincide — is a cache hit.
    let session = Session::builder().seed(5).build();
    let mut tr = session.native_trainer(PrecisionPolicy::hfp8()).expect("trainer");
    for _ in 0..4 {
        tr.step().expect("step");
    }
    assert_eq!(tr.plan_builds(), 9, "one instance per distinct GEMM shape");
    assert_eq!(tr.gemm_calls(), 4 * 9);
    assert_eq!(tr.plan_reuses(), tr.gemm_calls() - tr.plan_builds());
    assert_eq!(tr.packed_runs(), tr.gemm_calls(), "hfp8 must stay on the packed route");
    let builds_before_eval = tr.plan_builds();
    tr.accuracy().expect("accuracy");
    assert_eq!(tr.plan_builds(), builds_before_eval, "evaluation reuses the forward instances");
    assert!(tr.plan_reuses() > tr.gemm_calls() / 2);
}

#[test]
fn training_is_bit_identical_across_dispatch_backends() {
    // The differential suite's nn leg: a short training run (plus an
    // accuracy pass) on the pooled executor, the legacy scoped-thread
    // backend and the serial path must agree to the last bit — loss
    // trajectory and final master weights.
    use crate::util::parallel::{with_dispatch, Dispatch};
    let run = |mode: Dispatch| {
        with_dispatch(mode, || {
            let session = Session::builder().seed(9).build();
            let mut tr = session.native_trainer(PrecisionPolicy::hfp8()).expect("trainer");
            for _ in 0..3 {
                tr.step().expect("step");
            }
            let acc = tr.accuracy().expect("accuracy");
            let losses: Vec<u64> = tr.history.iter().map(|r| r.loss.to_bits()).collect();
            let w0: Vec<u32> = tr.model().layers[0].w.iter().map(|v| v.to_bits()).collect();
            (losses, w0, acc.to_bits())
        })
    };
    let pooled = run(Dispatch::Pool);
    assert_eq!(pooled, run(Dispatch::Scoped), "pool vs legacy scoped threads diverged");
    assert_eq!(pooled, run(Dispatch::Serial), "pool vs serial diverged");
}

#[test]
fn persistent_trainer_state_matches_per_call_engine() {
    // The trainer's persistent ctx/tape/arena against a hand-rolled
    // step loop that rebuilds a fresh GemmCtx and Tape every iteration
    // (the pre-executor behaviour): identical losses, identical
    // weights. Reuse is capacity, never state.
    let policy = PrecisionPolicy::hfp8();
    let session = Session::builder().seed(12).build();
    let mut tr = session.native_trainer(policy).expect("trainer");
    for _ in 0..3 {
        tr.step().expect("step");
    }
    // Reference loop: mirror TrainPlan::trainer + NativeTrainer::step
    // with per-call contexts.
    let data = Dataset::spiral(300, session.seed() ^ 0xD47A);
    let mut init_rng = session.rng();
    let mut model = Mlp::new(IN_DIM, 32, OUT_DIM, data.classes, Activation::Relu, &mut init_rng);
    let mut optim = Optim::new(OptimSpec::adam(4e-3));
    let mut scaler = LossScaler::for_policy(&policy);
    let mut rng = Rng::new(session.seed() ^ 0x5339);
    let mut losses = Vec::new();
    for _ in 0..3 {
        let (x, labels) = data.batch(64, &mut rng);
        let scale = scaler.scale();
        let mut ctx = GemmCtx::new(&session, policy.acc);
        let mut tape = Tape::new();
        let logits = model.forward(&mut ctx, &policy, &x, 64, Some(&mut tape)).expect("fwd");
        let loss = model.loss.forward(&logits, &labels, Some(&mut tape)).expect("loss");
        let g0 = model.loss.backward(&labels, scale, &mut tape).expect("g0");
        model.backward(&mut ctx, &policy, &g0, 64, &mut tape).expect("bwd");
        let finite = loss.is_finite() && model.grads_finite();
        if scaler.update(finite) {
            model.scale_grads((1.0 / scale) as f32);
            let mut params = model.params_mut();
            optim.step(&mut params).expect("optim");
        }
        losses.push(loss.to_bits());
    }
    let got: Vec<u64> = tr.history.iter().map(|r| r.loss.to_bits()).collect();
    assert_eq!(got, losses, "persistent executor state changed the numerics");
    for (i, (l, r)) in tr.model().layers.iter().zip(&model.layers).enumerate() {
        assert_eq!(
            l.w.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            r.w.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "layer {i} weights diverged"
        );
    }
}

#[test]
fn tape_arena_recycles_buffers() {
    // After one full step the tape pools hold recycled storage, and a
    // cleared tape sweeps leftover slots into the pools.
    let session = Session::builder().seed(3).build();
    let mut tr = session.native_trainer(PrecisionPolicy::fp32()).expect("trainer");
    tr.step().expect("step");
    tr.step().expect("step");
    let mut tape = Tape::new();
    tape.push_host(vec![1.0, 2.0]);
    tape.push_mf(session.tensor(&[0.5; 8], 1, 8, crate::formats::FP16).expect("tensor"));
    assert_eq!(tape.len(), 2);
    tape.clear();
    assert!(tape.is_empty());
    let (words, host) = tape.pooled();
    assert_eq!((words, host), (1, 1), "clear must sweep slots into the arena pools");
    // grab/recycle round-trips capacity.
    let buf = tape.grab_host();
    assert_eq!(tape.pooled().1, 0);
    tape.recycle_host(buf);
    assert_eq!(tape.pooled().1, 1);
}
