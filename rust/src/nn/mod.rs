//! Native mixed-precision training: a pure-Rust, offline-runnable NN
//! subsystem whose **every matmul routes through the typed
//! [`crate::api::GemmPlan`] minifloat path** — the same ExSdotp
//! accumulation order the simulated cluster executes, bit-identical to
//! it, with no f64 shortcut anywhere on the compute path.
//!
//! The paper's workload is low-precision NN *training*, but the
//! artifact-backed path ([`crate::coordinator`] → PJRT) cannot execute
//! offline. This subsystem closes that gap natively, reproducing the
//! mixed-precision recipes of Wang et al. 2018 ("Training DNNs with
//! 8-bit Floating Point Numbers") and Noune et al. 2022 ("8-bit
//! Numerical Formats for DNNs") on top of the ExSdotp datapath:
//!
//! * minifloat GEMMs with **wider ExSdotp accumulation** (FP8/FP8alt
//!   operands into FP16, FP16/FP16alt into FP32 — Table I's expanding
//!   pairs, alt variants via the CSR alt bits);
//! * **FP32 master weights** in the optimizer, cast down to the compute
//!   format at every step ([`optim`]);
//! * **dynamic loss scaling** with overflow backoff for the narrow
//!   backward formats ([`policy::LossScaler`]);
//! * per-tensor [`policy::PrecisionPolicy`] — e.g. the HFP8 recipe:
//!   FP8alt (e4m3) forward, FP8 (e5m2) backward, FP16 accumulation.
//!
//! ## Module map
//!
//! | module | role |
//! |---|---|
//! | [`policy`] | precision policies + dynamic loss scaling |
//! | [`engine`] | the GEMM router: caches compiled `PlanInstance`s, counts calls/reuses |
//! | [`tape`]   | reverse-mode tape over `MfTensor` activations + the step's buffer arena |
//! | [`layer`]  | Linear, ReLU/GELU, softmax-cross-entropy (fwd + bwd) |
//! | [`optim`]  | SGD with momentum, Adam — FP32 master weights |
//! | [`data`]   | synthetic datasets (spiral, rings), lane-padded |
//! | [`train`]  | [`train::NativeTrainer`] — the step loop |
//!
//! ## Layering
//!
//! `nn` sits **above** the numerics stack and calls only the [`crate::api`]
//! surface (`Session` / `MfTensor` / `GemmPlan`) and, through it, the
//! [`crate::batch`] engine. It must never call `kernels`, `cluster`,
//! or `core` directly — the typed plan layer is where problems are
//! validated and where the functional/cycle-accurate engines stay
//! interchangeable. The `api::train` module (`Session::train()` /
//! `Session::native_trainer`) is the sanctioned front door that
//! constructs the types in here.
//!
//! ```
//! use minifloat_nn::prelude::*;
//!
//! # fn main() -> minifloat_nn::util::error::Result<()> {
//! let session = Session::builder().seed(7).build();
//! let mut tr = session.native_trainer(PrecisionPolicy::hfp8())?;
//! tr.train(10, 0)?; // 10 HFP8 steps on the spiral task, all GEMMs through GemmPlan
//! assert_eq!(tr.gemm_calls(), 10 * 9); // 3 forward + 6 backward plans per step
//! # Ok(())
//! # }
//! ```

pub mod data;
pub mod engine;
pub mod layer;
pub mod optim;
pub mod policy;
pub mod tape;
pub mod train;

#[cfg(test)]
mod tests;

pub use data::{DataSpec, Dataset};
pub use engine::GemmCtx;
pub use layer::{Activation, Linear, Mlp, SoftmaxXent};
pub use optim::{Optim, OptimSpec, ParamMut};
pub use policy::{LossScaler, PrecisionPolicy};
pub use tape::Tape;
pub use train::{NativeTrainer, StepRecord};
