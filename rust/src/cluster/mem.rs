//! TCDM + global memory + per-cycle bank arbitration (the cluster's
//! `Bus` implementation).

use super::dma::DmaEngine;
use super::{ClusterCfg, MemStats, GLOBAL_BASE, TCDM_BASE};
use crate::core::Bus;

/// Shared memory fabric.
pub struct ClusterMem {
    /// Scratchpad bytes.
    pub tcdm: Vec<u8>,
    /// Global (bulk) memory bytes.
    pub global: Vec<u8>,
    /// DMA engine.
    pub dma: DmaEngine,
    /// Fabric statistics.
    pub stats: MemStats,
    cfg: ClusterCfg,
    /// Which requester (if any) holds each bank this cycle.
    bank_taken: Vec<bool>,
}

impl ClusterMem {
    /// Allocate the fabric.
    pub fn new(cfg: ClusterCfg) -> Self {
        ClusterMem {
            tcdm: vec![0; cfg.tcdm_size as usize],
            global: vec![0; cfg.global_size as usize],
            dma: DmaEngine::default(),
            stats: MemStats::default(),
            cfg,
            bank_taken: vec![false; cfg.banks as usize],
        }
    }

    /// Reset per-cycle arbitration state.
    pub fn begin_cycle(&mut self, _cycle: u64) {
        self.bank_taken.fill(false);
    }

    fn bank_of(&self, addr: u64) -> Option<usize> {
        if (TCDM_BASE..TCDM_BASE + self.cfg.tcdm_size as u64).contains(&addr) {
            Some((((addr - TCDM_BASE) >> 3) % self.cfg.banks as u64) as usize)
        } else {
            None
        }
    }

    /// Raw byte write (host/DMA path, no arbitration).
    pub fn store_bytes(&mut self, addr: u64, bytes: &[u8]) {
        let (mem, off) = self.region_mut(addr);
        mem[off..off + bytes.len()].copy_from_slice(bytes);
    }

    /// Raw byte read (host/DMA path, no arbitration).
    pub fn load_bytes(&self, addr: u64, len: usize) -> Vec<u8> {
        let (mem, off) = self.region(addr);
        mem[off..off + len].to_vec()
    }

    fn region(&self, addr: u64) -> (&[u8], usize) {
        if addr >= GLOBAL_BASE {
            (&self.global, (addr - GLOBAL_BASE) as usize)
        } else {
            assert!(addr >= TCDM_BASE, "access below TCDM base: {addr:#x}");
            (&self.tcdm, (addr - TCDM_BASE) as usize)
        }
    }

    fn region_mut(&mut self, addr: u64) -> (&mut Vec<u8>, usize) {
        if addr >= GLOBAL_BASE {
            (&mut self.global, (addr - GLOBAL_BASE) as usize)
        } else {
            assert!(addr >= TCDM_BASE, "access below TCDM base: {addr:#x}");
            (&mut self.tcdm, (addr - TCDM_BASE) as usize)
        }
    }
}

impl Bus for ClusterMem {
    fn request(&mut self, _requester: u32, addr: u64, _write: bool) -> bool {
        match self.bank_of(addr) {
            Some(b) => {
                if self.bank_taken[b] {
                    self.stats.conflicts += 1;
                    false
                } else {
                    self.bank_taken[b] = true;
                    self.stats.grants += 1;
                    true
                }
            }
            // Global memory: un-arbitrated convenience port.
            None => true,
        }
    }

    fn read64(&mut self, addr: u64) -> u64 {
        let b = self.load_bytes(addr & !7, 8);
        u64::from_le_bytes(b.try_into().unwrap())
    }

    fn write_n(&mut self, addr: u64, value: u64, bytes: u32) {
        self.store_bytes(addr, &value.to_le_bytes()[..bytes as usize]);
    }

    fn dma_src(&mut self, addr: u64) {
        self.dma.src = addr;
    }

    fn dma_dst(&mut self, addr: u64) {
        self.dma.dst = addr;
    }

    fn dma_copy(&mut self, len: u64) -> u32 {
        self.dma.enqueue(len)
    }

    fn dma_busy(&self) -> u32 {
        self.dma.outstanding()
    }
}
