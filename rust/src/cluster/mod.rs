//! The compute cluster (Fig. 6): eight MiniFloat-NN PEs + one DMA core
//! sharing a 32-bank scratchpad (TCDM) and an instruction cache.
//!
//! ## Memory system
//!
//! * **TCDM**: 128 kB software-managed scratchpad, 32 × 64-bit banks,
//!   word-interleaved (`bank = (addr >> 3) % 32`). Each bank serves one
//!   access per cycle; cores whose accesses collide retry next cycle
//!   (round-robin priority rotates every cycle). SSR ports, FP
//!   loads/stores and integer loads/stores all arbitrate here.
//! * **Global memory**: bulk storage reachable by the DMA engine (and,
//!   for convenience, by direct accesses at a fixed latency-free port —
//!   benchmarks keep all hot data in TCDM like the paper, which only
//!   evaluates "GEMM sizes for which all the data fits in the local
//!   memory").
//! * **DMA**: a queue of 1-D transfers processed at 64 B/cycle,
//!   modelling the dedicated mover core's bandwidth without stealing
//!   TCDM bank slots (simplification; the paper's benchmarks don't
//!   overlap DMA with compute either).
//!
//! The instruction cache is assumed warm (the FREP buffer absorbs the
//! inner-loop fetch pressure, which is its purpose).

pub mod dma;
pub mod mem;
#[cfg(test)]
mod tests;

use crate::core::{Core, CoreStats};
use crate::isa::Instr;
use mem::ClusterMem;

/// Cluster configuration (defaults follow the paper).
#[derive(Clone, Copy, Debug)]
pub struct ClusterCfg {
    /// Number of compute PEs (8 in the paper).
    pub n_cores: u32,
    /// TCDM bytes (128 kB in the paper).
    pub tcdm_size: u32,
    /// TCDM banks (32 in the paper).
    pub banks: u32,
    /// Global memory bytes.
    pub global_size: u32,
}

impl Default for ClusterCfg {
    fn default() -> Self {
        Self { n_cores: 8, tcdm_size: 128 * 1024, banks: 32, global_size: 16 * 1024 * 1024 }
    }
}

/// Byte address where the TCDM window starts.
pub const TCDM_BASE: u64 = 0x0001_0000;
/// Byte address where global memory starts.
pub const GLOBAL_BASE: u64 = 0x8000_0000;

/// The cluster: cores + shared memory fabric.
pub struct Cluster {
    /// Compute cores (index = hart id).
    pub cores: Vec<Core>,
    /// Shared memory + arbiter + DMA (the `Bus` implementation).
    pub mem: ClusterMem,
    cycle: u64,
}

impl Cluster {
    /// Build a cluster where every core runs `program(core_id)`.
    pub fn new(cfg: ClusterCfg, program: impl Fn(u32) -> Vec<Instr>) -> Self {
        let cores = (0..cfg.n_cores).map(|i| Core::new(i, program(i))).collect();
        Cluster { cores, mem: ClusterMem::new(cfg), cycle: 0 }
    }

    /// Build a cluster running one shared program (cores branch on
    /// `mhartid`, like real SPMD kernels).
    pub fn new_spmd(cfg: ClusterCfg, program: Vec<Instr>) -> Self {
        Self::new(cfg, |_| program.clone())
    }

    /// Advance one cycle.
    pub fn tick(&mut self) {
        self.cycle += 1;
        self.mem.begin_cycle(self.cycle);
        self.mem.dma.tick(&mut self.mem.tcdm, &mut self.mem.global);
        // Rotate service order for arbitration fairness.
        let n = self.cores.len();
        for k in 0..n {
            let i = (k + self.cycle as usize) % n;
            self.cores[i].tick(&mut self.mem);
        }
        // Hardware barrier: release once every live core has arrived.
        let mut any_waiting = false;
        let mut all_ready = true;
        for c in &self.cores {
            if c.at_barrier {
                any_waiting = true;
                if !c.barrier_ready() {
                    all_ready = false;
                }
            } else if !c.done() {
                all_ready = false;
            }
        }
        if any_waiting && all_ready {
            for c in &mut self.cores {
                c.release_barrier();
            }
        }
    }

    /// Run until every core is done (or `max_cycles`). Returns the cycle
    /// count.
    pub fn run(&mut self, max_cycles: u64) -> u64 {
        while self.cycle < max_cycles {
            if self.cores.iter().all(|c| c.done()) {
                break;
            }
            self.tick();
        }
        assert!(
            self.cores.iter().all(|c| c.done()),
            "cluster did not finish within {max_cycles} cycles (deadlock or runaway kernel?)"
        );
        self.cycle
    }

    /// Total cycles elapsed.
    pub fn cycles(&self) -> u64 {
        self.cycle
    }

    /// Aggregate core statistics.
    pub fn stats(&self) -> CoreStats {
        let mut agg = CoreStats::default();
        for c in &self.cores {
            let s = &c.stats;
            agg.cycles = agg.cycles.max(s.cycles);
            agg.int_retired += s.int_retired;
            agg.fp_issued += s.fp_issued;
            agg.flops += s.flops;
            agg.fp_idle += s.fp_idle;
            agg.stall_raw += s.stall_raw;
            agg.stall_bank += s.stall_bank;
            agg.stall_fifo_full += s.stall_fifo_full;
            agg.ssr_elems += s.ssr_elems;
            agg.ops_addmul += s.ops_addmul;
            agg.ops_sdotp += s.ops_sdotp;
            agg.ops_cast += s.ops_cast;
            agg.ops_comp += s.ops_comp;
            agg.ops_fmem += s.ops_fmem;
        }
        agg
    }

    /// Achieved FLOP/cycle across the cluster (Fig. 8's metric).
    pub fn flop_per_cycle(&self) -> f64 {
        self.stats().flops as f64 / self.cycle.max(1) as f64
    }

    // --------------------------- host-side data access (no timing cost)

    /// Write bytes into memory (setup; bypasses timing).
    pub fn store_bytes(&mut self, addr: u64, bytes: &[u8]) {
        self.mem.store_bytes(addr, bytes);
    }

    /// Read bytes from memory (verification; bypasses timing).
    pub fn load_bytes(&self, addr: u64, len: usize) -> Vec<u8> {
        self.mem.load_bytes(addr, len)
    }

    /// Store a slice of `u64` words.
    pub fn store_words(&mut self, addr: u64, words: &[u64]) {
        for (i, w) in words.iter().enumerate() {
            self.mem.store_bytes(addr + i as u64 * 8, &w.to_le_bytes());
        }
    }

    /// Load `n` 64-bit words.
    pub fn load_words(&self, addr: u64, n: usize) -> Vec<u64> {
        (0..n)
            .map(|i| {
                let b = self.mem.load_bytes(addr + i as u64 * 8, 8);
                u64::from_le_bytes(b.try_into().unwrap())
            })
            .collect()
    }
}

/// Bank-conflict and DMA counters for the fabric.
#[derive(Clone, Copy, Debug, Default)]
pub struct MemStats {
    /// Granted TCDM accesses.
    pub grants: u64,
    /// Rejected (conflicting) TCDM access attempts.
    pub conflicts: u64,
    /// Bytes moved by the DMA engine.
    pub dma_bytes: u64,
}
