//! Integration tests: programs through the full core + cluster stack.

use super::{Cluster, ClusterCfg, TCDM_BASE};
use crate::formats::{FP16, FP32};
use crate::isa::csr::addr as csr;
use crate::isa::instr::regs::*;
use crate::isa::instr::{Instr, OpWidth, ScalarFmt};
use crate::softfloat::{from_f64, to_f64};

fn one_core_cfg() -> ClusterCfg {
    ClusterCfg { n_cores: 1, ..ClusterCfg::default() }
}

/// Emit `li reg, value` (lui+addi or addi).
fn li(prog: &mut Vec<Instr>, rd: crate::isa::Reg, value: i64) {
    let v = value as i32;
    if (-2048..2048).contains(&v) {
        prog.push(Instr::Addi { rd, rs1: ZERO, imm: v });
    } else {
        let hi = (v + 0x800) >> 12;
        let lo = v - (hi << 12);
        prog.push(Instr::Lui { rd, imm: hi });
        if lo != 0 {
            prog.push(Instr::Addi { rd, rs1: rd, imm: lo });
        }
    }
}

#[test]
fn integer_loop_counts() {
    // x5 = sum of 1..=10 via a branch loop.
    let mut p = vec![];
    li(&mut p, x(5), 0); // acc
    li(&mut p, x(6), 1); // i
    li(&mut p, x(7), 11); // bound
    p.push(Instr::Add { rd: x(5), rs1: x(5), rs2: x(6) });
    p.push(Instr::Addi { rd: x(6), rs1: x(6), imm: 1 });
    p.push(Instr::Bne { rs1: x(6), rs2: x(7), offset: -2 });
    p.push(Instr::Halt);
    let mut cl = Cluster::new_spmd(one_core_cfg(), p);
    cl.run(10_000);
    assert_eq!(cl.cores[0].regs[5], 55);
    // Taken branches cost 2 cycles; sanity bound on the cycle count.
    assert!(cl.cycles() > 30 && cl.cycles() < 100, "cycles={}", cl.cycles());
}

#[test]
fn fp_load_compute_store_roundtrip() {
    // f3 = f1 * f2 + f3 over FP64 memory operands; store back.
    let a = TCDM_BASE as i64;
    let mut p = vec![];
    li(&mut p, x(10), a);
    p.push(Instr::FLoad { fmt: ScalarFmt::D, fd: f(1), rs1: x(10), imm: 0 });
    p.push(Instr::FLoad { fmt: ScalarFmt::D, fd: f(2), rs1: x(10), imm: 8 });
    p.push(Instr::FLoad { fmt: ScalarFmt::D, fd: f(3), rs1: x(10), imm: 16 });
    p.push(Instr::Fmadd { fmt: ScalarFmt::D, fd: f(3), fs1: f(1), fs2: f(2), fs3: f(3) });
    p.push(Instr::FStore { fmt: ScalarFmt::D, rs1: x(10), fs: f(3), imm: 24 });
    p.push(Instr::Halt);
    let mut cl = Cluster::new_spmd(one_core_cfg(), p);
    cl.store_words(TCDM_BASE, &[(2.5f64).to_bits(), (4.0f64).to_bits(), (1.0f64).to_bits()]);
    cl.run(10_000);
    let out = cl.load_words(TCDM_BASE + 24, 1)[0];
    assert_eq!(f64::from_bits(out), 2.5 * 4.0 + 1.0);
}

#[test]
fn ssr_frep_dot_product_fp64() {
    // Classic Snitch idiom: ft0·ft1 dot product with FREP, no explicit
    // loads in the loop.
    let n = 64u32;
    let a_base = TCDM_BASE;
    let b_base = TCDM_BASE + 1024;
    let mut p = vec![];
    // SSR0: A[0..n], 1-D, stride 8.
    li(&mut p, x(5), n as i64);
    p.push(Instr::ScfgWi { rs1: x(5), cfg: 0 }); // bound0 (streamer 0)
    li(&mut p, x(5), 8);
    p.push(Instr::ScfgWi { rs1: x(5), cfg: 8 }); // stride0
    li(&mut p, x(5), a_base as i64);
    p.push(Instr::ScfgWi { rs1: x(5), cfg: 16 }); // rptr, 1-D
    // SSR1: B.
    li(&mut p, x(5), n as i64);
    p.push(Instr::ScfgWi { rs1: x(5), cfg: 32 });
    li(&mut p, x(5), 8);
    p.push(Instr::ScfgWi { rs1: x(5), cfg: 40 });
    li(&mut p, x(5), b_base as i64);
    p.push(Instr::ScfgWi { rs1: x(5), cfg: 48 });
    // acc = 0; enable SSRs; frep n-1 over one fmadd (body runs n times).
    p.push(Instr::FmvWX { fd: f(3), rs1: ZERO });
    p.push(Instr::Csrrwi { rd: ZERO, csr: csr::SSR, imm: 1 });
    li(&mut p, x(6), n as i64 - 1);
    p.push(Instr::FrepO { rep: x(6), n_inst: 1 });
    p.push(Instr::Fmadd { fmt: ScalarFmt::D, fd: f(3), fs1: FT0, fs2: FT1, fs3: f(3) });
    p.push(Instr::Csrrwi { rd: ZERO, csr: csr::SSR, imm: 0 });
    li(&mut p, x(10), (TCDM_BASE + 2048) as i64);
    p.push(Instr::FStore { fmt: ScalarFmt::D, rs1: x(10), fs: f(3), imm: 0 });
    p.push(Instr::Halt);

    let mut cl = Cluster::new_spmd(one_core_cfg(), p);
    let mut expect = 0f64;
    for i in 0..n as u64 {
        let av = (i as f64) * 0.5;
        let bv = 2.0 + i as f64;
        expect += av * bv;
        cl.store_words(a_base + i * 8, &[av.to_bits()]);
        cl.store_words(b_base + i * 8, &[bv.to_bits()]);
    }
    let cycles = cl.run(100_000);
    let got = f64::from_bits(cl.load_words(TCDM_BASE + 2048, 1)[0]);
    assert_eq!(got, expect, "dot product numerics");
    // The FMA chain is serialized by the accumulator RAW dependency
    // (ADDMUL latency 3) → ≥ 3 cycles per element; SSR+FREP keep it well
    // below a load/compute/branch loop (~8+ per element).
    assert!(cycles > 3 * n as u64 && cycles < 6 * n as u64, "cycles={cycles}");
    assert_eq!(cl.cores[0].stats.ssr_elems, 2 * n as u64);
}

#[test]
fn exsdotp_pipeline_full_stack() {
    // SIMD exsdotp 16→32 through SSRs: one instruction consumes 4 FP16
    // pairs and updates 2 FP32 accumulators.
    let n_words = 8u64; // 8 × (4 FP16) = 32 pairs
    let a_base = TCDM_BASE;
    let b_base = TCDM_BASE + 512;
    let mut p = vec![];
    for (s, base) in [(0u16, a_base), (1, b_base)] {
        li(&mut p, x(5), n_words as i64);
        p.push(Instr::ScfgWi { rs1: x(5), cfg: s * 32 });
        li(&mut p, x(5), 8);
        p.push(Instr::ScfgWi { rs1: x(5), cfg: s * 32 + 8 });
        li(&mut p, x(5), base as i64);
        p.push(Instr::ScfgWi { rs1: x(5), cfg: s * 32 + 16 });
    }
    p.push(Instr::FmvWX { fd: f(3), rs1: ZERO }); // acc = [0.0f32; 2]
    p.push(Instr::Csrrwi { rd: ZERO, csr: csr::SSR, imm: 1 });
    li(&mut p, x(6), n_words as i64 - 1);
    p.push(Instr::FrepO { rep: x(6), n_inst: 1 });
    p.push(Instr::ExSdotp { w: OpWidth::HtoS, fd: f(3), fs1: FT0, fs2: FT1 });
    p.push(Instr::Csrrwi { rd: ZERO, csr: csr::SSR, imm: 0 });
    li(&mut p, x(10), (TCDM_BASE + 1024) as i64);
    p.push(Instr::FStore { fmt: ScalarFmt::D, rs1: x(10), fs: f(3), imm: 0 });
    p.push(Instr::Halt);

    let mut cl = Cluster::new_spmd(one_core_cfg(), p);
    // Fill A and B with small exact values; track the expected FP32 sums
    // (exact in f64, and exactly representable: products of halves).
    let mut lane0 = 0f64;
    let mut lane1 = 0f64;
    for w in 0..n_words {
        let mut aw = 0u64;
        let mut bw = 0u64;
        for l in 0..4u64 {
            let av = ((w * 4 + l) % 7) as f64 * 0.5;
            let bv = ((w * 4 + l) % 5) as f64 * 0.25;
            aw |= from_f64(av, FP16, crate::softfloat::RoundingMode::Rne) << (l * 16);
            bw |= from_f64(bv, FP16, crate::softfloat::RoundingMode::Rne) << (l * 16);
            if l < 2 {
                lane0 += av * bv;
            } else {
                lane1 += av * bv;
            }
        }
        cl.store_words(a_base + w * 8, &[aw]);
        cl.store_words(b_base + w * 8, &[bw]);
    }
    cl.run(100_000);
    let out = cl.load_words(TCDM_BASE + 1024, 1)[0];
    let out0 = to_f64(out & 0xffff_ffff, FP32);
    let out1 = to_f64(out >> 32, FP32);
    assert_eq!(out0, lane0);
    assert_eq!(out1, lane1);
    // 4 FLOP/lane-pair × 2 units × 8 instructions.
    assert_eq!(cl.cores[0].stats.flops, 8 * 8);
}

#[test]
fn barrier_synchronizes_cores() {
    // Core 0 writes a flag after a long loop; all cores barrier; then
    // every core reads the flag — all must see it.
    let flag = TCDM_BASE + 4096;
    let make = |id: u32| {
        let mut p = vec![];
        if id == 0 {
            // Busy loop then store flag.
            li(&mut p, x(5), 200);
            p.push(Instr::Addi { rd: x(5), rs1: x(5), imm: -1 });
            p.push(Instr::Bne { rs1: x(5), rs2: ZERO, offset: -1 });
            li(&mut p, x(6), 42);
            li(&mut p, x(7), flag as i64);
            p.push(Instr::Sw { rs1: x(7), rs2: x(6), imm: 0 });
        }
        p.push(Instr::Barrier);
        li(&mut p, x(7), flag as i64);
        p.push(Instr::Lw { rd: x(8), rs1: x(7), imm: 0 });
        p.push(Instr::Halt);
        p
    };
    let mut cl = Cluster::new(ClusterCfg { n_cores: 4, ..ClusterCfg::default() }, make);
    cl.run(100_000);
    for c in &cl.cores {
        assert_eq!(c.regs[8], 42, "core {} missed the flag", c.id);
    }
}

#[test]
fn bank_conflicts_slow_down_colliding_cores() {
    // Unit-stride streams spread across banks (fast even when all cores
    // share a region — the SSR FIFOs phase-shift them apart). A stride
    // of 256 B aliases every access onto ONE bank for all 8 cores: the
    // single bank port serializes the cluster.
    let run = |bank_aliasing: bool| -> u64 {
        let make = move |id: u32| {
            // id·256 keeps every core's whole stream on bank 0 when the
            // stride aliases (256 B = banks × width).
            let base = TCDM_BASE + id as u64 * 256;
            let stride: i64 = if bank_aliasing { 256 } else { 8 };
            let mut p = vec![];
            li(&mut p, x(5), 256);
            p.push(Instr::ScfgWi { rs1: x(5), cfg: 0 });
            li(&mut p, x(5), stride);
            p.push(Instr::ScfgWi { rs1: x(5), cfg: 8 });
            li(&mut p, x(5), base as i64);
            p.push(Instr::ScfgWi { rs1: x(5), cfg: 16 });
            p.push(Instr::FmvWX { fd: f(3), rs1: ZERO });
            p.push(Instr::Csrrwi { rd: ZERO, csr: csr::SSR, imm: 1 });
            li(&mut p, x(6), 255);
            p.push(Instr::FrepO { rep: x(6), n_inst: 1 });
            p.push(Instr::Fadd { fmt: ScalarFmt::D, fd: f(4), fs1: FT0, fs2: f(3) });
            p.push(Instr::Csrrwi { rd: ZERO, csr: csr::SSR, imm: 0 });
            p.push(Instr::Halt);
            p
        };
        let mut cl = Cluster::new(ClusterCfg { n_cores: 8, ..ClusterCfg::default() }, make);
        cl.run(1_000_000)
    };
    let fast = run(false);
    let slow = run(true);
    // Aliasing: 8 cores × 256 elements through one bank port ≈ 2048
    // cycles (fully serialized). Spread: bounded by the FAdd WAW chain
    // (3 cycles/element), not the memory system.
    assert!(slow >= 2048, "aliasing case must serialize on the single bank: {slow}");
    assert!(
        slow > fast * 2,
        "conflicts should dominate the spread case: spread={fast}, aliasing={slow}"
    );
}

#[test]
fn fp16_simd_fmadd_numerics() {
    // 4-lane vectorial FMA through registers.
    let mut p = vec![];
    li(&mut p, x(10), TCDM_BASE as i64);
    p.push(Instr::FLoad { fmt: ScalarFmt::D, fd: f(1), rs1: x(10), imm: 0 });
    p.push(Instr::FLoad { fmt: ScalarFmt::D, fd: f(2), rs1: x(10), imm: 8 });
    p.push(Instr::FmvWX { fd: f(3), rs1: ZERO });
    p.push(Instr::Fmadd { fmt: ScalarFmt::H, fd: f(3), fs1: f(1), fs2: f(2), fs3: f(3) });
    p.push(Instr::FStore { fmt: ScalarFmt::D, rs1: x(10), fs: f(3), imm: 16 });
    p.push(Instr::Halt);
    let mut cl = Cluster::new_spmd(one_core_cfg(), p);
    let rm = crate::softfloat::RoundingMode::Rne;
    let mut aw = 0u64;
    let mut bw = 0u64;
    let vals = [(1.5, 2.0), (0.25, 8.0), (-3.0, 0.5), (10.0, 0.125)];
    for (l, (av, bv)) in vals.iter().enumerate() {
        aw |= from_f64(*av, FP16, rm) << (l * 16);
        bw |= from_f64(*bv, FP16, rm) << (l * 16);
    }
    cl.store_words(TCDM_BASE, &[aw, bw]);
    cl.run(10_000);
    let out = cl.load_words(TCDM_BASE + 16, 1)[0];
    for (l, (av, bv)) in vals.iter().enumerate() {
        let got = to_f64((out >> (l * 16)) & 0xffff, FP16);
        assert_eq!(got, av * bv, "lane {l}");
    }
}

#[test]
fn dma_roundtrip_via_instructions() {
    use super::GLOBAL_BASE;
    let mut p = vec![];
    li(&mut p, x(5), GLOBAL_BASE as i64);
    p.push(Instr::DmSrc { rs1: x(5) });
    li(&mut p, x(6), TCDM_BASE as i64);
    p.push(Instr::DmDst { rs1: x(6) });
    li(&mut p, x(7), 512);
    p.push(Instr::DmCpy { rd: x(8), rs1: x(7) });
    // Wait for completion.
    p.push(Instr::DmStat { rd: x(9) });
    p.push(Instr::Bne { rs1: x(9), rs2: ZERO, offset: -1 });
    p.push(Instr::Halt);
    let mut cl = Cluster::new_spmd(one_core_cfg(), p);
    let data: Vec<u8> = (0..512u32).map(|i| (i % 251) as u8).collect();
    cl.store_bytes(GLOBAL_BASE, &data);
    cl.run(100_000);
    assert_eq!(cl.load_bytes(TCDM_BASE, 512), data);
}

#[test]
fn alt_format_kernel_differs_by_one_csr_write() {
    // §III-E: run the same SIMD FMA twice — once with src_is_alt=0
    // (FP16) and once with src_is_alt=1 (FP16alt). Inputs chosen so the
    // interpretations differ.
    let run = |alt: bool| -> u64 {
        let mut p = vec![];
        li(&mut p, x(10), TCDM_BASE as i64);
        if alt {
            // Set bit 8 of fcsr (src_is_alt). csrrwi imm is 5 bits, so
            // build the value in a register.
            li(&mut p, x(5), 1 << 8);
            p.push(Instr::Csrrw { rd: ZERO, csr: csr::FCSR, rs1: x(5) });
        }
        p.push(Instr::FLoad { fmt: ScalarFmt::D, fd: f(1), rs1: x(10), imm: 0 });
        p.push(Instr::FLoad { fmt: ScalarFmt::D, fd: f(2), rs1: x(10), imm: 8 });
        p.push(Instr::FmvWX { fd: f(3), rs1: ZERO });
        p.push(Instr::Fmadd { fmt: ScalarFmt::H, fd: f(3), fs1: f(1), fs2: f(2), fs3: f(3) });
        p.push(Instr::FStore { fmt: ScalarFmt::D, rs1: x(10), fs: f(3), imm: 16 });
        p.push(Instr::Halt);
        let mut cl = Cluster::new_spmd(one_core_cfg(), p);
        // The same bit pattern means different values in FP16 vs FP16alt.
        cl.store_words(TCDM_BASE, &[0x3c00_3c00_3c00_3c00, 0x4000_4000_4000_4000]);
        cl.run(10_000);
        cl.load_words(TCDM_BASE + 16, 1)[0]
    };
    let std_result = run(false);
    let alt_result = run(true);
    // FP16: 1.0 * 2.0 = 2.0 per lane.
    assert_eq!(to_f64(std_result & 0xffff, FP16), 2.0);
    assert_ne!(std_result, alt_result, "alt bit must change semantics");
}
