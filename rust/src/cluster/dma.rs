//! The cluster DMA engine (the ninth, data-mover core's backend).
//!
//! Transfers are byte copies between global memory and the TCDM (either
//! direction), processed in FIFO order at [`DMA_BYTES_PER_CYCLE`] — the
//! 512-bit-wide mover of the Snitch cluster. Two shapes are supported:
//!
//! * **1-D** ([`DmaEngine::enqueue`]): a contiguous copy of `len` bytes.
//! * **2-D strided** ([`DmaEngine::enqueue_2d`]): `rows` segments of
//!   `row_bytes` each, with independent source and destination strides
//!   between segment starts — the shape a GEMM tile sub-rectangle has
//!   in a larger row-major matrix. The per-cycle budget flows across
//!   row boundaries, so a 2-D transfer costs the same cycles as a 1-D
//!   transfer of the same total size (the real mover's address
//!   generators also keep the 512-bit port saturated across rows).
//!
//! Completion is observable two ways: drain [`DmaEngine::take_completed`]
//! for the ids finished since the last drain (always in FIFO order), or
//! register a hook with [`DmaEngine::set_on_complete`] that fires inside
//! `tick` the cycle a transfer retires — the double-buffering signal the
//! SoC model's ping-pong schedule keys on.

use super::{GLOBAL_BASE, TCDM_BASE};

/// Peak DMA bandwidth (bytes per cycle).
pub const DMA_BYTES_PER_CYCLE: u64 = 64;

/// One queued transfer (1-D is the `rows_left == 1` special case).
#[derive(Clone, Copy, Debug)]
struct Transfer {
    id: u32,
    /// Cursor into the current row.
    src: u64,
    dst: u64,
    /// Bytes left in the current row.
    row_remaining: u64,
    /// Rows left including the current one.
    rows_left: u64,
    /// Full row length (reloaded on row advance).
    row_bytes: u64,
    /// Start-to-start stride between consecutive source rows.
    src_stride: u64,
    /// Start-to-start stride between consecutive destination rows.
    dst_stride: u64,
    /// Base of the current row (cursor reload origin).
    src_row: u64,
    dst_row: u64,
}

impl Transfer {
    fn total_remaining(&self) -> u64 {
        self.row_remaining + (self.rows_left.saturating_sub(1)) * self.row_bytes
    }
}

/// FIFO DMA engine.
#[derive(Default)]
pub struct DmaEngine {
    /// Staged source address (set by `dmsrc`).
    pub src: u64,
    /// Staged destination address (set by `dmdst`).
    pub dst: u64,
    queue: Vec<Transfer>,
    next_id: u32,
    completed: Vec<u32>,
    on_complete: Option<Box<dyn FnMut(u32)>>,
    /// Total bytes moved (stats).
    pub bytes_moved: u64,
}

impl DmaEngine {
    /// Enqueue a 1-D copy of `len` bytes from the staged src to the
    /// staged dst. Returns the transfer id.
    pub fn enqueue(&mut self, len: u64) -> u32 {
        self.enqueue_2d(1, len, 0, 0)
    }

    /// Enqueue a 2-D strided copy: `rows` segments of `row_bytes` each,
    /// source rows `src_stride` bytes apart and destination rows
    /// `dst_stride` bytes apart (both measured start-to-start; a stride
    /// equal to `row_bytes` — or `0` with `rows == 1` — degenerates to
    /// 1-D). Returns the transfer id; ids complete in FIFO order.
    pub fn enqueue_2d(&mut self, rows: u64, row_bytes: u64, src_stride: u64, dst_stride: u64) -> u32 {
        let id = self.next_id;
        self.next_id += 1;
        if rows == 0 || row_bytes == 0 {
            // Zero-size transfers complete immediately (the hardware
            // raises the event without touching memory).
            self.completed.push(id);
            if let Some(f) = self.on_complete.as_mut() {
                f(id);
            }
            return id;
        }
        self.queue.push(Transfer {
            id,
            src: self.src,
            dst: self.dst,
            row_remaining: row_bytes,
            rows_left: rows,
            row_bytes,
            src_stride,
            dst_stride,
            src_row: self.src,
            dst_row: self.dst,
        });
        id
    }

    /// Transfers still in flight.
    pub fn outstanding(&self) -> u32 {
        self.queue.len() as u32
    }

    /// Bytes still to be moved across all queued transfers.
    pub fn bytes_outstanding(&self) -> u64 {
        self.queue.iter().map(|t| t.total_remaining()).sum()
    }

    /// Drain the ids of transfers that completed since the last drain,
    /// in completion (= FIFO submission) order.
    pub fn take_completed(&mut self) -> Vec<u32> {
        std::mem::take(&mut self.completed)
    }

    /// Register a transfer-complete hook, called inside [`DmaEngine::tick`]
    /// (and for zero-size enqueues) with the retiring transfer id.
    /// Replaces any previous hook. [`DmaEngine::take_completed`] still
    /// records ids independently of the hook.
    pub fn set_on_complete(&mut self, f: impl FnMut(u32) + 'static) {
        self.on_complete = Some(Box::new(f));
    }

    /// Move up to the per-cycle budget.
    pub fn tick(&mut self, tcdm: &mut [u8], global: &mut [u8]) {
        let mut budget = DMA_BYTES_PER_CYCLE;
        while budget > 0 {
            let Some(t) = self.queue.first_mut() else { break };
            // Copy within the current row only; the loop continues into
            // the next row (or next transfer) with the leftover budget.
            let chunk = t.row_remaining.min(budget);
            let mut buf = [0u8; DMA_BYTES_PER_CYCLE as usize];
            read_mem(tcdm, global, t.src, &mut buf[..chunk as usize]);
            write_mem(tcdm, global, t.dst, &buf[..chunk as usize]);
            t.src += chunk;
            t.dst += chunk;
            t.row_remaining -= chunk;
            self.bytes_moved += chunk;
            budget -= chunk;
            if t.row_remaining == 0 {
                t.rows_left -= 1;
                if t.rows_left == 0 {
                    let id = t.id;
                    self.queue.remove(0);
                    self.completed.push(id);
                    if let Some(f) = self.on_complete.as_mut() {
                        f(id);
                    }
                } else {
                    t.src_row += t.src_stride;
                    t.dst_row += t.dst_stride;
                    t.src = t.src_row;
                    t.dst = t.dst_row;
                    t.row_remaining = t.row_bytes;
                }
            }
        }
    }

    /// Run the engine to completion (host-side helper for models that
    /// account DMA time analytically): ticks until the queue drains and
    /// returns the number of cycles taken.
    pub fn drain(&mut self, tcdm: &mut [u8], global: &mut [u8]) -> u64 {
        let mut cycles = 0;
        while self.outstanding() > 0 {
            self.tick(tcdm, global);
            cycles += 1;
        }
        cycles
    }
}

fn read_mem(tcdm: &[u8], global: &[u8], addr: u64, out: &mut [u8]) {
    if addr >= GLOBAL_BASE {
        let o = (addr - GLOBAL_BASE) as usize;
        out.copy_from_slice(&global[o..o + out.len()]);
    } else {
        let o = (addr - TCDM_BASE) as usize;
        out.copy_from_slice(&tcdm[o..o + out.len()]);
    }
}

fn write_mem(tcdm: &mut [u8], global: &mut [u8], addr: u64, data: &[u8]) {
    if addr >= GLOBAL_BASE {
        let o = (addr - GLOBAL_BASE) as usize;
        global[o..o + data.len()].copy_from_slice(data);
    } else {
        let o = (addr - TCDM_BASE) as usize;
        tcdm[o..o + data.len()].copy_from_slice(data);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfers_complete_at_bandwidth() {
        let mut dma = DmaEngine::default();
        let mut tcdm = vec![0u8; 1024];
        let mut global = vec![0u8; 1024];
        for (i, b) in global.iter_mut().enumerate() {
            *b = i as u8;
        }
        dma.src = GLOBAL_BASE;
        dma.dst = TCDM_BASE;
        dma.enqueue(256);
        let mut cycles = 0;
        while dma.outstanding() > 0 {
            dma.tick(&mut tcdm, &mut global);
            cycles += 1;
        }
        assert_eq!(cycles, 256 / DMA_BYTES_PER_CYCLE);
        assert_eq!(&tcdm[..256], &global[..256]);
        assert_eq!(dma.bytes_moved, 256);
    }

    #[test]
    fn fifo_ordering_and_ids() {
        let mut dma = DmaEngine::default();
        dma.src = GLOBAL_BASE;
        dma.dst = TCDM_BASE;
        assert_eq!(dma.enqueue(10), 0);
        assert_eq!(dma.enqueue(10), 1);
        assert_eq!(dma.outstanding(), 2);
    }

    #[test]
    fn strided_2d_gathers_a_tile_rectangle() {
        // A 4-row × 24-byte sub-rectangle of a 64-byte-pitch matrix in
        // global memory, packed contiguously into TCDM.
        let (rows, row_bytes, pitch) = (4u64, 24u64, 64u64);
        let mut global = vec![0u8; 1024];
        for (i, b) in global.iter_mut().enumerate() {
            *b = (i % 251) as u8;
        }
        let mut tcdm = vec![0u8; 256];
        let mut dma = DmaEngine::default();
        let src_off = 8u64; // tile starts mid-row
        dma.src = GLOBAL_BASE + src_off;
        dma.dst = TCDM_BASE;
        dma.enqueue_2d(rows, row_bytes, pitch, row_bytes);
        let cycles = dma.drain(&mut tcdm, &mut global);
        // Budget flows across row boundaries: same cycles as a 1-D copy.
        let total = rows * row_bytes;
        assert_eq!(cycles, total.div_ceil(DMA_BYTES_PER_CYCLE));
        assert_eq!(dma.bytes_moved, total);
        for r in 0..rows {
            let g = (src_off + r * pitch) as usize;
            let t = (r * row_bytes) as usize;
            assert_eq!(
                &tcdm[t..t + row_bytes as usize],
                &global[g..g + row_bytes as usize],
                "row {r} stride math"
            );
        }
    }

    #[test]
    fn strided_2d_scatters_back_to_global() {
        // The write-back direction: contiguous TCDM rows scattered into
        // a strided global destination (C tile into the big C matrix).
        let (rows, row_bytes, pitch) = (3u64, 16u64, 40u64);
        let mut tcdm = vec![0u8; 256];
        for (i, b) in tcdm.iter_mut().enumerate() {
            *b = (i as u8) ^ 0xA5;
        }
        let mut global = vec![0u8; 512];
        let mut dma = DmaEngine::default();
        dma.src = TCDM_BASE;
        dma.dst = GLOBAL_BASE + 4;
        dma.enqueue_2d(rows, row_bytes, row_bytes, pitch);
        dma.drain(&mut tcdm, &mut global);
        for r in 0..rows {
            let t = (r * row_bytes) as usize;
            let g = (4 + r * pitch) as usize;
            assert_eq!(&global[g..g + row_bytes as usize], &tcdm[t..t + row_bytes as usize]);
        }
    }

    #[test]
    fn completion_events_drain_in_fifo_order() {
        let mut tcdm = vec![0u8; 1024];
        let mut global = vec![0u8; 1024];
        let mut dma = DmaEngine::default();
        dma.src = GLOBAL_BASE;
        dma.dst = TCDM_BASE;
        let id0 = dma.enqueue(96);
        dma.src = GLOBAL_BASE + 96;
        dma.dst = TCDM_BASE + 96;
        let id1 = dma.enqueue_2d(2, 32, 48, 32);
        assert!(dma.take_completed().is_empty(), "nothing retires before ticking");
        // 96 B = 1.5 cycles: id0 retires mid-cycle 2 and id1's first 32 B
        // move in the same cycle with the leftover budget.
        dma.tick(&mut tcdm, &mut global);
        assert!(dma.take_completed().is_empty());
        dma.tick(&mut tcdm, &mut global);
        assert_eq!(dma.take_completed(), vec![id0]);
        dma.drain(&mut tcdm, &mut global);
        assert_eq!(dma.take_completed(), vec![id1]);
        assert!(dma.take_completed().is_empty(), "drain is destructive");
    }

    #[test]
    fn completion_hook_fires_once_per_transfer() {
        use std::cell::RefCell;
        use std::rc::Rc;
        let mut tcdm = vec![0u8; 256];
        let mut global = vec![0u8; 256];
        let mut dma = DmaEngine::default();
        let seen: Rc<RefCell<Vec<u32>>> = Rc::default();
        let sink = Rc::clone(&seen);
        dma.set_on_complete(move |id| sink.borrow_mut().push(id));
        dma.src = GLOBAL_BASE;
        dma.dst = TCDM_BASE;
        let a = dma.enqueue(64);
        let b = dma.enqueue(64);
        let z = dma.enqueue(0); // zero-size: completes at enqueue
        assert_eq!(*seen.borrow(), vec![z]);
        dma.drain(&mut tcdm, &mut global);
        assert_eq!(*seen.borrow(), vec![z, a, b]);
        // The drain-style API observed the same retirements.
        assert_eq!(dma.take_completed(), vec![z, a, b]);
    }
}
