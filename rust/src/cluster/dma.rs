//! The cluster DMA engine (the ninth, data-mover core's backend).
//!
//! Transfers are 1-D byte copies between global memory and the TCDM
//! (either direction), processed in FIFO order at [`DMA_BYTES_PER_CYCLE`]
//! — the 512-bit-wide mover of the Snitch cluster.

use super::{GLOBAL_BASE, TCDM_BASE};

/// Peak DMA bandwidth (bytes per cycle).
pub const DMA_BYTES_PER_CYCLE: u64 = 64;

/// One queued transfer.
#[derive(Clone, Copy, Debug)]
struct Transfer {
    src: u64,
    dst: u64,
    remaining: u64,
}

/// FIFO DMA engine.
#[derive(Default)]
pub struct DmaEngine {
    /// Staged source address (set by `dmsrc`).
    pub src: u64,
    /// Staged destination address (set by `dmdst`).
    pub dst: u64,
    queue: Vec<Transfer>,
    next_id: u32,
    /// Total bytes moved (stats).
    pub bytes_moved: u64,
}

impl DmaEngine {
    /// Enqueue a copy of `len` bytes from the staged src to the staged
    /// dst. Returns the transfer id.
    pub fn enqueue(&mut self, len: u64) -> u32 {
        self.queue.push(Transfer { src: self.src, dst: self.dst, remaining: len });
        self.next_id += 1;
        self.next_id - 1
    }

    /// Transfers still in flight.
    pub fn outstanding(&self) -> u32 {
        self.queue.len() as u32
    }

    /// Move up to the per-cycle budget.
    pub fn tick(&mut self, tcdm: &mut [u8], global: &mut [u8]) {
        let mut budget = DMA_BYTES_PER_CYCLE;
        while budget > 0 {
            let Some(t) = self.queue.first_mut() else { break };
            let chunk = t.remaining.min(budget);
            // Byte-by-byte copy through a small stack buffer (chunk ≤ 64).
            let mut buf = [0u8; DMA_BYTES_PER_CYCLE as usize];
            read_mem(tcdm, global, t.src, &mut buf[..chunk as usize]);
            write_mem(tcdm, global, t.dst, &buf[..chunk as usize]);
            t.src += chunk;
            t.dst += chunk;
            t.remaining -= chunk;
            self.bytes_moved += chunk;
            budget -= chunk;
            if t.remaining == 0 {
                self.queue.remove(0);
            }
        }
    }
}

fn read_mem(tcdm: &[u8], global: &[u8], addr: u64, out: &mut [u8]) {
    if addr >= GLOBAL_BASE {
        let o = (addr - GLOBAL_BASE) as usize;
        out.copy_from_slice(&global[o..o + out.len()]);
    } else {
        let o = (addr - TCDM_BASE) as usize;
        out.copy_from_slice(&tcdm[o..o + out.len()]);
    }
}

fn write_mem(tcdm: &mut [u8], global: &mut [u8], addr: u64, data: &[u8]) {
    if addr >= GLOBAL_BASE {
        let o = (addr - GLOBAL_BASE) as usize;
        global[o..o + data.len()].copy_from_slice(data);
    } else {
        let o = (addr - TCDM_BASE) as usize;
        tcdm[o..o + data.len()].copy_from_slice(data);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfers_complete_at_bandwidth() {
        let mut dma = DmaEngine::default();
        let mut tcdm = vec![0u8; 1024];
        let mut global = vec![0u8; 1024];
        for (i, b) in global.iter_mut().enumerate() {
            *b = i as u8;
        }
        dma.src = GLOBAL_BASE;
        dma.dst = TCDM_BASE;
        dma.enqueue(256);
        let mut cycles = 0;
        while dma.outstanding() > 0 {
            dma.tick(&mut tcdm, &mut global);
            cycles += 1;
        }
        assert_eq!(cycles, 256 / DMA_BYTES_PER_CYCLE);
        assert_eq!(&tcdm[..256], &global[..256]);
        assert_eq!(dma.bytes_moved, 256);
    }

    #[test]
    fn fifo_ordering_and_ids() {
        let mut dma = DmaEngine::default();
        dma.src = GLOBAL_BASE;
        dma.dst = TCDM_BASE;
        assert_eq!(dma.enqueue(10), 0);
        assert_eq!(dma.enqueue(10), 1);
        assert_eq!(dma.outstanding(), 2);
    }
}
