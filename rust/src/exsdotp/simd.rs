//! The SIMD wrapper around replicated ExSdotp units (§III-D, Fig. 5).
//!
//! The FPU register file is 64-bit; the wrapper unpacks the three 64-bit
//! operand registers into lanes, feeds the parallel units, and repacks:
//!
//! * **16→32-bit**: two units. `rs1 = [a0 a1 a2 a3]`, `rs2 = [b0 b1 b2
//!   b3]` (4×16-bit), `rd = [e0 e1]` (2×32-bit). Unit *i* computes
//!   `e_i += a_{2i}·b_{2i} + a_{2i+1}·b_{2i+1}` — consuming *all* the
//!   register-file bandwidth, which is the whole point of Fig. 2.
//! * **8→16-bit**: four units, same pattern with 8×FP8 sources and
//!   4×FP16 accumulators.
//! * **Vsum / ExVsum**: pairwise lane reduction `rd_i = rs1_{2i} +
//!   rs1_{2i+1} + rd_i`, used to fold the packed partial accumulators
//!   after a GEMM inner loop (§III-C).

use super::unit::ExSdotpUnit;
use crate::formats::FpFormat;
use crate::softfloat::round::RoundingMode;

/// SIMD operation selector (the three MiniFloat-NN instructions).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum SimdOp {
    /// `exsdotp rd, rs1, rs2`
    ExSdotp,
    /// `exvsum rd, rs1`
    ExVsum,
    /// `vsum rd, rs1`
    Vsum,
}

/// The SDOTP operation-group module: lane plumbing over scalar units.
#[derive(Clone, Copy, Debug)]
pub struct SimdExSdotp {
    /// Scalar unit replicated per lane-pair.
    pub unit: ExSdotpUnit,
}

/// Extract lane `i` of width `w` bits from a 64-bit register. Lanes
/// beyond the register (`i·w ≥ 64`) do not exist and read as zero —
/// guarded explicitly, since `reg >> 64` would panic in debug builds
/// and is undefined-behaviour-adjacent (wrapping) in release.
#[inline]
pub fn lane(reg: u64, i: u32, w: u32) -> u64 {
    let shift = i * w;
    if shift >= 64 {
        return 0;
    }
    (reg >> shift) & if w >= 64 { u64::MAX } else { (1u64 << w) - 1 }
}

/// Insert `val` as lane `i` of width `w` into `reg`. Writes to lanes
/// beyond the register (`i·w ≥ 64`) are dropped (same guard as
/// [`lane`]).
#[inline]
pub fn set_lane(reg: u64, i: u32, w: u32, val: u64) -> u64 {
    let shift = i * w;
    if shift >= 64 {
        return reg;
    }
    let mask = if w >= 64 { u64::MAX } else { ((1u64 << w) - 1) << shift };
    (reg & !mask) | ((val << shift) & mask)
}

impl SimdExSdotp {
    /// Wrapper over `src→dst` scalar units.
    pub fn new(src: FpFormat, dst: FpFormat) -> Self {
        Self { unit: ExSdotpUnit::new(src, dst) }
    }

    /// Number of parallel scalar units (= destination lanes in 64 bits).
    pub fn n_units(&self) -> u32 {
        self.unit.dst.lanes_in_64()
    }

    /// Active unit pairs for the non-expanding Vsum: `rd_i = rs1_{2i} +
    /// rs1_{2i+1} + rd_i` consumes two `dst` lanes per result, so only
    /// `n_units/2` units participate (zero for a single-lane
    /// destination, where no pair exists and `rd` passes through).
    pub fn vsum_pairs(&self) -> u32 {
        self.n_units() / 2
    }

    /// FLOP performed by one SIMD instruction of kind `op` (the paper
    /// counts 1 ExSdotp = 4 FLOP, a three-term add = 2 FLOP). Counts
    /// follow the *active* units: all `n_units` for ExSdotp/ExVsum,
    /// [`Self::vsum_pairs`] for Vsum — consistent with what
    /// [`Self::execute`] actually computes, including single-lane
    /// destination configurations where Vsum performs no work.
    pub fn flops(&self, op: SimdOp) -> u64 {
        match op {
            SimdOp::ExSdotp => 4 * self.n_units() as u64,
            SimdOp::ExVsum => 2 * self.n_units() as u64,
            SimdOp::Vsum => 2 * self.vsum_pairs() as u64,
        }
    }

    /// Execute one SIMD instruction: returns the new `rd`.
    pub fn execute(&self, op: SimdOp, rs1: u64, rs2: u64, rd: u64, rm: RoundingMode) -> u64 {
        match op {
            SimdOp::ExSdotp => self.exsdotp(rs1, rs2, rd, rm),
            SimdOp::ExVsum => self.exvsum(rs1, rd, rm),
            SimdOp::Vsum => self.vsum(rs1, rd, rm),
        }
    }

    /// SIMD `exsdotp rd, rs1, rs2` (rd is also the accumulator input).
    /// Lane `i` rounds under `rm.sr_lane(i)` — identity for the IEEE
    /// modes, per-lane key split under stochastic rounding, matching
    /// the monomorphized tier lane for lane.
    pub fn exsdotp(&self, rs1: u64, rs2: u64, rd: u64, rm: RoundingMode) -> u64 {
        let sw = self.unit.src.width();
        let dw = self.unit.dst.width();
        let mut out = rd;
        for i in 0..self.n_units() {
            let a = lane(rs1, 2 * i, sw);
            let b = lane(rs2, 2 * i, sw);
            let c = lane(rs1, 2 * i + 1, sw);
            let d = lane(rs2, 2 * i + 1, sw);
            let e = lane(rd, i, dw);
            out = set_lane(out, i, dw, self.unit.exsdotp(a, b, c, d, e, rm.sr_lane(i)));
        }
        out
    }

    /// SIMD `exvsum rd, rs1`: `rd_i += rs1_{2i} + rs1_{2i+1}` (expanding).
    pub fn exvsum(&self, rs1: u64, rd: u64, rm: RoundingMode) -> u64 {
        let sw = self.unit.src.width();
        let dw = self.unit.dst.width();
        let mut out = rd;
        for i in 0..self.n_units() {
            let a = lane(rs1, 2 * i, sw);
            let c = lane(rs1, 2 * i + 1, sw);
            let e = lane(rd, i, dw);
            out = set_lane(out, i, dw, self.unit.exvsum(a, c, e, rm.sr_lane(i)));
        }
        out
    }

    /// SIMD `vsum rd, rs1`: pairwise reduction of `dst`-format lanes of
    /// rs1 into the low lanes of rd; upper lanes pass through. With a
    /// single-lane destination there is no pair to fold and `rd` passes
    /// through unchanged (consistent with [`Self::flops`] reporting 0).
    pub fn vsum(&self, rs1: u64, rd: u64, rm: RoundingMode) -> u64 {
        let dw = self.unit.dst.width();
        let mut out = rd;
        for i in 0..self.vsum_pairs() {
            let a = lane(rs1, 2 * i, dw);
            let c = lane(rs1, 2 * i + 1, dw);
            let e = lane(rd, i, dw);
            out = set_lane(out, i, dw, self.unit.vsum(a, c, e, rm.sr_lane(i)));
        }
        out
    }
}
