//! Infinitely-precise oracle for the ExSdotp operation.
//!
//! `a×b + c×d + e` is evaluated *exactly* in 768-bit fixed point and
//! rounded once — the mathematically ideal single-rounding result. The
//! fused datapath ([`super::unit`]) is validated against this oracle;
//! the ExFMA cascade ([`super::cascade`]) deviates from it by design,
//! and Table IV quantifies that deviation.

use crate::formats::FpFormat;
use crate::softfloat::round::{round_pack, RoundingMode};
use crate::softfloat::unpack::{unpack, Unpacked};
use crate::wide::WideInt;

/// Signed exact addend: `value = sign · mant · 2^exp`.
struct Exact {
    sign: bool,
    exp: i32,
    mant: u128,
}

enum Special {
    None,
    Nan,
    Inf(bool),
    /// Finite zero contribution with this sign.
    Zero(bool),
}

fn product(src: FpFormat, a: u64, b: u64) -> (Special, Option<Exact>) {
    let ua = unpack(src, a);
    let ub = unpack(src, b);
    if ua.is_nan() || ub.is_nan() {
        return (Special::Nan, None);
    }
    if (ua.is_inf() && ub.is_zero()) || (ua.is_zero() && ub.is_inf()) {
        return (Special::Nan, None);
    }
    let sign = ua.sign ^ ub.sign;
    if ua.is_inf() || ub.is_inf() {
        return (Special::Inf(sign), None);
    }
    if ua.is_zero() || ub.is_zero() {
        return (Special::Zero(sign), None);
    }
    (Special::None, Some(Exact { sign, exp: ua.exp + ub.exp, mant: ua.mant * ub.mant }))
}

fn operand(fmt: FpFormat, e: u64) -> (Special, Option<Exact>) {
    let ue: Unpacked = unpack(fmt, e);
    if ue.is_nan() {
        return (Special::Nan, None);
    }
    if ue.is_inf() {
        return (Special::Inf(ue.sign), None);
    }
    if ue.is_zero() {
        return (Special::Zero(ue.sign), None);
    }
    (Special::None, Some(Exact { sign: ue.sign, exp: ue.exp, mant: ue.mant }))
}

/// Exactly-rounded `a×b + c×d + e` (`a..d` in `src`; `e`, result in
/// `dst`). The gold standard for both datapaths.
pub fn exsdotp_exact(src: FpFormat, dst: FpFormat, a: u64, b: u64, c: u64, d: u64, e: u64, rm: RoundingMode) -> u64 {
    let terms = [product(src, a, b), product(src, c, d), operand(dst, e)];
    sum_exact(dst, terms, rm)
}

/// Exactly-rounded three-term sum `a + c + e`, all in `fmt` (Vsum oracle).
pub fn vsum_exact(fmt: FpFormat, a: u64, c: u64, e: u64, rm: RoundingMode) -> u64 {
    let terms = [operand(fmt, a), operand(fmt, c), operand(fmt, e)];
    sum_exact(fmt, terms, rm)
}

/// Exactly-rounded `a + c + e` with `a, c` in `src` (ExVsum oracle).
pub fn exvsum_exact(src: FpFormat, dst: FpFormat, a: u64, c: u64, e: u64, rm: RoundingMode) -> u64 {
    let terms = [operand(src, a), operand(src, c), operand(dst, e)];
    sum_exact(dst, terms, rm)
}

fn sum_exact(dst: FpFormat, terms: [(Special, Option<Exact>); 3], rm: RoundingMode) -> u64 {
    // Specials.
    let mut inf_sign: Option<bool> = None;
    for (s, _) in &terms {
        match s {
            Special::Nan => return dst.quiet_nan(),
            Special::Inf(sig) => match inf_sign {
                None => inf_sign = Some(*sig),
                Some(prev) if prev != *sig => return dst.quiet_nan(),
                _ => {}
            },
            _ => {}
        }
    }
    if let Some(s) = inf_sign {
        return dst.infinity(s);
    }

    // Exact fixed-point accumulation. Base = the minimum LSB exponent of
    // all finite addends; shifts can exceed 500 bits for FP16alt sources.
    let exacts: Vec<&Exact> = terms.iter().filter_map(|(_, e)| e.as_ref()).collect();
    let mut zero_sign: Option<bool> = None;
    for (s, _) in &terms {
        if let Special::Zero(sig) = s {
            zero_sign = Some(match zero_sign {
                None => *sig,
                Some(prev) if prev == *sig => *sig,
                _ => rm == RoundingMode::Rdn,
            });
        }
    }
    if exacts.is_empty() {
        return dst.zero(zero_sign.unwrap_or(false));
    }

    let base = exacts.iter().map(|e| e.exp).min().unwrap();
    let mut acc = WideInt::ZERO;
    for e in &exacts {
        let shift = (e.exp - base) as u32;
        assert!((shift as usize) < crate::wide::LIMBS * 64 - 130, "WideInt range exceeded");
        let m = WideInt::from_u128(e.mant).shl(shift);
        acc = if e.sign { acc.wrapping_sub(m) } else { acc.wrapping_add(m) };
    }

    if acc.is_zero() {
        return dst.zero(rm == RoundingMode::Rdn);
    }
    let sign = acc.is_negative();
    let mag = acc.abs();
    let msb = mag.msb().unwrap();
    // Compress into (u128 mantissa, sticky) for round_pack.
    if msb <= 126 {
        round_pack(sign, base, mag.extract_u128(0, msb + 1), false, dst, rm)
    } else {
        let drop = msb - 126;
        let kept = mag.extract_u128(drop, 127);
        let sticky = mag.any_below(drop);
        round_pack(sign, base + drop as i32, kept, sticky, dst, rm)
    }
}
