//! SWAR ExSdotp kernels — the lane-parallel tier of the batch engine.
//!
//! The scalar fast tier ([`super::fast`]) computes one destination lane
//! at a time, and each lane pays the full descriptor machinery: five
//! [`crate::softfloat::unpack`] calls, enum-classed addend terms, a
//! tuple sort and 128-bit three-term arithmetic. This module makes the
//! packed `u64` word the unit of computation instead:
//!
//! 1. **Register screen** — one branch-free AND-fold
//!    ([`crate::softfloat::swar::special_lanes`]) classifies all lanes
//!    of all three operand registers at once. Registers carrying any
//!    NaN/∞ lane (rare in GEMM traffic) are routed to the scalar tier,
//!    which *is* the reference — bit-identity for specials is therefore
//!    trivial, and the hot path below never sees them.
//! 2. **Bit-plane extraction** — sign/exponent/mantissa planes of every
//!    lane are peeled with shared masks
//!    ([`crate::softfloat::swar::sign_plane`] & friends), replacing the
//!    per-lane unpack round-trips.
//! 3. **Lane-parallel finite datapath** — each destination lane runs
//!    [`three_term_finite_m`]: the *same* sort / first-sum / widen /
//!    second-sum / single-round stages as
//!    [`super::unit::ExSdotpUnit::exsdotp`] (eqs. 2–4, Fig. 4), but in
//!    64-bit arithmetic. The internal field of every Table I pair fits
//!    a `u64` with its guard and sticky bits isolated below the carry
//!    chain: `2·p_dst + 4 + p_src ≤ 64` bits (63 for FP16→FP32, 29 for
//!    FP8→FP16), so no carry can escape a lane's working word.
//! 4. **Shared rounding** — every lane terminates in the same
//!    [`crate::softfloat::round::round_pack`] as the scalar tier and
//!    the cycle-accurate unit; there is exactly one rounding
//!    implementation in the crate.
//!
//! Bit-identity with the scalar tier is pinned by the differential
//! suite below (all six expanding pairs × all rounding modes × special
//! values, plus seeded full-register sweeps) and by the batch-level
//! tier differentials in [`crate::batch`]. Only [`crate::batch`]
//! selects tiers; everything above it inherits the speedup through an
//! unchanged API.

use super::fast::{simd_exsdotp_m, simd_vsum_m};
use crate::formats::spec::{ExpandTo, FormatSpec};
use crate::softfloat::round::{round_pack, RoundingMode};
use crate::softfloat::swar::{exp_plane, man_plane, sign_plane, special_lanes};

/// One finite addend: `±mant · 2^(e_msb − msb(mant))`, or a signed
/// zero when `mant == 0` (then `e_msb` is meaningless). `mant` is raw —
/// its MSB sits anywhere at or below bit `p_dst − 1`.
#[derive(Clone, Copy)]
struct Fin {
    sign: bool,
    e_msb: i32,
    mant: u64,
}

/// Decode lane `i` of the pre-extracted field planes into a [`Fin`]
/// operand term (mirrors `unpack` + `operand_term` for finite lanes:
/// subnormals keep the format's fixed subnormal weight, normals gain
/// the hidden bit).
#[inline(always)]
fn fin_lane<F: FormatSpec>(signs: u64, exps: u64, mans: u64, i: u32) -> Fin {
    let sh = i * F::WIDTH;
    let sign = (signs >> sh) & 1 == 1;
    let ef = (exps >> sh) & F::EXP_FIELD_MASK;
    let mf = (mans >> sh) & F::MAN_FIELD_MASK;
    let norm = (ef != 0) as u64;
    let mant = mf | (norm << F::MAN_BITS);
    // LSB weight: emin − man_bits for subnormals, ef − bias − man_bits
    // for normals — `max(ef, 1)` folds both (emin = 1 − bias).
    let e_lsb = (ef as i32).max(1) - F::BIAS - F::MAN_BITS as i32;
    if mant == 0 {
        Fin { sign, e_msb: 0, mant: 0 }
    } else {
        Fin { sign, e_msb: e_lsb + (63 - mant.leading_zeros() as i32), mant }
    }
}

/// The exact product of two finite lane operands (mirrors
/// `product_term` with both factors finite: zero absorbs, otherwise the
/// integer significands multiply exactly — ≤ `2·p_src ≤ p_dst` bits).
#[inline(always)]
fn prod_term(a: Fin, b: Fin, a_lsb: i32, b_lsb: i32) -> Fin {
    let sign = a.sign ^ b.sign;
    if a.mant == 0 || b.mant == 0 {
        return Fin { sign, e_msb: 0, mant: 0 };
    }
    let mant = a.mant * b.mant;
    let msb = 63 - mant.leading_zeros() as i32;
    Fin { sign, e_msb: a_lsb + b_lsb + msb, mant }
}

/// Right-shift with sticky collection (the 64-bit twin of the unit's
/// `shift_sticky`; operands here never exceed 64 significant bits).
#[inline(always)]
fn shift_sticky64(v: u64, n: u32) -> (u64, bool) {
    if n == 0 {
        (v, false)
    } else if n > 63 {
        (0, v != 0)
    } else {
        (v >> n, v & ((1u64 << n) - 1) != 0)
    }
}

/// Shift a mantissa so its MSB sits at `msb_at` (the unit's
/// `normalize_to`; addends carry ≤ `p_dst` bits, so this is always a
/// left shift).
#[inline(always)]
fn normalize_to64(mant: u64, msb_at: u32) -> u64 {
    debug_assert!(mant != 0);
    let msb = 63 - mant.leading_zeros();
    debug_assert!(msb <= msb_at, "addend wider than p_dst");
    mant << (msb_at - msb)
}

/// The fused three-term addition of [`super::unit::ExSdotpUnit`] for
/// **finite** addends, in 64-bit lane arithmetic: identical sort,
/// identical first-sum over `2·p_dst + 3` bits, identical `p_pad`
/// widening, identical second-sum branch structure (including the
/// cancellation-recovery and residue-collapse paths), identical
/// zero-sign rules, terminating in the same shared [`round_pack`]. The
/// only difference from the unit is the word size — legal because
/// `2·p_dst + 4 + p_pad ≤ 64` for every Table I pair (the guard bits
/// stay carry-isolated inside the `u64`).
#[inline]
fn three_term_finite_m<D: FormatSpec>(t0: Fin, t1: Fin, t2: Fin, p_pad: u32, rm: RoundingMode) -> u64 {
    let dst = D::FMT;
    debug_assert!(2 * D::PRECISION + 4 + p_pad <= 64, "lane working word would overflow");

    // Collect finite nonzero addends in argument order; fold zero signs
    // with the IEEE pairwise rule (exactly the unit's loop).
    let mut buf = [Fin { sign: false, e_msb: 0, mant: 0 }; 3];
    let mut n_finite = 0usize;
    let mut zero_sign: Option<bool> = None;
    for t in [t0, t1, t2] {
        if t.mant == 0 {
            zero_sign = Some(match zero_sign {
                None => t.sign,
                Some(prev) if prev == t.sign => t.sign,
                _ => rm == RoundingMode::Rdn,
            });
        } else {
            buf[n_finite] = t;
            n_finite += 1;
        }
    }
    let finite = &mut buf[..n_finite];

    let p_dst = D::PRECISION;
    let msb_at = p_dst - 1;
    for f in finite.iter_mut() {
        f.mant = normalize_to64(f.mant, msb_at);
    }

    match n_finite {
        0 => dst.zero(zero_sign.unwrap_or(false)),
        1 => {
            let f = finite[0];
            round_pack(f.sign, f.e_msb - msb_at as i32, f.mant as u128, false, dst, rm)
        }
        _ => {
            // Magnitude sort, descending (same 3-element network and the
            // same (exponent, mantissa) key as the unit).
            #[inline(always)]
            fn ge(a: &Fin, b: &Fin) -> bool {
                (a.e_msb, a.mant) >= (b.e_msb, b.mant)
            }
            if !ge(&finite[0], &finite[1]) {
                finite.swap(0, 1);
            }
            if n_finite == 3 {
                if !ge(&finite[1], &finite[2]) {
                    finite.swap(1, 2);
                }
                if !ge(&finite[0], &finite[1]) {
                    finite.swap(0, 1);
                }
            }
            let (max, int) = (finite[0], finite[1]);
            let min3 = (n_finite == 3).then(|| finite[2]);

            // --- First sum over 2·p_dst+3 bits.
            let up1 = p_dst + 3;
            let max_m = max.mant << up1;
            let d1 = (max.e_msb - int.e_msb) as u32;
            let (int_m, st_int) = shift_sticky64(int.mant << up1, d1);

            let (mut sign1, mut k1, mut st1);
            if max.sign == int.sign {
                sign1 = max.sign;
                k1 = max_m + int_m;
                st1 = st_int;
            } else {
                sign1 = max.sign;
                k1 = max_m - int_m - st_int as u64;
                st1 = st_int;
                if k1 == 0 && !st1 {
                    // Exact cancellation of max and int: recovery path.
                    return match min3 {
                        Some(f) => round_pack(f.sign, f.e_msb - msb_at as i32, f.mant as u128, false, dst, rm),
                        None => dst.zero(rm == RoundingMode::Rdn),
                    };
                }
            }

            // --- Widen by p_pad zeros.
            k1 <<= p_pad;

            // --- Second sum: add min on the widened grid, sticky
            // residues OR-folded exactly as in the unit.
            if let Some(f) = min3 {
                let d2 = (max.e_msb - f.e_msb) as u32;
                let (min_m, st_min) = shift_sticky64(f.mant << (up1 + p_pad), d2);
                if f.sign == sign1 {
                    k1 += min_m;
                    st1 |= st_min;
                } else {
                    use std::cmp::Ordering::*;
                    match (k1, st1).cmp(&(min_m, st_min)) {
                        Greater => {
                            if !st1 {
                                k1 = k1 - min_m - st_min as u64;
                            } else {
                                k1 -= min_m;
                            }
                            st1 |= st_min;
                        }
                        Less => {
                            if !st_min {
                                k1 = min_m - k1 - st1 as u64;
                            } else {
                                k1 = min_m - k1;
                            }
                            st1 |= st_min;
                            sign1 = f.sign;
                        }
                        Equal => {
                            if !st1 {
                                return dst.zero(rm == RoundingMode::Rdn);
                            }
                            k1 = 0;
                        }
                    }
                }
            }

            // --- Single normalization + rounding on the shared step.
            let grid = max.e_msb - (2 * p_dst as i32 + 2 + p_pad as i32);
            round_pack(sign1, grid, k1 as u128, st1, dst, rm)
        }
    }
}

/// Lane-parallel SIMD `exsdotp` over registers whose lanes are **all
/// finite** (caller guarantees it — see [`swar_exsdotp_m`] for the
/// screened entry). Bit-plane extraction once per register, then each
/// destination lane runs the finite three-term datapath.
#[inline]
pub fn swar_exsdotp_finite_m<S: ExpandTo<D>, D: FormatSpec>(rs1: u64, rs2: u64, rd: u64, rm: RoundingMode) -> u64 {
    debug_assert!(special_lanes::<S>(rs1) | special_lanes::<S>(rs2) | special_lanes::<D>(rd) == 0);
    let (s1, e1, m1) = (sign_plane::<S>(rs1), exp_plane::<S>(rs1), man_plane::<S>(rs1));
    let (s2, e2, m2) = (sign_plane::<S>(rs2), exp_plane::<S>(rs2), man_plane::<S>(rs2));
    let (sd, ed, md) = (sign_plane::<D>(rd), exp_plane::<D>(rd), man_plane::<D>(rd));
    let mut out = 0u64;
    for i in 0..D::LANES {
        let a = fin_lane::<S>(s1, e1, m1, 2 * i);
        let b = fin_lane::<S>(s2, e2, m2, 2 * i);
        let c = fin_lane::<S>(s1, e1, m1, 2 * i + 1);
        let d = fin_lane::<S>(s2, e2, m2, 2 * i + 1);
        let e = fin_lane::<D>(sd, ed, md, i);
        // `fin_lane` returns e_msb; products need the factors' LSB
        // weights, recovered as e_msb − msb(mant). Lane `i` rounds
        // under `rm.sr_lane(i)` — the same per-lane key split the
        // scalar tier applies, so SR stays bit-identical across tiers.
        let pa = prod_of(a, b);
        let pc = prod_of(c, d);
        let r = three_term_finite_m::<D>(pa, pc, e, S::PRECISION, rm.sr_lane(i));
        out |= r << (i * D::WIDTH);
    }
    out
}

/// Product of two finite [`Fin`] operand terms.
#[inline(always)]
fn prod_of(x: Fin, y: Fin) -> Fin {
    let x_lsb = if x.mant == 0 { 0 } else { x.e_msb - (63 - x.mant.leading_zeros() as i32) };
    let y_lsb = if y.mant == 0 { 0 } else { y.e_msb - (63 - y.mant.leading_zeros() as i32) };
    prod_term(x, y, x_lsb, y_lsb)
}

/// SIMD `exsdotp rd, rs1, rs2` on the SWAR tier: screens all three
/// registers with one branch, runs the lane-parallel finite datapath on
/// clean registers, and falls back to the scalar tier
/// ([`simd_exsdotp_m`]) when any lane is NaN/∞ — bit-identical to the
/// scalar tier either way.
#[inline]
pub fn swar_exsdotp_m<S: ExpandTo<D>, D: FormatSpec>(rs1: u64, rs2: u64, rd: u64, rm: RoundingMode) -> u64 {
    if special_lanes::<S>(rs1) | special_lanes::<S>(rs2) | special_lanes::<D>(rd) != 0 {
        return simd_exsdotp_m::<S, D>(rs1, rs2, rd, rm);
    }
    swar_exsdotp_finite_m::<S, D>(rs1, rs2, rd, rm)
}

/// [`swar_exsdotp_m`] for operand streams already known all-finite (the
/// pack-once panel screen): only the running accumulator — which can
/// still overflow to ±∞ — is screened per step.
#[inline]
pub fn swar_exsdotp_operands_finite_m<S: ExpandTo<D>, D: FormatSpec>(
    rs1: u64,
    rs2: u64,
    rd: u64,
    rm: RoundingMode,
) -> u64 {
    debug_assert!(special_lanes::<S>(rs1) | special_lanes::<S>(rs2) == 0);
    if special_lanes::<D>(rd) != 0 {
        return simd_exsdotp_m::<S, D>(rs1, rs2, rd, rm);
    }
    swar_exsdotp_finite_m::<S, D>(rs1, rs2, rd, rm)
}

/// SIMD `vsum rd, rs1` on the SWAR tier (pairwise reduction of `D`
/// lanes, upper `rd` lanes pass through) — the unit's multiplier-bypass
/// datapath with the same `p_src` widening, screened per register.
#[inline]
pub fn swar_vsum_m<S: ExpandTo<D>, D: FormatSpec>(rs1: u64, rd: u64, rm: RoundingMode) -> u64 {
    if special_lanes::<D>(rs1) | special_lanes::<D>(rd) != 0 {
        return simd_vsum_m::<S, D>(rs1, rd, rm);
    }
    let (s1, e1, m1) = (sign_plane::<D>(rs1), exp_plane::<D>(rs1), man_plane::<D>(rs1));
    let (sd, ed, md) = (sign_plane::<D>(rd), exp_plane::<D>(rd), man_plane::<D>(rd));
    let mut out = rd;
    for i in 0..D::LANES / 2 {
        let a = fin_lane::<D>(s1, e1, m1, 2 * i);
        let c = fin_lane::<D>(s1, e1, m1, 2 * i + 1);
        let e = fin_lane::<D>(sd, ed, md, i);
        let v = three_term_finite_m::<D>(a, c, e, S::PRECISION, rm.sr_lane(i));
        let sh = i * D::WIDTH;
        out = (out & !(D::LANE_MASK << sh)) | (v << sh);
    }
    out
}

/// The kernels' `vsum` epilogue tree on the SWAR tier (twin of
/// [`super::fast::vsum_tree_m`], including the per-level
/// `rm.sr_level(l)` key split).
#[inline]
pub fn vsum_tree_swar_m<S: ExpandTo<D>, D: FormatSpec>(acc: u64, rm: RoundingMode) -> u64 {
    let mut t = acc;
    let mut lanes = D::LANES;
    let mut level = 0u32;
    while lanes > 1 {
        t = swar_vsum_m::<S, D>(t, 0, rm.sr_level(level));
        lanes /= 2;
        level += 1;
    }
    t & D::LANE_MASK
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exsdotp::fast::vsum_tree_m;
    use crate::formats::spec::{Fp16, Fp16alt, Fp32, Fp8, Fp8alt};
    use crate::util::prop::{for_all, FpGen};
    use crate::util::rng::Rng;

    const RMS: [RoundingMode; 7] = [
        RoundingMode::Rne,
        RoundingMode::Rtz,
        RoundingMode::Rdn,
        RoundingMode::Rup,
        RoundingMode::Rmm,
        // Stochastic keys: the SWAR tier must split per-lane/per-level
        // keys exactly like the scalar tier for SR bit-identity.
        RoundingMode::StochasticRound(0),
        RoundingMode::StochasticRound(0x5EED_CAFE_F00D_BEEF),
    ];

    /// Pack one boundary-biased encoding per lane.
    fn pack_reg<F: FormatSpec>(rng: &mut Rng, pick: impl Fn(&FpGen, &mut Rng) -> u64) -> u64 {
        let g = FpGen::new(F::FMT);
        let mut reg = 0u64;
        for i in 0..F::LANES {
            reg |= pick(&g, rng) << (i * F::WIDTH);
        }
        reg
    }

    fn check_all_ops<S: ExpandTo<D>, D: FormatSpec>(rs1: u64, rs2: u64, rd: u64) {
        for rm in RMS {
            assert_eq!(
                swar_exsdotp_m::<S, D>(rs1, rs2, rd, rm),
                simd_exsdotp_m::<S, D>(rs1, rs2, rd, rm),
                "exsdotp rs1={rs1:#018x} rs2={rs2:#018x} rd={rd:#018x} rm={rm:?}"
            );
            assert_eq!(
                swar_vsum_m::<S, D>(rd, rs1, rm),
                simd_vsum_m::<S, D>(rd, rs1, rm),
                "vsum rs1={rd:#018x} rd={rs1:#018x} rm={rm:?}"
            );
            assert_eq!(
                vsum_tree_swar_m::<S, D>(rd, rm),
                vsum_tree_m::<S, D>(rd, rm),
                "vsum tree acc={rd:#018x} rm={rm:?}"
            );
        }
    }

    /// Seeded random full-register sweep for one expanding pair: raw
    /// registers (exercises the screen + fallback), edge-lane registers
    /// (NaN/∞/subnormal/±0/max-finite mixes), and all-finite registers
    /// (pins the lane-parallel path itself, including the
    /// operands-finite variant).
    fn diff_sweep<S: ExpandTo<D>, D: FormatSpec>(cases: u64) {
        for_all("swar vs scalar exsdotp", cases, |rng| {
            // Raw 64-bit noise: lanes land on every class.
            check_all_ops::<S, D>(rng.next_u64(), rng.next_u64(), rng.next_u64());
            // Boundary-biased lanes (dense NaN/∞/subnormal traffic).
            let rs1 = pack_reg::<S>(rng, |g, r| g.any(r));
            let rs2 = pack_reg::<S>(rng, |g, r| g.any(r));
            let rd = pack_reg::<D>(rng, |g, r| g.any(r));
            check_all_ops::<S, D>(rs1, rs2, rd);
            // All-finite registers: the SWAR finite path must run (not
            // the fallback) and still agree bit-for-bit.
            let f1 = pack_reg::<S>(rng, |g, r| g.finite(r));
            let f2 = pack_reg::<S>(rng, |g, r| g.finite(r));
            let fd = pack_reg::<D>(rng, |g, r| g.finite(r));
            assert!(special_lanes::<S>(f1) | special_lanes::<S>(f2) | special_lanes::<D>(fd) == 0);
            check_all_ops::<S, D>(f1, f2, fd);
            for rm in RMS {
                assert_eq!(
                    swar_exsdotp_operands_finite_m::<S, D>(f1, f2, fd, rm),
                    simd_exsdotp_m::<S, D>(f1, f2, fd, rm)
                );
                // Operands-finite variant with a special accumulator
                // must still fall back correctly.
                let inf_acc = fd | (D::EXP_FIELD_MASK << D::MAN_BITS);
                assert_eq!(
                    swar_exsdotp_operands_finite_m::<S, D>(f1, f2, inf_acc, rm),
                    simd_exsdotp_m::<S, D>(f1, f2, inf_acc, rm)
                );
            }
        });
    }

    #[test]
    fn swar_bit_identical_fp16_to_fp32() {
        diff_sweep::<Fp16, Fp32>(1_500);
    }

    #[test]
    fn swar_bit_identical_fp16alt_to_fp32() {
        diff_sweep::<Fp16alt, Fp32>(1_500);
    }

    #[test]
    fn swar_bit_identical_fp8_to_fp16() {
        diff_sweep::<Fp8, Fp16>(1_500);
    }

    #[test]
    fn swar_bit_identical_fp8_to_fp16alt() {
        diff_sweep::<Fp8, Fp16alt>(1_500);
    }

    #[test]
    fn swar_bit_identical_fp8alt_to_fp16() {
        diff_sweep::<Fp8alt, Fp16>(1_500);
    }

    #[test]
    fn swar_bit_identical_fp8alt_to_fp16alt() {
        diff_sweep::<Fp8alt, Fp16alt>(1_500);
    }

    #[test]
    fn targeted_special_registers() {
        // Hand-placed special lanes: NaN propagation, ±∞, ∞×0 invalid
        // products, signed-zero sums under Rdn, subnormal operands — all
        // must route through the screen to the scalar tier and agree.
        let nan16 = 0x7e00u64;
        let inf16 = 0x7c00u64;
        let sub16 = 0x0001u64;
        let nzero16 = 0x8000u64;
        let cases: [(u64, u64, u64); 6] = [
            // NaN in one source lane, rest finite.
            ((nan16 << 16) | 0x3c00, 0x3c00_3c00_3c00_3c00, 0),
            // +∞ × −1 product against finite accumulator.
            ((inf16 << 48) | 0x3c00, 0xbc00_3c00_3c00_3c00, 0x3f80_0000_3f80_0000),
            // ∞ × 0: invalid product ⇒ NaN lane.
            (inf16, 0x0000_0000_0000_0000, 0),
            // Subnormal-only sources (finite path, denormal weights).
            ((sub16 << 32) | sub16, (sub16 << 16) | sub16, 0),
            // Signed zeros everywhere: zero-sign rule per rounding mode.
            (nzero16 | (nzero16 << 16), nzero16 << 32, 0x8000_0000_8000_0000),
            // ∞ − ∞ through the accumulator.
            ((inf16 << 16) | inf16, 0x3c00_3c00_3c00_3c00, 0xff80_0000_7f80_0000),
        ];
        for (rs1, rs2, rd) in cases {
            check_all_ops::<Fp16, Fp32>(rs1, rs2, rd);
        }
        // FP8 lane torture: every lane a different class.
        let rs1 = 0x7c_7f_fc_00_80_01_7b_34u64; // inf nan -inf 0 -0 sub max 1-ish
        let rs2 = 0x34_34_34_34_34_34_34_34u64;
        check_all_ops::<Fp8, Fp16>(rs1, rs2, 0x7e00_0000_0001_8000);
    }

    #[test]
    fn finite_path_really_taken() {
        // Guard against a regression where the screen misclassifies and
        // everything silently falls back: an all-finite register must be
        // classified clean for both formats of the pair.
        let rs1 = 0x3434_3434_3434_3434u64;
        assert!(special_lanes::<Fp8>(rs1) == 0);
        assert_eq!(
            swar_exsdotp_finite_m::<Fp8, Fp16>(rs1, rs1, 0, RoundingMode::Rne),
            simd_exsdotp_m::<Fp8, Fp16>(rs1, rs1, 0, RoundingMode::Rne)
        );
    }
}
