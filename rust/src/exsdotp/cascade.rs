//! The discrete baseline: an expanding sum of dot products computed on
//! a **cascade of two ExFMA units** (§II-B, Fig. 3).
//!
//! The cascade computes `a×b + (c×d + e)` — note the parenthesization —
//! and rounds **twice** (once per FMA). Both properties differ from the
//! fused unit: FP addition is not associative, and double rounding loses
//! precision. Table IV measures exactly this gap; Fig. 7a measures the
//! area/timing cost of the two discrete units the cascade needs.

use crate::formats::FpFormat;
use crate::softfloat::ops::ex_fma;
use crate::softfloat::round::RoundingMode;

/// `a×b + (c×d + e)` on two chained expanding FMAs, rounding after each.
pub fn exsdotp_cascade(
    src: FpFormat,
    dst: FpFormat,
    a: u64,
    b: u64,
    c: u64,
    d: u64,
    e: u64,
    rm: RoundingMode,
) -> u64 {
    let inner = ex_fma(src, dst, c, d, e, rm); // c*d + e, rounded to dst
    ex_fma(src, dst, a, b, inner, rm) // a*b + (…), rounded again
}

/// `a + (c + e)` via the cascade (`b = d = 1`), the ExVsum baseline.
pub fn exvsum_cascade(src: FpFormat, dst: FpFormat, a: u64, c: u64, e: u64, rm: RoundingMode) -> u64 {
    let one = crate::softfloat::from_f64(1.0, src, RoundingMode::Rne);
    exsdotp_cascade(src, dst, a, one, c, one, e, rm)
}
