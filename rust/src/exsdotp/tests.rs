//! Validation of the fused datapath, the cascade baseline, and the SIMD
//! wrapper against the exact single-rounding oracle.

use super::cascade::{exsdotp_cascade, exvsum_cascade};
use super::exact::{exsdotp_exact, exvsum_exact, vsum_exact};
use super::simd::{lane, set_lane, SimdExSdotp, SimdOp};
use super::unit::ExSdotpUnit;
use crate::formats::*;
use crate::softfloat::{from_f64, to_f64, RoundingMode};
use crate::util::prop::{for_all, FpGen};
use crate::util::rng::Rng;

const RMS: [RoundingMode; 5] = [
    RoundingMode::Rne,
    RoundingMode::Rtz,
    RoundingMode::Rdn,
    RoundingMode::Rup,
    RoundingMode::Rmm,
];


fn same(fmt: FpFormat, x: u64, y: u64) -> bool {
    (fmt.is_nan(x) && fmt.is_nan(y)) || x == y
}

/// Map an encoding to a totally ordered integer so ulp distance is a
/// subtraction (±0 collapse to 0).
fn ulp_key(fmt: FpFormat, bits: u64) -> i64 {
    let mag = (bits & !fmt.sign_mask() & fmt.width_mask()) as i64;
    if fmt.sign(bits) {
        -mag
    } else {
        mag
    }
}

/// Distance in ulps between two non-NaN encodings.
fn ulp_dist(fmt: FpFormat, x: u64, y: u64) -> u64 {
    (ulp_key(fmt, x) - ulp_key(fmt, y)).unsigned_abs()
}

/// Tracks how often a faithfully-rounded datapath hits the exactly
/// rounded value. Fused three-term adders guarantee ≤ 1 ulp error; we
/// additionally require near-perfect agreement (the deviation window is
/// a ~2^-(p_src+3) sliver of the operand space).
struct Faithful {
    total: u64,
    off_by_one: u64,
}

impl Faithful {
    fn new() -> Self {
        Self { total: 0, off_by_one: 0 }
    }

    fn check(&mut self, fmt: FpFormat, got: u64, exact: u64, ctx: &str) {
        self.total += 1;
        if same(fmt, got, exact) {
            return;
        }
        assert!(
            !fmt.is_nan(got) && !fmt.is_nan(exact) && ulp_dist(fmt, got, exact) <= 1,
            "beyond faithful rounding: {ctx} got={got:#x} exact={exact:#x}"
        );
        self.off_by_one += 1;
    }

    fn assert_mostly_exact(&self, max_rate: f64) {
        let rate = self.off_by_one as f64 / self.total.max(1) as f64;
        assert!(rate <= max_rate, "off-by-one rate {rate} > {max_rate} ({}/{})", self.off_by_one, self.total);
    }
}

/// The paper's expanding format pairs under test.
fn expanding_pairs() -> [(FpFormat, FpFormat); 4] {
    [(FP16, FP32), (FP16ALT, FP32), (FP8, FP16), (FP8ALT, FP16)]
}

// ------------------------------------------------------- fused vs exact oracle

#[test]
fn fused_matches_exact_oracle_randomized() {
    for (src, dst) in expanding_pairs() {
        let unit = ExSdotpUnit::new(src, dst);
        let gs = FpGen::new(src);
        let gd = FpGen::new(dst);
        let mut stats = Faithful::new();
        for_all("fused vs exact", 30_000, |rng| {
            let (a, b, c, d) = (gs.any(rng), gs.any(rng), gs.any(rng), gs.any(rng));
            let e = gd.any(rng);
            for rm in RMS {
                let fused = unit.exsdotp(a, b, c, d, e, rm);
                let exact = exsdotp_exact(src, dst, a, b, c, d, e, rm);
                let ctx = format!(
                    "{}→{} rm={rm:?} a={a:#x} b={b:#x} c={c:#x} d={d:#x} e={e:#x}",
                    src.name(),
                    dst.name()
                );
                stats.check(dst, fused, exact, &ctx);
            }
        });
        stats.assert_mostly_exact(0.001);
    }
}

#[test]
fn fused_fp8_to_fp16_near_exhaustive_products() {
    // All 2^16 (a,b) products against a sweep of accumulators.
    let unit = ExSdotpUnit::fp8_to_fp16();
    let mut rng = Rng::new(99);
    let gd = FpGen::new(FP16);
    let mut stats = Faithful::new();
    for a in 0..256u64 {
        for b in 0..256u64 {
            let c = rng.next_u64() & 0xff;
            let d = rng.next_u64() & 0xff;
            let e = gd.any(&mut rng);
            let fused = unit.exsdotp(a, b, c, d, e, RoundingMode::Rne);
            let exact = exsdotp_exact(FP8, FP16, a, b, c, d, e, RoundingMode::Rne);
            stats.check(FP16, fused, exact, &format!("a={a:#x} b={b:#x} c={c:#x} d={d:#x} e={e:#x}"));
        }
    }
    stats.assert_mostly_exact(0.0005);
}

#[test]
fn fused_handles_paper_nonassociativity_example() {
    // §III-B: if |a| ≫ |c| and b = −a then (a+b)+c = c, but a+(b+c) may
    // return 0. Build it with products: a·1 + (−a)·1 + c.
    let unit = ExSdotpUnit::fp16_to_fp32();
    let one = from_f64(1.0, FP16, RoundingMode::Rne);
    let a = from_f64(60000.0, FP16, RoundingMode::Rne);
    let na = a | FP16.sign_mask();
    let c = from_f64(2f64.powi(-20), FP32, RoundingMode::Rne); // tiny accumulator
    let fused = unit.exsdotp(a, one, na, one, c, RoundingMode::Rne);
    assert_eq!(to_f64(fused, FP32), 2f64.powi(-20), "recovery path must preserve c");
}

#[test]
fn cancellation_recovery_path() {
    // max + int cancel exactly; min must come through unharmed even
    // though it was shifted out of the stage-1 field.
    for (src, dst) in expanding_pairs() {
        let unit = ExSdotpUnit::new(src, dst);
        // A large-but-finite source value (format-dependent range).
        let big = from_f64(2f64.powi(src.emax() / 2), src, RoundingMode::Rne);
        let one_s = from_f64(1.0, src, RoundingMode::Rne);
        let nbig = big | src.sign_mask();
        // e = smallest subnormal of dst: maximally shifted out.
        let e = dst.min_subnormal();
        let fused = unit.exsdotp(big, one_s, nbig, one_s, e, RoundingMode::Rne);
        assert_eq!(fused, e, "{}→{}", src.name(), dst.name());
    }
}

// --------------------------------------------------------------- vsum / exvsum

#[test]
fn exvsum_equals_exsdotp_with_ones() {
    for (src, dst) in expanding_pairs() {
        let unit = ExSdotpUnit::new(src, dst);
        let one = from_f64(1.0, src, RoundingMode::Rne);
        let gs = FpGen::new(src);
        let gd = FpGen::new(dst);
        for_all("exvsum = exsdotp(1)", 10_000, |rng| {
            let (a, c, e) = (gs.any(rng), gs.any(rng), gd.any(rng));
            let v = unit.exvsum(a, c, e, RoundingMode::Rne);
            let s = unit.exsdotp(a, one, c, one, e, RoundingMode::Rne);
            assert!(same(dst, v, s));
        });
    }
}

#[test]
fn exvsum_matches_exact() {
    for (src, dst) in expanding_pairs() {
        let unit = ExSdotpUnit::new(src, dst);
        let gs = FpGen::new(src);
        let gd = FpGen::new(dst);
        let mut stats = Faithful::new();
        for_all("exvsum vs exact", 10_000, |rng| {
            let (a, c, e) = (gs.any(rng), gs.any(rng), gd.any(rng));
            for rm in RMS {
                let v = unit.exvsum(a, c, e, rm);
                let x = exvsum_exact(src, dst, a, c, e, rm);
                let ctx = format!("{}→{} rm={rm:?} a={a:#x} c={c:#x} e={e:#x}", src.name(), dst.name());
                stats.check(dst, v, x, &ctx);
            }
        });
        // ExVsum feeds `1·x` products straight into the adder, and the
        // boundary-biased generator (25% subnormals/extremes) lands in
        // the double-sticky faithful-rounding window more often than the
        // dot-product path — allow a slightly higher rate.
        stats.assert_mostly_exact(0.005);
    }
}

#[test]
fn vsum_matches_exact_three_term() {
    for (src, dst) in expanding_pairs() {
        let unit = ExSdotpUnit::new(src, dst);
        let gd = FpGen::new(dst);
        let mut stats = Faithful::new();
        for_all("vsum vs exact", 10_000, |rng| {
            let (a, c, e) = (gd.any(rng), gd.any(rng), gd.any(rng));
            for rm in RMS {
                let v = unit.vsum(a, c, e, rm);
                let x = vsum_exact(dst, a, c, e, rm);
                let ctx = format!("{} rm={rm:?} a={a:#x} c={c:#x} e={e:#x}", dst.name());
                stats.check(dst, v, x, &ctx);
            }
        });
        stats.assert_mostly_exact(0.001);
    }
}

#[test]
fn vsum_is_single_rounded_unlike_two_adds() {
    // Find a case where (a+c)+e double-rounds differently and confirm
    // the fused Vsum matches the exact result.
    let unit = ExSdotpUnit::fp16_to_fp32();
    let gd = FpGen::new(FP32);
    let mut diffs = 0u32;
    let mut rng = Rng::new(2024);
    let mut stats = Faithful::new();
    for _ in 0..200_000 {
        let (a, c, e) = (gd.finite(&mut rng), gd.finite(&mut rng), gd.finite(&mut rng));
        let fused = unit.vsum(a, c, e, RoundingMode::Rne);
        let exact = vsum_exact(FP32, a, c, e, RoundingMode::Rne);
        stats.check(FP32, fused, exact, "vsum rne");
        let twostep = crate::softfloat::add(
            FP32,
            crate::softfloat::add(FP32, a, c, RoundingMode::Rne),
            e,
            RoundingMode::Rne,
        );
        if !same(FP32, twostep, exact) {
            diffs += 1;
        }
    }
    assert!(diffs > 0, "expected at least one double-rounding discrepancy");
    stats.assert_mostly_exact(0.0005);
}

// ------------------------------------------------------------------- specials

#[test]
fn nan_and_inf_propagation() {
    let unit = ExSdotpUnit::fp16_to_fp32();
    let one = from_f64(1.0, FP16, RoundingMode::Rne);
    let e1 = from_f64(1.0, FP32, RoundingMode::Rne);
    let nan_s = FP16.quiet_nan();
    let inf_s = FP16.infinity(false);
    let ninf_s = FP16.infinity(true);

    // NaN anywhere → NaN.
    assert!(FP32.is_nan(unit.exsdotp(nan_s, one, one, one, e1, RoundingMode::Rne)));
    assert!(FP32.is_nan(unit.exsdotp(one, one, one, nan_s, e1, RoundingMode::Rne)));
    assert!(FP32.is_nan(unit.exsdotp(one, one, one, one, FP32.quiet_nan(), RoundingMode::Rne)));
    // ∞ × 0 → NaN.
    assert!(FP32.is_nan(unit.exsdotp(inf_s, FP16.zero(false), one, one, e1, RoundingMode::Rne)));
    // Conflicting infinities → NaN.
    assert!(FP32.is_nan(unit.exsdotp(inf_s, one, ninf_s, one, e1, RoundingMode::Rne)));
    assert!(FP32.is_nan(unit.exsdotp(inf_s, one, one, one, FP32.infinity(true), RoundingMode::Rne)));
    // Agreeing infinities → that infinity.
    assert_eq!(unit.exsdotp(inf_s, one, one, one, e1, RoundingMode::Rne), FP32.infinity(false));
    assert_eq!(
        unit.exsdotp(ninf_s, one, one | (FP16.sign_mask()), one, FP32.infinity(true), RoundingMode::Rne),
        FP32.infinity(true)
    );
}

#[test]
fn zero_products_and_signed_zero() {
    let unit = ExSdotpUnit::fp16_to_fp32();
    let z = FP16.zero(false);
    let nz = FP16.zero(true);
    // 0·0 + 0·0 + e = e.
    let e = from_f64(3.5, FP32, RoundingMode::Rne);
    assert_eq!(unit.exsdotp(z, z, z, z, e, RoundingMode::Rne), e);
    // All-positive zeros → +0; a negative zero in the mix (RNE) → +0;
    // RDN with mixed signs → −0.
    assert_eq!(unit.exsdotp(z, z, z, z, FP32.zero(false), RoundingMode::Rne), FP32.zero(false));
    assert_eq!(unit.exsdotp(nz, z, z, z, FP32.zero(false), RoundingMode::Rdn), FP32.zero(true));
    assert_eq!(unit.exsdotp(nz, nz, nz, nz, FP32.zero(false), RoundingMode::Rne), FP32.zero(false));
}

#[test]
fn overflow_saturation_per_mode() {
    let unit = ExSdotpUnit::fp8_to_fp16();
    let big = FP8.max_finite(false);
    let e = FP16.max_finite(false);
    // max·max + max·max + max overflows FP16.
    assert_eq!(unit.exsdotp(big, big, big, big, e, RoundingMode::Rne), FP16.infinity(false));
    assert_eq!(unit.exsdotp(big, big, big, big, e, RoundingMode::Rtz), FP16.max_finite(false));
}

// ------------------------------------------------------------- cascade baseline

#[test]
fn cascade_rounds_twice_and_differs_from_fused() {
    // Aggregate: the cascade must (a) equal the fused result most of the
    // time, (b) differ on a nonzero fraction, (c) never be *more*
    // accurate than the fused result vs the exact oracle.
    for (src, dst) in expanding_pairs() {
        let unit = ExSdotpUnit::new(src, dst);
        let gs = FpGen::new(src);
        let gd = FpGen::new(dst);
        let mut rng = Rng::new(7);
        let mut differs = 0u64;
        let mut stats = Faithful::new();
        for _ in 0..100_000 {
            let (a, b, c, d) = (gs.finite(&mut rng), gs.finite(&mut rng), gs.finite(&mut rng), gs.finite(&mut rng));
            let e = gd.finite(&mut rng);
            let fused = unit.exsdotp(a, b, c, d, e, RoundingMode::Rne);
            let casc = exsdotp_cascade(src, dst, a, b, c, d, e, RoundingMode::Rne);
            let exact = exsdotp_exact(src, dst, a, b, c, d, e, RoundingMode::Rne);
            stats.check(dst, fused, exact, "cascade cmp");
            if !same(dst, casc, fused) {
                differs += 1;
            }
        }
        assert!(differs > 0, "{}→{}: cascade never differed", src.name(), dst.name());
    }
}

#[test]
fn exvsum_cascade_baseline_works() {
    let a = from_f64(1.0, FP16, RoundingMode::Rne);
    let c = from_f64(2.0, FP16, RoundingMode::Rne);
    let e = from_f64(0.5, FP32, RoundingMode::Rne);
    assert_eq!(to_f64(exvsum_cascade(FP16, FP32, a, c, e, RoundingMode::Rne), FP32), 3.5);
}

// ----------------------------------------------------------------------- SIMD

#[test]
fn simd_lane_packing_roundtrip() {
    let mut reg = 0u64;
    for i in 0..4 {
        reg = set_lane(reg, i, 16, 0x1000 + i as u64);
    }
    for i in 0..4 {
        assert_eq!(lane(reg, i, 16), 0x1000 + i as u64);
    }
    // 32-bit lanes overlay the same register.
    assert_eq!(lane(reg, 0, 32), (0x1001 << 16) | 0x1000);
}

#[test]
fn simd_exsdotp_matches_scalar_lanes() {
    for (src, dst) in expanding_pairs() {
        let simd = SimdExSdotp::new(src, dst);
        let unit = ExSdotpUnit::new(src, dst);
        let sw = src.width();
        let dw = dst.width();
        for_all("simd vs scalar", 5_000, |rng| {
            let rs1 = rng.next_u64();
            let rs2 = rng.next_u64();
            let rd = rng.next_u64();
            let out = simd.exsdotp(rs1, rs2, rd, RoundingMode::Rne);
            for i in 0..simd.n_units() {
                let want = unit.exsdotp(
                    lane(rs1, 2 * i, sw),
                    lane(rs2, 2 * i, sw),
                    lane(rs1, 2 * i + 1, sw),
                    lane(rs2, 2 * i + 1, sw),
                    lane(rd, i, dw),
                    RoundingMode::Rne,
                );
                assert!(same(dst, lane(out, i, dw), want), "lane {i}");
            }
        });
    }
}

#[test]
fn simd_unit_counts_match_paper() {
    // §III-D: two 16-to-32-bit and (four) 8-to-16-bit ExSdotp per cycle
    // in a 64-bit datapath: "up to two 16-to-32-bit or four 8-to-16-bit
    // ExSdotp operations each cycle".
    assert_eq!(SimdExSdotp::new(FP16, FP32).n_units(), 2);
    assert_eq!(SimdExSdotp::new(FP16ALT, FP32).n_units(), 2);
    assert_eq!(SimdExSdotp::new(FP8, FP16).n_units(), 4);
    assert_eq!(SimdExSdotp::new(FP8ALT, FP16).n_units(), 4);
    // FLOP/instruction: 8 (2 units × 4) and 16 (4 × 4) — the peak
    // FLOP/cycle in Table III.
    assert_eq!(SimdExSdotp::new(FP16, FP32).flops(SimdOp::ExSdotp), 8);
    assert_eq!(SimdExSdotp::new(FP8, FP16).flops(SimdOp::ExSdotp), 16);
}

#[test]
fn lane_helpers_tolerate_out_of_register_indices() {
    // Regression: `lane`/`set_lane` computed `reg >> (i*w)` which panics
    // in debug (and wraps in release) once i*w >= 64 — reachable for
    // single-lane (64-bit destination) configurations.
    let reg = 0xdead_beef_cafe_babe_u64;
    assert_eq!(lane(reg, 1, 64), 0);
    assert_eq!(lane(reg, 2, 32), 0);
    assert_eq!(lane(reg, 8, 8), 0);
    assert_eq!(set_lane(reg, 1, 64, 0x42), reg);
    assert_eq!(set_lane(reg, 4, 16, 0x42), reg);
    // In-register lanes are unaffected by the guard.
    assert_eq!(lane(reg, 0, 64), reg);
    assert_eq!(lane(reg, 3, 16), 0xdead);
}

#[test]
fn vsum_and_flops_consistent_per_op() {
    // flops() must report exactly the work execute() performs.
    let s1632 = SimdExSdotp::new(FP16, FP32);
    let s816 = SimdExSdotp::new(FP8, FP16);
    assert_eq!(s1632.vsum_pairs(), 1);
    assert_eq!(s816.vsum_pairs(), 2);
    assert_eq!(s1632.flops(SimdOp::Vsum), 2);
    assert_eq!(s816.flops(SimdOp::Vsum), 4);
    assert_eq!(s1632.flops(SimdOp::ExVsum), 4);
    assert_eq!(s816.flops(SimdOp::ExVsum), 8);
    // Vsum only touches the low `pairs` destination lanes; the rest of
    // rd passes through.
    let rs1 = 0x3c00_3c00_3c00_3c00; // four FP16 ones
    let rd = 0xaaaa_bbbb_0000_0000;
    let out = s816.vsum(rs1, rd, RoundingMode::Rne);
    assert_eq!(lane(out, 2, 16), 0xbbbb);
    assert_eq!(lane(out, 3, 16), 0xaaaa);
    assert_eq!(to_f64(lane(out, 0, 16), FP16), 2.0);
}

#[test]
fn simd_vsum_reduces_accumulator_pairs() {
    // After SIMD ExSdotp, rd holds packed partial sums; vsum folds them.
    let simd = SimdExSdotp::new(FP16, FP32);
    let a0 = from_f64(1.5, FP32, RoundingMode::Rne);
    let a1 = from_f64(2.25, FP32, RoundingMode::Rne);
    let rs1 = a0 | (a1 << 32);
    let acc = from_f64(0.25, FP32, RoundingMode::Rne);
    let out = simd.vsum(rs1, acc, RoundingMode::Rne);
    assert_eq!(to_f64(lane(out, 0, 32), FP32), 4.0);
}

// -------------------------------------------------------------- accuracy trend

#[test]
fn fused_accumulation_beats_cascade_in_aggregate() {
    // Miniature Table IV. Per-seed outcomes fluctuate (error cancellation
    // can favour either datapath on a single draw — the paper reports one
    // draw per n); in aggregate over seeds the fused unit must win.
    for (src, dst, n) in [(FP16, FP32, 1000usize), (FP8, FP16, 1000)] {
        let unit = ExSdotpUnit::new(src, dst);
        let mut sum_fused = 0f64;
        let mut sum_casc = 0f64;
        for seed in 0..32u64 {
            let mut rng = Rng::new(4242 + seed);
            let mut acc_fused = dst.zero(false);
            let mut acc_casc = dst.zero(false);
            let mut acc_f64 = 0f64;
            for _ in 0..n / 2 {
                let quant = |r: &mut Rng| from_f64(r.gaussian(), src, RoundingMode::Rne);
                let (a, b, c, d) = (quant(&mut rng), quant(&mut rng), quant(&mut rng), quant(&mut rng));
                acc_fused = unit.exsdotp(a, b, c, d, acc_fused, RoundingMode::Rne);
                acc_casc = exsdotp_cascade(src, dst, a, b, c, d, acc_casc, RoundingMode::Rne);
                acc_f64 += to_f64(a, src) * to_f64(b, src) + to_f64(c, src) * to_f64(d, src);
            }
            sum_fused += ((to_f64(acc_fused, dst) - acc_f64) / acc_f64).abs();
            sum_casc += ((to_f64(acc_casc, dst) - acc_f64) / acc_f64).abs();
        }
        assert!(
            sum_fused <= sum_casc,
            "{}→{}: mean fused err {} vs cascade {}",
            src.name(),
            dst.name(),
            sum_fused / 32.0,
            sum_casc / 32.0
        );
    }
}
