//! The fused ExSdotp datapath, bit-faithful to §III-B.
//!
//! Dataflow (Fig. 4), for `a×b + c×d + e`:
//!
//! 1. **Mantissa products** — `a×b` and `c×d` computed exactly
//!    (`2·p_src` bits each), then zero-padded to `p_dst` (eq. 2).
//! 2. **Sort** — the three addends (two products + accumulator `e`) are
//!    sorted by magnitude into `max`, `int`, `min` using the exponent
//!    datapath.
//! 3. **First sum** — `max` and `int` are placed in a `2·p_dst+3`-bit
//!    field (`{addend, 0_(p_dst+3)}`), `int` right-shifted by the
//!    exponent difference (shifted-out bits → sticky), then added
//!    (eq. 3) producing `2·p_dst+4` bits.
//! 4. **Widen** — the sum is zero-padded by another `p_src` bits to
//!    survive the cancellation case where `max` came from a
//!    normal×subnormal product (eq. 4).
//! 5. **Second sum** — `min`, aligned to the widened grid, is added.
//!    *Recovery path:* if the first sum was exactly zero, `min` is
//!    assigned directly, recovering its shifted-out bits.
//! 6. **Single normalize + round** — one rounding step, shared with the
//!    scalar softfloat via [`round_pack`].
//!
//! ExVsum reuses the path with `b = d = 1`; the non-expanding Vsum
//! bypasses the multipliers and feeds three `dst`-format operands
//! directly into the three-term adder (§III-C, Fig. 4 bypass arrows).

use crate::formats::FpFormat;
use crate::softfloat::round::{round_pack, RoundingMode};
use crate::softfloat::unpack::{unpack, Class, Unpacked};

/// One addend entering the three-term adder.
#[derive(Clone, Copy, Debug)]
enum Term {
    /// ±0 (sign kept for IEEE zero-sign rules).
    Zero(bool),
    /// Finite nonzero: `value = (-1)^sign · mant · 2^(e_msb - (msb_at))`,
    /// with `mant`'s MSB normalized to a fixed bit position.
    Finite { sign: bool, e_msb: i32, mant: u128 },
}

/// A parameterized ExSdotp unit instance (one per `src→dst` pair, like
/// one hardware instantiation; the SIMD wrapper replicates these).
#[derive(Clone, Copy, Debug)]
pub struct ExSdotpUnit {
    /// Source (input) format of `a, b, c, d`.
    pub src: FpFormat,
    /// Destination (accumulator/result) format.
    pub dst: FpFormat,
}

impl ExSdotpUnit {
    /// Instantiate a `src→dst` unit.
    ///
    /// Panics if the format pair violates the datapath constraints the
    /// paper's parameterization imposes: `2·p_src ≤ p_dst` (products must
    /// fit the padded accumulator width) and the internal field
    /// `2·p_dst + p_src + 5` must fit the 128-bit model arithmetic.
    pub fn new(src: FpFormat, dst: FpFormat) -> Self {
        assert!(
            2 * src.precision() <= dst.precision(),
            "ExSdotp requires 2*p_src <= p_dst (got {} -> {})",
            src.name(),
            dst.name()
        );
        assert!(2 * dst.precision() + src.precision() + 5 <= 127, "internal field exceeds model width");
        assert!(dst.exp_bits >= src.exp_bits, "destination dynamic range must cover the source");
        Self { src, dst }
    }

    /// The paper's 16-to-32-bit unit.
    pub fn fp16_to_fp32() -> Self {
        Self::new(crate::formats::FP16, crate::formats::FP32)
    }

    /// The paper's 8-to-16-bit unit.
    pub fn fp8_to_fp16() -> Self {
        Self::new(crate::formats::FP8, crate::formats::FP16)
    }

    /// `a×b + c×d + e` — the fused expanding sum of dot products (eq. 1).
    ///
    /// `#[inline]`: [`crate::exsdotp::fast`] calls this with constant
    /// formats; inlining lets each (src, dst) instantiation specialize.
    #[inline]
    pub fn exsdotp(&self, a: u64, b: u64, c: u64, d: u64, e: u64, rm: RoundingMode) -> u64 {
        let (src, dst) = (self.src, self.dst);
        let ua = unpack(src, a);
        let ub = unpack(src, b);
        let uc = unpack(src, c);
        let ud = unpack(src, d);
        let ue = unpack(dst, e);

        if ua.is_nan() || ub.is_nan() || uc.is_nan() || ud.is_nan() || ue.is_nan() {
            return dst.quiet_nan();
        }
        // Invalid products: ∞ × 0.
        if (ua.is_inf() && ub.is_zero()) || (ua.is_zero() && ub.is_inf()) {
            return dst.quiet_nan();
        }
        if (uc.is_inf() && ud.is_zero()) || (uc.is_zero() && ud.is_inf()) {
            return dst.quiet_nan();
        }

        let prod_ab = product_term(&ua, &ub);
        let prod_cd = product_term(&uc, &ud);
        let acc = operand_term(&ue);
        self.three_term(prod_ab, prod_cd, acc, src.precision(), rm)
    }

    /// `a + c + e` with `a, c` in the source format — ExVsum (eq. 5),
    /// implemented exactly as the hardware does: `b = d = 1`.
    #[inline]
    pub fn exvsum(&self, a: u64, c: u64, e: u64, rm: RoundingMode) -> u64 {
        let one = crate::softfloat::from_f64(1.0, self.src, RoundingMode::Rne);
        self.exsdotp(a, one, c, one, e, rm)
    }

    /// `a + c + e` with all operands in the destination format — the
    /// non-expanding Vsum (eq. 6): multipliers bypassed, three-term
    /// adder reused. Operand width grows to `dst` via the `a_vs`/`c_vs`
    /// register-field extension (§III-C).
    #[inline]
    pub fn vsum(&self, a: u64, c: u64, e: u64, rm: RoundingMode) -> u64 {
        let dst = self.dst;
        let ua = unpack(dst, a);
        let uc = unpack(dst, c);
        let ue = unpack(dst, e);
        if ua.is_nan() || uc.is_nan() || ue.is_nan() {
            return dst.quiet_nan();
        }
        // Vsum skips the multipliers, so p_src plays no role in padding;
        // the hardware still widens by p_src zeros — keep it identical.
        self.three_term(operand_term(&ua), operand_term(&uc), operand_term(&ue), self.src.precision(), rm)
    }

    /// The fused three-term addition (steps 2–6 above). `p_pad` is the
    /// stage-4 widening amount (= p_src in hardware).
    #[inline]
    fn three_term(&self, t0: TermOrInf, t1: TermOrInf, t2: TermOrInf, p_pad: u32, rm: RoundingMode) -> u64 {
        let dst = self.dst;

        // Infinity resolution across the three addends.
        let mut inf_sign: Option<bool> = None;
        for t in [&t0, &t1, &t2] {
            if let TermOrInf::Inf(s) = t {
                match inf_sign {
                    None => inf_sign = Some(*s),
                    Some(prev) if prev != *s => return dst.quiet_nan(),
                    _ => {}
                }
            }
        }
        if let Some(s) = inf_sign {
            return dst.infinity(s);
        }

        let terms = [unwrap_finite(t0), unwrap_finite(t1), unwrap_finite(t2)];

        // Collect finite nonzero addends (fixed buffer — this is the
        // simulator's per-lane hot path); resolve all-zero cases with
        // the IEEE pairwise zero-sign rule.
        let mut buf = [(false, 0i32, 0u128); 3];
        let mut n_finite = 0usize;
        let mut zero_sign: Option<bool> = None;
        for t in terms {
            match t {
                Term::Zero(s) => {
                    zero_sign = Some(match zero_sign {
                        None => s,
                        Some(prev) if prev == s => s,
                        _ => rm == RoundingMode::Rdn,
                    });
                }
                Term::Finite { sign, e_msb, mant } => {
                    buf[n_finite] = (sign, e_msb, mant);
                    n_finite += 1;
                }
            }
        }
        let finite = &mut buf[..n_finite];

        let p_dst = dst.precision();
        let msb_at = p_dst - 1; // normalization point of addend mantissas
        // Weight-align every mantissa to MSB = p_dst−1: products carry
        // ≤ 2·p_src ≤ p_dst bits and operands ≤ p_dst bits, so this is
        // the paper's zero-padding to p_dst (eq. 2) — never truncating.
        for f in finite.iter_mut() {
            f.2 = normalize_to(f.2, msb_at);
        }

        match n_finite {
            0 => dst.zero(zero_sign.unwrap_or(false)),
            1 => {
                let (sign, e_msb, mant) = finite[0];
                round_pack(sign, e_msb - msb_at as i32, mant, false, dst, rm)
            }
            _ => {
                // Sort by true magnitude, descending (exponent datapath +
                // mantissa tie-break). Hand-rolled 3-element network —
                // this is the hottest code in the cluster simulator.
                #[inline(always)]
                fn ge(a: &(bool, i32, u128), b: &(bool, i32, u128)) -> bool {
                    (a.1, a.2) >= (b.1, b.2)
                }
                if !ge(&finite[0], &finite[1]) {
                    finite.swap(0, 1);
                }
                if n_finite == 3 {
                    if !ge(&finite[1], &finite[2]) {
                        finite.swap(1, 2);
                    }
                    if !ge(&finite[0], &finite[1]) {
                        finite.swap(0, 1);
                    }
                }
                let (max, int) = (finite[0], finite[1]);
                let min3 = finite.get(2).copied();

                // --- Stage 3: first sum over 2·p_dst+3 bits.
                let up1 = (p_dst + 3) as u32; // {addend, 0_(p_dst+3)}
                let max_m = max.2 << up1;
                let d1 = (max.1 - int.1) as u32;
                let (int_m, st_int) = shift_sticky(int.2 << up1, d1);

                let (mut sign1, mut k1, mut st1);
                if max.0 == int.0 {
                    sign1 = max.0;
                    k1 = max_m + int_m;
                    st1 = st_int;
                } else {
                    sign1 = max.0;
                    k1 = max_m - int_m - st_int as u128;
                    st1 = st_int;
                    if k1 == 0 && !st1 {
                        // Exact cancellation of max and int: recovery
                        // path — the result is min alone (or a signed
                        // zero if there is no third addend).
                        return match min3 {
                            Some((s, e, m)) => round_pack(s, e - msb_at as i32, m, false, dst, rm),
                            None => dst.zero(rm == RoundingMode::Rdn),
                        };
                    }
                }

                // --- Stage 4: widen by p_pad zeros (eq. 4).
                k1 <<= p_pad;

                // --- Stage 5: add min. Like the hardware adder, this
                // stage operates on the *kept* bits and ORs the sticky
                // residues into the final rounding sticky. With two
                // independent sticky residues of unknown relative size,
                // the result is faithfully rounded (≤ 1 ulp), and exactly
                // rounded whenever at most one residue is nonzero — the
                // standard trade-off of fused three-term adders.
                if let Some((s_min, e_min, m_min)) = min3 {
                    let d2 = (max.1 - e_min) as u32;
                    let (min_m, st_min) = shift_sticky(m_min << (up1 + p_pad), d2);
                    if s_min == sign1 {
                        k1 += min_m;
                        st1 |= st_min;
                    } else {
                        use std::cmp::Ordering::*;
                        match (k1, st1).cmp(&(min_m, st_min)) {
                            Greater => {
                                // Borrow against the subtrahend's residue
                                // only when the minuend carries none —
                                // keeps single-residue cases exactly
                                // rounded.
                                if !st1 {
                                    k1 = k1 - min_m - st_min as u128;
                                } else {
                                    k1 -= min_m;
                                }
                                st1 |= st_min;
                            }
                            Less => {
                                // min dominates (deep cancellation of the
                                // first sum): magnitudes swap, sign flips.
                                if !st_min {
                                    k1 = min_m - k1 - st1 as u128;
                                } else {
                                    k1 = min_m - k1;
                                }
                                st1 |= st_min;
                                sign1 = s_min;
                            }
                            Equal => {
                                if !st1 {
                                    // Exact cancellation.
                                    return dst.zero(rm == RoundingMode::Rdn);
                                }
                                // Two sub-ulp residues of unknown relative
                                // size: collapse to a sticky-only value.
                                k1 = 0;
                            }
                        }
                    }
                }

                // --- Stage 6: single normalization and rounding. The
                // working grid LSB sits 2·p_dst+2+p_pad bits below max's
                // MSB exponent.
                let grid = max.1 - (2 * p_dst as i32 + 2 + p_pad as i32);
                round_pack(sign1, grid, k1, st1, dst, rm)
            }
        }
    }
}

/// Finite-or-infinite addend (NaNs are filtered before construction).
enum TermOrInf {
    Inf(bool),
    Fin(Term),
}

fn unwrap_finite(t: TermOrInf) -> Term {
    match t {
        TermOrInf::Fin(f) => f,
        TermOrInf::Inf(_) => unreachable!("infinities resolved earlier"),
    }
}

/// Build the addend for a product `x × y` (both already unpacked,
/// non-NaN, not ∞×0).
fn product_term(x: &Unpacked, y: &Unpacked) -> TermOrInf {
    let sign = x.sign ^ y.sign;
    if x.is_inf() || y.is_inf() {
        return TermOrInf::Inf(sign);
    }
    if x.is_zero() || y.is_zero() {
        return TermOrInf::Fin(Term::Zero(sign));
    }
    let mant = x.mant * y.mant; // exact, ≤ 2·p_src bits
    let msb = 127 - mant.leading_zeros() as i32;
    TermOrInf::Fin(Term::Finite { sign, e_msb: x.exp + y.exp + msb, mant })
}

/// Build the addend for a direct operand (accumulator or Vsum input).
fn operand_term(u: &Unpacked) -> TermOrInf {
    match u.class {
        Class::Inf => TermOrInf::Inf(u.sign),
        Class::Zero => TermOrInf::Fin(Term::Zero(u.sign)),
        _ => {
            let msb = 127 - u.mant.leading_zeros() as i32;
            TermOrInf::Fin(Term::Finite { sign: u.sign, e_msb: u.exp + msb, mant: u.mant })
        }
    }
}

/// Shift a raw mantissa so its MSB sits at `msb_at` (= `p_dst − 1`).
/// Addends never carry more than `p_dst` significant bits (products are
/// ≤ 2·p_src ≤ p_dst by the unit's constructor assertion), so this is
/// always a left shift — the paper's zero-padding, never a truncation.
#[inline(always)]
fn normalize_to(mant: u128, msb_at: u32) -> u128 {
    debug_assert!(mant != 0);
    let msb = 127 - mant.leading_zeros();
    debug_assert!(msb <= msb_at, "addend wider than p_dst: constructor invariant violated");
    mant << (msb_at - msb)
}

/// Right-shift with sticky collection.
#[inline(always)]
fn shift_sticky(v: u128, n: u32) -> (u128, bool) {
    if n == 0 {
        (v, false)
    } else if n > 127 {
        (0, v != 0)
    } else {
        (v >> n, v & ((1u128 << n) - 1) != 0)
    }
}
