//! Table I: source/destination format combinations supported by the
//! ExSdotp unit, per operation.
//!
//! | Source  | FP32           | FP16alt        | FP16           | FP8  | FP8alt |
//! |---------|----------------|----------------|----------------|------|--------|
//! | FP32    | Vsum           | –              | –              | –    | –      |
//! | FP16alt | ExSdotp/ExVsum | Vsum           | Vsum           | –    | –      |
//! | FP16    | ExSdotp/ExVsum | Vsum           | Vsum           | –    | –      |
//! | FP8     | –              | ExSdotp/ExVsum | ExSdotp/ExVsum | Vsum | Vsum   |
//! | FP8alt  | –              | ExSdotp/ExVsum | ExSdotp/ExVsum | Vsum | Vsum   |
//!
//! (Vsum rows with mismatched same-width formats — e.g. src FP16alt,
//! dst FP16 — reflect that Vsum reads `dst`-format operands; the *source
//! register* format is what the CSR `src_is_alt` bit says, but the
//! datapath treats them as `dst`-format values.)

use crate::formats::{FpFormat, FP16, FP16ALT, FP32, FP8, FP8ALT};

/// Operation kinds the unit provides.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum OpKind {
    /// Expanding sum of dot products (eq. 1).
    ExSdotp,
    /// Expanding vector inner sum (eq. 5).
    ExVsum,
    /// Non-expanding vector inner sum (eq. 6).
    Vsum,
}

/// Does the (src, dst) pair support `op`, per Table I?
pub fn supported(src: FpFormat, dst: FpFormat, op: OpKind) -> bool {
    let expanding_pairs: [(FpFormat, FpFormat); 6] = [
        (FP16, FP32),
        (FP16ALT, FP32),
        (FP8, FP16),
        (FP8, FP16ALT),
        (FP8ALT, FP16),
        (FP8ALT, FP16ALT),
    ];
    match op {
        OpKind::ExSdotp | OpKind::ExVsum => expanding_pairs.contains(&(src, dst)),
        OpKind::Vsum => {
            // Non-expanding, implemented for 8-, 16-, 32-bit formats;
            // src and dst must share the operation width.
            let w = src.width();
            w == dst.width() && (w == 8 || w == 16 || w == 32)
        }
    }
}

/// All (src, dst, op) triples supported — iterates Table I.
pub fn all_supported() -> Vec<(FpFormat, FpFormat, OpKind)> {
    let fmts = [FP32, FP16ALT, FP16, FP8, FP8ALT];
    let mut out = Vec::new();
    for src in fmts {
        for dst in fmts {
            for op in [OpKind::ExSdotp, OpKind::ExVsum, OpKind::Vsum] {
                if supported(src, dst, op) {
                    out.push((src, dst, op));
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expanding_combos_match_table1() {
        assert!(supported(FP16, FP32, OpKind::ExSdotp));
        assert!(supported(FP16ALT, FP32, OpKind::ExVsum));
        assert!(supported(FP8, FP16, OpKind::ExSdotp));
        assert!(supported(FP8, FP16ALT, OpKind::ExSdotp));
        assert!(supported(FP8ALT, FP16, OpKind::ExVsum));
        assert!(supported(FP8ALT, FP16ALT, OpKind::ExSdotp));
        // Not supported: skipping a level or going backwards.
        assert!(!supported(FP8, FP32, OpKind::ExSdotp));
        assert!(!supported(FP32, FP16, OpKind::ExSdotp));
        assert!(!supported(FP32, FP32, OpKind::ExSdotp));
        assert!(!supported(FP16, FP16, OpKind::ExSdotp));
    }

    #[test]
    fn vsum_combos_match_table1() {
        assert!(supported(FP32, FP32, OpKind::Vsum));
        assert!(supported(FP16, FP16, OpKind::Vsum));
        assert!(supported(FP16ALT, FP16, OpKind::Vsum));
        assert!(supported(FP16, FP16ALT, OpKind::Vsum));
        assert!(supported(FP8, FP8, OpKind::Vsum));
        assert!(supported(FP8ALT, FP8, OpKind::Vsum));
        assert!(!supported(FP16, FP32, OpKind::Vsum));
        assert!(!supported(FP32, FP16, OpKind::Vsum));
    }

    #[test]
    fn count_matches_table1() {
        // Table I: 6 ExSdotp cells + 6 ExVsum + (1 FP32 + 4 16-bit + 4
        // 8-bit) Vsum cells = 21 supported triples.
        assert_eq!(all_supported().len(), 6 + 6 + 9);
    }
}
