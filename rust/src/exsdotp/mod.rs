//! The ExSdotp unit — the paper's core hardware contribution (§III).
//!
//! An **expanding sum-of-dot-product** unit computes
//!
//! ```text
//! ExSdotp_2w = a_w × b_w + c_w × d_w + e_2w
//! ```
//!
//! with `a,b,c,d` in a `w`-bit source format and the accumulator `e` and
//! result in a `2w`-bit destination format — *fused*, i.e. with a single
//! normalization/rounding step at the end. Fusion both shrinks the
//! hardware (Fig. 7a: ~30% area/critical-path vs. a cascade of two
//! expanding FMAs) and removes the precision loss caused by the
//! non-associativity of two chained FP additions (Fig. 3, Table IV).
//!
//! Module map:
//!
//! * [`unit`] — the bit-accurate fused datapath (§III-B), stage by
//!   stage: mantissa products, zero-padding to `p_dst`, three-addend
//!   sort, progressively widened two-step addition, cancellation
//!   recovery, single round. Also computes ExVsum (`b=d=1`) and the
//!   non-expanding Vsum (multiplier bypass) on the same datapath
//!   (§III-C).
//! * [`cascade`] — the baseline: the same operation on two chained
//!   expanding FMAs, which rounds twice and computes `a×b + (c×d + e)`
//!   (§II-B). Used as the comparison point in Table IV and Fig. 7a.
//! * [`exact`] — an infinitely-precise oracle (`W384` fixed-point) that
//!   rounds once; the testbench for both datapaths.
//! * [`simd`] — the SIMD wrapper (§III-D): two 16→32-bit and two
//!   8→16-bit units behind a 64-bit three-operand register interface,
//!   with operand packing/unpacking.
//! * [`table1`] — the supported source/destination format combinations
//!   (Table I) as a queryable matrix.
//! * [`fast`] — monomorphized twins of [`unit`] and [`simd`] (constant
//!   formats via [`crate::formats::FormatSpec`]), the per-lane kernels
//!   behind the slice-level engine in [`crate::batch`].
//! * [`swar`] — the lane-parallel tier: bit-plane field extraction and
//!   one branch-free specials screen per packed register
//!   ([`crate::softfloat::swar`]), then the same fused datapath in
//!   64-bit lane arithmetic for all-finite registers. Specials fall
//!   back to [`fast`]; both paths end in the shared
//!   [`crate::softfloat::round::round_pack`], and the differential
//!   suites pin the tiers bit-identical.

pub mod cascade;
pub mod exact;
pub mod fast;
pub mod simd;
pub mod swar;
pub mod table1;
#[cfg(test)]
mod tests;
pub mod unit;

pub use cascade::{exsdotp_cascade, exvsum_cascade};
pub use exact::{exsdotp_exact, vsum_exact};
pub use fast::{exsdotp_m, simd_exsdotp_m, vsum_tree_m};
pub use simd::{SimdExSdotp, SimdOp};
pub use swar::{swar_exsdotp_m, swar_vsum_m, vsum_tree_swar_m};
pub use table1::{supported, OpKind};
pub use unit::ExSdotpUnit;
