//! Monomorphized ExSdotp kernels — Tier A of the batch numerics engine.
//!
//! Compile-time-dispatched twins of [`super::unit::ExSdotpUnit`] and
//! [`super::simd::SimdExSdotp`]: generic over a
//! [`FormatSpec`](crate::formats::FormatSpec) `(src, dst)` pair bounded
//! by [`ExpandTo`](crate::formats::ExpandTo), so only Table I's six
//! hardware-legal expanding combinations instantiate. Each function
//! builds the unit with constant formats and calls the **same**
//! `#[inline]` datapath implementation — one specialized code path per
//! pair, bit-identical to the descriptor-driven API by construction.
//!
//! This is what the slice-level engine ([`crate::batch`]) runs in its
//! inner loops: the SIMD wrappers have constant lane counts and widths
//! (the `for` trip counts below are compile-time constants after
//! monomorphization), so there is no per-lane re-dispatch left.

use super::unit::ExSdotpUnit;
use crate::formats::spec::{ExpandTo, FormatSpec};
use crate::softfloat::round::RoundingMode;

/// The `S → D` unit instance with compile-time formats. The
/// `S: ExpandTo<D>` bound enforces statically what
/// [`ExSdotpUnit::new`] asserts at runtime (Table I legality).
#[inline]
pub fn unit_m<S: ExpandTo<D>, D: FormatSpec>() -> ExSdotpUnit {
    ExSdotpUnit { src: S::FMT, dst: D::FMT }
}

/// Monomorphized scalar `a×b + c×d + e` (eq. 1).
#[inline]
pub fn exsdotp_m<S: ExpandTo<D>, D: FormatSpec>(a: u64, b: u64, c: u64, d: u64, e: u64, rm: RoundingMode) -> u64 {
    unit_m::<S, D>().exsdotp(a, b, c, d, e, rm)
}

/// Monomorphized scalar ExVsum `a + c + e` (eq. 5).
#[inline]
pub fn exvsum_m<S: ExpandTo<D>, D: FormatSpec>(a: u64, c: u64, e: u64, rm: RoundingMode) -> u64 {
    unit_m::<S, D>().exvsum(a, c, e, rm)
}

/// Monomorphized scalar Vsum `a + c + e`, all in `D` (eq. 6).
#[inline]
pub fn vsum_m<S: ExpandTo<D>, D: FormatSpec>(a: u64, c: u64, e: u64, rm: RoundingMode) -> u64 {
    unit_m::<S, D>().vsum(a, c, e, rm)
}

/// Monomorphized SIMD `exsdotp rd, rs1, rs2`: all `D::LANES` units in
/// one call, constant lane plumbing. Each lane rounds under
/// `rm.sr_lane(i)` — the identity for every non-stochastic mode, and
/// the per-lane key split under stochastic rounding (the SWAR tier and
/// the descriptor wrapper derive the same keys for the same `i`, so
/// the tiers stay bit-identical under SR too).
#[inline]
pub fn simd_exsdotp_m<S: ExpandTo<D>, D: FormatSpec>(rs1: u64, rs2: u64, rd: u64, rm: RoundingMode) -> u64 {
    let unit = unit_m::<S, D>();
    let mut out = rd;
    for i in 0..D::LANES {
        let a = lane_c::<S>(rs1, 2 * i);
        let b = lane_c::<S>(rs2, 2 * i);
        let c = lane_c::<S>(rs1, 2 * i + 1);
        let d = lane_c::<S>(rs2, 2 * i + 1);
        let e = lane_c::<D>(rd, i);
        out = set_lane_c::<D>(out, i, unit.exsdotp(a, b, c, d, e, rm.sr_lane(i)));
    }
    out
}

/// Monomorphized SIMD `exvsum rd, rs1` (per-lane `rm.sr_lane(i)`, like
/// [`simd_exsdotp_m`]).
#[inline]
pub fn simd_exvsum_m<S: ExpandTo<D>, D: FormatSpec>(rs1: u64, rd: u64, rm: RoundingMode) -> u64 {
    let unit = unit_m::<S, D>();
    let mut out = rd;
    for i in 0..D::LANES {
        let a = lane_c::<S>(rs1, 2 * i);
        let c = lane_c::<S>(rs1, 2 * i + 1);
        let e = lane_c::<D>(rd, i);
        out = set_lane_c::<D>(out, i, unit.exvsum(a, c, e, rm.sr_lane(i)));
    }
    out
}

/// Monomorphized SIMD `vsum rd, rs1` (pairwise reduction of `D` lanes;
/// upper `rd` lanes pass through; per-lane `rm.sr_lane(i)`).
#[inline]
pub fn simd_vsum_m<S: ExpandTo<D>, D: FormatSpec>(rs1: u64, rd: u64, rm: RoundingMode) -> u64 {
    let unit = unit_m::<S, D>();
    let mut out = rd;
    for i in 0..D::LANES / 2 {
        let a = lane_c::<D>(rs1, 2 * i);
        let c = lane_c::<D>(rs1, 2 * i + 1);
        let e = lane_c::<D>(rd, i);
        out = set_lane_c::<D>(out, i, unit.vsum(a, c, e, rm.sr_lane(i)));
    }
    out
}

/// Fold a packed accumulator register down to its low lane with the
/// kernels' `vsum` tree (one level for 2 destination lanes, two levels
/// for 4 — exactly the epilogue the generated GEMM programs execute).
/// Tree level `l` rounds under `rm.sr_level(l)` (identity for
/// non-stochastic modes; [`vsum_tree_swar_m`](crate::exsdotp::swar::vsum_tree_swar_m)
/// derives identically, keeping the tiers bit-identical under SR).
#[inline]
pub fn vsum_tree_m<S: ExpandTo<D>, D: FormatSpec>(acc: u64, rm: RoundingMode) -> u64 {
    let mut t = acc;
    let mut lanes = D::LANES;
    let mut level = 0u32;
    while lanes > 1 {
        t = simd_vsum_m::<S, D>(t, 0, rm.sr_level(level));
        lanes /= 2;
        level += 1;
    }
    lane_c::<D>(t, 0)
}

/// Compile-time-width lane extract (`F::WIDTH < 64` for every
/// expanding-pair member, so the shift is always in range).
#[inline]
fn lane_c<F: FormatSpec>(reg: u64, i: u32) -> u64 {
    (reg >> (i * F::WIDTH)) & ((1u64 << F::WIDTH) - 1)
}

/// Compile-time-width lane insert.
#[inline]
fn set_lane_c<F: FormatSpec>(reg: u64, i: u32, val: u64) -> u64 {
    let mask = ((1u64 << F::WIDTH) - 1) << (i * F::WIDTH);
    (reg & !mask) | ((val << (i * F::WIDTH)) & mask)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exsdotp::simd::{lane, SimdExSdotp};
    use crate::formats::spec::{Fp16, Fp16alt, Fp32, Fp8, Fp8alt};
    use crate::formats::FpFormat;
    use crate::util::prop::{for_all, FpGen};

    const RMS: [RoundingMode; 7] = [
        RoundingMode::Rne,
        RoundingMode::Rtz,
        RoundingMode::Rdn,
        RoundingMode::Rup,
        RoundingMode::Rmm,
        // Stochastic keys too: both tiers must split per-lane keys the
        // same way, so the differential holds beyond the IEEE modes.
        RoundingMode::StochasticRound(0),
        RoundingMode::StochasticRound(0x5EED_CAFE_F00D_BEEF),
    ];

    fn same(fmt: FpFormat, x: u64, y: u64) -> bool {
        (fmt.is_nan(x) && fmt.is_nan(y)) || x == y
    }

    /// One differential sweep: monomorphized vs descriptor-driven, all
    /// rounding modes, boundary-biased inputs (NaN/Inf/subnormal/±0).
    fn diff_sweep<S: ExpandTo<D>, D: FormatSpec>(cases: u64) {
        let unit = ExSdotpUnit::new(S::FMT, D::FMT);
        let simd = SimdExSdotp::new(S::FMT, D::FMT);
        let gs = FpGen::new(S::FMT);
        let gd = FpGen::new(D::FMT);
        for_all("fast exsdotp vs descriptor", cases, |rng| {
            let (a, b, c, d) = (gs.any(rng), gs.any(rng), gs.any(rng), gs.any(rng));
            let e = gd.any(rng);
            let (rs1, rs2, rd) = (rng.next_u64(), rng.next_u64(), rng.next_u64());
            for rm in RMS {
                assert_eq!(exsdotp_m::<S, D>(a, b, c, d, e, rm), unit.exsdotp(a, b, c, d, e, rm));
                assert_eq!(exvsum_m::<S, D>(a, c, e, rm), unit.exvsum(a, c, e, rm));
                assert_eq!(vsum_m::<S, D>(e, e, e, rm), unit.vsum(e, e, e, rm));
                assert_eq!(simd_exsdotp_m::<S, D>(rs1, rs2, rd, rm), simd.exsdotp(rs1, rs2, rd, rm));
                assert_eq!(simd_exvsum_m::<S, D>(rs1, rd, rm), simd.exvsum(rs1, rd, rm));
                assert_eq!(simd_vsum_m::<S, D>(rs1, rd, rm), simd.vsum(rs1, rd, rm));
            }
        });
    }

    #[test]
    fn fast_tier_bit_identical_all_pairs() {
        // All six Table I expanding pairs compile (ExpandTo) and agree.
        diff_sweep::<Fp16, Fp32>(4_000);
        diff_sweep::<Fp16alt, Fp32>(4_000);
        diff_sweep::<Fp8, Fp16>(4_000);
        diff_sweep::<Fp8, Fp16alt>(4_000);
        diff_sweep::<Fp8alt, Fp16>(4_000);
        diff_sweep::<Fp8alt, Fp16alt>(4_000);
    }

    #[test]
    fn vsum_tree_matches_kernel_epilogue() {
        // The tree must reproduce the generated kernels' epilogue: one
        // vsum level for 16→32, two for 8→16, reading lane 0.
        let rm = RoundingMode::Rne;
        let s1632 = SimdExSdotp::new(crate::formats::FP16, crate::formats::FP32);
        let s816 = SimdExSdotp::new(crate::formats::FP8, crate::formats::FP16);
        for_all("vsum tree", 5_000, |rng| {
            let acc = rng.next_u64();
            let want32 = lane(s1632.vsum(acc, 0, rm), 0, 32);
            assert!(same(crate::formats::FP32, vsum_tree_m::<Fp16, Fp32>(acc, rm), want32));
            let t = s816.vsum(acc, 0, rm);
            let want16 = lane(s816.vsum(t, 0, rm), 0, 16);
            assert!(same(crate::formats::FP16, vsum_tree_m::<Fp8, Fp16>(acc, rm), want16));
        });
    }
}
