//! API-layer tests: the typed surface must be bit-identical to the
//! pre-redesign free-function path, and every argument error must be a
//! typed `Error`, not a panic.

use super::*;
use crate::batch::{pack_cols_m, pack_rows_m};
use crate::formats::spec::Fp8;
use crate::formats::{FpFormat, FP16, FP32, FP64, FP8, FP8ALT};
use crate::isa::instr::{OpWidth, ScalarFmt};
use crate::kernels::gemm::{ExecMode, GemmKernel, GemmKind};
use crate::kernels::layout::quantize_f64;
use crate::softfloat::RoundingMode;
use crate::util::rng::Rng;

fn mats(m: usize, n: usize, k: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let a: Vec<f64> = (0..m * k).map(|_| rng.gaussian() * 0.25).collect();
    let b: Vec<f64> = (0..k * n).map(|_| rng.gaussian() * 0.25).collect();
    (a, b)
}

// ------------------------------------------------------- differential

#[test]
fn new_api_bit_identical_to_free_functions_both_modes() {
    // The acceptance gate: FP8→FP16 and FP16→FP32, both ExecModes —
    // C from the plan API must match the pre-redesign kernel path
    // (GemmKernel::run_mode) bit for bit. The deprecated `batch::gemm`
    // shim this test used to triangulate against has been removed; the
    // kernel path is the remaining independent reference.
    let (m, n, k) = (16, 16, 16);
    let (a, b) = mats(m, n, k, 11);
    for (src, dst, kind) in [
        (FP8, FP16, GemmKind::ExSdotp(OpWidth::BtoH)),
        (FP16, FP32, GemmKind::ExSdotp(OpWidth::HtoS)),
    ] {
        for mode in [ExecMode::Functional, ExecMode::CycleAccurate] {
            let old = GemmKernel::new(kind, m, n, k).run_mode(&a, &b, mode);
            let session = Session::builder().mode(mode).build();
            let report = session
                .gemm()
                .src(src)
                .acc(dst)
                .dims(m, n, k)
                .expect("valid plan")
                .run_f64(&a, &b)
                .expect("valid run");
            assert_eq!(bits_of(&report.c_f64()), bits_of(&old.c), "{}→{} {mode:?}", src.name(), dst.name());
            if mode == ExecMode::Functional {
                assert_eq!(report.cycles, Some(GemmKernel::new(kind, m, n, k).model_cycles()));
            } else {
                assert_eq!(report.cycles, Some(old.cycles));
                assert!(report.stats.is_some(), "cycle-accurate runs collect stats");
            }
            assert_eq!(report.c.fmt(), dst);
            assert_eq!(report.c.shape(), (m, n));
        }
    }
}

fn bits_of(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn tensor_run_equals_run_f64() {
    let (m, n, k) = (16, 16, 16);
    let (a, b) = mats(m, n, k, 3);
    let session = Session::new();
    let plan = session.gemm().src(FP8).acc(FP16).dims(m, n, k).unwrap();
    let ta = session.tensor(&a, m, k, FP8).unwrap();
    let tb = session.tensor(&b, k, n, FP8).unwrap();
    let from_tensors = plan.run(&ta, &tb).unwrap();
    let from_slices = plan.run_f64(&a, &b).unwrap();
    // B is row-major here, so this exercises the decode fallback route.
    assert!(!from_tensors.packed_input);
    assert_eq!(from_tensors.c, from_slices.c);
}

#[test]
fn packed_tensor_fast_path_matches_f64_path() {
    // A row-major + B column-major on a functional session takes the
    // zero-repack packed-word route through batch::gemm_packed_into; it must
    // produce the same C as the quantize-from-f64 route, for both
    // expanding kernel families.
    let (m, n, k) = (16, 16, 16);
    let (a, b) = mats(m, n, k, 31);
    let session = Session::new();
    for (src, dst) in [(FP8, FP16), (FP16, FP32)] {
        let plan = session.gemm().src(src).acc(dst).dims(m, n, k).unwrap();
        let ta = session.tensor(&a, m, k, src).unwrap();
        let tb = session.tensor_with_layout(&b, k, n, src, Layout::ColMajor).unwrap();
        let fast = plan.run(&ta, &tb).unwrap();
        let slow = plan.run_f64(&a, &b).unwrap();
        assert!(fast.packed_input, "{}→{}: packed route must actually run", src.name(), dst.name());
        assert!(!slow.packed_input);
        assert_eq!(fast.c, slow.c, "{}→{}", src.name(), dst.name());
        assert_eq!(fast.cycles, slow.cycles);
    }
}

#[test]
fn thread_budget_is_bit_identical() {
    let (m, n, k) = (16, 16, 16);
    let (a, b) = mats(m, n, k, 5);
    let wide = Session::new();
    let narrow = Session::builder().threads(1).build();
    let cw = wide.gemm().src(FP8).acc(FP16).dims(m, n, k).unwrap().run_f64(&a, &b).unwrap();
    let cn = narrow.gemm().src(FP8).acc(FP16).dims(m, n, k).unwrap().run_f64(&a, &b).unwrap();
    assert_eq!(cw.c, cn.c);
}

#[test]
fn cycle_model_toggle_controls_report_cycles() {
    let (m, n, k) = (16, 16, 16);
    let (a, b) = mats(m, n, k, 6);
    let off = Session::builder().cycle_model(false).build();
    let r = off.gemm().src(FP8).acc(FP16).dims(m, n, k).unwrap().run_f64(&a, &b).unwrap();
    assert_eq!(r.cycles, None);
    assert_eq!(r.flop_per_cycle(), None);
    assert_eq!(r.timing_label(), "disabled");
}

// ------------------------------------------------------- plan errors

#[test]
fn plan_rejects_invalid_format_pairs() {
    let session = Session::new();
    let err = session.gemm().src(FP8).acc(FP32).dims(16, 16, 16).unwrap_err();
    assert!(err.to_string().contains("no GEMM kernel for FP8->FP32"), "{err}");
    let err = session.gemm().src(FP16).acc(FP8).dims(16, 16, 16).unwrap_err();
    assert!(err.to_string().contains("no GEMM kernel"), "{err}");
    let err = session.gemm().src(FP8).dims(16, 16, 16).unwrap_err();
    assert!(err.to_string().contains("missing accumulation format"), "{err}");
    let err = session.gemm().dims(16, 16, 16).unwrap_err();
    assert!(err.to_string().contains("missing formats"), "{err}");
}

#[test]
fn plan_rejects_unsupported_simd_fma_kind() {
    // The former `panic!` in GemmKind::src_fmt, surfaced as a typed
    // error through the plan builder.
    let session = Session::new();
    for bad in [GemmKind::FmaSimd(ScalarFmt::D), GemmKind::FmaSimd(ScalarFmt::B)] {
        let err = session.gemm().kind(bad).dims(16, 16, 16).unwrap_err();
        assert!(
            err.to_string().contains("unsupported SIMD FMA format"),
            "wrong message for {bad:?}: {err}"
        );
        assert!(bad.validate().is_err());
        assert!(bad.try_src_fmt().is_err());
        assert!(bad.try_dst_fmt().is_err());
    }
}

#[test]
fn plan_rejects_kind_format_mismatch() {
    let session = Session::new();
    let err = session.gemm().kind(GemmKind::FmaF64).src(FP8).dims(16, 16, 16).unwrap_err();
    assert!(err.to_string().contains("streams FP64 sources"), "{err}");
}

#[test]
fn plan_rejects_bad_dims() {
    let session = Session::new();
    let err = session.gemm().src(FP8).acc(FP16).dims(10, 16, 16).unwrap_err();
    assert!(err.to_string().contains("M (10)"), "{err}");
    let err = session.gemm().src(FP8).acc(FP16).dims(16, 15, 16).unwrap_err();
    assert!(err.to_string().contains("N (15)"), "{err}");
    let err = session.gemm().src(FP8).acc(FP16).dims(16, 16, 12).unwrap_err();
    assert!(err.to_string().contains("K (12)"), "{err}");
    let err = session.gemm().src(FP64).acc(FP64).dims(0, 8, 8).unwrap_err();
    assert!(err.to_string().contains("positive"), "{err}");
}

#[test]
fn plan_rejects_tcdm_overflow_in_cycle_mode() {
    let cycle = Session::builder().mode(ExecMode::CycleAccurate).build();
    let err = cycle.gemm().kind(GemmKind::FmaF64).dims(256, 256, 256).unwrap_err();
    assert!(err.to_string().contains("128 kB"), "{err}");
    // The same problem is fine on the functional engine.
    assert!(Session::new().gemm().kind(GemmKind::FmaF64).dims(256, 256, 256).is_ok());
}

#[test]
fn plan_rejects_non_rne_rounding_with_cycle_accurate() {
    let s = Session::builder().mode(ExecMode::CycleAccurate).rounding(RoundingMode::Rtz).build();
    let err = s.gemm().src(FP8).acc(FP16).dims(16, 16, 16).unwrap_err();
    assert!(err.to_string().contains("rounds RNE"), "{err}");
}

#[test]
fn run_rejects_wrong_operand_shapes_and_formats() {
    let session = Session::new();
    let plan = session.gemm().src(FP8).acc(FP16).dims(16, 16, 16).unwrap();
    let (a, b) = mats(16, 16, 16, 8);
    let err = plan.run_f64(&a[..100], &b).unwrap_err();
    assert!(err.to_string().contains("A must be 16x16"), "{err}");
    let wrong_fmt = session.tensor(&a, 16, 16, FP16).unwrap();
    let ok_b = session.tensor(&b, 16, 16, FP8).unwrap();
    let err = plan.run(&wrong_fmt, &ok_b).unwrap_err();
    assert!(err.to_string().contains("cast it first"), "{err}");
    let small = session.tensor(&a[..16 * 8], 8, 16, FP8).unwrap();
    let err = plan.run(&small, &ok_b).unwrap_err();
    assert!(err.to_string().contains("A must be 16x16"), "{err}");
}

// ----------------------------------------------------------- tensors

#[test]
fn tensor_packing_matches_batch_engine_packers() {
    let (rows, cols) = (8, 16);
    let mut rng = Rng::new(19);
    let data: Vec<f64> = (0..rows * cols).map(|_| rng.gaussian()).collect();
    let rm = RoundingMode::Rne;
    let row = MfTensor::from_f64(&data, rows, cols, FP8, rm).unwrap();
    assert_eq!(row.words(), &pack_rows_m::<Fp8>(&data, rows, cols, rm)[..]);
    let col = MfTensor::from_f64_with_layout(&data, rows, cols, FP8, Layout::ColMajor, rm).unwrap();
    assert_eq!(col.words(), &pack_cols_m::<Fp8>(&data, rows, cols, rm)[..]);
    // Decoding either layout recovers the quantized matrix, row-major.
    let q = quantize_f64(&data, FP8);
    assert_eq!(row.to_f64(), q);
    assert_eq!(col.to_f64(), q);
    assert_eq!(row.with_layout(Layout::ColMajor).unwrap(), col);

    // Custom (non-paper) formats take the descriptor-driven fallback
    // packer; quantization must still match the softfloat grid.
    let e6m9 = FpFormat::new(6, 9); // width 16, 4 lanes — not a paper format
    let t = MfTensor::from_f64(&data, rows, cols, e6m9, rm).unwrap();
    for r in 0..rows {
        for c in 0..cols {
            let want = crate::softfloat::from_f64(data[r * cols + c], e6m9, rm);
            assert_eq!(t.bits(r, c), want, "({r},{c})");
        }
    }
}

#[test]
fn tensor_get_view_and_bits() {
    let data = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
    let t = MfTensor::from_f64(&data, 2, 4, FP16, RoundingMode::Rne).unwrap();
    assert_eq!(t.get(0, 0), 1.0);
    assert_eq!(t.get(1, 3), 8.0);
    assert_eq!(t.view().get(1, 0), 5.0);
    assert_eq!(t.bits(0, 0), crate::softfloat::from_f64(1.0, FP16, RoundingMode::Rne));
    assert_eq!(t.len(), 8);
    assert_eq!(t.layout(), Layout::RowMajor);
    // from_bits round-trips the packed words.
    let rebuilt = MfTensor::from_bits(t.words().to_vec(), 2, 4, FP16, Layout::RowMajor).unwrap();
    assert_eq!(rebuilt, t);
}

#[test]
fn tensor_cast_matches_cast_slice() {
    let mut rng = Rng::new(23);
    let data: Vec<f64> = (0..8 * 8).map(|_| rng.gaussian()).collect();
    let rm = RoundingMode::Rne;
    let t8 = MfTensor::from_f64(&data, 8, 8, FP8, rm).unwrap();
    let t16 = t8.cast(FP16, rm).unwrap();
    assert_eq!(t16.fmt(), FP16);
    for r in 0..8 {
        for c in 0..8 {
            let want = crate::softfloat::cast(FP8, FP16, t8.bits(r, c), rm);
            assert_eq!(t16.bits(r, c), want, "({r},{c})");
        }
    }
    // Casting back down is a value-level round trip for FP8-grid data.
    let back = t16.cast(FP8, rm).unwrap();
    assert_eq!(back.to_f64(), t8.to_f64());
}

#[test]
fn tensor_shape_validation() {
    let data = vec![0.0; 12];
    let err = MfTensor::from_f64(&data, 3, 4, FP8, RoundingMode::Rne).unwrap_err();
    assert!(err.to_string().contains("8 lanes"), "{err}");
    let err = MfTensor::from_f64(&data, 4, 4, FP8, RoundingMode::Rne).unwrap_err();
    assert!(err.to_string().contains("does not match"), "{err}");
    let err = MfTensor::from_bits(vec![0; 3], 2, 8, FP8, Layout::RowMajor).unwrap_err();
    assert!(err.to_string().contains("word count"), "{err}");
}

// ---------------------------------------------------------- accuracy

#[test]
fn accumulate_plan_matches_engine_paths() {
    type Engine = fn(FpFormat, FpFormat, usize, u64) -> crate::accuracy::AccuracyPoint;
    for (mode, gold) in [
        (ExecMode::Functional, crate::accuracy::accumulate_fast as Engine),
        (ExecMode::CycleAccurate, crate::accuracy::accumulate as Engine),
    ] {
        let session = Session::builder().mode(mode).seed(77).build();
        let plan = session.accumulate().src(FP8).acc(FP16).n(500).unwrap();
        let got = plan.run();
        let want = gold(FP8, FP16, 500, 77);
        assert_eq!(got.err_exsdotp.to_bits(), want.err_exsdotp.to_bits(), "{mode:?}");
        assert_eq!(got.err_exfma.to_bits(), want.err_exfma.to_bits(), "{mode:?}");
    }
}

#[test]
fn accumulate_sweep_matches_table4_averaged() {
    // plan.mean(draws) must reproduce accuracy::table4_averaged's
    // numbers exactly (same sweep_seed schedule, same engine).
    let session = Session::new();
    let rows = crate::accuracy::table4_averaged(4);
    for &(src, dst, n, want_f, want_c) in &rows {
        let (got_f, got_c) = session.accumulate().src(src).acc(dst).n(n).unwrap().mean(4);
        assert_eq!(got_f.to_bits(), want_f.to_bits(), "{}→{} n={n}", src.name(), dst.name());
        assert_eq!(got_c.to_bits(), want_c.to_bits(), "{}→{} n={n}", src.name(), dst.name());
    }
}

#[test]
fn accumulate_plan_rejects_bad_pairs() {
    let session = Session::new();
    let err = session.accumulate().src(FP16).acc(FP16).n(500).unwrap_err();
    assert!(err.to_string().contains("2*p_src <= p_dst"), "{err}");
    let err = session.accumulate().src(FP8).acc(FP16).n(1).unwrap_err();
    assert!(err.to_string().contains("at least one dot-product pair"), "{err}");
    let err = session.accumulate().src(FP8).n(500).unwrap_err();
    assert!(err.to_string().contains("missing formats"), "{err}");
    // The harness cannot honor a non-RNE session; that is a typed
    // error, not a silently-ignored knob.
    let rtz = Session::builder().rounding(RoundingMode::Rtz).build();
    let err = rtz.accumulate().src(FP8).acc(FP16).n(500).unwrap_err();
    assert!(err.to_string().contains("rounds RNE"), "{err}");
    // FP8alt (e4m3) has p=4; 2·4=8 ≤ 11, exp range 4 ≤ 5: legal.
    assert!(session.accumulate().src(FP8ALT).acc(FP16).n(500).is_ok());
}

// --------------------------------------------------------- CLI parse

#[test]
fn parse_helpers_accept_valid_and_reject_invalid() {
    assert_eq!(parse_size("128x128").unwrap(), (128, 128));
    assert_eq!(parse_size("64x256").unwrap(), (64, 256));
    for bad in ["banana", "128", "x128", "128x", "0x64", "-8x8", "8x-8"] {
        let err = parse_size(bad).unwrap_err();
        assert!(err.to_string().contains("--size must be MxN"), "{bad}: {err}");
    }
    assert_eq!(parse_kernel("fp8").unwrap(), GemmKind::ExSdotp(OpWidth::BtoH));
    assert_eq!(parse_kernel("fp16to32").unwrap(), GemmKind::ExSdotp(OpWidth::HtoS));
    assert_eq!(parse_kernel("fp64").unwrap(), GemmKind::FmaF64);
    let err = parse_kernel("fp12").unwrap_err();
    assert!(err.to_string().contains("--kernel must be"), "{err}");
    assert_eq!(parse_mode("cycle").unwrap(), ExecMode::CycleAccurate);
    assert_eq!(parse_mode("functional").unwrap(), ExecMode::Functional);
    let err = parse_mode("warp").unwrap_err();
    assert!(err.to_string().contains("--mode must be"), "{err}");
}

// ------------------------------------------- alt pairs and transposes

#[test]
fn alt_expanding_pairs_run_functionally_and_match_the_monomorphized_engine() {
    use crate::batch::gemm_m;
    use crate::formats::spec::{Fp16, Fp16alt, Fp32, Fp8alt};
    let (m, n, k) = (16, 16, 16);
    let (a, b) = mats(m, n, k, 40);
    let session = Session::builder().mode(ExecMode::Functional).build();
    // FP8alt→FP16 (the HFP8 forward pair).
    let run = session
        .gemm()
        .src(FP8ALT)
        .acc(FP16)
        .dims(m, n, k)
        .expect("alt pair is functional-legal")
        .run_f64(&a, &b)
        .expect("run");
    let want = gemm_m::<Fp8alt, Fp16>(m, n, k, &a, &b, RoundingMode::Rne);
    assert_eq!(bits_of(&run.c_f64()), bits_of(&want));
    assert_eq!(run.c.fmt(), FP16);
    // FP16alt→FP32.
    let run = session
        .gemm()
        .src(crate::formats::FP16ALT)
        .acc(FP32)
        .dims(m, n, k)
        .expect("alt pair")
        .run_f64(&a, &b)
        .expect("run");
    let want = gemm_m::<Fp16alt, Fp32>(m, n, k, &a, &b, RoundingMode::Rne);
    assert_eq!(bits_of(&run.c_f64()), bits_of(&want));
}

#[test]
fn alt_pairs_are_rejected_cycle_accurately() {
    let session = Session::builder().mode(ExecMode::CycleAccurate).build();
    let err = session.gemm().src(FP8ALT).acc(FP16).dims(16, 16, 16).unwrap_err();
    assert!(err.to_string().contains("src_is_alt"), "{err}");
    assert!(err.to_string().contains("functional"), "{err}");
}

/// Reference: C = Aᵀ·B via pre-transposing on the host and running the
/// plain plan — the transposed plan must be bit-identical.
fn host_transpose(x: &[f64], rows: usize, cols: usize) -> Vec<f64> {
    let mut out = vec![0f64; x.len()];
    for r in 0..rows {
        for c in 0..cols {
            out[c * rows + r] = x[r * cols + c];
        }
    }
    out
}

#[test]
fn transposed_plans_match_pretransposed_plain_plans() {
    let session = Session::builder().mode(ExecMode::Functional).build();
    let (m, n, k) = (16, 8, 24);
    for (src, acc) in [(FP8, FP16), (FP16, FP32), (FP32, FP32), (FP64, FP64)] {
        // A^T·B: raw A is k×m.
        let mut rng = Rng::new(71);
        let a_raw: Vec<f64> = (0..k * m).map(|_| rng.gaussian() * 0.25).collect();
        let b: Vec<f64> = (0..k * n).map(|_| rng.gaussian() * 0.25).collect();
        let tn = session
            .gemm()
            .src(src)
            .acc(acc)
            .transpose_a()
            .dims(m, n, k)
            .expect("plan")
            .run_f64(&a_raw, &b)
            .expect("run");
        let plain = session
            .gemm()
            .src(src)
            .acc(acc)
            .dims(m, n, k)
            .expect("plan")
            .run_f64(&host_transpose(&a_raw, k, m), &b)
            .expect("run");
        assert_eq!(bits_of(&tn.c_f64()), bits_of(&plain.c_f64()), "{}: A^T·B", src.name());
        // A·B^T: raw B is n×k.
        let a: Vec<f64> = (0..m * k).map(|_| rng.gaussian() * 0.25).collect();
        let b_raw: Vec<f64> = (0..n * k).map(|_| rng.gaussian() * 0.25).collect();
        let nt = session
            .gemm()
            .src(src)
            .acc(acc)
            .transpose_b()
            .dims(m, n, k)
            .expect("plan")
            .run_f64(&a, &b_raw)
            .expect("run");
        let plain = session
            .gemm()
            .src(src)
            .acc(acc)
            .dims(m, n, k)
            .expect("plan")
            .run_f64(&a, &host_transpose(&b_raw, n, k))
            .expect("run");
        assert_eq!(bits_of(&nt.c_f64()), bits_of(&plain.c_f64()), "{}: A·B^T", src.name());
    }
}

#[test]
fn transposed_tensor_runs_take_the_packed_route() {
    // The training backward pass feeds tensors whose storage already
    // streams the kernel: A^T·B wants A column-major + B column-major,
    // A·B^T wants both row-major. Assert the zero-repack route actually
    // runs and agrees with the f64 path.
    let session = Session::builder().mode(ExecMode::Functional).build();
    let (m, n, k) = (8, 8, 16);
    let mut rng = Rng::new(90);
    let a_raw: Vec<f64> = (0..k * m).map(|_| rng.gaussian() * 0.25).collect();
    let b: Vec<f64> = (0..k * n).map(|_| rng.gaussian() * 0.25).collect();
    let plan = session.gemm().src(FP8).acc(FP16).transpose_a().dims(m, n, k).expect("plan");
    let at = session.tensor_with_layout(&a_raw, k, m, FP8, Layout::ColMajor).expect("tensor");
    let bt = session.tensor_with_layout(&b, k, n, FP8, Layout::ColMajor).expect("tensor");
    let fast = plan.run(&at, &bt).expect("run");
    assert!(fast.packed_input, "A^T·B with matching layouts must run packed");
    let slow = plan.run_f64(&a_raw, &b).expect("run");
    assert_eq!(fast.c, slow.c);

    let a: Vec<f64> = (0..m * k).map(|_| rng.gaussian() * 0.25).collect();
    let b_raw: Vec<f64> = (0..n * k).map(|_| rng.gaussian() * 0.25).collect();
    let plan = session.gemm().src(FP8).acc(FP16).transpose_b().dims(m, n, k).expect("plan");
    let at = session.tensor(&a, m, k, FP8).expect("tensor");
    let bt = session.tensor(&b_raw, n, k, FP8).expect("tensor");
    let fast = plan.run(&at, &bt).expect("run");
    assert!(fast.packed_input, "A·B^T with matching layouts must run packed");
    let slow = plan.run_f64(&a, &b_raw).expect("run");
    assert_eq!(fast.c, slow.c);
}

#[test]
fn transpose_builder_rejections() {
    let session = Session::builder().mode(ExecMode::Functional).build();
    let err =
        session.gemm().src(FP8).acc(FP16).transpose_a().transpose_b().dims(16, 16, 16).unwrap_err();
    assert!(err.to_string().contains("cannot be combined"), "{err}");
    let cyc = Session::builder().mode(ExecMode::CycleAccurate).build();
    let err = cyc.gemm().src(FP8).acc(FP16).transpose_a().dims(16, 16, 16).unwrap_err();
    assert!(err.to_string().contains("functional batch engine"), "{err}");
    // Transposed operand shape errors name the raw (untransposed) shape.
    let plan = session.gemm().src(FP8).acc(FP16).transpose_a().dims(16, 16, 16).expect("plan");
    let bad = session.tensor(&vec![0.0; 16 * 8], 16, 8, FP8).expect("tensor");
    let good = session.tensor(&vec![0.0; 16 * 16], 16, 16, FP8).expect("tensor");
    let err = plan.run(&bad, &good).unwrap_err();
    assert!(err.to_string().contains("A must be 16x16"), "{err}");
}

// ------------------------------------------------------ plan instances

#[test]
fn instance_run_f64_bit_identical_to_plan_both_modes() {
    // A compiled PlanInstance must reproduce the one-shot plan exactly,
    // in both engines, across repeated runs on the same workspace.
    let (m, n, k) = (16, 16, 16);
    for mode in [ExecMode::Functional, ExecMode::CycleAccurate] {
        let session = Session::builder().mode(mode).build();
        let plan = session.gemm().src(FP8).acc(FP16).dims(m, n, k).unwrap();
        let mut inst = plan.instance();
        let mut out = Vec::new();
        for seed in [3u64, 4, 5] {
            let (a, b) = mats(m, n, k, seed);
            let want = plan.run_f64(&a, &b).unwrap();
            let info = inst.run_f64_into(&a, &b, &mut out).unwrap();
            assert_eq!(bits_of(&out), bits_of(&want.c_f64()), "seed {seed} {mode:?}");
            assert_eq!(info.cycles, want.cycles);
            assert_eq!(info.flops, want.flops);
            assert_eq!(info.mode, mode);
            assert_eq!(info.stats.is_some(), want.stats.is_some());
        }
        assert_eq!(inst.runs(), 3);
    }
}

#[test]
fn instance_run_into_routes_and_matches_plan_run() {
    // Packed fast path (A row-major, B col-major) and the decode
    // fallback (B row-major) both match GemmPlan::run bit for bit, and
    // the packed counter tracks the route.
    let (m, n, k) = (16, 16, 16);
    let session = Session::new();
    for (src, dst) in [(FP8, FP16), (FP16, FP32)] {
        let plan = session.gemm().src(src).acc(dst).dims(m, n, k).unwrap();
        let mut inst = plan.instance();
        let mut out = Vec::new();
        let (a, b) = mats(m, n, k, 21);
        let ta = session.tensor(&a, m, k, src).unwrap();
        let tb_col = session.tensor_with_layout(&b, k, n, src, Layout::ColMajor).unwrap();
        let tb_row = session.tensor(&b, k, n, src).unwrap();
        let fast = inst.run_into(&ta, &tb_col, &mut out).unwrap();
        assert!(fast.packed_input, "{}→{} packed route must run", src.name(), dst.name());
        assert_eq!(bits_of(&out), bits_of(&plan.run(&ta, &tb_col).unwrap().c_f64()));
        let slow = inst.run_into(&ta, &tb_row, &mut out).unwrap();
        assert!(!slow.packed_input);
        assert_eq!(bits_of(&out), bits_of(&plan.run(&ta, &tb_row).unwrap().c_f64()));
        assert_eq!(inst.runs(), 2);
        assert_eq!(inst.packed_runs(), 1);
        assert!(inst.workspace_bytes() > 0, "fallback route must have populated the workspace");
    }
}

#[test]
fn instance_transposed_shapes_match_plan() {
    // The backward-pass shapes through an instance == the one-shot plan.
    let (m, n, k) = (8, 16, 24);
    let session = Session::new();
    let mut rng = Rng::new(88);
    let at: Vec<f64> = (0..k * m).map(|_| rng.gaussian() * 0.25).collect(); // k×m (untransposed A)
    let b: Vec<f64> = (0..k * n).map(|_| rng.gaussian() * 0.25).collect();
    let plan = session.gemm().src(FP8).acc(FP16).transpose_a().dims(m, n, k).unwrap();
    let mut inst = plan.instance();
    let mut out = Vec::new();
    inst.run_f64_into(&at, &b, &mut out).unwrap();
    assert_eq!(bits_of(&out), bits_of(&plan.run_f64(&at, &b).unwrap().c_f64()));
    // Packed route with both streams in kernel layout (A col-major
    // because it arrives untransposed, B col-major as usual).
    let ta = session.tensor_with_layout(&at, k, m, FP8, Layout::ColMajor).unwrap();
    let tb = session.tensor_with_layout(&b, k, n, FP8, Layout::ColMajor).unwrap();
    let info = inst.run_into(&ta, &tb, &mut out).unwrap();
    assert!(info.packed_input);
    assert_eq!(bits_of(&out), bits_of(&plan.run(&ta, &tb).unwrap().c_f64()));
}

#[test]
fn instance_bound_operands_match_unbound_runs() {
    let (m, n, k) = (16, 16, 16);
    let session = Session::new();
    let (a, b) = mats(m, n, k, 61);
    let plan = session.gemm().src(FP8).acc(FP16).dims(m, n, k).unwrap();
    let ta = session.tensor(&a, m, k, FP8).unwrap();
    let tb = session.tensor_with_layout(&b, k, n, FP8, Layout::ColMajor).unwrap();
    let mut inst = plan.instance();
    let mut out = Vec::new();
    // run_reusing needs a bound B.
    assert!(inst.run_reusing(&ta, &mut out).is_err());
    inst.bind_b(&tb).unwrap();
    let reused = inst.run_reusing(&ta, &mut out).unwrap();
    assert!(reused.packed_input);
    let want = plan.run(&ta, &tb).unwrap();
    assert_eq!(bits_of(&out), bits_of(&want.c_f64()));
    // Fully bound.
    inst.bind_a(&ta).unwrap();
    inst.run_bound(&mut out).unwrap();
    assert_eq!(bits_of(&out), bits_of(&want.c_f64()));
    // Format/shape validation on bind is typed.
    let wrong_fmt = session.tensor(&b, k, n, FP16).unwrap();
    assert!(plan.instance().bind_b(&wrong_fmt).is_err(), "FP16 B on an FP8 plan must be rejected");
    let wrong_shape = session.tensor(&a[..8 * k], 8, k, FP8).unwrap();
    assert!(plan.instance().bind_a(&wrong_shape).is_err(), "8×k A on a 16×k plan must be rejected");
}

#[test]
fn instance_lane_tiers_bit_identical_at_blocked_shape() {
    // 32×128×512 FP8→FP16 crosses the BlockPlan threshold (wpr = 64,
    // n·wpr = 8192), so the instance's packed route runs the SWAR tier
    // cache-blocked; the pinned scalar tier through the same instance
    // must reproduce it bit for bit.
    use crate::batch::{with_lane_tier, BlockPlan, LaneTier};
    let (m, n, k) = (32, 128, 512);
    assert!(BlockPlan::for_problem(m, n, k / 8).blocked);
    let session = Session::new();
    let (a, b) = mats(m, n, k, 77);
    let plan = session.gemm().src(FP8).acc(FP16).dims(m, n, k).unwrap();
    let ta = session.tensor(&a, m, k, FP8).unwrap();
    let tb = session.tensor_with_layout(&b, k, n, FP8, Layout::ColMajor).unwrap();
    let mut inst = plan.instance();
    inst.bind_a(&ta).unwrap();
    inst.bind_b(&tb).unwrap();
    let mut swar = Vec::new();
    inst.run_bound(&mut swar).unwrap();
    let mut scalar = Vec::new();
    with_lane_tier(LaneTier::Scalar, || inst.run_bound(&mut scalar).unwrap());
    assert_eq!(inst.packed_runs(), inst.runs(), "both runs must ride the packed route");
    assert_eq!(bits_of(&swar), bits_of(&scalar));
}

#[test]
fn session_executor_handle_reflects_thread_budget() {
    use crate::util::parallel::{worker_count, Executor};
    let narrow = Session::builder().threads(2).build();
    assert_eq!(narrow.executor().budget(), Some(2));
    assert_eq!(narrow.executor().workers(), 2);
    assert_eq!(narrow.executor().scoped(worker_count), 2);
    let wide = Session::new();
    assert_eq!(wide.executor().budget(), None);
    assert_eq!(wide.executor().workers(), Executor::global().size());
}

#[test]
fn tensor_reusing_is_bit_identical_and_recycles() {
    let (rows, cols) = (8, 16);
    let (a, _) = mats(rows, 1, cols, 13); // a is rows×cols values
    let session = Session::new();
    let fresh = session.tensor(&a, rows, cols, FP8).unwrap();
    // A dirty recycled buffer must not leak into the packed words.
    let dirty = vec![0xFFFF_FFFF_FFFF_FFFFu64; 3];
    let reused = session.tensor_reusing(&a, rows, cols, FP8, Layout::RowMajor, dirty).unwrap();
    assert_eq!(fresh, reused);
    let words = reused.into_words();
    assert_eq!(words, fresh.words());
    // Round-trip the storage back in, col-major this time.
    let col = session.tensor_reusing(&a, rows, cols, FP8, Layout::ColMajor, words).unwrap();
    assert_eq!(col, session.tensor_with_layout(&a, rows, cols, FP8, Layout::ColMajor).unwrap());
}
