//! [`ServePlan`] — the validated front door to the serving subsystem,
//! mirroring [`crate::api::GemmPlan`] / [`crate::api::TrainPlan`]'s
//! builder style.
//!
//! `session.server().tenant("hfp8", model).max_batch(64).build()?`
//! checks everything a server needs before any request exists: the
//! session drives the functional engine, tenant names are unique,
//! the knobs are sane (batching mode, queue cap, per-tenant rate
//! limits included), and — per tenant, per layer — a **probe
//! [`crate::api::GemmPlan`]** is built for both the smallest padded
//! batch and the largest one, so an unsupported policy pair or a
//! lane-infeasible layer width is a typed error here, never a panic
//! (or a mid-trace failure) later.
//!
//! ```
//! use minifloat_nn::prelude::*;
//! use minifloat_nn::serve::InferenceModel;
//!
//! # fn main() -> minifloat_nn::util::error::Result<()> {
//! let session = Session::builder().seed(3).build();
//! let mut tr = session.native_trainer(PrecisionPolicy::hfp8())?;
//! tr.train(5, 0)?;
//! let model = InferenceModel::freeze(&session, tr.model(), tr.policy())?;
//! let plan = session
//!     .server()
//!     .tenant("prod", model)
//!     .max_batch(32)
//!     .queue_cap(256)
//!     .rate_limit("prod", 8.0, 32)
//!     .build()?;
//! let server = plan.server();
//! assert_eq!(server.tenants().len(), 1);
//! # Ok(())
//! # }
//! ```

use super::session::Session;
use crate::kernels::gemm::ExecMode;
use crate::serve::admission::RateLimit;
use crate::serve::batcher::{pad_rows, BatchMode, BatchPolicy, ROW_PAD};
use crate::serve::model::InferenceModel;
use crate::serve::worker::{Server, Tenant};
use crate::util::error::{Context, Result};
use crate::{bail, ensure};

/// Range-check the serving knobs. Shared by [`ServePlanBuilder::build`]
/// and the `repro serve` CLI, which wants to reject a bad knob *before*
/// spending seconds training in-process tenant models.
pub fn validate_knobs(max_batch: usize, max_wait_ticks: u64, shards: usize) -> Result<()> {
    ensure!(
        (1..=4096).contains(&max_batch),
        "max_batch ({max_batch}) must be in 1..=4096 (--max-batch)"
    );
    ensure!((1..=256).contains(&shards), "shard count ({shards}) must be in 1..=256 (--shards)");
    // Bounded so tick arithmetic (`arrival + max_wait`, the drain
    // bound) can never overflow u64 within any plausible trace.
    ensure!(
        max_wait_ticks <= 1 << 40,
        "max_wait_ticks ({max_wait_ticks}) must be at most 2^40 (--max-wait)"
    );
    Ok(())
}

/// Range-check a bounded-queue capacity. Shared with the CLI like
/// [`validate_knobs`].
pub fn validate_queue_cap(cap: usize) -> Result<()> {
    ensure!(
        (1..=1 << 20).contains(&cap),
        "queue_cap ({cap}) must be in 1..=2^20 requests (--queue-cap; omit for unbounded)"
    );
    Ok(())
}

/// Builder returned by [`Session::server`]; add at least one tenant,
/// every knob has a sensible default (batch 32, wait 4 ticks, 1 shard,
/// continuous batching, unbounded queues, no rate limits).
#[derive(Clone, Debug)]
pub struct ServePlanBuilder<'s> {
    session: &'s Session,
    tenants: Vec<Tenant>,
    max_batch: usize,
    max_wait_ticks: u64,
    shards: usize,
    mode: BatchMode,
    queue_cap: Option<usize>,
    rate_limits: Vec<(String, f64, u64)>,
}

impl<'s> ServePlanBuilder<'s> {
    pub(crate) fn new(session: &'s Session) -> Self {
        ServePlanBuilder {
            session,
            tenants: Vec::new(),
            max_batch: 32,
            max_wait_ticks: 4,
            shards: 1,
            mode: BatchMode::default(),
            queue_cap: None,
            rate_limits: Vec::new(),
        }
    }

    /// Register a tenant: a name plus its frozen model. Call once per
    /// tenant; names must be unique.
    pub fn tenant(mut self, name: &str, model: InferenceModel) -> Self {
        self.tenants.push(Tenant { name: name.to_string(), model });
        self
    }

    /// Largest logical batch one dispatch coalesces (default 32;
    /// `--max-batch` on the CLI).
    pub fn max_batch(mut self, n: usize) -> Self {
        self.max_batch = n;
        self
    }

    /// Longest a request may queue before its tenant dispatches anyway
    /// (default 4 ticks; `--max-wait` on the CLI). Only the WholeBatch
    /// mode waits — continuous batching admits every tick.
    pub fn max_wait_ticks(mut self, t: u64) -> Self {
        self.max_wait_ticks = t;
        self
    }

    /// Parallel shards in the worker pool (default 1; `--shards` on
    /// the CLI). Responses are bit-identical at any shard count.
    pub fn shards(mut self, n: usize) -> Self {
        self.shards = n;
        self
    }

    /// Wave scheduling mode (default [`BatchMode::Continuous`];
    /// `--batching` on the CLI). [`BatchMode::WholeBatch`] pins the
    /// legacy run-to-completion policy as the differential/timing
    /// reference.
    pub fn batching(mut self, mode: BatchMode) -> Self {
        self.mode = mode;
        self
    }

    /// Bound every tenant queue to `cap` pending requests; overflow is
    /// shed with a typed [`crate::serve::ShedReason::QueueFull`]
    /// (default unbounded; `--queue-cap` on the CLI).
    pub fn queue_cap(mut self, cap: usize) -> Self {
        self.queue_cap = Some(cap);
        self
    }

    /// Token-bucket rate limit for one tenant: `per_tick` requests per
    /// tick sustained (fractional allowed), `burst` requests of
    /// headroom. Validated (tenant name, ranges) at [`build`];
    /// submissions beyond the budget are shed with
    /// [`crate::serve::ShedReason::RateLimited`].
    ///
    /// [`build`]: ServePlanBuilder::build
    pub fn rate_limit(mut self, tenant: &str, per_tick: f64, burst: u64) -> Self {
        self.rate_limits.push((tenant.to_string(), per_tick, burst));
        self
    }

    /// Validate everything and return the runnable plan.
    pub fn build(self) -> Result<ServePlan> {
        ensure!(
            self.session.mode() == ExecMode::Functional,
            "serving runs on the functional batch engine (request batches are not \
             cycle-accurate workloads); build the session with ExecMode::Functional"
        );
        ensure!(
            !self.tenants.is_empty(),
            "a server needs at least one tenant (ServePlanBuilder::tenant / --tenants)"
        );
        validate_knobs(self.max_batch, self.max_wait_ticks, self.shards)?;
        if let Some(cap) = self.queue_cap {
            validate_queue_cap(cap)?;
        }
        for (i, t) in self.tenants.iter().enumerate() {
            ensure!(!t.name.is_empty(), "tenant {i} has an empty name");
            ensure!(
                !self.tenants[..i].iter().any(|o| o.name == t.name),
                "duplicate tenant name '{}'",
                t.name
            );
            t.model.validate()?;
            t.model.policy().validate()?;
            // Probe-build one GEMM plan per layer at the smallest and
            // largest padded batch shapes, so every plan the shards will
            // ever build is known runnable (typed errors here, not
            // mid-trace).
            for rows in [ROW_PAD, pad_rows(self.max_batch)] {
                for l in t.model.layers() {
                    self.session
                        .gemm()
                        .src(t.model.policy().fwd)
                        .acc(t.model.policy().acc)
                        .dims(rows, l.out_dim, l.in_dim)?;
                }
            }
        }
        let mut limits: Vec<Option<RateLimit>> = vec![None; self.tenants.len()];
        for (name, rate, burst) in &self.rate_limits {
            let Some(i) = self.tenants.iter().position(|t| &t.name == name) else {
                bail!("rate limit names unknown tenant '{name}'");
            };
            ensure!(limits[i].is_none(), "duplicate rate limit for tenant '{name}'");
            limits[i] = Some(
                RateLimit::per_tick(*rate, *burst)
                    .with_context(|| format!("rate limit for tenant '{name}'"))?,
            );
        }
        Ok(ServePlan {
            session: *self.session,
            tenants: self.tenants,
            policy: BatchPolicy {
                max_batch: self.max_batch,
                max_wait_ticks: self.max_wait_ticks,
                mode: self.mode,
            },
            shards: self.shards,
            queue_cap: self.queue_cap,
            limits,
        })
    }
}

/// A fully validated serving configuration. Constructed only through
/// [`ServePlanBuilder::build`]; [`ServePlan::server`] materializes the
/// stateful [`Server`] (queues, shard pool, stats).
#[derive(Clone, Debug)]
pub struct ServePlan {
    session: Session,
    tenants: Vec<Tenant>,
    policy: BatchPolicy,
    shards: usize,
    queue_cap: Option<usize>,
    limits: Vec<Option<RateLimit>>,
}

impl ServePlan {
    /// The batching knobs.
    pub fn batch_policy(&self) -> BatchPolicy {
        self.policy
    }

    /// The wave scheduling mode.
    pub fn batch_mode(&self) -> BatchMode {
        self.policy.mode
    }

    /// The bounded-queue capacity, if one was set.
    pub fn queue_cap(&self) -> Option<usize> {
        self.queue_cap
    }

    /// Shards the server will run.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Registered tenant names, in index order.
    pub fn tenant_names(&self) -> Vec<&str> {
        self.tenants.iter().map(|t| t.name.as_str()).collect()
    }

    /// Build a fresh server (clones the frozen models, so one plan can
    /// spawn several servers — e.g. the shard-count determinism tests).
    pub fn server(&self) -> Server {
        Server::assemble(
            self.session,
            self.tenants.clone(),
            self.policy,
            self.shards,
            self.queue_cap,
            self.limits.clone(),
        )
    }
}
