//! Validated op builders: [`GemmPlan`] and [`AccumulatePlan`].
//!
//! A plan is built in two steps — choose formats (or a kernel family),
//! then bind sizes — and **every** invalid combination is rejected with
//! a typed [`crate::util::error::Error`] at plan-build time: unsupported
//! format pairs, divisibility violations, problems that overflow the
//! simulated 128 kB TCDM, rounding modes the cycle-accurate cluster
//! cannot honor. Nothing panics after `dims()`/`n()` return `Ok`.

use super::session::Session;
use super::tensor::MfTensor;
use crate::accuracy::{self, AccuracyPoint};
use crate::core::CoreStats;
use crate::formats::FpFormat;
use crate::kernels::gemm::{ExecMode, GemmKernel, GemmKind};
use crate::softfloat::RoundingMode;
use crate::util::error::Result;
use crate::{bail, ensure};

/// Map one of Table I's six expanding `(src, dst)` pairs onto the
/// kernel family that streams its width class. The alt variants
/// (FP8alt, FP16alt) run the *same* kernel — the FP CSR's
/// `src_is_alt`/`dst_is_alt` bits (§III-E) retarget the datapath without
/// changing the program or its timing — so the issue-slot cycle model
/// carries over unchanged. Returns `None` for pairs outside Table I.
pub(crate) fn expanding_family(src: FpFormat, dst: FpFormat) -> Option<GemmKind> {
    use crate::formats::spec::FormatSpec;
    use crate::isa::instr::OpWidth;
    crate::with_expanding_pair!(src, dst, S, D, {
        Some(match (S::WIDTH, D::WIDTH) {
            (8, _) => GemmKind::ExSdotp(OpWidth::BtoH),
            _ => GemmKind::ExSdotp(OpWidth::HtoS),
        })
    }, {
        None
    })
}

/// Transpose a row-major `rows×cols` matrix into `cols×rows`, into a
/// caller-provided buffer (cleared and resized; capacity reused).
pub(crate) fn transpose_f64_into(src: &[f64], rows: usize, cols: usize, out: &mut Vec<f64>) {
    debug_assert_eq!(src.len(), rows * cols);
    out.clear();
    out.resize(rows * cols, 0f64);
    for r in 0..rows {
        for c in 0..cols {
            out[c * rows + r] = src[r * cols + c];
        }
    }
}

/// Builder returned by [`Session::gemm`]. Pick the kernel either by
/// format pair ([`GemmPlanBuilder::src`] + [`GemmPlanBuilder::acc`]) or
/// directly by family ([`GemmPlanBuilder::kind`]); optionally mark an
/// operand transposed ([`GemmPlanBuilder::transpose_a`] /
/// [`GemmPlanBuilder::transpose_b`] — the training backward-pass
/// shapes); [`GemmPlanBuilder::dims`] validates and finalizes.
#[derive(Clone, Copy, Debug)]
pub struct GemmPlanBuilder<'s> {
    session: &'s Session,
    src: Option<FpFormat>,
    acc: Option<FpFormat>,
    kind: Option<GemmKind>,
    ta: bool,
    tb: bool,
    chunk: Option<usize>,
}

impl<'s> GemmPlanBuilder<'s> {
    pub(crate) fn new(session: &'s Session) -> Self {
        GemmPlanBuilder { session, src: None, acc: None, kind: None, ta: false, tb: false, chunk: None }
    }

    /// Source element format of A and B.
    pub fn src(mut self, fmt: FpFormat) -> Self {
        self.src = Some(fmt);
        self
    }

    /// Accumulation / output format of C.
    pub fn acc(mut self, fmt: FpFormat) -> Self {
        self.acc = Some(fmt);
        self
    }

    /// Select the kernel family directly (alternative to `src`/`acc`).
    pub fn kind(mut self, kind: GemmKind) -> Self {
        self.kind = Some(kind);
        self
    }

    /// Compute `C = Aᵀ·B`: the A operand is handed over *untransposed*
    /// as `k×m` (the weight-gradient shape `Xᵀ·G` of the training
    /// backward pass). Functional engine only.
    pub fn transpose_a(mut self) -> Self {
        self.ta = true;
        self
    }

    /// Compute `C = A·Bᵀ`: the B operand is handed over *untransposed*
    /// as `n×k` (the input-gradient shape `G·Wᵀ` of the training
    /// backward pass). Functional engine only.
    pub fn transpose_b(mut self) -> Self {
        self.tb = true;
        self
    }

    /// Accumulate K in fixed-size chunks of `elems` source elements:
    /// each chunk folds from a fresh zero in the wide format and the
    /// per-chunk sums combine left-to-right (Wang et al. 2018's
    /// chunk-based accumulation — bounds the swamping error of long-K
    /// folds). `elems` must be a positive multiple of the source SIMD
    /// width; `elems ≥ k` degenerates to the naive fold bit-for-bit.
    /// Expanding (ExSdotp) family on the functional engine only.
    pub fn chunk_k(mut self, elems: usize) -> Self {
        self.chunk = Some(elems);
        self
    }

    /// Bind the problem size (`C = A·B` with A `m×k`, B `k×n`) and
    /// validate everything: format pair, kernel kind, divisibility,
    /// rounding-mode compatibility, and (cycle-accurate mode) the
    /// paper's 128 kB TCDM footprint.
    pub fn dims(self, m: usize, n: usize, k: usize) -> Result<GemmPlan<'s>> {
        let _sp = crate::obs::trace::span_with("plan.compile", "api", || {
            format!("\"m\":{m},\"n\":{n},\"k\":{k}")
        });
        crate::obs_count!("api.plan.compiles");
        let kind = match (self.kind, self.src, self.acc) {
            (Some(kind), src, acc) => {
                kind.validate()?;
                if let Some(s) = src {
                    ensure!(
                        kind.try_src_fmt()? == s,
                        "kind {:?} streams {} sources, but .src({}) was requested",
                        kind,
                        kind.try_src_fmt()?.name(),
                        s.name()
                    );
                }
                if let Some(a) = acc {
                    ensure!(
                        kind.try_dst_fmt()? == a,
                        "kind {:?} accumulates into {}, but .acc({}) was requested",
                        kind,
                        kind.try_dst_fmt()?.name(),
                        a.name()
                    );
                }
                kind
            }
            (None, Some(s), Some(a)) => match GemmKind::for_formats(s, a) {
                Ok(kind) => kind,
                // Alt-format expanding pairs (FP8alt→FP16, FP16alt→FP32,
                // …) are hardware-legal via the FP CSR's alt bits but the
                // kernel generators stream the nominal formats, so they
                // run on the functional batch engine only.
                Err(e) => match expanding_family(s, a) {
                    Some(kind) => {
                        ensure!(
                            self.session.mode() == ExecMode::Functional,
                            "the simulated kernels stream nominal formats only; the alt-format \
                             pair {}->{} (FP CSR src_is_alt/dst_is_alt, §III-E) runs on the \
                             functional engine — use ExecMode::Functional / --mode functional",
                            s.name(),
                            a.name()
                        );
                        kind
                    }
                    None => return Err(e),
                },
            },
            (None, Some(_), None) => bail!("missing accumulation format: call .acc(..) (or .kind(..))"),
            (None, None, _) => bail!("missing formats: call .src(..).acc(..) or .kind(..)"),
        };
        let (src_fmt, acc_fmt) = match (self.src, self.acc) {
            (Some(s), Some(a)) => (s, a),
            _ => (kind.try_src_fmt()?, kind.try_dst_fmt()?),
        };
        if self.ta || self.tb {
            ensure!(
                !(self.ta && self.tb),
                "transpose_a and transpose_b cannot be combined (no A^T*B^T kernel; \
                 swap the operands of a single-transpose plan instead)"
            );
            ensure!(
                self.session.mode() == ExecMode::Functional,
                "transposed GEMM shapes (A^T*B / A*B^T — the training backward pass) run on \
                 the functional batch engine; the kernel generators stream A*B only. Use \
                 ExecMode::Functional / --mode functional"
            );
        }
        if self.session.mode() == ExecMode::CycleAccurate {
            ensure!(
                self.session.rounding() == RoundingMode::Rne,
                "the cycle-accurate cluster rounds RNE; use RoundingMode::Rne or ExecMode::Functional \
                 (requested {:?})",
                self.session.rounding()
            );
        }
        if let Some(chunk) = self.chunk {
            ensure!(
                self.session.mode() == ExecMode::Functional,
                "chunked accumulation (chunk_k) runs on the functional batch engine; the \
                 simulated kernels stream the naive ascending-k fold only. Use \
                 ExecMode::Functional / --mode functional"
            );
            ensure!(
                matches!(kind, GemmKind::ExSdotp(_)),
                "chunk_k applies to the expanding (ExSdotp) GEMM family only (requested {:?})",
                kind
            );
            let lanes = src_fmt.lanes_in_64() as usize;
            ensure!(
                chunk >= lanes && chunk % lanes == 0,
                "chunk_k ({chunk}) must be a positive multiple of the SIMD width ({lanes} {} \
                 lanes per packed word)",
                src_fmt.name()
            );
        }
        let kern = GemmKernel::try_new(kind, m, n, k)?;
        if self.session.mode() == ExecMode::CycleAccurate {
            ensure!(
                kern.footprint() <= 128 * 1024,
                "{} {} needs {} bytes of TCDM but the simulated cluster has 128 kB; \
                 the functional engine (ExecMode::Functional / --mode functional) runs \
                 larger problems",
                kind.label(),
                kern.size_label(),
                kern.footprint()
            );
        }
        Ok(GemmPlan {
            session: self.session,
            kern,
            src: src_fmt,
            acc: acc_fmt,
            ta: self.ta,
            tb: self.tb,
            chunk: self.chunk,
        })
    }
}

/// A fully validated GEMM: kernel family + sizes + the session policy
/// that will run it. Constructed only through [`GemmPlanBuilder::dims`],
/// so a `GemmPlan` in hand is proof the problem is runnable.
#[derive(Clone, Copy, Debug)]
pub struct GemmPlan<'s> {
    session: &'s Session,
    kern: GemmKernel,
    src: FpFormat,
    acc: FpFormat,
    ta: bool,
    tb: bool,
    chunk: Option<usize>,
}

impl GemmPlan<'_> {
    /// The kernel family this plan runs.
    pub fn kind(&self) -> GemmKind {
        self.kern.kind
    }

    /// `(m, n, k)`.
    pub fn dims(&self) -> (usize, usize, usize) {
        (self.kern.m, self.kern.n, self.kern.k)
    }

    /// `(transpose_a, transpose_b)` — which operands arrive untransposed
    /// for a transposed product (see [`GemmPlanBuilder::transpose_a`]).
    pub fn transposes(&self) -> (bool, bool) {
        (self.ta, self.tb)
    }

    /// Chunk size (source elements of K per sub-accumulation) when
    /// chunked accumulation is on (see [`GemmPlanBuilder::chunk_k`]).
    pub fn chunk(&self) -> Option<usize> {
        self.chunk
    }

    /// The underlying kernel descriptor (program generator, cycle
    /// model, TCDM layout) — the machine-model escape hatch.
    pub fn kernel(&self) -> &GemmKernel {
        &self.kern
    }

    /// Source element format (may be an alt variant of the kernel
    /// family's nominal format — same width class, CSR-selected).
    pub fn src_fmt(&self) -> FpFormat {
        self.src
    }

    /// Accumulation / output format.
    pub fn acc_fmt(&self) -> FpFormat {
        self.acc
    }

    /// Compile the plan into a reusable [`crate::api::PlanInstance`]:
    /// an owned execution of this exact problem with its own
    /// [`crate::batch::Workspace`] and optional cached operands, so
    /// repeated runs (`run_into` / `run_reusing`) allocate nothing.
    /// The instance copies the session policy (`Session` is `Copy`), so
    /// it outlives this plan's borrow — trainers and serve shards hold
    /// instances across steps/dispatches. One-shot callers keep using
    /// [`GemmPlan::run`] / [`GemmPlan::run_f64`]; both paths are
    /// bit-identical (pinned by `api::tests`).
    pub fn instance(&self) -> super::instance::PlanInstance {
        super::instance::PlanInstance::assemble(
            *self.session,
            self.kern,
            self.src,
            self.acc,
            self.ta,
            self.tb,
            self.chunk,
        )
    }

    /// Run on row-major `f64` matrices (quantized to the source format
    /// on packing, exactly like the pre-API free functions). Transposed
    /// plans take their marked operand *untransposed*: `k×m` for A under
    /// [`GemmPlanBuilder::transpose_a`], `n×k` for B under
    /// [`GemmPlanBuilder::transpose_b`].
    ///
    /// A thin wrapper over a one-shot [`crate::api::PlanInstance`] —
    /// the instance owns the **single** implementation of the run
    /// routing (engine selection, packed fast path, epilogue
    /// re-encode), so the one-shot and reusable paths cannot diverge.
    pub fn run_f64(&self, a: &[f64], b: &[f64]) -> Result<RunReport> {
        let mut inst = self.instance();
        inst.skip_output_regrid(); // report() re-encodes with the same rounding
        let mut c = Vec::new();
        let info = inst.run_f64_into(a, b, &mut c)?;
        self.report(c, info)
    }

    /// Run on typed tensors. `a` must be `m×k` and `b` `k×n` (the
    /// marked operand untransposed — `k×m` / `n×k` — for transposed
    /// plans), both in the plan's source format (cast first otherwise);
    /// any storage layout is accepted.
    ///
    /// When the functional engine is selected and each tensor's storage
    /// already provides the stream the kernel wants — logical-A rows
    /// packed along `k`, logical-B columns packed along `k`; a transpose
    /// flips which [`crate::api::Layout`] that is — the packed words feed the batch
    /// engine **directly**: zero decode/re-pack. All other combinations
    /// restream from the decoded values, which is exact for on-grid
    /// tensors; both routes produce the same C (pinned by the
    /// `tensor_run_*` differential tests). Like [`GemmPlan::run_f64`],
    /// a thin wrapper over a one-shot [`crate::api::PlanInstance`].
    pub fn run(&self, a: &MfTensor, b: &MfTensor) -> Result<RunReport> {
        let mut inst = self.instance();
        inst.skip_output_regrid(); // report() re-encodes with the same rounding
        let mut c = Vec::new();
        let info = inst.run_into(a, b, &mut c)?;
        self.report(c, info)
    }

    /// Materialize a [`RunReport`] from an instance run: re-encode the
    /// (already acc-gridded) C values into a typed tensor — exact, so
    /// the report's tensor is bit-identical to the instance's decoded
    /// output.
    fn report(&self, c: Vec<f64>, info: super::instance::RunInfo) -> Result<RunReport> {
        let (m, n, _) = self.dims();
        let c = self.session.scoped(|| MfTensor::from_f64(&c, m, n, self.acc_fmt(), RoundingMode::Rne))?;
        Ok(RunReport {
            c,
            cycles: info.cycles,
            flops: info.flops,
            stats: info.stats,
            mode: info.mode,
            packed_input: info.packed_input,
            wall: info.wall,
        })
    }
}

/// Structured result of a plan run: the C tensor plus timing and (in
/// cycle-accurate mode) per-core machine stats.
#[derive(Clone, Debug)]
pub struct RunReport {
    /// The output matrix, typed and packed in the accumulation format.
    pub c: MfTensor,
    /// Cluster cycles: simulated ([`ExecMode::CycleAccurate`]), the
    /// analytic issue-slot estimate ([`ExecMode::Functional`] with the
    /// cycle model on), or `None` (cycle model off).
    pub cycles: Option<u64>,
    /// FLOP performed (2·M·N·K).
    pub flops: u64,
    /// Aggregate core stats (cycle-accurate runs only).
    pub stats: Option<CoreStats>,
    /// Which engine produced this report.
    pub mode: ExecMode,
    /// True when the operands' packed words fed the batch engine
    /// directly ([`GemmPlan::run`]'s zero-repack route); false on the
    /// quantize-from-f64 route and in cycle-accurate mode.
    pub packed_input: bool,
    /// Wall-clock time of the run.
    pub wall: std::time::Duration,
}

impl RunReport {
    /// FLOP per cycle across the cluster (Fig. 8's y-axis), when a
    /// cycle count is available.
    pub fn flop_per_cycle(&self) -> Option<f64> {
        self.cycles.map(|cy| self.flops as f64 / cy as f64)
    }

    /// The output decoded to row-major `f64`.
    pub fn c_f64(&self) -> Vec<f64> {
        self.c.to_f64()
    }

    /// Human label for where [`RunReport::cycles`] came from.
    pub fn timing_label(&self) -> &'static str {
        match (self.mode, self.cycles.is_some()) {
            (ExecMode::CycleAccurate, _) => "simulated",
            (ExecMode::Functional, true) => "issue-slot model",
            (ExecMode::Functional, false) => "disabled",
        }
    }
}

// ------------------------------------------------------------ accuracy

/// Builder returned by [`Session::accumulate`] — the Table IV
/// experiment (accumulate `n` Gaussian dot products through the fused
/// ExSdotp unit and the two-ExFMA cascade, against an FP64 golden).
#[derive(Clone, Copy, Debug)]
pub struct AccumulatePlanBuilder<'s> {
    session: &'s Session,
    src: Option<FpFormat>,
    acc: Option<FpFormat>,
}

impl<'s> AccumulatePlanBuilder<'s> {
    pub(crate) fn new(session: &'s Session) -> Self {
        AccumulatePlanBuilder { session, src: None, acc: None }
    }

    /// Source format of the dot-product inputs.
    pub fn src(mut self, fmt: FpFormat) -> Self {
        self.src = Some(fmt);
        self
    }

    /// Accumulation (destination) format.
    pub fn acc(mut self, fmt: FpFormat) -> Self {
        self.acc = Some(fmt);
        self
    }

    /// Bind the number of dot products and validate the format pair
    /// against the ExSdotp datapath constraints (§III-B): the exact
    /// products must fit the padded accumulator (`2·p_src ≤ p_dst`) and
    /// the destination must cover the source dynamic range. These are
    /// the conditions the raw [`crate::exsdotp::ExSdotpUnit`] asserts —
    /// surfaced here as typed errors instead.
    pub fn n(self, n: usize) -> Result<AccumulatePlan<'s>> {
        let (Some(src), Some(dst)) = (self.src, self.acc) else {
            bail!("missing formats: call .src(..).acc(..) before .n(..)");
        };
        ensure!(n >= 2, "n ({n}) must be at least one dot-product pair");
        // The Table IV experiment is defined for RNE; seeded stochastic
        // rounding is also honored (the harness threads the session
        // mode through both engines). Directed modes (Rtz/Rdn/Rup/Rmm)
        // would bias the error metric away from anything in the paper,
        // so they stay rejected — by name, with the supported set.
        ensure!(
            matches!(self.session.rounding(), RoundingMode::Rne | RoundingMode::StochasticRound(_)),
            "the accumulation harness supports RoundingMode::Rne (the Table IV setup) and \
             RoundingMode::StochasticRound; directed modes are not meaningful here \
             (requested {:?})",
            self.session.rounding()
        );
        ensure!(
            2 * src.precision() <= dst.precision(),
            "ExSdotp requires 2*p_src <= p_dst, got {} (p={}) -> {} (p={})",
            src.name(),
            src.precision(),
            dst.name(),
            dst.precision()
        );
        ensure!(
            dst.exp_bits >= src.exp_bits,
            "destination dynamic range must cover the source ({} -> {})",
            src.name(),
            dst.name()
        );
        ensure!(
            2 * dst.precision() + src.precision() + 5 <= 127,
            "internal datapath field for {} -> {} exceeds the 128-bit model width",
            src.name(),
            dst.name()
        );
        Ok(AccumulatePlan { session: self.session, src, dst, n })
    }
}

/// A validated accumulation experiment. [`ExecMode::Functional`]
/// sessions run the monomorphized fast path
/// ([`crate::accuracy::accumulate_fast`]); [`ExecMode::CycleAccurate`]
/// sessions run the descriptor-driven unit path
/// ([`crate::accuracy::accumulate`]). The two are bit-identical for the
/// paper's format pairs (pinned by differential tests), so the choice
/// only trades speed for dispatch fidelity.
#[derive(Clone, Copy, Debug)]
pub struct AccumulatePlan<'s> {
    session: &'s Session,
    src: FpFormat,
    dst: FpFormat,
    n: usize,
}

impl AccumulatePlan<'_> {
    /// `(src, dst)` formats.
    pub fn formats(&self) -> (FpFormat, FpFormat) {
        (self.src, self.dst)
    }

    /// Dot products per run.
    pub fn n(&self) -> usize {
        self.n
    }

    /// One draw with an explicit seed (honors the session rounding
    /// mode — RNE or seeded stochastic).
    pub fn run_seeded(&self, seed: u64) -> AccuracyPoint {
        let rm = self.session.rounding();
        match self.session.mode() {
            ExecMode::Functional => accuracy::accumulate_fast_with(self.src, self.dst, self.n, seed, rm),
            ExecMode::CycleAccurate => accuracy::accumulate_with(self.src, self.dst, self.n, seed, rm),
        }
    }

    /// One draw with the session seed (a Table IV cell).
    pub fn run(&self) -> AccuracyPoint {
        self.run_seeded(self.session.seed())
    }

    /// `draws` independent draws on the shared sweep-seed schedule
    /// ([`crate::accuracy::sweep_seed`] — the same seeds
    /// `accuracy::table4_averaged` uses, so sweeps agree across paths).
    pub fn sweep(&self, draws: u64) -> Vec<AccuracyPoint> {
        (0..draws).map(|d| self.run_seeded(accuracy::sweep_seed(d))).collect()
    }

    /// Mean fused / cascade relative error over [`AccumulatePlan::sweep`].
    pub fn mean(&self, draws: u64) -> (f64, f64) {
        let pts = self.sweep(draws);
        let s: (f64, f64) = pts.iter().fold((0.0, 0.0), |(f, c), p| (f + p.err_exsdotp, c + p.err_exfma));
        (s.0 / draws as f64, s.1 / draws as f64)
    }
}
