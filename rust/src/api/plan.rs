//! Validated op builders: [`GemmPlan`] and [`AccumulatePlan`].
//!
//! A plan is built in two steps — choose formats (or a kernel family),
//! then bind sizes — and **every** invalid combination is rejected with
//! a typed [`crate::util::error::Error`] at plan-build time: unsupported
//! format pairs, divisibility violations, problems that overflow the
//! simulated 128 kB TCDM, rounding modes the cycle-accurate cluster
//! cannot honor. Nothing panics after `dims()`/`n()` return `Ok`.

use super::session::Session;
use super::tensor::{expect_fmt, MfTensor};
use crate::accuracy::{self, AccuracyPoint};
use crate::core::CoreStats;
use crate::formats::FpFormat;
use crate::kernels::gemm::{ExecMode, GemmKernel, GemmKind};
use crate::softfloat::RoundingMode;
use crate::util::error::Result;
use crate::{bail, ensure};

/// Builder returned by [`Session::gemm`]. Pick the kernel either by
/// format pair ([`GemmPlanBuilder::src`] + [`GemmPlanBuilder::acc`]) or
/// directly by family ([`GemmPlanBuilder::kind`]); [`GemmPlanBuilder::dims`]
/// validates and finalizes.
#[derive(Clone, Copy, Debug)]
pub struct GemmPlanBuilder<'s> {
    session: &'s Session,
    src: Option<FpFormat>,
    acc: Option<FpFormat>,
    kind: Option<GemmKind>,
}

impl<'s> GemmPlanBuilder<'s> {
    pub(crate) fn new(session: &'s Session) -> Self {
        GemmPlanBuilder { session, src: None, acc: None, kind: None }
    }

    /// Source element format of A and B.
    pub fn src(mut self, fmt: FpFormat) -> Self {
        self.src = Some(fmt);
        self
    }

    /// Accumulation / output format of C.
    pub fn acc(mut self, fmt: FpFormat) -> Self {
        self.acc = Some(fmt);
        self
    }

    /// Select the kernel family directly (alternative to `src`/`acc`).
    pub fn kind(mut self, kind: GemmKind) -> Self {
        self.kind = Some(kind);
        self
    }

    /// Bind the problem size (`C = A·B` with A `m×k`, B `k×n`) and
    /// validate everything: format pair, kernel kind, divisibility,
    /// rounding-mode compatibility, and (cycle-accurate mode) the
    /// paper's 128 kB TCDM footprint.
    pub fn dims(self, m: usize, n: usize, k: usize) -> Result<GemmPlan<'s>> {
        let kind = match (self.kind, self.src, self.acc) {
            (Some(kind), src, acc) => {
                kind.validate()?;
                if let Some(s) = src {
                    ensure!(
                        kind.try_src_fmt()? == s,
                        "kind {:?} streams {} sources, but .src({}) was requested",
                        kind,
                        kind.try_src_fmt()?.name(),
                        s.name()
                    );
                }
                if let Some(a) = acc {
                    ensure!(
                        kind.try_dst_fmt()? == a,
                        "kind {:?} accumulates into {}, but .acc({}) was requested",
                        kind,
                        kind.try_dst_fmt()?.name(),
                        a.name()
                    );
                }
                kind
            }
            (None, Some(s), Some(a)) => GemmKind::for_formats(s, a)?,
            (None, Some(_), None) => bail!("missing accumulation format: call .acc(..) (or .kind(..))"),
            (None, None, _) => bail!("missing formats: call .src(..).acc(..) or .kind(..)"),
        };
        if self.session.mode() == ExecMode::CycleAccurate {
            ensure!(
                self.session.rounding() == RoundingMode::Rne,
                "the cycle-accurate cluster rounds RNE; use RoundingMode::Rne or ExecMode::Functional \
                 (requested {:?})",
                self.session.rounding()
            );
        }
        let kern = GemmKernel::try_new(kind, m, n, k)?;
        if self.session.mode() == ExecMode::CycleAccurate {
            ensure!(
                kern.footprint() <= 128 * 1024,
                "{} {} needs {} bytes of TCDM but the simulated cluster has 128 kB; \
                 the functional engine (ExecMode::Functional / --mode functional) runs \
                 larger problems",
                kind.label(),
                kern.size_label(),
                kern.footprint()
            );
        }
        Ok(GemmPlan { session: self.session, kern })
    }
}

/// A fully validated GEMM: kernel family + sizes + the session policy
/// that will run it. Constructed only through [`GemmPlanBuilder::dims`],
/// so a `GemmPlan` in hand is proof the problem is runnable.
#[derive(Clone, Copy, Debug)]
pub struct GemmPlan<'s> {
    session: &'s Session,
    kern: GemmKernel,
}

impl GemmPlan<'_> {
    /// The kernel family this plan runs.
    pub fn kind(&self) -> GemmKind {
        self.kern.kind
    }

    /// `(m, n, k)`.
    pub fn dims(&self) -> (usize, usize, usize) {
        (self.kern.m, self.kern.n, self.kern.k)
    }

    /// The underlying kernel descriptor (program generator, cycle
    /// model, TCDM layout) — the machine-model escape hatch.
    pub fn kernel(&self) -> &GemmKernel {
        &self.kern
    }

    /// Source element format.
    pub fn src_fmt(&self) -> FpFormat {
        self.kern.kind.try_src_fmt().expect("plan kinds are validated")
    }

    /// Accumulation / output format.
    pub fn acc_fmt(&self) -> FpFormat {
        self.kern.kind.try_dst_fmt().expect("plan kinds are validated")
    }

    /// Run on row-major `f64` matrices (quantized to the source format
    /// on packing, exactly like the pre-API free functions).
    pub fn run_f64(&self, a: &[f64], b: &[f64]) -> Result<RunReport> {
        let (m, n, k) = self.dims();
        ensure!(a.len() == m * k, "A must be {m}x{k} = {} elements, got {}", m * k, a.len());
        ensure!(b.len() == k * n, "B must be {k}x{n} = {} elements, got {}", k * n, b.len());
        let t0 = std::time::Instant::now();
        let mode = self.session.mode();
        let (c, cycles, stats) = self.session.scoped(|| match mode {
            ExecMode::CycleAccurate => {
                let r = self.kern.run(a, b);
                (r.c, Some(r.cycles), Some(r.stats))
            }
            ExecMode::Functional => {
                let c = crate::batch::gemm_dispatch(self.kern.kind, m, n, k, a, b, self.session.rounding());
                let cycles = self.session.cycle_model_enabled().then(|| self.kern.model_cycles());
                (c, cycles, None)
            }
        });
        let wall = t0.elapsed();
        // C values are on the destination grid, so re-encoding is exact
        // (scoped: the packer parallelizes under the thread budget too).
        let c = self.session.scoped(|| MfTensor::from_f64(&c, m, n, self.acc_fmt(), RoundingMode::Rne))?;
        Ok(RunReport { c, cycles, flops: self.kern.flops(), stats, mode, packed_input: false, wall })
    }

    /// Run on typed tensors. `a` must be `m×k` and `b` `k×n`, both in
    /// the plan's source format (cast first otherwise); any storage
    /// layout is accepted.
    ///
    /// When the functional engine is selected and the tensors already
    /// sit in the layouts the kernel streams (A row-major, B
    /// column-major) with an expanding kernel family, the packed words
    /// feed the batch engine **directly** — zero decode/re-pack. All
    /// other combinations restream from the decoded values, which is
    /// exact for on-grid tensors; both routes produce the same C
    /// (pinned by the `tensor_run_*` differential tests).
    pub fn run(&self, a: &MfTensor, b: &MfTensor) -> Result<RunReport> {
        use super::tensor::Layout;
        let (m, n, k) = self.dims();
        expect_fmt(a, self.src_fmt(), "A")?;
        expect_fmt(b, self.src_fmt(), "B")?;
        ensure!(a.shape() == (m, k), "A must be {m}x{k}, got {}x{}", a.rows(), a.cols());
        ensure!(b.shape() == (k, n), "B must be {k}x{n}, got {}x{}", b.rows(), b.cols());
        if self.session.mode() == ExecMode::Functional
            && a.layout() == Layout::RowMajor
            && b.layout() == Layout::ColMajor
        {
            let t0 = std::time::Instant::now();
            let rm = self.session.rounding();
            let packed = self.session.scoped(|| {
                crate::batch::gemm_packed(self.src_fmt(), self.acc_fmt(), m, n, k, a.words(), b.words(), rm)
            });
            if let Some(c) = packed {
                let wall = t0.elapsed();
                let cycles = self.session.cycle_model_enabled().then(|| self.kern.model_cycles());
                let c =
                    self.session.scoped(|| MfTensor::from_f64(&c, m, n, self.acc_fmt(), RoundingMode::Rne))?;
                return Ok(RunReport {
                    c,
                    cycles,
                    flops: self.kern.flops(),
                    stats: None,
                    mode: ExecMode::Functional,
                    packed_input: true,
                    wall,
                });
            }
        }
        self.run_f64(&a.to_f64(), &b.to_f64())
    }
}

/// Structured result of a plan run: the C tensor plus timing and (in
/// cycle-accurate mode) per-core machine stats.
#[derive(Clone, Debug)]
pub struct RunReport {
    /// The output matrix, typed and packed in the accumulation format.
    pub c: MfTensor,
    /// Cluster cycles: simulated ([`ExecMode::CycleAccurate`]), the
    /// analytic issue-slot estimate ([`ExecMode::Functional`] with the
    /// cycle model on), or `None` (cycle model off).
    pub cycles: Option<u64>,
    /// FLOP performed (2·M·N·K).
    pub flops: u64,
    /// Aggregate core stats (cycle-accurate runs only).
    pub stats: Option<CoreStats>,
    /// Which engine produced this report.
    pub mode: ExecMode,
    /// True when the operands' packed words fed the batch engine
    /// directly ([`GemmPlan::run`]'s zero-repack route); false on the
    /// quantize-from-f64 route and in cycle-accurate mode.
    pub packed_input: bool,
    /// Wall-clock time of the run.
    pub wall: std::time::Duration,
}

impl RunReport {
    /// FLOP per cycle across the cluster (Fig. 8's y-axis), when a
    /// cycle count is available.
    pub fn flop_per_cycle(&self) -> Option<f64> {
        self.cycles.map(|cy| self.flops as f64 / cy as f64)
    }

    /// The output decoded to row-major `f64`.
    pub fn c_f64(&self) -> Vec<f64> {
        self.c.to_f64()
    }

    /// Human label for where [`RunReport::cycles`] came from.
    pub fn timing_label(&self) -> &'static str {
        match (self.mode, self.cycles.is_some()) {
            (ExecMode::CycleAccurate, _) => "simulated",
            (ExecMode::Functional, true) => "issue-slot model",
            (ExecMode::Functional, false) => "disabled",
        }
    }
}

// ------------------------------------------------------------ accuracy

/// Builder returned by [`Session::accumulate`] — the Table IV
/// experiment (accumulate `n` Gaussian dot products through the fused
/// ExSdotp unit and the two-ExFMA cascade, against an FP64 golden).
#[derive(Clone, Copy, Debug)]
pub struct AccumulatePlanBuilder<'s> {
    session: &'s Session,
    src: Option<FpFormat>,
    acc: Option<FpFormat>,
}

impl<'s> AccumulatePlanBuilder<'s> {
    pub(crate) fn new(session: &'s Session) -> Self {
        AccumulatePlanBuilder { session, src: None, acc: None }
    }

    /// Source format of the dot-product inputs.
    pub fn src(mut self, fmt: FpFormat) -> Self {
        self.src = Some(fmt);
        self
    }

    /// Accumulation (destination) format.
    pub fn acc(mut self, fmt: FpFormat) -> Self {
        self.acc = Some(fmt);
        self
    }

    /// Bind the number of dot products and validate the format pair
    /// against the ExSdotp datapath constraints (§III-B): the exact
    /// products must fit the padded accumulator (`2·p_src ≤ p_dst`) and
    /// the destination must cover the source dynamic range. These are
    /// the conditions the raw [`crate::exsdotp::ExSdotpUnit`] asserts —
    /// surfaced here as typed errors instead.
    pub fn n(self, n: usize) -> Result<AccumulatePlan<'s>> {
        let (Some(src), Some(dst)) = (self.src, self.acc) else {
            bail!("missing formats: call .src(..).acc(..) before .n(..)");
        };
        ensure!(n >= 2, "n ({n}) must be at least one dot-product pair");
        // Both accumulation engines round RNE internally (the Table IV
        // experiment is defined that way); honoring any other session
        // mode is impossible, so reject instead of silently ignoring it.
        ensure!(
            self.session.rounding() == RoundingMode::Rne,
            "the accumulation harness rounds RNE (the Table IV setup); use RoundingMode::Rne \
             (requested {:?})",
            self.session.rounding()
        );
        ensure!(
            2 * src.precision() <= dst.precision(),
            "ExSdotp requires 2*p_src <= p_dst, got {} (p={}) -> {} (p={})",
            src.name(),
            src.precision(),
            dst.name(),
            dst.precision()
        );
        ensure!(
            dst.exp_bits >= src.exp_bits,
            "destination dynamic range must cover the source ({} -> {})",
            src.name(),
            dst.name()
        );
        ensure!(
            2 * dst.precision() + src.precision() + 5 <= 127,
            "internal datapath field for {} -> {} exceeds the 128-bit model width",
            src.name(),
            dst.name()
        );
        Ok(AccumulatePlan { session: self.session, src, dst, n })
    }
}

/// A validated accumulation experiment. [`ExecMode::Functional`]
/// sessions run the monomorphized fast path
/// ([`crate::accuracy::accumulate_fast`]); [`ExecMode::CycleAccurate`]
/// sessions run the descriptor-driven unit path
/// ([`crate::accuracy::accumulate`]). The two are bit-identical for the
/// paper's format pairs (pinned by differential tests), so the choice
/// only trades speed for dispatch fidelity.
#[derive(Clone, Copy, Debug)]
pub struct AccumulatePlan<'s> {
    session: &'s Session,
    src: FpFormat,
    dst: FpFormat,
    n: usize,
}

impl AccumulatePlan<'_> {
    /// `(src, dst)` formats.
    pub fn formats(&self) -> (FpFormat, FpFormat) {
        (self.src, self.dst)
    }

    /// Dot products per run.
    pub fn n(&self) -> usize {
        self.n
    }

    /// One draw with an explicit seed.
    pub fn run_seeded(&self, seed: u64) -> AccuracyPoint {
        match self.session.mode() {
            ExecMode::Functional => accuracy::accumulate_fast(self.src, self.dst, self.n, seed),
            ExecMode::CycleAccurate => accuracy::accumulate(self.src, self.dst, self.n, seed),
        }
    }

    /// One draw with the session seed (a Table IV cell).
    pub fn run(&self) -> AccuracyPoint {
        self.run_seeded(self.session.seed())
    }

    /// `draws` independent draws on the shared sweep-seed schedule
    /// ([`crate::accuracy::sweep_seed`] — the same seeds
    /// `accuracy::table4_averaged` uses, so sweeps agree across paths).
    pub fn sweep(&self, draws: u64) -> Vec<AccuracyPoint> {
        (0..draws).map(|d| self.run_seeded(accuracy::sweep_seed(d))).collect()
    }

    /// Mean fused / cascade relative error over [`AccumulatePlan::sweep`].
    pub fn mean(&self, draws: u64) -> (f64, f64) {
        let pts = self.sweep(draws);
        let s: (f64, f64) = pts.iter().fold((0.0, 0.0), |(f, c), p| (f + p.err_exsdotp, c + p.err_exfma));
        (s.0 / draws as f64, s.1 / draws as f64)
    }
}
