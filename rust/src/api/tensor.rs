//! [`MfTensor`] — an owned, typed minifloat tensor.
//!
//! The pre-API surface passed matrices around as raw `&[f64]` slices
//! plus positional `(rows, cols)` and a loose [`FpFormat`] — three
//! things that had to be kept consistent by hand at every call site.
//! `MfTensor` binds them together: the elements live **packed** in
//! `u64` words exactly as the 64-bit FP register file holds them
//! (§III-D: 2×FP32, 4×FP16, 8×FP8 lanes per word), alongside their
//! format, shape, and storage layout. Packing uses the same
//! `from_f64` quantization the kernels apply, so a tensor built with
//! [`MfTensor::from_f64`] holds bit-for-bit the words the batch engine
//! and the simulated cluster would stream.

use crate::formats::FpFormat;
use crate::kernels::layout::MatrixOrder;
use crate::softfloat::{from_f64, to_f64, RoundingMode};
use crate::util::error::Result;
use crate::{bail, ensure};

/// Storage layout of a tensor's packed words (re-export of the kernel
/// layer's [`MatrixOrder`]: row-major packs lanes along rows, the way
/// SSR stream `ft0` delivers A; column-major packs lanes down columns,
/// the way `ft1` delivers B to the packed kernels).
pub type Layout = MatrixOrder;

/// An owned matrix of minifloat encodings, packed `fmt.lanes_in_64()`
/// elements per `u64` word along the major dimension.
///
/// Invariants (enforced by every constructor):
/// * the major extent (cols for row-major, rows for column-major)
///   divides by the format's lane count, so words never straddle lines;
/// * `words.len() == lines * extent / lanes`.
///
/// Equality (`PartialEq`) is bit-equality of format, shape, layout and
/// packed words — what the differential tests compare.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MfTensor {
    fmt: FpFormat,
    rows: usize,
    cols: usize,
    layout: Layout,
    words: Vec<u64>,
}

/// A borrowed view of an [`MfTensor`] (same accessors, no ownership) —
/// hand these to readers that must not clone the packed storage.
#[derive(Clone, Copy, Debug)]
pub struct MfTensorView<'a> {
    fmt: FpFormat,
    rows: usize,
    cols: usize,
    layout: Layout,
    words: &'a [u64],
}

/// `(lines, extent)` of the major dimension for a layout.
fn major(rows: usize, cols: usize, layout: Layout) -> (usize, usize) {
    match layout {
        Layout::RowMajor => (rows, cols),
        Layout::ColMajor => (cols, rows),
    }
}

fn check_shape(fmt: FpFormat, rows: usize, cols: usize, layout: Layout) -> Result<usize> {
    ensure!(
        fmt.exp_bits >= 2 && fmt.man_bits >= 1 && fmt.width() <= 64,
        "unsupported format e{}m{}: need exp_bits >= 2, man_bits >= 1, width <= 64",
        fmt.exp_bits,
        fmt.man_bits
    );
    ensure!(rows > 0 && cols > 0, "tensor shape {rows}x{cols} must be non-empty");
    let lanes = fmt.lanes_in_64() as usize;
    let (lines, extent) = major(rows, cols, layout);
    ensure!(
        extent % lanes == 0,
        "{} extent ({extent}) must divide by {}'s {lanes} lanes per 64-bit word",
        match layout {
            Layout::RowMajor => "row",
            Layout::ColMajor => "column",
        },
        fmt.name()
    );
    Ok(lines * (extent / lanes))
}

impl MfTensor {
    /// Quantize a row-major `f64` matrix into a row-major packed tensor
    /// (the layout GEMM expects for A and C). `cols` must divide by the
    /// format's lane count.
    pub fn from_f64(data: &[f64], rows: usize, cols: usize, fmt: FpFormat, rm: RoundingMode) -> Result<Self> {
        Self::from_f64_with_layout(data, rows, cols, fmt, Layout::RowMajor, rm)
    }

    /// [`MfTensor::from_f64`] with an explicit storage layout (`data`
    /// is row-major `f64` either way; the layout controls how lanes are
    /// packed into words). Bit-identical to the batch engine's
    /// row/column packers for the six paper formats.
    pub fn from_f64_with_layout(
        data: &[f64],
        rows: usize,
        cols: usize,
        fmt: FpFormat,
        layout: Layout,
        rm: RoundingMode,
    ) -> Result<Self> {
        Self::from_f64_reusing(data, rows, cols, fmt, layout, rm, Vec::new())
    }

    /// [`MfTensor::from_f64_with_layout`] recycling `buf`'s allocation
    /// for the packed words (its contents are irrelevant — only the
    /// capacity is reused; pair with [`MfTensor::into_words`]).
    /// Bit-identical to the allocating constructors.
    pub fn from_f64_reusing(
        data: &[f64],
        rows: usize,
        cols: usize,
        fmt: FpFormat,
        layout: Layout,
        rm: RoundingMode,
        mut buf: Vec<u64>,
    ) -> Result<Self> {
        ensure!(
            data.len() == rows * cols,
            "data length ({}) does not match the {rows}x{cols} shape",
            data.len()
        );
        let n_words = check_shape(fmt, rows, cols, layout)?;
        // Paper formats pack on the batch engine's monomorphized,
        // row-parallel packers (bit-identical by construction — same
        // `from_f64` quantization, same lane order).
        let packed = match layout {
            Layout::RowMajor => crate::batch::pack_rows_into(fmt, data, rows, cols, rm, &mut buf),
            Layout::ColMajor => crate::batch::pack_cols_into(fmt, data, rows, cols, rm, &mut buf),
        };
        if packed {
            return Ok(MfTensor { fmt, rows, cols, layout, words: buf });
        }
        // Custom formats: descriptor-driven fallback, same layout.
        let lanes = fmt.lanes_in_64() as usize;
        let (lines, extent) = major(rows, cols, layout);
        let wpl = extent / lanes;
        buf.clear();
        buf.resize(n_words, 0);
        for line in 0..lines {
            for w in 0..wpl {
                let mut packed = 0u64;
                for lane_i in 0..lanes {
                    let e = w * lanes + lane_i;
                    let (r, c) = match layout {
                        Layout::RowMajor => (line, e),
                        Layout::ColMajor => (e, line),
                    };
                    // Same per-element SR key the batch packers derive
                    // (the row-major data index), so a custom-format
                    // tensor quantizes like a paper-format one would.
                    let idx = r * cols + c;
                    packed |= from_f64(data[idx], fmt, rm.sr_element(idx as u64))
                        << (lane_i as u32 * fmt.width());
                }
                buf[line * wpl + w] = packed;
            }
        }
        Ok(MfTensor { fmt, rows, cols, layout, words: buf })
    }

    /// Adopt already-packed words (e.g. read back from a simulated
    /// TCDM). Validates the word count against shape/format/layout.
    pub fn from_bits(words: Vec<u64>, rows: usize, cols: usize, fmt: FpFormat, layout: Layout) -> Result<Self> {
        let n_words = check_shape(fmt, rows, cols, layout)?;
        ensure!(
            words.len() == n_words,
            "word count ({}) does not match {rows}x{cols} {} packed as {:?} ({n_words} words)",
            words.len(),
            fmt.name(),
            layout
        );
        Ok(MfTensor { fmt, rows, cols, layout, words })
    }

    /// Cast every element into `to` (correctly rounded, single
    /// rounding), repacking at the new lane width. The target format
    /// must satisfy the same extent-divisibility invariant.
    pub fn cast(&self, to: FpFormat, rm: RoundingMode) -> Result<MfTensor> {
        let n_words = check_shape(to, self.rows, self.cols, self.layout)?;
        let lanes_to = to.lanes_in_64() as usize;
        let (lines, extent) = major(self.rows, self.cols, self.layout);
        // Gather encodings line by line, cast on the (monomorphized
        // where possible) slice path, repack.
        let mut elems = Vec::with_capacity(self.rows * self.cols);
        for line in 0..lines {
            for e in 0..extent {
                elems.push(self.view().line_bits(line, e));
            }
        }
        let cast = crate::batch::cast_slice(self.fmt, to, &elems, rm);
        let wpl = extent / lanes_to;
        let mut words = vec![0u64; n_words];
        for line in 0..lines {
            for w in 0..wpl {
                let mut packed = 0u64;
                for lane_i in 0..lanes_to {
                    let e = w * lanes_to + lane_i;
                    packed |= cast[line * extent + e] << (lane_i as u32 * to.width());
                }
                words[line * wpl + w] = packed;
            }
        }
        Ok(MfTensor { fmt: to, rows: self.rows, cols: self.cols, layout: self.layout, words })
    }

    /// Repack into the other storage layout (same format, same values).
    pub fn with_layout(&self, layout: Layout) -> Result<MfTensor> {
        if layout == self.layout {
            return Ok(self.clone());
        }
        // Decode is exact (values are on the format grid), so a
        // round-trip through f64 preserves every encoding except
        // non-canonical NaN payloads, which the register file does not
        // distinguish either.
        Self::from_f64_with_layout(&self.to_f64(), self.rows, self.cols, self.fmt, layout, RoundingMode::Rne)
    }

    /// Borrow as a view.
    pub fn view(&self) -> MfTensorView<'_> {
        MfTensorView {
            fmt: self.fmt,
            rows: self.rows,
            cols: self.cols,
            layout: self.layout,
            words: &self.words,
        }
    }

    /// Decode to a row-major `f64` matrix (exact for every format up to
    /// 64 bits wide).
    pub fn to_f64(&self) -> Vec<f64> {
        self.view().to_f64()
    }

    /// Decode one element.
    pub fn get(&self, r: usize, c: usize) -> f64 {
        self.view().get(r, c)
    }

    /// Raw encoding of one element.
    pub fn bits(&self, r: usize, c: usize) -> u64 {
        self.view().bits(r, c)
    }

    /// Element format.
    pub fn fmt(&self) -> FpFormat {
        self.fmt
    }

    /// `(rows, cols)`.
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Storage layout.
    pub fn layout(&self) -> Layout {
        self.layout
    }

    /// The packed words (lanes along the major dimension).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Consume the tensor and recover its packed-word storage — the
    /// buffer-recycling exit paired with [`MfTensor::from_f64_reusing`]
    /// (the nn tape and serve shards pool these across steps/batches).
    pub fn into_words(self) -> Vec<u64> {
        self.words
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.rows * self.cols
    }

    /// Always false (constructors reject empty shapes); here so
    /// clippy's `len`-without-`is_empty` convention holds.
    pub fn is_empty(&self) -> bool {
        false
    }
}

impl<'a> MfTensorView<'a> {
    /// Encoding at `(line, e)` in major coordinates.
    fn line_bits(&self, line: usize, e: usize) -> u64 {
        let lanes = self.fmt.lanes_in_64() as usize;
        let (_, extent) = major(self.rows, self.cols, self.layout);
        let wpl = extent / lanes;
        let word = self.words[line * wpl + e / lanes];
        (word >> ((e % lanes) as u32 * self.fmt.width())) & self.fmt.width_mask()
    }

    /// Raw encoding of element `(r, c)`.
    pub fn bits(&self, r: usize, c: usize) -> u64 {
        assert!(r < self.rows && c < self.cols, "index ({r},{c}) out of {}x{}", self.rows, self.cols);
        let (line, e) = match self.layout {
            Layout::RowMajor => (r, c),
            Layout::ColMajor => (c, r),
        };
        self.line_bits(line, e)
    }

    /// Decode element `(r, c)`.
    pub fn get(&self, r: usize, c: usize) -> f64 {
        to_f64(self.bits(r, c), self.fmt)
    }

    /// Decode to a row-major `f64` matrix.
    pub fn to_f64(&self) -> Vec<f64> {
        let mut out = Vec::new();
        self.to_f64_into(&mut out);
        out
    }

    /// Decode into a caller-provided buffer (cleared; capacity reused).
    pub fn to_f64_into(&self, out: &mut Vec<f64>) {
        out.clear();
        out.reserve(self.rows * self.cols);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.push(self.get(r, c));
            }
        }
    }

    /// Element format.
    pub fn fmt(&self) -> FpFormat {
        self.fmt
    }

    /// `(rows, cols)`.
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Storage layout.
    pub fn layout(&self) -> Layout {
        self.layout
    }

    /// The packed words.
    pub fn words(&self) -> &'a [u64] {
        self.words
    }
}

/// Guard used by [`crate::api::GemmPlan::run`]: a tensor handed to a
/// plan must already be in the format the kernel streams.
pub(crate) fn expect_fmt(t: &MfTensor, want: FpFormat, role: &str) -> Result<()> {
    if t.fmt() != want {
        bail!(
            "{role} tensor is {} but the plan's kernel streams {}; cast it first (MfTensor::cast)",
            t.fmt().name(),
            want.name()
        );
    }
    Ok(())
}
