//! [`Session`] — execution policy for the typed API.
//!
//! Every pre-API entry point threaded the same knobs positionally:
//! an [`ExecMode`], a [`RoundingMode`], a seed, a thread count, and
//! (implicitly) whether the functional path should bother modeling
//! cycles. A `Session` owns that policy once; plans built from it
//! ([`Session::gemm`], [`Session::accumulate`]) inherit it.

use super::plan::{AccumulatePlanBuilder, GemmPlanBuilder};
use super::serve::ServePlanBuilder;
use super::tensor::{Layout, MfTensor};
use super::train::TrainPlanBuilder;
use crate::coordinator::{Precision, Trainer};
use crate::formats::FpFormat;
use crate::kernels::gemm::ExecMode;
use crate::nn::policy::PrecisionPolicy;
use crate::nn::train::NativeTrainer;
use crate::softfloat::RoundingMode;
use crate::util::error::{Context, Result};
use crate::util::parallel::ExecutorHandle;
use crate::util::rng::Rng;

/// Immutable execution policy: which engine runs the work, how results
/// round, where randomness comes from, and how wide the batch engine
/// fans out. Build one with [`Session::builder`] (or take
/// `Session::default()`: functional engine, RNE, seed 42, all cores,
/// cycle model on).
#[derive(Clone, Copy, Debug)]
pub struct Session {
    mode: ExecMode,
    rm: RoundingMode,
    seed: u64,
    threads: Option<usize>,
    cycle_model: bool,
}

impl Default for Session {
    fn default() -> Self {
        Session {
            mode: ExecMode::Functional,
            rm: RoundingMode::Rne,
            seed: 42,
            threads: None,
            cycle_model: true,
        }
    }
}

impl Session {
    /// Start building a session.
    pub fn builder() -> SessionBuilder {
        SessionBuilder { inner: Session::default(), sr_from_seed: false }
    }

    /// The default policy (functional engine, RNE, seed 42).
    pub fn new() -> Self {
        Self::default()
    }

    /// Execution engine for plans built from this session.
    pub fn mode(&self) -> ExecMode {
        self.mode
    }

    /// Rounding mode applied to quantization and functional-engine runs.
    pub fn rounding(&self) -> RoundingMode {
        self.rm
    }

    /// A copy of this session with a different rounding mode — how the
    /// nn trainer honors a [`PrecisionPolicy`]'s stochastic-rounding
    /// knob without rebuilding the whole policy bundle.
    pub fn with_rounding(mut self, rm: RoundingMode) -> Session {
        self.rm = rm;
        self
    }

    /// Seed for [`Session::rng`] and the accuracy plans.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Thread budget for the batch engine (`None` = all cores).
    pub fn threads(&self) -> Option<usize> {
        self.threads
    }

    /// The executor this session dispatches batch work on: a handle on
    /// the persistent process worker pool
    /// ([`crate::util::parallel::Executor::global`]) carrying the
    /// session's thread budget. The budget caps how many spans a
    /// dispatch fans out to — identical semantics to the scoped-thread
    /// era, and results are bit-identical at any budget; the pool
    /// itself is never resized. Every `session.scoped` code path
    /// (plans, packing, the nn/serve subsystems) runs through this
    /// handle.
    pub fn executor(&self) -> ExecutorHandle {
        ExecutorHandle::with_budget(self.threads)
    }

    /// Whether functional GEMM runs attach the analytic issue-slot
    /// cycle estimate to their report.
    pub fn cycle_model_enabled(&self) -> bool {
        self.cycle_model
    }

    /// Start a typed GEMM plan: `session.gemm().src(FP8).acc(FP16)
    /// .dims(m, n, k)?` validates everything up front and returns a
    /// runnable [`crate::api::GemmPlan`].
    pub fn gemm(&self) -> GemmPlanBuilder<'_> {
        GemmPlanBuilder::new(self)
    }

    /// Start a typed accumulation plan (the Table IV experiment):
    /// `session.accumulate().src(FP8).acc(FP16).n(2000)?`.
    pub fn accumulate(&self) -> AccumulatePlanBuilder<'_> {
        AccumulatePlanBuilder::new(self)
    }

    /// A deterministic RNG seeded with the session seed.
    pub fn rng(&self) -> Rng {
        Rng::new(self.seed)
    }

    /// Quantize a row-major `f64` matrix into a row-major [`MfTensor`]
    /// using the session's rounding mode (and thread budget — packing
    /// parallelizes across rows).
    pub fn tensor(&self, data: &[f64], rows: usize, cols: usize, fmt: FpFormat) -> Result<MfTensor> {
        self.scoped(|| MfTensor::from_f64(data, rows, cols, fmt, self.rm))
    }

    /// [`Session::tensor`] with an explicit storage layout. Pack B
    /// column-major ([`crate::api::Layout::ColMajor`]) to hit
    /// [`crate::api::GemmPlan::run`]'s zero-repack fast path — that is
    /// the layout the packed kernels stream B in.
    pub fn tensor_with_layout(
        &self,
        data: &[f64],
        rows: usize,
        cols: usize,
        fmt: FpFormat,
        layout: Layout,
    ) -> Result<MfTensor> {
        self.scoped(|| MfTensor::from_f64_with_layout(data, rows, cols, fmt, layout, self.rm))
    }

    /// Start a typed native-training plan: the offline mixed-precision
    /// trainer whose every matmul runs through [`Session::gemm`] plans
    /// (`session.train().policy(PrecisionPolicy::hfp8()).build()?`).
    pub fn train(&self) -> TrainPlanBuilder<'_> {
        TrainPlanBuilder::new(self)
    }

    /// Start a typed serving plan: the multi-tenant batched inference
    /// server over frozen [`crate::serve::InferenceModel`]s
    /// (`session.server().tenant("prod", model).max_batch(64).build()?`).
    pub fn server(&self) -> ServePlanBuilder<'_> {
        ServePlanBuilder::new(self)
    }

    /// Convenience: a ready [`crate::nn::NativeTrainer`] with the given
    /// precision policy and default task/model (spiral, 32 hidden,
    /// batch 64, Adam). Equivalent to
    /// `self.train().policy(policy).build()?.trainer()`.
    pub fn native_trainer(&self, policy: PrecisionPolicy) -> Result<NativeTrainer> {
        self.train().policy(policy).build()?.trainer()
    }

    /// Construct the **artifact-backed** (PJRT) training driver with
    /// the session's seed — the fallback engine; it needs a
    /// PJRT-enabled build plus `make artifacts`. Offline, prefer the
    /// native engine: [`Session::train`] / [`Session::native_trainer`]
    /// (`repro train --engine native`).
    pub fn trainer(&self, artifacts_dir: &str, precision: Precision) -> Result<Trainer> {
        Trainer::new(artifacts_dir, precision, self.seed).context(
            "constructing the PJRT (artifact-backed) trainer; the native engine trains \
             offline without artifacts — use Session::train() / `repro train --engine native`",
        )
    }

    /// [`Session::tensor_with_layout`] recycling `buf`'s allocation for
    /// the packed words (capacity reuse only — bit-identical to the
    /// allocating constructor). Pair with
    /// [`crate::api::MfTensor::into_words`]; the nn tape and serve
    /// shards pool buffers through this to keep the hot loops
    /// allocation-free.
    pub fn tensor_reusing(
        &self,
        data: &[f64],
        rows: usize,
        cols: usize,
        fmt: FpFormat,
        layout: Layout,
        buf: Vec<u64>,
    ) -> Result<MfTensor> {
        self.scoped(|| MfTensor::from_f64_reusing(data, rows, cols, fmt, layout, self.rm, buf))
    }

    /// Round `vals` onto `fmt`'s grid in place under the session thread
    /// budget and rounding mode — the epilogue re-encode without
    /// materializing a tensor, bit-identical to
    /// `self.tensor(vals, ..)?.to_f64()` by construction (same `rm`,
    /// same quantizer).
    pub fn regrid_in_place(&self, fmt: FpFormat, vals: &mut [f64]) {
        self.scoped(|| crate::batch::regrid_in_place(fmt, vals, self.rm));
    }

    /// Run `f` under this session's executor handle (thread budget;
    /// no-op when unset).
    pub(crate) fn scoped<R>(&self, f: impl FnOnce() -> R) -> R {
        self.executor().scoped(f)
    }
}

/// Builder for [`Session`]; every knob is optional.
#[derive(Clone, Copy, Debug)]
pub struct SessionBuilder {
    inner: Session,
    /// Resolve the rounding mode to `StochasticRound(seed)` at build
    /// time (so `.stochastic_rounding()` and `.seed(..)` compose in
    /// either order).
    sr_from_seed: bool,
}

impl SessionBuilder {
    /// Select the execution engine (default [`ExecMode::Functional`]).
    pub fn mode(mut self, mode: ExecMode) -> Self {
        self.inner.mode = mode;
        self
    }

    /// Select the rounding mode (default RNE). Note the cycle-accurate
    /// cluster always rounds RNE — GEMM plan builders reject other
    /// modes when paired with [`ExecMode::CycleAccurate`].
    pub fn rounding(mut self, rm: RoundingMode) -> Self {
        self.inner.rm = rm;
        self.sr_from_seed = false;
        self
    }

    /// Round stochastically, keyed by the session seed: shorthand for
    /// `.rounding(RoundingMode::StochasticRound(seed))` that stays in
    /// sync with `.seed(..)` regardless of call order. Functional
    /// engine only (the cycle-accurate cluster rounds RNE); results are
    /// deterministic per seed and bit-identical across thread counts,
    /// lane tiers, and executor backends.
    pub fn stochastic_rounding(mut self) -> Self {
        self.sr_from_seed = true;
        self
    }

    /// Seed the session RNG and the accuracy plans (default 42).
    pub fn seed(mut self, seed: u64) -> Self {
        self.inner.seed = seed;
        self
    }

    /// Cap the batch engine's worker threads (default: all cores).
    /// Results are bit-identical at any thread count.
    pub fn threads(mut self, n: usize) -> Self {
        self.inner.threads = Some(n.max(1));
        self
    }

    /// Toggle the analytic cycle model for functional runs (default
    /// on). With it off, functional [`crate::api::RunReport`]s carry
    /// no cycle estimate.
    pub fn cycle_model(mut self, on: bool) -> Self {
        self.inner.cycle_model = on;
        self
    }

    /// Finish.
    pub fn build(mut self) -> Session {
        if self.sr_from_seed {
            self.inner.rm = RoundingMode::StochasticRound(self.inner.seed);
        }
        self.inner
    }
}
