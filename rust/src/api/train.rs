//! [`TrainPlan`] — the validated front door to native mixed-precision
//! training, mirroring [`crate::api::GemmPlan`]'s builder style.
//!
//! `session.train().policy(PrecisionPolicy::hfp8()).build()?` checks
//! everything a run needs before any compute happens: the policy's
//! format pairs resolve to runnable GEMM plans, the model/batch
//! dimensions divide by the lane and unroll requirements of *all three*
//! GEMM shapes (forward, `Xᵀ·G`, `G·Wᵀ`), the dataset is non-degenerate,
//! and the session drives the functional engine. A `TrainPlan` in hand
//! is proof the training loop cannot hit a shape panic.
//!
//! ```
//! use minifloat_nn::prelude::*;
//!
//! # fn main() -> minifloat_nn::util::error::Result<()> {
//! let session = Session::builder().seed(1).build();
//! let plan = session.train().policy(PrecisionPolicy::hfp8()).hidden(16).build()?;
//! let mut tr = plan.trainer()?;
//! tr.train(5, 0)?;
//! assert_eq!(tr.history.len(), 5);
//! # Ok(())
//! # }
//! ```

use super::session::Session;
use crate::ensure;
use crate::kernels::gemm::ExecMode;
use crate::nn::data::{DataSpec, IN_DIM, OUT_DIM};
use crate::nn::layer::Activation;
use crate::nn::optim::OptimSpec;
use crate::nn::policy::PrecisionPolicy;
use crate::nn::train::NativeTrainer;
use crate::util::error::Result;

/// Builder returned by [`Session::train`]; every knob has a sensible
/// default (HFP8 policy, spiral dataset, 32 hidden units, batch 64,
/// Adam at 4e-3, ReLU).
#[derive(Clone, Copy, Debug)]
pub struct TrainPlanBuilder<'s> {
    session: &'s Session,
    policy: PrecisionPolicy,
    data: DataSpec,
    hidden: usize,
    batch: usize,
    act: Activation,
    optim: OptimSpec,
}

impl<'s> TrainPlanBuilder<'s> {
    pub(crate) fn new(session: &'s Session) -> Self {
        TrainPlanBuilder {
            session,
            policy: PrecisionPolicy::hfp8(),
            data: DataSpec::Spiral { n_per_class: 300 },
            hidden: 32,
            batch: 64,
            act: Activation::Relu,
            optim: OptimSpec::adam(4e-3),
        }
    }

    /// Select the precision policy (default HFP8).
    pub fn policy(mut self, policy: PrecisionPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Select the dataset (default three-arm spiral, 300/arm).
    pub fn dataset(mut self, data: DataSpec) -> Self {
        self.data = data;
        self
    }

    /// Hidden width of the two hidden layers (default 32).
    pub fn hidden(mut self, hidden: usize) -> Self {
        self.hidden = hidden;
        self
    }

    /// Batch size (default 64).
    pub fn batch(mut self, batch: usize) -> Self {
        self.batch = batch;
        self
    }

    /// Activation between linear layers (default ReLU).
    pub fn activation(mut self, act: Activation) -> Self {
        self.act = act;
        self
    }

    /// Optimizer + hyperparameters (default Adam at 4e-3).
    pub fn optimizer(mut self, optim: OptimSpec) -> Self {
        self.optim = optim;
        self
    }

    /// Validate everything and return the runnable plan.
    pub fn build(self) -> Result<TrainPlan> {
        self.policy.validate()?;
        ensure!(
            self.session.mode() == ExecMode::Functional,
            "native training runs on the functional batch engine (the backward GEMM shapes \
             have no cycle-accurate kernels); build the session with ExecMode::Functional"
        );
        // Dimension requirements across all three GEMM shapes: every
        // one of {batch, hidden, IN_DIM, OUT_DIM} appears as an M
        // (multiple of the 8 cluster cores), an N (multiple of the
        // 4-wide unroll) and a K (multiple of the SIMD lane count ≤ 8)
        // in some plan, so a single "multiple of 8" rule covers all.
        let lanes = self.policy.max_lanes().max(8);
        for (what, v) in [("batch size", self.batch), ("hidden width", self.hidden)] {
            ensure!(
                v > 0 && v % lanes == 0,
                "{what} ({v}) must be a positive multiple of {lanes} so every forward and \
                 backward GEMM shape packs cleanly (SIMD lanes, unroll, and core count)"
            );
        }
        ensure!(
            self.data.len() >= self.batch,
            "dataset would have {} samples but the batch size is {}",
            self.data.len(),
            self.batch
        );
        // Probe-build one plan per role so unsupported policy/dimension
        // combinations surface here, typed, not mid-loop.
        self.session.gemm().src(self.policy.fwd).acc(self.policy.acc).dims(
            self.batch,
            self.hidden,
            IN_DIM,
        )?;
        self.session
            .gemm()
            .src(self.policy.bwd)
            .acc(self.policy.acc)
            .transpose_a()
            .dims(IN_DIM, self.hidden, self.batch)?;
        self.session
            .gemm()
            .src(self.policy.bwd)
            .acc(self.policy.acc)
            .transpose_b()
            .dims(self.batch, self.hidden, OUT_DIM)?;
        Ok(TrainPlan {
            session: *self.session,
            policy: self.policy,
            data: self.data,
            hidden: self.hidden,
            batch: self.batch,
            act: self.act,
            optim: self.optim,
        })
    }
}

/// A fully validated training configuration. Constructed only through
/// [`TrainPlanBuilder::build`]; [`TrainPlan::trainer`] materializes the
/// stateful [`NativeTrainer`] (dataset, model init, optimizer state).
#[derive(Clone, Copy, Debug)]
pub struct TrainPlan {
    session: Session,
    policy: PrecisionPolicy,
    data: DataSpec,
    hidden: usize,
    batch: usize,
    act: Activation,
    optim: OptimSpec,
}

impl TrainPlan {
    /// The precision policy.
    pub fn policy(&self) -> PrecisionPolicy {
        self.policy
    }

    /// `(hidden, batch)`.
    pub fn shape(&self) -> (usize, usize) {
        (self.hidden, self.batch)
    }

    /// Build the stateful trainer (deterministic from the session seed:
    /// dataset generation, weight init and batch sampling all derive
    /// from it).
    pub fn trainer(&self) -> Result<NativeTrainer> {
        // Same dataset-seed salt the PJRT coordinator applies, so both
        // engines train on identical points for a given session seed.
        let data = self.data.generate(self.session.seed() ^ 0xD47A);
        data.validate()?;
        Ok(NativeTrainer::assemble(
            self.session,
            self.policy,
            data,
            self.hidden,
            self.batch,
            self.act,
            self.optim,
        ))
    }
}
