//! [`PlanInstance`] — a compiled, reusable execution of one
//! [`crate::api::GemmPlan`].
//!
//! A [`crate::api::GemmPlan`] is validation: proof a problem is
//! runnable. A `PlanInstance` is the **execution substrate** compiled
//! from that proof once and reused across runs: it owns a
//! [`crate::batch::Workspace`] (packed-operand scratch + staging) and
//! optional cached packed operands, so the steady state — an nn
//! training step, a serve dispatch — performs **zero allocation per
//! GEMM**. Outputs are written into caller-provided buffers
//! ([`PlanInstance::run_into`] / [`PlanInstance::run_f64_into`]);
//! [`PlanInstance::bind_b`] + [`PlanInstance::run_reusing`] cover the
//! fixed-operand pattern (serve's frozen weights).
//!
//! Reuse is capacity-only: a workspace carries no numeric state, so a
//! run through an instance is bit-identical to the same run through
//! the one-shot [`crate::api::GemmPlan::run`]/`run_f64` (pinned by the
//! `instance_*` differential tests in `api::tests`).

use super::plan::transpose_f64_into;
use super::session::Session;
use super::tensor::{expect_fmt, Layout, MfTensor};
use crate::batch::{self, BlockPlan, Workspace};
use crate::core::CoreStats;
use crate::formats::FpFormat;
use crate::kernels::gemm::{ExecMode, GemmKernel};
use crate::softfloat::RoundingMode;
use crate::util::error::Result;
use crate::{bail, ensure};

/// Structured result of an instance run: [`crate::api::RunReport`]
/// minus the owned C tensor (C went into the caller's buffer instead).
#[derive(Clone, Debug)]
pub struct RunInfo {
    /// Cluster cycles: simulated, the analytic issue-slot estimate, or
    /// `None` (functional run with the cycle model off).
    pub cycles: Option<u64>,
    /// FLOP performed (2·M·N·K).
    pub flops: u64,
    /// Aggregate core stats (cycle-accurate runs only).
    pub stats: Option<CoreStats>,
    /// Which engine produced this result.
    pub mode: ExecMode,
    /// True when the operands' packed words fed the batch engine
    /// directly (the zero-repack route).
    pub packed_input: bool,
    /// Wall-clock time of the run.
    pub wall: std::time::Duration,
}

/// A reusable, workspace-owning execution of one validated GEMM plan.
/// Construct through [`crate::api::GemmPlan::instance`]; the instance
/// owns a copy of the session policy, so it outlives the plan borrow
/// and can persist across training steps / serve dispatches.
#[derive(Debug)]
pub struct PlanInstance {
    session: Session,
    kern: GemmKernel,
    src: FpFormat,
    acc: FpFormat,
    ta: bool,
    tb: bool,
    ws: Workspace,
    /// Cache-blocking decision for the packed route, compiled once at
    /// assembly time (the shape is fixed per instance) and replayed on
    /// every run — blocking is bit-invisible, so this is purely a
    /// skip-the-per-call-planning optimization.
    block_plan: BlockPlan,
    /// Packed words of K per chunked sub-accumulation, when the plan
    /// requested chunking ([`crate::api::GemmPlanBuilder::chunk_k`],
    /// builder-validated: expanding family, elems a multiple of the
    /// SIMD width).
    chunk_words: Option<usize>,
    a_bound: Option<MfTensor>,
    b_bound: Option<MfTensor>,
    /// Re-grid the decoded C onto the accumulation grid in place
    /// (default). The one-shot [`crate::api::GemmPlan`] wrappers turn
    /// this off: they immediately re-encode C into a tensor, which
    /// performs the identical rounding, so regridding first would be a
    /// wasted O(m·n) pass.
    regrid_output: bool,
    runs: u64,
    packed_runs: u64,
}

impl PlanInstance {
    pub(crate) fn assemble(
        session: Session,
        kern: GemmKernel,
        src: FpFormat,
        acc: FpFormat,
        ta: bool,
        tb: bool,
        chunk: Option<usize>,
    ) -> Self {
        // The packed route streams k/lanes words per output element;
        // non-paper source formats never reach it (gemm_packed_into
        // misses), so a defensive simple plan covers lanes that do not
        // divide k.
        let lanes = src.lanes_in_64() as usize;
        let block_plan = if lanes > 0 && kern.k % lanes == 0 {
            BlockPlan::for_problem(kern.m, kern.n, kern.k / lanes)
        } else {
            BlockPlan::simple()
        };
        // Builder-validated: chunk elems divide by the lane count.
        let chunk_words = chunk.map(|c| c / lanes.max(1));
        PlanInstance {
            session,
            kern,
            src,
            acc,
            ta,
            tb,
            block_plan,
            chunk_words,
            ws: Workspace::new(),
            a_bound: None,
            b_bound: None,
            regrid_output: true,
            runs: 0,
            packed_runs: 0,
        }
    }

    /// One-shot wrapper support (see the `regrid_output` field): the
    /// caller will re-encode C into a tensor itself, which rounds
    /// identically, so the in-place regrid is skipped.
    pub(crate) fn skip_output_regrid(&mut self) {
        self.regrid_output = false;
    }

    /// `(m, n, k)`.
    pub fn dims(&self) -> (usize, usize, usize) {
        (self.kern.m, self.kern.n, self.kern.k)
    }

    /// Source element format.
    pub fn src_fmt(&self) -> FpFormat {
        self.src
    }

    /// Accumulation / output format.
    pub fn acc_fmt(&self) -> FpFormat {
        self.acc
    }

    /// `(transpose_a, transpose_b)`.
    pub fn transposes(&self) -> (bool, bool) {
        (self.ta, self.tb)
    }

    /// Executions so far (the plan-reuse counter: every run after the
    /// first amortized the compile + workspace).
    pub fn runs(&self) -> u64 {
        self.runs
    }

    /// How many executions fed the batch engine packed words directly.
    pub fn packed_runs(&self) -> u64 {
        self.packed_runs
    }

    /// Bytes of scratch capacity the workspace currently holds.
    pub fn workspace_bytes(&self) -> usize {
        self.ws.capacity_bytes()
    }

    /// Row-major shape the A operand arrives in (transposed plans take
    /// it untransposed, `k×m`).
    fn a_shape(&self) -> (usize, usize) {
        let (m, _, k) = self.dims();
        if self.ta {
            (k, m)
        } else {
            (m, k)
        }
    }

    /// Row-major shape the B operand arrives in (`n×k` under
    /// `transpose_b`).
    fn b_shape(&self) -> (usize, usize) {
        let (_, n, k) = self.dims();
        if self.tb {
            (n, k)
        } else {
            (k, n)
        }
    }

    /// Run on row-major `f64` operands, writing decoded C (re-gridded
    /// onto the accumulation format, exactly like
    /// [`crate::api::GemmPlan::run_f64`]'s tensor re-encode) into `out`
    /// — cleared and resized, capacity reused.
    pub fn run_f64_into(&mut self, a: &[f64], b: &[f64], out: &mut Vec<f64>) -> Result<RunInfo> {
        let (m, n, k) = self.dims();
        let (ar, ac) = self.a_shape();
        let (br, bc) = self.b_shape();
        ensure!(a.len() == ar * ac, "A must be {ar}x{ac} = {} elements, got {}", ar * ac, a.len());
        ensure!(b.len() == br * bc, "B must be {br}x{bc} = {} elements, got {}", br * bc, b.len());
        let t0 = std::time::Instant::now();
        let mode = self.session.mode();
        let _sp = crate::obs::trace::span_with("plan.run", "api", || {
            format!("\"m\":{m},\"n\":{n},\"k\":{k},\"mode\":\"{mode:?}\",\"packed\":false")
        });
        let (cycles, stats) = match mode {
            ExecMode::CycleAccurate => {
                // Builder invariant: cycle-accurate plans are nominal
                // formats, untransposed.
                let r = self.kern.run(a, b);
                out.clear();
                out.extend_from_slice(&r.c);
                (Some(r.cycles), Some(r.stats))
            }
            ExecMode::Functional => {
                // Per-run key split: under seeded stochastic rounding
                // each execution of the instance draws a fresh key
                // stream (`sr_run` is the identity otherwise, and the
                // run counter starts at 0, so one-shot plan wrappers
                // and an instance's first run stay bit-identical).
                let rm = self.session.rounding().sr_run(self.runs);
                let (src, acc, ta, tb) = (self.src, self.acc, self.ta, self.tb);
                let kind = self.kern.kind;
                let chunk_words = self.chunk_words;
                let ws = &mut self.ws;
                self.session.scoped(|| {
                    let ran_chunked = match chunk_words {
                        Some(cw) => {
                            batch::gemm_expanding_chunked_into(src, acc, ta, tb, cw, m, n, k, a, b, rm, ws, out)
                        }
                        None => false,
                    };
                    if !ran_chunked && !batch::gemm_expanding_into(src, acc, ta, tb, m, n, k, a, b, rm, ws, out) {
                        // Non-expanding family (the FMA kernels):
                        // materialize the logical operands in the
                        // workspace's transpose staging (taken out for
                        // the nested call, then returned) and run the
                        // kind dispatcher.
                        let mut ta_buf = std::mem::take(&mut ws.ft_a);
                        let mut tb_buf = std::mem::take(&mut ws.ft_b);
                        let a2: &[f64] = if ta {
                            transpose_f64_into(a, k, m, &mut ta_buf);
                            &ta_buf
                        } else {
                            a
                        };
                        let b2: &[f64] = if tb {
                            transpose_f64_into(b, n, k, &mut tb_buf);
                            &tb_buf
                        } else {
                            b
                        };
                        batch::gemm_dispatch_into(kind, m, n, k, a2, b2, rm, ws, out);
                        ws.ft_a = ta_buf;
                        ws.ft_b = tb_buf;
                    }
                });
                (self.session.cycle_model_enabled().then(|| self.kern.model_cycles()), None)
            }
        };
        // Epilogue: C re-encoded onto the accumulation grid (always
        // RNE, matching the plan layer's tensor re-encode).
        if self.regrid_output {
            let acc = self.acc;
            self.session.scoped(|| batch::regrid_in_place(acc, out, RoundingMode::Rne));
        }
        self.runs += 1;
        crate::obs_count!("api.plan.runs");
        if self.session.rounding().is_stochastic() {
            crate::obs_count!("numerics.sr.runs");
        }
        Ok(RunInfo {
            cycles,
            flops: self.kern.flops(),
            stats,
            mode,
            packed_input: false,
            wall: t0.elapsed(),
        })
    }

    /// Run on typed tensors, writing decoded C into `out`. Identical
    /// routing to [`crate::api::GemmPlan::run`]: when the functional
    /// engine is selected and both tensors already provide the kernel's
    /// streams, the packed words feed the batch engine directly (zero
    /// decode/re-pack, `RunInfo::packed_input`); all other combinations
    /// decode into the workspace and take the f64 route. Both routes
    /// are bit-identical to the one-shot plan (pinned by tests).
    pub fn run_into(&mut self, a: &MfTensor, b: &MfTensor, out: &mut Vec<f64>) -> Result<RunInfo> {
        let (m, n, k) = self.dims();
        expect_fmt(a, self.src, "A")?;
        expect_fmt(b, self.src, "B")?;
        let (ar, ac) = self.a_shape();
        let (br, bc) = self.b_shape();
        ensure!(a.shape() == (ar, ac), "A must be {ar}x{ac}, got {}x{}", a.rows(), a.cols());
        ensure!(b.shape() == (br, bc), "B must be {br}x{bc}, got {}x{}", b.rows(), b.cols());
        let a_streams = a.layout() == if self.ta { Layout::ColMajor } else { Layout::RowMajor };
        let b_streams = b.layout() == if self.tb { Layout::RowMajor } else { Layout::ColMajor };
        if self.session.mode() == ExecMode::Functional && a_streams && b_streams {
            let t0 = std::time::Instant::now();
            let _sp = crate::obs::trace::span_with("plan.run", "api", || {
                format!("\"m\":{m},\"n\":{n},\"k\":{k},\"mode\":\"Functional\",\"packed\":true")
            });
            // Same per-run key split as the f64 route (identity for
            // non-stochastic modes), so both routes stay bit-identical
            // run for run.
            let rm = self.session.rounding().sr_run(self.runs);
            let (src, acc) = (self.src, self.acc);
            let plan = &self.block_plan;
            let chunk_words = self.chunk_words;
            let hit = self.session.scoped(|| match chunk_words {
                Some(cw) => {
                    batch::gemm_packed_chunked_into(src, acc, cw, m, n, k, a.words(), b.words(), rm, out)
                }
                None => batch::gemm_packed_planned_into(src, acc, plan, m, n, k, a.words(), b.words(), rm, out),
            });
            if hit {
                if self.regrid_output {
                    self.session.scoped(|| batch::regrid_in_place(acc, out, RoundingMode::Rne));
                }
                self.runs += 1;
                self.packed_runs += 1;
                crate::obs_count!("api.plan.runs");
                crate::obs_count!("api.plan.packed_runs");
                if self.session.rounding().is_stochastic() {
                    crate::obs_count!("numerics.sr.runs");
                }
                return Ok(RunInfo {
                    cycles: self.session.cycle_model_enabled().then(|| self.kern.model_cycles()),
                    flops: self.kern.flops(),
                    stats: None,
                    mode: ExecMode::Functional,
                    packed_input: true,
                    wall: t0.elapsed(),
                });
            }
        }
        // Fallback: decode into the workspace staging buffers (taken
        // out for the nested call, then returned) and run f64.
        let mut fa = std::mem::take(&mut self.ws.fa);
        let mut fb = std::mem::take(&mut self.ws.fb);
        a.view().to_f64_into(&mut fa);
        b.view().to_f64_into(&mut fb);
        let r = self.run_f64_into(&fa, &fb, out);
        self.ws.fa = fa;
        self.ws.fb = fb;
        r
    }

    /// Cache the A operand (validated now, cloned into the instance)
    /// for [`PlanInstance::run_bound`].
    pub fn bind_a(&mut self, a: &MfTensor) -> Result<()> {
        expect_fmt(a, self.src, "A")?;
        let (ar, ac) = self.a_shape();
        ensure!(a.shape() == (ar, ac), "A must be {ar}x{ac}, got {}x{}", a.rows(), a.cols());
        self.a_bound = Some(a.clone());
        Ok(())
    }

    /// Cache the B operand — the fixed-weights pattern: serve shards
    /// bind a frozen layer's packed weights once and stream request
    /// batches through [`PlanInstance::run_reusing`].
    pub fn bind_b(&mut self, b: &MfTensor) -> Result<()> {
        expect_fmt(b, self.src, "B")?;
        let (br, bc) = self.b_shape();
        ensure!(b.shape() == (br, bc), "B must be {br}x{bc}, got {}x{}", b.rows(), b.cols());
        self.b_bound = Some(b.clone());
        Ok(())
    }

    /// [`PlanInstance::run_into`] against the bound B operand.
    pub fn run_reusing(&mut self, a: &MfTensor, out: &mut Vec<f64>) -> Result<RunInfo> {
        let Some(b) = self.b_bound.take() else {
            bail!("no bound B operand: call PlanInstance::bind_b first (or use run_into)");
        };
        let r = self.run_into(a, &b, out);
        self.b_bound = Some(b);
        r
    }

    /// [`PlanInstance::run_into`] with both operands bound (steady-state
    /// benchmarking of a fixed problem).
    pub fn run_bound(&mut self, out: &mut Vec<f64>) -> Result<RunInfo> {
        ensure!(
            self.a_bound.is_some() && self.b_bound.is_some(),
            "both operands must be bound (bind_a + bind_b) before run_bound"
        );
        let a = self.a_bound.take().expect("checked above");
        let b = self.b_bound.take().expect("checked above");
        let r = self.run_into(&a, &b, out);
        self.a_bound = Some(a);
        self.b_bound = Some(b);
        r
    }
}
