//! The crate's typed front door: [`Session`] / [`MfTensor`] /
//! [`GemmPlan`].
//!
//! Everything below this module — softfloat, the batch engine, the
//! kernel generators, the cycle-accurate cluster — predates it and
//! speaks in raw `f64` slices, positional `(m, n, k)` sizes, and
//! runtime format values, with panics on unsupported combinations.
//! This module is the single coherent surface over that stack:
//!
//! * [`MfTensor`] — an owned packed-`u64` tensor that carries its
//!   [`FpFormat`](crate::formats::FpFormat), shape, and storage layout
//!   ([`Layout`]), with `from_f64` / `to_f64` / `cast` / `view`.
//! * [`Session`] — execution policy (engine, rounding, seed, thread
//!   budget, cycle-model toggle) owned once instead of threaded through
//!   every call.
//! * [`GemmPlan`] / [`AccumulatePlan`] — validated op builders:
//!   `session.gemm().src(FP8).acc(FP16).dims(m, n, k)?.run(&a, &b)?`
//!   returns a structured [`RunReport`]; every invalid format pair,
//!   shape mismatch, or infeasible problem is a typed
//!   [`Error`](crate::util::error::Error) at plan-build time, never a
//!   panic mid-run.
//! * [`PlanInstance`] — a plan compiled once into a reusable executor:
//!   owns its [`crate::batch::Workspace`] and cached packed operands,
//!   writes into caller buffers (`run_into` / `run_reusing`), so the
//!   steady state allocates nothing per GEMM. The substrate under the
//!   nn trainer's and serve shards' hot loops.
//!
//! The pre-API free functions are gone (the deprecated `batch::gemm`
//! shim served its one release and has been removed); the differential
//! tests in this module pin the typed surface bit-identical to the
//! kernel-level reference paths instead.
//!
//! ```
//! use minifloat_nn::prelude::*;
//!
//! # fn main() -> minifloat_nn::util::error::Result<()> {
//! let session = Session::builder().mode(ExecMode::Functional).seed(7).build();
//! let mut rng = session.rng();
//! let a: Vec<f64> = (0..16 * 16).map(|_| rng.gaussian() * 0.25).collect();
//! let b: Vec<f64> = (0..16 * 16).map(|_| rng.gaussian() * 0.25).collect();
//! let report = session.gemm().src(FP8).acc(FP16).dims(16, 16, 16)?.run_f64(&a, &b)?;
//! assert_eq!(report.c.shape(), (16, 16));
//! println!("{} FLOP in {:?} cycles", report.flops, report.cycles);
//! # Ok(())
//! # }
//! ```

pub mod instance;
pub mod plan;
pub mod serve;
pub mod session;
pub mod tensor;
pub mod train;
#[cfg(test)]
mod tests;

pub use instance::{PlanInstance, RunInfo};
pub use plan::{AccumulatePlan, AccumulatePlanBuilder, GemmPlan, GemmPlanBuilder, RunReport};
pub use serve::{ServePlan, ServePlanBuilder};
pub use session::{Session, SessionBuilder};
pub use tensor::{Layout, MfTensor, MfTensorView};
pub use train::{TrainPlan, TrainPlanBuilder};

use crate::bail;
use crate::kernels::gemm::{ExecMode, GemmKind};
use crate::util::error::Result;

// ---------------------------------------------------------- CLI parsing
//
// Shared by the `repro` binary and unit-testable without spawning it.

/// Parse an `MxN` problem size (e.g. `128x128`).
pub fn parse_size(s: &str) -> Result<(usize, usize)> {
    let parsed = s
        .split_once('x')
        .and_then(|(a, b)| Some((a.trim().parse::<usize>().ok()?, b.trim().parse::<usize>().ok()?)));
    match parsed {
        Some((m, n)) if m > 0 && n > 0 => Ok((m, n)),
        _ => bail!("--size must be MxN with positive integers (e.g. 128x128), got '{s}'"),
    }
}

/// Parse a kernel-family name (`fp64|fp32|fp16|fp16to32|fp8`).
pub fn parse_kernel(s: &str) -> Result<GemmKind> {
    use crate::isa::instr::{OpWidth, ScalarFmt};
    match s {
        "fp64" => Ok(GemmKind::FmaF64),
        "fp32" => Ok(GemmKind::FmaSimd(ScalarFmt::S)),
        "fp16" => Ok(GemmKind::FmaSimd(ScalarFmt::H)),
        "fp16to32" => Ok(GemmKind::ExSdotp(OpWidth::HtoS)),
        "fp8" => Ok(GemmKind::ExSdotp(OpWidth::BtoH)),
        other => bail!("--kernel must be fp64|fp32|fp16|fp16to32|fp8, got '{other}'"),
    }
}

/// Parse an execution-mode name (`functional|cycle`).
pub fn parse_mode(s: &str) -> Result<ExecMode> {
    match s {
        "cycle" => Ok(ExecMode::CycleAccurate),
        "functional" => Ok(ExecMode::Functional),
        other => bail!("--mode must be functional|cycle, got '{other}'"),
    }
}

/// Which engine drives `repro train`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrainEngine {
    /// The offline native trainer ([`Session::train`]).
    Native,
    /// The artifact-backed PJRT coordinator ([`Session::trainer`]).
    Pjrt,
}

/// Parse a training-engine name (`native|pjrt`).
pub fn parse_engine(s: &str) -> Result<TrainEngine> {
    match s {
        "native" => Ok(TrainEngine::Native),
        "pjrt" => Ok(TrainEngine::Pjrt),
        other => bail!("--engine must be native|pjrt, got '{other}'"),
    }
}

/// Parse a precision-policy name
/// (`fp32|fp16|fp16alt|fp8|hfp8|fp8sr|fp8flex`) — thin re-export of
/// [`crate::nn::PrecisionPolicy::parse`] so the CLI keeps one import.
pub fn parse_policy(s: &str) -> Result<crate::nn::PrecisionPolicy> {
    crate::nn::PrecisionPolicy::parse(s)
}
