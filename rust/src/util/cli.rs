//! Minimal CLI argument parsing for the `repro` binary (clap is
//! unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments; unknown options are collected and reported by the caller.

use std::collections::BTreeMap;

/// Parsed command line: subcommand, options, positionals.
#[derive(Debug, Default)]
pub struct Args {
    /// First positional argument (the subcommand), if any.
    pub command: Option<String>,
    /// `--key value` / `--key=value` options, keys without the `--`.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag`s (no value).
    pub flags: Vec<String>,
    /// Remaining positional arguments after the subcommand.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    /// Option lookup with a default, parsed to any `FromStr` type.
    /// A present-but-unparseable value silently falls back to the
    /// default; prefer [`Args::try_get`] where a typo must not turn
    /// into a different configuration.
    pub fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.options.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Like [`Args::get`] but a present, unparseable value is a typed
    /// error naming the flag — not a silent fallback to the default.
    pub fn try_get<T: std::str::FromStr>(
        &self,
        key: &str,
        default: T,
    ) -> crate::util::error::Result<T> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                crate::util::error::Error::msg(format!(
                    "--{key} expects a numeric value, got '{v}'"
                ))
            }),
        }
    }

    /// String option lookup.
    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.options.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Is a bare flag present?
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = Args::parse(argv("table2 extra --size 128x128 --fmt=fp8 --verbose"));
        assert_eq!(a.command.as_deref(), Some("table2"));
        assert_eq!(a.get_str("size", ""), "128x128");
        assert_eq!(a.get_str("fmt", ""), "fp8");
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn typed_defaults() {
        let a = Args::parse(argv("train --steps 300"));
        assert_eq!(a.get::<u64>("steps", 10), 300);
        assert_eq!(a.get::<u64>("batch", 32), 32);
        assert_eq!(a.get::<f64>("lr", 0.1), 0.1);
    }

    #[test]
    fn try_get_rejects_malformed_values() {
        let a = Args::parse(argv("serve --max-batch 6k --requests 24"));
        assert_eq!(a.try_get::<usize>("requests", 1).unwrap(), 24);
        assert_eq!(a.try_get::<usize>("absent", 7).unwrap(), 7);
        let err = a.try_get::<usize>("max-batch", 32).unwrap_err();
        assert!(err.to_string().contains("--max-batch expects"), "{err}");
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = Args::parse(argv("x --a --b v"));
        assert!(a.has_flag("a"));
        assert_eq!(a.get_str("b", ""), "v");
    }
}
