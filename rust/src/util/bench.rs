//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! Each `cargo bench` target is a plain `main()` that builds a
//! [`Bencher`] and registers closures. The harness warms up, then runs
//! timed batches until a time budget is spent, reporting median / mean /
//! stddev per iteration plus optional throughput.

use std::time::{Duration, Instant};

/// One benchmark's collected statistics.
#[derive(Clone, Debug)]
pub struct Stats {
    /// Benchmark label.
    pub name: String,
    /// Median time per iteration.
    pub median: Duration,
    /// Mean time per iteration.
    pub mean: Duration,
    /// Standard deviation across batch means.
    pub stddev: Duration,
    /// Iterations measured in total.
    pub iters: u64,
    /// Optional user-provided items/iteration for throughput reporting.
    pub items_per_iter: Option<f64>,
}

/// Measurement harness.
pub struct Bencher {
    /// Per-benchmark wall-clock budget.
    pub budget: Duration,
    /// Warmup duration before measurement.
    pub warmup: Duration,
    results: Vec<Stats>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    /// Harness with defaults (1 s budget, 200 ms warmup). Override via
    /// env `BENCH_BUDGET_MS` / `BENCH_WARMUP_MS` (useful in CI).
    pub fn new() -> Self {
        let ms = |var: &str, default: u64| {
            std::env::var(var).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
        };
        Bencher {
            budget: Duration::from_millis(ms("BENCH_BUDGET_MS", 1000)),
            warmup: Duration::from_millis(ms("BENCH_WARMUP_MS", 200)),
            results: Vec::new(),
        }
    }

    /// Benchmark `f`, which performs one iteration per call and returns a
    /// value that is black-boxed to keep the optimizer honest.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Stats {
        self.bench_items(name, None, move || {
            std::hint::black_box(f());
        })
    }

    /// Like [`Self::bench`] but records `items` processed per iteration so
    /// the report includes throughput (e.g. FLOP/s or ops/s).
    pub fn bench_throughput<T>(&mut self, name: &str, items: f64, mut f: impl FnMut() -> T) -> &Stats {
        self.bench_items(name, Some(items), move || {
            std::hint::black_box(f());
        })
    }

    fn bench_items(&mut self, name: &str, items: Option<f64>, mut f: impl FnMut()) -> &Stats {
        // Warmup and batch-size calibration.
        let t0 = Instant::now();
        let mut calib_iters = 0u64;
        while t0.elapsed() < self.warmup {
            f();
            calib_iters += 1;
        }
        let per_iter = self.warmup.as_secs_f64() / calib_iters.max(1) as f64;
        // Aim for ~50 batches within the budget.
        let batch = ((self.budget.as_secs_f64() / 50.0 / per_iter.max(1e-9)).ceil() as u64).max(1);

        let mut batch_means: Vec<f64> = Vec::new();
        let mut total_iters = 0u64;
        let start = Instant::now();
        while start.elapsed() < self.budget {
            let b0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            batch_means.push(b0.elapsed().as_secs_f64() / batch as f64);
            total_iters += batch;
        }
        batch_means.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = batch_means[batch_means.len() / 2];
        let mean = batch_means.iter().sum::<f64>() / batch_means.len() as f64;
        let var = batch_means.iter().map(|m| (m - mean) * (m - mean)).sum::<f64>() / batch_means.len() as f64;

        let stats = Stats {
            name: name.to_string(),
            median: Duration::from_secs_f64(median),
            mean: Duration::from_secs_f64(mean),
            stddev: Duration::from_secs_f64(var.sqrt()),
            iters: total_iters,
            items_per_iter: items,
        };
        print_stats(&stats);
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// All results collected so far.
    pub fn results(&self) -> &[Stats] {
        &self.results
    }
}

fn print_stats(s: &Stats) {
    let fmt_d = |d: Duration| {
        let ns = d.as_nanos() as f64;
        if ns < 1e3 {
            format!("{ns:.1} ns")
        } else if ns < 1e6 {
            format!("{:.2} µs", ns / 1e3)
        } else if ns < 1e9 {
            format!("{:.3} ms", ns / 1e6)
        } else {
            format!("{:.3} s", ns / 1e9)
        }
    };
    let mut line = format!(
        "{:<44} median {:>10}  mean {:>10} ± {:>9}  ({} iters)",
        s.name,
        fmt_d(s.median),
        fmt_d(s.mean),
        fmt_d(s.stddev),
        s.iters
    );
    if let Some(items) = s.items_per_iter {
        let rate = items / s.median.as_secs_f64();
        line += &if rate > 1e9 {
            format!("  [{:.2} G/s]", rate / 1e9)
        } else if rate > 1e6 {
            format!("  [{:.2} M/s]", rate / 1e6)
        } else {
            format!("  [{:.2} k/s]", rate / 1e3)
        };
    }
    println!("{line}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        std::env::set_var("BENCH_BUDGET_MS", "50");
        std::env::set_var("BENCH_WARMUP_MS", "10");
        let mut b = Bencher::new();
        let s = b.bench("noop-ish", || std::hint::black_box(3u64).wrapping_mul(7)).clone();
        assert!(s.iters > 0);
        assert!(s.median.as_nanos() < 1_000_000);
        assert_eq!(b.results().len(), 1);
    }
}
