//! Scoped-thread data parallelism (rayon is unavailable offline).
//!
//! One primitive is enough for the batch numerics engine:
//! [`par_chunks_mut`] splits a mutable slice into fixed-size chunks and
//! fans contiguous chunk ranges out over `std::thread::scope` workers.
//! Each chunk is processed by exactly one worker, so the result is
//! deterministic and independent of the thread count — the batch GEMM
//! relies on that to stay bit-identical to the serial reference.
//!
//! Worker count defaults to `std::thread::available_parallelism()`;
//! `MINIFLOAT_NN_THREADS=1` forces serial execution (useful when
//! bisecting or benchmarking the single-core path).

use std::cell::Cell;

thread_local! {
    /// Per-thread worker-count override (see [`with_worker_count`]).
    static WORKER_OVERRIDE: Cell<Option<usize>> = const { Cell::new(None) };
}

/// Number of worker threads to use.
pub fn worker_count() -> usize {
    if let Some(n) = WORKER_OVERRIDE.with(|c| c.get()) {
        return n.max(1);
    }
    if let Ok(v) = std::env::var("MINIFLOAT_NN_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f` with the worker count pinned to `n` on this thread (and any
/// [`par_chunks_mut`] fan-out it performs). Unlike the
/// `MINIFLOAT_NN_THREADS` env var this is scoped and thread-local, so a
/// `Session` thread budget cannot race with other sessions in the same
/// process. The previous override is restored even if `f` panics.
pub fn with_worker_count<R>(n: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(Option<usize>);
    impl Drop for Restore {
        fn drop(&mut self) {
            WORKER_OVERRIDE.with(|c| c.set(self.0));
        }
    }
    let _guard = Restore(WORKER_OVERRIDE.with(|c| c.replace(Some(n.max(1)))));
    f()
}

/// Apply `f(chunk_index, chunk)` to consecutive `chunk_len`-sized chunks
/// of `data` (the last chunk may be shorter), distributing contiguous
/// chunk ranges across worker threads.
pub fn par_chunks_mut<T: Send, F: Fn(usize, &mut [T]) + Sync>(data: &mut [T], chunk_len: usize, f: F) {
    assert!(chunk_len > 0, "chunk_len must be positive");
    if data.is_empty() {
        return;
    }
    let n_chunks = (data.len() + chunk_len - 1) / chunk_len;
    let threads = worker_count().min(n_chunks);
    if threads <= 1 {
        for (i, c) in data.chunks_mut(chunk_len).enumerate() {
            f(i, c);
        }
        return;
    }
    // Split on chunk boundaries into one contiguous span per worker.
    let chunks_per_worker = (n_chunks + threads - 1) / threads;
    let span = chunks_per_worker * chunk_len;
    std::thread::scope(|s| {
        for (t, part) in data.chunks_mut(span).enumerate() {
            let f = &f;
            s.spawn(move || {
                for (j, c) in part.chunks_mut(chunk_len).enumerate() {
                    f(t * chunks_per_worker + j, c);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_everything_once() {
        let mut v = vec![0u64; 1003]; // deliberately not a multiple of 16
        par_chunks_mut(&mut v, 16, |idx, chunk| {
            for (off, x) in chunk.iter_mut().enumerate() {
                *x = (idx * 16 + off) as u64 + 1;
            }
        });
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i as u64 + 1, "element {i} touched incorrectly");
        }
    }

    #[test]
    fn result_is_thread_count_independent() {
        let run = || {
            let mut v = vec![0u64; 257];
            par_chunks_mut(&mut v, 8, |idx, chunk| {
                for (off, x) in chunk.iter_mut().enumerate() {
                    *x = (idx as u64) << 32 | off as u64;
                }
            });
            v
        };
        // Same output regardless of how the scheduler slices it.
        assert_eq!(run(), run());
    }

    #[test]
    fn worker_override_is_scoped() {
        let outside = worker_count();
        let inside = with_worker_count(1, worker_count);
        assert_eq!(inside, 1);
        assert_eq!(worker_count(), outside, "override must not leak");
        // Nested overrides restore the outer one.
        with_worker_count(3, || {
            assert_eq!(worker_count(), 3);
            with_worker_count(2, || assert_eq!(worker_count(), 2));
            assert_eq!(worker_count(), 3);
        });
    }

    #[test]
    fn empty_and_tiny_inputs() {
        let mut e: Vec<u32> = vec![];
        par_chunks_mut(&mut e, 4, |_, _| panic!("no chunks expected"));
        let mut one = vec![7u32];
        par_chunks_mut(&mut one, 4, |idx, c| {
            assert_eq!(idx, 0);
            c[0] = 9;
        });
        assert_eq!(one, vec![9]);
    }
}
