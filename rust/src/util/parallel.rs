//! The parallel execution substrate: a persistent worker pool
//! ([`Executor`]) with the original chunked data-parallel primitive
//! ([`par_chunks_mut`]) as a thin shim over it.
//!
//! The paper's cluster keeps its eight cores and their TCDM hot across
//! an entire GEMM stream; the software analogue is a **long-lived
//! executor**. Early revisions spawned fresh `std::thread::scope`
//! workers on every call, which taxed the hottest paths (nn training
//! steps, serve dispatches) with thread churn. Now a process-wide pool
//! of workers ([`Executor::global`]) is spawned once and fed chunk
//! spans over channels; `par_chunks_mut` keeps its exact contract:
//!
//! * each chunk is processed by exactly one worker and `f` receives the
//!   **global** chunk index, so results are deterministic and
//!   bit-identical at any worker count and under any dispatch backend
//!   (pinned by the differential tests below);
//! * spans are balanced on chunk boundaries — worker `t` gets
//!   `base + (t < n_chunks % threads)` chunks, so no worker idles while
//!   another holds two spare chunks (the old ceil-split could leave
//!   trailing workers with zero chunks);
//! * a dispatch **nested inside a pool worker runs inline** on that
//!   worker (no cross-worker waiting, hence no pool deadlock); the
//!   outermost fan-out owns the parallelism.
//!
//! Worker count defaults to `std::thread::available_parallelism()`;
//! `MINIFLOAT_NN_THREADS` (read **once** per process, then cached)
//! overrides it, and [`with_worker_count`] scopes a thread-local
//! override per session. The legacy per-call scoped-thread backend
//! survives as [`Dispatch::Scoped`] for differential tests and the
//! steady-state benchmarks' allocate-per-call baseline.

use std::cell::Cell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Mutex, OnceLock};

thread_local! {
    /// Per-thread worker-count override (see [`with_worker_count`]).
    static WORKER_OVERRIDE: Cell<Option<usize>> = const { Cell::new(None) };
    /// Per-thread dispatch-backend override (see [`with_dispatch`]).
    static DISPATCH_OVERRIDE: Cell<Option<Dispatch>> = const { Cell::new(None) };
    /// Id of the [`Executor`] pool owning this thread, if any — tagged
    /// per pool so dispatching onto a *different* (idle) pool from a
    /// worker still parallelizes; only a same-pool nested dispatch
    /// inlines.
    static POOL_WORKER_OF: Cell<Option<usize>> = const { Cell::new(None) };
}

/// Process-default worker count: the `MINIFLOAT_NN_THREADS` env var if
/// set and parseable, else `available_parallelism()`. The env var is
/// read **once** and cached — it used to be re-parsed on every call,
/// on the hottest dispatch path.
fn default_worker_count() -> usize {
    static DEFAULT: OnceLock<usize> = OnceLock::new();
    *DEFAULT.get_or_init(|| {
        if let Ok(v) = std::env::var("MINIFLOAT_NN_THREADS") {
            if let Ok(n) = v.parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

/// Number of worker spans a dispatch fans out to: the thread-local
/// override if one is active, else the cached process default.
pub fn worker_count() -> usize {
    if let Some(n) = WORKER_OVERRIDE.with(|c| c.get()) {
        return n.max(1);
    }
    default_worker_count()
}

/// Run `f` with the worker count pinned to `n` on this thread (and any
/// [`par_chunks_mut`] fan-out it performs). Unlike the
/// `MINIFLOAT_NN_THREADS` env var this is scoped and thread-local, so a
/// `Session` thread budget cannot race with other sessions in the same
/// process. The previous override is restored even if `f` panics.
///
/// Budget semantics are unchanged from the scoped-thread era: `n` caps
/// the number of *spans* a dispatch splits into (and therefore the
/// concurrency), and results are bit-identical at any value.
pub fn with_worker_count<R>(n: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(Option<usize>);
    impl Drop for Restore {
        fn drop(&mut self) {
            WORKER_OVERRIDE.with(|c| c.set(self.0));
        }
    }
    let _guard = Restore(WORKER_OVERRIDE.with(|c| c.replace(Some(n.max(1)))));
    f()
}

// ------------------------------------------------------------ dispatch

/// Which backend executes a [`par_chunks_mut`] fan-out. All three run
/// the same balanced span split and hand `f` the same global chunk
/// indices, so they are bit-identical by construction (and pinned so
/// by tests).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dispatch {
    /// The persistent process pool ([`Executor::global`]) — the default.
    Pool,
    /// Legacy behaviour: fresh `std::thread::scope` workers per call.
    /// Kept as the differential-test reference and the benchmarks'
    /// allocate-per-call baseline.
    Scoped,
    /// Run every chunk inline on the calling thread.
    Serial,
}

/// The dispatch backend active on this thread (default [`Dispatch::Pool`]).
pub fn dispatch_mode() -> Dispatch {
    DISPATCH_OVERRIDE.with(|c| c.get()).unwrap_or(Dispatch::Pool)
}

/// Run `f` with the dispatch backend pinned on this thread; restored
/// on exit (even across panics). Exists for differential tests and
/// benchmarks — production code leaves the default pool in place.
pub fn with_dispatch<R>(d: Dispatch, f: impl FnOnce() -> R) -> R {
    struct Restore(Option<Dispatch>);
    impl Drop for Restore {
        fn drop(&mut self) {
            DISPATCH_OVERRIDE.with(|c| c.set(self.0));
        }
    }
    let _guard = Restore(DISPATCH_OVERRIDE.with(|c| c.replace(Some(d))));
    f()
}

// ------------------------------------------------------------ executor

/// One unit of pool work: a type-erased task executed for a strided
/// set of span indices, with a completion channel back to the
/// dispatcher.
struct Job {
    /// Lifetime-erased `&(dyn Fn(usize) + Sync)`. Valid for the whole
    /// job: [`Executor::run`] blocks until every job has reported
    /// completion before returning (or unwinding).
    task: *const (dyn Fn(usize) + Sync),
    start: usize,
    stride: usize,
    count: usize,
    done: Sender<std::thread::Result<()>>,
}

// SAFETY: the raw task pointer is only dereferenced while the
// dispatching `Executor::run` frame is alive (it joins on `done`
// messages before returning), and the pointee is `Sync`.
unsafe impl Send for Job {}

fn worker_loop(rx: Receiver<Job>, pool_id: usize) {
    POOL_WORKER_OF.with(|f| f.set(Some(pool_id)));
    while let Ok(job) = rx.recv() {
        let result = catch_unwind(AssertUnwindSafe(|| {
            // SAFETY: see `Job::task` — the dispatcher keeps the task
            // alive until this job's completion message is received.
            let task = unsafe { &*job.task };
            let mut i = job.start;
            for _ in 0..job.count {
                task(i);
                i += job.stride;
            }
        }));
        let _ = job.done.send(result);
    }
}

/// A persistent pool of worker threads fed over channels — the
/// process-wide execution substrate behind [`par_chunks_mut`].
///
/// Workers are spawned once and live for the pool's lifetime (the
/// global pool's lifetime is the process); a dispatch sends each used
/// worker one `Job` and blocks until all jobs report back, so
/// borrowed data outlives every access. Panics inside a task are
/// caught on the worker, forwarded, and re-raised on the dispatching
/// thread after the barrier — a panicking task cannot poison the pool.
#[derive(Debug)]
pub struct Executor {
    senders: Vec<Mutex<Sender<Job>>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    /// Unique pool id (for same-pool nested-dispatch detection).
    id: usize,
    /// Rotating placement offset: concurrent dispatchers whose span
    /// counts are below the pool size start at different workers
    /// instead of piling onto workers `0..used` while the tail of the
    /// pool idles. Placement never affects results (chunk indices are
    /// global), only load spread.
    next: AtomicUsize,
}

impl Executor {
    /// Spawn a dedicated pool with `workers` threads (clamped to ≥ 1).
    /// Dropping the pool closes the channels and joins the threads.
    pub fn new(workers: usize) -> Executor {
        static NEXT_POOL_ID: AtomicUsize = AtomicUsize::new(0);
        let id = NEXT_POOL_ID.fetch_add(1, Ordering::Relaxed);
        let workers = workers.max(1);
        let mut senders = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let (tx, rx) = channel::<Job>();
            senders.push(Mutex::new(tx));
            handles.push(
                std::thread::Builder::new()
                    .name(format!("mfnn-pool-{i}"))
                    .spawn(move || worker_loop(rx, id))
                    .expect("spawning an executor pool worker"),
            );
        }
        Executor { senders, handles, id, next: AtomicUsize::new(0) }
    }

    /// The shared process pool, spawned lazily on first use and sized
    /// by the cached default worker count. Session thread budgets do
    /// not resize it — they cap how many spans a dispatch uses.
    pub fn global() -> &'static Executor {
        static GLOBAL: OnceLock<Executor> = OnceLock::new();
        GLOBAL.get_or_init(|| Executor::new(default_worker_count()))
    }

    /// Worker threads in this pool.
    pub fn size(&self) -> usize {
        self.senders.len()
    }

    /// Execute `task(i)` exactly once for every `i in 0..spans`,
    /// fanning the indices out over the pool (span `i` runs on worker
    /// `i % used`, strided, so `spans` may exceed the pool size — e.g.
    /// a thread budget wider than the machine). Runs inline when there
    /// is one span or when already on one of **this pool's own**
    /// workers (same-pool nested dispatch — the deadlock case; a
    /// different pool's worker may dispatch here in parallel freely).
    /// Blocks until every span completed; re-raises the first task
    /// panic after the barrier.
    ///
    /// Dispatch cost per call: one completion channel plus one `Job`
    /// per used worker — a few small allocations, noise next to the
    /// per-call `thread::scope` spawns this pool replaces (a reusable
    /// countdown barrier could remove even that if it ever shows up in
    /// a profile).
    pub fn run(&self, spans: usize, task: &(dyn Fn(usize) + Sync)) {
        if spans == 0 {
            return;
        }
        if spans == 1 || POOL_WORKER_OF.with(|f| f.get()) == Some(self.id) {
            for i in 0..spans {
                task(i);
            }
            return;
        }
        let used = self.size().min(spans);
        let (done_tx, done_rx) = channel();
        // SAFETY: pure lifetime erasure of a fat pointer; the barrier
        // below guarantees this frame never unwinds or returns while a
        // dispatched job might still dereference it — even if the
        // dispatch loop itself panics mid-way (the guard drains a
        // completion message for every job already sent).
        let task_ptr: *const (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(task) };
        struct Barrier<'a> {
            rx: &'a Receiver<std::thread::Result<()>>,
            outstanding: usize,
            tx: Option<Sender<std::thread::Result<()>>>,
        }
        impl Drop for Barrier<'_> {
            fn drop(&mut self) {
                self.tx.take();
                while self.outstanding > 0 {
                    // Every sent job sends exactly one message (the
                    // worker's catch_unwind guarantees it); an Err here
                    // means every sender is gone, i.e. nothing still
                    // runs.
                    if self.rx.recv().is_err() {
                        break;
                    }
                    self.outstanding -= 1;
                }
            }
        }
        let mut barrier = Barrier { rx: &done_rx, outstanding: 0, tx: Some(done_tx) };
        // Rotate the placement start so concurrent small dispatches
        // spread over the whole pool.
        let base = self.next.fetch_add(used, Ordering::Relaxed);
        for t in 0..used {
            let done = barrier.tx.as_ref().expect("sender live during dispatch").clone();
            let job = Job {
                task: task_ptr,
                start: t,
                stride: used,
                count: (spans - t + used - 1) / used,
                done,
            };
            self.senders[(base + t) % self.size()]
                .lock()
                .expect("executor sender lock")
                .send(job)
                .expect("executor worker channel closed");
            barrier.outstanding += 1;
        }
        // Close our sender so a worker disappearance is observable as a
        // channel disconnect below.
        barrier.tx.take();
        let mut first_panic: Option<Box<dyn std::any::Any + Send>> = None;
        while barrier.outstanding > 0 {
            match barrier.rx.recv() {
                Ok(r) => {
                    barrier.outstanding -= 1;
                    if let Err(p) = r {
                        if first_panic.is_none() {
                            first_panic = Some(p);
                        }
                    }
                }
                // A worker vanished mid-job (it cannot panic out of
                // `worker_loop`, so this is defensive): every sender is
                // gone, so no job is still running.
                Err(_) => {
                    barrier.outstanding = 0;
                    if first_panic.is_none() {
                        first_panic = Some(Box::new("executor worker disappeared mid-job"));
                    }
                }
            }
        }
        if let Some(p) = first_panic {
            resume_unwind(p);
        }
    }
}

impl Drop for Executor {
    fn drop(&mut self) {
        // Closing the channels ends each worker's recv loop; then join.
        self.senders.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// A `Copy` pairing of the process pool with an optional thread
/// budget — what an [`crate::api::Session`] owns. The budget caps how
/// many spans a dispatch under [`ExecutorHandle::scoped`] fans out to;
/// it never resizes the pool, and results are bit-identical at any
/// value (the same determinism contract as the scoped-thread era).
/// The shared pool is resolved **lazily**: constructing a handle and
/// running work under [`ExecutorHandle::scoped`] never spawn threads
/// themselves (the first actual parallel dispatch does); only the
/// pool-introspecting accessors ([`ExecutorHandle::pool`], and
/// [`ExecutorHandle::workers`] on a budget-less handle) force the
/// spawn.
#[derive(Clone, Copy, Debug)]
pub struct ExecutorHandle {
    budget: Option<usize>,
}

impl ExecutorHandle {
    /// A handle on the global pool with the given budget (`None` = all
    /// pool workers).
    pub fn with_budget(budget: Option<usize>) -> ExecutorHandle {
        ExecutorHandle { budget }
    }

    /// The pool this handle dispatches on (spawned on first resolve).
    pub fn pool(&self) -> &'static Executor {
        Executor::global()
    }

    /// The configured budget (`None` = all pool workers).
    pub fn budget(&self) -> Option<usize> {
        self.budget
    }

    /// Worker spans a dispatch under this handle fans out to.
    pub fn workers(&self) -> usize {
        self.budget.map(|n| n.max(1)).unwrap_or_else(|| Executor::global().size())
    }

    /// Run `f` with [`worker_count`] pinned to the handle's budget
    /// (no-op when the budget is unset).
    pub fn scoped<R>(&self, f: impl FnOnce() -> R) -> R {
        match self.budget {
            Some(n) => with_worker_count(n, f),
            None => f(),
        }
    }
}

// ------------------------------------------------------ par_chunks_mut

/// Chunks assigned to worker `t` under the balanced split: the first
/// `n_chunks % threads` workers take one extra chunk, so span sizes
/// differ by at most one and every worker has work.
fn span_chunks(n_chunks: usize, threads: usize, t: usize) -> usize {
    n_chunks / threads + usize::from(t < n_chunks % threads)
}

/// Apply `f(chunk_index, chunk)` to consecutive `chunk_len`-sized chunks
/// of `data` (the last chunk may be shorter), distributing contiguous
/// balanced chunk spans across workers. A thin shim over the process
/// [`Executor`] (or the legacy backends under [`with_dispatch`]): each
/// chunk is processed exactly once with its global index, so the result
/// is bit-identical across worker counts and backends.
pub fn par_chunks_mut<T: Send, F: Fn(usize, &mut [T]) + Sync>(data: &mut [T], chunk_len: usize, f: F) {
    assert!(chunk_len > 0, "chunk_len must be positive");
    if data.is_empty() {
        return;
    }
    let n_chunks = (data.len() + chunk_len - 1) / chunk_len;
    let threads = worker_count().min(n_chunks);
    let mode = dispatch_mode();
    if threads <= 1 || mode == Dispatch::Serial {
        for (i, c) in data.chunks_mut(chunk_len).enumerate() {
            f(i, c);
        }
        return;
    }
    match mode {
        // Serial already returned via the early inline branch above.
        Dispatch::Serial => unreachable!("serial dispatch is handled by the inline early return"),
        Dispatch::Scoped => {
            // Legacy backend: one scope-spawned worker per span.
            std::thread::scope(|s| {
                let mut rest = data;
                let mut first = 0usize;
                for t in 0..threads {
                    let n = span_chunks(n_chunks, threads, t);
                    let take = (n * chunk_len).min(rest.len());
                    let (part, r) = rest.split_at_mut(take);
                    rest = r;
                    let f = &f;
                    let start = first;
                    s.spawn(move || {
                        for (j, c) in part.chunks_mut(chunk_len).enumerate() {
                            f(start + j, c);
                        }
                    });
                    first += n;
                }
            });
        }
        Dispatch::Pool => {
            // Pre-split into balanced disjoint spans, then hand span
            // indices to the pool.
            struct Span<T> {
                first: usize,
                ptr: *mut T,
                len: usize,
            }
            // SAFETY: spans are disjoint sub-slices of `data`, and the
            // executor runs each span index exactly once per dispatch.
            unsafe impl<T: Send> Send for Span<T> {}
            unsafe impl<T: Send> Sync for Span<T> {}
            let mut spans = Vec::with_capacity(threads);
            {
                let mut rest = &mut *data;
                let mut first = 0usize;
                for t in 0..threads {
                    let n = span_chunks(n_chunks, threads, t);
                    let take = (n * chunk_len).min(rest.len());
                    let (part, r) = rest.split_at_mut(take);
                    rest = r;
                    spans.push(Span { first, ptr: part.as_mut_ptr(), len: part.len() });
                    first += n;
                }
            }
            let spans = &spans;
            let f = &f;
            Executor::global().run(threads, &|t: usize| {
                let sp = &spans[t];
                // SAFETY: disjoint spans, each index executed once.
                let part = unsafe { std::slice::from_raw_parts_mut(sp.ptr, sp.len) };
                for (j, c) in part.chunks_mut(chunk_len).enumerate() {
                    f(sp.first + j, c);
                }
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_everything_once() {
        let mut v = vec![0u64; 1003]; // deliberately not a multiple of 16
        par_chunks_mut(&mut v, 16, |idx, chunk| {
            for (off, x) in chunk.iter_mut().enumerate() {
                *x = (idx * 16 + off) as u64 + 1;
            }
        });
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i as u64 + 1, "element {i} touched incorrectly");
        }
    }

    #[test]
    fn result_is_thread_count_independent() {
        let run = || {
            let mut v = vec![0u64; 257];
            par_chunks_mut(&mut v, 8, |idx, chunk| {
                for (off, x) in chunk.iter_mut().enumerate() {
                    *x = (idx as u64) << 32 | off as u64;
                }
            });
            v
        };
        // Same output regardless of how the scheduler slices it.
        assert_eq!(run(), run());
    }

    #[test]
    fn worker_override_is_scoped() {
        let outside = worker_count();
        let inside = with_worker_count(1, worker_count);
        assert_eq!(inside, 1);
        assert_eq!(worker_count(), outside, "override must not leak");
        // Nested overrides restore the outer one.
        with_worker_count(3, || {
            assert_eq!(worker_count(), 3);
            with_worker_count(2, || assert_eq!(worker_count(), 2));
            assert_eq!(worker_count(), 3);
        });
    }

    #[test]
    fn empty_and_tiny_inputs() {
        let mut e: Vec<u32> = vec![];
        par_chunks_mut(&mut e, 4, |_, _| panic!("no chunks expected"));
        let mut one = vec![7u32];
        par_chunks_mut(&mut one, 4, |idx, c| {
            assert_eq!(idx, 0);
            c[0] = 9;
        });
        assert_eq!(one, vec![9]);
    }

    /// Child half of `env_var_is_read_once_and_cached`: a no-op in the
    /// normal run; under the probe marker it asserts the cache. It runs
    /// in a `--test-threads=1` subprocess, so the mid-process
    /// `set_var` below cannot race another test thread's `getenv`
    /// (the reason the parent spawns it instead of mutating the env
    /// in the shared harness process).
    #[test]
    fn env_cache_child_probe() {
        let Some(marker) = std::env::var_os("MFNN_ENV_CACHE_PROBE") else {
            return;
        };
        let expect: usize = marker.to_str().expect("utf-8 marker").parse().expect("numeric marker");
        assert_eq!(worker_count(), expect, "preset MINIFLOAT_NN_THREADS must be honored at first read");
        std::env::set_var("MINIFLOAT_NN_THREADS", (expect + 1).to_string());
        assert_eq!(
            worker_count(),
            expect,
            "worker_count must cache the env var at first read, not re-parse it"
        );
        std::env::remove_var("MINIFLOAT_NN_THREADS");
        assert_eq!(worker_count(), expect);
        // The thread-local override still wins over the cache.
        assert_eq!(with_worker_count(expect + 2, worker_count), expect + 2);
    }

    /// Regression (the env var used to be re-parsed on every call):
    /// changing `MINIFLOAT_NN_THREADS` after the first read must not
    /// change the cached default. Drives the single-threaded child
    /// probe above.
    #[test]
    fn env_var_is_read_once_and_cached() {
        let exe = std::env::current_exe().expect("test executable path");
        let out = std::process::Command::new(exe)
            .args(["--exact", "util::parallel::tests::env_cache_child_probe", "--test-threads=1"])
            .env("MFNN_ENV_CACHE_PROBE", "3")
            .env("MINIFLOAT_NN_THREADS", "3")
            .output()
            .expect("spawning the env-cache child probe");
        let stdout = String::from_utf8_lossy(&out.stdout);
        assert!(
            out.status.success(),
            "child probe failed\nstdout: {stdout}\nstderr: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        // Guard against a vacuous pass from a filter mismatch: the
        // probe must actually have run.
        assert!(stdout.contains("1 passed"), "child probe did not run:\n{stdout}");
    }

    /// Regression for the span split: the old ceil-split could leave
    /// trailing workers with zero chunks when `n_chunks % threads != 0`.
    #[test]
    fn span_split_is_balanced_on_chunk_boundaries() {
        for threads in 1..=8usize {
            for n_chunks in threads..=24 {
                let sizes: Vec<usize> = (0..threads).map(|t| span_chunks(n_chunks, threads, t)).collect();
                assert_eq!(sizes.iter().sum::<usize>(), n_chunks, "{threads} workers, {n_chunks} chunks");
                assert!(sizes.iter().all(|&s| s >= 1), "idle worker in {sizes:?}");
                let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                assert!(max - min <= 1, "unbalanced spans {sizes:?}");
            }
        }
    }

    fn checkerboard(n: usize, chunk: usize) -> Vec<u64> {
        let mut v = vec![0u64; n];
        par_chunks_mut(&mut v, chunk, |idx, c| {
            for (off, x) in c.iter_mut().enumerate() {
                *x = (idx as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ off as u64;
            }
        });
        v
    }

    /// Determinism across worker counts {1, 3, 4, 7} and across all
    /// three dispatch backends, on a chunk count that divides by none
    /// of them.
    #[test]
    fn worker_counts_and_backends_are_bit_identical() {
        let want = with_worker_count(1, || checkerboard(1003, 16));
        for workers in [1usize, 3, 4, 7] {
            for mode in [Dispatch::Pool, Dispatch::Scoped, Dispatch::Serial] {
                let got =
                    with_worker_count(workers, || with_dispatch(mode, || checkerboard(1003, 16)));
                assert_eq!(got, want, "{workers} workers, {mode:?} backend diverged");
            }
        }
    }

    /// A thread budget wider than the pool must still run every span.
    #[test]
    fn budget_wider_than_pool_is_fine() {
        let small = Executor::new(2);
        let hits = std::sync::Mutex::new(vec![0u32; 7]);
        small.run(7, &|i| hits.lock().unwrap()[i] += 1);
        assert_eq!(*hits.lock().unwrap(), vec![1u32; 7]);
    }

    /// Nested dispatch from inside a pool worker runs inline (no
    /// deadlock) and still covers every chunk exactly once.
    #[test]
    fn nested_dispatch_is_inline_and_correct() {
        let mut outer = vec![vec![0u64; 65]; 6];
        par_chunks_mut(&mut outer, 1, |_, rows| {
            for row in rows {
                par_chunks_mut(row, 8, |idx, c| {
                    for (off, x) in c.iter_mut().enumerate() {
                        *x += (idx * 8 + off) as u64 + 1;
                    }
                });
            }
        });
        for row in &outer {
            for (i, &x) in row.iter().enumerate() {
                assert_eq!(x, i as u64 + 1);
            }
        }
    }

    /// A panicking task propagates to the dispatcher and the pool stays
    /// usable afterwards.
    #[test]
    fn pool_survives_task_panics() {
        let result = std::panic::catch_unwind(|| {
            let mut v = vec![0u8; 64];
            with_worker_count(4, || {
                par_chunks_mut(&mut v, 8, |idx, _| {
                    if idx == 5 {
                        panic!("boom");
                    }
                });
            });
        });
        assert!(result.is_err(), "task panic must propagate");
        // The pool still works.
        let mut v = vec![0u64; 64];
        with_worker_count(4, || {
            par_chunks_mut(&mut v, 8, |idx, c| {
                for (off, x) in c.iter_mut().enumerate() {
                    *x = (idx * 8 + off) as u64;
                }
            });
        });
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i as u64);
        }
    }

    /// The session-facing handle: budget caps spans, `None` means the
    /// whole pool, and `scoped` pins the thread-local count.
    #[test]
    fn executor_handle_honors_budget() {
        let h = ExecutorHandle::with_budget(Some(3));
        assert_eq!(h.workers(), 3);
        assert_eq!(h.scoped(worker_count), 3);
        let all = ExecutorHandle::with_budget(None);
        assert_eq!(all.workers(), Executor::global().size());
    }
}
