//! Minimal error handling in the spirit of `anyhow` (unavailable
//! offline): a boxed, message-chaining [`Error`], a crate-wide
//! [`Result`] alias, a [`Context`] extension trait and the
//! [`ensure!`](crate::ensure)/[`bail!`](crate::bail) macros.
//!
//! Only the subset the coordinator/runtime layers actually use is
//! implemented; the API shapes match `anyhow` so swapping the real
//! crate back in (once the build environment has a registry) is a
//! one-line import change.

use std::fmt;

/// A chain of error messages, outermost context first.
pub struct Error {
    chain: Vec<String>,
}

/// `Result` with [`Error`] as the failure type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from a message.
    pub fn msg(m: impl fmt::Display) -> Self {
        Error { chain: vec![m.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context(mut self, m: impl fmt::Display) -> Self {
        self.chain.insert(0, m.to_string());
        self
    }

    /// The messages, outermost first.
    pub fn chain(&self) -> &[String] {
        &self.chain
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Multi-line like anyhow's {:?}: message plus a cause list.
        writeln!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            writeln!(f, "\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                writeln!(f, "    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

// Like `anyhow::Error`, this type deliberately does NOT implement
// `std::error::Error`: the blanket conversion below would otherwise
// overlap with core's reflexive `impl From<T> for T`.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `anyhow::Context`-style extension: attach a message to the error
/// path of a `Result` or to `None`.
pub trait Context<T> {
    /// Wrap the error with `msg`.
    fn context(self, msg: impl fmt::Display) -> Result<T>;
    /// Wrap the error with a lazily-built message.
    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| e.into().context(msg))
    }

    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg))
    }

    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Return early with an error built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($arg)*)))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::util::error::Error::msg(format!($($arg)*)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<u32> {
        let v: u32 = s.parse().context("parsing a number")?;
        ensure!(v < 100, "{v} out of range");
        Ok(v)
    }

    #[test]
    fn context_chains_and_displays() {
        let e = parse("abc").unwrap_err();
        let text = e.to_string();
        assert!(text.starts_with("parsing a number:"), "{text}");
        assert_eq!(e.chain().len(), 2);
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"), "{dbg}");
    }

    #[test]
    fn ensure_and_ok_paths() {
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("420").is_err());
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        let e = none.with_context(|| format!("missing {}", "thing")).unwrap_err();
        assert_eq!(e.to_string(), "missing thing");
        assert_eq!(Some(7).context("fine").unwrap(), 7);
    }
}
