//! Tiny property-testing driver (proptest is unavailable offline).
//!
//! [`for_all`] runs a property over `n` seeded random cases; on failure
//! it reports the case index and seed so the exact input can be replayed
//! by re-seeding [`crate::util::rng::Rng`]. Generators for interesting
//! float encodings live in [`FpGen`] — they bias toward the boundary
//! values (zeros, subnormals, infs, NaNs, max-finite) where IEEE bugs
//! hide, the same trick proptest strategies would use.

use super::rng::Rng;
use crate::formats::FpFormat;

/// Run `prop` over `n` random cases. Panics with seed diagnostics on the
/// first failing case.
pub fn for_all(name: &str, n: u64, mut prop: impl FnMut(&mut Rng)) {
    let base_seed = 0x5eed_0000u64;
    for case in 0..n {
        let seed = base_seed ^ (case.wrapping_mul(0x9e37_79b9_7f4a_7c15));
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(e) = result {
            eprintln!("property '{name}' failed at case {case} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Random generator of format encodings, boundary-biased.
pub struct FpGen {
    /// Format to generate encodings for.
    pub fmt: FpFormat,
}

impl FpGen {
    /// Generator for `fmt`.
    pub fn new(fmt: FpFormat) -> Self {
        Self { fmt }
    }

    /// Any bit pattern, with 25% probability drawn from the boundary set
    /// (±0, min/max subnormal, min normal, max finite, ±inf, NaN, ±1).
    pub fn any(&self, rng: &mut Rng) -> u64 {
        if rng.below(4) == 0 {
            self.edge(rng)
        } else {
            rng.next_u64() & self.fmt.width_mask()
        }
    }

    /// A finite value (any sign), boundary-biased.
    pub fn finite(&self, rng: &mut Rng) -> u64 {
        loop {
            let b = self.any(rng);
            if !self.fmt.is_nan(b) && !self.fmt.is_inf(b) {
                return b;
            }
        }
    }

    /// A boundary encoding.
    pub fn edge(&self, rng: &mut Rng) -> u64 {
        let f = self.fmt;
        let one = crate::softfloat::from_f64(1.0, f, crate::softfloat::RoundingMode::Rne);
        let edges = [
            f.zero(false),
            f.zero(true),
            f.min_subnormal(),
            f.min_subnormal() | f.sign_mask(),
            f.min_normal() - 1, // max subnormal
            f.min_normal(),
            f.max_finite(false),
            f.max_finite(true),
            f.infinity(false),
            f.infinity(true),
            f.quiet_nan(),
            one,
            one | f.sign_mask(),
        ];
        edges[rng.below(edges.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::FP16;

    #[test]
    fn for_all_runs_all_cases() {
        let mut count = 0;
        for_all("counting", 25, |_rng| {
            count += 1;
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic]
    fn for_all_propagates_failures() {
        for_all("failing", 10, |rng| {
            assert!(rng.below(3) != 1, "eventually hits 1");
        });
    }

    #[test]
    fn generators_respect_format_width() {
        let g = FpGen::new(FP16);
        let mut rng = Rng::new(3);
        for _ in 0..1000 {
            assert_eq!(g.any(&mut rng) >> 16, 0);
            let f = g.finite(&mut rng);
            assert!(!FP16.is_nan(f) && !FP16.is_inf(f));
        }
    }
}
