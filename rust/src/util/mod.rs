//! Self-contained utilities replacing crates unavailable in the offline
//! build environment (`rand`, `criterion`, `proptest`, `clap`, `rayon`,
//! `anyhow`).
//!
//! * [`rng`] — splitmix64/xoshiro256** PRNG with uniform and Gaussian
//!   (Box–Muller) sampling; deterministic, seedable, used by the
//!   accuracy harness (Table IV needs Gaussian inputs) and the property
//!   tests.
//! * [`bench`] — a minimal measurement harness (warmup + timed
//!   iterations, median/mean/stddev) for the `cargo bench` targets.
//! * [`prop`] — a tiny property-testing driver: run a closure over N
//!   seeded random cases and report the failing seed on panic.
//! * [`cli`] — flag/option parsing for the `repro` binary.
//! * [`parallel`] — the persistent worker-pool executor
//!   ([`parallel::Executor`]) behind the batch numerics engine
//!   ([`crate::batch`]), with `par_chunks_mut` as the chunked
//!   data-parallel shim over it (legacy scoped-thread and serial
//!   backends kept for differential testing).
//! * [`error`] — `anyhow`-style `Result`/`Context`/`ensure!`/`bail!`.

pub mod bench;
pub mod cli;
pub mod error;
pub mod parallel;
pub mod prop;
pub mod rng;

pub use bench::Bencher;
pub use rng::Rng;
