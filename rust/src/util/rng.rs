//! Deterministic PRNG: xoshiro256** seeded via splitmix64, plus uniform
//! and Gaussian sampling.
//!
//! The paper's accuracy experiment (§IV-D) draws dot-product inputs
//! "randomly, with a Gaussian distribution, in the source precision";
//! [`Rng::gaussian`] (Box–Muller) provides that. Determinism matters:
//! every table in EXPERIMENTS.md is regenerable bit-for-bit from a seed.

/// xoshiro256** state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)];
        Rng { s }
    }

    /// Next raw 64-bit value (xoshiro256**).
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 random bits.
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`.
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire-style rejection-free for our (non-crypto) purposes.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal via Box–Muller (one value per call; the twin is
    /// discarded for simplicity — throughput is irrelevant here).
    pub fn gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            return r * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    /// Normal with the given mean and standard deviation.
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gaussian()
    }

    /// Uniform in `[lo, hi)`.
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(1234);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.gaussian();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }
}
