//! Fixed-width 768-bit signed integer arithmetic.
//!
//! The ExSdotp datapath (§III-B) manipulates significands of width
//! `2*p_dst + p_src + 5` (e.g. 77 bits for a 16→32-bit unit, and 135 bits
//! for a hypothetical 32→64-bit instance), and the *exact* reference used
//! to validate the datapath needs to align three addends over the full
//! exponent range of the destination format (over 500 bits for FP16alt
//! sources with FP32 destinations). [`WideInt`] covers both with headroom while staying a
//! cheap, fixed-size value type — no heap allocation in the simulator's
//! hot loop.

/// Number of 64-bit limbs.
pub const LIMBS: usize = 12;

/// A 768-bit two's-complement signed integer. Limb 0 is least
/// significant.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub struct WideInt(pub [u64; LIMBS]);

impl WideInt {
    /// Zero.
    pub const ZERO: WideInt = WideInt([0; LIMBS]);

    /// Construct from an unsigned 128-bit value.
    pub fn from_u128(v: u128) -> Self {
        let mut l = [0u64; LIMBS];
        l[0] = v as u64;
        l[1] = (v >> 64) as u64;
        WideInt(l)
    }

    /// Construct from a signed 128-bit value (sign-extended).
    pub fn from_i128(v: i128) -> Self {
        let mut w = Self::from_u128(v as u128);
        if v < 0 {
            for limb in w.0.iter_mut().skip(2) {
                *limb = u64::MAX;
            }
        }
        w
    }

    /// True if the value is zero.
    pub fn is_zero(&self) -> bool {
        self.0.iter().all(|&l| l == 0)
    }

    /// True if the value is negative (two's complement sign).
    pub fn is_negative(&self) -> bool {
        (self.0[LIMBS - 1] >> 63) != 0
    }

    /// Wrapping addition.
    pub fn wrapping_add(self, rhs: WideInt) -> WideInt {
        let mut out = [0u64; LIMBS];
        let mut carry = 0u64;
        for i in 0..LIMBS {
            let (s1, c1) = self.0[i].overflowing_add(rhs.0[i]);
            let (s2, c2) = s1.overflowing_add(carry);
            out[i] = s2;
            carry = (c1 as u64) + (c2 as u64);
        }
        WideInt(out)
    }

    /// Two's-complement negation.
    pub fn neg(self) -> WideInt {
        let mut out = [0u64; LIMBS];
        for i in 0..LIMBS {
            out[i] = !self.0[i];
        }
        WideInt(out).wrapping_add(WideInt::from_u128(1))
    }

    /// Wrapping subtraction.
    pub fn wrapping_sub(self, rhs: WideInt) -> WideInt {
        self.wrapping_add(rhs.neg())
    }

    /// Absolute value (as the same type; MIN overflows, never hit here).
    pub fn abs(self) -> WideInt {
        if self.is_negative() {
            self.neg()
        } else {
            self
        }
    }

    /// Logical left shift by `n` bits (0..384).
    pub fn shl(self, n: u32) -> WideInt {
        debug_assert!((n as usize) < LIMBS * 64);
        if n == 0 {
            return self;
        }
        let limb_shift = (n / 64) as usize;
        let bit_shift = n % 64;
        let mut out = [0u64; LIMBS];
        for i in (0..LIMBS).rev() {
            if i < limb_shift {
                continue;
            }
            let lo = self.0[i - limb_shift];
            let mut v = if bit_shift == 0 { lo } else { lo << bit_shift };
            if bit_shift != 0 && i > limb_shift {
                v |= self.0[i - limb_shift - 1] >> (64 - bit_shift);
            }
            out[i] = v;
        }
        WideInt(out)
    }

    /// Logical right shift by `n` bits (0..384).
    pub fn shr(self, n: u32) -> WideInt {
        debug_assert!((n as usize) < LIMBS * 64);
        if n == 0 {
            return self;
        }
        let limb_shift = (n / 64) as usize;
        let bit_shift = n % 64;
        let mut out = [0u64; LIMBS];
        for i in 0..LIMBS {
            if i + limb_shift >= LIMBS {
                break;
            }
            let hi = self.0[i + limb_shift];
            let mut v = if bit_shift == 0 { hi } else { hi >> bit_shift };
            if bit_shift != 0 && i + limb_shift + 1 < LIMBS {
                v |= self.0[i + limb_shift + 1] << (64 - bit_shift);
            }
            out[i] = v;
        }
        WideInt(out)
    }

    /// Position of the most significant set bit (0-based), or `None` if
    /// zero. Only meaningful for non-negative values.
    pub fn msb(&self) -> Option<u32> {
        for i in (0..LIMBS).rev() {
            if self.0[i] != 0 {
                return Some(i as u32 * 64 + 63 - self.0[i].leading_zeros());
            }
        }
        None
    }

    /// True if any bit strictly below position `n` is set (sticky-bit
    /// computation for rounding). Only for non-negative values.
    pub fn any_below(&self, n: u32) -> bool {
        let limb = (n / 64) as usize;
        let bit = n % 64;
        for i in 0..limb.min(LIMBS) {
            if self.0[i] != 0 {
                return true;
            }
        }
        if limb < LIMBS && bit > 0 && (self.0[limb] & ((1u64 << bit) - 1)) != 0 {
            return true;
        }
        false
    }

    /// Bit at position `n` (0-based).
    pub fn bit(&self, n: u32) -> bool {
        let limb = (n / 64) as usize;
        if limb >= LIMBS {
            return false;
        }
        (self.0[limb] >> (n % 64)) & 1 == 1
    }

    /// Extract bits `[lo, lo+len)` as a u128 (`len <= 128`). Only for
    /// non-negative values.
    pub fn extract_u128(&self, lo: u32, len: u32) -> u128 {
        debug_assert!(len <= 128);
        let shifted = self.shr(lo);
        let v = (shifted.0[0] as u128) | ((shifted.0[1] as u128) << 64);
        if len == 128 {
            v
        } else {
            v & ((1u128 << len) - 1)
        }
    }

    /// Compare magnitudes of two non-negative values.
    pub fn cmp_mag(&self, rhs: &WideInt) -> std::cmp::Ordering {
        for i in (0..LIMBS).rev() {
            match self.0[i].cmp(&rhs.0[i]) {
                std::cmp::Ordering::Equal => continue,
                o => return o,
            }
        }
        std::cmp::Ordering::Equal
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_sub_roundtrip() {
        let a = WideInt::from_u128(0x1234_5678_9abc_def0_1122_3344_5566_7788);
        let b = WideInt::from_u128(0xffff_ffff_ffff_ffff_ffff_ffff_ffff_ffff);
        let s = a.wrapping_add(b);
        assert_eq!(s.wrapping_sub(b), a);
        assert_eq!(s.wrapping_sub(a), b);
    }

    #[test]
    fn neg_and_sign() {
        let a = WideInt::from_u128(42);
        assert!(!a.is_negative());
        let n = a.neg();
        assert!(n.is_negative());
        assert_eq!(n.neg(), a);
        assert_eq!(WideInt::from_i128(-42), n);
        assert_eq!(n.abs(), a);
    }

    #[test]
    fn shift_roundtrip() {
        let a = WideInt::from_u128(0xdead_beef_cafe_babe);
        for n in [0u32, 1, 7, 63, 64, 65, 127, 128, 200, 300] {
            let x = a.shl(n);
            assert_eq!(x.shr(n), a, "shift roundtrip n={n}");
        }
    }

    #[test]
    fn shl_carries_across_limbs() {
        let a = WideInt::from_u128(1);
        let x = a.shl(LIMBS as u32 * 64 - 1);
        assert!(x.is_negative()); // bit 383 is the sign bit
        assert_eq!(x.0[LIMBS - 1], 1u64 << 63);
    }

    #[test]
    fn msb_positions() {
        assert_eq!(WideInt::ZERO.msb(), None);
        assert_eq!(WideInt::from_u128(1).msb(), Some(0));
        assert_eq!(WideInt::from_u128(0x8000_0000_0000_0000).msb(), Some(63));
        assert_eq!(WideInt::from_u128(1).shl(200).msb(), Some(200));
    }

    #[test]
    fn sticky_any_below() {
        let v = WideInt::from_u128(0b1010_0000);
        assert!(!v.any_below(5));
        assert!(v.any_below(6));
        assert!(v.any_below(8));
        let big = WideInt::from_u128(1).shl(130);
        assert!(!big.any_below(130));
        assert!(big.any_below(131));
    }

    #[test]
    fn extract_bits() {
        let v = WideInt::from_u128(0xabcd).shl(100);
        assert_eq!(v.extract_u128(100, 16), 0xabcd);
        assert_eq!(v.extract_u128(104, 8), 0xbc);
    }

    #[test]
    fn cmp_mag_ordering() {
        let a = WideInt::from_u128(5).shl(300);
        let b = WideInt::from_u128(6).shl(300);
        assert_eq!(a.cmp_mag(&b), std::cmp::Ordering::Less);
        assert_eq!(b.cmp_mag(&a), std::cmp::Ordering::Greater);
        assert_eq!(a.cmp_mag(&a), std::cmp::Ordering::Equal);
    }
}
