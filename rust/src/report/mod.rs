//! Reproduction report generators: one function per paper table/figure,
//! each returning formatted text (consumed by the `repro` CLI and
//! recorded in EXPERIMENTS.md).

use crate::api::Session;
use crate::area;
use crate::energy::{self, ComputeClass, EnergyTable};
use crate::exsdotp::table1::{supported, OpKind};
use crate::formats::{FP16, FP16ALT, FP32, FP8, FP8ALT, PAPER_FORMATS};
use crate::isa::instr::{OpWidth, ScalarFmt};
use crate::kernels::{ExecMode, GemmKind};

/// The Table II / Fig. 8 grid: kernels × sizes, paper cycle counts for
/// comparison. Sizes are `M×N` with `K = M`.
pub const TABLE2_GRID: &[(GemmKind, usize, usize, Option<u64>)] = &[
    (GemmKind::FmaF64, 64, 64, Some(37306)),
    (GemmKind::FmaSimd(ScalarFmt::S), 64, 64, Some(20195)),
    (GemmKind::FmaSimd(ScalarFmt::S), 64, 128, Some(38058)),
    (GemmKind::FmaSimd(ScalarFmt::H), 64, 64, Some(12232)),
    (GemmKind::FmaSimd(ScalarFmt::H), 64, 128, Some(20726)),
    (GemmKind::FmaSimd(ScalarFmt::H), 128, 128, Some(83890)),
    (GemmKind::ExSdotp(OpWidth::HtoS), 64, 64, Some(10968)),
    (GemmKind::ExSdotp(OpWidth::HtoS), 64, 128, Some(20169)),
    (GemmKind::ExSdotp(OpWidth::HtoS), 128, 128, Some(80709)),
    (GemmKind::ExSdotp(OpWidth::BtoH), 64, 64, Some(7019)),
    (GemmKind::ExSdotp(OpWidth::BtoH), 64, 128, Some(11165)),
    (GemmKind::ExSdotp(OpWidth::BtoH), 128, 128, Some(43244)),
    (GemmKind::ExSdotp(OpWidth::BtoH), 128, 256, Some(82501)),
];

/// One measured Table II cell.
pub struct Table2Row {
    /// Kernel family.
    pub kind: GemmKind,
    /// Problem label (`MxN`, K = M).
    pub size: String,
    /// Simulated cycles.
    pub cycles: u64,
    /// Paper cycles (where reported).
    pub paper: Option<u64>,
    /// Achieved FLOP/cycle (Fig. 8's y-axis).
    pub flop_per_cycle: f64,
}

/// Run the full Table II grid (also provides Fig. 8's series) on a
/// cycle-accurate [`Session`].
pub fn run_table2(seed: u64) -> Vec<Table2Row> {
    let session = Session::builder().mode(ExecMode::CycleAccurate).seed(seed).build();
    let mut rng = session.rng();
    TABLE2_GRID
        .iter()
        .map(|&(kind, m, n, paper)| {
            let k = m;
            let a: Vec<f64> = (0..m * k).map(|_| rng.gaussian() * 0.25).collect();
            let b: Vec<f64> = (0..k * n).map(|_| rng.gaussian() * 0.25).collect();
            let plan = session.gemm().kind(kind).dims(m, n, k).expect("Table II grid entries are valid");
            let run = plan.run_f64(&a, &b).expect("Table II operands are well-formed");
            Table2Row {
                kind,
                size: plan.kernel().size_label(),
                cycles: run.cycles.expect("cycle-accurate runs always carry cycles"),
                paper,
                flop_per_cycle: run.flop_per_cycle().unwrap_or(0.0),
            }
        })
        .collect()
}

/// Render Table II.
pub fn table2_text(rows: &[Table2Row]) -> String {
    let mut s = String::new();
    s += "Table II — GEMM execution cycles on the 8-core cluster (sizes MxN, K=M)\n";
    s += &format!(
        "{:<22} {:>9} {:>10} {:>10} {:>8} {:>11}\n",
        "kernel", "size", "cycles", "paper", "Δ%", "FLOP/cycle"
    );
    for r in rows {
        let delta = r
            .paper
            .map(|p| format!("{:+.1}", 100.0 * (r.cycles as f64 - p as f64) / p as f64))
            .unwrap_or_default();
        s += &format!(
            "{:<22} {:>9} {:>10} {:>10} {:>8} {:>11.2}\n",
            r.kind.label(),
            r.size,
            r.cycles,
            r.paper.map(|p| p.to_string()).unwrap_or_default(),
            delta,
            r.flop_per_cycle
        );
    }
    s
}

/// Render Fig. 8 (FLOP/cycle per format and size) as an ASCII chart.
pub fn fig8_text(rows: &[Table2Row]) -> String {
    let mut s = String::new();
    s += "Fig. 8 — Performance: FLOP/cycle per FP format and GEMM size\n";
    let max = rows.iter().map(|r| r.flop_per_cycle).fold(0.0, f64::max);
    for r in rows {
        let bar = "#".repeat((r.flop_per_cycle / max * 48.0).round() as usize);
        s += &format!("{:<22} {:>9} {:>7.1} |{}\n", r.kind.label(), r.size, r.flop_per_cycle, bar);
    }
    s += "(peaks: FP64 16, FP32 32, FP16 64, FP16->FP32 64, FP8->FP16 128 FLOP/cycle)\n";
    s
}

/// Render Table I (supported format combinations).
pub fn table1_text() -> String {
    let fmts = [FP32, FP16ALT, FP16, FP8, FP8ALT];
    let mut s = String::new();
    s += "Table I — source/destination format combinations of the ExSdotp unit\n";
    s += &format!("{:<9}", "src\\dst");
    for d in fmts {
        s += &format!("{:<16}", d.name());
    }
    s += "\n";
    for src in fmts {
        s += &format!("{:<9}", src.name());
        for dst in fmts {
            let mut cell = Vec::new();
            if supported(src, dst, OpKind::ExSdotp) {
                cell.push("ExSdotp/ExVsum");
            }
            if supported(src, dst, OpKind::Vsum) {
                cell.push("Vsum");
            }
            let cell = if cell.is_empty() { "-".to_string() } else { cell.join("+") };
            s += &format!("{:<16}", cell);
        }
        s += "\n";
    }
    s
}

/// Render Fig. 1 (format bit layouts).
pub fn formats_text() -> String {
    let mut s = String::new();
    s += "Fig. 1 — floating-point formats (exponent | mantissa bits)\n";
    for f in PAPER_FORMATS {
        s += &format!(
            "{:<8} 1 + {:>2}e + {:>2}m = {:>2} bits   bias {:>4}   max |x| ≈ {:.3e}\n",
            f.name(),
            f.exp_bits,
            f.man_bits,
            f.width(),
            f.bias(),
            crate::softfloat::to_f64(f.max_finite(false), f)
        );
    }
    s
}

/// Render Fig. 2 (register-file utilization argument).
pub fn fig2_text() -> String {
    let mut s = String::new();
    s += "Fig. 2 — register-file utilization per 64-bit register triple (rs1, rs2, rd)\n";
    s += "ExFMA  (16->32): reads 2x FP16 + 2x FP32, computes 1 FMA  =  2 FLOP/cycle\n";
    s += "ExSdotp(16->32): reads 8x FP16 + 2x FP32, computes 2 dotp =  8 FLOP/cycle\n";
    s += "ExSdotp(8->16):  reads 16x FP8 + 4x FP16, computes 4 dotp = 16 FLOP/cycle\n";
    s += "The expanding dot product consumes the full operand bandwidth (Fig. 2 right).\n";
    s
}

/// Render Fig. 7a (fused vs cascade area/delay).
pub fn fig7a_text() -> String {
    let mut s = String::new();
    s += "Fig. 7a — ExSdotp unit vs a cascade of two ExFMA units (area model)\n";
    for (src, dst) in [(FP16, FP32), (FP8, FP16)] {
        let fused = area::exsdotp_unit_ge(src, dst);
        let casc = 2.0 * area::exfma_unit_ge(src, dst);
        let dr = area::exsdotp_delay(src, dst) / area::exfma_cascade_delay(src, dst);
        s += &format!(
            "{:>5} -> {:<7}  fused {:>7.0} GE  cascade {:>7.0} GE  area ratio {:.2}  delay ratio {:.2}\n",
            src.name(),
            dst.name(),
            fused,
            casc,
            fused / casc,
            dr
        );
    }
    s += "(paper: ~30% area and critical-path reduction)\n";
    s
}

/// Render Fig. 7b (FPU area breakdown).
pub fn fig7b_text() -> String {
    let mut s = String::new();
    s += "Fig. 7b — extended-FPU area breakdown (calibrated gate-count model)\n";
    let total = area::fpu_total_kge();
    for (name, kge) in area::fpu_breakdown_kge() {
        s += &format!("{:<11} {:>6.1} kGE  ({:>4.1}%)\n", name, kge, 100.0 * kge / total);
    }
    s += &format!("{:<11} {:>6.1} kGE  (paper: 165 kGE, SDOTP 27%)\n", "total", total);
    s += &format!("cluster: {:.2} MGE (paper: 4.3 MGE)\n", area::cluster_total_mge());
    s
}

/// Render Table IV (accuracy vs FP64 golden). Single-draw rows run on
/// the descriptor-path ([`ExecMode::CycleAccurate`]) accumulation
/// plans, the averaged sweep on the functional fast path — the two are
/// bit-identical (see [`crate::accuracy::sweep_seed`]), so the rendered
/// numbers match the pre-API `accuracy::table4` / `table4_averaged`
/// output exactly.
pub fn table4_text(seed: u64) -> String {
    let single = Session::builder().mode(ExecMode::CycleAccurate).seed(seed).build();
    let sweep = Session::builder().mode(ExecMode::Functional).seed(seed).build();
    let mut s = String::new();
    s += "Table IV — relative error vs FP64 golden (single draw, like the paper)\n";
    s += &format!("{:<10} {:<14} {:>6} {:>14} {:>14}\n", "op", "format", "n", "ExSdotp", "ExFMA");
    for (src, dst) in crate::accuracy::TABLE4_PAIRS {
        for n in crate::accuracy::TABLE4_NS {
            let p = single
                .accumulate()
                .src(src)
                .acc(dst)
                .n(n)
                .expect("Table IV pairs are valid")
                .run();
            s += &format!(
                "{:<10} {:<14} {:>6} {:>14.2e} {:>14.2e}\n",
                "accum",
                format!("{}->{}", src.name(), dst.name()),
                p.n,
                p.err_exsdotp,
                p.err_exfma
            );
        }
    }
    s += "\nAveraged over 32 draws (reproduction robustness check):\n";
    for (src, dst) in crate::accuracy::TABLE4_PAIRS {
        for n in crate::accuracy::TABLE4_NS {
            let (f, c) = sweep
                .accumulate()
                .src(src)
                .acc(dst)
                .n(n)
                .expect("Table IV pairs are valid")
                .mean(32);
            s += &format!(
                "{:<10} {:<14} {:>6} {:>14.2e} {:>14.2e}\n",
                "mean",
                format!("{}->{}", src.name(), dst.name()),
                n,
                f,
                c
            );
        }
    }
    s
}

/// Render Table III (SoA FPU + cluster comparison rows we reproduce).
pub fn table3_text(seed: u64) -> String {
    let t = EnergyTable::default();
    let mut s = String::new();
    s += "Table III — FPU rows (model) and cluster rows (simulated GEMMs)\n\n";
    s += "FPU peaks (1.26 GHz, 0.8 V):\n";
    for (label, class, paper_perf, paper_eff) in [
        ("exFP8  (SIMD ExSdotp 8->16)", ComputeClass::Sdotp(OpWidth::BtoH), "16 FLOP/cyc", "1631"),
        ("exFP16 (SIMD ExSdotp 16->32)", ComputeClass::Sdotp(OpWidth::HtoS), "8 FLOP/cyc", "-"),
        ("FP16   (SIMD FMA)", ComputeClass::Fma(ScalarFmt::H), "8 FLOP/cyc", "-"),
        ("FP64   (FMA)", ComputeClass::Fma(ScalarFmt::D), "2 FLOP/cyc", "-"),
    ] {
        s += &format!(
            "  {:<30} {:>6.1} GFLOPS peak ({})   {:>7.0} GFLOPS/W (paper {})\n",
            label,
            energy::fpu_peak_gflops(class),
            paper_perf,
            energy::fpu_peak_gflops_per_w(class, &t),
            paper_eff
        );
    }

    s += "\nCluster rows (simulated GEMM, energy model):\n";
    let session = Session::builder().mode(ExecMode::CycleAccurate).seed(seed).build();
    let mut rng = session.rng();
    let mut run = |kind: GemmKind, m: usize, n: usize, class: ComputeClass, label: &str, paper: &str| {
        let k = m;
        let a: Vec<f64> = (0..m * k).map(|_| rng.gaussian() * 0.25).collect();
        let b: Vec<f64> = (0..k * n).map(|_| rng.gaussian() * 0.25).collect();
        let plan = session.gemm().kind(kind).dims(m, n, k).expect("Table III rows are valid");
        let r = plan.run_f64(&a, &b).expect("Table III operands are well-formed");
        let stats = r.stats.expect("cycle-accurate runs collect stats");
        let e = energy::estimate(&stats, r.cycles.unwrap_or(0), class, &t);
        format!(
            "  {:<34} {:>6.1} GFLOPS  {:>6.0} mW  {:>6.0} GFLOPS/W   (paper: {})\n",
            label, e.gflops, e.avg_mw, e.gflops_per_w, paper
        )
    };
    s += &run(
        GemmKind::ExSdotp(OpWidth::BtoH),
        128,
        256,
        ComputeClass::Sdotp(OpWidth::BtoH),
        "MiniFloat-NN, FP8->FP16 128x256",
        "128 GFLOPS, 224 mW, 575 GFLOPS/W",
    );
    s += &run(
        GemmKind::ExSdotp(OpWidth::HtoS),
        128,
        128,
        ComputeClass::Sdotp(OpWidth::HtoS),
        "MiniFloat-NN, FP16->FP32 128x128",
        "-",
    );
    s += &run(
        GemmKind::FmaF64,
        64,
        64,
        ComputeClass::Fma(ScalarFmt::D),
        "baseline FP64 64x64",
        "80 GFLOPS/W (22nm Snitch)",
    );
    s
}

// ------------------------------------------------------------- roofline

/// Render the SoC roofline sweep: FLOPS/cycle and GFLOPS/W vs cluster
/// count × expanding format pair (what `repro roofline` prints).
pub fn roofline_text(rows: &[crate::soc::RooflineRow]) -> String {
    let mut s = String::new();
    s += "SoC roofline — achieved FLOP/cycle and GFLOPS/W vs cluster count\n";
    s += &format!(
        "{:<22} {:>4} {:>11} {:>9} {:>10} {:>6} {:>8} {:>9} {:>9} {:>8}\n",
        "kernel", "ncl", "size", "cycles", "FLOP/cyc", "util%", "GFLOPS", "clW", "socW", "FLOP/B"
    );
    for r in rows {
        let fmt_eff = |v: Option<f64>| v.map(|e| format!("{e:.0}")).unwrap_or_else(|| "-".into());
        s += &format!(
            "{:<22} {:>4} {:>11} {:>9} {:>10.1} {:>6.1} {:>8.1} {:>9} {:>9} {:>8.1}\n",
            r.kind.label(),
            r.n_clusters,
            format!("{}x{}x{}", r.m, r.n, r.k),
            r.total_cycles,
            r.flop_per_cycle,
            100.0 * r.utilization,
            r.gflops,
            fmt_eff(r.cluster_gflops_per_w),
            fmt_eff(r.soc_gflops_per_w),
            r.arith_intensity
        );
    }
    s += "(clW = compute-region GFLOPS/W, paper anchor 575 at 1 cluster FP8; \
          socW adds L2/interconnect/idle-static)\n";
    s
}

/// Render the roofline sweep as one JSON line (the `--json` output and
/// the BENCH_cluster.json trajectory record body).
pub fn roofline_json(rows: &[crate::soc::RooflineRow]) -> String {
    let cells: Vec<String> = rows
        .iter()
        .map(|r| {
            let opt = |v: Option<f64>| v.map(|e| format!("{e:.3}")).unwrap_or_else(|| "null".into());
            format!(
                "{{\"kernel\":\"{}\",\"clusters\":{},\"m\":{},\"n\":{},\"k\":{},\
                 \"total_cycles\":{},\"compute_cycles\":{},\"dma_stall_cycles\":{},\
                 \"flops\":{},\"flop_per_cycle\":{:.3},\"utilization\":{:.4},\
                 \"gflops\":{:.3},\"cluster_gflops_per_w\":{},\"soc_gflops_per_w\":{},\
                 \"l2_bytes\":{},\"arith_intensity\":{:.3}}}",
                r.kind.label(),
                r.n_clusters,
                r.m,
                r.n,
                r.k,
                r.total_cycles,
                r.compute_cycles,
                r.dma_stall_cycles,
                r.flops,
                r.flop_per_cycle,
                r.utilization,
                r.gflops,
                opt(r.cluster_gflops_per_w),
                opt(r.soc_gflops_per_w),
                r.l2_bytes,
                r.arith_intensity
            )
        })
        .collect();
    format!("{{\"roofline\":[{}]}}", cells.join(","))
}

// ------------------------------------------------------ native training

/// Compact loss-curve summary for a native training run: ~10 evenly
/// spaced rows of (step, loss, loss scale) plus overflow-skip counts —
/// the text the `repro train --engine native` summary and the training
/// example print.
pub fn train_curve_text(history: &[crate::nn::StepRecord]) -> String {
    if history.is_empty() {
        return "(no training steps recorded)\n".to_string();
    }
    let mut s = String::from("step     loss      scale   skipped-so-far\n");
    let rows = 10usize.min(history.len());
    let stride = ((history.len() + rows - 1) / rows).max(1);
    let mut skipped = 0usize;
    for (i, r) in history.iter().enumerate() {
        skipped += r.skipped as usize;
        if i % stride == 0 || i + 1 == history.len() {
            s += &format!("{:>4}  {:>9.4}  {:>7}   {:>3}\n", r.step, r.loss, r.scale, skipped);
        }
    }
    s
}

/// Human-readable summary of a serving run: throughput, batching,
/// latency percentiles (virtual ticks) and per-tenant GEMM routing —
/// what `repro serve` prints after a trace replay.
pub fn serve_stats_text(stats: &crate::serve::ServeStats, tenant_names: &[String]) -> String {
    let mut s = String::new();
    s += &format!(
        "requests     : {} completed / {} submitted over {} ticks ({:.2} req/tick)\n",
        stats.completed,
        stats.submitted,
        stats.ticks,
        stats.throughput_per_tick()
    );
    s += &format!(
        "batching     : {} dispatches, mean batch {:.1}, histogram {}\n",
        stats.batches,
        stats.mean_batch(),
        stats
            .batch_hist
            .iter()
            .map(|(size, n)| format!("{size}x{n}"))
            .collect::<Vec<_>>()
            .join(" ")
    );
    s += &format!(
        "waves        : {} layer waves, mean {:.1} rows/wave, goodput {:.2} req/tick\n",
        stats.waves,
        stats.mean_wave_rows(),
        stats.goodput_per_tick()
    );
    let (p50, p95, p99) = stats.latency_percentiles();
    s += &format!(
        "latency      : p50 {p50} / p95 {p95} / p99 {p99} ticks, {} deadline misses\n",
        stats.deadline_misses
    );
    s += &format!(
        "queue depth  : max {}, mean {:.1}\n",
        stats.queue_depth_max,
        stats.mean_queue_depth()
    );
    if stats.shed() > 0 {
        s += &format!(
            "shed         : {} submissions ({} rate-limited, {} queue-full, {:.1}% of offered)\n",
            stats.shed(),
            stats.shed_rate_limited,
            stats.shed_queue_full,
            stats.shed_rate() * 100.0
        );
    }
    for (t, c) in stats.tenants.iter().enumerate() {
        let name = tenant_names.get(t).map(|n| n.as_str()).unwrap_or("?");
        // "100%" means exactly all-packed — a single fallback run must
        // not round away (the smoke test keys on this string).
        let packed = if c.gemm_calls == 0 {
            "idle".to_string()
        } else if c.packed_runs == c.gemm_calls {
            "100% packed fast path".to_string()
        } else {
            format!("{}/{} packed fast path", c.packed_runs, c.gemm_calls)
        };
        s += &format!("tenant {name:<8}: {} GemmPlan runs, {packed}\n", c.gemm_calls);
    }
    s
}

// ------------------------------------------------- accuracy-at-scale

/// Render the accuracy-at-scale matrix (`repro accuracy`): spiral
/// training per policy, the big-K dot probe, and the SR determinism
/// verdict.
pub fn accuracy_text(sweep: &crate::numerics::AccuracySweep) -> String {
    let mut s = String::new();
    s += &format!(
        "Accuracy-at-scale matrix — spiral training ({} steps, seed {})\n",
        sweep.steps, sweep.seed
    );
    s += &format!(
        "{:<9} {:>8} {:>7} {:>10} {:>11} {:>8}\n",
        "policy", "rounding", "scaled", "accuracy", "final loss", "skipped"
    );
    for t in &sweep.train {
        s += &format!(
            "{:<9} {:>8} {:>7} {:>9.1}% {:>11.4} {:>8}\n",
            t.policy,
            t.rounding,
            if t.scaled { "yes" } else { "no" },
            100.0 * t.accuracy,
            t.final_loss,
            t.skipped
        );
    }
    s += &format!(
        "\nBig-K dot probe — FP8->FP16 ExSdotp, {}x{}x{} vs f64 reference\n",
        crate::numerics::sweep::PROBE_M,
        crate::numerics::sweep::PROBE_N,
        crate::numerics::sweep::PROBE_K
    );
    s += &format!("{:<9} {:>9} {:>13} {:>13}\n", "rounding", "chunk", "max |err|", "mean |err|");
    for d in &sweep.dot {
        s += &format!(
            "{:<9} {:>9} {:>13.3e} {:>13.3e}\n",
            d.rounding,
            d.chunk.map(|c| c.to_string()).unwrap_or_else(|| "naive".into()),
            d.max_abs_err,
            d.mean_abs_err
        );
    }
    s += &format!(
        "\nSR bit-determinism across thread budgets {{1, 4, 7}}: {}\n",
        if sweep.sr_deterministic { "PASS" } else { "FAIL" }
    );
    s
}

/// The machine-readable companion of [`accuracy_text`] (one JSON line —
/// the `--json` output and the BENCH_accuracy.json body).
pub fn accuracy_json(sweep: &crate::numerics::AccuracySweep) -> String {
    let train: Vec<String> = sweep
        .train
        .iter()
        .map(|t| {
            format!(
                "{{\"policy\":\"{}\",\"rounding\":\"{}\",\"scaled\":{},\
                 \"accuracy\":{:.6},\"final_loss\":{:.6},\"skipped\":{}}}",
                t.policy, t.rounding, t.scaled, t.accuracy, t.final_loss, t.skipped
            )
        })
        .collect();
    let dot: Vec<String> = sweep
        .dot
        .iter()
        .map(|d| {
            format!(
                "{{\"rounding\":\"{}\",\"chunk\":{},\"max_abs_err\":{:.6e},\
                 \"mean_abs_err\":{:.6e}}}",
                d.rounding,
                d.chunk.map(|c| c.to_string()).unwrap_or_else(|| "null".into()),
                d.max_abs_err,
                d.mean_abs_err
            )
        })
        .collect();
    format!(
        "{{\"steps\":{},\"seed\":{},\"probe\":{{\"m\":{},\"n\":{},\"k\":{},\"chunk\":{}}},\
         \"sr_deterministic\":{},\"train\":[{}],\"dot\":[{}]}}",
        sweep.steps,
        sweep.seed,
        crate::numerics::sweep::PROBE_M,
        crate::numerics::sweep::PROBE_N,
        crate::numerics::sweep::PROBE_K,
        crate::numerics::sweep::PROBE_CHUNK,
        sweep.sr_deterministic,
        train.join(","),
        dot.join(",")
    )
}

// ------------------------------------------------------- observability

/// Human-readable roll-up of an observability snapshot — the
/// `--metrics` report every instrumented subcommand appends. Sections
/// whose counters are all zero (pillars the run never touched) are
/// omitted, so a pure-GEMM run prints two lines, not an empty SoC/serve
/// scaffold.
pub fn obs_text(snap: &crate::obs::metrics::Snapshot) -> String {
    let p = crate::obs::prof::profile(snap);
    let mut s = String::from("== observability roll-up ==\n");
    if p.plan_runs > 0 {
        s += &format!(
            "plans        : {} runs, {} packed ({:.0}% fast path), {} compiled\n",
            p.plan_runs,
            p.plan_packed,
            100.0 * p.packed_rate(),
            snap.counter("api.plan.compiles"),
        );
    }
    if p.tier_swar + p.tier_scalar > 0 {
        s += &format!(
            "lane tiers   : {} SWAR / {} scalar dispatches ({:.0}% SWAR), {} blocked / {} simple loops\n",
            p.tier_swar,
            p.tier_scalar,
            100.0 * p.swar_rate(),
            p.gemm_blocked,
            p.gemm_simple,
        );
    }
    if p.plan_builds + p.plan_reuses > 0 {
        s += &format!(
            "plan cache   : {} builds, {} reuses\n",
            p.plan_builds, p.plan_reuses
        );
    }
    let steps = snap.counter("train.steps");
    if steps > 0 {
        s += &format!(
            "training     : {} steps, {} overflow skips, {} scale growths\n",
            steps, p.scale_skips, p.scale_growths
        );
    }
    if p.soc_total > 0 {
        let (compute, stall, idle) = p.soc_shares();
        s += &format!(
            "soc cycles   : {} total — {:.0}% compute / {:.0}% dma-stall / {:.0}% other\n",
            p.soc_total,
            100.0 * compute,
            100.0 * stall,
            100.0 * idle,
        );
        s += &format!(
            "l2 traffic   : {} B read, {} B written, {} transfers\n",
            snap.counter("soc.l2.read_bytes"),
            snap.counter("soc.l2.write_bytes"),
            snap.counter("soc.l2.transfers"),
        );
    }
    if p.serve_submitted > 0 {
        s += &format!(
            "serving      : {}/{} completed over {} ticks, {} batches, {} deadline misses\n",
            p.serve_completed, p.serve_submitted, p.serve_ticks, p.serve_batches, p.serve_deadline_misses
        );
        if let Some((p50, p95, p99)) = p.serve_latency {
            s += &format!("serve latency: p50 ≤{p50} / p95 ≤{p95} / p99 ≤{p99} ticks (bucket upper edges)\n");
        }
        for t in &p.tenants {
            s += &format!(
                "tenant {:<8}: {} GEMM calls, {} packed\n",
                t.name, t.gemm_calls, t.packed_runs
            );
        }
    }
    if s.ends_with("==\n") {
        s += "(no instrumented work recorded)\n";
    }
    s
}

/// The machine-readable companion of [`obs_text`]: the raw snapshot
/// JSON (byte-stable; see `obs::metrics::Snapshot::json`). Kept as a
/// report entry point so callers never format snapshots ad hoc.
pub fn obs_json(snap: &crate::obs::metrics::Snapshot) -> String {
    snap.json()
}
